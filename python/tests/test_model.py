"""Model-level tests: shapes, parameter contract, mode behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as model_lib
from compile import train as train_lib
from compile.configs import (
    BIT_SERIAL,
    MODE_AMS,
    MODE_BASELINE,
    MODE_OURS,
    ModelConfig,
    PimConfig,
    QuantConfig,
    TrainConfig,
)

QCFG = QuantConfig()
TCFG = TrainConfig(batch=4)


def _mk(mcfg=None, mode=MODE_BASELINE, scheme=BIT_SERIAL, uc=8):
    mcfg = mcfg or ModelConfig(depth_n=1, width=8, image=16)
    params, state = model_lib.model_init(jax.random.PRNGKey(0), mcfg)
    apply = train_lib.make_apply(mcfg, QCFG, PimConfig(scheme=scheme, unit_channels=uc), mode, TCFG)
    x = jnp.asarray(np.random.default_rng(0).uniform(0, 1, (4, mcfg.image, mcfg.image, 3)), jnp.float32)
    return mcfg, params, state, apply, x


def _run(apply, params, state, x, train=False, levels=127.0, eta=1.0, sigma=0.0):
    return apply(
        params, state, x, jnp.float32(levels), jnp.float32(eta),
        jnp.float32(sigma), jax.random.PRNGKey(0), train,
    )


class TestShapes:
    @pytest.mark.parametrize("depth_n,width", [(1, 8), (2, 8), (1, 16)])
    def test_resnet_logits(self, depth_n, width):
        mcfg = ModelConfig(depth_n=depth_n, width=width, image=16)
        _, params, state, apply, x = _mk(mcfg)
        logits, ns = _run(apply, params, state, x)
        assert logits.shape == (4, 10)
        assert set(ns.keys()) == set(state.keys())

    def test_vgg_logits(self):
        mcfg = ModelConfig(arch="vgg11", depth_n=0, width=8, image=16)
        _, params, state, apply, x = _mk(mcfg)
        logits, _ = _run(apply, params, state, x)
        assert logits.shape == (4, 10)

    def test_cifar100_head(self):
        mcfg = ModelConfig(depth_n=1, width=8, image=16, classes=100)
        _, params, state, apply, x = _mk(mcfg)
        logits, _ = _run(apply, params, state, x)
        assert logits.shape == (4, 100)


class TestParamContract:
    def test_flatten_roundtrip(self):
        mcfg = ModelConfig(depth_n=2, width=8, image=16)
        params, state = model_lib.model_init(jax.random.PRNGKey(1), mcfg)
        flat = model_lib.flatten_tree(params)
        rebuilt = model_lib.unflatten_like(params, [v for _, v in flat])
        for (k1, v1), (k2, v2) in zip(flat, model_lib.flatten_tree(rebuilt)):
            assert k1 == k2
            np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))

    def test_flatten_deterministic_order(self):
        mcfg = ModelConfig(depth_n=1, width=8, image=16)
        p1, _ = model_lib.model_init(jax.random.PRNGKey(0), mcfg)
        p2, _ = model_lib.model_init(jax.random.PRNGKey(9), mcfg)
        assert [k for k, _ in model_lib.flatten_tree(p1)] == [
            k for k, _ in model_lib.flatten_tree(p2)
        ]

    def test_resnet20_param_count(self):
        """The full-size config reproduces ResNet20's ~0.27M params."""
        mcfg = ModelConfig(depth_n=3, width=16, image=32)
        params, _ = model_lib.model_init(jax.random.PRNGKey(0), mcfg)
        n = sum(int(np.prod(v.shape)) for _, v in model_lib.flatten_tree(params))
        assert 0.25e6 < n < 0.30e6


class TestModes:
    def test_ours_differs_from_baseline_at_low_bpim(self):
        _, params, state, apply_b, x = _mk(mode=MODE_BASELINE)
        *_, apply_o, _ = _mk(mode=MODE_OURS)
        lb, _ = _run(apply_b, params, state, x)
        lo, _ = _run(apply_o, params, state, x, levels=7.0)
        assert not np.allclose(np.asarray(lb), np.asarray(lo), atol=1e-3)

    def test_ours_converges_to_baseline_at_high_bpim(self):
        _, params, state, apply_b, x = _mk(mode=MODE_BASELINE)
        *_, apply_o, _ = _mk(mode=MODE_OURS)
        lb, _ = _run(apply_b, params, state, x)
        lo, _ = _run(apply_o, params, state, x, levels=2.0**22 - 1)
        np.testing.assert_allclose(np.asarray(lb), np.asarray(lo), atol=5e-3)

    def test_ams_noise_only_in_training(self):
        _, params, state, apply, x = _mk(mode=MODE_AMS)
        l1, _ = _run(apply, params, state, x, train=False, sigma=0.5)
        l2, _ = _run(apply, params, state, x, train=False, sigma=0.5)
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
        lt, _ = _run(apply, params, state, x, train=True, sigma=0.5)
        assert not np.allclose(np.asarray(l1), np.asarray(lt), atol=1e-4)

    def test_bn_state_updates_in_training_only(self):
        _, params, state, apply, x = _mk()
        _, ns_eval = _run(apply, params, state, x, train=False)
        np.testing.assert_array_equal(
            np.asarray(ns_eval["bn0"]["mean"]), np.asarray(state["bn0"]["mean"])
        )
        _, ns_train = _run(apply, params, state, x, train=True)
        assert not np.allclose(
            np.asarray(ns_train["bn0"]["mean"]), np.asarray(state["bn0"]["mean"])
        )


class TestTrainStep:
    def test_loss_decreases_on_fixed_batch(self):
        """Over-fitting a single batch must drive the loss down (all modes)."""
        mcfg = ModelConfig(depth_n=1, width=8, image=16)
        for mode, levels in ((MODE_BASELINE, 127.0), (MODE_OURS, 127.0)):
            step, meta = train_lib.make_train_step(
                mcfg, QCFG, PimConfig(scheme=BIT_SERIAL, unit_channels=8), mode,
                TrainConfig(batch=8),
            )
            init = train_lib.make_init(mcfg)
            outs = list(jax.jit(init)(jnp.int32(0)))
            n_p, n_s = len(meta["param_paths"]), len(meta["state_paths"])
            rng = np.random.default_rng(0)
            x = jnp.asarray(rng.uniform(0, 1, (8, 16, 16, 3)), jnp.float32)
            y = jnp.asarray(rng.integers(0, 10, 8), jnp.int32)
            jstep = jax.jit(step)
            losses = []
            for i in range(30):
                res = jstep(
                    *outs, x, y, jnp.float32(0.05), jnp.float32(levels),
                    jnp.float32(1.03), jnp.float32(0.0), jnp.int32(i),
                )
                outs = list(res[: 2 * n_p + n_s])
                losses.append(float(res[-2]))
            assert losses[-1] < losses[0] * 0.7, (mode, losses[0], losses[-1])

    def test_eval_step_counts(self):
        mcfg = ModelConfig(depth_n=1, width=8, image=16)
        estep = train_lib.make_eval_step(
            mcfg, QCFG, PimConfig(), MODE_BASELINE, TrainConfig(batch=8)
        )
        params, state = model_lib.model_init(jax.random.PRNGKey(0), mcfg)
        p = [v for _, v in model_lib.flatten_tree(params)]
        s = [v for _, v in model_lib.flatten_tree(state)]
        x = jnp.zeros((8, 16, 16, 3))
        y = jnp.zeros((8,), jnp.int32)
        loss_sum, acc = jax.jit(estep)(*p, *s, x, y, jnp.float32(127.0), jnp.float32(1.0))
        assert 0.0 <= float(acc) <= 8.0
        assert np.isfinite(float(loss_sum))
