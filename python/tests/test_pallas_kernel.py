"""Layer-1 Pallas kernel vs the oracle: shapes/dtypes swept with hypothesis.

This is the CORE correctness signal for the kernel — `interpret=True`
numerics must match the paper-literal reference for every scheme, every
resolution, and across tile boundaries (block_m smaller than M exercises the
grid accumulation path).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.configs import SCHEMES, QuantConfig
from compile.kernels import ref
from compile.kernels.pim_mac import pim_matmul_pallas


def _case(rng, cfg, m_, g_, n_, o_):
    a_int = rng.integers(0, cfg.a_levels + 1, (m_, g_, n_))
    w_int = rng.integers(-cfg.w_levels, cfg.w_levels + 1, (g_, n_, o_))
    return a_int, w_int


@pytest.mark.parametrize("scheme", SCHEMES)
@pytest.mark.parametrize("b_pim", [3, 7])
def test_pallas_matches_ref(scheme, b_pim):
    cfg = QuantConfig()
    rng = np.random.default_rng(len(scheme) * 1000 + b_pim)
    a_int, w_int = _case(rng, cfg, 8, 2, 18, 4)
    levels = 2**b_pim - 1
    y_ref = ref.pim_matmul_ref(a_int, w_int, levels, scheme, cfg)
    y_pl = np.asarray(
        pim_matmul_pallas(
            jnp.asarray(a_int / cfg.a_levels, jnp.float32),
            jnp.asarray(w_int / cfg.w_levels, jnp.float32),
            jnp.asarray([float(levels)]),
            scheme,
            cfg,
            block_m=4,  # force multi-tile grid + accumulation
        )
    )
    np.testing.assert_allclose(y_pl, y_ref, atol=2e-5)


@given(
    scheme=st.sampled_from(SCHEMES),
    b_pim=st.integers(2, 10),
    m_dac=st.sampled_from([1, 2, 4]),
    m_=st.sampled_from([2, 4, 8]),
    g_=st.integers(1, 3),
    n_=st.integers(2, 24),
    o_=st.integers(1, 5),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=40, deadline=None)
def test_pallas_matches_ref_hypothesis(scheme, b_pim, m_dac, m_, g_, n_, o_, seed):
    cfg = QuantConfig(m=m_dac)
    rng = np.random.default_rng(seed)
    a_int, w_int = _case(rng, cfg, m_, g_, n_, o_)
    levels = 2**b_pim - 1
    y_ref = ref.pim_matmul_ref(a_int, w_int, levels, scheme, cfg)
    y_pl = np.asarray(
        pim_matmul_pallas(
            jnp.asarray(a_int / cfg.a_levels, jnp.float32),
            jnp.asarray(w_int / cfg.w_levels, jnp.float32),
            jnp.asarray([float(levels)]),
            scheme,
            cfg,
            block_m=m_,
        )
    )
    np.testing.assert_allclose(y_pl, y_ref, atol=5e-5)


def test_block_m_invariance():
    """The grid decomposition must not change the numbers."""
    cfg = QuantConfig()
    rng = np.random.default_rng(9)
    a_int, w_int = _case(rng, cfg, 16, 2, 18, 4)
    outs = []
    for bm in (2, 4, 16):
        outs.append(
            np.asarray(
                pim_matmul_pallas(
                    jnp.asarray(a_int / 15.0, jnp.float32),
                    jnp.asarray(w_int / 7.0, jnp.float32),
                    jnp.asarray([127.0]),
                    "bit_serial",
                    cfg,
                    block_m=bm,
                )
            )
        )
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[1], outs[2])


def test_rejects_ragged_m():
    cfg = QuantConfig()
    with pytest.raises(ValueError):
        pim_matmul_pallas(
            jnp.zeros((10, 1, 9)), jnp.zeros((1, 9, 2)), jnp.asarray([7.0]),
            "bit_serial", cfg, block_m=4,
        )
