"""GSTE backward (Theorem 1) and the rescaling techniques (§3.3, Eqn. 8)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import pim
from compile.configs import BIT_SERIAL, NATIVE, SCHEMES, QuantConfig
from compile.rescale import forward_eta

CFG = QuantConfig()


def _case(seed, m_=6, g_=2, n_=18, o_=4):
    rng = np.random.default_rng(seed)
    a_u = jnp.asarray(rng.integers(0, 16, (m_, g_, n_)) / 15.0, jnp.float32)
    w_u = jnp.asarray(rng.integers(-7, 8, (g_, n_, o_)) / 7.0, jnp.float32)
    return a_u, w_u


@pytest.mark.parametrize("scheme", SCHEMES)
def test_gste_grad_equals_scaled_matmul_grad(scheme):
    """Theorem 1: backward of pim_matmul == ξ·η × backward of exact matmul."""
    a_u, w_u = _case(0)
    levels, eta = jnp.float32(31.0), jnp.float32(2.0)
    g = jnp.ones((6, 4), jnp.float32)

    def f(a, w):
        return jnp.sum(pim.pim_matmul(a, w, levels, eta, scheme, CFG, True) * g)

    da, dw = jax.grad(f, argnums=(0, 1))(a_u, w_u)

    # ξ recomputed exactly as in _pim_matmul_fwd
    y_pim = pim.pim_forward(a_u, w_u, levels, scheme, CFG)
    y_ex = pim.digital_forward(a_u, w_u)
    xi = float(jnp.sqrt((jnp.var(y_pim) + 1e-12) / (jnp.var(y_ex) + 1e-12)))

    def f_exact(a, w):
        return jnp.sum(pim.digital_forward(a, w) * g)

    da_e, dw_e = jax.grad(f_exact, argnums=(0, 1))(a_u, w_u)
    np.testing.assert_allclose(np.asarray(da), 2.0 * xi * np.asarray(da_e), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(dw), 2.0 * xi * np.asarray(dw_e), rtol=1e-4)


def test_no_bwd_rescale_sets_xi_one():
    a_u, w_u = _case(1)
    levels, eta = jnp.float32(7.0), jnp.float32(1.0)

    def f(a):
        return jnp.sum(pim.pim_matmul(a, w_u, levels, eta, BIT_SERIAL, CFG, False))

    da = jax.grad(f)(a_u)

    def f_exact(a):
        return jnp.sum(pim.digital_forward(a, w_u))

    np.testing.assert_allclose(
        np.asarray(da), np.asarray(jax.grad(f_exact)(a_u)), rtol=1e-5
    )


def test_xi_tracks_scale_enlargement():
    """ξ > 1 at very low b_PIM (the scale-enlarging effect, Appendix A3)."""
    a_u, w_u = _case(2, m_=64, n_=144, o_=16)
    y3 = pim.pim_forward(a_u, w_u, jnp.float32(7.0), BIT_SERIAL, CFG)
    y_ex = pim.digital_forward(a_u, w_u)
    xi = float(jnp.std(y3) / jnp.std(y_ex))
    assert xi > 1.2


def test_hyperparams_get_zero_grad():
    a_u, w_u = _case(3)

    def f(levels, eta):
        return jnp.sum(pim.pim_matmul(a_u, w_u, levels, eta, NATIVE, CFG, True))

    dl, de = jax.grad(f, argnums=(0, 1))(jnp.float32(31.0), jnp.float32(5.0))
    assert float(dl) == 0.0 and float(de) == 0.0


def test_forward_eta_scales_output():
    a_u, w_u = _case(4)
    y1 = pim.pim_matmul(a_u, w_u, jnp.float32(31.0), jnp.float32(1.0), NATIVE, CFG, True)
    y9 = pim.pim_matmul(a_u, w_u, jnp.float32(31.0), jnp.float32(9.0), NATIVE, CFG, True)
    np.testing.assert_allclose(np.asarray(y9), 9.0 * np.asarray(y1), rtol=1e-5)


class TestRescaleTable:
    """Table A1 pinning — mirrored by rust/src/config/rescale.rs."""

    def test_values(self):
        assert forward_eta("native", 3) == 100.0
        assert forward_eta("native", 4) == 20.0
        assert forward_eta("native", 5) == 1.0
        assert forward_eta("differential", 6) == 1000.0
        assert forward_eta("bit_serial", 4) == 30.0
        assert forward_eta("bit_serial", 7) == 1.03

    def test_extremes(self):
        assert forward_eta("bit_serial", 10) == 1.0
        assert forward_eta("bit_serial", 2) == 100.0
