"""The vectorized jnp PIM model vs the loop-level oracle (kernels/ref.py).

Hypothesis sweeps shapes, bit-widths, DAC resolutions and ADC resolutions —
the jnp twin must agree with the paper-literal oracle to float precision on
every scheme.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import pim
from compile.configs import BIT_SERIAL, DIFFERENTIAL, NATIVE, SCHEMES, QuantConfig
from compile.kernels import ref


def _rand_case(rng, cfg, m_, g_, n_, o_):
    a_int = rng.integers(0, cfg.a_levels + 1, (m_, g_, n_))
    w_int = rng.integers(-cfg.w_levels, cfg.w_levels + 1, (g_, n_, o_))
    a_u = (a_int / cfg.a_levels).astype(np.float32)
    w_u = (w_int / cfg.w_levels).astype(np.float32)
    return a_int, w_int, a_u, w_u


@pytest.mark.parametrize("scheme", SCHEMES)
@pytest.mark.parametrize("b_pim", [3, 5, 7, 10])
def test_jnp_matches_ref(scheme, b_pim):
    cfg = QuantConfig()
    rng = np.random.default_rng(len(scheme) * 1000 + b_pim)
    a_int, w_int, a_u, w_u = _rand_case(rng, cfg, 6, 2, 18, 4)
    levels = 2**b_pim - 1
    y_ref = ref.pim_matmul_ref(a_int, w_int, levels, scheme, cfg)
    y_jnp = np.asarray(
        pim.pim_forward(jnp.asarray(a_u), jnp.asarray(w_u), jnp.float32(levels), scheme, cfg)
    )
    np.testing.assert_allclose(y_jnp, y_ref, atol=2e-5)


@given(
    scheme=st.sampled_from(SCHEMES),
    b_pim=st.integers(2, 12),
    m_dac=st.sampled_from([1, 2, 4]),
    b_w=st.sampled_from([2, 3, 4]),
    n_=st.integers(1, 40),
    o_=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=60, deadline=None)
def test_jnp_matches_ref_hypothesis(scheme, b_pim, m_dac, b_w, n_, o_, seed):
    cfg = QuantConfig(b_w=b_w, b_a=4, m=m_dac)
    rng = np.random.default_rng(seed)
    a_int, w_int, a_u, w_u = _rand_case(rng, cfg, 3, 2, n_, o_)
    levels = 2**b_pim - 1
    y_ref = ref.pim_matmul_ref(a_int, w_int, levels, scheme, cfg)
    y_jnp = np.asarray(
        pim.pim_forward(jnp.asarray(a_u), jnp.asarray(w_u), jnp.float32(levels), scheme, cfg)
    )
    np.testing.assert_allclose(y_jnp, y_ref, atol=5e-5)


@pytest.mark.parametrize("scheme", SCHEMES)
def test_high_resolution_converges_to_digital(scheme):
    """b_PIM → ∞ must recover the exact digital inner product (Thm. 1)."""
    cfg = QuantConfig()
    rng = np.random.default_rng(7)
    a_int, w_int, a_u, w_u = _rand_case(rng, cfg, 4, 2, 18, 3)
    y_dig = ref.digital_matmul_ref(a_int, w_int, cfg)
    y_hi = np.asarray(
        pim.pim_forward(
            jnp.asarray(a_u), jnp.asarray(w_u), jnp.float32(2.0**20 - 1), scheme, cfg
        )
    )
    np.testing.assert_allclose(y_hi, y_dig, atol=1e-4)


@pytest.mark.parametrize("scheme", SCHEMES)
def test_error_monotone_in_resolution(scheme):
    """Mean-squared PIM error must (weakly) shrink as b_PIM grows."""
    cfg = QuantConfig()
    rng = np.random.default_rng(11)
    a_int, w_int, a_u, w_u = _rand_case(rng, cfg, 16, 2, 36, 8)
    y_dig = ref.digital_matmul_ref(a_int, w_int, cfg)
    errs = []
    for b in (3, 5, 7, 9):
        y = np.asarray(
            pim.pim_forward(
                jnp.asarray(a_u), jnp.asarray(w_u), jnp.float32(2.0**b - 1), scheme, cfg
            )
        )
        errs.append(np.mean((y - y_dig) ** 2))
    assert errs[0] >= errs[1] >= errs[2] >= errs[3]


def test_scale_enlarging_effect_fig_a2():
    """Fig. A2: the std ratio ρ = std(y_PIM)/std(y) grows as b_PIM falls
    (bit-serial scheme) and approaches 1 at high resolution."""
    cfg = QuantConfig()
    rng = np.random.default_rng(3)
    a_int, w_int, a_u, w_u = _rand_case(rng, cfg, 64, 2, 144, 16)
    y_dig = ref.digital_matmul_ref(a_int, w_int, cfg)
    rho = {}
    for b in (3, 7, 10):
        y = np.asarray(
            pim.pim_forward(
                jnp.asarray(a_u), jnp.asarray(w_u), jnp.float32(2.0**b - 1), BIT_SERIAL, cfg
            )
        )
        rho[b] = float(np.std(y) / np.std(y_dig))
    assert rho[3] > rho[7] > 0.5
    assert abs(rho[10] - 1.0) < 0.1
    assert rho[3] > 1.5  # the paper reports 2–4x at 3–4 bit


def test_differential_equals_native_when_all_positive():
    """With all-positive weights the negative half is empty: differential
    must reduce exactly to native."""
    cfg = QuantConfig()
    rng = np.random.default_rng(5)
    a_int = rng.integers(0, 16, (4, 1, 9))
    w_int = rng.integers(0, 8, (1, 9, 3))
    for levels in (7, 127):
        y_n = ref.pim_matmul_ref(a_int, w_int, levels, NATIVE, cfg)
        y_d = ref.pim_matmul_ref(a_int, w_int, levels, DIFFERENTIAL, cfg)
        np.testing.assert_allclose(y_n, y_d, atol=1e-9)


def test_group_decomposition_identity():
    """Splitting channels into more groups only changes *where* quantization
    happens; at infinite resolution the grouping must not matter."""
    cfg = QuantConfig()
    rng = np.random.default_rng(6)
    a_int = rng.integers(0, 16, (4, 4, 9))
    w_int = rng.integers(-7, 8, (4, 9, 3))
    y4 = ref.pim_matmul_ref(a_int, w_int, 2**18 - 1, BIT_SERIAL, cfg)
    a2 = a_int.reshape(4, 2, 18)
    w2 = w_int.reshape(2, 18, 3)
    y2 = ref.pim_matmul_ref(a2, w2, 2**18 - 1, BIT_SERIAL, cfg)
    # f32 ADC arithmetic leaves ~LSB-scale residuals at finite "infinite"
    # resolution; the identity is structural, not bit-exact.
    np.testing.assert_allclose(y4, y2, atol=5e-4)


class TestLayoutHelpers:
    def test_effective_unit_channels(self):
        assert pim.effective_unit_channels(8, 16) == 8
        assert pim.effective_unit_channels(32, 16) == 16
        assert pim.effective_unit_channels(12, 8) == 6
        assert pim.effective_unit_channels(7, 4) == 1

    def test_grouped_patches_shapes(self):
        x = jnp.zeros((2, 8, 8, 16))
        p, oh, ow, uc = pim.grouped_patches(x, 3, 1, 8)
        assert p.shape == (2 * 8 * 8, 2, 72) and (oh, ow, uc) == (8, 8, 8)
        p, oh, ow, uc = pim.grouped_patches(x, 3, 2, 8)
        assert p.shape == (2 * 4 * 4, 2, 72) and (oh, ow) == (4, 4)

    def test_patch_weight_layout_consistency(self):
        """conv(x, w) computed via grouped_patches/grouped_weights at infinite
        resolution must equal lax.conv."""
        import jax

        rng = np.random.default_rng(8)
        x = jnp.asarray(rng.uniform(0, 1, (2, 6, 6, 8)).astype(np.float32))
        w = jnp.asarray(rng.normal(0, 1, (3, 3, 8, 5)).astype(np.float32))
        p, oh, ow, _ = pim.grouped_patches(x, 3, 1, 4)
        gw = pim.grouped_weights(w, 4)
        y = pim.digital_forward(p, gw).reshape(2, oh, ow, 5)
        y_ref = jax.lax.conv_general_dilated(
            x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)
