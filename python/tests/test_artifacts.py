"""Artifact/manifest sanity: the contract rust relies on.

These run against the artifacts directory if `make artifacts` has produced
one (skipped otherwise, so pytest stays runnable before the first build).
"""

import json
import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="artifacts not built yet"
)


@pytest.fixture(scope="module")
def manifest():
    with open(MANIFEST) as f:
        return json.load(f)


def test_all_files_exist(manifest):
    for a in manifest["artifacts"]:
        assert os.path.exists(os.path.join(ART, a["file"])), a["name"]


def test_hlo_is_text_not_proto(manifest):
    for a in manifest["artifacts"][:3]:
        with open(os.path.join(ART, a["file"])) as f:
            head = f.read(200)
        assert "HloModule" in head, "expected HLO text format"


def test_input_counts(manifest):
    for a in manifest["artifacts"]:
        n_p, n_s = a["n_params"], a["n_state"]
        n_in = len(a["inputs"])
        if a["kind"] == "init":
            assert n_in == 1
            assert a["n_outputs"] == 2 * n_p + n_s
        elif a["kind"] == "train":
            assert n_in == 2 * n_p + n_s + 7
            assert a["n_outputs"] == 2 * n_p + n_s + 2
        elif a["kind"] in ("eval", "pimeval"):
            assert n_in == n_p + n_s + 4
            assert a["n_outputs"] == 2
        elif a["kind"] == "kernel":
            assert n_in == 3
            assert a["n_outputs"] == 1


def test_param_paths_match_model_entry(manifest):
    for a in manifest["artifacts"]:
        m = manifest["models"][a["model"]]
        assert a["n_params"] == len(m["param_paths"])
        assert a["n_state"] == len(m["state_paths"])


def test_required_artifact_set(manifest):
    names = {a["name"] for a in manifest["artifacts"]}
    required = {
        "tiny_init",
        "tiny_eval",
        "tiny_train_baseline",
        "tiny_train_ams",
        "tiny_train_ours_native_uc1",
        "tiny_train_ours_bit_serial_uc8",
        "tiny_train_ours_differential_uc8",
        "tiny_pimeval_bit_serial_uc8",
        "small_train_ours_bit_serial_uc16",
    }
    assert required <= names, required - names


def test_goldens_exist():
    gold = os.path.join(ART, "golden")
    for f in (
        "pim_mac_native.json",
        "pim_mac_bit_serial.json",
        "pim_mac_differential.json",
        "quant.json",
        "model_tiny.json",
    ):
        assert os.path.exists(os.path.join(gold, f)), f
