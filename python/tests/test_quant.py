"""Unit tests for the modified-DoReFa quantizers (paper Eqn. A20)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import quant
from compile.configs import QuantConfig

CFG = QuantConfig()


class TestWeightQuant:
    def test_on_grid(self):
        rng = np.random.default_rng(0)
        w = jnp.asarray(rng.normal(0, 1, (3, 3, 8, 16)).astype(np.float32))
        q = quant.weight_quant_unit(w, CFG)
        ints = np.asarray(q) * CFG.w_levels
        assert np.allclose(ints, np.round(ints), atol=1e-5)

    def test_range(self):
        rng = np.random.default_rng(1)
        w = jnp.asarray(rng.normal(0, 3, (64,)).astype(np.float32))
        q = np.asarray(quant.weight_quant_unit(w, CFG))
        assert q.min() >= -1.0 - 1e-6 and q.max() <= 1.0 + 1e-6

    def test_max_maps_to_full_scale(self):
        w = jnp.asarray([0.1, -2.5, 0.3], jnp.float32)
        q = np.asarray(quant.weight_quant_unit(w, CFG))
        # the element with max |tanh| maps to ±1 exactly
        assert abs(q[1]) == pytest.approx(1.0, abs=1e-6)

    def test_monotone(self):
        w = jnp.linspace(-2, 2, 101)
        q = np.asarray(quant.weight_quant_unit(w, CFG))
        assert np.all(np.diff(q) >= -1e-7)

    def test_scale_normalizes_variance(self):
        rng = np.random.default_rng(2)
        w = jnp.asarray(rng.normal(0, 1, (128, 32)).astype(np.float32))
        q = quant.weight_quant_unit(w, CFG)
        s = quant.weight_scale(q, 32)
        assert float(s) == pytest.approx(
            1.0 / np.sqrt(32 * np.var(np.asarray(q))), rel=1e-4
        )

    def test_gradient_flows(self):
        w = jnp.asarray([0.3, -0.4, 0.9], jnp.float32)
        g = jax.grad(lambda w: jnp.sum(quant.weight_quant_unit(w, CFG) ** 2))(w)
        assert np.all(np.isfinite(np.asarray(g)))
        assert np.any(np.asarray(g) != 0)


class TestActQuant:
    @given(st.lists(st.floats(-2, 3, width=32), min_size=1, max_size=64))
    @settings(max_examples=25, deadline=None)
    def test_grid_and_range(self, xs):
        x = jnp.asarray(xs, jnp.float32)
        q = np.asarray(quant.act_quant(x, CFG))
        assert q.min() >= 0 and q.max() <= 1
        ints = q * CFG.a_levels
        assert np.allclose(ints, np.round(ints), atol=1e-4)

    def test_identity_on_grid(self):
        grid = jnp.arange(16, dtype=jnp.float32) / 15.0
        q = np.asarray(quant.act_quant(grid, CFG))
        assert np.allclose(q, np.asarray(grid), atol=1e-6)

    def test_clip(self):
        x = jnp.asarray([-0.5, 1.5], jnp.float32)
        q = np.asarray(quant.act_quant(x, CFG))
        assert q[0] == 0.0 and q[1] == 1.0

    def test_ste_gradient_inside_range(self):
        # STE: d/dx quant(clip(x)) = 1 inside (0,1), 0 outside.
        g = jax.grad(lambda x: jnp.sum(quant.act_quant(x, CFG)))(
            jnp.asarray([0.5, -0.5, 1.5], jnp.float32)
        )
        assert np.asarray(g).tolist() == [1.0, 0.0, 0.0]

    def test_bits_8(self):
        x = jnp.asarray([0.5], jnp.float32)
        q = np.asarray(quant.act_quant_bits(x, 8))
        assert abs(q[0] - round(0.5 * 255) / 255) < 1e-6
