"""Vectorized PIM forward model + GSTE backward (paper §3.1–§3.3).

This is the Layer-2 compute core: the grouped, plane-decomposed,
ADC-quantized matmul of Eqn. 1 / Appendix A1, wrapped in a ``jax.custom_vjp``
that implements the generalized straight-through estimator (Assumption 1,
Theorem 1) with the backward rescaling ξ = sqrt(VAR[y_PIM]/VAR[y]) of
Eqn. 8.

The math here is the vectorized twin of the loop-level oracle in
``kernels/ref.py``; ``tests/test_pim_schemes.py`` pins them against each
other exactly.  ``b_PIM`` enters only through ``levels = 2^{b_PIM}-1``, a
*traced* scalar, so one lowered artifact serves every resolution.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from .configs import BIT_SERIAL, DIFFERENTIAL, NATIVE, QuantConfig


def _input_planes(a_int: jnp.ndarray, cfg: QuantConfig) -> jnp.ndarray:
    """[L, M, G, N] DAC planes of integer activations (Eqn. A2)."""
    d = float(cfg.delta)
    planes = [
        jnp.mod(jnp.floor(a_int / (d**l)), d) for l in range(cfg.n_slices)
    ]
    return jnp.stack(planes, axis=0)


def _weight_bit_planes(w_int: jnp.ndarray, cfg: QuantConfig) -> jnp.ndarray:
    """[K, G, N, O] two's-complement bit planes of integer weights (A9)."""
    u = jnp.where(w_int < 0, w_int + 2**cfg.b_w, w_int)
    planes = [jnp.mod(jnp.floor(u / 2.0**k), 2.0) for k in range(cfg.b_w)]
    return jnp.stack(planes, axis=0)


def _adc(s: jnp.ndarray, full_scale: float, levels: jnp.ndarray) -> jnp.ndarray:
    """Ideal ADC: round onto the `levels`-step grid over [0, FS] (banker's
    rounding — identical to numpy/rust ties-to-even)."""
    lsb = full_scale / levels
    return jnp.round(s / lsb) * lsb


def pim_forward(
    a_unit: jnp.ndarray,  # [M, G, N] activations on the 1/a_levels grid
    w_unit: jnp.ndarray,  # [G, N, O] weights on the 1/w_levels grid
    levels: jnp.ndarray,  # scalar f32, 2^{b_PIM} - 1
    scheme: str,
    cfg: QuantConfig,
) -> jnp.ndarray:
    """Noiseless, perfectly-linear PIM grouped matmul (Eqn. 4a) → [M, O].

    Output is in unit scale: the PIM estimate of einsum('mgn,gno->mo').
    """
    n = a_unit.shape[-1]
    d = cfg.delta
    wl, al = float(cfg.w_levels), float(cfg.a_levels)
    a_int = jnp.round(a_unit * al)
    w_int = jnp.round(w_unit * wl)
    a_planes = _input_planes(a_int, cfg)  # [L,M,G,N]
    slice_w = jnp.asarray([float(d) ** l for l in range(cfg.n_slices)])

    if scheme == NATIVE:
        fs = wl * n * (d - 1)
        s = jnp.einsum("lmgn,gno->lmgo", a_planes, w_int)
        q = _adc(s, fs, levels)
        y = jnp.einsum("l,lmgo->mo", slice_w, q)
        return y / (wl * al)

    if scheme == DIFFERENTIAL:
        fs = wl * n * (d - 1)
        wp = jnp.maximum(w_int, 0.0)
        wn = jnp.maximum(-w_int, 0.0)
        sp = jnp.einsum("lmgn,gno->lmgo", a_planes, wp)
        sn = jnp.einsum("lmgn,gno->lmgo", a_planes, wn)
        q = _adc(sp, fs, levels) - _adc(sn, fs, levels)
        y = jnp.einsum("l,lmgo->mo", slice_w, q)
        return y / (wl * al)

    if scheme == BIT_SERIAL:
        fs = float(n * (d - 1))
        w_bits = _weight_bit_planes(w_int, cfg)  # [K,G,N,O]
        bit_w = jnp.asarray(
            [
                (-1.0 if k == cfg.b_w - 1 else 1.0) * 2.0**k
                for k in range(cfg.b_w)
            ]
        )
        s = jnp.einsum("lmgn,kgno->klmgo", a_planes, w_bits)
        q = _adc(s, fs, levels)
        y = jnp.einsum("k,l,klmgo->mo", bit_w, slice_w, q)
        return y / (wl * al)

    raise ValueError(f"unknown scheme {scheme!r}")


def digital_forward(a_unit: jnp.ndarray, w_unit: jnp.ndarray) -> jnp.ndarray:
    """The b_PIM = +∞ limit (conventional digital accelerator) → [M, O]."""
    return jnp.einsum("mgn,gno->mo", a_unit, w_unit)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def pim_matmul(
    a_unit: jnp.ndarray,
    w_unit: jnp.ndarray,
    levels: jnp.ndarray,
    eta: jnp.ndarray,
    scheme: str,
    cfg: QuantConfig,
    bwd_rescale: bool,
) -> jnp.ndarray:
    """η-scaled PIM matmul with GSTE backward (Theorem 1 + Eqn. 8).

    Forward:  z = η · Q_PIM(Σ W̃q̃; levels)          (Eqn. 4a, §3.3 forward η)
    Backward: dz = η · ξ · d(Σ W̃q̃),  ξ = √(VAR[y_PIM]/VAR[y])   (4b, 8)
    """
    return eta * pim_forward(a_unit, w_unit, levels, scheme, cfg)


def _pim_matmul_fwd(a_unit, w_unit, levels, eta, scheme, cfg, bwd_rescale):
    y_pim = pim_forward(a_unit, w_unit, levels, scheme, cfg)
    if bwd_rescale:
        y_exact = digital_forward(a_unit, w_unit)
        xi = jnp.sqrt(
            (jnp.var(y_pim) + 1e-12) / (jnp.var(y_exact) + 1e-12)
        )
        xi = jax.lax.stop_gradient(xi)
    else:
        xi = jnp.float32(1.0)
    return eta * y_pim, (a_unit, w_unit, eta, xi)


def _pim_matmul_bwd(scheme, cfg, bwd_rescale, res, g):
    a_unit, w_unit, eta, xi = res
    scale = eta * xi
    da = scale * jnp.einsum("mo,gno->mgn", g, w_unit)
    dw = scale * jnp.einsum("mgn,mo->gno", a_unit, g)
    # levels and eta are hyper-parameters: no gradient.
    return da, dw, jnp.zeros(()), jnp.zeros(())


pim_matmul.defvjp(_pim_matmul_fwd, _pim_matmul_bwd)


# ---------------------------------------------------------------------------
# Grouped patch extraction (the PIM channel decomposition for convolutions)
# ---------------------------------------------------------------------------


def grouped_patches(
    x: jnp.ndarray,  # [B, H, W, C] NHWC
    kernel_hw: int,
    stride: int,
    unit_channels: int,
) -> Tuple[jnp.ndarray, int, int, int]:
    """im2col with the PIM group layout.

    Returns (patches [M, G, N], out_h, out_w, uc_eff) where
    ``n = cg * kh*kw + (dy * kw + dx)`` indexes within a group of
    ``uc_eff`` input channels — the layout contract shared with
    ``grouped_weights`` and the rust chip simulator (rust/src/pim/layout.rs).
    """
    b, h, w, c = x.shape
    k = kernel_hw
    uc = effective_unit_channels(c, unit_channels)
    g = c // uc
    pad = k // 2
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    oh = (h + 2 * pad - k) // stride + 1
    ow = (w + 2 * pad - k) // stride + 1
    slabs = []
    for dy in range(k):
        for dx in range(k):
            slabs.append(
                jax.lax.slice(
                    xp,
                    (0, dy, dx, 0),
                    (b, dy + (oh - 1) * stride + 1, dx + (ow - 1) * stride + 1, c),
                    (1, stride, stride, 1),
                )
            )
    # [B, OH, OW, C, k*k] -> [B, OH, OW, G, uc, k*k] -> [M, G, uc*k*k]
    p = jnp.stack(slabs, axis=-1)
    p = p.reshape(b, oh, ow, g, uc, k * k)
    p = p.reshape(b * oh * ow, g, uc * k * k)
    return p, oh, ow, uc


def grouped_weights(
    w: jnp.ndarray,  # [kh, kw, C, O]
    unit_channels: int,
) -> jnp.ndarray:
    """Reshape conv weights to [G, N, O] with the grouped_patches layout."""
    kh, kw, c, o = w.shape
    uc = effective_unit_channels(c, unit_channels)
    g = c // uc
    # [C, kh*kw, O] -> [G, uc, kh*kw, O] -> [G, uc*kh*kw, O]
    wt = jnp.transpose(w, (2, 0, 1, 3)).reshape(c, kh * kw, o)
    return wt.reshape(g, uc, kh * kw, o).reshape(g, uc * kh * kw, o)


def effective_unit_channels(c: int, unit_channels: int) -> int:
    """Largest uc ≤ unit_channels that divides C (a narrow early layer maps
    onto a smaller slice of the array; documented in DESIGN.md)."""
    uc = min(unit_channels, c)
    while c % uc != 0:
        uc -= 1
    return uc
