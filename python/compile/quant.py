"""Conventional (digital) quantizers: modified DoReFa (paper Eqn. A20).

Weights: ``Q = s * round((2^{b_w-1}-1) * tanh(W)/max|tanh(W)|) / (2^{b_w-1}-1)``
with the scale-adjusted-training factor ``s = 1/sqrt(n_out * VAR[q])`` (Jin et
al. 2020), *without* the DoReFa [-1,1]→[0,1] interval mapping.

Activations: DoReFa ``round((2^{b_a}-1) * clip(x, 0, 1)) / (2^{b_a}-1)``.

Both use the plain STE (GSTE with ξ=1) for their own round; the PIM
quantizer's GSTE with ξ≠1 lives in ``pim.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .configs import QuantConfig


def ste_round(x: jnp.ndarray) -> jnp.ndarray:
    """round(x) with a straight-through gradient (GSTE, ξ = 1)."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def weight_quant_unit(w: jnp.ndarray, cfg: QuantConfig) -> jnp.ndarray:
    """Quantized weights on the [-1, 1] integer grid (no scale s).

    This is what the PIM array physically stores: integers in
    [-w_levels, w_levels] divided by ``w_levels``.
    """
    t = jnp.tanh(w)
    t = t / (jnp.max(jnp.abs(t)) + 1e-12)
    lv = float(cfg.w_levels)
    return ste_round(t * lv) / lv


def weight_scale(q_unit: jnp.ndarray, n_out: int) -> jnp.ndarray:
    """Scale-adjusted-training factor s = 1/sqrt(n_out * VAR[q]) (Eqn. A20b).

    Applied digitally after the (PIM) MAC — it never enters the analog array.
    """
    var = jnp.var(jax.lax.stop_gradient(q_unit)) + 1e-12
    return 1.0 / jnp.sqrt(n_out * var)


def weight_quant(w: jnp.ndarray, n_out: int, cfg: QuantConfig) -> jnp.ndarray:
    """Full digital quantized weight Q = s * q_unit (for digital layers)."""
    q = weight_quant_unit(w, cfg)
    return weight_scale(q, n_out) * q


def act_quant(x: jnp.ndarray, cfg: QuantConfig) -> jnp.ndarray:
    """DoReFa activation quantizer onto {0, 1/a_levels, ..., 1}."""
    lv = float(cfg.a_levels)
    return ste_round(jnp.clip(x, 0.0, 1.0) * lv) / lv


def act_quant_bits(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Activation quantizer at an explicit bit-width (first layer uses 8)."""
    lv = float(2**bits - 1)
    return ste_round(jnp.clip(x, 0.0, 1.0) * lv) / lv
