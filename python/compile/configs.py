"""Configuration dataclasses shared by the compile path.

These mirror the rust-side ``config`` module (rust/src/config/).  The contract
between the two sides is the artifact *manifest* emitted by ``aot.py`` — the
dataclasses here are never pickled across the boundary.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

# PIM decomposition schemes (paper §2, Appendix A1).
NATIVE = "native"
BIT_SERIAL = "bit_serial"
DIFFERENTIAL = "differential"
SCHEMES = (NATIVE, BIT_SERIAL, DIFFERENTIAL)

# Training modes.
MODE_OURS = "ours"          # PIM-QAT: PIM forward + GSTE backward (+rescaling)
MODE_BASELINE = "baseline"  # conventional QAT (digital forward), Jin et al. 2020
MODE_AMS = "ams"            # Rekhi et al. 2019 additive-noise model
MODES = (MODE_OURS, MODE_BASELINE, MODE_AMS)


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Bit-widths of the conventional (digital) quantization step.

    The paper fixes ``b_w = b_a = 4`` for all experiments (§A2.1); ``m`` is
    the DAC resolution used to slice activations into ``b_a / m`` planes
    (Eqn. A2).  ``m`` must divide ``b_a``.
    """

    b_w: int = 4
    b_a: int = 4
    m: int = 4

    def __post_init__(self) -> None:
        if self.b_a % self.m != 0:
            raise ValueError(f"m={self.m} must divide b_a={self.b_a}")
        if self.b_w < 2:
            raise ValueError("b_w must be >= 2 (one sign bit + magnitude)")

    @property
    def w_levels(self) -> int:
        """Positive full-scale of the weight grid: weights are integers in
        [-w_levels, w_levels] (DoReFa never emits -2^{b_w-1})."""
        return 2 ** (self.b_w - 1) - 1

    @property
    def a_levels(self) -> int:
        """Full-scale of the activation grid: integers in [0, a_levels]."""
        return 2**self.b_a - 1

    @property
    def delta(self) -> int:
        """DAC radix Δ = 2^m (Eqn. A2c)."""
        return 2**self.m

    @property
    def n_slices(self) -> int:
        """Number of input (activation) planes b_a / m."""
        return self.b_a // self.m


@dataclasses.dataclass(frozen=True)
class PimConfig:
    """Static PIM-array parameters baked into an artifact.

    ``b_PIM`` (the ADC resolution) is deliberately NOT here: it is a runtime
    scalar input (``levels = 2^{b_PIM} - 1``) so a single artifact covers the
    whole Table-3/Fig-5 resolution sweep and adjusted-precision training.
    """

    scheme: str = BIT_SERIAL
    unit_channels: int = 8  # input channels per analog group ("unit channel")
    kernel_hw: int = 3

    def __post_init__(self) -> None:
        if self.scheme not in SCHEMES:
            raise ValueError(f"unknown scheme {self.scheme!r}")

    @property
    def n_macs(self) -> int:
        """N, the number of MACs summed on one analog bitline."""
        return self.unit_channels * self.kernel_hw * self.kernel_hw


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """CIFAR-style model family (paper §A2.1).

    ``depth_n`` follows the 6n+2 ResNet convention (n=3 → ResNet20).  The
    1-core-CPU reproduction defaults to a narrower, shallower instance; the
    paper's exact shapes are reachable with width=16, depth_n=3, image=32.
    """

    arch: str = "resnet"  # "resnet" | "vgg11"
    depth_n: int = 1
    width: int = 8
    image: int = 16
    classes: int = 10
    in_channels: int = 3

    @property
    def name(self) -> str:
        if self.arch == "resnet":
            return f"resnet{6 * self.depth_n + 2}w{self.width}i{self.image}"
        return f"{self.arch}w{self.width}i{self.image}"


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Hyper-parameters of the SGD step baked into the train artifact."""

    batch: int = 32
    momentum: float = 0.9
    weight_decay: float = 1e-4
    nesterov: bool = True
    bn_momentum: float = 0.1
    # Rescaling toggles (§3.3, ablated in Table A3).
    fwd_rescale: bool = True
    bwd_rescale: bool = True


def artifact_tag(mode: str, scheme: str, pim: PimConfig, model: ModelConfig) -> str:
    """Canonical artifact-set name, mirrored by rust/src/runtime/registry.rs."""
    if mode == MODE_OURS:
        return f"{model.name}_{mode}_{scheme}_uc{pim.unit_channels}"
    return f"{model.name}_{mode}"


def plane_weights(cfg: QuantConfig, scheme: str) -> Tuple[Tuple[float, ...], int]:
    """Digital recombination weights for each ADC plane and the integer
    full-scale FS of one plane sum (see DESIGN.md and Appendix A1).

    Returns (weights, full_scale) where the PIM output in integer units is
    ``sum_p weights[p] * dequant(plane_sum_p)`` and each plane sum lies in
    [0, FS] (bit-serial / differential halves) or [-FS, FS] (native).
    Plane order: for bit-serial the planes enumerate (weight bit k, input
    slice l) row-major in k; otherwise just input slices l.
    """
    d = cfg.delta
    if scheme == BIT_SERIAL:
        ws = []
        for k in range(cfg.b_w):
            sign = -1.0 if k == cfg.b_w - 1 else 1.0
            for l in range(cfg.n_slices):
                ws.append(sign * (2.0**k) * (float(d) ** l))
        return tuple(ws), 1  # FS multiplier: N*(Δ-1) * 1 (binary weight bits)
    # native & differential: planes are input slices; weights are multi-bit.
    ws = tuple(float(d) ** l for l in range(cfg.n_slices))
    return ws, cfg.w_levels  # FS multiplier: N*(Δ-1) * (2^{b_w-1}-1)
