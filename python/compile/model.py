"""Layer-2 model: quantized CIFAR-style ResNet-k / VGG11 with PIM-mapped convs.

Layer placement follows the paper (§A2.1):
  * the first conv, the final FC, and the 1×1 residual-shortcut convs run on
    the digital system (b_PIM = +∞); their weights are still 4-bit DoReFa;
  * every other conv runs through the PIM forward model (`compile.pim`);
  * inputs to the first layer are 8-bit, all other activations b_a-bit;
  * BN parameters and the FC bias stay full-precision.

Training modes (§4, Table 3):
  * ``ours``     — PIM-QAT (Eqn. 4a/4b + rescaling);
  * ``baseline`` — conventional QAT (digital forward, Jin et al. 2020);
  * ``ams``      — Rekhi et al. 2019: digital forward + additive Gaussian
    noise whose std (in unit output scale) models the whole AMS chain.

Parameters / state are nested dicts; ``flatten_tree`` defines the
deterministic ordering contract with the rust side (manifest in aot.py).
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import pim, quant
from .configs import MODE_AMS, MODE_BASELINE, MODE_OURS, ModelConfig, PimConfig, QuantConfig

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Parameter tree utilities (ordering contract with rust/src/train/manifest.rs)
# ---------------------------------------------------------------------------


def flatten_tree(tree: Params, prefix: str = "") -> List[Tuple[str, jnp.ndarray]]:
    """Depth-first, key-sorted flattening — THE parameter order contract."""
    out: List[Tuple[str, jnp.ndarray]] = []
    for key in sorted(tree.keys()):
        path = f"{prefix}/{key}" if prefix else key
        val = tree[key]
        if isinstance(val, dict):
            out.extend(flatten_tree(val, path))
        else:
            out.append((path, val))
    return out


def unflatten_like(tree: Params, leaves: List[jnp.ndarray]) -> Params:
    """Inverse of flatten_tree given a structural template."""
    it = iter(leaves)

    def rec(t: Params) -> Params:
        return {
            k: rec(v) if isinstance(v, dict) else next(it)
            for k, v in ((k, t[k]) for k in sorted(t.keys()))
        }

    return rec(tree)


# ---------------------------------------------------------------------------
# Initialization (lowered into the `init` artifact: rust never re-implements)
# ---------------------------------------------------------------------------


def _kaiming(key, shape, fan_in):
    return jax.random.normal(key, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)


def _conv_init(key, k, cin, cout):
    return {"w": _kaiming(key, (k, k, cin, cout), k * k * cin)}


def _bn_init(c):
    return {"gamma": jnp.ones((c,)), "beta": jnp.zeros((c,))}


def _bn_state_init(c):
    return {"mean": jnp.zeros((c,)), "var": jnp.ones((c,))}


def resnet_init(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    """(params, bn_state) for the 6n+2 CIFAR ResNet."""
    keys = iter(jax.random.split(key, 128))
    w = cfg.width
    params: Params = {"conv0": _conv_init(next(keys), 3, cfg.in_channels, w)}
    state: Params = {"bn0": _bn_state_init(w)}
    params["bn0"] = _bn_init(w)
    cin = w
    for s in range(3):
        cout = w * (2**s)
        for b in range(cfg.depth_n):
            blk = f"s{s}b{b}"
            params[blk] = {
                "conv1": _conv_init(next(keys), 3, cin, cout),
                "bn1": _bn_init(cout),
                "conv2": _conv_init(next(keys), 3, cout, cout),
                "bn2": _bn_init(cout),
            }
            # BN state is a single-level dict keyed by slash-joined paths so
            # the forward pass can record updates without nested plumbing.
            state[f"{blk}/bn1"] = _bn_state_init(cout)
            state[f"{blk}/bn2"] = _bn_state_init(cout)
            if cin != cout:
                params[blk]["convs"] = _conv_init(next(keys), 1, cin, cout)
                params[blk]["bns"] = _bn_init(cout)
                state[f"{blk}/bns"] = _bn_state_init(cout)
            cin = cout
    params["fc"] = {
        "w": _kaiming(next(keys), (cin, cfg.classes), cin),
        "b": jnp.zeros((cfg.classes,)),
    }
    return params, state


# VGG11 feature plan: (out_channels_multiplier, pool_after).  Adapted from the
# modified VGGNet11 of Jia et al. 2020; pool count trimmed to the image size
# in vgg11_plan().
_VGG11_MULTS = (1, 2, 4, 4, 8, 8, 8, 8)


def vgg11_plan(cfg: ModelConfig) -> List[Tuple[int, bool]]:
    import math

    max_pools = max(2, int(math.log2(cfg.image)) - 1)  # keep final map >= 2x2
    pool_after = {0: True, 1: True, 3: True, 5: True, 7: True}
    plan, pools = [], 0
    for i, mult in enumerate(_VGG11_MULTS):
        do_pool = pool_after.get(i, False) and pools < max_pools
        pools += int(do_pool)
        plan.append((cfg.width * mult, do_pool))
    return plan


def vgg_init(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    keys = iter(jax.random.split(key, 64))
    params: Params = {}
    state: Params = {}
    cin = cfg.in_channels
    for i, (cout, _) in enumerate(vgg11_plan(cfg)):
        params[f"conv{i}"] = _conv_init(next(keys), 3, cin, cout)
        params[f"bn{i}"] = _bn_init(cout)
        state[f"bn{i}"] = _bn_state_init(cout)
        cin = cout
    params["fc"] = {
        "w": _kaiming(next(keys), (cin, cfg.classes), cin),
        "b": jnp.zeros((cfg.classes,)),
    }
    return params, state


def model_init(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    if cfg.arch == "resnet":
        return resnet_init(key, cfg)
    if cfg.arch == "vgg11":
        return vgg_init(key, cfg)
    raise ValueError(cfg.arch)


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


class Ctx:
    """Per-call context threaded through the forward pass."""

    def __init__(
        self,
        qcfg: QuantConfig,
        pcfg: PimConfig,
        mode: str,
        levels: jnp.ndarray,
        eta: jnp.ndarray,
        ams_sigma: jnp.ndarray,
        train: bool,
        bn_momentum: float,
        bwd_rescale: bool,
        key: Optional[jnp.ndarray],
    ):
        self.qcfg = qcfg
        self.pcfg = pcfg
        self.mode = mode
        self.levels = levels
        self.eta = eta
        self.ams_sigma = ams_sigma
        self.train = train
        self.bn_momentum = bn_momentum
        self.bwd_rescale = bwd_rescale
        self.key = key
        self.new_state: Params = {}

    def next_key(self):
        self.key, sub = jax.random.split(self.key)
        return sub


def _digital_conv(x, w, stride, n_out, qcfg):
    """Digital-system conv (first layer / shortcuts): 4-bit DoReFa weights,
    exact accumulation."""
    wq = quant.weight_quant(w, n_out, qcfg)
    return jax.lax.conv_general_dilated(
        x,
        wq,
        (stride, stride),
        "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _pim_conv(x, w, stride, ctx: Ctx):
    """A PIM-mapped conv: grouped im2col → per-group quantized MAC →
    digital recombination, then the digital weight scale s (Eqn. A20b)."""
    qcfg, pcfg = ctx.qcfg, ctx.pcfg
    kh, kw, cin, cout = w.shape
    wq = quant.weight_quant_unit(w, qcfg)  # [-1,1] grid: what the array stores
    s = quant.weight_scale(wq, cout)
    patches, oh, ow, _uc = pim.grouped_patches(x, kh, stride, pcfg.unit_channels)
    gw = pim.grouped_weights(wq, pcfg.unit_channels)
    if ctx.mode == MODE_OURS:
        y = pim.pim_matmul(
            patches, gw, ctx.levels, ctx.eta, pcfg.scheme, qcfg, ctx.bwd_rescale
        )
    else:
        y = pim.digital_forward(patches, gw)
        if ctx.mode == MODE_AMS and ctx.train:
            # Rekhi et al. 2019: the whole AMS chain as one additive Gaussian
            # noise source on the (unit-scale) MAC output.
            noise = jax.random.normal(ctx.next_key(), y.shape, y.dtype)
            y = y + ctx.ams_sigma * noise
    y = y.reshape(x.shape[0], oh, ow, cout)
    return s * y


def _bn(x, p, st, name, ctx: Ctx):
    """BatchNorm with running-stat update (training) or running stats (eval).
    The running stats are exactly what BN calibration (§3.4) overwrites."""
    if ctx.train:
        mean = jnp.mean(x, axis=(0, 1, 2))
        var = jnp.var(x, axis=(0, 1, 2))
        mom = ctx.bn_momentum
        ctx.new_state[name] = {
            "mean": (1 - mom) * st["mean"] + mom * mean,
            "var": (1 - mom) * st["var"] + mom * var,
        }
    else:
        mean, var = st["mean"], st["var"]
        ctx.new_state[name] = dict(st)
    inv = jax.lax.rsqrt(var + 1e-5)
    return p["gamma"] * (x - mean) * inv + p["beta"]


def _act(x, ctx: Ctx):
    return quant.act_quant(jax.nn.relu(x), ctx.qcfg)


def resnet_apply(params, state, x, cfg: ModelConfig, ctx: Ctx):
    x = quant.act_quant_bits(x, 8)  # 8-bit first-layer inputs (§A2.1)
    x = _digital_conv(x, params["conv0"]["w"], 1, cfg.width, ctx.qcfg)
    x = _bn(x, params["bn0"], state["bn0"], "bn0", ctx)
    x = _act(x, ctx)
    cin = cfg.width
    for s in range(3):
        cout = cfg.width * (2**s)
        for b in range(cfg.depth_n):
            blk = f"s{s}b{b}"
            bp = params[blk]
            stride = 2 if (s > 0 and b == 0) else 1
            h = _pim_conv(x, bp["conv1"]["w"], stride, ctx)
            h = _bn(h, bp["bn1"], state[f"{blk}/bn1"], f"{blk}/bn1", ctx)
            h = _act(h, ctx)
            h = _pim_conv(h, bp["conv2"]["w"], 1, ctx)
            h = _bn(h, bp["bn2"], state[f"{blk}/bn2"], f"{blk}/bn2", ctx)
            if cin != cout or stride != 1:
                sc = _digital_conv(x, bp["convs"]["w"], stride, cout, ctx.qcfg)
                sc = _bn(sc, bp["bns"], state[f"{blk}/bns"], f"{blk}/bns", ctx)
            else:
                sc = x
            x = _act(h + sc, ctx)
            cin = cout
    x = jnp.mean(x, axis=(1, 2))
    wq = quant.weight_quant(params["fc"]["w"], cfg.classes, ctx.qcfg)
    return x @ wq + params["fc"]["b"]


def vgg_apply(params, state, x, cfg: ModelConfig, ctx: Ctx):
    x = quant.act_quant_bits(x, 8)
    cin = cfg.in_channels
    for i, (cout, do_pool) in enumerate(vgg11_plan(cfg)):
        w = params[f"conv{i}"]["w"]
        if i == 0:
            x = _digital_conv(x, w, 1, cout, ctx.qcfg)
        else:
            x = _pim_conv(x, w, 1, ctx)
        x = _bn(x, params[f"bn{i}"], state[f"bn{i}"], f"bn{i}", ctx)
        x = _act(x, ctx)
        if do_pool:
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
            )
        cin = cout
    x = jnp.mean(x, axis=(1, 2))
    wq = quant.weight_quant(params["fc"]["w"], cfg.classes, ctx.qcfg)
    return x @ wq + params["fc"]["b"]


def model_apply(params, state, x, cfg: ModelConfig, ctx: Ctx):
    """Returns (logits, new_bn_state)."""
    if cfg.arch == "resnet":
        logits = resnet_apply(params, state, x, cfg, ctx)
    elif cfg.arch == "vgg11":
        logits = vgg_apply(params, state, x, cfg, ctx)
    else:
        raise ValueError(cfg.arch)
    return logits, ctx.new_state
