"""Forward-rescaling constants (paper Table A1, §3.3).

The paper finds a constant forward scale η, applied to the PIM output before
batch normalization, is required for convergence at low b_PIM.  Values below
are Table A1 verbatim for b_PIM in 3..7; for higher resolutions the PIM output
scale approaches the digital one (Fig. A2) so η → 1.  The rust side mirrors
this table in rust/src/config/rescale.rs; ``test_rescale.py`` pins both.
"""

from __future__ import annotations

from . import configs

# Table A1 (b_PIM -> eta), per decomposition scheme.
_TABLE_A1 = {
    configs.NATIVE: {3: 100.0, 4: 20.0, 5: 1.0, 6: 1.0, 7: 1.0},
    configs.DIFFERENTIAL: {3: 1000.0, 4: 1000.0, 5: 1000.0, 6: 1000.0, 7: 1000.0},
    configs.BIT_SERIAL: {3: 100.0, 4: 30.0, 5: 30.0, 6: 30.0, 7: 1.03},
}


def forward_eta(scheme: str, b_pim: int) -> float:
    """η(scheme, b_PIM): Table A1 inside 3..7, 1.0 above, clamped-to-3 below."""
    table = _TABLE_A1[scheme]
    if b_pim in table:
        return table[b_pim]
    if b_pim < 3:
        return table[3]
    return 1.0
