"""AOT lowering: JAX → HLO text artifacts + manifest for the rust runtime.

Run once via ``make artifacts``::

    cd python && python -m compile.aot --out-dir ../artifacts [--set full]

Interchange format is HLO **text**, not a serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids that the crate's xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

The manifest (artifacts/manifest.json) is the single contract with rust:
parameter ordering (flatten_tree), input/output signatures, and the
model/quant/PIM configuration of every artifact.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_lib
from . import train as train_lib
from .configs import (
    BIT_SERIAL,
    DIFFERENTIAL,
    MODE_AMS,
    MODE_BASELINE,
    MODE_OURS,
    NATIVE,
    ModelConfig,
    PimConfig,
    QuantConfig,
    TrainConfig,
)

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple for rust's
    ``to_tuple`` unwrapping)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# Model zoo: the scaled stand-ins for the paper's models (see EXPERIMENTS.md
# for the mapping table: paper ResNet20 → r8w16 etc. on this 1-core testbed).
# ---------------------------------------------------------------------------

MODELS: Dict[str, ModelConfig] = {
    "tiny": ModelConfig(depth_n=1, width=8, image=16),
    "small": ModelConfig(depth_n=1, width=16, image=16),
    "r14": ModelConfig(depth_n=2, width=16, image=16),
    "r20": ModelConfig(depth_n=3, width=16, image=16),
    "vgg11": ModelConfig(arch="vgg11", depth_n=0, width=8, image=16),
    "tiny100": ModelConfig(depth_n=1, width=8, image=16, classes=100),
    "small100": ModelConfig(depth_n=1, width=16, image=16, classes=100),
}

QCFG = QuantConfig(b_w=4, b_a=4, m=4)
BATCH = 32


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


@dataclasses.dataclass
class Artifact:
    name: str
    kind: str  # init | train | eval | pimeval
    model: str
    mode: str | None = None
    pim: PimConfig | None = None
    tcfg: TrainConfig | None = None


def default_artifacts(full: bool) -> List[Artifact]:
    tc = TrainConfig(batch=BATCH)
    arts: List[Artifact] = []

    def add_model(mkey: str, schemes: List[tuple], baseline=True, ams=False, pimeval=None):
        arts.append(Artifact(f"{mkey}_init", "init", mkey))
        arts.append(Artifact(f"{mkey}_eval", "eval", mkey, MODE_BASELINE, tcfg=tc))
        if baseline:
            arts.append(Artifact(f"{mkey}_train_baseline", "train", mkey, MODE_BASELINE, tcfg=tc))
        if ams:
            arts.append(Artifact(f"{mkey}_train_ams", "train", mkey, MODE_AMS, tcfg=tc))
        for scheme, uc in schemes:
            arts.append(
                Artifact(
                    f"{mkey}_train_ours_{scheme}_uc{uc}",
                    "train",
                    mkey,
                    MODE_OURS,
                    PimConfig(scheme=scheme, unit_channels=uc),
                    tc,
                )
            )
        for scheme, uc in pimeval or []:
            arts.append(
                Artifact(
                    f"{mkey}_pimeval_{scheme}_uc{uc}",
                    "pimeval",
                    mkey,
                    MODE_OURS,
                    PimConfig(scheme=scheme, unit_channels=uc),
                    tc,
                )
            )

    # Core set: everything the default experiment grids need.
    add_model(
        "tiny",
        [(NATIVE, 1), (BIT_SERIAL, 8), (DIFFERENTIAL, 8)],
        baseline=True,
        ams=True,
        pimeval=[(BIT_SERIAL, 8), (NATIVE, 1), (DIFFERENTIAL, 8)],
    )
    # Rescaling ablation variants (Table A3): fwd/bwd rescale toggles.
    for fwd, bwd, tag in ((False, True, "nofwd"), (False, False, "norescale")):
        arts.append(
            Artifact(
                f"tiny_train_ours_bit_serial_uc8_{tag}",
                "train",
                "tiny",
                MODE_OURS,
                PimConfig(scheme=BIT_SERIAL, unit_channels=8),
                dataclasses.replace(tc, fwd_rescale=fwd, bwd_rescale=bwd),
            )
        )
    add_model("small", [(BIT_SERIAL, 8), (BIT_SERIAL, 16), (DIFFERENTIAL, 16)])
    add_model("tiny100", [(BIT_SERIAL, 8)])
    add_model("vgg11", [(BIT_SERIAL, 8)])
    # L1 kernel artifacts: the same grouped PIM matmul lowered through the
    # Pallas kernel and through the jnp twin — the rust integration test
    # proves the Pallas path loads and runs via PJRT, and the runtime bench
    # compares the two lowerings.
    arts.append(Artifact("kernel_pim_mac_pallas", "kernel", "tiny", MODE_OURS,
                         PimConfig(scheme=BIT_SERIAL, unit_channels=8), tc))
    arts.append(Artifact("kernel_pim_mac_jnp", "kernel", "tiny", MODE_OURS,
                         PimConfig(scheme=BIT_SERIAL, unit_channels=8), tc))
    if full:
        add_model("r14", [(BIT_SERIAL, 8), (BIT_SERIAL, 16)])
        add_model("r20", [(BIT_SERIAL, 8), (BIT_SERIAL, 16)])
        add_model("small100", [(BIT_SERIAL, 8), (BIT_SERIAL, 16)])
    return arts


# Kernel-artifact geometry: M×(G,N)×O grouped matmul (one mid-size conv's
# worth of work; see rust/benches/runtime_step.rs).
KERNEL_M, KERNEL_G, KERNEL_N, KERNEL_O = 256, 2, 72, 16


def lower_artifact(art: Artifact, out_dir: str) -> Dict[str, Any]:
    mcfg = MODELS[art.model]
    pcfg = art.pim or PimConfig()
    tcfg = art.tcfg or TrainConfig(batch=BATCH)
    b = tcfg.batch
    img, cin = mcfg.image, mcfg.in_channels

    p0, s0 = model_lib.model_init(jax.random.PRNGKey(0), mcfg)
    p_flat = model_lib.flatten_tree(p0)
    s_flat = model_lib.flatten_tree(s0)
    p_specs = [spec(v.shape) for _, v in p_flat]
    s_specs = [spec(v.shape) for _, v in s_flat]

    inputs: List[Dict[str, Any]]
    if art.kind == "init":
        fn = train_lib.make_init(mcfg)
        args = [spec((), I32)]
        inputs = [{"name": "seed", "shape": [], "dtype": "i32"}]
        n_out = 2 * len(p_specs) + len(s_specs)
    elif art.kind == "train":
        fn, _meta = train_lib.make_train_step(mcfg, QCFG, pcfg, art.mode, tcfg)
        args = (
            p_specs
            + s_specs
            + p_specs  # momentum
            + [
                spec((b, img, img, cin)),
                spec((b,), I32),
                spec(()),
                spec(()),
                spec(()),
                spec(()),
                spec((), I32),
            ]
        )
        inputs = (
            [{"name": f"param:{k}", "shape": list(v.shape), "dtype": "f32"} for k, v in p_flat]
            + [{"name": f"state:{k}", "shape": list(v.shape), "dtype": "f32"} for k, v in s_flat]
            + [{"name": f"mom:{k}", "shape": list(v.shape), "dtype": "f32"} for k, v in p_flat]
            + [
                {"name": "x", "shape": [b, img, img, cin], "dtype": "f32"},
                {"name": "y", "shape": [b], "dtype": "i32"},
                {"name": "lr", "shape": [], "dtype": "f32"},
                {"name": "levels", "shape": [], "dtype": "f32"},
                {"name": "eta", "shape": [], "dtype": "f32"},
                {"name": "ams_sigma", "shape": [], "dtype": "f32"},
                {"name": "seed", "shape": [], "dtype": "i32"},
            ]
        )
        n_out = 2 * len(p_specs) + len(s_specs) + 2
    elif art.kind in ("eval", "pimeval"):
        fn = train_lib.make_eval_step(mcfg, QCFG, pcfg, art.mode, tcfg)
        args = p_specs + s_specs + [
            spec((b, img, img, cin)),
            spec((b,), I32),
            spec(()),
            spec(()),
        ]
        inputs = (
            [{"name": f"param:{k}", "shape": list(v.shape), "dtype": "f32"} for k, v in p_flat]
            + [{"name": f"state:{k}", "shape": list(v.shape), "dtype": "f32"} for k, v in s_flat]
            + [
                {"name": "x", "shape": [b, img, img, cin], "dtype": "f32"},
                {"name": "y", "shape": [b], "dtype": "i32"},
                {"name": "levels", "shape": [], "dtype": "f32"},
                {"name": "eta", "shape": [], "dtype": "f32"},
            ]
        )
        n_out = 2
    elif art.kind == "kernel":
        from . import pim as pim_lib
        from .kernels.pim_mac import pim_matmul_pallas

        m_, g_, n_, o_ = KERNEL_M, KERNEL_G, KERNEL_N, KERNEL_O
        if art.name.endswith("pallas"):
            def fn(a, w, lv):
                return pim_matmul_pallas(a, w, lv, pcfg.scheme, QCFG, block_m=64)
        else:
            def fn(a, w, lv):
                return pim_lib.pim_forward(a, w, lv[0], pcfg.scheme, QCFG)
        args = [spec((m_, g_, n_)), spec((g_, n_, o_)), spec((1,))]
        inputs = [
            {"name": "a", "shape": [m_, g_, n_], "dtype": "f32"},
            {"name": "w", "shape": [g_, n_, o_], "dtype": "f32"},
            {"name": "levels", "shape": [1], "dtype": "f32"},
        ]
        n_out = 1
    else:
        raise ValueError(art.kind)

    # keep_unused: the manifest promises a fixed input arity for every mode;
    # without it jax DCEs e.g. `levels` out of the baseline train step and
    # the compiled program rejects the rust-side buffer list.
    lowered = jax.jit(fn, keep_unused=True).lower(*args)
    text = to_hlo_text(lowered)
    fname = f"{art.name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)

    return {
        "name": art.name,
        "file": fname,
        "kind": art.kind,
        "model": art.model,
        "mode": art.mode,
        "scheme": pcfg.scheme if art.pim else None,
        "unit_channels": pcfg.unit_channels if art.pim else None,
        "batch": b,
        "fwd_rescale": tcfg.fwd_rescale,
        "bwd_rescale": tcfg.bwd_rescale,
        "n_params": len(p_specs),
        "n_state": len(s_specs),
        "n_outputs": n_out,
        "inputs": inputs,
    }


def model_entry(key: str) -> Dict[str, Any]:
    mcfg = MODELS[key]
    p0, s0 = model_lib.model_init(jax.random.PRNGKey(0), mcfg)
    return {
        "arch": mcfg.arch,
        "depth_n": mcfg.depth_n,
        "width": mcfg.width,
        "image": mcfg.image,
        "classes": mcfg.classes,
        "in_channels": mcfg.in_channels,
        "param_paths": [k for k, _ in model_lib.flatten_tree(p0)],
        "param_shapes": [list(v.shape) for _, v in model_lib.flatten_tree(p0)],
        "state_paths": [k for k, _ in model_lib.flatten_tree(s0)],
        "state_shapes": [list(v.shape) for _, v in model_lib.flatten_tree(s0)],
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--set", default="default", choices=["default", "full"])
    ap.add_argument("--only", default=None, help="comma-separated artifact names")
    args = ap.parse_args(argv)

    os.makedirs(args.out_dir, exist_ok=True)
    arts = default_artifacts(args.set == "full")
    if args.only:
        keep = set(args.only.split(","))
        arts = [a for a in arts if a.name in keep]

    entries = []
    for i, art in enumerate(arts):
        print(f"[{i + 1}/{len(arts)}] lowering {art.name} ...", flush=True)
        entries.append(lower_artifact(art, args.out_dir))

    manifest = {
        "quant": {"b_w": QCFG.b_w, "b_a": QCFG.b_a, "m": QCFG.m},
        "batch": BATCH,
        "models": {k: model_entry(k) for k in sorted({a.model for a in arts})},
        "artifacts": entries,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(entries)} artifacts + manifest.json to {args.out_dir}")


if __name__ == "__main__":
    main()
