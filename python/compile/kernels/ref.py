"""Pure-numpy oracle for the PIM MAC (paper Eqn. 1 / Appendix A1).

Deliberately written in the most literal, loop-level style — one analog group
at a time, one ADC plane at a time — so it can be audited against the paper's
equations (A3, A7, A11).  It is the single source of truth that the
vectorized jnp implementation (``compile.pim``), the Pallas kernel
(``compile.kernels.pim_mac``), and the rust chip simulator
(``rust/src/pim/``) are all tested against.

Integer-domain convention (see DESIGN.md):
  * activations are integers ``a ∈ [0, 2^{b_a}-1]``  (q̃ = a / a_levels)
  * weights    are integers ``w ∈ [-wl, wl]``, wl = 2^{b_w-1}-1  (Q̃ = w / wl)
  * a plane sum S is quantized by the ADC as ``code = round(S * levels / FS)``
    with ``levels = 2^{b_PIM} - 1`` and FS the plane's integer full-scale,
    then dequantized as ``code * FS / levels`` and recombined digitally.
"""

from __future__ import annotations

import numpy as np

from ..configs import BIT_SERIAL, DIFFERENTIAL, NATIVE, QuantConfig


def _round_half_away(x: np.ndarray) -> np.ndarray:
    """round-half-away-from-zero — matches jnp.round? No: jnp.round is
    banker's rounding.  We therefore use banker's rounding (numpy default)
    everywhere, including the rust side, so all four implementations agree on
    ties."""
    return np.round(x)


def input_slices(a_int: np.ndarray, cfg: QuantConfig) -> list[np.ndarray]:
    """Decompose integer activations into b_a/m DAC planes (Eqn. A2)."""
    return [
        (a_int // (cfg.delta**l)) % cfg.delta for l in range(cfg.n_slices)
    ]


def weight_bits(w_int: np.ndarray, cfg: QuantConfig) -> list[np.ndarray]:
    """Two's-complement bit planes of integer weights (Eqn. A9): plane k has
    digital weight +2^k for k < b_w-1 and -2^{b_w-1} for the MSB."""
    u = np.where(w_int < 0, w_int + 2**cfg.b_w, w_int)
    return [(u // 2**k) % 2 for k in range(cfg.b_w)]


def adc(s: np.ndarray, full_scale: float, levels: int) -> np.ndarray:
    """Ideal PIM quantizer Q(·; b_PIM): direct bit-truncation onto the
    ``levels = 2^{b_PIM}-1`` grid covering [0, FS] (or [-FS, FS] for signed
    native sums — round() handles the sign symmetrically).

    All arithmetic is float32 on purpose: the jnp/Pallas twins and the rust
    chip simulator compute the ADC input in f32, and a tie (x.5) can fall on
    different sides in f64 vs f32.  Standardizing on f32 + ties-to-even makes
    all four implementations bit-identical.
    """
    lsb = np.float32(full_scale) / np.float32(levels)
    u = np.float32(s) / lsb
    return np.float32(_round_half_away(u)) * lsb


def pim_mac_group(
    a_int: np.ndarray,  # [N] integer activations of one analog group
    w_int: np.ndarray,  # [N] integer weights of one column
    levels: int,
    scheme: str,
    cfg: QuantConfig,
) -> float:
    """One PIM inner product (Eqn. 1 forward, noiseless & perfectly linear).

    Returns the recombined output in *unit* scale, i.e. the PIM estimate of
    ``sum_i (w_i/wl) * (a_i/al)``.
    """
    n = a_int.shape[0]
    d = cfg.delta
    slices = input_slices(a_int, cfg)

    if scheme == NATIVE:
        # A3b: signed multi-bit analog weights, one ADC conversion per slice.
        fs = float(cfg.w_levels * n * (d - 1))
        y = 0.0
        for l, a_l in enumerate(slices):
            s = float(np.dot(w_int, a_l))
            y += (d**l) * adc(s, fs, levels)
        return y / (cfg.w_levels * cfg.a_levels)

    if scheme == DIFFERENTIAL:
        # A7b: weights split into positive / negative halves, two conversions
        # per slice, subtracted digitally.
        wp = np.maximum(w_int, 0)
        wn = np.maximum(-w_int, 0)
        fs = float(cfg.w_levels * n * (d - 1))
        y = 0.0
        for l, a_l in enumerate(slices):
            sp = float(np.dot(wp, a_l))
            sn = float(np.dot(wn, a_l))
            y += (d**l) * (adc(sp, fs, levels) - adc(sn, fs, levels))
        return y / (cfg.w_levels * cfg.a_levels)

    if scheme == BIT_SERIAL:
        # A11b: binary weight planes (MSB negative), one conversion per
        # (weight bit k, input slice l).
        bits = weight_bits(w_int, cfg)
        fs = float(n * (d - 1))
        y = 0.0
        for k, b_k in enumerate(bits):
            sign = -1.0 if k == cfg.b_w - 1 else 1.0
            for l, a_l in enumerate(slices):
                s = float(np.dot(b_k, a_l))
                y += sign * (2.0**k) * (d**l) * adc(s, fs, levels)
        return y / (cfg.w_levels * cfg.a_levels)

    raise ValueError(f"unknown scheme {scheme!r}")


def pim_matmul_ref(
    a_int: np.ndarray,  # [M, G, N] integer activations
    w_int: np.ndarray,  # [G, N, O] integer weights
    levels: int,
    scheme: str,
    cfg: QuantConfig,
) -> np.ndarray:
    """Grouped PIM matmul oracle: quantize each group's partial result, then
    digitally accumulate over groups.  Returns [M, O] in unit scale."""
    m_, g_, n_ = a_int.shape
    o_ = w_int.shape[2]
    out = np.zeros((m_, o_), dtype=np.float64)
    for mi in range(m_):
        for gi in range(g_):
            for oi in range(o_):
                out[mi, oi] += pim_mac_group(
                    a_int[mi, gi], w_int[gi, :, oi], levels, scheme, cfg
                )
    return out


def digital_matmul_ref(a_int: np.ndarray, w_int: np.ndarray, cfg: QuantConfig) -> np.ndarray:
    """The b_PIM = +∞ limit: exact grouped matmul in unit scale."""
    y = np.einsum("mgn,gno->mo", a_int.astype(np.float64), w_int.astype(np.float64))
    return y / (cfg.w_levels * cfg.a_levels)
