"""Layer-1 Pallas kernel: the PIM-quantized grouped MAC.

One kernel instance plays the role of one PIM macro activation: it holds a
single analog group's weights resident (the SRAM cell array → a VMEM-resident
``[N, O]`` tile), streams a tile of input rows through the DAC planes, applies
the ADC quantizer to every partial sum *before* digital accumulation — exactly
where the chip digitizes — and shift-and-adds the planes (§Hardware-Adaptation
in DESIGN.md).

Grid: ``(M / block_m, G)`` — output tiles × analog groups; the output block is
revisited across the G axis and accumulated, mirroring the chip's digital
accumulator that sums partial results from successive channel groups.

CPU PJRT cannot execute Mosaic custom-calls, so ``interpret=True`` is
mandatory here; the kernel's numerics are pinned against ``ref.py`` by
``tests/test_pallas_kernel.py`` and the lowered HLO is load-tested from rust
(``rust/tests/runtime_pallas.rs``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..configs import BIT_SERIAL, DIFFERENTIAL, NATIVE, QuantConfig


def _adc(s, full_scale, levels):
    lsb = full_scale / levels
    return jnp.round(s / lsb) * lsb


def _pim_group_kernel(a_ref, w_ref, lv_ref, o_ref, *, scheme: str, cfg: QuantConfig, n: int):
    """Compute one (row-tile × analog-group) partial PIM product."""
    g = pl.program_id(1)
    a_unit = a_ref[:, 0, :]  # [bm, N] on the 1/a_levels grid
    w_unit = w_ref[0]  # [N, O] on the 1/w_levels grid
    levels = lv_ref[0]
    d = float(cfg.delta)
    wl, al = float(cfg.w_levels), float(cfg.a_levels)
    a_int = jnp.round(a_unit * al)
    w_int = jnp.round(w_unit * wl)

    y = jnp.zeros((a_unit.shape[0], w_unit.shape[1]), jnp.float32)
    for l in range(cfg.n_slices):
        a_l = jnp.mod(jnp.floor(a_int / (d**l)), d)
        if scheme == NATIVE:
            fs = wl * n * (d - 1)
            y += (d**l) * _adc(a_l @ w_int, fs, levels)
        elif scheme == DIFFERENTIAL:
            fs = wl * n * (d - 1)
            wp = jnp.maximum(w_int, 0.0)
            wn = jnp.maximum(-w_int, 0.0)
            y += (d**l) * (_adc(a_l @ wp, fs, levels) - _adc(a_l @ wn, fs, levels))
        elif scheme == BIT_SERIAL:
            fs = float(n * (d - 1))
            u = jnp.where(w_int < 0, w_int + 2**cfg.b_w, w_int)
            for k in range(cfg.b_w):
                sign = -1.0 if k == cfg.b_w - 1 else 1.0
                b_k = jnp.mod(jnp.floor(u / 2.0**k), 2.0)
                y += sign * (2.0**k) * (d**l) * _adc(a_l @ b_k, fs, levels)
        else:
            raise ValueError(scheme)
    y = y / (wl * al)

    # Digital accumulator across channel groups.
    @pl.when(g == 0)
    def _init():
        o_ref[...] = y

    @pl.when(g != 0)
    def _acc():
        o_ref[...] += y


@functools.partial(jax.jit, static_argnames=("scheme", "cfg", "block_m"))
def pim_matmul_pallas(
    a_unit: jnp.ndarray,  # [M, G, N]
    w_unit: jnp.ndarray,  # [G, N, O]
    levels: jnp.ndarray,  # [1] f32
    scheme: str = BIT_SERIAL,
    cfg: QuantConfig = QuantConfig(),
    block_m: int = 64,
) -> jnp.ndarray:
    """Grouped PIM matmul through the Pallas kernel → [M, O]."""
    m, g, n = a_unit.shape
    o = w_unit.shape[2]
    bm = min(block_m, m)
    if m % bm != 0:
        raise ValueError(f"M={m} must be a multiple of block_m={bm}")
    kern = functools.partial(_pim_group_kernel, scheme=scheme, cfg=cfg, n=n)
    return pl.pallas_call(
        kern,
        grid=(m // bm, g),
        in_specs=[
            pl.BlockSpec((bm, 1, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, n, o), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, o), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, o), jnp.float32),
        interpret=True,  # CPU PJRT: Mosaic custom-calls are not executable
    )(a_unit, w_unit, levels)
