"""Golden-vector emitter: pins the rust substrates to the python oracle.

``make artifacts`` runs this after aot.py.  Rust unit/integration tests read
``artifacts/golden/*.json`` (rust/tests/golden_cross.rs) and must reproduce:

  * ``pim_mac_*.json``   — grouped PIM matmul, integer-exact per scheme;
  * ``quant.json``       — modified-DoReFa weight quantization (Eqn. A20);
  * ``model_tiny.json``  — full-model forward: software logits and ideal-PIM
                           logits for each scheme, from the real init params.
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import model as model_lib
from . import pim, quant, train as train_lib
from .configs import (
    BIT_SERIAL,
    DIFFERENTIAL,
    MODE_BASELINE,
    MODE_OURS,
    NATIVE,
    ModelConfig,
    PimConfig,
    QuantConfig,
    TrainConfig,
)
from .kernels import ref


def emit_pim_mac(out_dir: str, rng: np.random.Generator) -> None:
    cfg = QuantConfig()
    for scheme in (NATIVE, BIT_SERIAL, DIFFERENTIAL):
        cases = []
        for levels in (7, 31, 127):
            m_, g_, n_, o_ = 4, 2, 18, 3
            a_int = rng.integers(0, cfg.a_levels + 1, (m_, g_, n_))
            w_int = rng.integers(-cfg.w_levels, cfg.w_levels + 1, (g_, n_, o_))
            y = ref.pim_matmul_ref(a_int, w_int, levels, scheme, cfg)
            cases.append(
                {
                    "levels": levels,
                    "m": m_,
                    "g": g_,
                    "n": n_,
                    "o": o_,
                    "a_int": a_int.flatten().tolist(),
                    "w_int": w_int.flatten().tolist(),
                    "y": y.flatten().tolist(),
                }
            )
        with open(os.path.join(out_dir, f"pim_mac_{scheme}.json"), "w") as f:
            json.dump({"scheme": scheme, "b_w": cfg.b_w, "b_a": cfg.b_a, "m_dac": cfg.m, "cases": cases}, f)


def emit_quant(out_dir: str, rng: np.random.Generator) -> None:
    cfg = QuantConfig()
    w = rng.normal(0, 0.5, (3, 3, 4, 8)).astype(np.float32)
    qu = np.asarray(quant.weight_quant_unit(jnp.asarray(w), cfg))
    s = float(quant.weight_scale(jnp.asarray(qu), 8))
    x = rng.uniform(-0.2, 1.2, (64,)).astype(np.float32)
    qa = np.asarray(quant.act_quant(jnp.asarray(x), cfg))
    with open(os.path.join(out_dir, "quant.json"), "w") as f:
        json.dump(
            {
                "b_w": cfg.b_w,
                "b_a": cfg.b_a,
                "w": w.flatten().tolist(),
                "w_shape": list(w.shape),
                "q_unit": qu.flatten().tolist(),
                "scale": s,
                "x": x.tolist(),
                "q_act": qa.tolist(),
            },
            f,
        )


def emit_model(
    out_dir: str,
    rng: np.random.Generator,
    mcfg: ModelConfig | None = None,
    fname: str = "model_tiny.json",
) -> None:
    mcfg = mcfg or ModelConfig(depth_n=1, width=8, image=16)
    qcfg = QuantConfig()
    tcfg = TrainConfig(batch=4)
    params, state = model_lib.model_init(jax.random.PRNGKey(0), mcfg)
    x = rng.uniform(0, 1, (4, mcfg.image, mcfg.image, 3)).astype(np.float32)

    entry = {
        "model": {"depth_n": mcfg.depth_n, "width": mcfg.width, "image": mcfg.image, "classes": mcfg.classes},
        "x": x.flatten().tolist(),
        "params": {
            k: np.asarray(v).flatten().tolist()
            for k, v in model_lib.flatten_tree(params)
        },
        "param_shapes": {
            k: list(v.shape) for k, v in model_lib.flatten_tree(params)
        },
        "state": {
            k: np.asarray(v).flatten().tolist()
            for k, v in model_lib.flatten_tree(state)
        },
        "logits": {},
    }

    # Software (digital) logits.
    apply_sw = train_lib.make_apply(mcfg, qcfg, PimConfig(), MODE_BASELINE, tcfg)
    logits, _ = apply_sw(
        params, state, jnp.asarray(x), jnp.float32(127.0), jnp.float32(1.0),
        jnp.float32(0.0), jax.random.PRNGKey(0), False,
    )
    entry["logits"]["software"] = np.asarray(logits).flatten().tolist()

    # Ideal-PIM logits per scheme at a couple of resolutions.
    for scheme, uc in ((NATIVE, 1), (BIT_SERIAL, 8), (DIFFERENTIAL, 8)):
        for b_pim in (5, 7):
            ap = train_lib.make_apply(
                mcfg, qcfg, PimConfig(scheme=scheme, unit_channels=uc), MODE_OURS, tcfg
            )
            lg, _ = ap(
                params, state, jnp.asarray(x),
                jnp.float32(2.0**b_pim - 1.0), jnp.float32(1.0),
                jnp.float32(0.0), jax.random.PRNGKey(0), False,
            )
            entry["logits"][f"{scheme}_uc{uc}_b{b_pim}"] = (
                np.asarray(lg).flatten().tolist()
            )

    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(entry, f)


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts/golden")
    ap.add_argument(
        "--micro",
        action="store_true",
        help=(
            "emit the micro committed fixture (model_micro.json at width=4 "
            "image=8 plus the MAC/quant goldens) instead of the full set; "
            "pair with --out-dir ../rust/tests/golden"
        ),
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    rng = np.random.default_rng(1234)
    emit_pim_mac(args.out_dir, rng)
    emit_quant(args.out_dir, rng)
    if args.micro:
        # micro geometry keeps the committed fixture small (~100 KB) while
        # exercising every layer kind the tiny golden does
        emit_model(
            args.out_dir,
            rng,
            mcfg=ModelConfig(depth_n=1, width=4, image=8),
            fname="model_micro.json",
        )
    else:
        emit_model(args.out_dir, rng)
    print(f"goldens written to {args.out_dir}")


if __name__ == "__main__":
    main()
