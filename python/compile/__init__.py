"""Compile-time (build-path) package for the PIM-QAT reproduction.

Everything here runs exactly once inside `make artifacts`; nothing is
imported at run time.
"""
