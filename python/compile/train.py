"""Layer-2 training step: SGD (Nesterov) + cross-entropy, built for AOT.

``make_train_step`` returns a pure function over *flat lists* of tensors —
exactly the calling convention the rust runtime uses (ordered buffers, no
pytrees across the boundary).  The ordering contract is ``flatten_tree`` and
is recorded in the artifact manifest.

Hyper-parameters that sweep at run time are traced scalars:
  lr        — learning-rate schedule lives in rust (rust/src/train/schedule.rs)
  levels    — 2^{b_PIM}-1 (PIM-QAT / adjusted-precision training, §3.5)
  eta       — forward rescale (Table A1), fed from rust's mirror table
  ams_sigma — AMS additive-noise std (unit output scale), for mode=ams
  seed      — per-step RNG seed (AMS noise)
"""

from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp

from . import model as model_lib
from .configs import MODE_OURS, ModelConfig, PimConfig, QuantConfig, TrainConfig


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    return jnp.mean(nll)


def accuracy_count(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum((jnp.argmax(logits, axis=1) == labels).astype(jnp.float32))


def make_apply(mcfg: ModelConfig, qcfg: QuantConfig, pcfg: PimConfig, mode: str, tcfg: TrainConfig):
    """Returns apply(params, state, x, levels, eta, ams_sigma, key, train)."""

    def apply(params, state, x, levels, eta, ams_sigma, key, train):
        ctx = model_lib.Ctx(
            qcfg=qcfg,
            pcfg=pcfg,
            mode=mode,
            levels=levels,
            eta=eta if (tcfg.fwd_rescale and mode == MODE_OURS) else jnp.float32(1.0),
            ams_sigma=ams_sigma,
            train=train,
            bn_momentum=tcfg.bn_momentum,
            bwd_rescale=tcfg.bwd_rescale,
            key=key,
        )
        return model_lib.model_apply(params, state, x, mcfg, ctx)

    return apply


def make_train_step(
    mcfg: ModelConfig,
    qcfg: QuantConfig,
    pcfg: PimConfig,
    mode: str,
    tcfg: TrainConfig,
):
    """Flat-list SGD train step for AOT lowering.

    Signature (all f32 unless noted):
      inputs : params... , bn_state... , momentum... ,
               x [B,H,W,C], y i32[B], lr, levels, eta, ams_sigma, seed i32
      outputs: params'..., bn_state'..., momentum'..., loss, acc_count
    """
    apply = make_apply(mcfg, qcfg, pcfg, mode, tcfg)
    p0, s0 = model_lib.model_init(jax.random.PRNGKey(0), mcfg)
    p_paths = [k for k, _ in model_lib.flatten_tree(p0)]
    s_paths = [k for k, _ in model_lib.flatten_tree(s0)]
    n_p, n_s = len(p_paths), len(s_paths)

    def step(*args):
        params_flat = list(args[:n_p])
        state_flat = list(args[n_p : n_p + n_s])
        mom_flat = list(args[n_p + n_s : 2 * n_p + n_s])
        x, y, lr, levels, eta, ams_sigma, seed = args[2 * n_p + n_s :]
        params = model_lib.unflatten_like(p0, params_flat)
        state = model_lib.unflatten_like(s0, state_flat)
        key = jax.random.PRNGKey(seed)

        def loss_fn(params):
            logits, new_state = apply(
                params, state, x, levels, eta, ams_sigma, key, True
            )
            loss = cross_entropy(logits, y)
            return loss, (new_state, accuracy_count(logits, y))

        (loss, (new_state, acc)), grads = jax.value_and_grad(
            loss_fn, has_aux=True
        )(params)

        g_flat = [v for _, v in model_lib.flatten_tree(grads)]
        new_p, new_m = [], []
        for p, g, m in zip(params_flat, g_flat, mom_flat):
            g = g + tcfg.weight_decay * p
            m_new = tcfg.momentum * m + g
            upd = g + tcfg.momentum * m_new if tcfg.nesterov else m_new
            new_p.append(p - lr * upd)
            new_m.append(m_new)
        ns_flat = [v for _, v in model_lib.flatten_tree(new_state)]
        return tuple(new_p) + tuple(ns_flat) + tuple(new_m) + (loss, acc)

    meta = {
        "param_paths": p_paths,
        "state_paths": s_paths,
        "param_shapes": [list(v.shape) for _, v in model_lib.flatten_tree(p0)],
        "state_shapes": [list(v.shape) for _, v in model_lib.flatten_tree(s0)],
    }
    return step, meta


def make_eval_step(mcfg: ModelConfig, qcfg: QuantConfig, pcfg: PimConfig, mode: str, tcfg: TrainConfig):
    """Software (digital) or ideal-PIM evaluation step.

    inputs : params..., bn_state..., x, y, levels, eta
    outputs: loss_sum, acc_count
    """
    apply = make_apply(mcfg, qcfg, pcfg, mode, tcfg)
    p0, s0 = model_lib.model_init(jax.random.PRNGKey(0), mcfg)
    n_p = len(model_lib.flatten_tree(p0))
    n_s = len(model_lib.flatten_tree(s0))

    def step(*args):
        params = model_lib.unflatten_like(p0, list(args[:n_p]))
        state = model_lib.unflatten_like(s0, list(args[n_p : n_p + n_s]))
        x, y, levels, eta = args[n_p + n_s :]
        logits, _ = apply(
            params, state, x, levels, eta, jnp.float32(0.0), jax.random.PRNGKey(0), False
        )
        bsz = x.shape[0]
        return cross_entropy(logits, y) * bsz, accuracy_count(logits, y)

    return step


def make_init(mcfg: ModelConfig):
    """Parameter/state/momentum initialization, lowered to its own artifact
    so rust never re-implements Kaiming init.

    inputs : seed i32 ; outputs: params..., bn_state..., momentum...
    """

    def init(seed):
        params, state = model_lib.model_init(jax.random.PRNGKey(seed), mcfg)
        p_flat = [v for _, v in model_lib.flatten_tree(params)]
        s_flat = [v for _, v in model_lib.flatten_tree(state)]
        m_flat = [jnp.zeros_like(v) for v in p_flat]
        return tuple(p_flat) + tuple(s_flat) + tuple(m_flat)

    return init
