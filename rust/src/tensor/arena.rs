//! Grown-once buffer pool (EXPERIMENTS.md §Perf L3.5, extended to feature
//! maps in L3.7): recycles the large flat buffers of the training hot
//! loop — im2col patches, quantized u8 grids, transposed-GEMM outputs,
//! scaled-gradient staging, and every feature-map intermediate (conv/BN/
//! activation outputs, STE masks, maxpool argmax indices, gradient
//! feature maps) — so the steady-state train step performs zero large
//! allocations end to end.
//!
//! `take_*` hands out the smallest pooled buffer whose capacity fits the
//! requested length (best fit), or a fresh one when nothing fits (the
//! grow-once phase); `put_*` returns a buffer for reuse.  A training step
//! requests the same multiset of sizes every iteration, so from step 2 on
//! every take is a hit.  [`BufPool::take_like`]/[`BufPool::put_tensor`]
//! are the tensor-shaped conveniences: a "pooled tensor" is an ordinary
//! [`Tensor`] whose storage happens to come from the pool and is owed back
//! to it.  Ownership rules live in DESIGN.md §Arena.

use super::Tensor;

/// Size-classed free lists of reusable flat buffers.
#[derive(Debug, Default)]
pub struct BufPool {
    f32s: Vec<Vec<f32>>,
    u8s: Vec<Vec<u8>>,
    u32s: Vec<Vec<u32>>,
}

impl BufPool {
    pub fn new() -> Self {
        BufPool::default()
    }

    /// Take a cleared f32 buffer with capacity for at least `len` elements
    /// if one is pooled, else a fresh one with that capacity.
    pub fn take_f32(&mut self, len: usize) -> Vec<f32> {
        take(&mut self.f32s, len)
    }

    /// Return an f32 buffer for reuse.
    pub fn put_f32(&mut self, buf: Vec<f32>) {
        if buf.capacity() > 0 {
            self.f32s.push(buf);
        }
    }

    /// Take a cleared u8 buffer with capacity for at least `len` elements
    /// if one is pooled, else a fresh one with that capacity.
    pub fn take_u8(&mut self, len: usize) -> Vec<u8> {
        take(&mut self.u8s, len)
    }

    /// Return a u8 buffer for reuse.
    pub fn put_u8(&mut self, buf: Vec<u8>) {
        if buf.capacity() > 0 {
            self.u8s.push(buf);
        }
    }

    /// Take a cleared u32 buffer (maxpool argmax indices) with capacity
    /// for at least `len` elements.
    pub fn take_u32(&mut self, len: usize) -> Vec<u32> {
        take(&mut self.u32s, len)
    }

    /// Return a u32 buffer for reuse.
    pub fn put_u32(&mut self, buf: Vec<u32>) {
        if buf.capacity() > 0 {
            self.u32s.push(buf);
        }
    }

    /// Take an f32 buffer pre-sized to exactly `len` zeros (scatter-add
    /// targets).
    pub fn take_zeroed_f32(&mut self, len: usize) -> Vec<f32> {
        let mut v = self.take_f32(len);
        v.resize(len, 0.0);
        v
    }

    /// Pooled clone: a tensor with `src`'s shape and contents whose
    /// storage comes from the pool (owed back via [`BufPool::put_tensor`]).
    pub fn take_like(&mut self, src: &Tensor) -> Tensor {
        let mut v = self.take_f32(src.len());
        v.extend_from_slice(&src.data);
        Tensor::from_vec(&src.shape, v)
    }

    /// Return a pooled tensor's storage (the shape vector is dropped).
    pub fn put_tensor(&mut self, t: Tensor) {
        self.put_f32(t.data);
    }

    /// Number of buffers currently pooled (tests / diagnostics).
    pub fn pooled(&self) -> usize {
        self.f32s.len() + self.u8s.len() + self.u32s.len()
    }
}

/// Slot-sharded accumulation buffer (§Perf L3.10): `slots` flat f32
/// buffers of identical length, written disjointly (one writer per slot —
/// lock-free by ownership, not by atomics) and combined by a **fixed-order
/// tree reduce**.
///
/// The reduction schedule is recursive halving over slot indices: at
/// stride `s`, slot `i` absorbs slot `i + s` for every `i ≡ 0 (mod 2s)`,
/// element by element in index order.  The floating-point association is
/// therefore a pure function of the slot indices — never of arrival
/// order, worker identity, or thread count — so the reduced sum in slot 0
/// is bitwise reproducible, and identical whether the pairs of a level
/// run in parallel on the worker pool ([`SlotBank::reduce_tree`]) or
/// serially on the calling thread
/// ([`SlotBank::reduce_serial_reference`], the parity oracle the tests
/// pin the parallel path against).
#[derive(Debug)]
pub struct SlotBank {
    slots: Vec<Vec<f32>>,
}

impl SlotBank {
    /// `slots` zeroed buffers of `len` elements each, allocated once.
    pub fn new(slots: usize, len: usize) -> SlotBank {
        SlotBank { slots: (0..slots.max(1)).map(|_| vec![0.0; len]).collect() }
    }

    /// Element count of one slot buffer.
    pub fn len(&self) -> usize {
        self.slots[0].len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots[0].is_empty()
    }

    /// Number of slots.
    pub fn slots(&self) -> usize {
        self.slots.len()
    }

    /// Disjoint per-slot write access: hand `&mut` of slot `m` to the
    /// writer that owns microbatch `m` (one writer per slot — the
    /// lock-free contract).
    pub fn slots_mut(&mut self) -> &mut [Vec<f32>] {
        &mut self.slots
    }

    /// Fixed-order tree reduce into slot 0, the pairs of each level run as
    /// jobs on the shared worker pool.  Returns the reduced sum.  Slots
    /// other than 0 are left holding partial sums; every slot must be
    /// fully rewritten before the next reduce.
    pub fn reduce_tree(&mut self) -> &[f32] {
        let n = self.slots.len();
        let mut stride = 1;
        while stride < n {
            let mut jobs: Vec<crate::util::pool::ScopedJob<'_>> = Vec::new();
            for chunk in self.slots.chunks_mut(2 * stride) {
                if chunk.len() > stride {
                    let (a, b) = chunk.split_at_mut(stride);
                    let (dst, src) = (&mut a[0], &b[0]);
                    jobs.push(Box::new(move || add_assign(dst, src)));
                }
            }
            crate::util::pool::run_scoped(jobs);
            stride *= 2;
        }
        &self.slots[0]
    }

    /// The same halving schedule executed strictly serially on the calling
    /// thread — the reference [`SlotBank::reduce_tree`] must match
    /// bitwise (each pair's element-order sum is computed identically; the
    /// pool only changes *where* a pair runs, never its association).
    pub fn reduce_serial_reference(&mut self) -> &[f32] {
        let n = self.slots.len();
        let mut stride = 1;
        while stride < n {
            for chunk in self.slots.chunks_mut(2 * stride) {
                if chunk.len() > stride {
                    let (a, b) = chunk.split_at_mut(stride);
                    add_assign(&mut a[0], &b[0]);
                }
            }
            stride *= 2;
        }
        &self.slots[0]
    }
}

fn add_assign(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src) {
        *d += *s;
    }
}

/// Best-fit take: the smallest pooled buffer whose capacity covers `len`.
/// A too-small buffer is left pooled for its own size class — growing it
/// would reallocate anyway.
fn take<T>(pool: &mut Vec<Vec<T>>, len: usize) -> Vec<T> {
    let mut best: Option<usize> = None;
    for (i, b) in pool.iter().enumerate() {
        if b.capacity() >= len && best.map_or(true, |j| b.capacity() < pool[j].capacity()) {
            best = Some(i);
        }
    }
    match best {
        Some(i) => {
            let mut v = pool.swap_remove(i);
            v.clear();
            v
        }
        None => Vec::with_capacity(len),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_best_fit_and_steady_state_reuses() {
        let mut p = BufPool::new();
        let small = {
            let mut v = p.take_f32(16);
            v.resize(16, 1.0);
            v
        };
        let big = {
            let mut v = p.take_f32(1024);
            v.resize(1024, 2.0);
            v
        };
        p.put_f32(big);
        p.put_f32(small);
        assert_eq!(p.pooled(), 2);
        // a 16-element request must not steal the 1024-capacity buffer
        let v = p.take_f32(16);
        assert!(v.capacity() >= 16 && v.capacity() < 1024);
        assert!(v.is_empty(), "taken buffers come back cleared");
        let v2 = p.take_f32(1000);
        assert!(v2.capacity() >= 1024);
        assert_eq!(p.pooled(), 0);
    }

    #[test]
    fn tensor_helpers_roundtrip_through_the_pool() {
        let mut p = BufPool::new();
        let src = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let t = p.take_like(&src);
        assert_eq!(t.shape, src.shape);
        assert_eq!(t.data, src.data);
        p.put_tensor(t);
        assert_eq!(p.pooled(), 1);
        let z = p.take_zeroed_f32(6);
        assert_eq!(z, vec![0.0; 6], "reused storage must come back zeroed");
        assert_eq!(p.pooled(), 0, "take_zeroed must reuse the pooled buffer");
        let i = p.take_u32(4);
        assert!(i.capacity() >= 4);
        p.put_u32(i);
        assert_eq!(p.pooled(), 1);
    }

    /// Deterministic pseudo-random fill (no RNG dep in this module's tests).
    fn fill(bank: &mut SlotBank) {
        for (m, slot) in bank.slots_mut().iter_mut().enumerate() {
            for (i, v) in slot.iter_mut().enumerate() {
                let h = (m as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(i as u64);
                *v = ((h >> 40) as f32 / 1.6e7) - 0.5;
            }
        }
    }

    #[test]
    fn tree_reduce_matches_serial_fold_reference_bitwise() {
        for &slots in &[1usize, 2, 3, 4, 5, 8] {
            let mut a = SlotBank::new(slots, 257);
            let mut b = SlotBank::new(slots, 257);
            fill(&mut a);
            fill(&mut b);
            let pa = a.reduce_tree().to_vec();
            let pb = b.reduce_serial_reference().to_vec();
            assert_eq!(pa, pb, "parallel tree diverged from the serial fold at {slots} slots");
        }
    }

    #[test]
    fn tree_reduce_is_deterministic_and_close_to_naive_sum() {
        let run = || {
            let mut bank = SlotBank::new(4, 1001);
            fill(&mut bank);
            bank.reduce_tree().to_vec()
        };
        let first = run();
        assert_eq!(first, run(), "tree reduce must be bitwise reproducible");
        // numerical sanity vs the naive left fold (not bitwise: different
        // association, same value to f64 accuracy of the inputs)
        let mut bank = SlotBank::new(4, 1001);
        fill(&mut bank);
        let mut naive = vec![0.0f64; 1001];
        for slot in bank.slots_mut().iter() {
            for (d, s) in naive.iter_mut().zip(slot) {
                *d += *s as f64;
            }
        }
        for (t, n) in first.iter().zip(&naive) {
            assert!((*t as f64 - n).abs() < 1e-4, "tree sum {t} vs naive {n}");
        }
    }

    #[test]
    fn slot_bank_single_slot_is_identity() {
        let mut bank = SlotBank::new(1, 8);
        fill(&mut bank);
        let want = bank.slots_mut()[0].clone();
        assert_eq!(bank.reduce_tree(), &want[..]);
        assert_eq!(bank.slots(), 1);
        assert_eq!(bank.len(), 8);
    }

    #[test]
    fn miss_hands_out_fresh_capacity() {
        let mut p = BufPool::new();
        let v = p.take_u8(64);
        assert!(v.capacity() >= 64);
        p.put_u8(v);
        // zero-capacity buffers are not worth pooling
        p.put_u8(Vec::new());
        assert_eq!(p.pooled(), 1);
    }
}
