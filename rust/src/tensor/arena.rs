//! Grown-once buffer pool (EXPERIMENTS.md §Perf L3.5): recycles the large
//! flat buffers of the training hot loop — im2col patches, quantized u8
//! grids, transposed-GEMM outputs, scaled-gradient staging — so the
//! steady-state train step performs zero large allocations.
//!
//! `take_*` hands out the smallest pooled buffer whose capacity fits the
//! requested length (best fit), or a fresh one when nothing fits (the
//! grow-once phase); `put_*` returns a buffer for reuse.  A training step
//! requests the same multiset of sizes every iteration, so from step 2 on
//! every take is a hit.  Ownership rules live in DESIGN.md §Arena.

/// Size-classed free lists of reusable flat buffers.
#[derive(Debug, Default)]
pub struct BufPool {
    f32s: Vec<Vec<f32>>,
    u8s: Vec<Vec<u8>>,
}

impl BufPool {
    pub fn new() -> Self {
        BufPool::default()
    }

    /// Take a cleared f32 buffer with capacity for at least `len` elements
    /// if one is pooled, else a fresh one with that capacity.
    pub fn take_f32(&mut self, len: usize) -> Vec<f32> {
        take(&mut self.f32s, len)
    }

    /// Return an f32 buffer for reuse.
    pub fn put_f32(&mut self, buf: Vec<f32>) {
        if buf.capacity() > 0 {
            self.f32s.push(buf);
        }
    }

    /// Take a cleared u8 buffer with capacity for at least `len` elements
    /// if one is pooled, else a fresh one with that capacity.
    pub fn take_u8(&mut self, len: usize) -> Vec<u8> {
        take(&mut self.u8s, len)
    }

    /// Return a u8 buffer for reuse.
    pub fn put_u8(&mut self, buf: Vec<u8>) {
        if buf.capacity() > 0 {
            self.u8s.push(buf);
        }
    }

    /// Number of buffers currently pooled (tests / diagnostics).
    pub fn pooled(&self) -> usize {
        self.f32s.len() + self.u8s.len()
    }
}

/// Best-fit take: the smallest pooled buffer whose capacity covers `len`.
/// A too-small buffer is left pooled for its own size class — growing it
/// would reallocate anyway.
fn take<T>(pool: &mut Vec<Vec<T>>, len: usize) -> Vec<T> {
    let mut best: Option<usize> = None;
    for (i, b) in pool.iter().enumerate() {
        if b.capacity() >= len && best.map_or(true, |j| b.capacity() < pool[j].capacity()) {
            best = Some(i);
        }
    }
    match best {
        Some(i) => {
            let mut v = pool.swap_remove(i);
            v.clear();
            v
        }
        None => Vec::with_capacity(len),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_best_fit_and_steady_state_reuses() {
        let mut p = BufPool::new();
        let small = {
            let mut v = p.take_f32(16);
            v.resize(16, 1.0);
            v
        };
        let big = {
            let mut v = p.take_f32(1024);
            v.resize(1024, 2.0);
            v
        };
        p.put_f32(big);
        p.put_f32(small);
        assert_eq!(p.pooled(), 2);
        // a 16-element request must not steal the 1024-capacity buffer
        let v = p.take_f32(16);
        assert!(v.capacity() >= 16 && v.capacity() < 1024);
        assert!(v.is_empty(), "taken buffers come back cleared");
        let v2 = p.take_f32(1000);
        assert!(v2.capacity() >= 1024);
        assert_eq!(p.pooled(), 0);
    }

    #[test]
    fn miss_hands_out_fresh_capacity() {
        let mut p = BufPool::new();
        let v = p.take_u8(64);
        assert!(v.capacity() >= 64);
        p.put_u8(v);
        // zero-capacity buffers are not worth pooling
        p.put_u8(Vec::new());
        assert_eq!(p.pooled(), 1);
    }
}
