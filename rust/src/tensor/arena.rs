//! Grown-once buffer pool (EXPERIMENTS.md §Perf L3.5, extended to feature
//! maps in L3.7): recycles the large flat buffers of the training hot
//! loop — im2col patches, quantized u8 grids, transposed-GEMM outputs,
//! scaled-gradient staging, and every feature-map intermediate (conv/BN/
//! activation outputs, STE masks, maxpool argmax indices, gradient
//! feature maps) — so the steady-state train step performs zero large
//! allocations end to end.
//!
//! `take_*` hands out the smallest pooled buffer whose capacity fits the
//! requested length (best fit), or a fresh one when nothing fits (the
//! grow-once phase); `put_*` returns a buffer for reuse.  A training step
//! requests the same multiset of sizes every iteration, so from step 2 on
//! every take is a hit.  [`BufPool::take_like`]/[`BufPool::put_tensor`]
//! are the tensor-shaped conveniences: a "pooled tensor" is an ordinary
//! [`Tensor`] whose storage happens to come from the pool and is owed back
//! to it.  Ownership rules live in DESIGN.md §Arena.

use super::Tensor;

/// Size-classed free lists of reusable flat buffers.
#[derive(Debug, Default)]
pub struct BufPool {
    f32s: Vec<Vec<f32>>,
    u8s: Vec<Vec<u8>>,
    u32s: Vec<Vec<u32>>,
}

impl BufPool {
    pub fn new() -> Self {
        BufPool::default()
    }

    /// Take a cleared f32 buffer with capacity for at least `len` elements
    /// if one is pooled, else a fresh one with that capacity.
    pub fn take_f32(&mut self, len: usize) -> Vec<f32> {
        take(&mut self.f32s, len)
    }

    /// Return an f32 buffer for reuse.
    pub fn put_f32(&mut self, buf: Vec<f32>) {
        if buf.capacity() > 0 {
            self.f32s.push(buf);
        }
    }

    /// Take a cleared u8 buffer with capacity for at least `len` elements
    /// if one is pooled, else a fresh one with that capacity.
    pub fn take_u8(&mut self, len: usize) -> Vec<u8> {
        take(&mut self.u8s, len)
    }

    /// Return a u8 buffer for reuse.
    pub fn put_u8(&mut self, buf: Vec<u8>) {
        if buf.capacity() > 0 {
            self.u8s.push(buf);
        }
    }

    /// Take a cleared u32 buffer (maxpool argmax indices) with capacity
    /// for at least `len` elements.
    pub fn take_u32(&mut self, len: usize) -> Vec<u32> {
        take(&mut self.u32s, len)
    }

    /// Return a u32 buffer for reuse.
    pub fn put_u32(&mut self, buf: Vec<u32>) {
        if buf.capacity() > 0 {
            self.u32s.push(buf);
        }
    }

    /// Take an f32 buffer pre-sized to exactly `len` zeros (scatter-add
    /// targets).
    pub fn take_zeroed_f32(&mut self, len: usize) -> Vec<f32> {
        let mut v = self.take_f32(len);
        v.resize(len, 0.0);
        v
    }

    /// Pooled clone: a tensor with `src`'s shape and contents whose
    /// storage comes from the pool (owed back via [`BufPool::put_tensor`]).
    pub fn take_like(&mut self, src: &Tensor) -> Tensor {
        let mut v = self.take_f32(src.len());
        v.extend_from_slice(&src.data);
        Tensor::from_vec(&src.shape, v)
    }

    /// Return a pooled tensor's storage (the shape vector is dropped).
    pub fn put_tensor(&mut self, t: Tensor) {
        self.put_f32(t.data);
    }

    /// Number of buffers currently pooled (tests / diagnostics).
    pub fn pooled(&self) -> usize {
        self.f32s.len() + self.u8s.len() + self.u32s.len()
    }
}

/// Best-fit take: the smallest pooled buffer whose capacity covers `len`.
/// A too-small buffer is left pooled for its own size class — growing it
/// would reallocate anyway.
fn take<T>(pool: &mut Vec<Vec<T>>, len: usize) -> Vec<T> {
    let mut best: Option<usize> = None;
    for (i, b) in pool.iter().enumerate() {
        if b.capacity() >= len && best.map_or(true, |j| b.capacity() < pool[j].capacity()) {
            best = Some(i);
        }
    }
    match best {
        Some(i) => {
            let mut v = pool.swap_remove(i);
            v.clear();
            v
        }
        None => Vec::with_capacity(len),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_best_fit_and_steady_state_reuses() {
        let mut p = BufPool::new();
        let small = {
            let mut v = p.take_f32(16);
            v.resize(16, 1.0);
            v
        };
        let big = {
            let mut v = p.take_f32(1024);
            v.resize(1024, 2.0);
            v
        };
        p.put_f32(big);
        p.put_f32(small);
        assert_eq!(p.pooled(), 2);
        // a 16-element request must not steal the 1024-capacity buffer
        let v = p.take_f32(16);
        assert!(v.capacity() >= 16 && v.capacity() < 1024);
        assert!(v.is_empty(), "taken buffers come back cleared");
        let v2 = p.take_f32(1000);
        assert!(v2.capacity() >= 1024);
        assert_eq!(p.pooled(), 0);
    }

    #[test]
    fn tensor_helpers_roundtrip_through_the_pool() {
        let mut p = BufPool::new();
        let src = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let t = p.take_like(&src);
        assert_eq!(t.shape, src.shape);
        assert_eq!(t.data, src.data);
        p.put_tensor(t);
        assert_eq!(p.pooled(), 1);
        let z = p.take_zeroed_f32(6);
        assert_eq!(z, vec![0.0; 6], "reused storage must come back zeroed");
        assert_eq!(p.pooled(), 0, "take_zeroed must reuse the pooled buffer");
        let i = p.take_u32(4);
        assert!(i.capacity() >= 4);
        p.put_u32(i);
        assert_eq!(p.pooled(), 1);
    }

    #[test]
    fn miss_hands_out_fresh_capacity() {
        let mut p = BufPool::new();
        let v = p.take_u8(64);
        assert!(v.capacity() >= 64);
        p.put_u8(v);
        // zero-capacity buffers are not worth pooling
        p.put_u8(Vec::new());
        assert_eq!(p.pooled(), 1);
    }
}
