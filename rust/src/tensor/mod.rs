//! Tensor substrate (S2): dense f32 tensors (NHWC for images) with the ops
//! the chip-sim inference engine needs — im2col, GEMM, conv, pooling.
//!
//! This is deliberately a small, predictable library: the inference hot path
//! (grouped integer MAC) lives in `crate::pim`; this module provides the
//! digital layers (first conv, shortcuts, BN, FC) and the patch plumbing.

pub mod arena;
pub mod gemm;
pub mod kernels;
pub mod ops;

/// A dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} vs data len {}",
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Reshape (must preserve element count).
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            self.data.len(),
            "reshape {:?} -> {shape:?}",
            self.shape
        );
        self.shape = shape.to_vec();
        self
    }

    /// 4-D index (NHWC).
    #[inline]
    pub fn at4(&self, n: usize, h: usize, w: usize, c: usize) -> f32 {
        debug_assert_eq!(self.rank(), 4);
        let (sh, sw, sc) = (self.shape[1], self.shape[2], self.shape[3]);
        self.data[((n * sh + h) * sw + w) * sc + c]
    }

    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.rank(), 2);
        self.data[i * self.shape[1] + j]
    }

    pub fn map(mut self, f: impl Fn(f32) -> f32) -> Self {
        for v in &mut self.data {
            *v = f(*v);
        }
        self
    }

    /// In-place elementwise add of an equally-shaped tensor — the
    /// residual-sum hot path (avoids the allocating [`Tensor::zip`] in the
    /// pooled training step).
    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// Elementwise binary op with an equally-shaped tensor.
    pub fn zip(mut self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Self {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a = f(*a, *b);
        }
        self
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_index() {
        let t = Tensor::from_vec(&[1, 2, 2, 3], (0..12).map(|i| i as f32).collect());
        assert_eq!(t.at4(0, 1, 0, 2), 8.0);
        assert_eq!(t.at4(0, 0, 1, 0), 3.0);
    }

    #[test]
    #[should_panic(expected = "shape")]
    fn bad_shape_panics() {
        Tensor::from_vec(&[2, 2], vec![1.0]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).reshape(&[3, 2]);
        assert_eq!(t.at2(2, 1), 6.0);
    }

    #[test]
    fn map_zip() {
        let a = Tensor::from_vec(&[3], vec![1., -2., 3.]);
        let b = Tensor::from_vec(&[3], vec![1., 1., 1.]);
        let c = a.map(f32::abs).zip(&b, |x, y| x + y);
        assert_eq!(c.data, vec![2., 3., 4.]);
    }
}
