//! NN ops over `Tensor` (NHWC): im2col, conv, pooling, batch norm.
//!
//! The im2col patch layout is channel-major — column index
//! ``c * kh*kw + (dy * kw + dx)`` — which makes a PIM channel-group of
//! ``uc`` channels a *contiguous* run of ``uc * kh*kw`` columns.  This is the
//! same layout contract as ``python/compile/pim.py::grouped_patches`` and is
//! what lets `crate::pim` reuse these patches directly.

use super::{gemm::gemm, Tensor};
use crate::util::pool;

/// Minimum elements touched before a threaded op dispatches to the worker
/// pool when threading is fully automatic; below this (CI smoke
/// geometries) the queue handoff costs more than the loop itself, so the
/// op runs inline — matching the engine's skip-at-1 behavior.  An
/// explicit pin — a nonzero `threads` argument or `$PIM_QAT_THREADS` — is
/// always honored.
pub(crate) const PAR_MIN_ELEMS: usize = 1 << 14;

/// The `$PIM_QAT_THREADS` pin, when set to a positive count.
fn env_threads() -> Option<usize> {
    std::env::var("PIM_QAT_THREADS").ok().and_then(|v| v.parse::<usize>().ok()).filter(|&t| t > 0)
}

/// Thread count for a threaded op over `work` total elements: explicit
/// pins win; otherwise tiny workloads run inline (see [`PAR_MIN_ELEMS`]).
pub(crate) fn work_threads(requested: usize, work: usize, cap: usize) -> usize {
    if requested == 0 && env_threads().is_none() && work < PAR_MIN_ELEMS {
        1
    } else {
        resolve_threads(requested).min(cap.max(1)).max(1)
    }
}

/// SAME-conv output spatial dims for an (h, w) input, kernel `k`, stride
/// `s` — lets arena callers size patch buffers before running im2col.
pub fn conv_out_dims(h: usize, w: usize, k: usize, s: usize) -> (usize, usize) {
    let pad = k / 2;
    ((h + 2 * pad - k) / s + 1, (w + 2 * pad - k) / s + 1)
}

/// Extract SAME-padded conv patches: x [B,H,W,C] → ([M, C*k*k], out_h, out_w)
/// with stride `s` and the channel-major layout documented above.
pub fn im2col(x: &Tensor, k: usize, s: usize) -> (Tensor, usize, usize) {
    im2col_threaded(x, k, s, 1)
}

/// `im2col` with the per-image work split across `threads` worker-pool
/// jobs (0 = auto: $PIM_QAT_THREADS or the available parallelism).  Every
/// patch row is a pure function of the input, so the output is
/// bit-identical to the single-threaded path for any thread count.
pub fn im2col_threaded(x: &Tensor, k: usize, s: usize, threads: usize) -> (Tensor, usize, usize) {
    let mut out = Vec::new();
    let (oh, ow) = im2col_into(x, k, s, threads, &mut out);
    let (b, c) = (x.shape[0], x.shape[3]);
    (Tensor::from_vec(&[b * oh * ow, c * k * k], out), oh, ow)
}

/// [`im2col_threaded`] writing into a reused buffer: `out` is cleared,
/// zero-filled and resized to B·oh·ow·C·k² — no allocation once it has
/// grown to size (the arena path of the training hot loop).
pub fn im2col_into(
    x: &Tensor,
    k: usize,
    s: usize,
    threads: usize,
    out: &mut Vec<f32>,
) -> (usize, usize) {
    assert_eq!(x.rank(), 4, "im2col expects NHWC");
    let (b, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (oh, ow) = conv_out_dims(h, w, k, s);
    let cols = c * k * k;
    let img = oh * ow * cols;
    out.clear();
    out.resize(b * img, 0.0);
    let threads = work_threads(threads, b * img, b);
    if threads <= 1 {
        for (bi, chunk) in out.chunks_mut(img).enumerate() {
            im2col_image(x, bi, k, s, oh, ow, chunk);
        }
    } else {
        let per = (b + threads - 1) / threads;
        let mut jobs: Vec<pool::ScopedJob<'_>> = Vec::with_capacity(threads);
        for (ti, block) in out.chunks_mut(per * img).enumerate() {
            let x = &*x;
            jobs.push(Box::new(move || {
                for (off, chunk) in block.chunks_mut(img).enumerate() {
                    im2col_image(x, ti * per + off, k, s, oh, ow, chunk);
                }
            }));
        }
        pool::run_scoped(jobs);
    }
    (oh, ow)
}

/// Patch extraction of one image into its [oh*ow, cols] output block.
fn im2col_image(x: &Tensor, bi: usize, k: usize, s: usize, oh: usize, ow: usize, out: &mut [f32]) {
    let (h, w, c) = (x.shape[1], x.shape[2], x.shape[3]);
    let pad = k / 2;
    let cols = c * k * k;
    for oy in 0..oh {
        for ox in 0..ow {
            let row = (oy * ow + ox) * cols;
            for dy in 0..k {
                let iy = (oy * s + dy) as isize - pad as isize;
                if iy < 0 || iy >= h as isize {
                    continue;
                }
                for dx in 0..k {
                    let ix = (ox * s + dx) as isize - pad as isize;
                    if ix < 0 || ix >= w as isize {
                        continue;
                    }
                    let src = ((bi * h + iy as usize) * w + ix as usize) * c;
                    let p = dy * k + dx;
                    for ci in 0..c {
                        out[row + ci * k * k + p] = x.data[src + ci];
                    }
                }
            }
        }
    }
}

/// Thread-count resolution shared by the threaded ops (0 = auto).
pub fn resolve_threads(requested: usize) -> usize {
    if requested > 0 {
        return requested;
    }
    env_threads()
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

/// Adjoint of [`im2col`]: scatter-add patch-row gradients [B*oh*ow, C*k*k]
/// back into an input-shaped [B,H,W,C] tensor (the data-gradient pass of a
/// SAME conv — "conv transpose" in backprop terms).  Images are disjoint
/// output slices, so the work is split per-image across worker-pool jobs
/// with bit-identical results at any thread count; tiny workloads skip the
/// dispatch entirely.
pub fn col2im(dpatches: &Tensor, x_shape: &[usize], k: usize, s: usize) -> Tensor {
    assert_eq!(x_shape.len(), 4, "col2im expects an NHWC target shape");
    let (oh, ow) = conv_out_dims(x_shape[1], x_shape[2], k, s);
    assert_eq!(
        dpatches.shape,
        vec![x_shape[0] * oh * ow, x_shape[3] * k * k],
        "patch gradient shape"
    );
    let mut out = Vec::new();
    col2im_into(&dpatches.data, x_shape, k, s, &mut out);
    Tensor::from_vec(x_shape, out)
}

/// [`col2im`] from a raw patch-gradient slice into a reused buffer: `out`
/// is cleared, zero-filled and resized to B·H·W·C — no allocation once it
/// has grown to size.
pub fn col2im_into(dpatches: &[f32], x_shape: &[usize], k: usize, s: usize, out: &mut Vec<f32>) {
    assert_eq!(x_shape.len(), 4, "col2im expects an NHWC target shape");
    let (b, h, w, c) = (x_shape[0], x_shape[1], x_shape[2], x_shape[3]);
    let (oh, ow) = conv_out_dims(h, w, k, s);
    let cols = c * k * k;
    assert_eq!(dpatches.len(), b * oh * ow * cols, "patch gradient size");
    let img = h * w * c;
    out.clear();
    out.resize(b * img, 0.0);
    let threads = work_threads(0, dpatches.len(), b);
    if threads <= 1 {
        for (bi, chunk) in out.chunks_mut(img).enumerate() {
            col2im_image(dpatches, bi, h, w, c, k, s, oh, ow, chunk);
        }
    } else {
        let per = (b + threads - 1) / threads;
        let mut jobs: Vec<pool::ScopedJob<'_>> = Vec::with_capacity(threads);
        for (ti, block) in out.chunks_mut(per * img).enumerate() {
            jobs.push(Box::new(move || {
                for (off, chunk) in block.chunks_mut(img).enumerate() {
                    col2im_image(dpatches, ti * per + off, h, w, c, k, s, oh, ow, chunk);
                }
            }));
        }
        pool::run_scoped(jobs);
    }
}

/// Scatter one image's patch gradients into its [h*w*c] output block.
#[allow(clippy::too_many_arguments)]
fn col2im_image(
    dp: &[f32],
    bi: usize,
    h: usize,
    w: usize,
    c: usize,
    k: usize,
    s: usize,
    oh: usize,
    ow: usize,
    out: &mut [f32],
) {
    let pad = k / 2;
    let cols = c * k * k;
    for oy in 0..oh {
        for ox in 0..ow {
            let row = ((bi * oh + oy) * ow + ox) * cols;
            for dy in 0..k {
                let iy = (oy * s + dy) as isize - pad as isize;
                if iy < 0 || iy >= h as isize {
                    continue;
                }
                for dx in 0..k {
                    let ix = (ox * s + dx) as isize - pad as isize;
                    if ix < 0 || ix >= w as isize {
                        continue;
                    }
                    let dst = ((iy as usize) * w + ix as usize) * c;
                    let p = dy * k + dx;
                    for ci in 0..c {
                        out[dst + ci] += dp[row + ci * k * k + p];
                    }
                }
            }
        }
    }
}

/// Reorder conv weights [kh,kw,C,O] (python HWIO) to the im2col column
/// layout: [C*k*k, O].
pub fn weights_to_cols(w: &Tensor) -> Tensor {
    assert_eq!(w.rank(), 4);
    let (kh, kw, c, o) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
    let mut out = vec![0.0f32; kh * kw * c * o];
    for dy in 0..kh {
        for dx in 0..kw {
            for ci in 0..c {
                for oi in 0..o {
                    let src = ((dy * kw + dx) * c + ci) * o + oi;
                    let dst = (ci * kh * kw + dy * kw + dx) * o + oi;
                    out[dst] = w.data[src];
                }
            }
        }
    }
    Tensor::from_vec(&[c * kh * kw, o], out)
}

/// Inverse of [`weights_to_cols`]: fold an im2col-layout gradient
/// [C*k*k, O] back to HWIO [kh,kw,C,O] (the weight-gradient pass).
pub fn cols_to_weights(g: &Tensor, kh: usize, kw: usize, c: usize, o: usize) -> Tensor {
    assert_eq!(g.shape, vec![c * kh * kw, o], "cols gradient shape");
    cols_to_weights_from(&g.data, kh, kw, c, o)
}

/// [`cols_to_weights`] from a raw [C·k·k·O] slice — arena callers keep the
/// column gradient in a pooled buffer instead of a `Tensor`.
pub fn cols_to_weights_from(g: &[f32], kh: usize, kw: usize, c: usize, o: usize) -> Tensor {
    assert_eq!(g.len(), c * kh * kw * o, "cols gradient size");
    let mut out = vec![0.0f32; kh * kw * c * o];
    for dy in 0..kh {
        for dx in 0..kw {
            for ci in 0..c {
                for oi in 0..o {
                    let src = (ci * kh * kw + dy * kw + dx) * o + oi;
                    let dst = ((dy * kw + dx) * c + ci) * o + oi;
                    out[dst] = g[src];
                }
            }
        }
    }
    Tensor::from_vec(&[kh, kw, c, o], out)
}

/// Quantize unit-scale activations onto the integer u8 grid the PIM engine
/// consumes: `dst[i] = round_ties_even(src[i] · levels)` (values must land
/// in [0, 255]).  Clears and refills `dst` — zero allocations once the
/// buffer has grown to size.
pub fn quantize_into_u8(src: &[f32], levels: f32, dst: &mut Vec<u8>) {
    dst.clear();
    dst.extend(src.iter().map(|&v| crate::chip::round_ties_even(v * levels) as u8));
}

/// Digital SAME conv, NHWC × HWIO → NHWC.
pub fn conv2d(x: &Tensor, w: &Tensor, stride: usize) -> Tensor {
    let (patches, oh, ow) = im2col(x, w.shape[0], stride);
    let wc = weights_to_cols(w);
    let m = patches.shape[0];
    let k = patches.shape[1];
    let o = wc.shape[1];
    let y = gemm(m, k, o, &patches.data, &wc.data);
    Tensor::from_vec(&[x.shape[0], oh, ow, o], y)
}

/// 2×2 max pool, stride 2 (VGG path).
pub fn maxpool2(x: &Tensor) -> Tensor {
    let (b, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (oh, ow) = (h / 2, w / 2);
    let mut out = Tensor::zeros(&[b, oh, ow, c]);
    for bi in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                for ci in 0..c {
                    let mut m = f32::NEG_INFINITY;
                    for dy in 0..2 {
                        for dx in 0..2 {
                            m = m.max(x.at4(bi, 2 * oy + dy, 2 * ox + dx, ci));
                        }
                    }
                    out.data[((bi * oh + oy) * ow + ox) * c + ci] = m;
                }
            }
        }
    }
    out
}

/// Global average pool: [B,H,W,C] → [B,C].
pub fn global_avg_pool(x: &Tensor) -> Tensor {
    let (b, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let mut out = vec![0.0f32; b * c];
    let inv = 1.0 / (h * w) as f32;
    for bi in 0..b {
        for hi in 0..h {
            for wi in 0..w {
                let src = ((bi * h + hi) * w + wi) * c;
                for ci in 0..c {
                    out[bi * c + ci] += x.data[src + ci] * inv;
                }
            }
        }
    }
    Tensor::from_vec(&[b, c], out)
}

/// BatchNorm (inference): per-channel affine with given running stats.
/// eps matches the jax model (1e-5).
pub fn batch_norm(x: &Tensor, gamma: &[f32], beta: &[f32], mean: &[f32], var: &[f32]) -> Tensor {
    let c = *x.shape.last().unwrap();
    assert!(gamma.len() == c && beta.len() == c && mean.len() == c && var.len() == c);
    let mut out = x.clone();
    let inv: Vec<f32> = var.iter().map(|v| 1.0 / (v + 1e-5).sqrt()).collect();
    for (i, v) in out.data.iter_mut().enumerate() {
        let ci = i % c;
        *v = gamma[ci] * (*v - mean[ci]) * inv[ci] + beta[ci];
    }
    out
}

/// Per-channel mean/variance over (B,H,W) — BN calibration's batch stats.
pub fn channel_stats(x: &Tensor) -> (Vec<f32>, Vec<f32>) {
    let c = *x.shape.last().unwrap();
    let n = x.len() / c;
    let mut mean = vec![0.0f64; c];
    for (i, v) in x.data.iter().enumerate() {
        mean[i % c] += *v as f64;
    }
    for m in &mut mean {
        *m /= n as f64;
    }
    let mut var = vec![0.0f64; c];
    for (i, v) in x.data.iter().enumerate() {
        let d = *v as f64 - mean[i % c];
        var[i % c] += d * d;
    }
    for v in &mut var {
        *v /= n as f64;
    }
    (
        mean.iter().map(|&m| m as f32).collect(),
        var.iter().map(|&v| v as f32).collect(),
    )
}

/// ReLU.
pub fn relu(x: Tensor) -> Tensor {
    x.map(|v| v.max(0.0))
}

/// Row-wise argmax of a [B, K] tensor.
pub fn argmax_rows(x: &Tensor) -> Vec<usize> {
    let (b, k) = (x.shape[0], x.shape[1]);
    (0..b)
        .map(|i| {
            let row = &x.data[i * k..(i + 1) * k];
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0
        })
        .collect()
}

/// Mean cross-entropy of logits [B,K] against labels.
pub fn cross_entropy(logits: &Tensor, labels: &[usize]) -> f32 {
    let (b, k) = (logits.shape[0], logits.shape[1]);
    let mut total = 0.0f64;
    for i in 0..b {
        let row = &logits.data[i * k..(i + 1) * k];
        let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let lse = row.iter().map(|&v| ((v - mx) as f64).exp()).sum::<f64>().ln() + mx as f64;
        total += lse - row[labels[i]] as f64;
    }
    (total / b as f64) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn conv_naive(x: &Tensor, w: &Tensor, s: usize) -> Tensor {
        let (b, h, wd, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
        let (kh, kw, _, o) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
        let pad = kh / 2;
        let oh = (h + 2 * pad - kh) / s + 1;
        let ow = (wd + 2 * pad - kw) / s + 1;
        let mut out = Tensor::zeros(&[b, oh, ow, o]);
        for bi in 0..b {
            for oy in 0..oh {
                for ox in 0..ow {
                    for oi in 0..o {
                        let mut acc = 0.0;
                        for dy in 0..kh {
                            for dx in 0..kw {
                                let iy = (oy * s + dy) as isize - pad as isize;
                                let ix = (ox * s + dx) as isize - pad as isize;
                                if iy < 0 || ix < 0 || iy >= h as isize || ix >= wd as isize {
                                    continue;
                                }
                                for ci in 0..c {
                                    acc += x.at4(bi, iy as usize, ix as usize, ci)
                                        * w.data[((dy * kw + dx) * c + ci) * o + oi];
                                }
                            }
                        }
                        out.data[((bi * oh + oy) * ow + ox) * o + oi] = acc;
                    }
                }
            }
        }
        out
    }

    #[test]
    fn conv_matches_naive() {
        let mut rng = Rng::new(2);
        for &(h, c, o, k, s) in &[(6, 4, 3, 3, 1), (8, 8, 5, 3, 2), (5, 2, 2, 1, 1)] {
            let x = Tensor::from_vec(
                &[2, h, h, c],
                (0..2 * h * h * c).map(|_| rng.normal_in(0.0, 1.0)).collect(),
            );
            let w = Tensor::from_vec(
                &[k, k, c, o],
                (0..k * k * c * o).map(|_| rng.normal_in(0.0, 1.0)).collect(),
            );
            let y1 = conv2d(&x, &w, s);
            let y2 = conv_naive(&x, &w, s);
            assert_eq!(y1.shape, y2.shape);
            assert!(y1.max_abs_diff(&y2) < 1e-4);
        }
    }

    #[test]
    fn im2col_threaded_bit_identical() {
        let mut rng = Rng::new(9);
        let x = Tensor::from_vec(
            &[5, 6, 6, 3],
            (0..5 * 6 * 6 * 3).map(|_| rng.normal_in(0.0, 1.0)).collect(),
        );
        for &(k, s) in &[(3usize, 1usize), (3, 2), (1, 1)] {
            let (p1, oh, ow) = im2col_threaded(&x, k, s, 1);
            for t in [2usize, 3, 8] {
                let (pt, oht, owt) = im2col_threaded(&x, k, s, t);
                assert_eq!((oh, ow), (oht, owt));
                assert_eq!(p1.data, pt.data, "k={k} s={s} t={t}");
            }
        }
    }

    #[test]
    fn im2col_group_contiguity() {
        // a PIM channel group (uc channels) must be contiguous in the column.
        let x = Tensor::from_vec(&[1, 2, 2, 4], (0..16).map(|i| i as f32).collect());
        let (p, _, _) = im2col(&x, 1, 1);
        // with k=1 the patch is just the channel vector
        assert_eq!(p.shape, vec![4, 4]);
        assert_eq!(&p.data[0..4], &[0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn col2im_is_im2col_adjoint() {
        // ⟨G, im2col(x)⟩ == ⟨col2im(G), x⟩ for all x, G — the defining
        // property of the conv data-gradient.
        let mut rng = Rng::new(11);
        for &(h, c, k, s) in &[(6usize, 3usize, 3usize, 1usize), (7, 2, 3, 2), (5, 4, 1, 1)] {
            let x = Tensor::from_vec(
                &[2, h, h, c],
                (0..2 * h * h * c).map(|_| rng.normal_in(0.0, 1.0)).collect(),
            );
            let (p, _, _) = im2col(&x, k, s);
            let g = Tensor::from_vec(
                &p.shape,
                (0..p.len()).map(|_| rng.normal_in(0.0, 1.0)).collect(),
            );
            let lhs: f64 = g.data.iter().zip(&p.data).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
            let dx = col2im(&g, &x.shape, k, s);
            assert_eq!(dx.shape, x.shape);
            let rhs: f64 =
                dx.data.iter().zip(&x.data).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
            assert!((lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0), "k={k} s={s}: {lhs} vs {rhs}");
        }
    }

    #[test]
    fn into_variants_match_and_reuse_buffers() {
        let mut rng = Rng::new(21);
        let x = Tensor::from_vec(
            &[3, 5, 5, 2],
            (0..150).map(|_| rng.normal_in(0.0, 1.0)).collect(),
        );
        let (p, oh, ow) = im2col_threaded(&x, 3, 1, 0);
        let mut buf = Vec::new();
        let (oh2, ow2) = im2col_into(&x, 3, 1, 0, &mut buf);
        assert_eq!((oh, ow), (oh2, ow2));
        assert_eq!(p.data, buf);
        let cap = buf.capacity();
        // second fill into the grown buffer: same result, no growth
        im2col_into(&x, 3, 1, 0, &mut buf);
        assert_eq!(p.data, buf);
        assert_eq!(buf.capacity(), cap);

        let g = Tensor::from_vec(&p.shape, (0..p.len()).map(|_| rng.normal_in(0.0, 1.0)).collect());
        let dx = col2im(&g, &x.shape, 3, 1);
        let mut dbuf = Vec::new();
        col2im_into(&g.data, &x.shape, 3, 1, &mut dbuf);
        assert_eq!(dx.data, dbuf);
    }

    #[test]
    fn quantize_into_u8_rounds_ties_even() {
        let src = vec![0.0, 1.0, 0.5, 0.1];
        let mut dst = Vec::new();
        quantize_into_u8(&src, 15.0, &mut dst);
        // 0.5·15 = 7.5 → 8 (ties-to-even), 0.1·15 = 1.5 → 2
        assert_eq!(dst, vec![0, 15, 8, 2]);
        let cap = dst.capacity();
        quantize_into_u8(&src, 15.0, &mut dst);
        assert_eq!(dst.capacity(), cap);
    }

    #[test]
    fn cols_to_weights_roundtrip() {
        let mut rng = Rng::new(12);
        let w = Tensor::from_vec(
            &[3, 3, 4, 5],
            (0..3 * 3 * 4 * 5).map(|_| rng.normal_in(0.0, 1.0)).collect(),
        );
        let cols = weights_to_cols(&w);
        let back = cols_to_weights(&cols, 3, 3, 4, 5);
        assert_eq!(back.shape, w.shape);
        assert_eq!(back.data, w.data);
    }

    #[test]
    fn maxpool_and_gap() {
        let x = Tensor::from_vec(&[1, 2, 2, 1], vec![1., 2., 3., 4.]);
        assert_eq!(maxpool2(&x).data, vec![4.0]);
        assert_eq!(global_avg_pool(&x).data, vec![2.5]);
    }

    #[test]
    fn bn_identity_when_normalized() {
        let x = Tensor::from_vec(&[1, 1, 1, 2], vec![3.0, -1.0]);
        let y = batch_norm(&x, &[1.0, 1.0], &[0.0, 0.0], &[3.0, -1.0], &[1.0, 1.0]);
        assert!(y.data.iter().all(|v| v.abs() < 1e-6));
    }

    #[test]
    fn channel_stats_simple() {
        let x = Tensor::from_vec(&[1, 1, 2, 2], vec![1.0, 10.0, 3.0, 20.0]);
        let (m, v) = channel_stats(&x);
        assert_eq!(m, vec![2.0, 15.0]);
        assert_eq!(v, vec![1.0, 25.0]);
    }

    #[test]
    fn ce_and_argmax() {
        let l = Tensor::from_vec(&[2, 3], vec![10., 0., 0., 0., 0., 5.]);
        assert_eq!(argmax_rows(&l), vec![0, 2]);
        assert!(cross_entropy(&l, &[0, 2]) < 0.01);
        assert!(cross_entropy(&l, &[1, 0]) > 2.0);
    }
}
