//! One-shot deterministic startup autotuner for the blocked-GEMM tile
//! triple (§Perf L3.9).
//!
//! The packed-panel driver (`kernels::blocked`) needs an (MC, KC, NC)
//! block geometry.  Good values are host-dependent (L1/L2 sizes, SIMD
//! width), so instead of a compile-time guess the first resolution probes
//! a **small fixed candidate set** on a fixed synthetic workload and
//! caches the winner for the rest of the process in a `OnceLock`:
//!
//! * the candidate list and probe workload are compiled in — no search
//!   space drift between hosts;
//! * candidates are probed in a **seeded deterministic order** (a fixed-
//!   seed Fisher–Yates permutation), and ties break toward the earlier
//!   probe, so the only host-dependent input is the timing itself;
//! * the probe runs once, at startup (first `kernels::active()` call on a
//!   SIMD arm), single-threaded, on ~1 MiB of data — tens of milliseconds
//!   end to end.
//!
//! Reproducibility knobs (DESIGN.md §Kernel dispatch, knob table):
//!
//! * `PIM_QAT_TILE=MCxKCxNC` (e.g. `64x64x256`) pins the triple outright —
//!   the probe never runs.  A malformed value panics loudly rather than
//!   silently degrading the reproducibility the pin was asked for.
//! * `PIM_QAT_NO_AUTOTUNE=1` skips the probe and uses the fixed
//!   [`DEFAULT`] triple — the CI / cross-host-comparison configuration
//!   (combine with `PIM_QAT_NO_SIMD=1` for cross-host *bitwise* f32
//!   comparisons; the scalar arm never consults the tile at all).
//!
//! Within a process the resolved tile is immutable, so the f32 blocked
//! path stays bit-identical run-to-run (the L3.6 determinism contract).
//! Across *processes* the probed winner may differ when host timing
//! flips between close candidates — pin the tile (or disable autotune)
//! when two runs must agree bitwise.

use std::sync::OnceLock;
use std::time::Instant;

use super::blocked::{self, TileKernel};
use crate::util::rng::Rng;

/// Blocked-GEMM tile triple: C is walked in NC-wide column stripes, K in
/// KC slabs (the packed B panel is KC×NC), and rows in MC blocks (the
/// packed A block is MC×KC).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Tile {
    pub mc: usize,
    pub kc: usize,
    pub nc: usize,
}

/// Fixed default (the `PIM_QAT_NO_AUTOTUNE=1` triple): a 16 KiB A block
/// (half of a typical L1d) and a 64 KiB B panel (comfortably L2), with
/// the NC-wide C stripe (1 KiB/row) staying L1-resident across the KC
/// loop.
pub const DEFAULT: Tile = Tile { mc: 64, kc: 64, nc: 256 };

/// The probe's fixed candidate set.  Small on purpose: the probe is paid
/// at every process start, and the per-candidate parity sweep in
/// `tests/engine_parity.rs` runs the full f32 contract over every entry.
pub const CANDIDATES: &[Tile] = &[
    DEFAULT,
    Tile { mc: 32, kc: 32, nc: 384 },  // the pre-L3.9 AVX2 guess (KB=32, NB=384)
    Tile { mc: 128, kc: 64, nc: 128 }, // taller A block, narrower stripe
    Tile { mc: 32, kc: 128, nc: 256 }, // deeper K slab
    Tile { mc: 64, kc: 256, nc: 64 },  // deepest K, narrow stripe (tall-k shapes)
    Tile { mc: 16, kc: 64, nc: 512 },  // wide stripe (large-n shapes)
];

static TILE: OnceLock<Tile> = OnceLock::new();

/// Resolve the process tile eagerly for the selected arm — called by
/// `kernels::select()` once, right after SIMD arm selection, so the probe
/// cost lands at startup instead of inside the first training step.
pub(super) fn warm(table: &super::KernelTable) {
    let _ = tile_for(table.gemm_acc_tile);
}

/// The process-wide tile triple, resolved on first call (env pin →
/// fixed default → probe with `kernel`) and cached in the `OnceLock`.
pub fn tile_for(kernel: TileKernel) -> Tile {
    *TILE.get_or_init(|| resolve(kernel))
}

/// The already-resolved tile, if any (benches report it alongside the arm
/// name; `None` until the first blocked dispatch or `warm`).
pub fn chosen() -> Option<Tile> {
    TILE.get().copied()
}

/// `PIM_QAT_NO_AUTOTUNE=1` (any non-empty value other than "0") forces
/// the fixed [`DEFAULT`] triple.
fn no_autotune_forced() -> bool {
    std::env::var_os("PIM_QAT_NO_AUTOTUNE").is_some_and(|v| !v.is_empty() && v != "0")
}

fn resolve(kernel: TileKernel) -> Tile {
    if let Ok(s) = std::env::var("PIM_QAT_TILE") {
        if !s.is_empty() {
            return parse_tile(&s).unwrap_or_else(|| {
                panic!("PIM_QAT_TILE must be MCxKCxNC, e.g. 64x64x256 (got {s:?})")
            });
        }
    }
    if no_autotune_forced() {
        return DEFAULT;
    }
    probe(kernel)
}

/// Parse `MCxKCxNC` (three positive decimal sizes separated by `x`).
pub fn parse_tile(s: &str) -> Option<Tile> {
    let mut parts = s.split('x');
    let mc: usize = parts.next()?.parse().ok()?;
    let kc: usize = parts.next()?.parse().ok()?;
    let nc: usize = parts.next()?.parse().ok()?;
    if parts.next().is_some() || mc == 0 || kc == 0 || nc == 0 {
        return None;
    }
    Some(Tile { mc, kc, nc })
}

/// The seeded deterministic probe order: a fixed-seed Fisher–Yates
/// permutation of the candidate indices — identical on every host.
fn probe_order() -> Vec<usize> {
    let mut order: Vec<usize> = (0..CANDIDATES.len()).collect();
    Rng::new(0x9A07).shuffle(&mut order);
    order
}

/// Probe workload: one mid-size GEMM per candidate (several repetitions,
/// best-of), big enough to exercise the packed-panel walk for every
/// candidate and small enough to keep startup cost in the tens of
/// milliseconds on a SIMD arm.
const PROBE_M: usize = 96;
const PROBE_K: usize = 256;
const PROBE_N: usize = 256;
const PROBE_REPS: usize = 3;

fn probe(kernel: TileKernel) -> Tile {
    let mut rng = Rng::new(0x711E);
    let a: Vec<f32> = (0..PROBE_M * PROBE_K).map(|_| rng.normal_in(0.0, 1.0)).collect();
    let b: Vec<f32> = (0..PROBE_K * PROBE_N).map(|_| rng.normal_in(0.0, 1.0)).collect();
    let mut c = vec![0.0f32; PROBE_M * PROBE_N];
    let mut best: Option<(f64, Tile)> = None;
    for ci in probe_order() {
        let t = CANDIDATES[ci];
        // one unmeasured warmup pass per candidate (panel arena grow,
        // instruction cache), then best-of-REPS
        blocked::gemm_acc_packed_with(t, PROBE_M, PROBE_K, PROBE_N, &a, &b, &mut c, kernel);
        let mut best_ns = f64::INFINITY;
        for _ in 0..PROBE_REPS {
            c.fill(0.0);
            let t0 = Instant::now();
            blocked::gemm_acc_packed_with(t, PROBE_M, PROBE_K, PROBE_N, &a, &b, &mut c, kernel);
            best_ns = best_ns.min(t0.elapsed().as_nanos() as f64);
        }
        std::hint::black_box(&c);
        // strict `<`: ties keep the earlier candidate in the seeded order
        if best.is_none_or(|(ns, _)| best_ns < ns) {
            best = Some((best_ns, t));
        }
    }
    best.map(|(_, t)| t).unwrap_or(DEFAULT)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_tile_roundtrip_and_rejects_garbage() {
        assert_eq!(parse_tile("64x64x256"), Some(DEFAULT));
        assert_eq!(parse_tile("8x16x32"), Some(Tile { mc: 8, kc: 16, nc: 32 }));
        for bad in ["", "64", "64x64", "64x64x0", "0x1x1", "axbxc", "64x64x256x4", "64X64X256"] {
            assert_eq!(parse_tile(bad), None, "{bad:?} must be rejected");
        }
    }

    #[test]
    fn probe_order_is_a_seeded_deterministic_permutation() {
        let o1 = probe_order();
        let o2 = probe_order();
        assert_eq!(o1, o2, "probe order must be deterministic");
        let mut sorted = o1.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..CANDIDATES.len()).collect::<Vec<_>>());
    }

    #[test]
    fn candidates_are_positive_and_include_the_fixed_default() {
        assert!(CANDIDATES.contains(&DEFAULT), "NO_AUTOTUNE triple must be a probed candidate");
        for t in CANDIDATES {
            assert!(t.mc > 0 && t.kc > 0 && t.nc > 0, "{t:?}");
        }
    }

    #[test]
    fn tile_for_caches_one_process_wide_answer() {
        let t1 = tile_for(super::super::scalar::gemm_acc_tile);
        let t2 = tile_for(super::super::scalar::gemm_acc_tile);
        assert_eq!(t1, t2, "OnceLock must hand out one tile");
        assert_eq!(chosen(), Some(t1));
        assert!(t1.mc > 0 && t1.kc > 0 && t1.nc > 0);
    }
}
