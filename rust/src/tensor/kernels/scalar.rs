//! Scalar reference arm: always compiled, on every target.  The integer
//! kernels here define the bit-exact contract every SIMD arm must match;
//! the f32 kernels are the pre-dispatch implementations unchanged (4-wide
//! k register blocking for `gemm_acc`, dot-product `gemm_nt_acc`,
//! zero-skip `gemm_tn_acc`).

use super::KernelTable;

/// The scalar kernel table.  Note `gemm_acc` here is the direct (non-
/// blocked) walk: the scalar arm never routes through the packed-panel
/// driver, which keeps `PIM_QAT_NO_SIMD=1` outputs bit-identical across
/// releases (the cross-host / checkpoint-compat contract).
pub static TABLE: KernelTable = KernelTable {
    name: "scalar",
    gemm_acc,
    gemm_acc_tile,
    gemm_nt_acc,
    gemm_tn_acc,
    gemm_acc_u8_i16,
    gemm_acc_u8_bin,
    gemm_acc_u8_bin_packed,
};

/// C[m,n] += A[m,k] · B[k,n], row-major, dense f32.
pub fn gemm_acc(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        let mut kk = 0;
        // register-blocked: 4 rows of B share one pass over the C row
        while kk + 4 <= k {
            let a0 = arow[kk];
            let a1 = arow[kk + 1];
            let a2 = arow[kk + 2];
            let a3 = arow[kk + 3];
            let b0 = &b[kk * n..kk * n + n];
            let b1 = &b[(kk + 1) * n..(kk + 1) * n + n];
            let b2 = &b[(kk + 2) * n..(kk + 2) * n + n];
            let b3 = &b[(kk + 3) * n..(kk + 3) * n + n];
            for j in 0..n {
                crow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
            }
            kk += 4;
        }
        while kk < k {
            let aik = arow[kk];
            let brow = &b[kk * n..kk * n + n];
            for j in 0..n {
                crow[j] += aik * brow[j];
            }
            kk += 1;
        }
    }
}

/// Packed-tile microkernel for the blocked driver (`kernels::blocked`):
/// accumulate `pa[mb,kb] · pb[kb,nb]` into the C block at flat offset
/// `c0` with row stride `ldc`.  Same 4-wide k register blocking as
/// [`gemm_acc`]; the reference [`TileKernel`](super::blocked::TileKernel)
/// the per-candidate parity tests compare SIMD tile kernels against.
pub fn gemm_acc_tile(
    mb: usize,
    kb: usize,
    nb: usize,
    pa: &[f32],
    pb: &[f32],
    c: &mut [f32],
    c0: usize,
    ldc: usize,
) {
    assert_eq!(pa.len(), mb * kb);
    assert_eq!(pb.len(), kb * nb);
    assert!(nb <= ldc);
    if mb == 0 || nb == 0 {
        return;
    }
    assert!(c0 + (mb - 1) * ldc + nb <= c.len());
    for ii in 0..mb {
        let arow = &pa[ii * kb..(ii + 1) * kb];
        let crow = &mut c[c0 + ii * ldc..c0 + ii * ldc + nb];
        let mut kk = 0;
        while kk + 4 <= kb {
            let a0 = arow[kk];
            let a1 = arow[kk + 1];
            let a2 = arow[kk + 2];
            let a3 = arow[kk + 3];
            let b0 = &pb[kk * nb..kk * nb + nb];
            let b1 = &pb[(kk + 1) * nb..(kk + 1) * nb + nb];
            let b2 = &pb[(kk + 2) * nb..(kk + 2) * nb + nb];
            let b3 = &pb[(kk + 3) * nb..(kk + 3) * nb + nb];
            for j in 0..nb {
                crow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
            }
            kk += 4;
        }
        while kk < kb {
            let aik = arow[kk];
            let brow = &pb[kk * nb..kk * nb + nb];
            for j in 0..nb {
                crow[j] += aik * brow[j];
            }
            kk += 1;
        }
    }
}

/// C[m,n] += A[m,p] · B[n,p]ᵀ (both row-major), dot-product form — both
/// operands stream row-wise.
pub fn gemm_nt_acc(m: usize, p: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert_eq!(a.len(), m * p);
    assert_eq!(b.len(), n * p);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * p..(i + 1) * p];
        let crow = &mut c[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = &b[j * p..(j + 1) * p];
            let mut s = 0.0f32;
            for q in 0..p {
                s += arow[q] * brow[q];
            }
            crow[j] += s;
        }
    }
}

/// C[m,n] += A[p,m]ᵀ · B[p,n] (both row-major).  Keeps the zero-skip on A
/// — the weight-gradient pass feeds post-ReLU quantized patch rows, which
/// carry many exact zeros.
pub fn gemm_tn_acc(p: usize, m: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert_eq!(a.len(), p * m);
    assert_eq!(b.len(), p * n);
    assert_eq!(c.len(), m * n);
    for q in 0..p {
        let arow = &a[q * m..(q + 1) * m];
        let brow = &b[q * n..(q + 1) * n];
        for (i, &aq) in arow.iter().enumerate() {
            if aq == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += aq * brow[j];
            }
        }
    }
}

/// Integer plane kernel: C[m,n] += A[m,k] · B[k,n] with u8 activations,
/// i16 weights, i32 accumulators.  Exact, so any accumulation order is
/// bit-identical (all magnitudes ≤ 2²⁴).
pub fn gemm_acc_u8_i16(m: usize, k: usize, n: usize, a: &[u8], b: &[i16], c: &mut [i32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        let mut kk = 0;
        while kk + 4 <= k {
            let a0 = arow[kk] as i32;
            let a1 = arow[kk + 1] as i32;
            let a2 = arow[kk + 2] as i32;
            let a3 = arow[kk + 3] as i32;
            let b0 = &b[kk * n..kk * n + n];
            let b1 = &b[(kk + 1) * n..(kk + 1) * n + n];
            let b2 = &b[(kk + 2) * n..(kk + 2) * n + n];
            let b3 = &b[(kk + 3) * n..(kk + 3) * n + n];
            for j in 0..n {
                crow[j] +=
                    a0 * b0[j] as i32 + a1 * b1[j] as i32 + a2 * b2[j] as i32 + a3 * b3[j] as i32;
            }
            kk += 4;
        }
        while kk < k {
            let aik = arow[kk] as i32;
            let brow = &b[kk * n..kk * n + n];
            for j in 0..n {
                crow[j] += aik * brow[j] as i32;
            }
            kk += 1;
        }
    }
}

/// Binary-plane kernel: weights are bit-serial planes in {0, 1} stored one
/// per u8.  Keeps the activation zero-skip (DAC planes under m=1 slicing
/// are ~half zeros).
pub fn gemm_acc_u8_bin(m: usize, k: usize, n: usize, a: &[u8], b: &[u8], c: &mut [i32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0 {
                continue;
            }
            let av = aik as i32;
            let brow = &b[kk * n..kk * n + n];
            for j in 0..n {
                crow[j] += av * brow[j] as i32;
            }
        }
    }
}

/// Bit-packed binary-plane kernel: B row `kk` is `(n+63)/64` u64 words,
/// bit `o%64` of word `o/64` ↔ column `o` — 8× less weight traffic than
/// the u8 layout.  The scalar arm walks set bits with
/// `trailing_zeros` / clear-lowest; sums are exact, so this is
/// bit-identical to [`gemm_acc_u8_bin`] on the unpacked plane.
pub fn gemm_acc_u8_bin_packed(m: usize, k: usize, n: usize, a: &[u8], b: &[u64], c: &mut [i32]) {
    let wpr = crate::pim::layout::packed_words(n);
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * wpr);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0 {
                continue;
            }
            let av = aik as i32;
            let brow = &b[kk * wpr..(kk + 1) * wpr];
            for (wi, &word) in brow.iter().enumerate() {
                let mut w = word;
                let o0 = wi * 64;
                while w != 0 {
                    let o = o0 + w.trailing_zeros() as usize;
                    crow[o] += av;
                    w &= w - 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packed_matches_unpacked_bin() {
        // pad bits live in the last word; programming never sets them
        let (m, k, n) = (3usize, 5usize, 70usize);
        let a: Vec<u8> = (0..m * k).map(|i| (i % 3) as u8).collect();
        let bin: Vec<u8> = (0..k * n).map(|i| ((i * 7) % 3 == 0) as u8).collect();
        let packed = crate::pim::layout::pack_bin_plane(&bin, k, n);
        let mut c1 = vec![3i32; m * n];
        let mut c2 = vec![3i32; m * n];
        gemm_acc_u8_bin(m, k, n, &a, &bin, &mut c1);
        gemm_acc_u8_bin_packed(m, k, n, &a, &packed, &mut c2);
        assert_eq!(c1, c2);
    }

    #[test]
    fn nt_tn_accumulate() {
        // the table contract: += into c, not overwrite
        let a = vec![1.0f32, 2.0];
        let b = vec![3.0f32, 4.0];
        let mut c = vec![10.0f32];
        gemm_nt_acc(1, 2, 1, &a, &b, &mut c);
        assert_eq!(c, vec![21.0]);
        let mut c = vec![5.0f32];
        gemm_tn_acc(2, 1, 1, &a, &b, &mut c);
        assert_eq!(c, vec![16.0]);
    }
}
