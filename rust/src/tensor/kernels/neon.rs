//! NEON arm (`std::arch::aarch64`), selected at runtime by
//! [`super::active`] on aarch64 hosts (NEON is baseline on every aarch64
//! target Rust ships, but the runtime check keeps the selection honest and
//! mirrors the AVX2 arm's discipline).
//!
//! Scope (the L3.7 satellite): the **integer plane kernels** — u8×i16→i32
//! and the bit-packed binary-plane kernel — which carry the PIM engine's
//! hot loops.  Both compute exact i32 sums, so they are **bit-identical to
//! the scalar arm** on every shape; k/n tails that are not multiples of
//! the vector width run the same scalar tail code.  Pinned by the existing
//! odd-shape property sweep in `tests/engine_parity.rs` (which compares
//! the dispatched arm against scalar — on aarch64 that *is* this arm).
//! The f32 entries and the legacy u8 binary plane stay scalar: the f32
//! family is bandwidth-bound on the small-model shapes this repo runs, so
//! a NEON arm there is a measured follow-up, not a freebie.
//!
//! * `gemm_acc_u8_i16` — widening multiply-accumulate: the u8 activation
//!   (≤ 255, so it fits i16 exactly) broadcasts as the scalar operand of
//!   `vmlal_n_s16`/`vmlal_high_n_s16`, turning 8 weight lanes into 8 i32
//!   accumulations per step.  Products are ≤ 255·32767 < 2²³ — exact.
//! * `gemm_acc_u8_bin_packed` — each byte of a packed u64 word expands to
//!   two 4-lane 0/−1 masks (broadcast-AND-compare against per-lane bit
//!   constants) and the broadcast activation accumulates under the mask —
//!   the 128-bit analogue of the AVX2 broadcast-AND-accumulate loop.
//!
//! Every public fn asserts the slice geometry *and* the NEON feature
//! before entering the `#[target_feature]` inner body, so each table entry
//! is independently sound (same rationale as `kernels::avx2`).

#![allow(unsafe_code)]

use std::arch::aarch64::*;

use super::KernelTable;

/// The NEON kernel table.  Only select this after feature detection.
pub static TABLE: KernelTable = KernelTable {
    name: "neon",
    // f32 kernels stay scalar (see module docs)
    gemm_acc: super::scalar::gemm_acc,
    gemm_nt_acc: super::scalar::gemm_nt_acc,
    gemm_tn_acc: super::scalar::gemm_tn_acc,
    gemm_acc_u8_i16,
    // the one-weight-per-u8 binary layout survives only as the
    // reference/compat surface; the engine runs the packed kernel below
    gemm_acc_u8_bin: super::scalar::gemm_acc_u8_bin,
    gemm_acc_u8_bin_packed,
};

/// Release-mode guard: these are safe `pub fn`s, so executing the NEON
/// bodies without the feature would be UB reachable from safe code.  The
/// detection macro caches its answer — one load per GEMM call.
#[inline]
fn check_features() {
    assert!(
        std::arch::is_aarch64_feature_detected!("neon"),
        "neon kernel table used without NEON"
    );
}

// -- u8 × i16 → i32 plane kernel --------------------------------------------

pub fn gemm_acc_u8_i16(m: usize, k: usize, n: usize, a: &[u8], b: &[i16], c: &mut [i32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    check_features();
    unsafe { gemm_acc_u8_i16_impl(m, k, n, a, b, c) }
}

#[target_feature(enable = "neon")]
unsafe fn gemm_acc_u8_i16_impl(m: usize, k: usize, n: usize, a: &[u8], b: &[i16], c: &mut [i32]) {
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0 {
                // exact sums: the activation zero-skip is bit-neutral
                continue;
            }
            let a16 = aik as i16;
            let brow = b.as_ptr().add(kk * n);
            let cp = crow.as_mut_ptr();
            let mut j = 0;
            while j + 8 <= n {
                let w = vld1q_s16(brow.add(j));
                let c0 = vld1q_s32(cp.add(j) as *const i32);
                let c1 = vld1q_s32(cp.add(j + 4) as *const i32);
                let c0 = vmlal_n_s16(c0, vget_low_s16(w), a16);
                let c1 = vmlal_high_n_s16(c1, w, a16);
                vst1q_s32(cp.add(j), c0);
                vst1q_s32(cp.add(j + 4), c1);
                j += 8;
            }
            while j < n {
                crow[j] += aik as i32 * *brow.add(j) as i32;
                j += 1;
            }
        }
    }
}

// -- bit-packed binary plane kernel -----------------------------------------

pub fn gemm_acc_u8_bin_packed(m: usize, k: usize, n: usize, a: &[u8], b: &[u64], c: &mut [i32]) {
    let wpr = crate::pim::layout::packed_words(n);
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * wpr);
    assert_eq!(c.len(), m * n);
    check_features();
    unsafe { gemm_acc_u8_bin_packed_impl(m, k, n, wpr, a, b, c) }
}

#[target_feature(enable = "neon")]
unsafe fn gemm_acc_u8_bin_packed_impl(
    m: usize,
    k: usize,
    n: usize,
    wpr: usize,
    a: &[u8],
    b: &[u64],
    c: &mut [i32],
) {
    // per-lane bit constants: lane j of the low/high half tests bit j /
    // bit j+4 of the broadcast byte
    let lo_bits = [1i32, 2, 4, 8];
    let hi_bits = [16i32, 32, 64, 128];
    let bits_lo = vld1q_s32(lo_bits.as_ptr());
    let bits_hi = vld1q_s32(hi_bits.as_ptr());
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0 {
                continue;
            }
            let av = vdupq_n_s32(aik as i32);
            let brow = &b[kk * wpr..(kk + 1) * wpr];
            for (wi, &word) in brow.iter().enumerate() {
                if word == 0 {
                    continue;
                }
                let o0 = wi * 64;
                if o0 + 64 <= n {
                    // full word: 8 bytes × 8 lanes, broadcast-AND-accumulate
                    let cp = crow.as_mut_ptr();
                    for byte in 0..8 {
                        let bv = ((word >> (8 * byte)) & 0xFF) as i32;
                        if bv == 0 {
                            continue;
                        }
                        let bvv = vdupq_n_s32(bv);
                        let m_lo =
                            vreinterpretq_s32_u32(vceqq_s32(vandq_s32(bvv, bits_lo), bits_lo));
                        let m_hi =
                            vreinterpretq_s32_u32(vceqq_s32(vandq_s32(bvv, bits_hi), bits_hi));
                        let j = o0 + 8 * byte;
                        let c0 = vld1q_s32(cp.add(j) as *const i32);
                        let c1 = vld1q_s32(cp.add(j + 4) as *const i32);
                        vst1q_s32(cp.add(j), vaddq_s32(c0, vandq_s32(av, m_lo)));
                        vst1q_s32(cp.add(j + 4), vaddq_s32(c1, vandq_s32(av, m_hi)));
                    }
                } else {
                    // tail word (n not a multiple of 64): scalar bit walk
                    let mut w = word;
                    while w != 0 {
                        let o = o0 + w.trailing_zeros() as usize;
                        crow[o] += aik as i32;
                        w &= w - 1;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::scalar;
    use crate::util::rng::Rng;

    fn have_neon() -> bool {
        std::arch::is_aarch64_feature_detected!("neon")
    }

    #[test]
    fn u8_i16_bit_identical_to_scalar() {
        if !have_neon() {
            return;
        }
        let mut rng = Rng::new(0xA4);
        let shapes = [(1, 1, 1), (3, 5, 7), (2, 9, 8), (4, 13, 17), (5, 64, 33), (2, 7, 130)];
        for &(m, k, n) in &shapes {
            let a: Vec<u8> = (0..m * k).map(|_| rng.int_in(0, 15) as u8).collect();
            let w: Vec<i16> = (0..k * n).map(|_| rng.int_in(-7, 7) as i16).collect();
            let mut c1: Vec<i32> = (0..m * n).map(|_| rng.int_in(-9, 9) as i32).collect();
            let mut c2 = c1.clone();
            scalar::gemm_acc_u8_i16(m, k, n, &a, &w, &mut c1);
            super::gemm_acc_u8_i16(m, k, n, &a, &w, &mut c2);
            assert_eq!(c1, c2, "u8i16 ({m},{k},{n})");
        }
    }

    #[test]
    fn packed_bit_identical_to_scalar() {
        if !have_neon() {
            return;
        }
        let mut rng = Rng::new(0xB4);
        for &(m, k, n) in &[(1, 1, 1), (2, 3, 63), (3, 5, 64), (2, 9, 65), (4, 7, 200)] {
            let a: Vec<u8> = (0..m * k).map(|_| rng.int_in(0, 3) as u8).collect();
            let bin: Vec<u8> = (0..k * n).map(|_| rng.below(2) as u8).collect();
            let packed = crate::pim::layout::pack_bin_plane(&bin, k, n);
            let mut c1: Vec<i32> = (0..m * n).map(|_| rng.int_in(0, 5) as i32).collect();
            let mut c2 = c1.clone();
            scalar::gemm_acc_u8_bin_packed(m, k, n, &a, &packed, &mut c1);
            super::gemm_acc_u8_bin_packed(m, k, n, &a, &packed, &mut c2);
            assert_eq!(c1, c2, "packed ({m},{k},{n})");
        }
    }
}
