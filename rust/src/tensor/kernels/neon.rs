//! NEON arm (`std::arch::aarch64`), selected at runtime by
//! [`super::active`] on aarch64 hosts (NEON is baseline on every aarch64
//! target Rust ships, but the runtime check keeps the selection honest and
//! mirrors the AVX2 arm's discipline).
//!
//! The **integer plane kernels** (the L3.7 satellite) — u8×i16→i32 and
//! the bit-packed binary-plane kernel — carry the PIM engine's hot loops.
//! Both compute exact i32 sums, so they are **bit-identical to the scalar
//! arm** on every shape; k/n tails that are not multiples of the vector
//! width run the same scalar tail code.  Pinned by the odd-shape property
//! sweep in `tests/engine_parity.rs` (which compares the dispatched arm
//! against scalar — on aarch64 that *is* this arm).
//!
//! The **f32 family** (added in L3.9) uses 4-lane FMA with a fixed
//! (shape-only) tile order — the packed-panel blocked walk of
//! `kernels::blocked` for `gemm_acc` (autotuned per-process tile triple,
//! then fixed), 4-lane partial sums reduced by `vaddvq_f32` for
//! `gemm_nt_acc`, zero-skip axpy for `gemm_tn_acc` — so outputs are
//! deterministic run-to-run and differ from scalar only by summation
//! order (1e-3 absolute tolerance on unit-scale data).  Only the legacy
//! u8 binary plane still delegates to scalar.
//!
//! * `gemm_acc_u8_i16` — widening multiply-accumulate: the u8 activation
//!   (≤ 255, so it fits i16 exactly) broadcasts as the scalar operand of
//!   `vmlal_n_s16`/`vmlal_high_n_s16`, turning 8 weight lanes into 8 i32
//!   accumulations per step.  Products are ≤ 255·32767 < 2²³ — exact.
//! * `gemm_acc_u8_bin_packed` — each byte of a packed u64 word expands to
//!   two 4-lane 0/−1 masks (broadcast-AND-compare against per-lane bit
//!   constants) and the broadcast activation accumulates under the mask —
//!   the 128-bit analogue of the AVX2 broadcast-AND-accumulate loop.
//!
//! Every public fn asserts the slice geometry *and* the NEON feature
//! before entering the `#[target_feature]` inner body, so each table entry
//! is independently sound (same rationale as `kernels::avx2`).

#![allow(unsafe_code)]

use std::arch::aarch64::*;

use super::KernelTable;

/// The NEON kernel table.  Only select this after feature detection.
pub static TABLE: KernelTable = KernelTable {
    name: "neon",
    gemm_acc,
    gemm_acc_tile,
    gemm_nt_acc,
    gemm_tn_acc,
    gemm_acc_u8_i16,
    // the one-weight-per-u8 binary layout survives only as the
    // reference/compat surface; the engine runs the packed kernel below
    gemm_acc_u8_bin: super::scalar::gemm_acc_u8_bin,
    gemm_acc_u8_bin_packed,
};

/// Release-mode guard: these are safe `pub fn`s, so executing the NEON
/// bodies without the feature would be UB reachable from safe code.  The
/// detection macro caches its answer — one load per GEMM call.
#[inline]
fn check_features() {
    assert!(
        std::arch::is_aarch64_feature_detected!("neon"),
        "neon kernel table used without NEON"
    );
}

// -- f32 dense: C += A·B (packed-panel blocked) -----------------------------

/// Dense f32 GEMM routes through the packed-panel blocked driver
/// (`kernels::blocked`, §Perf L3.9): the driver packs MC×KC / KC×NC
/// panels into arena scratch and hands them to [`gemm_acc_tile`].
pub fn gemm_acc(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    check_features();
    super::blocked::gemm_acc_packed(m, k, n, a, b, c, gemm_acc_tile);
}

/// Packed-tile microkernel: `pa[mb,kb] · pb[kb,nb]` accumulated into the
/// C block at flat offset `c0`, row stride `ldc`.  4-lane FMA
/// (`vfmaq_n_f32`) over the contiguous packed B rows, 4-wide k register
/// blocking, scalar j tail — a fixed shape-only order.
pub fn gemm_acc_tile(
    mb: usize,
    kb: usize,
    nb: usize,
    pa: &[f32],
    pb: &[f32],
    c: &mut [f32],
    c0: usize,
    ldc: usize,
) {
    assert_eq!(pa.len(), mb * kb);
    assert_eq!(pb.len(), kb * nb);
    assert!(nb <= ldc);
    if mb == 0 || nb == 0 {
        return;
    }
    assert!(c0 + (mb - 1) * ldc + nb <= c.len());
    check_features();
    unsafe { gemm_acc_tile_impl(mb, kb, nb, pa, pb, c, c0, ldc) }
}

#[target_feature(enable = "neon")]
#[allow(clippy::too_many_arguments)]
unsafe fn gemm_acc_tile_impl(
    mb: usize,
    kb: usize,
    nb: usize,
    pa: &[f32],
    pb: &[f32],
    c: &mut [f32],
    c0: usize,
    ldc: usize,
) {
    for ii in 0..mb {
        let arow = &pa[ii * kb..(ii + 1) * kb];
        let cp = c.as_mut_ptr().add(c0 + ii * ldc);
        let mut kk = 0;
        while kk + 4 <= kb {
            let a0 = arow[kk];
            let a1 = arow[kk + 1];
            let a2 = arow[kk + 2];
            let a3 = arow[kk + 3];
            let b0 = pb.as_ptr().add(kk * nb);
            let b1 = pb.as_ptr().add((kk + 1) * nb);
            let b2 = pb.as_ptr().add((kk + 2) * nb);
            let b3 = pb.as_ptr().add((kk + 3) * nb);
            let mut j = 0;
            while j + 4 <= nb {
                let mut cv = vld1q_f32(cp.add(j));
                cv = vfmaq_n_f32(cv, vld1q_f32(b0.add(j)), a0);
                cv = vfmaq_n_f32(cv, vld1q_f32(b1.add(j)), a1);
                cv = vfmaq_n_f32(cv, vld1q_f32(b2.add(j)), a2);
                cv = vfmaq_n_f32(cv, vld1q_f32(b3.add(j)), a3);
                vst1q_f32(cp.add(j), cv);
                j += 4;
            }
            while j < nb {
                *cp.add(j) += a0 * *b0.add(j) + a1 * *b1.add(j) + a2 * *b2.add(j) + a3 * *b3.add(j);
                j += 1;
            }
            kk += 4;
        }
        while kk < kb {
            let av = arow[kk];
            let brow = pb.as_ptr().add(kk * nb);
            let mut j = 0;
            while j + 4 <= nb {
                let cv = vld1q_f32(cp.add(j));
                vst1q_f32(cp.add(j), vfmaq_n_f32(cv, vld1q_f32(brow.add(j)), av));
                j += 4;
            }
            while j < nb {
                *cp.add(j) += av * *brow.add(j);
                j += 1;
            }
            kk += 1;
        }
    }
}

// -- f32 A·Bᵀ: dot-product rows ---------------------------------------------

pub fn gemm_nt_acc(m: usize, p: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert_eq!(a.len(), m * p);
    assert_eq!(b.len(), n * p);
    assert_eq!(c.len(), m * n);
    check_features();
    unsafe { gemm_nt_acc_impl(m, p, n, a, b, c) }
}

#[target_feature(enable = "neon")]
unsafe fn gemm_nt_acc_impl(m: usize, p: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    for i in 0..m {
        let arow = a.as_ptr().add(i * p);
        let crow = &mut c[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = b.as_ptr().add(j * p);
            let mut acc = vdupq_n_f32(0.0);
            let mut q = 0;
            while q + 4 <= p {
                acc = vfmaq_f32(acc, vld1q_f32(arow.add(q)), vld1q_f32(brow.add(q)));
                q += 4;
            }
            // vaddvq_f32 reduces in a fixed lane order — deterministic
            let mut s = vaddvq_f32(acc);
            while q < p {
                s += *arow.add(q) * *brow.add(q);
                q += 1;
            }
            crow[j] += s;
        }
    }
}

// -- f32 Aᵀ·B: zero-skip axpy rows ------------------------------------------

pub fn gemm_tn_acc(p: usize, m: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert_eq!(a.len(), p * m);
    assert_eq!(b.len(), p * n);
    assert_eq!(c.len(), m * n);
    check_features();
    unsafe { gemm_tn_acc_impl(p, m, n, a, b, c) }
}

#[target_feature(enable = "neon")]
unsafe fn gemm_tn_acc_impl(p: usize, m: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    for q in 0..p {
        let arow = &a[q * m..(q + 1) * m];
        let brow = b.as_ptr().add(q * n);
        for (i, &aq) in arow.iter().enumerate() {
            if aq == 0.0 {
                continue;
            }
            let cp = c.as_mut_ptr().add(i * n);
            let mut j = 0;
            while j + 4 <= n {
                let cv = vld1q_f32(cp.add(j));
                vst1q_f32(cp.add(j), vfmaq_n_f32(cv, vld1q_f32(brow.add(j)), aq));
                j += 4;
            }
            while j < n {
                *cp.add(j) += aq * *brow.add(j);
                j += 1;
            }
        }
    }
}

// -- u8 × i16 → i32 plane kernel --------------------------------------------

pub fn gemm_acc_u8_i16(m: usize, k: usize, n: usize, a: &[u8], b: &[i16], c: &mut [i32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    check_features();
    unsafe { gemm_acc_u8_i16_impl(m, k, n, a, b, c) }
}

#[target_feature(enable = "neon")]
unsafe fn gemm_acc_u8_i16_impl(m: usize, k: usize, n: usize, a: &[u8], b: &[i16], c: &mut [i32]) {
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0 {
                // exact sums: the activation zero-skip is bit-neutral
                continue;
            }
            let a16 = aik as i16;
            let brow = b.as_ptr().add(kk * n);
            let cp = crow.as_mut_ptr();
            let mut j = 0;
            while j + 8 <= n {
                let w = vld1q_s16(brow.add(j));
                let c0 = vld1q_s32(cp.add(j) as *const i32);
                let c1 = vld1q_s32(cp.add(j + 4) as *const i32);
                let c0 = vmlal_n_s16(c0, vget_low_s16(w), a16);
                let c1 = vmlal_high_n_s16(c1, w, a16);
                vst1q_s32(cp.add(j), c0);
                vst1q_s32(cp.add(j + 4), c1);
                j += 8;
            }
            while j < n {
                crow[j] += aik as i32 * *brow.add(j) as i32;
                j += 1;
            }
        }
    }
}

// -- bit-packed binary plane kernel -----------------------------------------

pub fn gemm_acc_u8_bin_packed(m: usize, k: usize, n: usize, a: &[u8], b: &[u64], c: &mut [i32]) {
    let wpr = crate::pim::layout::packed_words(n);
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * wpr);
    assert_eq!(c.len(), m * n);
    check_features();
    unsafe { gemm_acc_u8_bin_packed_impl(m, k, n, wpr, a, b, c) }
}

#[target_feature(enable = "neon")]
unsafe fn gemm_acc_u8_bin_packed_impl(
    m: usize,
    k: usize,
    n: usize,
    wpr: usize,
    a: &[u8],
    b: &[u64],
    c: &mut [i32],
) {
    // per-lane bit constants: lane j of the low/high half tests bit j /
    // bit j+4 of the broadcast byte
    let lo_bits = [1i32, 2, 4, 8];
    let hi_bits = [16i32, 32, 64, 128];
    let bits_lo = vld1q_s32(lo_bits.as_ptr());
    let bits_hi = vld1q_s32(hi_bits.as_ptr());
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0 {
                continue;
            }
            let av = vdupq_n_s32(aik as i32);
            let brow = &b[kk * wpr..(kk + 1) * wpr];
            for (wi, &word) in brow.iter().enumerate() {
                if word == 0 {
                    continue;
                }
                let o0 = wi * 64;
                if o0 + 64 <= n {
                    // full word: 8 bytes × 8 lanes, broadcast-AND-accumulate
                    let cp = crow.as_mut_ptr();
                    for byte in 0..8 {
                        let bv = ((word >> (8 * byte)) & 0xFF) as i32;
                        if bv == 0 {
                            continue;
                        }
                        let bvv = vdupq_n_s32(bv);
                        let m_lo =
                            vreinterpretq_s32_u32(vceqq_s32(vandq_s32(bvv, bits_lo), bits_lo));
                        let m_hi =
                            vreinterpretq_s32_u32(vceqq_s32(vandq_s32(bvv, bits_hi), bits_hi));
                        let j = o0 + 8 * byte;
                        let c0 = vld1q_s32(cp.add(j) as *const i32);
                        let c1 = vld1q_s32(cp.add(j + 4) as *const i32);
                        vst1q_s32(cp.add(j), vaddq_s32(c0, vandq_s32(av, m_lo)));
                        vst1q_s32(cp.add(j + 4), vaddq_s32(c1, vandq_s32(av, m_hi)));
                    }
                } else {
                    // tail word (n not a multiple of 64): scalar bit walk
                    let mut w = word;
                    while w != 0 {
                        let o = o0 + w.trailing_zeros() as usize;
                        crow[o] += aik as i32;
                        w &= w - 1;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::scalar;
    use crate::util::rng::Rng;

    fn have_neon() -> bool {
        std::arch::is_aarch64_feature_detected!("neon")
    }

    #[test]
    fn u8_i16_bit_identical_to_scalar() {
        if !have_neon() {
            return;
        }
        let mut rng = Rng::new(0xA4);
        let shapes = [(1, 1, 1), (3, 5, 7), (2, 9, 8), (4, 13, 17), (5, 64, 33), (2, 7, 130)];
        for &(m, k, n) in &shapes {
            let a: Vec<u8> = (0..m * k).map(|_| rng.int_in(0, 15) as u8).collect();
            let w: Vec<i16> = (0..k * n).map(|_| rng.int_in(-7, 7) as i16).collect();
            let mut c1: Vec<i32> = (0..m * n).map(|_| rng.int_in(-9, 9) as i32).collect();
            let mut c2 = c1.clone();
            scalar::gemm_acc_u8_i16(m, k, n, &a, &w, &mut c1);
            super::gemm_acc_u8_i16(m, k, n, &a, &w, &mut c2);
            assert_eq!(c1, c2, "u8i16 ({m},{k},{n})");
        }
    }

    #[test]
    fn f32_kernels_close_to_scalar() {
        if !have_neon() {
            return;
        }
        let mut rng = Rng::new(0xC6);
        for &(m, k, n) in &[(1, 1, 1), (4, 9, 6), (3, 130, 17), (7, 33, 384), (2, 400, 10)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal_in(0.0, 1.0)).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal_in(0.0, 1.0)).collect();
            let mut c1 = vec![0.0f32; m * n];
            let mut c2 = vec![0.0f32; m * n];
            scalar::gemm_acc(m, k, n, &a, &b, &mut c1);
            super::gemm_acc(m, k, n, &a, &b, &mut c2);
            for (x, y) in c1.iter().zip(&c2) {
                assert!((x - y).abs() < 1e-3, "acc ({m},{k},{n}): {x} vs {y}");
            }
            // nt: b as [n, k]ᵀ operand
            let bt: Vec<f32> = (0..n * k).map(|_| rng.normal_in(0.0, 1.0)).collect();
            let mut c3 = vec![0.0f32; m * n];
            let mut c4 = vec![0.0f32; m * n];
            scalar::gemm_nt_acc(m, k, n, &a, &bt, &mut c3);
            super::gemm_nt_acc(m, k, n, &a, &bt, &mut c4);
            for (x, y) in c3.iter().zip(&c4) {
                assert!((x - y).abs() < 1e-3, "nt ({m},{k},{n}): {x} vs {y}");
            }
            // tn: a as [k, m] operand (zero-skip path)
            let a2: Vec<f32> = (0..k * m)
                .map(|_| if rng.below(3) == 0 { 0.0 } else { rng.normal_in(0.0, 1.0) })
                .collect();
            let b2: Vec<f32> = (0..k * n).map(|_| rng.normal_in(0.0, 1.0)).collect();
            let mut c5 = vec![0.0f32; m * n];
            let mut c6 = vec![0.0f32; m * n];
            scalar::gemm_tn_acc(k, m, n, &a2, &b2, &mut c5);
            super::gemm_tn_acc(k, m, n, &a2, &b2, &mut c6);
            for (x, y) in c5.iter().zip(&c6) {
                assert!((x - y).abs() < 1e-3, "tn ({k},{m},{n}): {x} vs {y}");
            }
        }
    }

    #[test]
    fn packed_bit_identical_to_scalar() {
        if !have_neon() {
            return;
        }
        let mut rng = Rng::new(0xB4);
        for &(m, k, n) in &[(1, 1, 1), (2, 3, 63), (3, 5, 64), (2, 9, 65), (4, 7, 200)] {
            let a: Vec<u8> = (0..m * k).map(|_| rng.int_in(0, 3) as u8).collect();
            let bin: Vec<u8> = (0..k * n).map(|_| rng.below(2) as u8).collect();
            let packed = crate::pim::layout::pack_bin_plane(&bin, k, n);
            let mut c1: Vec<i32> = (0..m * n).map(|_| rng.int_in(0, 5) as i32).collect();
            let mut c2 = c1.clone();
            scalar::gemm_acc_u8_bin_packed(m, k, n, &a, &packed, &mut c1);
            super::gemm_acc_u8_bin_packed(m, k, n, &a, &packed, &mut c2);
            assert_eq!(c1, c2, "packed ({m},{k},{n})");
        }
    }
}
