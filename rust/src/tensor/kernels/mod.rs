//! Runtime-dispatched GEMM kernel subsystem (§Perf L3.6, completed in
//! §Perf L3.9).
//!
//! Every plane GEMM and f32 GEMM in the crate goes through one function-
//! pointer table, resolved **once per process**:
//!
//! * [`scalar`] — the portable reference arm, always compiled.  Its integer
//!   kernels define the bit-exact contract; its f32 kernels are the
//!   pre-dispatch implementations unchanged (the scalar arm never routes
//!   through the blocked driver, so `PIM_QAT_NO_SIMD=1` outputs stay
//!   bit-identical across releases).
//! * `avx512` (`kernels/avx512.rs`) — `std::arch::x86_64` AVX-512 paths
//!   (16-lane zmm FMA, widening u8×i16→i32, native-`__mmask16` masked
//!   adds for the bit-packed binary plane), selected at runtime via
//!   `is_x86_feature_detected!("avx512f")`.  Compiled only on x86_64.
//! * [`avx2`] — `std::arch::x86_64` paths (AVX2 + FMA), the fallback when
//!   AVX-512 is absent.  Compiled only on x86_64; other targets fall back
//!   to [`scalar`] at compile time.
//! * `neon` (`kernels/neon.rs`) — `std::arch::aarch64` paths for the
//!   integer plane kernels (u8×i16→i32 and the bit-packed binary plane)
//!   *and* the f32 family (4-lane FMA), selected at runtime via
//!   `is_aarch64_feature_detected!`.  Compiled only on aarch64.
//!
//! The SIMD arms' dense f32 `gemm_acc` routes through the packed-panel
//! **blocked driver** ([`blocked`]) with an arm-specific tile microkernel
//! (`gemm_acc_tile`); the (MC, KC, NC) tile triple is resolved once per
//! process by the deterministic startup autotuner ([`autotune`]) —
//! `PIM_QAT_TILE=MCxKCxNC` pins it, `PIM_QAT_NO_AUTOTUNE=1` forces the
//! fixed default.
//!
//! Selection order: `PIM_QAT_NO_SIMD=1` forces the scalar arm (the CI leg
//! that keeps the fallback exercised); otherwise the best SIMD arm the
//! CPU has (AVX-512F, else AVX2+FMA, on x86_64; NEON on aarch64);
//! otherwise scalar.  Selecting a SIMD arm also warms the autotuner so
//! the probe cost lands at startup, not inside the first training step.
//!
//! ## Exactness contract (DESIGN.md §Kernel dispatch)
//!
//! * **Integer kernels** (`gemm_acc_u8_i16`, `gemm_acc_u8_bin`,
//!   `gemm_acc_u8_bin_packed`) compute exact i32 sums, so every arm must be
//!   **bit-identical** to scalar on every shape — including k/n tails that
//!   are not multiples of the vector width.  Pinned by the property tests
//!   in `tests/engine_parity.rs`.
//! * **f32 kernels** (`gemm_acc`, `gemm_nt_acc`, `gemm_tn_acc`) may differ
//!   from scalar by summation order (FMA, 8-lane partial sums), but each
//!   arm uses a **fixed tile order** that depends only on the shape — never
//!   on data or thread count — so results are deterministic run-to-run at
//!   any parallelism.  Tested against scalar at 1e-3 absolute tolerance on
//!   unit-scale data.
//!
//! All table entries **accumulate** into `c` (callers zero `c` when they
//! want a plain product), and every arm asserts the slice geometry itself,
//! so each entry is independently sound.

pub mod autotune;
pub mod blocked;
pub mod scalar;

#[cfg(target_arch = "x86_64")]
pub mod avx2;

#[cfg(target_arch = "x86_64")]
pub mod avx512;

#[cfg(target_arch = "aarch64")]
pub mod neon;

use std::sync::OnceLock;

/// The dispatched kernel set.  One static instance per arm; `active()`
/// returns the arm selected for this process.
pub struct KernelTable {
    /// Arm name ("scalar", "avx2", "avx512", "neon") — surfaced by benches
    /// and tests.
    pub name: &'static str,
    /// C[m,n] += A[m,k] · B[k,n], dense f32 (row-major).  SIMD arms route
    /// this through the packed-panel blocked driver.
    pub gemm_acc: fn(usize, usize, usize, &[f32], &[f32], &mut [f32]),
    /// Packed-tile microkernel consumed by `blocked::gemm_acc_packed` (and
    /// by the autotune probe, which times it under each tile candidate).
    pub gemm_acc_tile: blocked::TileKernel,
    /// C[m,n] += A[m,p] · B[n,p]ᵀ, f32 (dot-product form).
    pub gemm_nt_acc: fn(usize, usize, usize, &[f32], &[f32], &mut [f32]),
    /// C[m,n] += A[p,m]ᵀ · B[p,n], f32 (zero-skip on A).
    pub gemm_tn_acc: fn(usize, usize, usize, &[f32], &[f32], &mut [f32]),
    /// C[m,n] += A[m,k] · B[k,n], u8 activations × i16 weights → i32.
    pub gemm_acc_u8_i16: fn(usize, usize, usize, &[u8], &[i16], &mut [i32]),
    /// C[m,n] += A[m,k] · B[k,n], u8 activations × {0,1} u8 weights → i32.
    pub gemm_acc_u8_bin: fn(usize, usize, usize, &[u8], &[u8], &mut [i32]),
    /// C[m,n] += A[m,k] · B[k,n] with B a bit-packed binary plane:
    /// `(n+63)/64` u64 words per row, bit `o%64` of word `o/64` ↔ column
    /// `o` (see `pim::layout::packed_words`).
    pub gemm_acc_u8_bin_packed: fn(usize, usize, usize, &[u8], &[u64], &mut [i32]),
}

static ACTIVE: OnceLock<&'static KernelTable> = OnceLock::new();

/// The kernel table selected for this process (resolved on first call).
pub fn active() -> &'static KernelTable {
    ACTIVE.get_or_init(select)
}

/// `PIM_QAT_NO_SIMD=1` (any non-empty value other than "0") forces the
/// scalar arm.
fn no_simd_forced() -> bool {
    std::env::var_os("PIM_QAT_NO_SIMD").is_some_and(|v| !v.is_empty() && v != "0")
}

fn select() -> &'static KernelTable {
    if no_simd_forced() {
        // scalar never consults the tile triple, so the NO_SIMD leg also
        // skips the autotune probe entirely
        return &scalar::TABLE;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx512f") {
            autotune::warm(&avx512::TABLE);
            return &avx512::TABLE;
        }
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            autotune::warm(&avx2::TABLE);
            return &avx2::TABLE;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            autotune::warm(&neon::TABLE);
            return &neon::TABLE;
        }
    }
    &scalar::TABLE
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn active_is_resolved_once_and_named() {
        let t1 = active();
        let t2 = active();
        assert!(std::ptr::eq(t1, t2), "OnceLock must hand out one table");
        let known = ["scalar", "avx2", "avx512", "neon"];
        assert!(known.contains(&t1.name), "unknown arm {:?}", t1.name);
    }

    #[test]
    fn scalar_table_is_always_available() {
        // the reference arm must exist on every target
        let a = vec![1u8, 2, 3, 4];
        let b = vec![1i16, 0, 0, 1];
        let mut c = vec![0i32; 4];
        (scalar::TABLE.gemm_acc_u8_i16)(2, 2, 2, &a, &b, &mut c);
        assert_eq!(c, vec![1, 2, 3, 4]);
    }
}
