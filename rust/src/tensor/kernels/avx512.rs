//! AVX-512 arm (`std::arch::x86_64`), selected at runtime by
//! [`super::active`] when `is_x86_feature_detected!("avx512f")` passes —
//! ahead of the AVX2 arm (§Perf L3.9).
//!
//! * Integer kernels are exact i32 arithmetic, so they are **bit-identical
//!   to the scalar arm** on every shape; k/n tails that are not multiples
//!   of the 16-lane width run the same scalar tail code.
//! * f32 kernels use 512-bit FMA with a fixed (shape-only) tile order —
//!   the packed-panel blocked walk of `kernels::blocked` for
//!   [`gemm_acc`] (autotuned per-process tile triple, then fixed),
//!   16-lane partial sums reduced in a fixed quarter order for
//!   [`gemm_nt_acc`] — so outputs are deterministic run-to-run, and
//!   differ from scalar only by summation order (1e-3 absolute tolerance
//!   on unit-scale data).
//! * The bit-packed binary kernel is where AVX-512 pulls ahead cleanly:
//!   each 16-bit chunk of a packed u64 word **is** a native `__mmask16`,
//!   so the plane accumulate is one masked add per 16 outputs —
//!   `_mm512_mask_add_epi32` under the bit chunk — with no byte-expand
//!   or compare step at all (the AVX2 arm needs both).
//!
//! Every public fn here asserts the slice geometry *and* the CPU feature
//! before entering the `#[target_feature]` inner body, so each table entry
//! is sound in isolation — the feature assert runs in release too (these
//! are safe `pub fn`s; without it, a direct call on a non-AVX-512 CPU
//! would be UB reachable from safe code).  The in-bounds pointer
//! arithmetic is established by the geometry asserts.  512-bit FMA and
//! the masked integer ops are all part of the base AVX512F set — no
//! additional feature bits are required.

#![allow(unsafe_code)]

use std::arch::x86_64::*;

use super::KernelTable;

/// The AVX-512 kernel table.  Only select this after feature detection.
pub static TABLE: KernelTable = KernelTable {
    name: "avx512",
    gemm_acc,
    gemm_acc_tile,
    gemm_nt_acc,
    gemm_tn_acc,
    gemm_acc_u8_i16,
    // the u8 binary-plane kernel stays scalar: the engine's bit-serial path
    // uses the packed kernel below, and the u8 layout survives only as the
    // reference/compat surface
    gemm_acc_u8_bin: super::scalar::gemm_acc_u8_bin,
    gemm_acc_u8_bin_packed,
};

/// Release-mode guard: these are safe `pub fn`s, so executing the AVX-512
/// bodies on a CPU without the feature would be UB reachable from safe
/// code.  `is_x86_feature_detected!` caches its answer, so this is one
/// atomic load per GEMM call — noise next to the kernel itself.
#[inline]
fn check_features() {
    assert!(
        is_x86_feature_detected!("avx512f"),
        "avx512 kernel table used without AVX-512F"
    );
}

// -- f32 dense: C += A·B (packed-panel blocked) -----------------------------

/// Dense f32 GEMM routes through the packed-panel blocked driver
/// (`kernels::blocked`, §Perf L3.9): the driver packs MC×KC / KC×NC
/// panels into arena scratch and hands them to [`gemm_acc_tile`].
pub fn gemm_acc(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    check_features();
    super::blocked::gemm_acc_packed(m, k, n, a, b, c, gemm_acc_tile);
}

/// Packed-tile microkernel: `pa[mb,kb] · pb[kb,nb]` accumulated into the
/// C block at flat offset `c0`, row stride `ldc`.  16-lane zmm FMA over
/// the contiguous packed B rows, 4-wide k register blocking, scalar j
/// tail — a fixed shape-only order.
pub fn gemm_acc_tile(
    mb: usize,
    kb: usize,
    nb: usize,
    pa: &[f32],
    pb: &[f32],
    c: &mut [f32],
    c0: usize,
    ldc: usize,
) {
    assert_eq!(pa.len(), mb * kb);
    assert_eq!(pb.len(), kb * nb);
    assert!(nb <= ldc);
    if mb == 0 || nb == 0 {
        return;
    }
    assert!(c0 + (mb - 1) * ldc + nb <= c.len());
    check_features();
    unsafe { gemm_acc_tile_impl(mb, kb, nb, pa, pb, c, c0, ldc) }
}

#[target_feature(enable = "avx512f")]
#[allow(clippy::too_many_arguments)]
unsafe fn gemm_acc_tile_impl(
    mb: usize,
    kb: usize,
    nb: usize,
    pa: &[f32],
    pb: &[f32],
    c: &mut [f32],
    c0: usize,
    ldc: usize,
) {
    for ii in 0..mb {
        let arow = &pa[ii * kb..(ii + 1) * kb];
        let cp = c.as_mut_ptr().add(c0 + ii * ldc);
        let mut kk = 0;
        while kk + 4 <= kb {
            let a0 = _mm512_set1_ps(arow[kk]);
            let a1 = _mm512_set1_ps(arow[kk + 1]);
            let a2 = _mm512_set1_ps(arow[kk + 2]);
            let a3 = _mm512_set1_ps(arow[kk + 3]);
            let b0 = pb.as_ptr().add(kk * nb);
            let b1 = pb.as_ptr().add((kk + 1) * nb);
            let b2 = pb.as_ptr().add((kk + 2) * nb);
            let b3 = pb.as_ptr().add((kk + 3) * nb);
            let mut j = 0;
            while j + 16 <= nb {
                let mut cv = _mm512_loadu_ps(cp.add(j));
                cv = _mm512_fmadd_ps(a0, _mm512_loadu_ps(b0.add(j)), cv);
                cv = _mm512_fmadd_ps(a1, _mm512_loadu_ps(b1.add(j)), cv);
                cv = _mm512_fmadd_ps(a2, _mm512_loadu_ps(b2.add(j)), cv);
                cv = _mm512_fmadd_ps(a3, _mm512_loadu_ps(b3.add(j)), cv);
                _mm512_storeu_ps(cp.add(j), cv);
                j += 16;
            }
            while j < nb {
                *cp.add(j) += arow[kk] * *b0.add(j)
                    + arow[kk + 1] * *b1.add(j)
                    + arow[kk + 2] * *b2.add(j)
                    + arow[kk + 3] * *b3.add(j);
                j += 1;
            }
            kk += 4;
        }
        while kk < kb {
            let av = _mm512_set1_ps(arow[kk]);
            let brow = pb.as_ptr().add(kk * nb);
            let mut j = 0;
            while j + 16 <= nb {
                let cv = _mm512_loadu_ps(cp.add(j));
                _mm512_storeu_ps(cp.add(j), _mm512_fmadd_ps(av, _mm512_loadu_ps(brow.add(j)), cv));
                j += 16;
            }
            while j < nb {
                *cp.add(j) += arow[kk] * *brow.add(j);
                j += 1;
            }
            kk += 1;
        }
    }
}

// -- f32 A·Bᵀ: dot-product rows ---------------------------------------------

pub fn gemm_nt_acc(m: usize, p: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert_eq!(a.len(), m * p);
    assert_eq!(b.len(), n * p);
    assert_eq!(c.len(), m * n);
    check_features();
    unsafe { gemm_nt_acc_impl(m, p, n, a, b, c) }
}

/// Fixed-order horizontal sum: quarters (0+1) + (2+3), then the same
/// 128-bit pairwise reduction the AVX2 arm uses.
#[target_feature(enable = "avx512f")]
unsafe fn hsum512(v: __m512) -> f32 {
    let q0 = _mm512_extractf32x4_ps(v, 0);
    let q1 = _mm512_extractf32x4_ps(v, 1);
    let q2 = _mm512_extractf32x4_ps(v, 2);
    let q3 = _mm512_extractf32x4_ps(v, 3);
    let s = _mm_add_ps(_mm_add_ps(q0, q1), _mm_add_ps(q2, q3));
    let shuf = _mm_movehdup_ps(s); // [1,1,3,3]
    let sums = _mm_add_ps(s, shuf); // [0+1, _, 2+3, _]
    let shuf2 = _mm_movehl_ps(shuf, sums); // [2+3, _, ...]
    _mm_cvtss_f32(_mm_add_ss(sums, shuf2))
}

#[target_feature(enable = "avx512f")]
unsafe fn gemm_nt_acc_impl(m: usize, p: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    for i in 0..m {
        let arow = a.as_ptr().add(i * p);
        let crow = &mut c[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = b.as_ptr().add(j * p);
            let mut acc = _mm512_setzero_ps();
            let mut q = 0;
            while q + 16 <= p {
                acc = _mm512_fmadd_ps(
                    _mm512_loadu_ps(arow.add(q)),
                    _mm512_loadu_ps(brow.add(q)),
                    acc,
                );
                q += 16;
            }
            let mut s = hsum512(acc);
            while q < p {
                s += *arow.add(q) * *brow.add(q);
                q += 1;
            }
            crow[j] += s;
        }
    }
}

// -- f32 Aᵀ·B: zero-skip axpy rows ------------------------------------------

pub fn gemm_tn_acc(p: usize, m: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert_eq!(a.len(), p * m);
    assert_eq!(b.len(), p * n);
    assert_eq!(c.len(), m * n);
    check_features();
    unsafe { gemm_tn_acc_impl(p, m, n, a, b, c) }
}

#[target_feature(enable = "avx512f")]
unsafe fn gemm_tn_acc_impl(p: usize, m: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    for q in 0..p {
        let arow = &a[q * m..(q + 1) * m];
        let brow = b.as_ptr().add(q * n);
        for (i, &aq) in arow.iter().enumerate() {
            if aq == 0.0 {
                continue;
            }
            let av = _mm512_set1_ps(aq);
            let cp = c.as_mut_ptr().add(i * n);
            let mut j = 0;
            while j + 16 <= n {
                let cv = _mm512_loadu_ps(cp.add(j));
                _mm512_storeu_ps(cp.add(j), _mm512_fmadd_ps(av, _mm512_loadu_ps(brow.add(j)), cv));
                j += 16;
            }
            while j < n {
                *cp.add(j) += aq * *brow.add(j);
                j += 1;
            }
        }
    }
}

// -- u8 × i16 → i32 plane kernel --------------------------------------------

pub fn gemm_acc_u8_i16(m: usize, k: usize, n: usize, a: &[u8], b: &[i16], c: &mut [i32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    check_features();
    unsafe { gemm_acc_u8_i16_impl(m, k, n, a, b, c) }
}

#[target_feature(enable = "avx512f")]
unsafe fn gemm_acc_u8_i16_impl(m: usize, k: usize, n: usize, a: &[u8], b: &[i16], c: &mut [i32]) {
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        let mut kk = 0;
        // 4 weight rows share one pass over the C row (same blocking as
        // scalar; sums are exact, so the order is irrelevant to the bits)
        while kk + 4 <= k {
            let cp = crow.as_mut_ptr();
            let a0 = _mm512_set1_epi32(arow[kk] as i32);
            let a1 = _mm512_set1_epi32(arow[kk + 1] as i32);
            let a2 = _mm512_set1_epi32(arow[kk + 2] as i32);
            let a3 = _mm512_set1_epi32(arow[kk + 3] as i32);
            let b0 = b.as_ptr().add(kk * n);
            let b1 = b.as_ptr().add((kk + 1) * n);
            let b2 = b.as_ptr().add((kk + 2) * n);
            let b3 = b.as_ptr().add((kk + 3) * n);
            let mut j = 0;
            while j + 16 <= n {
                let w0 = _mm512_cvtepi16_epi32(_mm256_loadu_si256(b0.add(j) as *const __m256i));
                let w1 = _mm512_cvtepi16_epi32(_mm256_loadu_si256(b1.add(j) as *const __m256i));
                let w2 = _mm512_cvtepi16_epi32(_mm256_loadu_si256(b2.add(j) as *const __m256i));
                let w3 = _mm512_cvtepi16_epi32(_mm256_loadu_si256(b3.add(j) as *const __m256i));
                let mut cv = _mm512_loadu_epi32(cp.add(j));
                cv = _mm512_add_epi32(cv, _mm512_mullo_epi32(a0, w0));
                cv = _mm512_add_epi32(cv, _mm512_mullo_epi32(a1, w1));
                cv = _mm512_add_epi32(cv, _mm512_mullo_epi32(a2, w2));
                cv = _mm512_add_epi32(cv, _mm512_mullo_epi32(a3, w3));
                _mm512_storeu_epi32(cp.add(j), cv);
                j += 16;
            }
            while j < n {
                crow[j] += arow[kk] as i32 * *b0.add(j) as i32
                    + arow[kk + 1] as i32 * *b1.add(j) as i32
                    + arow[kk + 2] as i32 * *b2.add(j) as i32
                    + arow[kk + 3] as i32 * *b3.add(j) as i32;
                j += 1;
            }
            kk += 4;
        }
        while kk < k {
            let cp = crow.as_mut_ptr();
            let av = _mm512_set1_epi32(arow[kk] as i32);
            let brow = b.as_ptr().add(kk * n);
            let mut j = 0;
            while j + 16 <= n {
                let w = _mm512_cvtepi16_epi32(_mm256_loadu_si256(brow.add(j) as *const __m256i));
                let cv = _mm512_loadu_epi32(cp.add(j));
                _mm512_storeu_epi32(cp.add(j), _mm512_add_epi32(cv, _mm512_mullo_epi32(av, w)));
                j += 16;
            }
            while j < n {
                crow[j] += arow[kk] as i32 * *brow.add(j) as i32;
                j += 1;
            }
            kk += 1;
        }
    }
}

// -- bit-packed binary plane kernel -----------------------------------------

pub fn gemm_acc_u8_bin_packed(m: usize, k: usize, n: usize, a: &[u8], b: &[u64], c: &mut [i32]) {
    let wpr = crate::pim::layout::packed_words(n);
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * wpr);
    assert_eq!(c.len(), m * n);
    check_features();
    unsafe { gemm_acc_u8_bin_packed_impl(m, k, n, wpr, a, b, c) }
}

#[target_feature(enable = "avx512f")]
unsafe fn gemm_acc_u8_bin_packed_impl(
    m: usize,
    k: usize,
    n: usize,
    wpr: usize,
    a: &[u8],
    b: &[u64],
    c: &mut [i32],
) {
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0 {
                continue;
            }
            let av = _mm512_set1_epi32(aik as i32);
            let brow = &b[kk * wpr..(kk + 1) * wpr];
            for (wi, &word) in brow.iter().enumerate() {
                if word == 0 {
                    continue;
                }
                let o0 = wi * 64;
                if o0 + 64 <= n {
                    // full word: each 16-bit chunk is a native __mmask16 —
                    // one masked add per 16 outputs, no expand/compare
                    let cp = crow.as_mut_ptr();
                    for chunk in 0..4 {
                        let mask = ((word >> (16 * chunk)) & 0xFFFF) as __mmask16;
                        if mask == 0 {
                            continue;
                        }
                        let j = o0 + 16 * chunk;
                        let cv = _mm512_loadu_epi32(cp.add(j));
                        _mm512_storeu_epi32(cp.add(j), _mm512_mask_add_epi32(cv, mask, cv, av));
                    }
                } else {
                    // tail word (n not a multiple of 64): scalar bit walk
                    let mut w = word;
                    while w != 0 {
                        let o = o0 + w.trailing_zeros() as usize;
                        crow[o] += aik as i32;
                        w &= w - 1;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::scalar;
    use crate::util::rng::Rng;

    fn have_avx512() -> bool {
        is_x86_feature_detected!("avx512f")
    }

    #[test]
    fn integer_kernels_bit_identical_to_scalar() {
        if !have_avx512() {
            return; // nothing to check on this host; covered where avx512 exists
        }
        let mut rng = Rng::new(0xA5);
        let shapes = [(1, 1, 1), (3, 5, 7), (2, 9, 16), (4, 13, 17), (5, 64, 33), (2, 7, 130)];
        for &(m, k, n) in &shapes {
            let a: Vec<u8> = (0..m * k).map(|_| rng.int_in(0, 15) as u8).collect();
            let w: Vec<i16> = (0..k * n).map(|_| rng.int_in(-7, 7) as i16).collect();
            let mut c1: Vec<i32> = (0..m * n).map(|_| rng.int_in(-9, 9) as i32).collect();
            let mut c2 = c1.clone();
            scalar::gemm_acc_u8_i16(m, k, n, &a, &w, &mut c1);
            super::gemm_acc_u8_i16(m, k, n, &a, &w, &mut c2);
            assert_eq!(c1, c2, "u8i16 ({m},{k},{n})");
        }
    }

    #[test]
    fn packed_kernel_bit_identical_to_scalar() {
        if !have_avx512() {
            return;
        }
        let mut rng = Rng::new(0xB5);
        for &(m, k, n) in &[(1, 1, 1), (2, 3, 63), (3, 5, 64), (2, 9, 65), (4, 7, 200)] {
            let a: Vec<u8> = (0..m * k).map(|_| rng.int_in(0, 3) as u8).collect();
            let bin: Vec<u8> = (0..k * n).map(|_| rng.below(2) as u8).collect();
            let packed = crate::pim::layout::pack_bin_plane(&bin, k, n);
            let mut c1: Vec<i32> = (0..m * n).map(|_| rng.int_in(0, 5) as i32).collect();
            let mut c2 = c1.clone();
            scalar::gemm_acc_u8_bin_packed(m, k, n, &a, &packed, &mut c1);
            super::gemm_acc_u8_bin_packed(m, k, n, &a, &packed, &mut c2);
            assert_eq!(c1, c2, "packed ({m},{k},{n})");
        }
    }

    #[test]
    fn f32_kernels_close_to_scalar() {
        if !have_avx512() {
            return;
        }
        let mut rng = Rng::new(0xC5);
        for &(m, k, n) in &[(1, 1, 1), (4, 9, 6), (3, 130, 17), (7, 33, 384), (2, 400, 10)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal_in(0.0, 1.0)).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal_in(0.0, 1.0)).collect();
            let mut c1 = vec![0.0f32; m * n];
            let mut c2 = vec![0.0f32; m * n];
            scalar::gemm_acc(m, k, n, &a, &b, &mut c1);
            super::gemm_acc(m, k, n, &a, &b, &mut c2);
            for (x, y) in c1.iter().zip(&c2) {
                assert!((x - y).abs() < 1e-3, "acc ({m},{k},{n}): {x} vs {y}");
            }
            // nt: b as [n, k]ᵀ operand
            let bt: Vec<f32> = (0..n * k).map(|_| rng.normal_in(0.0, 1.0)).collect();
            let mut c3 = vec![0.0f32; m * n];
            let mut c4 = vec![0.0f32; m * n];
            scalar::gemm_nt_acc(m, k, n, &a, &bt, &mut c3);
            super::gemm_nt_acc(m, k, n, &a, &bt, &mut c4);
            for (x, y) in c3.iter().zip(&c4) {
                assert!((x - y).abs() < 1e-3, "nt ({m},{k},{n}): {x} vs {y}");
            }
            // tn: a as [k, m] operand (zero-skip path)
            let a2: Vec<f32> = (0..k * m)
                .map(|_| if rng.below(3) == 0 { 0.0 } else { rng.normal_in(0.0, 1.0) })
                .collect();
            let b2: Vec<f32> = (0..k * n).map(|_| rng.normal_in(0.0, 1.0)).collect();
            let mut c5 = vec![0.0f32; m * n];
            let mut c6 = vec![0.0f32; m * n];
            scalar::gemm_tn_acc(k, m, n, &a2, &b2, &mut c5);
            super::gemm_tn_acc(k, m, n, &a2, &b2, &mut c6);
            for (x, y) in c5.iter().zip(&c6) {
                assert!((x - y).abs() < 1e-3, "tn ({k},{m},{n}): {x} vs {y}");
            }
        }
    }
}
