//! Packed-panel blocked f32 GEMM driver (§Perf L3.9).
//!
//! The SIMD arms' dense `gemm_acc` no longer streams B straight from the
//! caller's row-major buffer: the driver here walks C in (NC, KC, MC)
//! blocks — the classic jc→pc→ic loop nest — and **packs** the current
//! KC×NC panel of B and MC×KC block of A into contiguous scratch buffers
//! before handing them to an arm-specific [`TileKernel`].  Packing happens
//! once per tile: the B panel is reused across every MC block of the
//! column stripe, and the packed operands stream linearly through the
//! microkernel regardless of the caller's leading dimensions, so large-k /
//! large-n shapes (the backward passes) stop thrashing the TLB and L2.
//!
//! Panel scratch comes from a **thread-local [`BufPool`] arena** — the
//! same grown-once discipline as the training-step arena (DESIGN.md
//! §Arena ownership), so steady-state panel packing performs zero large
//! allocations (the counting-allocator test in `train::native` pins the
//! whole armed window, packed panels included).  Worker-pool threads get
//! their own pool each; workers are never torn down, so the grow-once
//! phase happens once per thread, not once per call.
//!
//! Tile sizes come from [`super::autotune`]: resolved once per process
//! (deterministic probe, `PIM_QAT_TILE` override, `PIM_QAT_NO_AUTOTUNE`
//! fixed default) and then fixed, so the block walk depends only on the
//! shape and the per-process tile triple — the f32 determinism contract
//! (fixed shape-only tile order, bit-identical run-to-run within a
//! process) survives unchanged.

use std::cell::RefCell;

use super::autotune::{self, Tile};
use crate::tensor::arena::BufPool;

/// Arm-specific packed-tile microkernel: accumulate the product of a
/// packed `mb×kb` A block (`pa`, row-major contiguous) and a packed
/// `kb×nb` B panel (`pb`, row-major contiguous) into the C block starting
/// at flat offset `c0` with row stride `ldc` (`c[c0 + ii*ldc + jj] +=`).
/// Every implementation must assert the slice geometry itself and use a
/// fixed, shape-only accumulation order.
pub type TileKernel = fn(
    mb: usize,
    kb: usize,
    nb: usize,
    pa: &[f32],
    pb: &[f32],
    c: &mut [f32],
    c0: usize,
    ldc: usize,
);

thread_local! {
    /// Per-thread panel arena (grown once per thread, reused forever).
    static PANELS: RefCell<BufPool> = RefCell::new(BufPool::new());
}

/// C[m,n] += A[m,k] · B[k,n] through the packed-panel blocked walk, with
/// the tile triple resolved by the process-wide autotuner.
pub fn gemm_acc_packed(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    kernel: TileKernel,
) {
    let t = autotune::tile_for(kernel);
    gemm_acc_packed_with(t, m, k, n, a, b, c, kernel);
}

/// [`gemm_acc_packed`] with an explicit tile triple — the autotune probe
/// and the per-candidate parity tests call this directly, so tile choice
/// and the block walk stay independently testable.
pub fn gemm_acc_packed_with(
    t: Tile,
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    kernel: TileKernel,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    // Single-block fast path: the whole problem already is one contiguous
    // packed tile (A is mb×kb row-major, B is kb×nb row-major with
    // ldc = n = nb), so packing would be a pure copy.  Shape-only branch —
    // determinism is unaffected.
    if m <= t.mc && k <= t.kc && n <= t.nc {
        kernel(m, k, n, a, b, c, 0, n);
        return;
    }
    let (mut pa, mut pb) = PANELS.with(|p| {
        let mut pool = p.borrow_mut();
        (pool.take_f32(t.mc * t.kc), pool.take_f32(t.kc * t.nc))
    });
    for j0 in (0..n).step_by(t.nc) {
        let nb = (n - j0).min(t.nc);
        for k0 in (0..k).step_by(t.kc) {
            let kb = (k - k0).min(t.kc);
            // pack the KC×NC panel of B once per (j0, k0) stripe
            pb.clear();
            for kk in 0..kb {
                let row = (k0 + kk) * n + j0;
                pb.extend_from_slice(&b[row..row + nb]);
            }
            for i0 in (0..m).step_by(t.mc) {
                let mb = (m - i0).min(t.mc);
                pa.clear();
                for ii in 0..mb {
                    let row = (i0 + ii) * k + k0;
                    pa.extend_from_slice(&a[row..row + kb]);
                }
                kernel(mb, kb, nb, &pa, &pb, c, i0 * n + j0, n);
            }
        }
    }
    PANELS.with(|p| {
        let mut pool = p.borrow_mut();
        pool.put_f32(pa);
        pool.put_f32(pb);
    });
}

#[cfg(test)]
mod tests {
    use super::super::scalar;
    use super::*;
    use crate::util::rng::Rng;

    fn gemm_naive(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
        for i in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + kk] * b[kk * n + j];
                }
            }
        }
    }

    #[test]
    fn blocked_matches_naive_for_assorted_tiles_and_shapes() {
        let mut rng = Rng::new(0xB10C);
        let tiles = [
            Tile { mc: 2, kc: 3, nc: 5 }, // stress every block tail
            Tile { mc: 8, kc: 8, nc: 8 },
            Tile { mc: 64, kc: 64, nc: 256 },
        ];
        for t in tiles {
            for &(m, k, n) in &[(1, 1, 1), (3, 7, 5), (5, 9, 17), (7, 130, 33), (16, 65, 64)] {
                // integer-valued data keeps f32 sums exact, so any
                // accumulation order must agree bitwise with naive
                let a: Vec<f32> = (0..m * k).map(|_| rng.int_in(-7, 7) as f32).collect();
                let b: Vec<f32> = (0..k * n).map(|_| rng.int_in(-7, 7) as f32).collect();
                let c0: Vec<f32> = (0..m * n).map(|_| rng.int_in(-3, 3) as f32).collect();
                let mut cn = c0.clone();
                let mut cb = c0.clone();
                gemm_naive(m, k, n, &a, &b, &mut cn);
                gemm_acc_packed_with(t, m, k, n, &a, &b, &mut cb, scalar::gemm_acc_tile);
                assert_eq!(cn, cb, "tile {t:?} shape ({m},{k},{n})");
            }
        }
    }

    #[test]
    fn blocked_is_bitwise_stable_under_a_pinned_tile() {
        let mut rng = Rng::new(0x51AB);
        let t = Tile { mc: 4, kc: 6, nc: 10 };
        let (m, k, n) = (9, 31, 23);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal_in(0.0, 1.0)).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal_in(0.0, 1.0)).collect();
        let run = || {
            let mut c = vec![0.0f32; m * n];
            gemm_acc_packed_with(t, m, k, n, &a, &b, &mut c, scalar::gemm_acc_tile);
            c
        };
        assert_eq!(run(), run(), "pinned tile must give bit-identical reruns");
    }

    #[test]
    fn single_block_fast_path_matches_blocked_walk() {
        let mut rng = Rng::new(0xFA57);
        let (m, k, n) = (4, 7, 9);
        let a: Vec<f32> = (0..m * k).map(|_| rng.int_in(-5, 5) as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.int_in(-5, 5) as f32).collect();
        let big = Tile { mc: 64, kc: 64, nc: 64 }; // covers the whole problem
        let small = Tile { mc: 2, kc: 2, nc: 4 };
        let mut c1 = vec![0.0f32; m * n];
        let mut c2 = vec![0.0f32; m * n];
        gemm_acc_packed_with(big, m, k, n, &a, &b, &mut c1, scalar::gemm_acc_tile);
        gemm_acc_packed_with(small, m, k, n, &a, &b, &mut c2, scalar::gemm_acc_tile);
        assert_eq!(c1, c2, "integer data: fast path and blocked walk must agree exactly");
    }
}
