//! GEMM facade over the runtime-dispatched kernel subsystem
//! (`tensor::kernels`, §Perf L3.6 / L3.9).
//!
//! All single-call GEMM entry points live here (threading happens above,
//! across batch rows, in `crate::pim::engine`); the actual inner loops are
//! the arm picked once per process by [`crate::tensor::kernels::active`] —
//! AVX-512F, else AVX2+FMA, on capable x86_64 hosts, NEON on aarch64, the
//! portable scalar reference otherwise or under `PIM_QAT_NO_SIMD=1`.  The
//! SIMD arms' dense f32 path runs the packed-panel blocked driver
//! (`kernels::blocked`) with a per-process autotuned tile triple
//! (`kernels::autotune`; `PIM_QAT_TILE` / `PIM_QAT_NO_AUTOTUNE` pin it).
//!
//! * [`gemm_acc`] / [`gemm`] / [`gemm_into`] — dense f32 C += A·B.
//! * [`gemm_nt`] / [`gemm_nt_into`] — C = A·Bᵀ (data-gradient pass).
//! * [`gemm_tn`] / [`gemm_tn_into`] — C = Aᵀ·B (weight-gradient pass,
//!   zero-skip on A).
//! * [`gemm_acc_sparse`] / [`gemm_sparse`] — f32 with a per-element zero
//!   skip, for genuinely sparse inputs (post-ReLU quantized activation
//!   patches on the digital conv path).  Always scalar: the skip is the
//!   point, and it defeats vectorization anyway.
//! * [`gemm_acc_u8_i16`] — integer plane kernel (u8 DAC-plane activations ×
//!   i16 weights → i32).  Plane sums are exact integers ≤ 2²⁴, so every
//!   arm is bit-identical.
//! * [`gemm_acc_u8_bin`] — binary planes stored one weight per u8 (the
//!   reference layout; kept for parity tests and compat).
//! * [`gemm_acc_u8_bin_packed`] — binary planes bit-packed 64 columns per
//!   u64 word (`pim::layout::packed_words`), the layout `PimEngine` stores
//!   for the bit-serial scheme: 8× less weight traffic, broadcast-AND-
//!   accumulate inner loops on the AVX2/NEON arms, native `__mmask16`
//!   masked adds on the AVX-512 arm.
//!
//! Exactness contract: integer kernels are bit-identical across arms on
//! every shape (tails included); f32 kernels are deterministic per arm
//! (fixed tile order) and match scalar to documented tolerance — see
//! DESIGN.md §Kernel dispatch.

use crate::tensor::kernels::active;

/// C[m,n] += A[m,k] * B[k,n], row-major, dense f32 (dispatched).
pub fn gemm_acc(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    (active().gemm_acc)(m, k, n, a, b, c)
}

/// Dense-accumulate variant with a per-element zero skip.  Only worth it
/// on genuinely sparse f32 inputs — post-ReLU quantized activation patches
/// on the digital conv path.  (Binary bit-serial planes stopped using this
/// in PR 1: they run on the integer [`gemm_acc_u8_bin`] /
/// [`gemm_acc_u8_bin_packed`] kernels.)  On dense inputs the branch costs
/// more than the multiplies it saves.
pub fn gemm_acc_sparse(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let brow = &b[kk * n..kk * n + n];
            for j in 0..n {
                crow[j] += aik * brow[j];
            }
        }
    }
}

/// Integer plane kernel: C[m,n] += A[m,k] * B[k,n] with u8 activations,
/// i16 weights, i32 accumulators (dispatched; bit-identical across arms).
pub fn gemm_acc_u8_i16(m: usize, k: usize, n: usize, a: &[u8], b: &[i16], c: &mut [i32]) {
    (active().gemm_acc_u8_i16)(m, k, n, a, b, c)
}

/// Binary-plane kernel: weights are bit-serial planes in {0, 1} stored as
/// u8 — the reference layout.  `PimEngine` stores packed planes and calls
/// [`gemm_acc_u8_bin_packed`] instead; this stays as the parity/compat
/// surface.  Keeps the activation zero-skip (DAC planes under m=1 slicing
/// are ~half zeros).
pub fn gemm_acc_u8_bin(m: usize, k: usize, n: usize, a: &[u8], b: &[u8], c: &mut [i32]) {
    (active().gemm_acc_u8_bin)(m, k, n, a, b, c)
}

/// Bit-packed binary-plane kernel: B row `kk` is
/// `pim::layout::packed_words(n)` u64 words, bit `o%64` of word `o/64` ↔
/// column `o`.  Pad bits past `n` in the last word must be zero (the
/// engine's programming guarantees this; a stray pad bit panics on the
/// bounds check rather than corrupting memory).  Dispatched;
/// bit-identical across arms and to [`gemm_acc_u8_bin`] on the unpacked
/// plane.
pub fn gemm_acc_u8_bin_packed(m: usize, k: usize, n: usize, a: &[u8], b: &[u64], c: &mut [i32]) {
    (active().gemm_acc_u8_bin_packed)(m, k, n, a, b, c)
}

/// C = A * B (allocating convenience wrapper, dense).
pub fn gemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
    let mut c = Vec::new();
    gemm_into(m, k, n, a, b, &mut c);
    c
}

/// C = A * B into a reused buffer (`c` is cleared, zero-filled and resized
/// to m·n): the zero-allocation twin of [`gemm`] for arena callers.
pub fn gemm_into(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut Vec<f32>) {
    c.clear();
    c.resize(m * n, 0.0);
    gemm_acc(m, k, n, a, b, c);
}

/// C[m,n] = A[m,p] · B[n,p]ᵀ (both row-major).  The data-gradient pass of
/// the native trainer: dPatches[M,K] = dY[M,O] · W[K,O]ᵀ.  Dot-product
/// form — both operands stream row-wise.
pub fn gemm_nt(m: usize, p: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
    let mut c = Vec::new();
    gemm_nt_into(m, p, n, a, b, &mut c);
    c
}

/// [`gemm_nt`] into a reused buffer (cleared and resized to m·n).
pub fn gemm_nt_into(m: usize, p: usize, n: usize, a: &[f32], b: &[f32], c: &mut Vec<f32>) {
    assert_eq!(a.len(), m * p);
    assert_eq!(b.len(), n * p);
    c.clear();
    c.resize(m * n, 0.0);
    (active().gemm_nt_acc)(m, p, n, a, b, c);
}

/// C[m,n] = A[p,m]ᵀ · B[p,n] (both row-major).  The weight-gradient pass:
/// dW[K,O] = patches[M,K]ᵀ · dY[M,O].  Keeps the zero-skip on A — patch
/// rows are post-ReLU quantized activations, which carry many exact zeros.
pub fn gemm_tn(p: usize, m: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
    let mut c = Vec::new();
    gemm_tn_into(p, m, n, a, b, &mut c);
    c
}

/// [`gemm_tn`] into a reused buffer (cleared and resized to m·n).
pub fn gemm_tn_into(p: usize, m: usize, n: usize, a: &[f32], b: &[f32], c: &mut Vec<f32>) {
    assert_eq!(a.len(), p * m);
    assert_eq!(b.len(), p * n);
    c.clear();
    c.resize(m * n, 0.0);
    (active().gemm_tn_acc)(p, m, n, a, b, c);
}

/// C = A * B via the sparse kernel (digital conv path: A is post-ReLU
/// quantized patches, which carry many exact zeros).
pub fn gemm_sparse(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
    let mut c = vec![0.0; m * n];
    gemm_acc_sparse(m, k, n, a, b, &mut c);
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn gemm_naive(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + kk] * b[kk * n + j];
                }
            }
        }
        c
    }

    #[test]
    fn matches_naive_small() {
        let a = vec![1., 2., 3., 4.];
        let b = vec![5., 6., 7., 8.];
        assert_eq!(gemm(2, 2, 2, &a, &b), gemm_naive(2, 2, 2, &a, &b));
    }

    #[test]
    fn matches_naive_random_sizes() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 7, 5), (17, 130, 9), (64, 72, 33), (5, 300, 300)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal_in(0.0, 1.0)).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal_in(0.0, 1.0)).collect();
            let c1 = gemm(m, k, n, &a, &b);
            let c2 = gemm_naive(m, k, n, &a, &b);
            for (x, y) in c1.iter().zip(&c2) {
                assert!((x - y).abs() < 1e-3, "({m},{k},{n}): {x} vs {y}");
            }
        }
    }

    #[test]
    fn transposed_kernels_match_naive() {
        let mut rng = Rng::new(7);
        for &(m, p, n) in &[(1usize, 1usize, 1usize), (4, 9, 6), (7, 30, 12)] {
            let a: Vec<f32> = (0..m * p).map(|_| rng.normal_in(0.0, 1.0)).collect();
            let b: Vec<f32> = (0..n * p).map(|_| rng.normal_in(0.0, 1.0)).collect();
            // A·Bᵀ against explicit transposition + plain gemm
            let mut bt = vec![0.0f32; p * n];
            for j in 0..n {
                for q in 0..p {
                    bt[q * n + j] = b[j * p + q];
                }
            }
            let c1 = gemm_nt(m, p, n, &a, &b);
            let c2 = gemm_naive(m, p, n, &a, &bt);
            for (x, y) in c1.iter().zip(&c2) {
                assert!((x - y).abs() < 1e-4, "nt ({m},{p},{n}): {x} vs {y}");
            }
            // Aᵀ·B against explicit transposition (reuse a as the [p,m] side)
            let a2: Vec<f32> = (0..p * m).map(|_| rng.normal_in(0.0, 1.0)).collect();
            let b2: Vec<f32> = (0..p * n).map(|_| rng.normal_in(0.0, 1.0)).collect();
            let mut a2t = vec![0.0f32; m * p];
            for q in 0..p {
                for i in 0..m {
                    a2t[i * p + q] = a2[q * m + i];
                }
            }
            let c3 = gemm_tn(p, m, n, &a2, &b2);
            let c4 = gemm_naive(m, p, n, &a2t, &b2);
            for (x, y) in c3.iter().zip(&c4) {
                assert!((x - y).abs() < 1e-4, "tn ({p},{m},{n}): {x} vs {y}");
            }
        }
    }

    #[test]
    fn sparse_matches_dense() {
        let mut rng = Rng::new(2);
        for &(m, k, n) in &[(4, 9, 6), (7, 65, 12)] {
            // ~60% zeros, like quantized ReLU activations; integer-valued
            // data keeps f32 sums exact, so dispatched == scalar == sparse
            let a: Vec<f32> = (0..m * k)
                .map(|_| if rng.below(5) < 3 { 0.0 } else { rng.int_in(1, 15) as f32 })
                .collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.int_in(-7, 7) as f32).collect();
            assert_eq!(gemm(m, k, n, &a, &b), gemm_sparse(m, k, n, &a, &b));
        }
    }

    #[test]
    fn integer_kernels_match_float() {
        let mut rng = Rng::new(3);
        for &(m, k, n) in &[(1, 1, 1), (5, 9, 4), (6, 73, 17), (3, 144, 32)] {
            let a_u8: Vec<u8> = (0..m * k).map(|_| rng.int_in(0, 15) as u8).collect();
            let w_i16: Vec<i16> = (0..k * n).map(|_| rng.int_in(-7, 7) as i16).collect();
            let w_bin: Vec<u8> = (0..k * n).map(|_| rng.below(2) as u8).collect();
            let af: Vec<f32> = a_u8.iter().map(|&v| v as f32).collect();

            let mut ci = vec![0i32; m * n];
            gemm_acc_u8_i16(m, k, n, &a_u8, &w_i16, &mut ci);
            let wf: Vec<f32> = w_i16.iter().map(|&v| v as f32).collect();
            let cf = gemm_naive(m, k, n, &af, &wf);
            for (x, y) in ci.iter().zip(&cf) {
                assert_eq!(*x as f32, *y);
            }

            let mut cb = vec![0i32; m * n];
            gemm_acc_u8_bin(m, k, n, &a_u8, &w_bin, &mut cb);
            let wbf: Vec<f32> = w_bin.iter().map(|&v| v as f32).collect();
            let cbf = gemm_naive(m, k, n, &af, &wbf);
            for (x, y) in cb.iter().zip(&cbf) {
                assert_eq!(*x as f32, *y);
            }

            // bit-packed layout of the same binary plane
            let wp = crate::pim::layout::pack_bin_plane(&w_bin, k, n);
            let mut cp = vec![0i32; m * n];
            gemm_acc_u8_bin_packed(m, k, n, &a_u8, &wp, &mut cp);
            assert_eq!(cb, cp, "({m},{k},{n}): packed plane diverged from u8 plane");
        }
    }

    #[test]
    fn integer_kernels_accumulate() {
        let a = vec![1u8, 0, 0, 1];
        let b = vec![2i16, 0, 0, 2];
        let mut c = vec![1i32; 4];
        gemm_acc_u8_i16(2, 2, 2, &a, &b, &mut c);
        assert_eq!(c, vec![3, 1, 1, 3]);
    }

    #[test]
    fn accumulates_into_c() {
        let a = vec![1.0, 0.0, 0.0, 1.0];
        let b = vec![2.0, 0.0, 0.0, 2.0];
        let mut c = vec![1.0; 4];
        gemm_acc(2, 2, 2, &a, &b, &mut c);
        assert_eq!(c, vec![3.0, 1.0, 1.0, 3.0]);
    }
}
