//! Small blocked GEMM used by the digital conv path and the PIM engine's
//! plane sums.  Single-threaded (the testbed is 1 core); the blocking keeps
//! the working set in L1/L2 which is what matters here (§Perf L3).

/// C[m,n] += A[m,k] * B[k,n], row-major.
pub fn gemm_acc(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    const BK: usize = 64;
    const BN: usize = 256;
    for k0 in (0..k).step_by(BK) {
        let k1 = (k0 + BK).min(k);
        for n0 in (0..n).step_by(BN) {
            let n1 = (n0 + BN).min(n);
            for i in 0..m {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut c[i * n..(i + 1) * n];
                for kk in k0..k1 {
                    let aik = arow[kk];
                    if aik == 0.0 {
                        continue; // bit-planes and ReLU outputs are sparse
                    }
                    let brow = &b[kk * n..(kk + 1) * n];
                    for nn in n0..n1 {
                        crow[nn] += aik * brow[nn];
                    }
                }
            }
        }
    }
}

/// C = A * B (allocating convenience wrapper).
pub fn gemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
    let mut c = vec![0.0; m * n];
    gemm_acc(m, k, n, a, b, &mut c);
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn gemm_naive(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + kk] * b[kk * n + j];
                }
            }
        }
        c
    }

    #[test]
    fn matches_naive_small() {
        let a = vec![1., 2., 3., 4.];
        let b = vec![5., 6., 7., 8.];
        assert_eq!(gemm(2, 2, 2, &a, &b), gemm_naive(2, 2, 2, &a, &b));
    }

    #[test]
    fn matches_naive_random_sizes() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 7, 5), (17, 130, 9), (64, 72, 33), (5, 300, 300)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal_in(0.0, 1.0)).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal_in(0.0, 1.0)).collect();
            let c1 = gemm(m, k, n, &a, &b);
            let c2 = gemm_naive(m, k, n, &a, &b);
            for (x, y) in c1.iter().zip(&c2) {
                assert!((x - y).abs() < 1e-3, "({m},{k},{n}): {x} vs {y}");
            }
        }
    }

    #[test]
    fn accumulates_into_c() {
        let a = vec![1.0, 0.0, 0.0, 1.0];
        let b = vec![2.0, 0.0, 0.0, 2.0];
        let mut c = vec![1.0; 4];
        gemm_acc(2, 2, 2, &a, &b, &mut c);
        assert_eq!(c, vec![3.0, 1.0, 1.0, 3.0]);
    }
}
