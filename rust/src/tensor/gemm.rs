//! GEMM microkernels for the digital conv path and the PIM engine's plane
//! sums (§Perf L3).
//!
//! Four variants, all single-call (threading happens above, across batch
//! rows, in `crate::pim::engine`):
//!
//! * [`gemm_acc`] — dense f32, register-blocked (4-wide k unroll).  The old
//!   per-element `aik == 0.0` skip is gone: on dense native-scheme planes it
//!   cost a branch per element and defeated vectorization.
//! * [`gemm_acc_sparse`] — f32 with the zero-skip, for genuinely sparse
//!   inputs (post-ReLU quantized activation patches).
//! * [`gemm_acc_u8_i16`] — the integer-native plane kernel: u8 DAC-plane
//!   activations × i16 weights accumulated in i32.  Plane sums are exact
//!   integers, so any accumulation order is bit-identical to the float
//!   reference (all magnitudes ≤ 2^24).
//! * [`gemm_acc_u8_bin`] — binary-plane specialization (bit-serial weights
//!   w ∈ {0,1} stored as u8): half the weight-memory traffic of the i16
//!   kernel, and it keeps the zero-skip on activations, which pays off for
//!   m=1 DAC slicing where activation planes are ~half zeros.

/// C[m,n] += A[m,k] * B[k,n], row-major, dense f32.
pub fn gemm_acc(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        let mut kk = 0;
        // register-blocked: 4 rows of B share one pass over the C row
        while kk + 4 <= k {
            let a0 = arow[kk];
            let a1 = arow[kk + 1];
            let a2 = arow[kk + 2];
            let a3 = arow[kk + 3];
            let b0 = &b[kk * n..kk * n + n];
            let b1 = &b[(kk + 1) * n..(kk + 1) * n + n];
            let b2 = &b[(kk + 2) * n..(kk + 2) * n + n];
            let b3 = &b[(kk + 3) * n..(kk + 3) * n + n];
            for j in 0..n {
                crow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
            }
            kk += 4;
        }
        while kk < k {
            let aik = arow[kk];
            let brow = &b[kk * n..kk * n + n];
            for j in 0..n {
                crow[j] += aik * brow[j];
            }
            kk += 1;
        }
    }
}

/// Dense-accumulate variant with a per-element zero skip.  Only worth it on
/// sparse inputs (ReLU outputs, binary planes); on dense inputs the branch
/// costs more than the multiplies it saves.
pub fn gemm_acc_sparse(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let brow = &b[kk * n..kk * n + n];
            for j in 0..n {
                crow[j] += aik * brow[j];
            }
        }
    }
}

/// Integer plane kernel: C[m,n] += A[m,k] * B[k,n] with u8 activations,
/// i16 weights, i32 accumulators.
pub fn gemm_acc_u8_i16(m: usize, k: usize, n: usize, a: &[u8], b: &[i16], c: &mut [i32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        let mut kk = 0;
        while kk + 4 <= k {
            let a0 = arow[kk] as i32;
            let a1 = arow[kk + 1] as i32;
            let a2 = arow[kk + 2] as i32;
            let a3 = arow[kk + 3] as i32;
            let b0 = &b[kk * n..kk * n + n];
            let b1 = &b[(kk + 1) * n..(kk + 1) * n + n];
            let b2 = &b[(kk + 2) * n..(kk + 2) * n + n];
            let b3 = &b[(kk + 3) * n..(kk + 3) * n + n];
            for j in 0..n {
                crow[j] +=
                    a0 * b0[j] as i32 + a1 * b1[j] as i32 + a2 * b2[j] as i32 + a3 * b3[j] as i32;
            }
            kk += 4;
        }
        while kk < k {
            let aik = arow[kk] as i32;
            let brow = &b[kk * n..kk * n + n];
            for j in 0..n {
                crow[j] += aik * brow[j] as i32;
            }
            kk += 1;
        }
    }
}

/// Binary-plane kernel: weights are bit-serial planes in {0, 1} stored as
/// u8.  Keeps the activation zero-skip (the sparse variant of the integer
/// path — DAC planes under m=1 slicing are ~half zeros).
pub fn gemm_acc_u8_bin(m: usize, k: usize, n: usize, a: &[u8], b: &[u8], c: &mut [i32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0 {
                continue;
            }
            let av = aik as i32;
            let brow = &b[kk * n..kk * n + n];
            for j in 0..n {
                crow[j] += av * brow[j] as i32;
            }
        }
    }
}

/// C = A * B (allocating convenience wrapper, dense).
pub fn gemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
    let mut c = Vec::new();
    gemm_into(m, k, n, a, b, &mut c);
    c
}

/// C = A * B into a reused buffer (`c` is cleared, zero-filled and resized
/// to m·n): the zero-allocation twin of [`gemm`] for arena callers.
pub fn gemm_into(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut Vec<f32>) {
    c.clear();
    c.resize(m * n, 0.0);
    gemm_acc(m, k, n, a, b, c);
}

/// C[m,n] = A[m,p] · B[n,p]ᵀ (both row-major).  The data-gradient pass of
/// the native trainer: dPatches[M,K] = dY[M,O] · W[K,O]ᵀ.  Dot-product
/// form — both operands stream row-wise.
pub fn gemm_nt(m: usize, p: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
    let mut c = Vec::new();
    gemm_nt_into(m, p, n, a, b, &mut c);
    c
}

/// [`gemm_nt`] into a reused buffer (cleared and resized to m·n).
pub fn gemm_nt_into(m: usize, p: usize, n: usize, a: &[f32], b: &[f32], c: &mut Vec<f32>) {
    assert_eq!(a.len(), m * p);
    assert_eq!(b.len(), n * p);
    c.clear();
    c.resize(m * n, 0.0);
    for i in 0..m {
        let arow = &a[i * p..(i + 1) * p];
        let crow = &mut c[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = &b[j * p..(j + 1) * p];
            let mut s = 0.0f32;
            for q in 0..p {
                s += arow[q] * brow[q];
            }
            crow[j] = s;
        }
    }
}

/// C[m,n] = A[p,m]ᵀ · B[p,n] (both row-major).  The weight-gradient pass:
/// dW[K,O] = patches[M,K]ᵀ · dY[M,O].  Keeps the zero-skip on A — patch
/// rows are post-ReLU quantized activations, which carry many exact zeros.
pub fn gemm_tn(p: usize, m: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
    let mut c = Vec::new();
    gemm_tn_into(p, m, n, a, b, &mut c);
    c
}

/// [`gemm_tn`] into a reused buffer (cleared and resized to m·n).
pub fn gemm_tn_into(p: usize, m: usize, n: usize, a: &[f32], b: &[f32], c: &mut Vec<f32>) {
    assert_eq!(a.len(), p * m);
    assert_eq!(b.len(), p * n);
    c.clear();
    c.resize(m * n, 0.0);
    for q in 0..p {
        let arow = &a[q * m..(q + 1) * m];
        let brow = &b[q * n..(q + 1) * n];
        for (i, &aq) in arow.iter().enumerate() {
            if aq == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += aq * brow[j];
            }
        }
    }
}

/// C = A * B via the sparse kernel (digital conv path: A is post-ReLU
/// quantized patches, which carry many exact zeros).
pub fn gemm_sparse(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
    let mut c = vec![0.0; m * n];
    gemm_acc_sparse(m, k, n, a, b, &mut c);
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn gemm_naive(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + kk] * b[kk * n + j];
                }
            }
        }
        c
    }

    #[test]
    fn matches_naive_small() {
        let a = vec![1., 2., 3., 4.];
        let b = vec![5., 6., 7., 8.];
        assert_eq!(gemm(2, 2, 2, &a, &b), gemm_naive(2, 2, 2, &a, &b));
    }

    #[test]
    fn matches_naive_random_sizes() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 7, 5), (17, 130, 9), (64, 72, 33), (5, 300, 300)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal_in(0.0, 1.0)).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal_in(0.0, 1.0)).collect();
            let c1 = gemm(m, k, n, &a, &b);
            let c2 = gemm_naive(m, k, n, &a, &b);
            for (x, y) in c1.iter().zip(&c2) {
                assert!((x - y).abs() < 1e-3, "({m},{k},{n}): {x} vs {y}");
            }
        }
    }

    #[test]
    fn transposed_kernels_match_naive() {
        let mut rng = Rng::new(7);
        for &(m, p, n) in &[(1usize, 1usize, 1usize), (4, 9, 6), (7, 30, 12)] {
            let a: Vec<f32> = (0..m * p).map(|_| rng.normal_in(0.0, 1.0)).collect();
            let b: Vec<f32> = (0..n * p).map(|_| rng.normal_in(0.0, 1.0)).collect();
            // A·Bᵀ against explicit transposition + plain gemm
            let mut bt = vec![0.0f32; p * n];
            for j in 0..n {
                for q in 0..p {
                    bt[q * n + j] = b[j * p + q];
                }
            }
            let c1 = gemm_nt(m, p, n, &a, &b);
            let c2 = gemm_naive(m, p, n, &a, &bt);
            for (x, y) in c1.iter().zip(&c2) {
                assert!((x - y).abs() < 1e-4, "nt ({m},{p},{n}): {x} vs {y}");
            }
            // Aᵀ·B against explicit transposition (reuse a as the [p,m] side)
            let a2: Vec<f32> = (0..p * m).map(|_| rng.normal_in(0.0, 1.0)).collect();
            let b2: Vec<f32> = (0..p * n).map(|_| rng.normal_in(0.0, 1.0)).collect();
            let mut a2t = vec![0.0f32; m * p];
            for q in 0..p {
                for i in 0..m {
                    a2t[i * p + q] = a2[q * m + i];
                }
            }
            let c3 = gemm_tn(p, m, n, &a2, &b2);
            let c4 = gemm_naive(m, p, n, &a2t, &b2);
            for (x, y) in c3.iter().zip(&c4) {
                assert!((x - y).abs() < 1e-4, "tn ({p},{m},{n}): {x} vs {y}");
            }
        }
    }

    #[test]
    fn sparse_matches_dense() {
        let mut rng = Rng::new(2);
        for &(m, k, n) in &[(4, 9, 6), (7, 65, 12)] {
            // ~60% zeros, like quantized ReLU activations
            let a: Vec<f32> = (0..m * k)
                .map(|_| if rng.below(5) < 3 { 0.0 } else { rng.int_in(1, 15) as f32 })
                .collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.int_in(-7, 7) as f32).collect();
            assert_eq!(gemm(m, k, n, &a, &b), gemm_sparse(m, k, n, &a, &b));
        }
    }

    #[test]
    fn integer_kernels_match_float() {
        let mut rng = Rng::new(3);
        for &(m, k, n) in &[(1, 1, 1), (5, 9, 4), (6, 73, 17), (3, 144, 32)] {
            let a_u8: Vec<u8> = (0..m * k).map(|_| rng.int_in(0, 15) as u8).collect();
            let w_i16: Vec<i16> = (0..k * n).map(|_| rng.int_in(-7, 7) as i16).collect();
            let w_bin: Vec<u8> = (0..k * n).map(|_| rng.below(2) as u8).collect();
            let af: Vec<f32> = a_u8.iter().map(|&v| v as f32).collect();

            let mut ci = vec![0i32; m * n];
            gemm_acc_u8_i16(m, k, n, &a_u8, &w_i16, &mut ci);
            let wf: Vec<f32> = w_i16.iter().map(|&v| v as f32).collect();
            let cf = gemm_naive(m, k, n, &af, &wf);
            for (x, y) in ci.iter().zip(&cf) {
                assert_eq!(*x as f32, *y);
            }

            let mut cb = vec![0i32; m * n];
            gemm_acc_u8_bin(m, k, n, &a_u8, &w_bin, &mut cb);
            let wbf: Vec<f32> = w_bin.iter().map(|&v| v as f32).collect();
            let cbf = gemm_naive(m, k, n, &af, &wbf);
            for (x, y) in cb.iter().zip(&cbf) {
                assert_eq!(*x as f32, *y);
            }
        }
    }

    #[test]
    fn integer_kernels_accumulate() {
        let a = vec![1u8, 0, 0, 1];
        let b = vec![2i16, 0, 0, 2];
        let mut c = vec![1i32; 4];
        gemm_acc_u8_i16(2, 2, 2, &a, &b, &mut c);
        assert_eq!(c, vec![3, 1, 1, 3]);
    }

    #[test]
    fn accumulates_into_c() {
        let a = vec![1.0, 0.0, 0.0, 1.0];
        let b = vec![2.0, 0.0, 0.0, 2.0];
        let mut c = vec![1.0; 4];
        gemm_acc(2, 2, 2, &a, &b, &mut c);
        assert_eq!(c, vec![3.0, 1.0, 1.0, 3.0]);
    }
}
