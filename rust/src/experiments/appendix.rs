//! Appendix experiments: Table A2/Fig A4 (idealized bit-serial resolution
//! sweep), Table A3/Fig A5 (rescaling ablation), Fig A6 (BN calibration
//! ablation), Table A4/Fig A7 (gain & offset variation).

use crate::util::error::Result;

use crate::chip::curves::{synthesize_bank_with, CurveStats};
use crate::chip::ChipModel;
use crate::config::Scheme;
use crate::coordinator::SweepRunner;
use crate::report::{pct, Report};

use super::common::{self, Scale};

/// Table A2 / Fig. A4: ideal noiseless bit-serial PIM, b_PIM ∈ 3..10,
/// baseline vs ours (no BN calibration, no noise — pure PIM-QAT effect).
pub fn table_a2(runner: &mut SweepRunner, scale: Scale) -> Result<Report> {
    let mut r = Report::new(
        "tableA2",
        "Idealized bit-serial PIM: baseline vs ours (paper Table A2)",
        &["b_PIM", "Baseline", "Ours", "Paper (base/ours)"],
    );
    let paper: &[(u32, f64, f64)] = &[
        (3, 10.0, 61.8),
        (4, 10.2, 77.2),
        (5, 11.0, 86.5),
        (6, 41.1, 89.5),
        (7, 85.8, 90.8),
        (8, 90.3, 90.8),
        (9, 91.2, 90.8),
        (10, 91.6, 90.8),
    ];
    let grid: Vec<u32> = match scale {
        Scale::Quick => vec![3, 5, 7, 9],
        Scale::Full => paper.iter().map(|p| p.0).collect(),
    };
    let baseline = runner.run(&common::baseline_job("tiny", scale))?;
    let n_test = scale.chip_test_size();
    for &(b, pb, po) in paper.iter().filter(|p| grid.contains(&p.0)) {
        let chip = ChipModel::ideal(b);
        let acc_b = common::chip_eval(
            runner, &baseline, Scheme::BitSerial, 8, &chip, false, 0, n_test,
        )?;
        let ours = runner.run(&common::ours_job("tiny", Scheme::BitSerial, 8, b, scale))?;
        let acc_o =
            common::chip_eval(runner, &ours, Scheme::BitSerial, 8, &chip, false, 0, n_test)?;
        r.row(vec![b.to_string(), pct(acc_b), pct(acc_o), format!("{pb}/{po}")]);
    }
    r.note("shape: ours >> baseline below ~8 bits; baseline catches up (and may edge ahead) at 9-10 bits where PIM quantization is nearly lossless");
    Ok(r)
}

/// Table A3 / Fig. A5: rescaling ablation — fwd/bwd rescaling on/off for
/// bit-serial PIM-QAT.  (N/Y and Y/Y artifacts exist as lowered variants;
/// N/N is `norescale`.)
pub fn table_a3(runner: &mut SweepRunner, scale: Scale) -> Result<Report> {
    let mut r = Report::new(
        "tableA3",
        "Rescaling ablation, bit-serial (paper Table A3)",
        &["b_PIM", "Fwd", "Bwd", "Acc.", "Paper"],
    );
    let paper: &[(u32, [f64; 3])] = &[
        (3, [10.0, 17.1, 61.8]),
        (5, [10.3, 17.5, 86.5]),
        (7, [88.8, 91.0, 90.8]),
    ];
    let grid: Vec<u32> = match scale {
        Scale::Quick => vec![3, 7],
        Scale::Full => vec![3, 5, 7],
    };
    let n_test = scale.chip_test_size();
    for &(b, prow) in paper.iter().filter(|p| grid.contains(&p.0)) {
        for (variant, fwd, bwd, pi) in
            [("norescale", "N", "N", 0usize), ("nofwd", "N", "Y", 1), ("", "Y", "Y", 2)]
        {
            let mut job = common::ours_job("tiny", Scheme::BitSerial, 8, b, scale);
            job.variant = variant.into();
            let out = runner.run(&job)?;
            let chip = ChipModel::ideal(b);
            let acc = common::chip_eval(
                runner, &out, Scheme::BitSerial, 8, &chip, false, 0, n_test,
            )?;
            r.row(vec![
                b.to_string(),
                fwd.into(),
                bwd.into(),
                pct(acc),
                pct(prow[pi]),
            ]);
        }
    }
    r.note("shape: at low b_PIM training without rescaling is unstable (accuracy near chance); both techniques together recover it (paper Table A3 / Fig. A5)");
    Ok(r)
}

/// Fig. A6: BN-calibration ablation on 7-bit ideal and real chips, for both
/// the baseline and ours.
pub fn fig_a6(runner: &mut SweepRunner, scale: Scale) -> Result<Report> {
    let mut r = Report::new(
        "figA6",
        "BN calibration ablation, 7-bit bit-serial (paper Fig. A6)",
        &["chip", "Method", "no calib", "with calib"],
    );
    let n_test = scale.chip_test_size();
    let cb = scale.calib_batches();
    // ENOB-matched chip resolution (see table4 / EXPERIMENTS.md §Deviations):
    // the scaled models need a 4-bit chip to sit in the paper's 7-bit regime.
    let b = 4u32;
    let baseline = runner.run(&common::baseline_job("tiny", scale))?;
    let ours = runner.run(&common::ours_job("tiny", Scheme::BitSerial, 8, b, scale))?;
    let real = ChipModel {
        b_pim: b,
        noise_lsb: 0.35,
        bank: Some(crate::chip::curves::synthesize_bank(b, 32, 0xC819)),
        unit_out: 8,
        faults: None,
    };
    for (label, chip) in [
        ("ideal 4b + noise 0.35", ChipModel::ideal(b).with_noise(0.35)),
        ("real curves (4b) + noise 0.35", real),
    ] {
        for (m, out) in [("Baseline", &baseline), ("Ours", &ours)] {
            let acc0 = common::chip_eval(
                runner, out, Scheme::BitSerial, 8, &chip, false, 0, n_test,
            )?;
            let acc1 = common::chip_eval(
                runner, out, Scheme::BitSerial, 8, &chip, true, cb, n_test,
            )?;
            r.row(vec![label.into(), m.into(), pct(acc0), pct(acc1)]);
        }
    }
    r.note("shape: calibration helps everywhere, most dramatically on the real chip; the calibrated baseline still trails ours by a wide margin (paper Fig. A6)");
    Ok(r)
}

/// Table A4 / Fig. A7: idealized 7-bit curves with pre-calibration gain &
/// offset variation (gain ~ N(1, 0.024), offset ~ N(0, 2.04) LSB) — BN
/// calibration repairs the collapse without hardware trimming.
pub fn table_a4(runner: &mut SweepRunner, scale: Scale) -> Result<Report> {
    let mut r = Report::new(
        "tableA4",
        "Gain & offset variation + BN calibration (paper Table A4)",
        &["Model", "N", "G&O var.", "BN calib", "Acc.", "Paper"],
    );
    let n_test = scale.chip_test_size();
    let cb = scale.calib_batches();
    // variation-only curve bank: gain/offset from the paper's Fig. A7, no INL
    // ENOB-matched 4-bit chip (table4 rationale); gain/offset stats are the
    // paper's measured pre-calibration variation.
    let b = 4u32;
    let mut stats = CurveStats::uncalibrated();
    stats.inl_peak_lsb = 0.0;
    let bank = synthesize_bank_with(b, 32, 0xA7, stats);
    let vchip =
        ChipModel { b_pim: b, noise_lsb: 0.0, bank: Some(bank), unit_out: 8, faults: None };
    let ichip = ChipModel::ideal(b);

    struct Row {
        model: &'static str,
        standin: &'static str,
        uc: usize,
        paper: [f64; 3],
    }
    let rows = [
        Row { model: "tiny", standin: "r20", uc: 8, paper: [91.2, 10.0, 90.7] },
        Row { model: "small", standin: "r56", uc: 16, paper: [90.8, 10.0, 90.6] },
    ];
    for row in &rows {
        let ours = runner.run(&common::ours_job(row.model, Scheme::BitSerial, row.uc, b, scale))?;
        let n = row.uc * 9;
        let acc_ideal = common::chip_eval(
            runner, &ours, Scheme::BitSerial, row.uc, &ichip, false, 0, n_test,
        )?;
        r.row(vec![
            format!("{} ({})", row.standin, row.model),
            n.to_string(),
            "N".into(),
            "-".into(),
            pct(acc_ideal),
            pct(row.paper[0]),
        ]);
        let acc_raw = common::chip_eval(
            runner, &ours, Scheme::BitSerial, row.uc, &vchip, false, 0, n_test,
        )?;
        r.row(vec![
            format!("{} ({})", row.standin, row.model),
            n.to_string(),
            "Y".into(),
            "N".into(),
            pct(acc_raw),
            pct(row.paper[1]),
        ]);
        let acc_cal = common::chip_eval(
            runner, &ours, Scheme::BitSerial, row.uc, &vchip, true, cb, n_test,
        )?;
        r.row(vec![
            format!("{} ({})", row.standin, row.model),
            n.to_string(),
            "Y".into(),
            "Y".into(),
            pct(acc_cal),
            pct(row.paper[2]),
        ]);
    }
    r.note("shape: raw gain/offset variation collapses accuracy to chance; BN calibration alone recovers it to within ~1 point of the variation-free chip (paper Table A4)");
    Ok(r)
}
