//! Table 1 (hardware efficiency) and Table 2 (method applicability).

use crate::util::error::Result;

use crate::chip::energy;
use crate::report::Report;

/// Table 1: peak energy efficiency of different hardware.
pub fn table1() -> Result<Report> {
    let mut r = Report::new(
        "table1",
        "Energy efficiency of different hardware (TOPS/W)",
        &["Hardware", "Efficiency (TOPS/W)", "Source", "Paper"],
    );
    let paper = [0.1, 2.3, 11.0, 49.6];
    for ((hw, eff, src), p) in energy::table1().into_iter().zip(paper) {
        r.row(vec![hw.to_string(), format!("{eff:.1}"), src.to_string(), format!("{p}")]);
    }
    r.note("digital rows are the paper's citations; the SRAM PIM row is the in-tree energy model calibrated to the prototype's configuration (N=144, b_PIM=7, 4 planes)");
    Ok(r)
}

/// Table 2: which training method supports which PIM decomposition scheme.
/// The ✓/✗ pattern is structural: the baseline ignores PIM quantization
/// entirely; AMS's additive-noise abstraction assumes a single analog
/// summation (native) and has no ENOB model for bit-serial/differential
/// recombination; PIM-QAT models the decomposition explicitly (§2, Table 2).
pub fn table2() -> Result<Report> {
    let mut r = Report::new(
        "table2",
        "Training methods vs PIM decomposition schemes",
        &["Method", "Native", "Bit Serial", "Differential"],
    );
    r.row(vec!["Baseline".into(), "✗".into(), "✗".into(), "✗".into()]);
    r.row(vec!["AMS".into(), "✓".into(), "✗".into(), "✗".into()]);
    r.row(vec!["Ours".into(), "✓".into(), "✓".into(), "✓".into()]);
    r.note("matches the paper verbatim; the ✓ entries are exercised empirically by table3 (native) and fig5 (all three schemes)");
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_has_four_rows_and_matches_paper_sram() {
        let r = table1().unwrap();
        assert_eq!(r.rows.len(), 4);
        let sram: f64 = r.rows[3][1].parse().unwrap();
        assert!((sram - 49.6).abs() < 2.5);
    }

    #[test]
    fn table2_shape() {
        let r = table2().unwrap();
        assert_eq!(r.rows.len(), 3);
        assert_eq!(r.rows[2][1..], ["✓", "✓", "✓"].map(String::from));
    }
}
