//! Fig. 4 (adjusted-precision training map) and Fig. 5 (three schemes ×
//! resolution × noise, ours vs baseline+BN-calibration).

use crate::util::error::Result;

use crate::chip::{enob, ChipModel};
use crate::config::Scheme;
use crate::coordinator::{adjusted, SweepRunner};
use crate::report::{pct, Report};

use super::common::{self, Scale};

/// Fig. 4: for each (inference resolution, noise) cell, search the training
/// resolution (candidates from the ENOB rule) and report the winner.
pub fn fig4(runner: &mut SweepRunner, scale: Scale) -> Result<Report> {
    let mut r = Report::new(
        "fig4",
        "Adjusted-precision training: best TR per (IR, noise) (paper Fig. 4)",
        &["IR (bits)", "noise (LSB)", "ENOB rule", "best TR", "acc @ best", "acc @ TR=IR"],
    );
    let (irs, noises): (&[u32], &[f32]) = match scale {
        Scale::Quick => (&[5, 7], &[0.25, 1.0, 2.0]),
        Scale::Full => (&[4, 5, 6, 7, 8], &[0.25, 0.5, 1.0, 1.5, 2.0]),
    };
    for &ir in irs {
        for &noise in noises {
            let base = common::ours_job("tiny", Scheme::BitSerial, 8, ir, scale);
            let res = adjusted::search(runner, &base, ir, noise, scale.calib_batches())?;
            let best = res.best();
            let at_ir = res
                .candidates
                .iter()
                .find(|c| c.train_resolution == ir)
                .map(|c| c.chip_acc)
                .unwrap_or(f64::NAN);
            r.row(vec![
                ir.to_string(),
                format!("{noise}"),
                format!("{:.2} -> {}", enob::enob(ir, noise), res.enob_suggestion),
                best.train_resolution.to_string(),
                pct(best.chip_acc),
                pct(at_ir),
            ]);
        }
    }
    r.note("shape to reproduce: at low noise the best TR equals IR; as noise grows the optimum drops below IR, earlier for higher IR (paper Fig. 4)");
    Ok(r)
}

/// Fig. 5: ours vs baseline(+BN calibration) on ideal PIM chips of every
/// scheme, across resolution and noise.  N=9 native, N=72 for bit-serial /
/// differential on the tiny model (the paper's 144 needs the w16 model —
/// covered in table4).
pub fn fig5(runner: &mut SweepRunner, scale: Scale) -> Result<Report> {
    let mut r = Report::new(
        "fig5",
        "Ideal PIM, all schemes: ours vs baseline+BNcalib (paper Fig. 5)",
        &["scheme", "b_PIM", "noise (LSB)", "Baseline+calib", "Ours"],
    );
    let schemes: &[(Scheme, usize)] =
        &[(Scheme::Native, 1), (Scheme::BitSerial, 8), (Scheme::Differential, 8)];
    let (bs_grid, noises): (&[u32], &[f32]) = match scale {
        Scale::Quick => (&[4, 5, 7], &[0.0, 1.0]),
        Scale::Full => (&[4, 5, 6, 7, 8], &[0.0, 0.5, 1.0]),
    };
    let n_test = scale.chip_test_size();
    let cb = scale.calib_batches();
    let baseline = runner.run(&common::baseline_job("tiny", scale))?;
    for &(scheme, uc) in schemes {
        for &b in bs_grid {
            let ours = runner.run(&common::ours_job("tiny", scheme, uc, b, scale))?;
            for &noise in noises {
                let chip = ChipModel::ideal(b).with_noise(noise);
                let acc_b = common::chip_eval(
                    runner, &baseline, scheme, uc, &chip, true, cb, n_test,
                )?;
                let acc_o =
                    common::chip_eval(runner, &ours, scheme, uc, &chip, true, cb, n_test)?;
                r.row(vec![
                    scheme.to_string(),
                    b.to_string(),
                    format!("{noise}"),
                    pct(acc_b),
                    pct(acc_o),
                ]);
            }
        }
    }
    r.note("shape to reproduce: ours consistently above baseline+calib, with the margin largest at low resolution / high noise and for the bit-serial & differential schemes (paper Fig. 5)");
    Ok(r)
}
