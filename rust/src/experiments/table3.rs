//! Table 3: native scheme (N=9), b_PIM ∈ {3..7}, Baseline vs AMS vs Ours.
//!
//! Paper: ResNet20/CIFAR10; here the scaled stand-in (see EXPERIMENTS.md).
//! Baseline is ONE conventionally-trained checkpoint evaluated on PIM chips
//! of each resolution (that is exactly the deployment the paper warns
//! about); AMS and Ours are trained per-resolution.

use crate::util::error::Result;

use crate::chip::ChipModel;
use crate::config::{Mode, Scheme};
use crate::coordinator::SweepRunner;
use crate::report::{pct, Report};

use super::common::{self, Scale};

pub fn run(runner: &mut SweepRunner, scale: Scale) -> Result<Report> {
    let mut r = Report::new(
        "table3",
        "Native scheme (N=9): accuracy vs b_PIM (paper Table 3)",
        &["b_PIM", "Method", "Acc.", "Paper"],
    );
    // paper numbers for ResNet20/CIFAR10 (shape reference, not target)
    let paper: &[(u32, [f64; 3])] = &[
        (3, [8.3, 73.3, 81.7]),
        (4, [27.2, 85.0, 87.7]),
        (5, [80.5, 89.0, 90.7]),
        (6, [89.2, 90.3, 90.9]),
        (7, [91.0, 90.7, 91.0]),
    ];

    let baseline = runner.run(&common::baseline_job("tiny", scale))?;
    let n_test = scale.chip_test_size();

    for &(b, paper_row) in paper {
        let chip = ChipModel::ideal(b);
        // Baseline: conventionally trained, deployed on the PIM chip as-is.
        let acc_b = common::chip_eval(
            runner, &baseline, Scheme::Native, 1, &chip, false, 0, n_test,
        )?;
        r.row(vec![b.to_string(), "Baseline".into(), pct(acc_b), pct(paper_row[0])]);

        // AMS (Rekhi et al. 2019): additive-noise-trained, per resolution.
        let mut ams = common::base_job("tiny", scale);
        ams.mode = Mode::Ams;
        ams.scheme = Scheme::Native;
        ams.unit_channels = 1;
        ams.b_pim_train = b;
        let out_a = runner.run(&ams)?;
        let acc_a =
            common::chip_eval(runner, &out_a, Scheme::Native, 1, &chip, false, 0, n_test)?;
        r.row(vec![b.to_string(), "AMS".into(), pct(acc_a), pct(paper_row[1])]);

        // Ours: PIM-QAT at the inference resolution.
        let ours = common::ours_job("tiny", Scheme::Native, 1, b, scale);
        let out_o = runner.run(&ours)?;
        let acc_o =
            common::chip_eval(runner, &out_o, Scheme::Native, 1, &chip, false, 0, n_test)?;
        r.row(vec![b.to_string(), "Ours".into(), pct(acc_o), pct(paper_row[2])]);
    }
    // the b_PIM = +∞ row: software accuracy of the baseline checkpoint
    r.row(vec![
        "+inf".into(),
        "Baseline (software)".into(),
        pct(baseline.software_acc),
        pct(91.6),
    ]);
    r.note("shape to reproduce: Ours ≥ AMS ≥ Baseline at every resolution, with the gap exploding below 5 bits (paper: 81.7 vs 73.3 vs 8.3 at 3-bit)");
    Ok(r)
}
