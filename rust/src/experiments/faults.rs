//! Fault ledger (beyond the paper's exhibits): clean / injured /
//! self-tuned accuracy across fault severities.
//!
//! One PIM-QAT checkpoint is deployed onto the same chip three times per
//! row: healthy, injured by a [`FaultProfile`] preset (device-to-device
//! gain/offset spread, drift, stuck columns, noise bursts — the
//! `chip::faults` subsystem), and injured-then-self-tuned (§3.4's BN
//! calibration streamed through the injured forward path, `pim-qat
//! calibrate`).  The story the ledger pins: accuracy falls monotonically
//! with severity, and self-tuning recovers most of the gain/offset damage
//! while stuck columns stay lost.

use crate::util::error::Result;

use crate::chip::{ChipModel, FaultProfile};
use crate::config::Scheme;
use crate::coordinator::SweepRunner;
use crate::report::{pct, Report};
use crate::train::{self_tune, SelfTuneCfg};

use super::common::{self, Scale};

pub fn run(runner: &mut SweepRunner, scale: Scale) -> Result<Report> {
    let mut r = Report::new(
        "faults",
        "Degraded-chip ladder: clean / injured / BN self-tuned per fault severity",
        &["Profile", "Chip", "Clean", "Injured", "Self-tuned", "Recovered"],
    );
    let uc = 8usize;
    let job = common::ours_job("tiny", Scheme::BitSerial, uc, 7, scale);
    let out = runner.run(&job)?;
    let chip = ChipModel::ideal(7).with_noise(0.35);
    let cfg = SelfTuneCfg {
        scheme: Scheme::BitSerial,
        unit_channels: uc,
        calib_batches: scale.calib_batches(),
        batch: 32,
        test_size: scale.chip_test_size(),
        seed: 1,
    };
    let (train_ds, test_ds) = {
        let pair = runner.datasets(&job)?;
        (pair.0.clone(), pair.1.clone())
    };
    for (label, profile) in [
        ("mild", FaultProfile::mild().on_chip(0xC4)),
        ("moderate", FaultProfile::moderate().on_chip(0xC4)),
        ("severe", FaultProfile::severe().on_chip(0xC4)),
    ] {
        let rep = self_tune(
            runner.manifest(),
            &out.ckpt,
            &chip,
            &profile,
            &cfg,
            &train_ds,
            &test_ds,
        )?;
        r.row(vec![
            label.into(),
            format!("{:#x}", profile.chip_id),
            pct(rep.clean_acc),
            pct(rep.injured_acc),
            pct(rep.tuned_acc),
            format!("{:.0}%", 100.0 * rep.recovered()),
        ]);
    }
    // Variability-aware training goes through the data-parallel driver
    // (DESIGN.md §Data parallelism): 2 replica trainers, and every
    // microbatch slot trains against its *own* injured chip —
    // `FaultProfile::on_chip(chip_id + slot)`, the chip-farm fingerprint
    // convention — so the QAT graph sees device-to-device spread across
    // the farm, not one chip's draw.  The row reports the software
    // accuracy of the fault-hardened checkpoint under "Clean".
    let mut fj = job.clone();
    fj.faults = "mild:196".to_string(); // chip 0xc4; slots bind 0xc4, 0xc5
    let hardened = crate::train::run_job_parallel(
        runner.manifest(),
        &fj,
        &train_ds,
        &test_ds,
        usize::MAX,
        &crate::train::ParallelCfg::new(2),
    )?;
    r.row(vec![
        "mild (in-train, 2 replicas)".into(),
        "0xc4+slot".into(),
        pct(hardened.software_acc),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);
    r.note("shape to reproduce: accuracy falls with fault severity; BN self-tuning recovers most of the gain/offset damage, stuck columns stay lost");
    r.note("last row: variability-aware QAT through the data-parallel driver, each replica slot bound to its own injured chip");
    Ok(r)
}
