//! Experiment registry: every table and figure of the paper's evaluation,
//! regenerated end-to-end (DESIGN.md's per-experiment index).
//!
//! Each entry prints the paper's rows (our measurement next to the paper's
//! number), saves CSV + JSON under `results/`, and is driven by
//! `pim-qat experiment <id>` (or `all`).

pub mod appendix;
pub mod basic_tables;
pub mod common;
pub mod faults;
pub mod fig45;
pub mod figures;
pub mod table3;
pub mod table4;

pub use common::Scale;

use crate::util::error::{anyhow, Result};

use crate::coordinator::SweepRunner;
use crate::report::Report;
use crate::train::Backend;

/// All experiment ids: the paper's 13 exhibits in paper order, plus the
/// `faults` degraded-chip ledger (this repo's fault-injection subsystem).
pub const ALL: &[&str] = &[
    "table1", "table2", "table3", "table4", "fig3", "fig4", "fig5", "figA2",
    "figA3", "tableA2", "tableA3", "figA6", "tableA4", "faults",
];

/// Which experiments need a training backend vs pure analysis.
pub fn needs_runtime(id: &str) -> bool {
    !matches!(id, "table1" | "table2" | "fig3" | "figA2" | "figA3")
}

/// Run one experiment by id.  Training-dependent experiments run on any
/// [`Backend`] (native by default — no artifacts required).
pub fn run_one(id: &str, backend: Option<&dyn Backend>, scale: Scale) -> Result<Report> {
    let mut runner_slot;
    let runner: Option<&mut SweepRunner> = match backend {
        Some(b) => {
            runner_slot = SweepRunner::new(b);
            Some(&mut runner_slot)
        }
        None => None,
    };
    let need = needs_runtime(id);
    let runner = match (need, runner) {
        (true, Some(r)) => Some(r),
        (true, None) => return Err(anyhow!("experiment {id} needs a training backend")),
        (false, _) => None,
    };
    match id {
        "table1" => basic_tables::table1(),
        "table2" => basic_tables::table2(),
        "table3" => table3::run(runner.unwrap(), scale),
        "table4" => table4::run(runner.unwrap(), scale),
        "fig3" => figures::fig3(),
        "fig4" => fig45::fig4(runner.unwrap(), scale),
        "fig5" => fig45::fig5(runner.unwrap(), scale),
        "figA2" => figures::fig_a2(),
        "figA3" => figures::fig_a3(),
        "tableA2" => appendix::table_a2(runner.unwrap(), scale),
        "tableA3" => appendix::table_a3(runner.unwrap(), scale),
        "figA6" => appendix::fig_a6(runner.unwrap(), scale),
        "tableA4" => appendix::table_a4(runner.unwrap(), scale),
        "faults" => faults::run(runner.unwrap(), scale),
        _ => Err(anyhow!("unknown experiment {id:?}; known: {ALL:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_every_paper_exhibit() {
        // main body: tables 1-4, figures 3-5; appendix: A2/A3 figures,
        // A2/A3/A4 tables (A4/A5/A6/A7 figures are views of those tables);
        // +1 for the repo's own degraded-chip fault ledger
        assert_eq!(ALL.len(), 14);
    }

    #[test]
    fn analysis_experiments_run_standalone() {
        for id in ["table1", "table2", "figA3"] {
            let r = run_one(id, None, Scale::Quick).unwrap();
            assert!(!r.rows.is_empty());
        }
    }

    #[test]
    fn runtime_experiments_require_runtime() {
        assert!(run_one("table3", None, Scale::Quick).is_err());
    }

    #[test]
    fn unknown_id_rejected() {
        assert!(run_one("table99", None, Scale::Quick).is_err());
    }
}
