//! Table 4: accuracy on the (simulated) 7-bit real chip, bit-serial scheme,
//! with measured-curve non-linearity and 0.35 LSB thermal noise.
//!
//! Paper models → scaled stand-ins (EXPERIMENTS.md §Model mapping):
//!   ResNet20 → tiny (r8 w8), ResNet44 → small (r8 w16), VGGNet11 → vgg11,
//!   CIFAR100/ResNet20 → tiny100.  N ∈ {72, 144} where the model is wide
//!   enough (w8 stages cap uc at 8 → N=72; the w16 model reaches N=144).
//!
//! Ours rows include BN calibration (§3.4 is part of the method); baseline
//! rows are the paper's deploy-as-is failure mode.

use crate::util::error::Result;

use crate::chip::ChipModel;
use crate::config::Scheme;
use crate::coordinator::SweepRunner;
use crate::report::{pct, Report};

use super::common::{self, Scale};

pub fn run(runner: &mut SweepRunner, scale: Scale) -> Result<Report> {
    let mut r = Report::new(
        "table4",
        "Real chip (measured curves + 0.35 LSB noise), bit-serial (paper Table 4)",
        &["Dataset", "Model", "Method", "N", "Acc.", "Paper"],
    );
    // ENOB matching (EXPERIMENTS.md §Deviations): the paper's 7-bit chip
    // sits right at its ResNet20's failure threshold; our shallower scaled
    // models tolerate 7-bit PIM quantization, so the equivalent regime here
    // is a 4-bit chip — same relative severity, same qualitative story.
    let b_chip = 4u32;
    let chip = ChipModel {
        b_pim: b_chip,
        noise_lsb: 0.35,
        bank: Some(crate::chip::curves::synthesize_bank(b_chip, 32, 0xC819)),
        unit_out: 8,
        faults: None,
    };
    let n_test = scale.chip_test_size();
    let cb = scale.calib_batches();

    // (dataset label, model key, paper stand-in, ucs, paper rows)
    // paper rows: (software, baseline@72, baseline@144, ours@72, ours@144)
    struct Row {
        dataset: &'static str,
        model: &'static str,
        standin: &'static str,
        ucs: &'static [usize],
        paper: [f64; 5],
    }
    let rows = [
        Row { dataset: "CIFAR10", model: "tiny", standin: "ResNet20", ucs: &[8],
              paper: [91.6, 13.9, 10.9, 89.7, 89.1] },
        Row { dataset: "CIFAR100", model: "tiny100", standin: "ResNet20", ucs: &[8],
              paper: [67.0, 1.8, 1.3, 62.6, 61.8] },
    ];
    let rows_full = [
        Row { dataset: "CIFAR10", model: "small", standin: "ResNet44", ucs: &[8, 16],
              paper: [92.8, 10.5, 10.0, 90.6, 90.7] },
        Row { dataset: "CIFAR10", model: "vgg11", standin: "VGGNet11", ucs: &[8],
              paper: [93.7, 10.0, 9.9, 94.2, 94.0] },
    ];
    let rows: Vec<&Row> = match scale {
        Scale::Quick => rows.iter().collect(),
        Scale::Full => rows.iter().chain(rows_full.iter()).collect(),
    };

    for row in rows {
        let baseline = runner.run(&common::baseline_job(row.model, scale))?;
        r.row(vec![
            row.dataset.into(),
            format!("{} ({})", row.standin, row.model),
            "Software".into(),
            "-".into(),
            pct(baseline.software_acc),
            pct(row.paper[0]),
        ]);
        for (i, &uc) in row.ucs.iter().enumerate() {
            let n = uc * 9;
            // Baseline deployed as-is on the noisy, non-linear chip.
            let acc_b = common::chip_eval(
                runner, &baseline, Scheme::BitSerial, uc, &chip, false, 0, n_test,
            )?;
            r.row(vec![
                row.dataset.into(),
                format!("{} ({})", row.standin, row.model),
                "Baseline".into(),
                n.to_string(),
                pct(acc_b),
                pct(row.paper[1 + i]),
            ]);
            // Ours: PIM-QAT at the chip resolution + BN calibration.
            let ours = common::ours_job(row.model, Scheme::BitSerial, uc, b_chip, scale);
            let out = runner.run(&ours)?;
            let acc_o = common::chip_eval(
                runner, &out, Scheme::BitSerial, uc, &chip, true, cb, n_test,
            )?;
            r.row(vec![
                row.dataset.into(),
                format!("{} ({})", row.standin, row.model),
                "Ours".into(),
                n.to_string(),
                pct(acc_o),
                pct(row.paper[3 + i]),
            ]);
        }
    }
    r.note("shape to reproduce: baseline ≈ random guess on the real chip; ours recovers most of its software accuracy");
    r.note("chip resolution 4 bit = the ENOB-matched equivalent of the paper's 7-bit chip for these scaled models (see EXPERIMENTS.md §Deviations); small/vgg11 rows run at --full scale");
    Ok(r)
}
