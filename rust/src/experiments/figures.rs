//! Fig. 3 (computing error vs noise / ENOB), Fig. A2 (scale-enlarging ρ),
//! Fig. A3 (non-ideality impact on BN statistics) — the analysis figures
//! that need no training.

use crate::util::error::Result;

use crate::chip::{enob, ChipModel};
use crate::config::Scheme;
use crate::pim::{pim_grouped_matmul, QuantBits};
use crate::tensor::ops::channel_stats;
use crate::tensor::Tensor;
use crate::report::Report;
use crate::util::rng::Rng;
use crate::util::Welford;

/// Fig. 3: std of MAC computing errors vs injected noise std on the 7-bit
/// chip, normalized by the noiseless quantization error; plus the ENOB
/// (equivalent ideal lower-bit system) each noise level corresponds to.
pub fn fig3() -> Result<Report> {
    let mut r = Report::new(
        "fig3",
        "Computing error vs noise std, 7-bit PIM (paper Fig. 3)",
        &["noise (LSB)", "error-std ratio", "model sqrt(1+12s^2)", "ENOB (bits)"],
    );
    for &sigma in &[0.0f32, 0.1, 0.2, 0.35, 0.5, 0.75, 1.0, 1.5, 2.0] {
        let ratio = enob::error_std_ratio(7, sigma, 120_000, 42);
        let model = (1.0 + 12.0 * (sigma as f64).powi(2)).sqrt();
        r.row(vec![
            format!("{sigma}"),
            format!("{ratio:.3}"),
            format!("{model:.3}"),
            format!("{:.2}", enob::enob(7, sigma)),
        ]);
    }
    r.note("the measured ratio tracks sqrt(1+12σ²); at the chip's 0.35 LSB the 7-bit converter behaves like a ~6.3-bit ideal one — the basis of adjusted-precision training (§3.5)");
    Ok(r)
}

/// Fig. A2: scale-enlarging effect ρ = std(y_PIM)/std(y) vs b_PIM, for
/// c_in ∈ {16, 32, 64} (bit-serial, unit channel 16 → N = 144).
pub fn fig_a2() -> Result<Report> {
    let mut r = Report::new(
        "figA2",
        "Std ratio rho vs PIM resolution (paper Fig. A2)",
        &["b_PIM", "c_in=16", "c_in=32", "c_in=64", "average"],
    );
    let bits = QuantBits::default();
    let chip_bits: Vec<u32> = (3..=10).collect();
    let mut rows: Vec<Vec<String>> = Vec::new();
    for &b in &chip_bits {
        let chip = ChipModel::ideal(b);
        let mut vals = Vec::new();
        for &c_in in &[16usize, 32, 64] {
            let mut rng = Rng::new(100 + c_in as u64);
            let (m, k, o, uc) = (96usize, 3usize, 16usize, 16usize);
            let cols = c_in * k * k;
            let a = Tensor::from_vec(
                &[m, cols],
                (0..m * cols).map(|_| rng.int_in(0, 15) as f32).collect(),
            );
            let w = Tensor::from_vec(
                &[cols, o],
                (0..cols * o).map(|_| rng.int_in(-7, 7) as f32).collect(),
            );
            let mut nrng = Rng::new(0);
            let y_pim = pim_grouped_matmul(
                Scheme::BitSerial, bits, &a, &w, c_in, k, uc, &chip, &mut nrng,
            );
            let hi = ChipModel::ideal(24);
            let y_ref =
                pim_grouped_matmul(Scheme::BitSerial, bits, &a, &w, c_in, k, uc, &hi, &mut nrng);
            let std = |t: &Tensor| {
                let mut w = Welford::default();
                for &v in &t.data {
                    w.push(v as f64);
                }
                w.std()
            };
            vals.push(std(&y_pim) / std(&y_ref));
        }
        let avg = vals.iter().sum::<f64>() / vals.len() as f64;
        rows.push(vec![
            b.to_string(),
            format!("{:.2}", vals[0]),
            format!("{:.2}", vals[1]),
            format!("{:.2}", vals[2]),
            format!("{avg:.2}"),
        ]);
    }
    for row in rows {
        r.row(row);
    }
    r.note("paper: ratio ~1 above 7 bits, growing to 2–4x at 3–4 bits — the scale-enlarging effect motivating both rescaling techniques (§3.3)");
    Ok(r)
}

/// Fig. A3: impact of non-linearity + noise on one conv layer's output
/// statistics (the BN running stats that §3.4 recalibrates).
pub fn fig_a3() -> Result<Report> {
    let mut r = Report::new(
        "figA3",
        "Output statistics under chip non-idealities (paper Fig. A3)",
        &["chip", "noise (LSB)", "mean shift (%)", "std shift (%)"],
    );
    let bits = QuantBits::default();
    let (m, c_in, k, o, uc) = (128usize, 16usize, 3usize, 32usize, 16usize);
    let cols = c_in * k * k;
    let mut rng = Rng::new(7);
    let a = Tensor::from_vec(&[m, cols], (0..m * cols).map(|_| rng.int_in(0, 15) as f32).collect());
    let w = Tensor::from_vec(&[cols, o], (0..cols * o).map(|_| rng.int_in(-7, 7) as f32).collect());
    let run = |chip: &ChipModel, seed: u64| {
        let mut nrng = Rng::new(seed);
        let y = pim_grouped_matmul(Scheme::BitSerial, bits, &a, &w, c_in, k, uc, chip, &mut nrng);
        channel_stats(&y.reshape(&[m, 1, 1, o]))
    };
    let (m0, v0) = run(&ChipModel::ideal(7), 1);
    let agg = |xs: &[f32]| xs.iter().map(|&v| v as f64).sum::<f64>() / xs.len() as f64;
    let (bm0, bv0) = (agg(&m0.iter().map(|v| v.abs()).collect::<Vec<_>>()), agg(&v0));
    for &(label, noise) in &[("ideal", 0.0f32), ("ideal", 0.35), ("ideal", 1.0),
                             ("real curves", 0.0), ("real curves", 0.35), ("real curves", 1.0)] {
        let chip = if label == "ideal" {
            ChipModel::ideal(7).with_noise(noise)
        } else {
            ChipModel::real(0xC819).with_noise(noise)
        };
        let (mm, vv) = run(&chip, 1);
        let dm = (agg(&mm.iter().map(|v| v.abs()).collect::<Vec<_>>()) - bm0) / bm0 * 100.0;
        let dv = (agg(&vv) - bv0) / bv0 * 100.0;
        r.row(vec![label.into(), format!("{noise}"), format!("{dm:+.1}"), format!("{dv:+.1}")]);
    }
    r.note("paper reports output statistics shifting by as much as 30% under real-chip non-idealities — the reason BN calibration works");
    Ok(r)
}
