//! Shared machinery for the paper-reproduction experiments.

use crate::util::error::Result;

use crate::chip::ChipModel;
use crate::config::{JobConfig, Mode, Scheme};
use crate::coordinator::SweepRunner;
use crate::nn::ExecSpec;
use crate::train::network_from_ckpt;
use crate::util::rng::Rng;

/// Experiment scale: quick (default grids, short schedules) or full.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    Quick,
    Full,
}

impl Scale {
    pub fn steps(&self) -> usize {
        match self {
            Scale::Quick => 300,
            Scale::Full => 800,
        }
    }

    pub fn train_size(&self) -> usize {
        match self {
            Scale::Quick => 4096,
            Scale::Full => 8192,
        }
    }

    /// Test-set size for chip-sim (expensive) evaluations.
    pub fn chip_test_size(&self) -> usize {
        match self {
            Scale::Quick => 256,
            Scale::Full => 512,
        }
    }

    pub fn calib_batches(&self) -> usize {
        match self {
            Scale::Quick => 4,
            Scale::Full => 8,
        }
    }
}

/// Base job for an experiment.
pub fn base_job(model: &str, scale: Scale) -> JobConfig {
    JobConfig {
        model: model.into(),
        steps: scale.steps(),
        train_size: scale.train_size(),
        test_size: 512,
        ..Default::default()
    }
}

/// Evaluate a checkpoint on a chip configuration, optionally BN-calibrated
/// (§3.4: calibration uses training data under the *same* non-idealities).
/// Returns top-1 % on `test_size` test images.
pub fn chip_eval(
    runner: &mut SweepRunner,
    outcome: &crate::coordinator::JobOutcome,
    scheme: Scheme,
    unit_channels: usize,
    chip: &ChipModel,
    calibrate: bool,
    calib_batches: usize,
    test_size: usize,
) -> Result<f64> {
    let mut net = network_from_ckpt(runner.manifest(), &outcome.ckpt)?;
    // reuse the sweep's persistent engines: matching layers reprogram in
    // place instead of re-deriving their weight planes per chip point
    net.set_engine_cache(std::mem::take(&mut runner.eval_engines));
    let exec = ExecSpec::Pim { scheme, unit_channels, chip };
    // deterministic noise stream per (chip config, checkpoint)
    let mut rng = Rng::new(0xE7A1 ^ chip.b_pim as u64 ^ ((chip.noise_lsb * 100.0) as u64) << 8);
    let res = (|| {
        // borrow the runner's cached datasets for the evaluation only —
        // no per-point deep clones of the image buffers
        let (train_ds, test_ds) = runner.datasets(&outcome.job)?;
        if calibrate {
            net.calibrate_bn(train_ds, 32, calib_batches, &exec, &mut rng)?;
        }
        let sub = subset(test_ds, test_size);
        net.evaluate(&sub, 32, &exec, &mut rng)
    })();
    runner.eval_engines = net.take_engine_cache();
    res
}

/// First-n subset of a dataset.
pub fn subset(ds: &crate::data::Dataset, n: usize) -> crate::data::Dataset {
    let n = n.min(ds.len());
    crate::data::Dataset {
        images: ds.images[..n].to_vec(),
        labels: ds.labels[..n].to_vec(),
        classes: ds.classes,
    }
}

/// Train (cached) the conventional-QAT baseline for a model.
pub fn baseline_job(model: &str, scale: Scale) -> JobConfig {
    let mut j = base_job(model, scale);
    j.mode = Mode::Baseline;
    j
}

/// Train (cached) a PIM-QAT job.  Low ADC resolutions get a gentler, longer
/// schedule — the severe quantization needs a smaller LR to escape the
/// coarse-grid plateau (the scaled-stack analogue of the paper's 200-epoch
/// budget; calibration sweep in EXPERIMENTS.md §Deviations).
pub fn ours_job(model: &str, scheme: Scheme, uc: usize, b_pim: u32, scale: Scale) -> JobConfig {
    let mut j = base_job(model, scale);
    j.mode = Mode::Ours;
    j.scheme = scheme;
    j.unit_channels = uc;
    j.b_pim_train = b_pim;
    if b_pim <= 4 {
        j.lr = 0.03;
        j.steps = scale.steps() * 3;
    } else if b_pim == 5 {
        j.lr = 0.05;
        j.steps = scale.steps() * 2;
    }
    j
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scales() {
        assert!(Scale::Full.steps() > Scale::Quick.steps());
        assert!(Scale::Full.train_size() > Scale::Quick.train_size());
    }

    #[test]
    fn jobs_cacheable_across_experiments() {
        // Table 3 and Fig. 5 share the native-scheme job — fingerprints match.
        use crate::coordinator::sweep::fingerprint;
        let a = ours_job("tiny", Scheme::Native, 1, 5, Scale::Quick);
        let b = ours_job("tiny", Scheme::Native, 1, 5, Scale::Quick);
        assert_eq!(fingerprint(&a), fingerprint(&b));
    }
}
