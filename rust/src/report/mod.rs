//! Reporting substrate (S13): experiment records rendered as ASCII tables,
//! saved as CSV + JSON under `results/`.

use std::path::{Path, PathBuf};

use crate::util::error::Result;

use crate::util::json::Json;
use crate::util::table::{to_csv, Table};

/// One regenerated table/figure.
pub struct Report {
    /// Experiment id, e.g. "table3", "fig5".
    pub id: String,
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    /// Free-form notes (paper-vs-measured commentary).
    pub notes: Vec<String>,
}

impl Report {
    pub fn new(id: &str, title: &str, headers: &[&str]) -> Self {
        Report {
            id: id.to_string(),
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "report row arity");
        self.rows.push(cells);
    }

    pub fn note(&mut self, s: impl Into<String>) {
        self.notes.push(s.into());
    }

    pub fn render(&self) -> String {
        let mut t = Table::new(&self.headers.iter().map(|s| s.as_str()).collect::<Vec<_>>());
        for r in &self.rows {
            t.row(r);
        }
        let mut out = format!("== {} — {} ==\n{}\n", self.id, self.title, t.render());
        for n in &self.notes {
            out.push_str(&format!("  note: {n}\n"));
        }
        out
    }

    /// Write `<dir>/<id>.csv` and `<dir>/<id>.json`.
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let headers: Vec<&str> = self.headers.iter().map(|s| s.as_str()).collect();
        std::fs::write(dir.join(format!("{}.csv", self.id)), to_csv(&headers, &self.rows))?;
        let j = Json::obj(vec![
            ("id", Json::str(&self.id)),
            ("title", Json::str(&self.title)),
            (
                "headers",
                Json::Arr(self.headers.iter().map(|h| Json::str(h)).collect()),
            ),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| Json::Arr(r.iter().map(|c| Json::str(c)).collect()))
                        .collect(),
                ),
            ),
            (
                "notes",
                Json::Arr(self.notes.iter().map(|n| Json::str(n)).collect()),
            ),
        ]);
        std::fs::write(dir.join(format!("{}.json", self.id)), j.to_string())?;
        Ok(())
    }
}

/// Default results directory ($PIM_QAT_RESULTS or ./results).
pub fn results_dir() -> PathBuf {
    std::env::var_os("PIM_QAT_RESULTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("results"))
}

/// Format a float accuracy as the paper prints them.
pub fn pct(v: f64) -> String {
    format!("{v:.1}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_and_save() {
        let mut r = Report::new("test_exp", "demo", &["a", "b"]);
        r.row(vec!["1".into(), "x".into()]);
        r.note("shape holds");
        let s = r.render();
        assert!(s.contains("test_exp") && s.contains("shape holds"));
        let dir = std::env::temp_dir().join("pimqat_report_test");
        r.save(&dir).unwrap();
        assert!(dir.join("test_exp.csv").exists());
        let j = crate::util::json::parse_file(&dir.join("test_exp.json")).unwrap();
        assert_eq!(j.get("rows").idx(0).idx(1).as_str(), Some("x"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn row_arity() {
        Report::new("x", "y", &["a"]).row(vec![]);
    }
}
