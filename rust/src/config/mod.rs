//! Config substrate (S9): experiment configuration types, presets, and
//! `key=value` override parsing for the CLI.

pub mod rescale;

use std::fmt;
use std::str::FromStr;

/// PIM decomposition scheme (paper §2 / Appendix A1, Table 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scheme {
    Native,
    BitSerial,
    Differential,
}

impl Scheme {
    pub const ALL: [Scheme; 3] = [Scheme::Native, Scheme::BitSerial, Scheme::Differential];

    pub fn as_str(&self) -> &'static str {
        match self {
            Scheme::Native => "native",
            Scheme::BitSerial => "bit_serial",
            Scheme::Differential => "differential",
        }
    }
}

impl FromStr for Scheme {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "native" => Ok(Scheme::Native),
            "bit_serial" | "bitserial" | "bit-serial" => Ok(Scheme::BitSerial),
            "differential" | "diff" => Ok(Scheme::Differential),
            _ => Err(format!("unknown scheme {s:?}")),
        }
    }
}

impl fmt::Display for Scheme {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Training mode (Table 3 rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Mode {
    /// PIM-QAT (this paper).
    Ours,
    /// Conventional QAT (Jin et al. 2020), PIM-unaware.
    Baseline,
    /// Rekhi et al. 2019 additive-noise AMS model.
    Ams,
}

impl Mode {
    pub fn as_str(&self) -> &'static str {
        match self {
            Mode::Ours => "ours",
            Mode::Baseline => "baseline",
            Mode::Ams => "ams",
        }
    }
}

impl FromStr for Mode {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "ours" => Ok(Mode::Ours),
            "baseline" => Ok(Mode::Baseline),
            "ams" => Ok(Mode::Ams),
            _ => Err(format!("unknown mode {s:?}")),
        }
    }
}

impl fmt::Display for Mode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// One training job's configuration (consumed by `crate::train` and produced
/// by presets / the coordinator's sweep grids).
#[derive(Debug, Clone)]
pub struct JobConfig {
    /// Model key in the artifact manifest ("tiny", "small", ...).
    pub model: String,
    pub mode: Mode,
    pub scheme: Scheme,
    pub unit_channels: usize,
    /// Training-time PIM resolution (adjusted-precision training trains at
    /// a resolution ≤ the inference resolution, §3.5).
    pub b_pim_train: u32,
    /// Rescaling-ablation variant tag appended to the artifact name
    /// ("", "nofwd", "norescale").
    pub variant: String,
    /// Override the Table-A1 forward rescale η (the paper notes the best
    /// value is software-version dependent, §A5).
    pub eta_override: Option<f32>,
    pub steps: usize,
    pub lr: f32,
    /// LR decay milestones as fractions of `steps` (paper: 0.5, 0.75).
    pub milestones: (f64, f64),
    pub seed: u64,
    /// Dataset size (synthetic corpus).
    pub train_size: usize,
    pub test_size: usize,
    /// Fault-profile spec for variability-aware training: a preset name
    /// (`mild`/`moderate`/`severe`, optionally `:chip_id`) or a JSON path
    /// understood by `chip::FaultProfile::parse`.  Empty (default) trains
    /// on the paper's clean chip.
    pub faults: String,
}

impl Default for JobConfig {
    fn default() -> Self {
        JobConfig {
            model: "tiny".into(),
            mode: Mode::Ours,
            scheme: Scheme::BitSerial,
            unit_channels: 8,
            b_pim_train: 7,
            variant: String::new(),
            eta_override: None,
            steps: 300,
            lr: 0.1,
            milestones: (0.5, 0.75),
            seed: 0,
            train_size: 2048,
            test_size: 512,
            faults: String::new(),
        }
    }
}

impl JobConfig {
    /// Artifact-set name for this job (mirrors python `artifact_tag`).
    pub fn artifact_name(&self) -> String {
        let base = match self.mode {
            Mode::Ours => format!(
                "{}_train_ours_{}_uc{}",
                self.model, self.scheme, self.unit_channels
            ),
            Mode::Baseline => format!("{}_train_baseline", self.model),
            Mode::Ams => format!("{}_train_ams", self.model),
        };
        if self.variant.is_empty() {
            base
        } else {
            format!("{base}_{}", self.variant)
        }
    }

    /// Apply a `key=value` override; returns Err on unknown key/bad value.
    pub fn set(&mut self, key: &str, value: &str) -> Result<(), String> {
        let bad = |e: String| format!("{key}={value}: {e}");
        match key {
            "model" => self.model = value.to_string(),
            "mode" => self.mode = value.parse().map_err(bad)?,
            "scheme" => self.scheme = value.parse().map_err(bad)?,
            "uc" | "unit_channels" => {
                self.unit_channels = value.parse().map_err(|e| bad(format!("{e}")))?
            }
            "b_pim" | "b_pim_train" => {
                self.b_pim_train = value.parse().map_err(|e| bad(format!("{e}")))?
            }
            "variant" => self.variant = value.to_string(),
            "eta" => {
                self.eta_override = Some(value.parse().map_err(|e| bad(format!("{e}")))?)
            }
            "steps" => self.steps = value.parse().map_err(|e| bad(format!("{e}")))?,
            "lr" => self.lr = value.parse().map_err(|e| bad(format!("{e}")))?,
            "seed" => self.seed = value.parse().map_err(|e| bad(format!("{e}")))?,
            "train_size" => self.train_size = value.parse().map_err(|e| bad(format!("{e}")))?,
            "test_size" => self.test_size = value.parse().map_err(|e| bad(format!("{e}")))?,
            "faults" => self.faults = value.to_string(),
            _ => return Err(format!("unknown config key {key:?}")),
        }
        Ok(())
    }

    /// Parse a list of `key=value` overrides.
    pub fn apply_overrides(&mut self, kvs: &[String]) -> Result<(), String> {
        for kv in kvs {
            let (k, v) = kv
                .split_once('=')
                .ok_or_else(|| format!("expected key=value, got {kv:?}"))?;
            self.set(k.trim(), v.trim())?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_roundtrip() {
        for s in Scheme::ALL {
            assert_eq!(s.as_str().parse::<Scheme>().unwrap(), s);
        }
        assert!("bogus".parse::<Scheme>().is_err());
    }

    #[test]
    fn artifact_names() {
        let mut j = JobConfig::default();
        assert_eq!(j.artifact_name(), "tiny_train_ours_bit_serial_uc8");
        j.mode = Mode::Baseline;
        assert_eq!(j.artifact_name(), "tiny_train_baseline");
        j.mode = Mode::Ours;
        j.variant = "nofwd".into();
        assert_eq!(j.artifact_name(), "tiny_train_ours_bit_serial_uc8_nofwd");
    }

    #[test]
    fn overrides() {
        let mut j = JobConfig::default();
        j.apply_overrides(&[
            "scheme=native".into(),
            "uc=1".into(),
            "b_pim=5".into(),
            "steps=10".into(),
        ])
        .unwrap();
        assert_eq!(j.scheme, Scheme::Native);
        assert_eq!(j.unit_channels, 1);
        assert_eq!(j.b_pim_train, 5);
        assert!(j.apply_overrides(&["nope=1".into()]).is_err());
        assert!(j.apply_overrides(&["steps".into()]).is_err());
    }
}
