//! Forward-rescaling constants η (paper Table A1, §3.3 — see PAPER.md).
//!
//! Rescaling is half of the PIM-QAT training recipe: the forward output of
//! every PIM-mapped matmul is scaled by η (this table) to keep activation
//! statistics in the BN-friendly range despite coarse ADC quantization,
//! and the backward pass is scaled by `ξ = sqrt(VAR[y_PIM]/VAR[y])`
//! (Eqn. 8, computed per layer per step by the backends — see
//! `crate::nn::grad` / `crate::train::native` for the native
//! implementation).  Table A3 ablates both knobs via the job `variant`
//! field ("nofwd", "norescale").
//!
//! The paper states outright that the best η "can even be different for
//! different software package versions" (§A5).  On this stack (jax 0.8 →
//! XLA-CPU, batch 32, the scaled models) the Table-A1 magnitudes (30–1000)
//! destabilize training at low b_PIM, while η ≈ 1 trains every scheme — so
//! `forward_eta` returns the values *tuned for this stack*, and
//! `paper_eta` preserves Table A1 verbatim for reference/pinning.
//! EXPERIMENTS.md §Deviations records the calibration sweep.

use super::Scheme;

/// η tuned for this reproduction stack (used by the trainer).
pub fn forward_eta(scheme: Scheme, b_pim: u32) -> f32 {
    match scheme {
        // bit-serial at 7 bit keeps the paper's near-unity value; everything
        // else trains best at 1.0 here.
        Scheme::BitSerial if b_pim == 7 => 1.03,
        _ => 1.0,
    }
}

/// Table A1 verbatim (the paper's GTX-1080 stack), clamped like the python
/// mirror in `compile/rescale.py`.
pub fn paper_eta(scheme: Scheme, b_pim: u32) -> f32 {
    let b = b_pim.clamp(3, 31);
    match scheme {
        Scheme::Native => match b {
            3 => 100.0,
            4 => 20.0,
            _ => 1.0,
        },
        Scheme::Differential => match b {
            3..=7 => 1000.0,
            _ => 1.0,
        },
        Scheme::BitSerial => match b {
            3 => 100.0,
            4..=6 => 30.0,
            7 => 1.03,
            _ => 1.0,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_table_a1_values() {
        assert_eq!(paper_eta(Scheme::Native, 3), 100.0);
        assert_eq!(paper_eta(Scheme::Native, 4), 20.0);
        assert_eq!(paper_eta(Scheme::Native, 5), 1.0);
        assert_eq!(paper_eta(Scheme::Differential, 3), 1000.0);
        assert_eq!(paper_eta(Scheme::Differential, 7), 1000.0);
        assert_eq!(paper_eta(Scheme::BitSerial, 3), 100.0);
        assert_eq!(paper_eta(Scheme::BitSerial, 4), 30.0);
        assert_eq!(paper_eta(Scheme::BitSerial, 6), 30.0);
        assert_eq!(paper_eta(Scheme::BitSerial, 7), 1.03);
    }

    #[test]
    fn tuned_values_near_unity() {
        for s in Scheme::ALL {
            for b in 3..=10 {
                let eta = forward_eta(s, b);
                assert!((0.5..=2.0).contains(&eta), "{s} b{b}: {eta}");
            }
        }
        assert_eq!(forward_eta(Scheme::BitSerial, 7), 1.03);
    }

    #[test]
    fn paper_extremes() {
        assert_eq!(paper_eta(Scheme::BitSerial, 10), 1.0);
        assert_eq!(paper_eta(Scheme::BitSerial, 2), 100.0);
        assert_eq!(paper_eta(Scheme::Differential, 8), 1.0);
    }
}
