//! PIM MAC engine substrate (S3): the integer-exact model of Eqn. 1 /
//! Appendix A1 that plays the role of the paper's prototype chip.
//!
//! The engine consumes integer activations/weights (the grids the digital
//! quantizers produce), decomposes them per the configured scheme, forms the
//! analog plane sums, pushes every partial sum through the ADC model
//! (`crate::chip`), and recombines digitally.  With an ideal ADC and zero
//! noise it agrees bit-exactly with the jnp/Pallas forward — pinned by the
//! golden cross-tests (rust/tests/golden_cross.rs).

pub mod cache;
pub mod engine;
pub mod layout;

pub use cache::EngineCache;
pub use engine::{pim_grouped_matmul, PimEngine};

use crate::config::Scheme;

/// Quantization bit-widths (mirror of python QuantConfig).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantBits {
    pub b_w: u32,
    pub b_a: u32,
    /// DAC resolution m (input slices of m bits, Eqn. A2).
    pub m: u32,
}

impl Default for QuantBits {
    fn default() -> Self {
        QuantBits { b_w: 4, b_a: 4, m: 4 }
    }
}

impl QuantBits {
    /// Positive full-scale of the weight grid (2^{b_w-1} - 1).
    pub fn w_levels(&self) -> i32 {
        (1 << (self.b_w - 1)) - 1
    }
    /// Full-scale of the activation grid (2^{b_a} - 1).
    pub fn a_levels(&self) -> i32 {
        (1 << self.b_a) - 1
    }
    /// DAC radix Δ = 2^m.
    pub fn delta(&self) -> i32 {
        1 << self.m
    }
    /// Number of input planes b_a / m.
    pub fn n_slices(&self) -> u32 {
        self.b_a / self.m
    }
}

/// Integer full-scale FS of one analog plane sum for a given scheme and
/// group size N (see DESIGN.md): the ADC grid covers [0, FS] ([-FS, FS] for
/// the signed native scheme).
pub fn plane_full_scale(scheme: Scheme, bits: &QuantBits, n: usize) -> f32 {
    let base = (n as i32 * (bits.delta() - 1)) as f32;
    match scheme {
        Scheme::BitSerial => base,
        Scheme::Native | Scheme::Differential => base * bits.w_levels() as f32,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bit_helpers() {
        let q = QuantBits::default();
        assert_eq!(q.w_levels(), 7);
        assert_eq!(q.a_levels(), 15);
        assert_eq!(q.delta(), 16);
        assert_eq!(q.n_slices(), 1);
        let q2 = QuantBits { b_w: 4, b_a: 4, m: 1 };
        assert_eq!(q2.delta(), 2);
        assert_eq!(q2.n_slices(), 4);
    }

    #[test]
    fn full_scale_matches_paper() {
        let q = QuantBits::default();
        // bit-serial N=144: plane sums in [0, 144*15] = [0, 2160] — the paper
        // notes the analog-level count can far exceed the ADC levels (§2).
        assert_eq!(plane_full_scale(Scheme::BitSerial, &q, 144), 2160.0);
        assert_eq!(plane_full_scale(Scheme::Native, &q, 9), 9.0 * 15.0 * 7.0);
    }
}
