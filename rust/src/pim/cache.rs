//! Shared per-layer engine cache (§Perf L3.5/L3.6): one persistent
//! [`PimEngine`] per PIM conv, keyed by layer name, reprogrammed in place
//! when only the weights moved and rebuilt when the geometry did.
//!
//! Both halves of the system use this same keying:
//!
//! * the native trainer's `TrainArena` (one cache per job, weights move
//!   every step), and
//! * the evaluation path (`nn::Network`): chip sweeps evaluate one
//!   checkpoint under many chip configurations — and many checkpoints
//!   under one — so the cache is handed from `Network` to `Network` by the
//!   sweep drivers (`SweepRunner::eval_engines`) instead of re-deriving
//!   every layer's decomposed planes per evaluation.
//!
//! The engine's *weight planes* are chip-independent (the ADC/noise model
//! is applied per `matmul` call), which is why a chip sweep can share one
//! programmed engine across all its configurations.  Since the fault
//! subsystem, an engine may additionally carry a per-replica
//! [`FaultModel`](crate::chip::FaultModel) — its own injured ADC columns —
//! which overrides whatever chip model a `matmul` passes in.  Replica
//! faults are identity, not geometry: they survive in-place reprogramming
//! and are carried over when a geometry change forces a rebuild under the
//! same key.

use std::collections::BTreeMap;

use crate::chip::FaultModel;
use crate::config::Scheme;

use super::layout::plan_groups;
use super::{PimEngine, QuantBits};

/// Persistent per-layer-name engine cache.
#[derive(Default)]
pub struct EngineCache {
    engines: BTreeMap<String, PimEngine>,
    /// Replica identity stamped onto engines created *after*
    /// [`EngineCache::set_faults_all`] ran.  The cache is lazily populated
    /// (a `Network` builds engines on first forward), so a serving replica
    /// binds its fault model before any engine exists — the default makes
    /// that binding stick instead of silently applying to nothing.
    default_faults: Option<FaultModel>,
}

impl EngineCache {
    pub fn new() -> Self {
        EngineCache::default()
    }

    /// Number of cached engines.
    pub fn len(&self) -> usize {
        self.engines.len()
    }

    pub fn is_empty(&self) -> bool {
        self.engines.is_empty()
    }

    /// The cached engine for `name`, if any.
    pub fn get(&self, name: &str) -> Option<&PimEngine> {
        self.engines.get(name)
    }

    /// Mutable access to a cached engine (thread pinning, fault binding).
    pub fn get_mut(&mut self, name: &str) -> Option<&mut PimEngine> {
        self.engines.get_mut(name)
    }

    /// The replica fault model engines built by this cache inherit (the
    /// identity bound by the last [`EngineCache::set_faults_all`]), if any.
    /// The serving health monitor reads this to report which injury a
    /// quarantined replica carries.
    pub fn default_faults(&self) -> Option<&FaultModel> {
        self.default_faults.as_ref()
    }

    /// Bind one replica fault model to every cached engine (a whole farm
    /// node going bad), or clear them all with `None`.  The binding also
    /// becomes the cache's *default*: engines built later by
    /// [`EngineCache::ensure_engine`] inherit it, so binding before the
    /// lazily-populated cache warms up still takes effect.
    pub fn set_faults_all(&mut self, faults: Option<FaultModel>) {
        self.default_faults = faults;
        for e in self.engines.values_mut() {
            e.set_faults(faults);
        }
    }

    /// Make sure the cached engine for layer `name` exists, matches the
    /// layer geometry, and carries the integer weights `w_int`
    /// ([C·k·k, O], im2col column order), then return it.  Cache hit →
    /// in-place [`PimEngine::reprogram`] (groups with unchanged weights
    /// skipped); miss, or a scheme / bits / shape change → fresh
    /// [`PimEngine::prepare_cols`].
    #[allow(clippy::too_many_arguments)]
    pub fn ensure_engine(
        &mut self,
        name: &str,
        scheme: Scheme,
        bits: QuantBits,
        w_int: &[f32],
        out: usize,
        c_in: usize,
        kernel: usize,
        unit_channels: usize,
    ) -> &PimEngine {
        let plan = plan_groups(c_in, kernel, unit_channels);
        let hit = self.engines.get(name).is_some_and(|e| {
            e.scheme == scheme && e.bits == bits && e.out == out && e.plan == plan
        });
        if hit {
            let e = self.engines.get_mut(name).expect("hit checked above");
            e.reprogram(w_int);
            return e;
        }
        let mut engine =
            PimEngine::prepare_cols(scheme, bits, w_int, out, c_in, kernel, unit_channels);
        // a geometry rebuild replaces the planes, not the replica identity;
        // a genuinely fresh engine inherits the cache-wide default replica
        match self.engines.get(name) {
            Some(old) => engine.set_faults(old.faults().copied()),
            None => engine.set_faults(self.default_faults),
        }
        self.engines.insert(name.to_string(), engine);
        self.engines.get(name).expect("just inserted")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chip::ChipModel;
    use crate::util::rng::Rng;

    #[test]
    fn hit_reprograms_miss_rebuilds() {
        let mut cache = EngineCache::new();
        let bits = QuantBits::default();
        let mut rng = Rng::new(4);
        let (c, k, o, uc) = (2usize, 3usize, 4usize, 1usize);
        let w1: Vec<f32> = (0..c * k * k * o).map(|_| rng.int_in(-7, 7) as f32).collect();
        cache.ensure_engine("l0", Scheme::BitSerial, bits, &w1, o, c, k, uc);
        assert_eq!(cache.len(), 1);
        // weight-only change: same engine object, reprogrammed
        let mut w2 = w1.clone();
        w2[0] = if w2[0] > 0.0 { -5.0 } else { 5.0 };
        cache.ensure_engine("l0", Scheme::BitSerial, bits, &w2, o, c, k, uc);
        assert_eq!(cache.len(), 1);
        // the reprogrammed engine matches a fresh prepare bitwise
        let a: Vec<u8> = (0..3 * c * k * k).map(|_| rng.int_in(0, 15) as u8).collect();
        let chip = ChipModel::ideal(7).with_noise(0.4);
        let fresh = PimEngine::prepare_cols(Scheme::BitSerial, bits, &w2, o, c, k, uc);
        let mut y1 = Vec::new();
        let mut y2 = Vec::new();
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        cache.get("l0").unwrap().matmul_u8_into(&a, &chip, &mut r1, &mut y1);
        fresh.matmul_u8_into(&a, &chip, &mut r2, &mut y2);
        assert_eq!(y1, y2);
        // scheme change rebuilds under the same key
        let e = cache.ensure_engine("l0", Scheme::Native, bits, &w2, o, c, k, uc);
        assert_eq!(e.scheme, Scheme::Native);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn rebuild_preserves_replica_faults() {
        use crate::chip::FaultProfile;
        let mut cache = EngineCache::new();
        let bits = QuantBits::default();
        let mut rng = Rng::new(17);
        let (c, k, o, uc) = (2usize, 3usize, 4usize, 1usize);
        let w: Vec<f32> = (0..c * k * k * o).map(|_| rng.int_in(-7, 7) as f32).collect();
        cache.ensure_engine("l0", Scheme::BitSerial, bits, &w, o, c, k, uc);
        let fm = FaultModel::new(FaultProfile::moderate().on_chip(5)).at_step(3);
        cache.get_mut("l0").unwrap().set_faults(Some(fm));
        // weight-only reprogram keeps the faults
        cache.ensure_engine("l0", Scheme::BitSerial, bits, &w, o, c, k, uc);
        assert_eq!(cache.get("l0").unwrap().faults(), Some(&fm));
        // geometry rebuild (scheme change) keeps the replica identity too
        cache.ensure_engine("l0", Scheme::Native, bits, &w, o, c, k, uc);
        assert_eq!(cache.get("l0").unwrap().faults(), Some(&fm));
        cache.set_faults_all(None);
        assert_eq!(cache.get("l0").unwrap().faults(), None);
    }

    #[test]
    fn faults_bound_before_warmup_stick_to_lazily_built_engines() {
        use crate::chip::FaultProfile;
        let mut cache = EngineCache::new();
        let bits = QuantBits::default();
        let mut rng = Rng::new(23);
        let (c, k, o, uc) = (2usize, 3usize, 4usize, 1usize);
        let w: Vec<f32> = (0..c * k * k * o).map(|_| rng.int_in(-7, 7) as f32).collect();
        // bind the replica identity while the cache is still empty — the
        // serving path does exactly this before the first forward
        let fm = FaultModel::new(FaultProfile::mild().on_chip(3)).at_step(0);
        cache.set_faults_all(Some(fm));
        cache.ensure_engine("l0", Scheme::BitSerial, bits, &w, o, c, k, uc);
        assert_eq!(cache.get("l0").unwrap().faults(), Some(&fm));
        // an engine that already carries its own identity is not overwritten
        // by the default on rebuild
        let fm2 = FaultModel::new(FaultProfile::severe().on_chip(9)).at_step(1);
        cache.get_mut("l0").unwrap().set_faults(Some(fm2));
        cache.ensure_engine("l0", Scheme::Native, bits, &w, o, c, k, uc);
        assert_eq!(cache.get("l0").unwrap().faults(), Some(&fm2));
        // clearing resets the default for future engines too
        cache.set_faults_all(None);
        cache.ensure_engine("l1", Scheme::BitSerial, bits, &w, o, c, k, uc);
        assert_eq!(cache.get("l1").unwrap().faults(), None);
    }
}
