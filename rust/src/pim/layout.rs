//! Channel-group layout — rust mirror of `python/compile/pim.py`'s
//! grouped_patches/grouped_weights contract.
//!
//! With the channel-major im2col layout (`tensor::ops::im2col`), a PIM
//! channel group of `uc` input channels occupies a *contiguous* run of
//! ``n = uc * k * k`` columns, so grouping is pure index arithmetic.

/// Largest uc ≤ `unit_channels` dividing `c` (mirror of python
/// `effective_unit_channels`; a narrow early layer maps onto a smaller slice
/// of the analog array).
pub fn effective_unit_channels(c: usize, unit_channels: usize) -> usize {
    let mut uc = unit_channels.min(c).max(1);
    while c % uc != 0 {
        uc -= 1;
    }
    uc
}

/// Group geometry of one conv layer on the PIM array.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GroupPlan {
    /// Channels per group actually used.
    pub uc: usize,
    /// Number of groups G.
    pub groups: usize,
    /// MACs per analog inner product: N = uc * k * k.
    pub n: usize,
}

impl GroupPlan {
    /// Total patch columns G·N.
    pub fn cols(&self) -> usize {
        self.groups * self.n
    }

    /// The contiguous im2col column range of group `g`.
    pub fn col_range(&self, g: usize) -> std::ops::Range<usize> {
        debug_assert!(g < self.groups);
        g * self.n..(g + 1) * self.n
    }

    /// The flat index range of group `g`'s rows in a row-major [cols, out]
    /// weight matrix.  Groups are contiguous row blocks, so one group's
    /// weights are one contiguous slice — what makes in-place
    /// reprogramming and the unchanged-group comparison pure slice ops.
    pub fn weight_range(&self, g: usize, out: usize) -> std::ops::Range<usize> {
        let r = self.col_range(g);
        r.start * out..r.end * out
    }
}

pub fn plan_groups(c_in: usize, kernel: usize, unit_channels: usize) -> GroupPlan {
    let uc = effective_unit_channels(c_in, unit_channels);
    GroupPlan { uc, groups: c_in / uc, n: uc * kernel * kernel }
}

/// u64 words per bit-packed plane row: 64 output columns per word (bit
/// `o % 64` of word `o / 64` ↔ output column `o`).  This is the storage
/// contract between `PimEngine`'s bit-serial weight planes and
/// `tensor::gemm::gemm_acc_u8_bin_packed`; pad bits past `out` in the last
/// word are always zero.
pub fn packed_words(out: usize) -> usize {
    (out + 63) / 64
}

/// Pack a row-major {0,1} u8 plane [k, n] into the bit-packed layout
/// ([`packed_words`] u64 words per row, bit `o % 64` of word `o / 64` ↔
/// column `o`).  The single definition of the packing rule for tests and
/// benches; `PimEngine::program_group` packs directly from two's-complement
/// weights but follows the same contract (pinned by the parity suites).
pub fn pack_bin_plane(bin: &[u8], k: usize, n: usize) -> Vec<u64> {
    assert_eq!(bin.len(), k * n);
    let wpr = packed_words(n);
    let mut packed = vec![0u64; k * wpr];
    for r in 0..k {
        for o in 0..n {
            if bin[r * n + o] != 0 {
                packed[r * wpr + o / 64] |= 1u64 << (o % 64);
            }
        }
    }
    packed
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mirrors_python() {
        assert_eq!(effective_unit_channels(8, 16), 8);
        assert_eq!(effective_unit_channels(32, 16), 16);
        assert_eq!(effective_unit_channels(12, 8), 6);
        assert_eq!(effective_unit_channels(7, 4), 1);
        assert_eq!(effective_unit_channels(1, 1), 1);
    }

    #[test]
    fn col_ranges_tile_the_patch() {
        let p = plan_groups(32, 3, 16);
        assert_eq!(p.cols(), 288);
        assert_eq!(p.col_range(0), 0..144);
        assert_eq!(p.col_range(1), 144..288);
    }

    #[test]
    fn weight_ranges_tile_the_matrix() {
        let p = plan_groups(32, 3, 16);
        let out = 64;
        assert_eq!(p.weight_range(0, out), 0..144 * 64);
        assert_eq!(p.weight_range(1, out), 144 * 64..288 * 64);
    }

    #[test]
    fn packed_words_rounds_up() {
        assert_eq!(packed_words(1), 1);
        assert_eq!(packed_words(63), 1);
        assert_eq!(packed_words(64), 1);
        assert_eq!(packed_words(65), 2);
        assert_eq!(packed_words(128), 2);
        assert_eq!(packed_words(129), 3);
    }

    #[test]
    fn pack_bin_plane_sets_expected_bits() {
        // 2 rows × 66 cols: column 65 lands in bit 1 of the second word
        let mut bin = vec![0u8; 2 * 66];
        bin[0] = 1; // row 0, col 0
        bin[65] = 1; // row 0, col 65
        bin[66 + 63] = 1; // row 1, col 63
        let packed = pack_bin_plane(&bin, 2, 66);
        assert_eq!(packed.len(), 2 * 2);
        assert_eq!(packed[0], 1);
        assert_eq!(packed[1], 1 << 1);
        assert_eq!(packed[2], 1 << 63);
        assert_eq!(packed[3], 0);
    }

    #[test]
    fn plan_n144() {
        // the paper's N=144: unit channel 16, 3x3 kernel
        let p = plan_groups(32, 3, 16);
        assert_eq!(p, GroupPlan { uc: 16, groups: 2, n: 144 });
        // N=72: unit channel 8
        assert_eq!(plan_groups(16, 3, 8).n, 72);
        // native: unit channel 1 → N=9 (matches Table 3)
        assert_eq!(plan_groups(16, 3, 1).n, 9);
    }
}
