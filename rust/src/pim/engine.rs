//! The PIM MAC engine: plane decomposition → analog plane sums (GEMM) →
//! ADC conversion (curve + noise) → digital recombination.
//!
//! Weights are prepared once per layer (`PimEngine::prepare`) into their
//! decomposed form — bit planes for bit-serial, ±halves for differential —
//! mirroring how a chip programs its cell array once and streams inputs.

use crate::chip::ChipModel;
use crate::config::Scheme;
use crate::tensor::gemm::gemm_acc;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

use super::layout::{plan_groups, GroupPlan};
use super::{plane_full_scale, QuantBits};

/// One layer's weights, decomposed for the configured scheme.
#[derive(Debug, Clone)]
enum GroupWeights {
    /// [N, O] signed integer weights (native: multi-bit analog cells).
    Native(Vec<f32>),
    /// Positive and negative halves, each [N, O] of non-negative ints.
    Differential(Vec<f32>, Vec<f32>),
    /// b_w binary planes of [N, O] (bit-serial SRAM cells).
    BitSerial(Vec<Vec<f32>>),
}

/// PIM execution engine for grouped matmuls of one geometry.
#[derive(Debug, Clone)]
pub struct PimEngine {
    pub scheme: Scheme,
    pub bits: QuantBits,
    pub plan: GroupPlan,
    pub out: usize,
    fs: f32,
    groups: Vec<GroupWeights>,
}

impl PimEngine {
    /// Prepare integer weights `w_int` laid out [C*k*k, O] (im2col column
    /// order) for execution. `unit_channels` is the requested group size.
    pub fn prepare(
        scheme: Scheme,
        bits: QuantBits,
        w_int: &Tensor,
        c_in: usize,
        kernel: usize,
        unit_channels: usize,
    ) -> Self {
        assert_eq!(w_int.rank(), 2);
        let cols = w_int.shape[0];
        let out = w_int.shape[1];
        assert_eq!(cols, c_in * kernel * kernel, "weight columns vs c_in*k*k");
        let plan = plan_groups(c_in, kernel, unit_channels);
        let n = plan.n;
        let fs = plane_full_scale(scheme, &bits, n);
        let b_w = bits.b_w;

        let groups = (0..plan.groups)
            .map(|g| {
                let rows = g * n..(g + 1) * n;
                match scheme {
                    Scheme::Native => {
                        let mut w = vec![0.0f32; n * out];
                        for (ri, r) in rows.clone().enumerate() {
                            w[ri * out..(ri + 1) * out]
                                .copy_from_slice(&w_int.data[r * out..(r + 1) * out]);
                        }
                        GroupWeights::Native(w)
                    }
                    Scheme::Differential => {
                        let mut wp = vec![0.0f32; n * out];
                        let mut wn = vec![0.0f32; n * out];
                        for (ri, r) in rows.clone().enumerate() {
                            for o in 0..out {
                                let v = w_int.data[r * out + o];
                                if v > 0.0 {
                                    wp[ri * out + o] = v;
                                } else {
                                    wn[ri * out + o] = -v;
                                }
                            }
                        }
                        GroupWeights::Differential(wp, wn)
                    }
                    Scheme::BitSerial => {
                        let mut planes = vec![vec![0.0f32; n * out]; b_w as usize];
                        for (ri, r) in rows.clone().enumerate() {
                            for o in 0..out {
                                let v = w_int.data[r * out + o] as i32;
                                // two's complement over b_w bits
                                let u = if v < 0 { v + (1 << b_w) } else { v } as u32;
                                for (k, plane) in planes.iter_mut().enumerate() {
                                    plane[ri * out + o] = ((u >> k) & 1) as f32;
                                }
                            }
                        }
                        GroupWeights::BitSerial(planes)
                    }
                }
            })
            .collect();

        PimEngine { scheme, bits, plan, out, fs, groups }
    }

    /// Total MACs per output row (for throughput accounting).
    pub fn macs_per_row(&self) -> usize {
        self.plan.groups * self.plan.n * self.out
    }

    /// Execute the grouped PIM matmul over integer activation patches
    /// [M, C*k*k] (values on the 0..a_levels integer grid, stored as f32).
    /// Output [M, O] is in unit scale (estimate of Σ W̃ q̃).
    pub fn matmul(&self, patches_int: &Tensor, chip: &ChipModel, rng: &mut Rng) -> Tensor {
        let m = patches_int.shape[0];
        let cols = patches_int.shape[1];
        let n = self.plan.n;
        assert_eq!(cols, self.plan.groups * n, "patch columns vs group plan");
        let out = self.out;
        let signed = matches!(self.scheme, Scheme::Native);
        let n_slices = self.bits.n_slices();
        let delta = self.bits.delta();

        let conv = crate::chip::Converter::new(chip, self.fs);
        let mut y = vec![0.0f32; m * out];
        // scratch buffers reused across groups/planes (no alloc in hot loop)
        let mut a_grp = vec![0.0f32; m * n];
        let mut a_plane = vec![0.0f32; m * n];
        let mut s = vec![0.0f32; m * out];

        for (g, gw) in self.groups.iter().enumerate() {
            // gather this group's patch columns into a contiguous block
            for i in 0..m {
                let src = &patches_int.data[i * cols + g * n..i * cols + (g + 1) * n];
                a_grp[i * n..(i + 1) * n].copy_from_slice(src);
            }
            for l in 0..n_slices {
                let slice_w = (delta as f32).powi(l as i32);
                // input DAC plane: (a >> m*l) & (Δ-1), computed on integers
                if n_slices == 1 {
                    a_plane.copy_from_slice(&a_grp);
                } else {
                    let shift = (delta as f32).powi(l as i32);
                    for (dst, &src) in a_plane.iter_mut().zip(&a_grp) {
                        *dst = ((src / shift).floor()) % delta as f32;
                    }
                }
                match gw {
                    GroupWeights::Native(w) => {
                        s.iter_mut().for_each(|v| *v = 0.0);
                        gemm_acc(m, n, out, &a_plane, w, &mut s);
                        for i in 0..m {
                            for o in 0..out {
                                y[i * out + o] += slice_w
                                    * conv.convert(s[i * out + o], o, signed, rng);
                            }
                        }
                    }
                    GroupWeights::Differential(wp, wn) => {
                        s.iter_mut().for_each(|v| *v = 0.0);
                        gemm_acc(m, n, out, &a_plane, wp, &mut s);
                        for i in 0..m {
                            for o in 0..out {
                                y[i * out + o] += slice_w
                                    * conv.convert(s[i * out + o], o, false, rng);
                            }
                        }
                        s.iter_mut().for_each(|v| *v = 0.0);
                        gemm_acc(m, n, out, &a_plane, wn, &mut s);
                        for i in 0..m {
                            for o in 0..out {
                                y[i * out + o] -= slice_w
                                    * conv.convert(s[i * out + o], o, false, rng);
                            }
                        }
                    }
                    GroupWeights::BitSerial(planes) => {
                        for (k, wp) in planes.iter().enumerate() {
                            let sign = if k as u32 == self.bits.b_w - 1 { -1.0 } else { 1.0 };
                            let bit_w = sign * (1u32 << k) as f32 * slice_w;
                            s.iter_mut().for_each(|v| *v = 0.0);
                            gemm_acc(m, n, out, &a_plane, wp, &mut s);
                            for i in 0..m {
                                for o in 0..out {
                                    y[i * out + o] += bit_w
                                        * conv.convert(s[i * out + o], o, false, rng);
                                }
                            }
                        }
                    }
                }
            }
        }

        let denom = (self.bits.w_levels() * self.bits.a_levels()) as f32;
        for v in &mut y {
            *v /= denom;
        }
        Tensor::from_vec(&[m, out], y)
    }
}

/// One-shot convenience: prepare + execute (tests, goldens).
pub fn pim_grouped_matmul(
    scheme: Scheme,
    bits: QuantBits,
    a_int: &Tensor, // [M, G*N]
    w_int: &Tensor, // [G*N, O]
    c_in: usize,
    kernel: usize,
    unit_channels: usize,
    chip: &ChipModel,
    rng: &mut Rng,
) -> Tensor {
    PimEngine::prepare(scheme, bits, w_int, c_in, kernel, unit_channels)
        .matmul(a_int, chip, rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bits() -> QuantBits {
        QuantBits::default()
    }

    /// Loop-level reimplementation of one group/one output (the ref.py shape)
    /// for an ideal chip — a second, independent implementation inside rust.
    fn ref_one(a: &[f32], w: &[f32], scheme: Scheme, b_pim: u32, q: &QuantBits) -> f32 {
        let n = a.len();
        let levels = ((1u32 << b_pim) - 1) as f32;
        let fs = plane_full_scale(scheme, q, n);
        let lsb = fs / levels;
        let adc = |s: f32| crate::chip::round_ties_even(s / lsb) * lsb;
        let mut y = 0.0f32;
        match scheme {
            Scheme::Native => {
                let s: f32 = a.iter().zip(w).map(|(x, y)| x * y).sum();
                y += adc(s);
            }
            Scheme::Differential => {
                let sp: f32 = a.iter().zip(w).map(|(x, y)| x * y.max(0.0)).sum();
                let sn: f32 = a.iter().zip(w).map(|(x, y)| x * (-y).max(0.0)).sum();
                y += adc(sp) - adc(sn);
            }
            Scheme::BitSerial => {
                for k in 0..q.b_w {
                    let sign = if k == q.b_w - 1 { -1.0 } else { 1.0 };
                    let s: f32 = a
                        .iter()
                        .zip(w)
                        .map(|(x, wv)| {
                            let v = *wv as i32;
                            let u = if v < 0 { v + (1 << q.b_w) } else { v } as u32;
                            x * ((u >> k) & 1) as f32
                        })
                        .sum();
                    y += sign * (1u32 << k) as f32 * adc(s);
                }
            }
        }
        y / (q.w_levels() * q.a_levels()) as f32
    }

    #[test]
    fn engine_matches_inline_ref_all_schemes() {
        let q = bits();
        let mut rng = Rng::new(42);
        for scheme in [Scheme::Native, Scheme::BitSerial, Scheme::Differential] {
            for &b_pim in &[3u32, 5, 7] {
                let (m, c, k, o, uc) = (5usize, 2usize, 3usize, 4usize, 2usize);
                let n = uc * k * k;
                let cols = c * k * k;
                let a = Tensor::from_vec(
                    &[m, cols],
                    (0..m * cols).map(|_| rng.int_in(0, 15) as f32).collect(),
                );
                let w = Tensor::from_vec(
                    &[cols, o],
                    (0..cols * o).map(|_| rng.int_in(-7, 7) as f32).collect(),
                );
                let chip = ChipModel::ideal(b_pim);
                let mut nrng = Rng::new(0);
                let y = pim_grouped_matmul(scheme, q, &a, &w, c, k, uc, &chip, &mut nrng);
                // independent reference, group by group
                let groups = cols / n;
                for i in 0..m {
                    for oi in 0..o {
                        let mut want = 0.0;
                        for g in 0..groups {
                            let arow: Vec<f32> =
                                (0..n).map(|j| a.data[i * cols + g * n + j]).collect();
                            let wcol: Vec<f32> =
                                (0..n).map(|j| w.data[(g * n + j) * o + oi]).collect();
                            want += ref_one(&arow, &wcol, scheme, b_pim, &q);
                        }
                        let got = y.data[i * o + oi];
                        assert!(
                            (got - want).abs() < 1e-5,
                            "{scheme} b{b_pim} [{i},{oi}]: {got} vs {want}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn infinite_resolution_recovers_exact_product() {
        let q = bits();
        let mut rng = Rng::new(1);
        let (m, c, k, o, uc) = (4usize, 4usize, 3usize, 3usize, 2usize);
        let cols = c * k * k;
        let a = Tensor::from_vec(
            &[m, cols],
            (0..m * cols).map(|_| rng.int_in(0, 15) as f32).collect(),
        );
        let w = Tensor::from_vec(
            &[cols, o],
            (0..cols * o).map(|_| rng.int_in(-7, 7) as f32).collect(),
        );
        let chip = ChipModel::ideal(24);
        let mut nrng = Rng::new(0);
        let y = pim_grouped_matmul(Scheme::BitSerial, q, &a, &w, c, k, uc, &chip, &mut nrng);
        for i in 0..m {
            for oi in 0..o {
                let exact: f32 = (0..cols)
                    .map(|j| a.data[i * cols + j] * w.data[j * o + oi])
                    .sum::<f32>()
                    / 105.0;
                assert!((y.data[i * o + oi] - exact).abs() < 2e-3);
            }
        }
    }

    #[test]
    fn noise_changes_output_determinately() {
        let q = bits();
        let mut rng = Rng::new(2);
        let a = Tensor::from_vec(&[2, 9], (0..18).map(|_| rng.int_in(0, 15) as f32).collect());
        let w = Tensor::from_vec(&[9, 2], (0..18).map(|_| rng.int_in(-7, 7) as f32).collect());
        let chip = ChipModel::ideal(7).with_noise(0.5);
        let run = |seed| {
            let mut r = Rng::new(seed);
            pim_grouped_matmul(Scheme::BitSerial, q, &a, &w, 1, 3, 1, &chip, &mut r)
        };
        assert_eq!(run(3), run(3), "same seed, same output");
        assert_ne!(run(3), run(4), "different noise stream differs");
    }

    #[test]
    fn m1_dac_slices() {
        // m=1 (binary DAC): 4 input planes; must still match high-res exact.
        let q = QuantBits { b_w: 4, b_a: 4, m: 1 };
        let mut rng = Rng::new(5);
        let a = Tensor::from_vec(&[3, 9], (0..27).map(|_| rng.int_in(0, 15) as f32).collect());
        let w = Tensor::from_vec(&[9, 2], (0..18).map(|_| rng.int_in(-7, 7) as f32).collect());
        let chip = ChipModel::ideal(24);
        let mut nrng = Rng::new(0);
        let y = pim_grouped_matmul(Scheme::BitSerial, q, &a, &w, 1, 3, 1, &chip, &mut nrng);
        for i in 0..3 {
            for oi in 0..2 {
                let exact: f32 = (0..9)
                    .map(|j| a.data[i * 9 + j] * w.data[j * 2 + oi])
                    .sum::<f32>()
                    / 105.0;
                assert!((y.data[i * 2 + oi] - exact).abs() < 2e-3);
            }
        }
    }
}
