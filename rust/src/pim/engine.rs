//! The PIM MAC engine: plane decomposition → analog plane sums (integer
//! GEMM) → ADC conversion (curve + noise) → digital recombination.
//!
//! Weights are prepared once per layer (`PimEngine::prepare`) into their
//! decomposed form — bit planes for bit-serial, ±halves for differential —
//! mirroring how a chip programs its cell array once and streams inputs.
//!
//! §Perf (EXPERIMENTS.md): the execution path is integer-native and
//! multi-threaded.  Activations arrive on the u8 grid
//! ([`PimEngine::matmul_u8_into`]), DAC input planes are extracted with
//! shifts/masks, plane sums accumulate in i32 (exact, so bit-identical to
//! the seed float path) through the runtime-dispatched kernel table
//! (`tensor::kernels`, §Perf L3.6) — bit-serial weight planes are stored
//! bit-packed (64 columns per u64 word, `layout::packed_words`) and run on
//! the broadcast-AND-accumulate kernel — conversion runs row-batched through
//! `Converter::convert_row`, and rows are partitioned across the shared
//! worker pool (`util::pool`) with per-thread scratch buffers from a
//! reusable arena.  Thermal noise comes from a counter-based RNG addressed
//! by (group, plane, row, column) — see DESIGN.md §RNG contract — which is
//! what makes the output bit-identical at any thread count.
//!
//! Engines are persistent: [`PimEngine::prepare`] decomposes the weights
//! once, and [`PimEngine::reprogram`] rewrites the group buffers in place
//! on later steps, skipping groups whose integer weights did not change —
//! the engine-cache half of §Perf L3.5.

use std::fmt;
use std::sync::Mutex;

use crate::chip::{ChipModel, Converter, FaultModel};
use crate::config::Scheme;
use crate::tensor::gemm::{gemm_acc_u8_bin_packed, gemm_acc_u8_i16};
use crate::tensor::Tensor;
use crate::util::rng::{CounterRng, Rng};

use super::layout::{packed_words, plan_groups, GroupPlan};
use super::{plane_full_scale, QuantBits};

/// One layer's weights, decomposed for the configured scheme, on integer
/// grids (i16 analog cells, u8 bit planes).
#[derive(Debug, Clone)]
enum GroupWeights {
    /// [N, O] signed integer weights (native: multi-bit analog cells).
    Native(Vec<i16>),
    /// Positive and negative halves, each [N, O] of non-negative ints.
    Differential(Vec<i16>, Vec<i16>),
    /// b_w binary planes, each bit-packed [N, packed_words(O)] — 64 output
    /// columns per u64 word (`layout::packed_words`), 8× less weight
    /// traffic than one u8 per cell.  Pad bits past O are always zero.
    BitSerial(Vec<Vec<u64>>),
}

/// Reusable per-thread scratch: group activations, one DAC plane, and the
/// i32 plane-sum block.  Pooled on the engine so repeated `matmul` calls
/// (training-scale evaluation) do not reallocate.
#[derive(Default)]
struct Scratch {
    a_grp: Vec<u8>,
    a_plane: Vec<u8>,
    s: Vec<i32>,
}

struct ScratchPool(Mutex<Vec<Scratch>>);

impl ScratchPool {
    fn new() -> Self {
        ScratchPool(Mutex::new(Vec::new()))
    }

    fn take(&self) -> Scratch {
        self.0.lock().unwrap().pop().unwrap_or_default()
    }

    fn put(&self, s: Scratch) {
        self.0.lock().unwrap().push(s);
    }
}

/// PIM execution engine for grouped matmuls of one geometry.
pub struct PimEngine {
    pub scheme: Scheme,
    pub bits: QuantBits,
    pub plan: GroupPlan,
    pub out: usize,
    fs: f32,
    /// Worker threads for `matmul`: 0 = auto ($PIM_QAT_THREADS or the
    /// available parallelism).
    threads: usize,
    groups: Vec<GroupWeights>,
    /// The raw integer weights last programmed, flat [cols·out] — what
    /// `reprogram` compares against to skip unchanged groups.
    w_cache: Vec<i16>,
    /// Per-replica degradation: when set, this engine converts through its
    /// own injured ADC columns, overriding any `ChipModel`-level fault model
    /// passed to `matmul` — the substrate for a chip farm where replicas of
    /// one layer sit on physically distinct (differently injured) chips.
    faults: Option<FaultModel>,
    scratch: ScratchPool,
}

impl Clone for PimEngine {
    fn clone(&self) -> Self {
        PimEngine {
            scheme: self.scheme,
            bits: self.bits,
            plan: self.plan,
            out: self.out,
            fs: self.fs,
            threads: self.threads,
            groups: self.groups.clone(),
            w_cache: self.w_cache.clone(),
            faults: self.faults,
            scratch: ScratchPool::new(),
        }
    }
}

impl fmt::Debug for PimEngine {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("PimEngine")
            .field("scheme", &self.scheme)
            .field("bits", &self.bits)
            .field("plan", &self.plan)
            .field("out", &self.out)
            .field("fs", &self.fs)
            .field("threads", &self.threads)
            .field("groups", &self.groups.len())
            .field("faults", &self.faults)
            .finish()
    }
}

impl PimEngine {
    /// Prepare integer weights `w_int` laid out [C*k*k, O] (im2col column
    /// order) for execution. `unit_channels` is the requested group size.
    pub fn prepare(
        scheme: Scheme,
        bits: QuantBits,
        w_int: &Tensor,
        c_in: usize,
        kernel: usize,
        unit_channels: usize,
    ) -> Self {
        assert_eq!(w_int.rank(), 2);
        assert_eq!(w_int.shape[0], c_in * kernel * kernel, "weight columns vs c_in*k*k");
        Self::prepare_cols(scheme, bits, &w_int.data, w_int.shape[1], c_in, kernel, unit_channels)
    }

    /// [`PimEngine::prepare`] from a raw row-major [C·k·k, O] slice —
    /// arena callers keep the quantized weights in a pooled buffer instead
    /// of building a `Tensor`.
    pub fn prepare_cols(
        scheme: Scheme,
        bits: QuantBits,
        w_int: &[f32],
        out: usize,
        c_in: usize,
        kernel: usize,
        unit_channels: usize,
    ) -> Self {
        assert!(bits.b_a <= 8, "u8 activation grid needs b_a <= 8");
        let plan = plan_groups(c_in, kernel, unit_channels);
        assert_eq!(w_int.len(), plan.cols() * out, "weight size vs group plan");
        let n = plan.n;
        let fs = plane_full_scale(scheme, &bits, n);
        let groups = (0..plan.groups)
            .map(|_| match scheme {
                Scheme::Native => GroupWeights::Native(vec![0i16; n * out]),
                Scheme::Differential => {
                    GroupWeights::Differential(vec![0i16; n * out], vec![0i16; n * out])
                }
                Scheme::BitSerial => {
                    let wpr = packed_words(out);
                    GroupWeights::BitSerial(vec![vec![0u64; n * wpr]; bits.b_w as usize])
                }
            })
            .collect();
        let mut engine = PimEngine {
            scheme,
            bits,
            plan,
            out,
            fs,
            threads: 0,
            groups,
            w_cache: vec![0i16; plan.cols() * out],
            faults: None,
            scratch: ScratchPool::new(),
        };
        for g in 0..engine.plan.groups {
            engine.program_group(g, w_int);
        }
        engine
    }

    /// Reprogram the weight planes in place for this step's integer
    /// weights `w_int` (same [C·k·k, O] layout as [`PimEngine::prepare`]).
    /// Groups whose integer weights are unchanged since the last
    /// (re)programming are skipped — the common case late in low-`b_w`
    /// training, where most quantized weights stop moving.  Returns the
    /// number of groups rewritten.
    ///
    /// The result is bitwise identical to a fresh `prepare` with the same
    /// weights (pinned by `tests/engine_parity.rs`).  Geometry, scheme and
    /// bit widths are fixed at `prepare` time — changing those needs a new
    /// engine (see DESIGN.md §Engine cache).
    pub fn reprogram(&mut self, w_int: &[f32]) -> usize {
        assert_eq!(w_int.len(), self.plan.cols() * self.out, "weight size vs group plan");
        let out = self.out;
        let mut rewritten = 0;
        for g in 0..self.plan.groups {
            let wr = self.plan.weight_range(g, out);
            let unchanged =
                self.w_cache[wr.clone()].iter().zip(&w_int[wr]).all(|(&c, &v)| c == v as i16);
            if unchanged {
                continue;
            }
            self.program_group(g, w_int);
            rewritten += 1;
        }
        rewritten
    }

    /// (Re)write group `g`'s decomposed weight buffers — and its slice of
    /// the raw-weight cache — from the full [cols·out] weight slice.
    fn program_group(&mut self, g: usize, w_int: &[f32]) {
        let out = self.out;
        let n = self.plan.n;
        let b_w = self.bits.b_w;
        let wr = self.plan.weight_range(g, out);
        let src = &w_int[wr.clone()];
        for (c, &v) in self.w_cache[wr].iter_mut().zip(src) {
            *c = v as i16;
        }
        match &mut self.groups[g] {
            GroupWeights::Native(w) => {
                for (d, &v) in w.iter_mut().zip(src) {
                    *d = v as i16;
                }
            }
            GroupWeights::Differential(wp, wn) => {
                for i in 0..n * out {
                    let v = src[i];
                    if v > 0.0 {
                        wp[i] = v as i16;
                        wn[i] = 0;
                    } else {
                        wp[i] = 0;
                        wn[i] = (-v) as i16;
                    }
                }
            }
            GroupWeights::BitSerial(planes) => {
                let wpr = packed_words(out);
                for plane in planes.iter_mut() {
                    plane.iter_mut().for_each(|w| *w = 0);
                }
                for r in 0..n {
                    for o in 0..out {
                        let v = src[r * out + o] as i32;
                        // two's complement over b_w bits
                        let u = if v < 0 { v + (1 << b_w) } else { v } as u32;
                        if u == 0 {
                            continue;
                        }
                        let word = r * wpr + o / 64;
                        let bit = 1u64 << (o % 64);
                        for (k, plane) in planes.iter_mut().enumerate() {
                            if (u >> k) & 1 == 1 {
                                plane[word] |= bit;
                            }
                        }
                    }
                }
            }
        }
    }

    /// Pin the worker-thread count (0 = auto).  Outputs are bit-identical
    /// at every thread count; this only controls the row partitioning.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Bind (or clear) this replica's fault model.  Takes precedence over
    /// `chip.faults` in [`PimEngine::matmul`]; survives `reprogram` and the
    /// engine cache's geometry-change rebuild.
    pub fn set_faults(&mut self, faults: Option<FaultModel>) {
        self.faults = faults;
    }

    /// This replica's fault model, if any.
    pub fn faults(&self) -> Option<&FaultModel> {
        self.faults.as_ref()
    }

    /// Total MACs per output row (for throughput accounting).
    pub fn macs_per_row(&self) -> usize {
        self.plan.groups * self.plan.n * self.out
    }

    fn effective_threads(&self, rows: usize) -> usize {
        crate::tensor::ops::resolve_threads(self.threads).min(rows).max(1)
    }

    /// Execute the grouped PIM matmul over integer activation patches
    /// [M, C*k*k] (values on the 0..a_levels integer grid, stored as f32).
    /// Output [M, O] is in unit scale (estimate of Σ W̃ q̃).  Convenience
    /// wrapper over [`PimEngine::matmul_u8_into`] — the training hot loop
    /// quantizes into a reused u8 buffer instead.
    ///
    /// `rng` seeds the thermal-noise field: when the chip has noise, one
    /// draw is taken and every noise sample becomes a pure function of
    /// (that seed, group, plane, row, column).  Same seed → same output,
    /// for any thread count.
    pub fn matmul(&self, patches_int: &Tensor, chip: &ChipModel, rng: &mut Rng) -> Tensor {
        let m = patches_int.shape[0];
        assert_eq!(patches_int.shape[1], self.plan.cols(), "patch columns vs group plan");
        let a8: Vec<u8> = patches_int.data.iter().map(|&v| v as u8).collect();
        let mut y = Vec::new();
        self.matmul_u8_into(&a8, chip, rng, &mut y);
        Tensor::from_vec(&[m, self.out], y)
    }

    /// The allocation-free execution core: grouped PIM matmul over u8
    /// activation patches (row-major [M, C·k·k] on the 0..a_levels grid),
    /// writing the [M, O] unit-scale output into `y` (cleared and resized
    /// — no allocation once the buffer has grown).  Noise contract is that
    /// of [`PimEngine::matmul`]; rows fan out across the shared worker
    /// pool.
    pub fn matmul_u8_into(
        &self,
        patches: &[u8],
        chip: &ChipModel,
        rng: &mut Rng,
        y: &mut Vec<f32>,
    ) {
        let cols = self.plan.cols();
        assert!(cols > 0 && patches.len() % cols == 0, "patch columns vs group plan");
        let m = patches.len() / cols;
        let out = self.out;

        // per-replica faults win over the chip-level model; either way the
        // compiled per-column view is built once here (single-threaded) and
        // shared read-only by the row workers — bit-identical at any thread
        // count.
        let faults = self.faults.as_ref().or(chip.faults.as_ref());
        let conv = Converter::with_faults(chip, self.fs, out, faults);
        let noise = if chip.noise_lsb > 0.0 {
            Some((CounterRng::new(rng.next_u64()), chip.noise_lsb))
        } else {
            None
        };

        y.clear();
        y.resize(m * out, 0.0);
        let threads = self.effective_threads(m);
        if threads <= 1 {
            self.run_rows(patches, 0, m, &conv, noise.as_ref(), y);
        } else {
            let chunk = (m + threads - 1) / threads;
            let mut jobs: Vec<crate::util::pool::ScopedJob<'_>> = Vec::with_capacity(threads);
            for (ti, ych) in y.chunks_mut(chunk * out).enumerate() {
                let conv = &conv;
                let noise = noise.as_ref();
                jobs.push(Box::new(move || {
                    let rows = ych.len() / out;
                    self.run_rows(patches, ti * chunk, rows, conv, noise, ych);
                }));
            }
            crate::util::pool::run_scoped(jobs);
        }

        let denom = (self.bits.w_levels() * self.bits.a_levels()) as f32;
        for v in y.iter_mut() {
            *v /= denom;
        }
    }

    /// Process rows [row0, row0+rows): gather each group's u8 columns,
    /// extract DAC planes with shift/mask, form i32 plane sums, and
    /// convert row-batched.  One worker's share of the matmul.
    fn run_rows(
        &self,
        patches: &[u8],
        row0: usize,
        rows: usize,
        conv: &Converter,
        noise: Option<&(CounterRng, f32)>,
        y: &mut [f32],
    ) {
        let n = self.plan.n;
        let out = self.out;
        let cols = self.plan.cols();
        let n_slices = self.bits.n_slices();
        let delta = self.bits.delta();
        let mask = (delta - 1) as u8;

        let mut sc = self.scratch.take();
        sc.a_grp.clear();
        sc.a_grp.resize(rows * n, 0);
        sc.a_plane.clear();
        sc.a_plane.resize(rows * n, 0);
        sc.s.clear();
        sc.s.resize(rows * out, 0);

        for (g, gw) in self.groups.iter().enumerate() {
            let crange = self.plan.col_range(g);
            // gather this group's patch columns (already on the u8 grid)
            for i in 0..rows {
                let base = (row0 + i) * cols;
                sc.a_grp[i * n..(i + 1) * n]
                    .copy_from_slice(&patches[base + crange.start..base + crange.end]);
            }
            for l in 0..n_slices {
                let slice_w = (delta as f32).powi(l as i32);
                // input DAC plane: (a >> m·l) & (Δ-1), pure shift/mask
                if n_slices == 1 {
                    sc.a_plane.copy_from_slice(&sc.a_grp);
                } else {
                    let shift = self.bits.m * l;
                    for (d, &v) in sc.a_plane.iter_mut().zip(&sc.a_grp) {
                        *d = (v >> shift) & mask;
                    }
                }
                match gw {
                    GroupWeights::Native(w) => {
                        sc.s.fill(0);
                        gemm_acc_u8_i16(rows, n, out, &sc.a_plane, w, &mut sc.s);
                        self.convert_block(
                            conv, noise, g, l as usize, row0, rows, &sc.s, slice_w, true, y,
                        );
                    }
                    GroupWeights::Differential(wp, wn) => {
                        sc.s.fill(0);
                        gemm_acc_u8_i16(rows, n, out, &sc.a_plane, wp, &mut sc.s);
                        self.convert_block(
                            conv,
                            noise,
                            g,
                            2 * l as usize,
                            row0,
                            rows,
                            &sc.s,
                            slice_w,
                            false,
                            y,
                        );
                        sc.s.fill(0);
                        gemm_acc_u8_i16(rows, n, out, &sc.a_plane, wn, &mut sc.s);
                        self.convert_block(
                            conv,
                            noise,
                            g,
                            2 * l as usize + 1,
                            row0,
                            rows,
                            &sc.s,
                            -slice_w,
                            false,
                            y,
                        );
                    }
                    GroupWeights::BitSerial(planes) => {
                        for (k, wp) in planes.iter().enumerate() {
                            let sign = if k as u32 == self.bits.b_w - 1 { -1.0 } else { 1.0 };
                            let bit_w = sign * (1u32 << k) as f32 * slice_w;
                            sc.s.fill(0);
                            gemm_acc_u8_bin_packed(rows, n, out, &sc.a_plane, wp, &mut sc.s);
                            let plane = l as usize * self.bits.b_w as usize + k;
                            self.convert_block(
                                conv, noise, g, plane, row0, rows, &sc.s, bit_w, false, y,
                            );
                        }
                    }
                }
            }
        }
        self.scratch.put(sc);
    }

    /// Convert a [rows, out] block of plane sums, accumulating
    /// `coef · adc(s)` into `y`.  `plane` is the conversion's index within
    /// the group (unique per DAC slice / bit plane / differential half), so
    /// the noise position key (group, plane, absolute row, column) never
    /// collides.
    #[allow(clippy::too_many_arguments)]
    fn convert_block(
        &self,
        conv: &Converter,
        noise: Option<&(CounterRng, f32)>,
        g: usize,
        plane: usize,
        row0: usize,
        rows: usize,
        s: &[i32],
        coef: f32,
        signed: bool,
        y: &mut [f32],
    ) {
        let out = self.out;
        for i in 0..rows {
            let srow = &s[i * out..(i + 1) * out];
            let yrow = &mut y[i * out..(i + 1) * out];
            match noise {
                Some((field, sigma)) => {
                    let stream = field.stream3(g as u64, plane as u64, (row0 + i) as u64);
                    conv.convert_row(srow, signed, coef, Some((&stream, *sigma)), yrow);
                }
                None => conv.convert_row(srow, signed, coef, None, yrow),
            }
        }
    }
}

/// One-shot convenience: prepare + execute (tests, goldens).
#[allow(clippy::too_many_arguments)]
pub fn pim_grouped_matmul(
    scheme: Scheme,
    bits: QuantBits,
    a_int: &Tensor, // [M, G*N]
    w_int: &Tensor, // [G*N, O]
    c_in: usize,
    kernel: usize,
    unit_channels: usize,
    chip: &ChipModel,
    rng: &mut Rng,
) -> Tensor {
    PimEngine::prepare(scheme, bits, w_int, c_in, kernel, unit_channels)
        .matmul(a_int, chip, rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bits() -> QuantBits {
        QuantBits::default()
    }

    /// Loop-level reimplementation of one group/one output (the ref.py shape)
    /// for an ideal chip — a second, independent implementation inside rust.
    fn ref_one(a: &[f32], w: &[f32], scheme: Scheme, b_pim: u32, q: &QuantBits) -> f32 {
        let n = a.len();
        let levels = ((1u32 << b_pim) - 1) as f32;
        let fs = plane_full_scale(scheme, q, n);
        let lsb = fs / levels;
        let adc = |s: f32| crate::chip::round_ties_even(s / lsb) * lsb;
        let mut y = 0.0f32;
        match scheme {
            Scheme::Native => {
                let s: f32 = a.iter().zip(w).map(|(x, y)| x * y).sum();
                y += adc(s);
            }
            Scheme::Differential => {
                let sp: f32 = a.iter().zip(w).map(|(x, y)| x * y.max(0.0)).sum();
                let sn: f32 = a.iter().zip(w).map(|(x, y)| x * (-y).max(0.0)).sum();
                y += adc(sp) - adc(sn);
            }
            Scheme::BitSerial => {
                for k in 0..q.b_w {
                    let sign = if k == q.b_w - 1 { -1.0 } else { 1.0 };
                    let s: f32 = a
                        .iter()
                        .zip(w)
                        .map(|(x, wv)| {
                            let v = *wv as i32;
                            let u = if v < 0 { v + (1 << q.b_w) } else { v } as u32;
                            x * ((u >> k) & 1) as f32
                        })
                        .sum();
                    y += sign * (1u32 << k) as f32 * adc(s);
                }
            }
        }
        y / (q.w_levels() * q.a_levels()) as f32
    }

    #[test]
    fn engine_matches_inline_ref_all_schemes() {
        let q = bits();
        let mut rng = Rng::new(42);
        for scheme in [Scheme::Native, Scheme::BitSerial, Scheme::Differential] {
            for &b_pim in &[3u32, 5, 7] {
                let (m, c, k, o, uc) = (5usize, 2usize, 3usize, 4usize, 2usize);
                let n = uc * k * k;
                let cols = c * k * k;
                let a = Tensor::from_vec(
                    &[m, cols],
                    (0..m * cols).map(|_| rng.int_in(0, 15) as f32).collect(),
                );
                let w = Tensor::from_vec(
                    &[cols, o],
                    (0..cols * o).map(|_| rng.int_in(-7, 7) as f32).collect(),
                );
                let chip = ChipModel::ideal(b_pim);
                let mut nrng = Rng::new(0);
                let y = pim_grouped_matmul(scheme, q, &a, &w, c, k, uc, &chip, &mut nrng);
                // independent reference, group by group
                let groups = cols / n;
                for i in 0..m {
                    for oi in 0..o {
                        let mut want = 0.0;
                        for g in 0..groups {
                            let arow: Vec<f32> =
                                (0..n).map(|j| a.data[i * cols + g * n + j]).collect();
                            let wcol: Vec<f32> =
                                (0..n).map(|j| w.data[(g * n + j) * o + oi]).collect();
                            want += ref_one(&arow, &wcol, scheme, b_pim, &q);
                        }
                        let got = y.data[i * o + oi];
                        assert!(
                            (got - want).abs() < 1e-5,
                            "{scheme} b{b_pim} [{i},{oi}]: {got} vs {want}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn infinite_resolution_recovers_exact_product() {
        let q = bits();
        let mut rng = Rng::new(1);
        let (m, c, k, o, uc) = (4usize, 4usize, 3usize, 3usize, 2usize);
        let cols = c * k * k;
        let a = Tensor::from_vec(
            &[m, cols],
            (0..m * cols).map(|_| rng.int_in(0, 15) as f32).collect(),
        );
        let w = Tensor::from_vec(
            &[cols, o],
            (0..cols * o).map(|_| rng.int_in(-7, 7) as f32).collect(),
        );
        let chip = ChipModel::ideal(24);
        let mut nrng = Rng::new(0);
        let y = pim_grouped_matmul(Scheme::BitSerial, q, &a, &w, c, k, uc, &chip, &mut nrng);
        for i in 0..m {
            for oi in 0..o {
                let exact: f32 = (0..cols)
                    .map(|j| a.data[i * cols + j] * w.data[j * o + oi])
                    .sum::<f32>()
                    / 105.0;
                assert!((y.data[i * o + oi] - exact).abs() < 2e-3);
            }
        }
    }

    #[test]
    fn noise_changes_output_determinately() {
        let q = bits();
        let mut rng = Rng::new(2);
        let a = Tensor::from_vec(&[2, 9], (0..18).map(|_| rng.int_in(0, 15) as f32).collect());
        let w = Tensor::from_vec(&[9, 2], (0..18).map(|_| rng.int_in(-7, 7) as f32).collect());
        let chip = ChipModel::ideal(7).with_noise(0.5);
        let run = |seed| {
            let mut r = Rng::new(seed);
            pim_grouped_matmul(Scheme::BitSerial, q, &a, &w, 1, 3, 1, &chip, &mut r)
        };
        assert_eq!(run(3), run(3), "same seed, same output");
        assert_ne!(run(3), run(4), "different noise stream differs");
    }

    #[test]
    fn m1_dac_slices() {
        // m=1 (binary DAC): 4 input planes; must still match high-res exact.
        let q = QuantBits { b_w: 4, b_a: 4, m: 1 };
        let mut rng = Rng::new(5);
        let a = Tensor::from_vec(&[3, 9], (0..27).map(|_| rng.int_in(0, 15) as f32).collect());
        let w = Tensor::from_vec(&[9, 2], (0..18).map(|_| rng.int_in(-7, 7) as f32).collect());
        let chip = ChipModel::ideal(24);
        let mut nrng = Rng::new(0);
        let y = pim_grouped_matmul(Scheme::BitSerial, q, &a, &w, 1, 3, 1, &chip, &mut nrng);
        for i in 0..3 {
            for oi in 0..2 {
                let exact: f32 = (0..9)
                    .map(|j| a.data[i * 9 + j] * w.data[j * 2 + oi])
                    .sum::<f32>()
                    / 105.0;
                assert!((y.data[i * 2 + oi] - exact).abs() < 2e-3);
            }
        }
    }

    #[test]
    fn reprogram_skips_unchanged_groups_and_matches_prepare() {
        let q = bits();
        let mut rng = Rng::new(9);
        let (c, k, o, uc) = (4usize, 3usize, 3usize, 2usize); // 2 groups
        let cols = c * k * k;
        let w1 = Tensor::from_vec(
            &[cols, o],
            (0..cols * o).map(|_| rng.int_in(-7, 7) as f32).collect(),
        );
        let mut w2 = w1.clone();
        // flip one weight in the LAST group only
        let flip = (cols - 1) * o;
        w2.data[flip] = if w2.data[flip] > 0.0 { -7.0 } else { 7.0 };
        for scheme in [Scheme::Native, Scheme::Differential, Scheme::BitSerial] {
            let mut engine = PimEngine::prepare(scheme, q, &w1, c, k, uc);
            assert_eq!(engine.reprogram(&w1.data), 0, "{scheme}: identical weights, all skipped");
            assert_eq!(engine.reprogram(&w2.data), 1, "{scheme}: exactly one group changed");
            let fresh = PimEngine::prepare(scheme, q, &w2, c, k, uc);
            assert_eq!(engine.w_cache, fresh.w_cache);
            let a = Tensor::from_vec(
                &[3, cols],
                (0..3 * cols).map(|_| rng.int_in(0, 15) as f32).collect(),
            );
            let chip = ChipModel::ideal(7).with_noise(0.4);
            let mut r1 = Rng::new(5);
            let mut r2 = Rng::new(5);
            assert_eq!(
                engine.matmul(&a, &chip, &mut r1).data,
                fresh.matmul(&a, &chip, &mut r2).data,
                "{scheme}: reprogrammed engine must match a fresh prepare bitwise"
            );
        }
    }

    /// Reference packing: the u8-plane layout (one cell per weight bit, as
    /// the engine stored before L3.6) packed into u64 words.
    fn pack_u8_planes(w: &[f32], n: usize, out: usize, b_w: u32) -> Vec<Vec<u64>> {
        let wpr = super::packed_words(out);
        let mut planes = vec![vec![0u64; n * wpr]; b_w as usize];
        for r in 0..n {
            for o in 0..out {
                let v = w[r * out + o] as i32;
                let u = if v < 0 { v + (1 << b_w) } else { v } as u32;
                for (k, plane) in planes.iter_mut().enumerate() {
                    if (u >> k) & 1 == 1 {
                        plane[r * wpr + o / 64] |= 1u64 << (o % 64);
                    }
                }
            }
        }
        planes
    }

    #[test]
    fn packed_planes_match_u8_layout_after_prepare_and_reprogram() {
        let q = bits();
        let mut rng = Rng::new(21);
        // out=70 exercises the partial last word (pad bits must stay zero)
        let (c, k, o, uc) = (2usize, 3usize, 70usize, 1usize);
        let cols = c * k * k;
        let n = plan_groups(c, k, uc).n;
        let w1 = Tensor::from_vec(
            &[cols, o],
            (0..cols * o).map(|_| rng.int_in(-7, 7) as f32).collect(),
        );
        let mut engine = PimEngine::prepare(Scheme::BitSerial, q, &w1, c, k, uc);
        let check = |engine: &PimEngine, w: &Tensor| {
            for g in 0..engine.plan.groups {
                let wr = engine.plan.weight_range(g, o);
                let want = pack_u8_planes(&w.data[wr], n, o, q.b_w);
                match &engine.groups[g] {
                    GroupWeights::BitSerial(planes) => {
                        assert_eq!(planes, &want, "group {g}: packed planes diverged");
                    }
                    other => panic!("expected BitSerial planes, got {other:?}"),
                }
            }
        };
        check(&engine, &w1);
        // reprogram with one changed group (the other takes the skip path)
        let mut w2 = w1.clone();
        w2.data[0] = if w2.data[0] > 0.0 { -3.0 } else { 3.0 };
        assert_eq!(engine.reprogram(&w2.data), 1);
        check(&engine, &w2);
    }

    #[test]
    fn engine_faults_override_chip_faults() {
        use crate::chip::FaultProfile;
        let q = bits();
        let mut rng = Rng::new(8);
        let a = Tensor::from_vec(&[4, 18], (0..72).map(|_| rng.int_in(0, 15) as f32).collect());
        let w = Tensor::from_vec(&[18, 3], (0..54).map(|_| rng.int_in(-7, 7) as f32).collect());
        let healthy = ChipModel::ideal(7);
        let injured = healthy.clone().with_faults(FaultProfile::severe().on_chip(1));
        let mut engine = PimEngine::prepare(Scheme::BitSerial, q, &w, 2, 3, 1);
        let run = |e: &PimEngine, chip: &ChipModel| e.matmul(&a, chip, &mut Rng::new(0)).data;

        let clean = run(&engine, &healthy);
        let chip_faulted = run(&engine, &injured);
        assert_ne!(clean, chip_faulted, "chip-level faults must perturb the output");

        // engine replica carries its own (different) injury: it wins over
        // the chip-level model
        engine.set_faults(Some(FaultModel::new(FaultProfile::severe().on_chip(2))));
        let replica = run(&engine, &injured);
        assert_ne!(replica, chip_faulted, "replica profile must override chip profile");
        assert_eq!(replica, run(&engine, &healthy), "override makes the chip model moot");

        // clearing restores the chip-level behaviour and survives clone
        let cloned = engine.clone();
        assert_eq!(run(&cloned, &injured), replica, "clone must keep the replica faults");
        engine.set_faults(None);
        assert_eq!(run(&engine, &injured), chip_faulted);
        assert_eq!(run(&engine, &healthy), clean);
    }

    #[test]
    fn scratch_arena_reuses_buffers() {
        let q = bits();
        let mut rng = Rng::new(6);
        let a = Tensor::from_vec(&[4, 18], (0..72).map(|_| rng.int_in(0, 15) as f32).collect());
        let w = Tensor::from_vec(&[18, 3], (0..54).map(|_| rng.int_in(-7, 7) as f32).collect());
        let engine =
            PimEngine::prepare(Scheme::BitSerial, q, &w, 2, 3, 1).with_threads(1);
        let chip = ChipModel::ideal(7);
        let mut nrng = Rng::new(0);
        let y1 = engine.matmul(&a, &chip, &mut nrng);
        // second call pops the pooled scratch; results must be unchanged
        let y2 = engine.matmul(&a, &chip, &mut nrng);
        assert_eq!(y1.data, y2.data);
        assert_eq!(engine.scratch.0.lock().unwrap().len(), 1);
    }
}
