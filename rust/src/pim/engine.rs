//! The PIM MAC engine: plane decomposition → analog plane sums (integer
//! GEMM) → ADC conversion (curve + noise) → digital recombination.
//!
//! Weights are prepared once per layer (`PimEngine::prepare`) into their
//! decomposed form — bit planes for bit-serial, ±halves for differential —
//! mirroring how a chip programs its cell array once and streams inputs.
//!
//! §Perf (EXPERIMENTS.md): the execution path is integer-native and
//! multi-threaded.  Activations live on the u8 grid inside the engine, DAC
//! input planes are extracted with shifts/masks, plane sums accumulate in
//! i32 (exact, so bit-identical to the seed float path), conversion runs
//! row-batched through `Converter::convert_row`, and rows are partitioned
//! across scoped threads with per-thread scratch buffers from a reusable
//! arena.  Thermal noise comes from a counter-based RNG addressed by
//! (group, plane, row, column) — see DESIGN.md §RNG contract — which is
//! what makes the output bit-identical at any thread count.

use std::fmt;
use std::sync::Mutex;

use crate::chip::{ChipModel, Converter};
use crate::config::Scheme;
use crate::tensor::gemm::{gemm_acc_u8_bin, gemm_acc_u8_i16};
use crate::tensor::Tensor;
use crate::util::rng::{CounterRng, Rng};

use super::layout::{plan_groups, GroupPlan};
use super::{plane_full_scale, QuantBits};

/// One layer's weights, decomposed for the configured scheme, on integer
/// grids (i16 analog cells, u8 bit planes).
#[derive(Debug, Clone)]
enum GroupWeights {
    /// [N, O] signed integer weights (native: multi-bit analog cells).
    Native(Vec<i16>),
    /// Positive and negative halves, each [N, O] of non-negative ints.
    Differential(Vec<i16>, Vec<i16>),
    /// b_w binary planes of [N, O] (bit-serial SRAM cells).
    BitSerial(Vec<Vec<u8>>),
}

/// Reusable per-thread scratch: group activations, one DAC plane, and the
/// i32 plane-sum block.  Pooled on the engine so repeated `matmul` calls
/// (training-scale evaluation) do not reallocate.
#[derive(Default)]
struct Scratch {
    a_grp: Vec<u8>,
    a_plane: Vec<u8>,
    s: Vec<i32>,
}

struct ScratchPool(Mutex<Vec<Scratch>>);

impl ScratchPool {
    fn new() -> Self {
        ScratchPool(Mutex::new(Vec::new()))
    }

    fn take(&self) -> Scratch {
        self.0.lock().unwrap().pop().unwrap_or_default()
    }

    fn put(&self, s: Scratch) {
        self.0.lock().unwrap().push(s);
    }
}

/// PIM execution engine for grouped matmuls of one geometry.
pub struct PimEngine {
    pub scheme: Scheme,
    pub bits: QuantBits,
    pub plan: GroupPlan,
    pub out: usize,
    fs: f32,
    /// Worker threads for `matmul`: 0 = auto ($PIM_QAT_THREADS or the
    /// available parallelism).
    threads: usize,
    groups: Vec<GroupWeights>,
    scratch: ScratchPool,
}

impl Clone for PimEngine {
    fn clone(&self) -> Self {
        PimEngine {
            scheme: self.scheme,
            bits: self.bits,
            plan: self.plan,
            out: self.out,
            fs: self.fs,
            threads: self.threads,
            groups: self.groups.clone(),
            scratch: ScratchPool::new(),
        }
    }
}

impl fmt::Debug for PimEngine {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("PimEngine")
            .field("scheme", &self.scheme)
            .field("bits", &self.bits)
            .field("plan", &self.plan)
            .field("out", &self.out)
            .field("fs", &self.fs)
            .field("threads", &self.threads)
            .field("groups", &self.groups.len())
            .finish()
    }
}

impl PimEngine {
    /// Prepare integer weights `w_int` laid out [C*k*k, O] (im2col column
    /// order) for execution. `unit_channels` is the requested group size.
    pub fn prepare(
        scheme: Scheme,
        bits: QuantBits,
        w_int: &Tensor,
        c_in: usize,
        kernel: usize,
        unit_channels: usize,
    ) -> Self {
        assert_eq!(w_int.rank(), 2);
        let cols = w_int.shape[0];
        let out = w_int.shape[1];
        assert_eq!(cols, c_in * kernel * kernel, "weight columns vs c_in*k*k");
        assert!(bits.b_a <= 8, "u8 activation grid needs b_a <= 8");
        let plan = plan_groups(c_in, kernel, unit_channels);
        let n = plan.n;
        let fs = plane_full_scale(scheme, &bits, n);
        let b_w = bits.b_w;

        let groups = (0..plan.groups)
            .map(|g| {
                let rows = plan.col_range(g);
                match scheme {
                    Scheme::Native => {
                        let mut w = vec![0i16; n * out];
                        for (ri, r) in rows.clone().enumerate() {
                            for o in 0..out {
                                w[ri * out + o] = w_int.data[r * out + o] as i16;
                            }
                        }
                        GroupWeights::Native(w)
                    }
                    Scheme::Differential => {
                        let mut wp = vec![0i16; n * out];
                        let mut wn = vec![0i16; n * out];
                        for (ri, r) in rows.clone().enumerate() {
                            for o in 0..out {
                                let v = w_int.data[r * out + o];
                                if v > 0.0 {
                                    wp[ri * out + o] = v as i16;
                                } else {
                                    wn[ri * out + o] = (-v) as i16;
                                }
                            }
                        }
                        GroupWeights::Differential(wp, wn)
                    }
                    Scheme::BitSerial => {
                        let mut planes = vec![vec![0u8; n * out]; b_w as usize];
                        for (ri, r) in rows.clone().enumerate() {
                            for o in 0..out {
                                let v = w_int.data[r * out + o] as i32;
                                // two's complement over b_w bits
                                let u = if v < 0 { v + (1 << b_w) } else { v } as u32;
                                for (k, plane) in planes.iter_mut().enumerate() {
                                    plane[ri * out + o] = ((u >> k) & 1) as u8;
                                }
                            }
                        }
                        GroupWeights::BitSerial(planes)
                    }
                }
            })
            .collect();

        PimEngine {
            scheme,
            bits,
            plan,
            out,
            fs,
            threads: 0,
            groups,
            scratch: ScratchPool::new(),
        }
    }

    /// Pin the worker-thread count (0 = auto).  Outputs are bit-identical
    /// at every thread count; this only controls the row partitioning.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Total MACs per output row (for throughput accounting).
    pub fn macs_per_row(&self) -> usize {
        self.plan.groups * self.plan.n * self.out
    }

    fn effective_threads(&self, rows: usize) -> usize {
        crate::tensor::ops::resolve_threads(self.threads).min(rows).max(1)
    }

    /// Execute the grouped PIM matmul over integer activation patches
    /// [M, C*k*k] (values on the 0..a_levels integer grid, stored as f32).
    /// Output [M, O] is in unit scale (estimate of Σ W̃ q̃).
    ///
    /// `rng` seeds the thermal-noise field: when the chip has noise, one
    /// draw is taken and every noise sample becomes a pure function of
    /// (that seed, group, plane, row, column).  Same seed → same output,
    /// for any thread count.
    pub fn matmul(&self, patches_int: &Tensor, chip: &ChipModel, rng: &mut Rng) -> Tensor {
        let m = patches_int.shape[0];
        let cols = patches_int.shape[1];
        assert_eq!(cols, self.plan.cols(), "patch columns vs group plan");
        let out = self.out;

        let conv = Converter::new(chip, self.fs, out);
        let noise = if chip.noise_lsb > 0.0 {
            Some((CounterRng::new(rng.next_u64()), chip.noise_lsb))
        } else {
            None
        };

        let mut y = vec![0.0f32; m * out];
        let threads = self.effective_threads(m);
        if threads <= 1 {
            self.run_rows(patches_int, 0, m, &conv, noise.as_ref(), &mut y);
        } else {
            let chunk = (m + threads - 1) / threads;
            std::thread::scope(|sc| {
                for (ti, ych) in y.chunks_mut(chunk * out).enumerate() {
                    let conv = &conv;
                    let noise = noise.as_ref();
                    sc.spawn(move || {
                        let rows = ych.len() / out;
                        self.run_rows(patches_int, ti * chunk, rows, conv, noise, ych);
                    });
                }
            });
        }

        let denom = (self.bits.w_levels() * self.bits.a_levels()) as f32;
        for v in &mut y {
            *v /= denom;
        }
        Tensor::from_vec(&[m, out], y)
    }

    /// Process rows [row0, row0+rows): gather each group's columns onto the
    /// u8 grid, extract DAC planes with shift/mask, form i32 plane sums,
    /// and convert row-batched.  One thread's worth of work.
    fn run_rows(
        &self,
        patches: &Tensor,
        row0: usize,
        rows: usize,
        conv: &Converter,
        noise: Option<&(CounterRng, f32)>,
        y: &mut [f32],
    ) {
        let n = self.plan.n;
        let out = self.out;
        let cols = self.plan.cols();
        let n_slices = self.bits.n_slices();
        let delta = self.bits.delta();
        let mask = (delta - 1) as u8;

        let mut sc = self.scratch.take();
        sc.a_grp.clear();
        sc.a_grp.resize(rows * n, 0);
        sc.a_plane.clear();
        sc.a_plane.resize(rows * n, 0);
        sc.s.clear();
        sc.s.resize(rows * out, 0);

        for (g, gw) in self.groups.iter().enumerate() {
            let crange = self.plan.col_range(g);
            // gather this group's patch columns, quantized to the u8 grid
            for i in 0..rows {
                let base = (row0 + i) * cols;
                let src = &patches.data[base + crange.start..base + crange.end];
                for (d, &v) in sc.a_grp[i * n..(i + 1) * n].iter_mut().zip(src) {
                    *d = v as u8;
                }
            }
            for l in 0..n_slices {
                let slice_w = (delta as f32).powi(l as i32);
                // input DAC plane: (a >> m·l) & (Δ-1), pure shift/mask
                if n_slices == 1 {
                    sc.a_plane.copy_from_slice(&sc.a_grp);
                } else {
                    let shift = self.bits.m * l;
                    for (d, &v) in sc.a_plane.iter_mut().zip(&sc.a_grp) {
                        *d = (v >> shift) & mask;
                    }
                }
                match gw {
                    GroupWeights::Native(w) => {
                        sc.s.fill(0);
                        gemm_acc_u8_i16(rows, n, out, &sc.a_plane, w, &mut sc.s);
                        self.convert_block(
                            conv, noise, g, l as usize, row0, rows, &sc.s, slice_w, true, y,
                        );
                    }
                    GroupWeights::Differential(wp, wn) => {
                        sc.s.fill(0);
                        gemm_acc_u8_i16(rows, n, out, &sc.a_plane, wp, &mut sc.s);
                        self.convert_block(
                            conv,
                            noise,
                            g,
                            2 * l as usize,
                            row0,
                            rows,
                            &sc.s,
                            slice_w,
                            false,
                            y,
                        );
                        sc.s.fill(0);
                        gemm_acc_u8_i16(rows, n, out, &sc.a_plane, wn, &mut sc.s);
                        self.convert_block(
                            conv,
                            noise,
                            g,
                            2 * l as usize + 1,
                            row0,
                            rows,
                            &sc.s,
                            -slice_w,
                            false,
                            y,
                        );
                    }
                    GroupWeights::BitSerial(planes) => {
                        for (k, wp) in planes.iter().enumerate() {
                            let sign = if k as u32 == self.bits.b_w - 1 { -1.0 } else { 1.0 };
                            let bit_w = sign * (1u32 << k) as f32 * slice_w;
                            sc.s.fill(0);
                            gemm_acc_u8_bin(rows, n, out, &sc.a_plane, wp, &mut sc.s);
                            let plane = l as usize * self.bits.b_w as usize + k;
                            self.convert_block(
                                conv, noise, g, plane, row0, rows, &sc.s, bit_w, false, y,
                            );
                        }
                    }
                }
            }
        }
        self.scratch.put(sc);
    }

    /// Convert a [rows, out] block of plane sums, accumulating
    /// `coef · adc(s)` into `y`.  `plane` is the conversion's index within
    /// the group (unique per DAC slice / bit plane / differential half), so
    /// the noise position key (group, plane, absolute row, column) never
    /// collides.
    #[allow(clippy::too_many_arguments)]
    fn convert_block(
        &self,
        conv: &Converter,
        noise: Option<&(CounterRng, f32)>,
        g: usize,
        plane: usize,
        row0: usize,
        rows: usize,
        s: &[i32],
        coef: f32,
        signed: bool,
        y: &mut [f32],
    ) {
        let out = self.out;
        for i in 0..rows {
            let srow = &s[i * out..(i + 1) * out];
            let yrow = &mut y[i * out..(i + 1) * out];
            match noise {
                Some((field, sigma)) => {
                    let stream = field.stream3(g as u64, plane as u64, (row0 + i) as u64);
                    conv.convert_row(srow, signed, coef, Some((&stream, *sigma)), yrow);
                }
                None => conv.convert_row(srow, signed, coef, None, yrow),
            }
        }
    }
}

/// One-shot convenience: prepare + execute (tests, goldens).
#[allow(clippy::too_many_arguments)]
pub fn pim_grouped_matmul(
    scheme: Scheme,
    bits: QuantBits,
    a_int: &Tensor, // [M, G*N]
    w_int: &Tensor, // [G*N, O]
    c_in: usize,
    kernel: usize,
    unit_channels: usize,
    chip: &ChipModel,
    rng: &mut Rng,
) -> Tensor {
    PimEngine::prepare(scheme, bits, w_int, c_in, kernel, unit_channels)
        .matmul(a_int, chip, rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bits() -> QuantBits {
        QuantBits::default()
    }

    /// Loop-level reimplementation of one group/one output (the ref.py shape)
    /// for an ideal chip — a second, independent implementation inside rust.
    fn ref_one(a: &[f32], w: &[f32], scheme: Scheme, b_pim: u32, q: &QuantBits) -> f32 {
        let n = a.len();
        let levels = ((1u32 << b_pim) - 1) as f32;
        let fs = plane_full_scale(scheme, q, n);
        let lsb = fs / levels;
        let adc = |s: f32| crate::chip::round_ties_even(s / lsb) * lsb;
        let mut y = 0.0f32;
        match scheme {
            Scheme::Native => {
                let s: f32 = a.iter().zip(w).map(|(x, y)| x * y).sum();
                y += adc(s);
            }
            Scheme::Differential => {
                let sp: f32 = a.iter().zip(w).map(|(x, y)| x * y.max(0.0)).sum();
                let sn: f32 = a.iter().zip(w).map(|(x, y)| x * (-y).max(0.0)).sum();
                y += adc(sp) - adc(sn);
            }
            Scheme::BitSerial => {
                for k in 0..q.b_w {
                    let sign = if k == q.b_w - 1 { -1.0 } else { 1.0 };
                    let s: f32 = a
                        .iter()
                        .zip(w)
                        .map(|(x, wv)| {
                            let v = *wv as i32;
                            let u = if v < 0 { v + (1 << q.b_w) } else { v } as u32;
                            x * ((u >> k) & 1) as f32
                        })
                        .sum();
                    y += sign * (1u32 << k) as f32 * adc(s);
                }
            }
        }
        y / (q.w_levels() * q.a_levels()) as f32
    }

    #[test]
    fn engine_matches_inline_ref_all_schemes() {
        let q = bits();
        let mut rng = Rng::new(42);
        for scheme in [Scheme::Native, Scheme::BitSerial, Scheme::Differential] {
            for &b_pim in &[3u32, 5, 7] {
                let (m, c, k, o, uc) = (5usize, 2usize, 3usize, 4usize, 2usize);
                let n = uc * k * k;
                let cols = c * k * k;
                let a = Tensor::from_vec(
                    &[m, cols],
                    (0..m * cols).map(|_| rng.int_in(0, 15) as f32).collect(),
                );
                let w = Tensor::from_vec(
                    &[cols, o],
                    (0..cols * o).map(|_| rng.int_in(-7, 7) as f32).collect(),
                );
                let chip = ChipModel::ideal(b_pim);
                let mut nrng = Rng::new(0);
                let y = pim_grouped_matmul(scheme, q, &a, &w, c, k, uc, &chip, &mut nrng);
                // independent reference, group by group
                let groups = cols / n;
                for i in 0..m {
                    for oi in 0..o {
                        let mut want = 0.0;
                        for g in 0..groups {
                            let arow: Vec<f32> =
                                (0..n).map(|j| a.data[i * cols + g * n + j]).collect();
                            let wcol: Vec<f32> =
                                (0..n).map(|j| w.data[(g * n + j) * o + oi]).collect();
                            want += ref_one(&arow, &wcol, scheme, b_pim, &q);
                        }
                        let got = y.data[i * o + oi];
                        assert!(
                            (got - want).abs() < 1e-5,
                            "{scheme} b{b_pim} [{i},{oi}]: {got} vs {want}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn infinite_resolution_recovers_exact_product() {
        let q = bits();
        let mut rng = Rng::new(1);
        let (m, c, k, o, uc) = (4usize, 4usize, 3usize, 3usize, 2usize);
        let cols = c * k * k;
        let a = Tensor::from_vec(
            &[m, cols],
            (0..m * cols).map(|_| rng.int_in(0, 15) as f32).collect(),
        );
        let w = Tensor::from_vec(
            &[cols, o],
            (0..cols * o).map(|_| rng.int_in(-7, 7) as f32).collect(),
        );
        let chip = ChipModel::ideal(24);
        let mut nrng = Rng::new(0);
        let y = pim_grouped_matmul(Scheme::BitSerial, q, &a, &w, c, k, uc, &chip, &mut nrng);
        for i in 0..m {
            for oi in 0..o {
                let exact: f32 = (0..cols)
                    .map(|j| a.data[i * cols + j] * w.data[j * o + oi])
                    .sum::<f32>()
                    / 105.0;
                assert!((y.data[i * o + oi] - exact).abs() < 2e-3);
            }
        }
    }

    #[test]
    fn noise_changes_output_determinately() {
        let q = bits();
        let mut rng = Rng::new(2);
        let a = Tensor::from_vec(&[2, 9], (0..18).map(|_| rng.int_in(0, 15) as f32).collect());
        let w = Tensor::from_vec(&[9, 2], (0..18).map(|_| rng.int_in(-7, 7) as f32).collect());
        let chip = ChipModel::ideal(7).with_noise(0.5);
        let run = |seed| {
            let mut r = Rng::new(seed);
            pim_grouped_matmul(Scheme::BitSerial, q, &a, &w, 1, 3, 1, &chip, &mut r)
        };
        assert_eq!(run(3), run(3), "same seed, same output");
        assert_ne!(run(3), run(4), "different noise stream differs");
    }

    #[test]
    fn m1_dac_slices() {
        // m=1 (binary DAC): 4 input planes; must still match high-res exact.
        let q = QuantBits { b_w: 4, b_a: 4, m: 1 };
        let mut rng = Rng::new(5);
        let a = Tensor::from_vec(&[3, 9], (0..27).map(|_| rng.int_in(0, 15) as f32).collect());
        let w = Tensor::from_vec(&[9, 2], (0..18).map(|_| rng.int_in(-7, 7) as f32).collect());
        let chip = ChipModel::ideal(24);
        let mut nrng = Rng::new(0);
        let y = pim_grouped_matmul(Scheme::BitSerial, q, &a, &w, 1, 3, 1, &chip, &mut nrng);
        for i in 0..3 {
            for oi in 0..2 {
                let exact: f32 = (0..9)
                    .map(|j| a.data[i * 9 + j] * w.data[j * 2 + oi])
                    .sum::<f32>()
                    / 105.0;
                assert!((y.data[i * 2 + oi] - exact).abs() < 2e-3);
            }
        }
    }

    #[test]
    fn scratch_arena_reuses_buffers() {
        let q = bits();
        let mut rng = Rng::new(6);
        let a = Tensor::from_vec(&[4, 18], (0..72).map(|_| rng.int_in(0, 15) as f32).collect());
        let w = Tensor::from_vec(&[18, 3], (0..54).map(|_| rng.int_in(-7, 7) as f32).collect());
        let engine =
            PimEngine::prepare(Scheme::BitSerial, q, &w, 2, 3, 1).with_threads(1);
        let chip = ChipModel::ideal(7);
        let mut nrng = Rng::new(0);
        let y1 = engine.matmul(&a, &chip, &mut nrng);
        // second call pops the pooled scratch; results must be unchanged
        let y2 = engine.matmul(&a, &chip, &mut nrng);
        assert_eq!(y1.data, y2.data);
        assert_eq!(engine.scratch.0.lock().unwrap().len(), 1);
    }
}
