//! Debug allocation counter (unit tests only): a thin wrapper around the
//! system allocator that counts allocations at or above an armed size
//! threshold **on the armed thread**.  `train::native` tests use it to pin
//! the zero-large-allocation contract of the steady-state train step
//! (EXPERIMENTS.md §Perf L3.5): from step 2 on, the arena and the engine
//! cache must absorb every patch-scale buffer.
//!
//! Counting is thread-filtered (thread-local threshold and counter) so the
//! worker pool and unrelated tests running in parallel do not perturb the
//! armed thread's count.  The `#[global_allocator]` registration is
//! compiled into the unit-test binary only (`#[cfg(test)]` in `util`), so
//! release builds and integration tests keep the plain system allocator.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    /// Armed size threshold in bytes; `usize::MAX` = disarmed.
    static THRESHOLD: Cell<usize> = const { Cell::new(usize::MAX) };
    /// Number of at-or-above-threshold allocations since arming.
    static LARGE: Cell<usize> = const { Cell::new(0) };
}

/// System allocator with per-thread large-allocation counting.
pub struct CountingAllocator;

#[global_allocator]
static GLOBAL: CountingAllocator = CountingAllocator;

#[inline]
fn note(size: usize) {
    // `try_with` so allocations during TLS teardown never panic.
    let armed = THRESHOLD.try_with(Cell::get).unwrap_or(usize::MAX);
    if size >= armed {
        let _ = LARGE.try_with(|c| c.set(c.get() + 1));
    }
}

unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        note(layout.size());
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        note(layout.size());
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if new_size > layout.size() {
            note(new_size);
        }
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

/// Start counting allocations of `threshold` bytes or more on this thread.
pub fn arm(threshold: usize) {
    THRESHOLD.with(|c| c.set(threshold));
    LARGE.with(|c| c.set(0));
}

/// Stop counting; returns the number of large allocations seen on this
/// thread since [`arm`].
pub fn disarm() -> usize {
    THRESHOLD.with(|c| c.set(usize::MAX));
    LARGE.with(|c| c.get())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_only_armed_thread_and_threshold() {
        arm(1 << 16);
        let small = vec![0u8; 1 << 10];
        assert_eq!(LARGE.with(|c| c.get()), 0, "small allocation must not count");
        let big = vec![0u8; 1 << 17];
        let seen = disarm();
        assert!(seen >= 1, "large allocation must count");
        // disarmed: further large allocations are free
        let big2 = vec![0u8; 1 << 17];
        assert_eq!(disarm(), 0);
        std::hint::black_box((small, big, big2));
    }
}
