//! In-tree substrates replacing crates unavailable in the offline cache
//! (DESIGN.md §Substrates S10–S13).

#[cfg(test)]
pub mod alloc;
pub mod bench;
pub mod error;
pub mod json;
pub mod pool;
pub mod rng;
pub mod table;

/// Running mean/variance accumulator (Welford).  Used by BN calibration and
/// the statistics in experiments.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    pub n: u64,
    pub mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    /// Population variance (matches jnp.var / BN batch statistics).
    pub fn var(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = (self.n + other.n) as f64;
        let d = other.mean - self.mean;
        self.mean += d * other.n as f64 / n;
        self.m2 += other.m2 + d * d * self.n as f64 * other.n as f64 / n;
        self.n += other.n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!((w.mean - mean).abs() < 1e-12);
        assert!((w.var() - var).abs() < 1e-12);
    }

    #[test]
    fn welford_merge() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 3.0).collect();
        let mut a = Welford::default();
        let mut b = Welford::default();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        let mut full = Welford::default();
        for &x in &xs {
            full.push(x);
        }
        assert!((a.mean - full.mean).abs() < 1e-10);
        assert!((a.var() - full.var()).abs() < 1e-10);
    }
}
