//! Error substrate (S14): a minimal `anyhow`-compatible error type.
//!
//! The offline crate cache has no `anyhow`, and the default build must link
//! with zero external dependencies (DESIGN.md §Substrates), so the crate
//! carries the subset of the `anyhow` API it actually uses: a formatted
//! string error, the `anyhow!` macro, `Result<T>`, and the `Context`
//! extension trait.  Like `anyhow::Error`, this type deliberately does NOT
//! implement `std::error::Error` — that is what makes the blanket
//! `From<E: std::error::Error>` conversion (the `?` operator) coherent.

use std::fmt;

/// A formatted diagnostic error (message-only; the crate's errors are
/// human-readable strings, not matchable variants).
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(m: impl fmt::Display) -> Self {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(&e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or any `Display` value,
/// mirroring `anyhow::anyhow!`.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::util::error::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::util::error::Error::msg($err)
    };
}

pub use crate::anyhow;

/// Attach context to an error, mirroring `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(&ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        Err(anyhow!("broke at step {}", 3))
    }

    #[test]
    fn macro_forms() {
        assert_eq!(fails().unwrap_err().to_string(), "broke at step 3");
        let n = 7;
        assert_eq!(anyhow!("n={n}").to_string(), "n=7");
        assert_eq!(anyhow!(String::from("owned")).to_string(), "owned");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn context_wraps() {
        let e: Result<()> = Err(anyhow!("inner")).context("outer");
        assert_eq!(e.unwrap_err().to_string(), "outer: inner");
        let e: Result<()> = Err(anyhow!("inner")).with_context(|| format!("job {}", 2));
        assert_eq!(e.unwrap_err().to_string(), "job 2: inner");
        let v: Result<i32> = None.context("missing");
        assert_eq!(v.unwrap_err().to_string(), "missing");
    }
}
