//! Persistent worker pool (EXPERIMENTS.md §Perf L3.5): scoped fork-join
//! parallelism on long-lived threads, replacing the per-call
//! `std::thread::scope` spawns in `tensor::ops` and `pim::engine`.
//!
//! Why: a training step issues hundreds of small parallel regions (im2col,
//! PIM plane-sum batches, col2im, the ξ digital twin), and OS thread
//! creation was charged to every one of them.  The pool spawns workers
//! once, on first use, and every later region is a queue push plus a
//! condvar wake.
//!
//! Semantics match `std::thread::scope`: [`run_scoped`] returns only after
//! every job has finished, so jobs may borrow from the caller's stack (the
//! lifetime is erased internally, which is sound *because* of that
//! barrier).  A panic inside a job is caught and re-raised on the caller.
//! `$PIM_QAT_THREADS` keeps its meaning — callers decide how many jobs to
//! create (see `tensor::ops::resolve_threads`); the pool grows to match,
//! and the calling thread works the queue itself while it waits.
//!
//! [`submit`] is the detached counterpart (§Perf L3.7): it queues a batch
//! of `'static` jobs and returns a [`Ticket`] immediately, so work — the
//! batch loader's next-batch assembly — can run *concurrently with* the
//! submitter's own compute (the current step's backward) instead of inside
//! a barrier.  The receiving side calls [`Ticket::wait`] before touching
//! anything the jobs write; a panic in a detached job re-raises there.

use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// A borrowing job, as accepted by [`run_scoped`].
pub type ScopedJob<'env> = Box<dyn FnOnce() + Send + 'env>;

/// One queued job plus the scope it reports completion to.
struct Task {
    job: Box<dyn FnOnce() + Send + 'static>,
    scope: Arc<ScopeState>,
}

impl Task {
    fn run(self) {
        if let Err(payload) = catch_unwind(AssertUnwindSafe(self.job)) {
            // keep the FIRST payload so the caller re-raises the real
            // message/location, as std::thread::scope would
            let mut slot = self.scope.panic.lock().unwrap();
            if slot.is_none() {
                *slot = Some(payload);
            }
        }
        let mut pending = self.scope.pending.lock().unwrap();
        *pending -= 1;
        if *pending == 0 {
            self.scope.done.notify_all();
        }
    }
}

/// Completion latch of one `run_scoped` call.
struct ScopeState {
    pending: Mutex<usize>,
    done: Condvar,
    panic: Mutex<Option<Box<dyn Any + Send + 'static>>>,
}

struct PoolShared {
    queue: Mutex<VecDeque<Task>>,
    ready: Condvar,
}

struct Pool {
    shared: Arc<PoolShared>,
    /// Workers spawned so far; grows on demand, never shrinks.
    workers: Mutex<usize>,
}

fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| Pool {
        shared: Arc::new(PoolShared { queue: Mutex::new(VecDeque::new()), ready: Condvar::new() }),
        workers: Mutex::new(0),
    })
}

impl Pool {
    fn ensure_workers(&self, want: usize) {
        let mut n = self.workers.lock().unwrap();
        while *n < want {
            let shared = Arc::clone(&self.shared);
            std::thread::Builder::new()
                .name(format!("pim-qat-pool-{}", *n))
                .spawn(move || loop {
                    let task = {
                        let mut q = shared.queue.lock().unwrap();
                        loop {
                            match q.pop_front() {
                                Some(t) => break t,
                                None => q = shared.ready.wait(q).unwrap(),
                            }
                        }
                    };
                    task.run();
                })
                .expect("spawn pool worker");
            *n += 1;
        }
    }
}

/// Completion handle of a detached [`submit`] batch.  The jobs may still
/// be running (or still queued) while this exists; [`Ticket::wait`] is the
/// only way to learn they are done.  Dropping a ticket without waiting is
/// allowed *only* when the jobs borrow nothing (`submit`'s safe `'static`
/// contract); callers that erased a lifetime to get `'static` jobs must
/// wait before the borrowed data dies — see `data::loader` for the
/// canonical discipline (wait before every slot reuse and in `Drop`).
#[must_use = "detached jobs are only known finished after Ticket::wait"]
pub struct Ticket {
    scope: Arc<ScopeState>,
}

impl Ticket {
    /// Non-blocking completion probe: `true` once every job in the batch
    /// has finished (a panic inside a job still counts as finished — it is
    /// re-raised by [`Ticket::wait`], which remains the only way to
    /// *observe* it).  Serving dispatchers poll this to find a free
    /// replica without parking on a busy one.
    pub fn is_complete(&self) -> bool {
        *self.scope.pending.lock().unwrap() == 0
    }

    /// Block until every job in the batch has finished; the first panic
    /// from any job re-raises here.  When the batch is already complete —
    /// the steady-state prefetch hit — this returns without touching the
    /// queue, so work submitted moments earlier stays on the workers
    /// instead of being dragged onto the waiting thread (draining here
    /// would serialize exactly what [`submit`] exists to overlap).  Only
    /// while the batch is genuinely unfinished does the caller help work
    /// the queue (it may then execute tasks from other scopes — harmless,
    /// and better than idling).
    pub fn wait(self) {
        loop {
            if *self.scope.pending.lock().unwrap() == 0 {
                break;
            }
            let task = pool().shared.queue.lock().unwrap().pop_front();
            match task {
                Some(t) => t.run(),
                // queue empty but our jobs still running on workers:
                // fall through to the condvar
                None => break,
            }
        }
        let mut pending = self.scope.pending.lock().unwrap();
        while *pending > 0 {
            pending = self.scope.done.wait(pending).unwrap();
        }
        drop(pending);
        if let Some(payload) = self.scope.panic.lock().unwrap().take() {
            resume_unwind(payload);
        }
    }
}

/// Grow the pool to at least `n` workers without queueing anything.
/// Long-lived submitters (the serving farm: one detached batch per chip
/// replica) call this once up front so their single-job submissions run
/// side by side instead of serializing on however many workers earlier
/// callers happened to leave behind.
pub fn reserve(n: usize) {
    pool().ensure_workers(n);
}

/// Pre-grow the pool for `replicas` concurrent coarse-grained submitters
/// (data-parallel replica trainers, farm chip replicas), each of which
/// fans its own hot loops out over `threads_per_replica` workers.  This is
/// the one place the `$PIM_QAT_THREADS` semantics are decided:
/// **`PIM_QAT_THREADS` is a per-replica, per-op budget** (what
/// `tensor::ops::resolve_threads` hands each GEMM/assembly call), so the
/// pool itself must hold roughly `replicas × threads` workers for the
/// replicas to run side by side instead of serializing their bursts.
/// Returns the worker count requested, for diagnostics.
pub fn reserve_for(replicas: usize, threads_per_replica: usize) -> usize {
    let n = replicas.max(1) * threads_per_replica.max(1);
    reserve(n);
    n
}

/// Queue `jobs` for asynchronous execution on the pool and return a
/// [`Ticket`] immediately — the detached twin of [`run_scoped`].  Jobs
/// must be `'static`: nothing here blocks, so there is no barrier to make
/// borrowed environments sound.  The pool is grown to at least `jobs.len()`
/// workers so a submit-then-wait cannot deadlock even when the submitter
/// never touches the queue in between.
pub fn submit(jobs: Vec<ScopedJob<'static>>) -> Ticket {
    let n = jobs.len();
    let scope = Arc::new(ScopeState {
        pending: Mutex::new(n),
        done: Condvar::new(),
        panic: Mutex::new(None),
    });
    if n == 0 {
        return Ticket { scope };
    }
    let p = pool();
    p.ensure_workers(n);
    {
        let mut q = p.shared.queue.lock().unwrap();
        for job in jobs {
            q.push_back(Task { job, scope: Arc::clone(&scope) });
        }
    }
    p.shared.ready.notify_all();
    Ticket { scope }
}

/// Run `jobs` to completion across the pool's workers and the calling
/// thread.  Blocks until every job has finished; a panic in any job
/// resurfaces here.  Equivalent to spawning each job under
/// `std::thread::scope`, minus the per-call thread startup.
pub fn run_scoped(jobs: Vec<ScopedJob<'_>>) {
    let n = jobs.len();
    if n == 0 {
        return;
    }
    if n == 1 {
        // nothing to overlap — run inline, no queue traffic
        let job = jobs.into_iter().next().unwrap();
        job();
        return;
    }
    let p = pool();
    p.ensure_workers(n - 1);
    let scope = Arc::new(ScopeState {
        pending: Mutex::new(n),
        done: Condvar::new(),
        panic: Mutex::new(None),
    });
    {
        let mut q = p.shared.queue.lock().unwrap();
        for job in jobs {
            // SAFETY: erases the borrowed environment's lifetime.  Sound
            // because this function does not return until `pending == 0`,
            // i.e. until every erased closure has finished running, so no
            // borrow outlives its referent — the same contract
            // `std::thread::scope` enforces by joining.
            let job: Box<dyn FnOnce() + Send + 'static> = unsafe { std::mem::transmute(job) };
            q.push_back(Task { job, scope: Arc::clone(&scope) });
        }
    }
    p.shared.ready.notify_all();
    // The caller works the queue too.  It may pick up a task from a
    // sibling scope on another thread — harmless, it just helps that scope
    // finish while this one's tasks run elsewhere.
    loop {
        let task = p.shared.queue.lock().unwrap().pop_front();
        match task {
            Some(t) => t.run(),
            None => break,
        }
    }
    let mut pending = scope.pending.lock().unwrap();
    while *pending > 0 {
        pending = scope.done.wait(pending).unwrap();
    }
    drop(pending);
    if let Some(payload) = scope.panic.lock().unwrap().take() {
        resume_unwind(payload);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs_with_borrows() {
        let mut data = vec![0u64; 64];
        let jobs: Vec<ScopedJob<'_>> = data
            .chunks_mut(16)
            .enumerate()
            .map(|(i, chunk)| {
                let job: ScopedJob<'_> = Box::new(move || {
                    for (j, v) in chunk.iter_mut().enumerate() {
                        *v = (i * 16 + j) as u64;
                    }
                });
                job
            })
            .collect();
        run_scoped(jobs);
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i as u64);
        }
    }

    #[test]
    fn empty_and_single_job() {
        run_scoped(Vec::new());
        let hit = AtomicUsize::new(0);
        run_scoped(vec![Box::new(|| {
            hit.fetch_add(1, Ordering::Relaxed);
        })]);
        assert_eq!(hit.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn reuses_workers_across_calls() {
        // many consecutive same-size scopes must not accumulate threads:
        // the pool grows to the largest request and stays there.  (The
        // pool is process-global and other tests may grow it concurrently,
        // so assert non-growth across THIS loop, not an absolute count.)
        let baseline = {
            run_scoped((0..4).map(|_| Box::new(|| {}) as ScopedJob<'_>).collect());
            *pool().workers.lock().unwrap()
        };
        for round in 0..50u64 {
            let total = AtomicUsize::new(0);
            let jobs: Vec<ScopedJob<'_>> = (0..4)
                .map(|i| {
                    let total = &total;
                    let job: ScopedJob<'_> = Box::new(move || {
                        total.fetch_add((round + i) as usize, Ordering::Relaxed);
                    });
                    job
                })
                .collect();
            run_scoped(jobs);
            assert_eq!(total.load(Ordering::Relaxed), (4 * round + 6) as usize);
        }
        let after = *pool().workers.lock().unwrap();
        let ceiling = baseline.max(std::thread::available_parallelism().map_or(8, |n| n.get()));
        assert!(after >= 3, "4-job scopes need at least 3 workers, saw {after}");
        assert!(after <= ceiling, "same-size scopes must not keep growing the pool: {after}");
    }

    #[test]
    fn submit_runs_detached_and_wait_joins() {
        use std::sync::atomic::AtomicU64;
        let total = Arc::new(AtomicU64::new(0));
        let jobs: Vec<ScopedJob<'static>> = (0..6u64)
            .map(|i| {
                let total = Arc::clone(&total);
                let job: ScopedJob<'static> = Box::new(move || {
                    total.fetch_add(i + 1, Ordering::SeqCst);
                });
                job
            })
            .collect();
        let ticket = submit(jobs);
        // run a barrier region while the detached batch is in flight —
        // the two must coexist on one queue
        run_scoped((0..3).map(|_| Box::new(|| {}) as ScopedJob<'_>).collect());
        ticket.wait();
        assert_eq!(total.load(Ordering::SeqCst), 21);
        // an empty submission is a no-op ticket
        submit(Vec::new()).wait();
    }

    #[test]
    fn is_complete_probe_tracks_batch_lifecycle() {
        use std::sync::mpsc;
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        let ticket = submit(vec![Box::new(move || {
            gate_rx.recv().unwrap();
        }) as ScopedJob<'static>]);
        assert!(!ticket.is_complete(), "job is parked on the gate");
        gate_tx.send(()).unwrap();
        ticket.wait();
        // an empty batch is born complete
        let empty = submit(Vec::new());
        assert!(empty.is_complete());
        empty.wait();
    }

    #[test]
    fn submit_panic_propagates_on_wait() {
        let ticket = submit(vec![Box::new(|| panic!("detached")) as ScopedJob<'static>]);
        let caught = catch_unwind(AssertUnwindSafe(|| ticket.wait()));
        let payload = caught.expect_err("panic in a detached job must resurface on wait");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert_eq!(msg, "detached");
    }

    #[test]
    fn pool_stays_usable_after_detached_panic() {
        // the robustness contract behind the divergence guard: one crashed
        // job must neither deadlock the queue nor poison the workers —
        // after the panic resurfaces on wait(), both submission modes
        // still run to completion on the same global pool
        let ticket = submit(vec![Box::new(|| panic!("one-off")) as ScopedJob<'static>]);
        catch_unwind(AssertUnwindSafe(|| ticket.wait()))
            .expect_err("panic must resurface on wait");
        let hits = Arc::new(AtomicUsize::new(0));
        let jobs: Vec<ScopedJob<'static>> = (0..4)
            .map(|_| {
                let hits = Arc::clone(&hits);
                Box::new(move || {
                    hits.fetch_add(1, Ordering::SeqCst);
                }) as ScopedJob<'static>
            })
            .collect();
        submit(jobs).wait();
        assert_eq!(hits.load(Ordering::SeqCst), 4, "detached path dead after panic");
        let barrier_hits = AtomicUsize::new(0);
        run_scoped(
            (0..4)
                .map(|_| {
                    let h = &barrier_hits;
                    Box::new(move || {
                        h.fetch_add(1, Ordering::SeqCst);
                    }) as ScopedJob<'_>
                })
                .collect(),
        );
        assert_eq!(barrier_hits.load(Ordering::SeqCst), 4, "barrier path dead after panic");
    }

    #[test]
    fn job_panic_propagates_with_payload() {
        let caught = catch_unwind(|| {
            let jobs: Vec<ScopedJob<'_>> =
                vec![Box::new(|| {}), Box::new(|| panic!("inner")), Box::new(|| {})];
            run_scoped(jobs);
        });
        let payload = caught.expect_err("panic in a job must resurface on the caller");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert_eq!(msg, "inner", "the original panic payload must be preserved");
    }
}
