//! Bench harness substrate (S12): criterion is not in the offline cache, so
//! `cargo bench` targets (harness = false) use this minimal warmup + timed
//! iteration harness with robust statistics.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p05_ns: f64,
    pub p95_ns: f64,
    /// Optional work units per iteration (e.g. MACs) for throughput lines.
    pub work_per_iter: Option<f64>,
}

impl BenchStats {
    pub fn throughput(&self) -> Option<f64> {
        self.work_per_iter.map(|w| w / (self.mean_ns * 1e-9))
    }

    /// Machine-readable record for the BENCH_*.json perf-trajectory files.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut fields = vec![
            ("name", Json::str(&self.name)),
            ("iters", Json::num(self.iters as f64)),
            ("ns_per_iter", Json::num(self.mean_ns)),
            ("median_ns", Json::num(self.median_ns)),
        ];
        if let Some(t) = self.throughput() {
            fields.push(("ops_per_s", Json::num(t)));
            fields.push(("gmacs_per_s", Json::num(t / 1e9)));
        }
        Json::obj(fields)
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "{:<44} {:>10} iters  mean {:>12}  median {:>12}  [p05 {} .. p95 {}]",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.p05_ns),
            fmt_ns(self.p95_ns),
        );
        if let Some(t) = self.throughput() {
            s.push_str(&format!("  ({:.3e} ops/s)", t));
        }
        s
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner: warm up for `warmup`, then time iterations until
/// `measure` elapses (at least 5 iterations).
pub struct Bencher {
    pub warmup: Duration,
    pub measure: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup: Duration::from_millis(300), measure: Duration::from_secs(2) }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher { warmup: Duration::from_millis(50), measure: Duration::from_millis(400) }
    }

    /// Run `f` repeatedly; `f` must do one unit of work per call.
    pub fn run<F: FnMut()>(&self, name: &str, work_per_iter: Option<f64>, mut f: F) -> BenchStats {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            f();
        }
        // Measure.
        let mut samples_ns: Vec<f64> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.measure || samples_ns.len() < 5 {
            let t0 = Instant::now();
            f();
            samples_ns.push(t0.elapsed().as_nanos() as f64);
            if samples_ns.len() >= 1_000_000 {
                break;
            }
        }
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples_ns.len();
        let mean = samples_ns.iter().sum::<f64>() / n as f64;
        let pct = |p: f64| samples_ns[((n as f64 - 1.0) * p) as usize];
        BenchStats {
            name: name.to_string(),
            iters: n,
            mean_ns: mean,
            median_ns: pct(0.5),
            p05_ns: pct(0.05),
            p95_ns: pct(0.95),
            work_per_iter,
        }
    }
}

/// Write `BENCH_<name>.json` next to the working directory so the perf
/// trajectory is tracked across PRs (consumed by CI / tooling; schema:
/// `{"benches": [{name, iters, ns_per_iter, median_ns, ops_per_s,
/// gmacs_per_s}]}`).
pub fn save_json(path: &std::path::Path, stats: &[BenchStats]) -> crate::util::error::Result<()> {
    use crate::util::json::Json;
    let j = Json::obj(vec![(
        "benches",
        Json::Arr(stats.iter().map(|s| s.to_json()).collect()),
    )]);
    std::fs::write(path, j.to_string())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn times_something() {
        let b = Bencher { warmup: Duration::from_millis(5), measure: Duration::from_millis(30) };
        let mut acc = 0u64;
        let stats = b.run("spin", Some(1000.0), || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
        });
        assert!(stats.iters >= 5);
        assert!(stats.mean_ns > 0.0);
        assert!(stats.p05_ns <= stats.median_ns && stats.median_ns <= stats.p95_ns);
        assert!(stats.throughput().unwrap() > 0.0);
        std::hint::black_box(acc);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("µs"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_ns(2e9).contains(" s"));
    }

    #[test]
    fn json_roundtrip() {
        let stats = BenchStats {
            name: "case".into(),
            iters: 10,
            mean_ns: 1_000.0,
            median_ns: 900.0,
            p05_ns: 800.0,
            p95_ns: 1_200.0,
            work_per_iter: Some(2_000_000.0),
        };
        let path = std::env::temp_dir().join("BENCH_test.json");
        save_json(&path, &[stats]).unwrap();
        let j = crate::util::json::parse_file(&path).unwrap();
        let b = j.get("benches").idx(0);
        assert_eq!(b.get("name").as_str(), Some("case"));
        assert_eq!(b.get("ns_per_iter").as_f64(), Some(1_000.0));
        // 2e6 ops in 1µs = 2e15 ops/s = 2e6 GMAC/s
        assert!((b.get("gmacs_per_s").as_f64().unwrap() - 2e6).abs() < 1e-3);
    }
}
