//! Minimal JSON substrate (S10 in DESIGN.md).
//!
//! The offline crate cache has no serde, so the repo carries its own JSON
//! value type, recursive-descent parser, and writer.  It covers the full
//! JSON grammar we exchange with the python compile path (manifest, goldens,
//! checkpoints, experiment records): objects, arrays, numbers (f64), strings
//! with escapes, booleans, null.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access; `Json::Null` for anything missing.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    /// Array index access; `Json::Null` out of range.
    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }
    /// Convenience: a numeric array as `Vec<f32>`.
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_f64()).map(|f| f as f32).collect())
    }
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
    }

    pub fn obj(entries: Vec<(&str, Json)>) -> Json {
        Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
    pub fn f32s(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x as f64)).collect())
    }
    pub fn usizes(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x as f64)).collect())
    }
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, ParseError> {
        Err(ParseError { pos: self.pos, msg: msg.to_string() })
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(&format!("expected {:?}", c as char))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(b'N') => self.lit("NaN", Json::Num(f64::NAN)), // python json emits these
            Some(b'I') => self.lit("Infinity", Json::Num(f64::INFINITY)),
            _ => self.err("unexpected character"),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            self.err(&format!("expected literal {s}"))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return self.err("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                .map_err(|_| ParseError { pos: self.pos, msg: "bad utf8".into() })?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| ParseError { pos: self.pos, msg: "bad hex".into() })?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a run of plain utf-8 bytes
                    let start = self.pos;
                    while self.pos < self.b.len()
                        && self.b[self.pos] != b'"'
                        && self.b[self.pos] != b'\\'
                    {
                        self.pos += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| ParseError { pos: start, msg: "bad utf8".into() })?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
            if self.peek() == Some(b'I') {
                self.lit("Infinity", Json::Null)?;
                return Ok(Json::Num(f64::NEG_INFINITY));
            }
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| ParseError { pos: start, msg: format!("bad number {text:?}") })
    }
}

/// Parse a complete JSON document.
pub fn parse(text: &str) -> Result<Json, ParseError> {
    let mut p = Parser { b: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

/// Parse a JSON file.
pub fn parse_file(path: &std::path::Path) -> crate::util::error::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| crate::anyhow!("reading {}: {e}", path.display()))?;
    Ok(parse(&text)?)
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        write!(f, "{}", *n as i64)
                    } else {
                        write!(f, "{n}")
                    }
                } else if n.is_nan() {
                    write!(f, "NaN")
                } else if *n > 0.0 {
                    write!(f, "Infinity")
                } else {
                    write!(f, "-Infinity")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1", "3.5", "\"hi\""] {
            let v = parse(src).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v, "{src}");
        }
    }

    #[test]
    fn nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").idx(2).get("b").as_str(), Some("x\ny"));
        assert!(v.get("c").is_null());
        assert!(v.get("missing").is_null());
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(parse("-2.5e-2").unwrap().as_f64(), Some(-0.025));
        assert_eq!(parse("0.0001").unwrap().as_f64(), Some(0.0001));
    }

    #[test]
    fn python_nonfinite() {
        assert!(parse("NaN").unwrap().as_f64().unwrap().is_nan());
        assert_eq!(parse("Infinity").unwrap().as_f64(), Some(f64::INFINITY));
        assert_eq!(parse("-Infinity").unwrap().as_f64(), Some(f64::NEG_INFINITY));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("{'a':1}").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\u{7}".into());
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""é""#).unwrap().as_str(), Some("é"));
    }

    #[test]
    fn f32_vec_helper() {
        let v = parse("[1, 2.5, -3]").unwrap();
        assert_eq!(v.as_f32_vec().unwrap(), vec![1.0, 2.5, -3.0]);
    }

    #[test]
    fn writer_integers_stay_exact() {
        let v = Json::Num(1234567.0);
        assert_eq!(v.to_string(), "1234567");
    }
}
