//! ASCII table rendering for experiment reports (part of S13).

/// A simple left/right-aligned ASCII table with a header row.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncol {
                s.push_str(&format!(" {:<width$} |", cells[i], width = widths[i]));
            }
            s
        };
        let mut out = String::new();
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out
    }
}

/// CSV rendering of the same data (for results/*.csv).
pub fn to_csv(headers: &[&str], rows: &[Vec<String>]) -> String {
    let esc = |s: &str| {
        if s.contains(',') || s.contains('"') || s.contains('\n') {
            format!("\"{}\"", s.replace('"', "\"\""))
        } else {
            s.to_string()
        }
    };
    let mut out = headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",");
    out.push('\n');
    for row in rows {
        out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["b_PIM", "Method", "Acc."]);
        t.row(&["3".into(), "Baseline".into(), "8.3".into()]);
        t.row(&["3".into(), "Ours".into(), "81.7".into()]);
        let s = t.render();
        assert!(s.contains("| b_PIM | Method   | Acc. |"));
        assert_eq!(s.lines().count(), 6);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        Table::new(&["a", "b"]).row(&["1".into()]);
    }

    #[test]
    fn csv_escaping() {
        let s = to_csv(&["a", "b"], &[vec!["x,y".into(), "q\"z".into()]]);
        assert_eq!(s, "a,b\n\"x,y\",\"q\"\"z\"\n");
    }
}
