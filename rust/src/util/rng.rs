//! RNG substrate (S11): SplitMix64 + xoshiro256** + Box–Muller normal, plus
//! a counter-based generator for order-independent noise sampling.
//!
//! The offline crate cache has no `rand`; the simulator needs deterministic,
//! seedable randomness for thermal noise, curve synthesis, the synthetic
//! dataset, and property tests.  xoshiro256** is the same generator family
//! the `rand_xoshiro` crate ships; SplitMix64 seeds it per Blackman &
//! Vigna's recommendation.
//!
//! [`CounterRng`] is the engine-facing generator (DESIGN.md §RNG contract):
//! every draw is a pure function of `(seed, coordinates, counter)` — a
//! Philox-style construction built from the SplitMix64 finalizer — so the
//! PIM engine's thermal-noise draws do not depend on execution order or
//! thread partitioning.

/// xoshiro256** with Box–Muller Gaussian sampling.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the last Box–Muller pair.
    gauss_spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Deterministic construction from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            gauss_spare: None,
        }
    }

    /// Derive an independent stream (for per-job / per-layer seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free approximation is fine for our n << 2^64.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn int_in(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi >= lo);
        lo + (self.next_u64() % ((hi - lo + 1) as u64)) as i64
    }

    /// Standard normal via Box–Muller (pair-cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.gauss_spare = Some(r * s);
            return r * c;
        }
    }

    /// Normal with mean/std, f32.
    pub fn normal_in(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

const GOLDEN: u64 = 0x9E3779B97F4A7C15;

/// SplitMix64 finalizer: full-avalanche 64-bit mix.
#[inline]
fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Counter-based RNG: stateless draws addressed by coordinates.
///
/// Unlike [`Rng`], a `CounterRng` has no mutable stream — `u64_at(i)` /
/// `normal_at(i)` are pure functions of the (absorbed) seed and the counter,
/// so two threads sampling disjoint coordinate ranges produce exactly the
/// values a single thread would.  This is what makes the multi-threaded PIM
/// engine bit-identical at any thread count.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CounterRng {
    state: u64,
}

impl CounterRng {
    pub fn new(seed: u64) -> Self {
        CounterRng { state: mix(seed.wrapping_add(GOLDEN)) }
    }

    /// Derive the substream for one coordinate (group, plane, row, ...).
    #[inline]
    pub fn stream(&self, coord: u64) -> CounterRng {
        CounterRng {
            state: mix(self.state ^ coord.wrapping_mul(GOLDEN).wrapping_add(0xD1B54A32D192ED03)),
        }
    }

    /// Absorb three coordinates at once (the engine's (group, plane, row)).
    #[inline]
    pub fn stream3(&self, a: u64, b: u64, c: u64) -> CounterRng {
        self.stream(a).stream(b).stream(c)
    }

    /// Raw 64-bit draw at counter `i`.
    #[inline]
    pub fn u64_at(&self, i: u64) -> u64 {
        mix(self.state ^ i.wrapping_mul(GOLDEN))
    }

    /// Uniform f64 in [0, 1) at counter `i`.
    #[inline]
    pub fn uniform_at(&self, i: u64) -> f64 {
        (self.u64_at(i) >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) at counter `i` (the positional twin of
    /// [`Rng::below`]; modulo bias is negligible for our n << 2^64).  Used
    /// by the data loader's per-sample augmentation streams.
    #[inline]
    pub fn below_at(&self, i: u64, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.u64_at(i) % n as u64) as usize
    }

    /// Standard normal at counter `i` (Box–Muller, cosine branch only — no
    /// pair caching, so the draw stays a pure function of position).
    #[inline]
    pub fn normal_at(&self, i: u64) -> f64 {
        let r1 = self.u64_at(i);
        let r2 = mix(r1 ^ GOLDEN);
        // u1 in (0, 1] so ln() is finite; u2 in [0, 1)
        let u1 = ((r1 >> 11) as f64 + 1.0) * (1.0 / (1u64 << 53) as f64);
        let u2 = (r2 >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn int_in_bounds() {
        let mut r = Rng::new(3);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..1000 {
            let v = r.int_in(-7, 7);
            assert!((-7..=7).contains(&v));
            seen_lo |= v == -7;
            seen_hi |= v == 7;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(11);
        let mut f1 = base.fork(1);
        let mut f2 = base.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }

    #[test]
    fn counter_rng_is_positional() {
        let a = CounterRng::new(5);
        let b = CounterRng::new(5);
        // same position, same draw — regardless of access order
        assert_eq!(a.u64_at(1000), b.u64_at(1000));
        assert_eq!(a.stream3(1, 2, 3).normal_at(4), b.stream3(1, 2, 3).normal_at(4));
        assert_ne!(a.u64_at(0), a.u64_at(1));
        assert_ne!(CounterRng::new(5).u64_at(0), CounterRng::new(6).u64_at(0));
        assert_ne!(a.stream3(1, 2, 3).u64_at(0), a.stream3(3, 2, 1).u64_at(0));
    }

    #[test]
    fn counter_normal_moments() {
        let r = CounterRng::new(13);
        let n = 50_000u64;
        let (mut s1, mut s2) = (0.0, 0.0);
        for i in 0..n {
            let z = r.normal_at(i);
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn counter_below_bounds_and_positional() {
        let r = CounterRng::new(40);
        let mut seen = [false; 5];
        for i in 0..500 {
            let v = r.below_at(i, 5);
            assert!(v < 5);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
        assert_eq!(r.below_at(7, 5), CounterRng::new(40).below_at(7, 5));
    }

    #[test]
    fn counter_uniform_range() {
        let r = CounterRng::new(21);
        for i in 0..5_000 {
            let u = r.uniform_at(i);
            assert!((0.0..1.0).contains(&u));
        }
    }
}
