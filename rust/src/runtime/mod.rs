//! PJRT runtime (S1): loads AOT-lowered HLO text artifacts and executes them
//! on the CPU client — the only place the `xla` crate is touched.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO **text** → HloModuleProto
//! → XlaComputation → compile → execute.  Outputs are lowered with
//! `return_tuple=True`, so every execution returns one tuple literal that we
//! decompose into the flat output list the manifest describes.
//!
//! The whole client is gated behind the off-by-default `pjrt` cargo feature
//! (the `xla` bindings crate is not in the offline crate cache).  Without
//! it, [`Runtime`] still loads manifests — so the chip simulator, sweeps
//! over cached checkpoints, and analysis experiments work — but `load`
//! returns an error instead of compiling artifacts.

pub mod literal;
pub mod manifest;

pub use manifest::{ArtifactSpec, DType, Kind, Manifest, ModelEntry};

#[cfg(feature = "pjrt")]
mod client {
    use std::collections::HashMap;
    use std::path::Path;
    use std::sync::Mutex;

    use crate::util::error::{anyhow, Result};
    use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

    use super::manifest::{ArtifactSpec, Manifest};

    /// The PJRT CPU runtime plus a compile cache.
    pub struct Runtime {
        client: PjRtClient,
        pub manifest: Manifest,
        cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
    }

    /// A compiled artifact ready to execute.
    pub struct Executable {
        pub spec: ArtifactSpec,
        exe: PjRtLoadedExecutable,
    }

    // SAFETY: PJRT clients and loaded executables are documented thread-safe
    // in XLA (the C++ objects are internally synchronized; IFRT/PJRT
    // contract).  The rust wrapper types only miss the auto-markers because
    // they hold raw pointers.  We never expose interior mutation beyond the
    // Mutex-guarded compile cache.
    unsafe impl Send for Runtime {}
    unsafe impl Sync for Runtime {}
    unsafe impl Send for Executable {}
    unsafe impl Sync for Executable {}

    impl Runtime {
        /// Create the CPU client and load the manifest from `dir`.
        pub fn new(dir: &Path) -> Result<Self> {
            let manifest = Manifest::load(dir)?;
            let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
            Ok(Runtime { client, manifest, cache: Mutex::new(HashMap::new()) })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile an artifact by manifest name (cached).
        pub fn load(&self, name: &str) -> Result<std::sync::Arc<Executable>> {
            if let Some(e) = self.cache.lock().unwrap().get(name) {
                return Ok(e.clone());
            }
            let spec = self.manifest.artifact(name)?.clone();
            let proto = HloModuleProto::from_text_file(&spec.file)
                .map_err(|e| anyhow!("parsing HLO text {}: {e}", spec.file.display()))?;
            let comp = XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e}"))?;
            let arc = std::sync::Arc::new(Executable { spec, exe });
            self.cache.lock().unwrap().insert(name.to_string(), arc.clone());
            Ok(arc)
        }
    }

    impl Executable {
        /// Execute with the manifest-ordered input literals; returns the
        /// flat output list (tuple decomposed).
        pub fn run(&self, inputs: &[Literal]) -> Result<Vec<Literal>> {
            if inputs.len() != self.spec.inputs.len() {
                return Err(anyhow!(
                    "{}: got {} inputs, artifact expects {}",
                    self.spec.name,
                    inputs.len(),
                    self.spec.inputs.len()
                ));
            }
            let bufs = self
                .exe
                .execute::<Literal>(inputs)
                .map_err(|e| anyhow!("executing {}: {e}", self.spec.name))?;
            let tuple = bufs[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetching result of {}: {e}", self.spec.name))?;
            let outs = tuple
                .to_tuple()
                .map_err(|e| anyhow!("decomposing result tuple of {}: {e}", self.spec.name))?;
            if outs.len() != self.spec.n_outputs {
                return Err(anyhow!(
                    "{}: artifact produced {} outputs, manifest says {}",
                    self.spec.name,
                    outs.len(),
                    self.spec.n_outputs
                ));
            }
            Ok(outs)
        }

        /// Validate a set of input literals against the manifest signature
        /// (shape check); used by tests and the trainer's sanity pass.
        pub fn check_inputs(&self, inputs: &[Literal]) -> Result<()> {
            for (i, (lit, spec)) in inputs.iter().zip(&self.spec.inputs).enumerate() {
                let shape = lit
                    .array_shape()
                    .map_err(|e| anyhow!("input {i} ({}) shape: {e}", spec.name))?;
                let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                if dims != spec.shape {
                    return Err(anyhow!(
                        "input {i} ({}): shape {dims:?} != manifest {:?}",
                        spec.name,
                        spec.shape
                    ));
                }
            }
            Ok(())
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod client {
    use std::path::Path;

    use super::literal::Literal;
    use super::manifest::{ArtifactSpec, Manifest};
    use crate::util::error::{anyhow, Result};

    /// Offline stand-in for the PJRT runtime: the manifest loads (so model
    /// geometry, sweeps over cached checkpoints, and chip-sim evaluation
    /// work), but artifact compilation needs the `pjrt` feature.
    pub struct Runtime {
        pub manifest: Manifest,
    }

    /// Stub executable; never constructed without the `pjrt` feature.
    pub struct Executable {
        pub spec: ArtifactSpec,
    }

    impl Runtime {
        pub fn new(dir: &Path) -> Result<Self> {
            Ok(Runtime { manifest: Manifest::load(dir)? })
        }

        pub fn platform(&self) -> String {
            "none (built without the `pjrt` feature)".to_string()
        }

        pub fn load(&self, name: &str) -> Result<std::sync::Arc<Executable>> {
            Err(anyhow!(
                "cannot compile artifact {name:?}: built without the `pjrt` feature \
                 (enable it and provide the `xla` crate — see rust/Cargo.toml)"
            ))
        }
    }

    impl Executable {
        pub fn run(&self, _inputs: &[Literal]) -> Result<Vec<Literal>> {
            Err(anyhow!("{}: built without the `pjrt` feature", self.spec.name))
        }

        pub fn check_inputs(&self, _inputs: &[Literal]) -> Result<()> {
            Err(anyhow!("{}: built without the `pjrt` feature", self.spec.name))
        }
    }
}

pub use client::{Executable, Runtime};

use crate::util::error::{Context, Result};

/// Open the default runtime (artifacts dir from env / cwd).
pub fn open_default() -> Result<Runtime> {
    let dir = manifest::default_artifacts_dir();
    Runtime::new(&dir).with_context(|| {
        format!(
            "opening artifacts at {} — run `make artifacts` first",
            dir.display()
        )
    })
}
