//! Artifact manifest — the contract written by `python/compile/aot.py`.
//!
//! The manifest pins parameter ordering (flatten_tree), input/output
//! signatures and the model/quant/PIM configuration of every artifact; this
//! module parses it into typed structs the trainer and registry consume.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::error::{anyhow, Context, Result};

use crate::util::json::{parse_file, Json};

/// Tensor dtype in the artifact signature.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

/// One input slot of an artifact.
#[derive(Debug, Clone)]
pub struct InputSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

/// Artifact kind (mirrors aot.py).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Init,
    Train,
    Eval,
    PimEval,
    Kernel,
}

/// One lowered HLO artifact.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub kind: Kind,
    pub model: String,
    pub mode: Option<String>,
    pub scheme: Option<String>,
    pub unit_channels: Option<usize>,
    pub batch: usize,
    pub fwd_rescale: bool,
    pub bwd_rescale: bool,
    pub n_params: usize,
    pub n_state: usize,
    pub n_outputs: usize,
    pub inputs: Vec<InputSpec>,
}

/// A model family's parameter layout.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub arch: String,
    pub depth_n: usize,
    pub width: usize,
    pub image: usize,
    pub classes: usize,
    pub in_channels: usize,
    pub param_paths: Vec<String>,
    pub param_shapes: Vec<Vec<usize>>,
    pub state_paths: Vec<String>,
    pub state_shapes: Vec<Vec<usize>>,
}

impl ModelEntry {
    pub fn param_count(&self) -> usize {
        self.param_shapes.iter().map(|s| s.iter().product::<usize>()).sum()
    }
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub b_w: u32,
    pub b_a: u32,
    pub m_dac: u32,
    pub batch: usize,
    pub models: BTreeMap<String, ModelEntry>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn shapes(j: &Json) -> Vec<Vec<usize>> {
    j.as_arr()
        .map(|a| a.iter().filter_map(|s| s.as_usize_vec()).collect())
        .unwrap_or_default()
}

fn strings(j: &Json) -> Vec<String> {
    j.as_arr()
        .map(|a| a.iter().filter_map(|s| s.as_str().map(String::from)).collect())
        .unwrap_or_default()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let j = parse_file(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;

        let q = j.get("quant");
        let mut models = BTreeMap::new();
        for (key, m) in j.get("models").as_obj().ok_or_else(|| anyhow!("models missing"))? {
            models.insert(
                key.clone(),
                ModelEntry {
                    arch: m.get("arch").as_str().unwrap_or("resnet").to_string(),
                    depth_n: m.get("depth_n").as_usize().unwrap_or(1),
                    width: m.get("width").as_usize().unwrap_or(8),
                    image: m.get("image").as_usize().unwrap_or(16),
                    classes: m.get("classes").as_usize().unwrap_or(10),
                    in_channels: m.get("in_channels").as_usize().unwrap_or(3),
                    param_paths: strings(m.get("param_paths")),
                    param_shapes: shapes(m.get("param_shapes")),
                    state_paths: strings(m.get("state_paths")),
                    state_shapes: shapes(m.get("state_shapes")),
                },
            );
        }

        let mut artifacts = BTreeMap::new();
        for a in j.get("artifacts").as_arr().ok_or_else(|| anyhow!("artifacts missing"))? {
            let name = a.get("name").as_str().ok_or_else(|| anyhow!("artifact name"))?;
            let kind = match a.get("kind").as_str() {
                Some("init") => Kind::Init,
                Some("train") => Kind::Train,
                Some("eval") => Kind::Eval,
                Some("pimeval") => Kind::PimEval,
                Some("kernel") => Kind::Kernel,
                other => return Err(anyhow!("unknown kind {other:?} for {name}")),
            };
            let inputs = a
                .get("inputs")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|i| InputSpec {
                    name: i.get("name").as_str().unwrap_or("").to_string(),
                    shape: i.get("shape").as_usize_vec().unwrap_or_default(),
                    dtype: if i.get("dtype").as_str() == Some("i32") {
                        DType::I32
                    } else {
                        DType::F32
                    },
                })
                .collect();
            artifacts.insert(
                name.to_string(),
                ArtifactSpec {
                    name: name.to_string(),
                    file: dir.join(a.get("file").as_str().unwrap_or("")),
                    kind,
                    model: a.get("model").as_str().unwrap_or("").to_string(),
                    mode: a.get("mode").as_str().map(String::from),
                    scheme: a.get("scheme").as_str().map(String::from),
                    unit_channels: a.get("unit_channels").as_usize(),
                    batch: a.get("batch").as_usize().unwrap_or(0),
                    fwd_rescale: a.get("fwd_rescale").as_bool().unwrap_or(true),
                    bwd_rescale: a.get("bwd_rescale").as_bool().unwrap_or(true),
                    n_params: a.get("n_params").as_usize().unwrap_or(0),
                    n_state: a.get("n_state").as_usize().unwrap_or(0),
                    n_outputs: a.get("n_outputs").as_usize().unwrap_or(0),
                    inputs,
                },
            );
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            b_w: q.get("b_w").as_i64().unwrap_or(4) as u32,
            b_a: q.get("b_a").as_i64().unwrap_or(4) as u32,
            m_dac: q.get("m").as_i64().unwrap_or(4) as u32,
            batch: j.get("batch").as_usize().unwrap_or(32),
            models,
            artifacts,
        })
    }

    /// The built-in model registry used when no `manifest.json` exists:
    /// the native backend needs only model geometry (no lowered artifacts),
    /// so the default zero-dependency build can train without ever running
    /// `make artifacts`.  Quant config and model shapes mirror
    /// `python/compile/configs.py` / the experiment registry.
    pub fn builtin() -> Manifest {
        let mut models = BTreeMap::new();
        for (key, arch, depth_n, width, image, classes) in [
            ("tiny", "resnet", 1usize, 8usize, 16usize, 10usize),
            ("tiny100", "resnet", 1, 8, 16, 100),
            ("small", "resnet", 1, 16, 16, 10),
            ("vgg11", "vgg11", 1, 8, 16, 10),
        ] {
            let mut e = ModelEntry {
                arch: arch.to_string(),
                depth_n,
                width,
                image,
                classes,
                in_channels: 3,
                param_paths: vec![],
                param_shapes: vec![],
                state_paths: vec![],
                state_shapes: vec![],
            };
            let (pspecs, sspecs) = crate::nn::init::param_specs(&e);
            e.param_paths = pspecs.iter().map(|(n, _)| n.clone()).collect();
            e.param_shapes = pspecs.into_iter().map(|(_, s)| s).collect();
            e.state_paths = sspecs.iter().map(|(n, _)| n.clone()).collect();
            e.state_shapes = sspecs.into_iter().map(|(_, s)| s).collect();
            models.insert(key.to_string(), e);
        }
        Manifest {
            dir: PathBuf::from("builtin"),
            b_w: 4,
            b_a: 4,
            m_dac: 4,
            batch: 32,
            models,
            artifacts: BTreeMap::new(),
        }
    }

    /// Load `<dir>/manifest.json` when present, else fall back to the
    /// built-in registry (the native backend's default path).
    pub fn load_or_builtin(dir: &Path) -> Result<Manifest> {
        if dir.join("manifest.json").exists() {
            Manifest::load(dir)
        } else {
            Ok(Manifest::builtin())
        }
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name).ok_or_else(|| {
            anyhow!(
                "artifact {name:?} not in manifest (have: {:?}); re-run `make artifacts`",
                self.artifacts.keys().take(8).collect::<Vec<_>>()
            )
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("model {name:?} not in manifest"))
    }
}

/// Default artifacts dir: $PIM_QAT_ARTIFACTS or ./artifacts.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("PIM_QAT_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join("pimqat_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let text = r#"{
          "quant": {"b_w": 4, "b_a": 4, "m": 4},
          "batch": 32,
          "models": {"tiny": {"arch": "resnet", "depth_n": 1, "width": 8,
            "image": 16, "classes": 10, "in_channels": 3,
            "param_paths": ["conv0/w"], "param_shapes": [[3,3,3,8]],
            "state_paths": ["bn0/mean"], "state_shapes": [[8]]}},
          "artifacts": [{"name": "tiny_init", "file": "tiny_init.hlo.txt",
            "kind": "init", "model": "tiny", "mode": null, "scheme": null,
            "unit_channels": null, "batch": 32, "fwd_rescale": true,
            "bwd_rescale": true, "n_params": 1, "n_state": 1, "n_outputs": 3,
            "inputs": [{"name": "seed", "shape": [], "dtype": "i32"}]}]
        }"#;
        parse(text).unwrap(); // grammar sanity
        std::fs::write(dir.join("manifest.json"), text).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.b_w, 4);
        let a = m.artifact("tiny_init").unwrap();
        assert_eq!(a.kind, Kind::Init);
        assert_eq!(a.inputs[0].dtype, DType::I32);
        assert_eq!(m.model("tiny").unwrap().param_count(), 3 * 3 * 3 * 8);
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn builtin_models_are_complete() {
        let m = Manifest::builtin();
        for key in ["tiny", "tiny100", "small", "vgg11"] {
            let e = m.model(key).unwrap();
            assert!(!e.param_paths.is_empty(), "{key} params");
            assert_eq!(e.param_paths.len(), e.param_shapes.len());
            assert_eq!(e.state_paths.len(), e.state_shapes.len());
            assert!(e.param_count() > 0);
        }
        assert_eq!(m.model("tiny100").unwrap().classes, 100);
        assert_eq!(m.model("small").unwrap().width, 16);
        assert!(m.artifacts.is_empty());
    }

    #[test]
    fn load_or_builtin_falls_back() {
        let dir = std::env::temp_dir().join("pimqat_manifest_missing");
        let _ = std::fs::remove_dir_all(&dir);
        let m = Manifest::load_or_builtin(&dir).unwrap();
        assert!(m.model("tiny").is_ok());
    }
}
