//! Literal ⇄ Tensor conversions at the PJRT boundary.
//!
//! With the `pjrt` feature the [`Literal`] type is `xla::Literal`; without
//! it, a zero-size stub keeps every caller (trainer, benches) compiling
//! while the conversion helpers return a descriptive error at runtime.

#[cfg(feature = "pjrt")]
mod imp {
    use crate::util::error::{anyhow, Result};
    use xla::ArrayElement;
    pub use xla::Literal;

    use crate::tensor::Tensor;

    /// f32 tensor → literal with the tensor's shape.
    pub fn tensor_to_literal(t: &Tensor) -> Result<Literal> {
        let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
        Literal::vec1(&t.data)
            .reshape(&dims)
            .map_err(|e| anyhow!("literal reshape {:?}: {e}", t.shape))
    }

    /// f32 literal → tensor (shape taken from the literal).
    pub fn literal_to_tensor(l: &Literal) -> Result<Tensor> {
        let shape = l.array_shape().map_err(|e| anyhow!("literal shape: {e}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = l.to_vec::<f32>().map_err(|e| anyhow!("literal to_vec: {e}"))?;
        Ok(Tensor::from_vec(&dims, data))
    }

    /// Scalar literals.
    pub fn scalar_f32(v: f32) -> Literal {
        Literal::scalar(v)
    }

    pub fn scalar_i32(v: i32) -> Literal {
        Literal::scalar(v)
    }

    /// i32 vector literal (labels).
    pub fn vec_i32(v: &[i32]) -> Literal {
        Literal::vec1(v)
    }

    /// Extract a scalar from a literal.
    pub fn to_scalar_f32(l: &Literal) -> Result<f32> {
        l.get_first_element::<f32>()
            .map_err(|e| anyhow!("scalar f32: {e}"))
    }

    /// Raw f32 data of a literal (flat).
    pub fn to_vec_f32(l: &Literal) -> Result<Vec<f32>> {
        l.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e}"))
    }

    /// Element count sanity helper.
    pub fn element_count(l: &Literal) -> usize {
        l.element_count()
    }

    /// Build a literal of an arbitrary supported dtype from f32-ish data
    /// (artifact inputs are all f32 or i32 per the manifest).
    pub fn from_spec_data<T: ArrayElement + xla::NativeType>(
        data: &[T],
        shape: &[usize],
    ) -> Result<Literal> {
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        Literal::vec1(data)
            .reshape(&dims)
            .map_err(|e| anyhow!("literal reshape {shape:?}: {e}"))
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use crate::tensor::Tensor;
    use crate::util::error::{anyhow, Error, Result};

    /// Stub literal; carries no data.  Conversions error at runtime.
    #[derive(Debug, Clone, Default)]
    pub struct Literal;

    fn disabled(what: &str) -> Error {
        anyhow!("{what}: built without the `pjrt` feature (see rust/Cargo.toml)")
    }

    pub fn tensor_to_literal(_t: &Tensor) -> Result<Literal> {
        Err(disabled("tensor_to_literal"))
    }

    pub fn literal_to_tensor(_l: &Literal) -> Result<Tensor> {
        Err(disabled("literal_to_tensor"))
    }

    pub fn scalar_f32(_v: f32) -> Literal {
        Literal
    }

    pub fn scalar_i32(_v: i32) -> Literal {
        Literal
    }

    pub fn vec_i32(_v: &[i32]) -> Literal {
        Literal
    }

    pub fn to_scalar_f32(_l: &Literal) -> Result<f32> {
        Err(disabled("to_scalar_f32"))
    }

    pub fn to_vec_f32(_l: &Literal) -> Result<Vec<f32>> {
        Err(disabled("to_vec_f32"))
    }

    pub fn element_count(_l: &Literal) -> usize {
        0
    }

    pub fn from_spec_data<T>(_data: &[T], _shape: &[usize]) -> Result<Literal> {
        Err(disabled("from_spec_data"))
    }
}

pub use imp::*;
