//! Literal ⇄ Tensor conversions at the PJRT boundary.

use anyhow::{anyhow, Result};
use xla::{ArrayElement, Literal};

use crate::tensor::Tensor;

/// f32 tensor → literal with the tensor's shape.
pub fn tensor_to_literal(t: &Tensor) -> Result<Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    Literal::vec1(&t.data)
        .reshape(&dims)
        .map_err(|e| anyhow!("literal reshape {:?}: {e}", t.shape))
}

/// f32 literal → tensor (shape taken from the literal).
pub fn literal_to_tensor(l: &Literal) -> Result<Tensor> {
    let shape = l.array_shape().map_err(|e| anyhow!("literal shape: {e}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = l.to_vec::<f32>().map_err(|e| anyhow!("literal to_vec: {e}"))?;
    Ok(Tensor::from_vec(&dims, data))
}

/// Scalar literals.
pub fn scalar_f32(v: f32) -> Literal {
    Literal::scalar(v)
}

pub fn scalar_i32(v: i32) -> Literal {
    Literal::scalar(v)
}

/// i32 vector literal (labels).
pub fn vec_i32(v: &[i32]) -> Literal {
    Literal::vec1(v)
}

/// Extract a scalar from a literal.
pub fn to_scalar_f32(l: &Literal) -> Result<f32> {
    l.get_first_element::<f32>()
        .map_err(|e| anyhow!("scalar f32: {e}"))
}

/// Raw f32 data of a literal (flat).
pub fn to_vec_f32(l: &Literal) -> Result<Vec<f32>> {
    l.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e}"))
}

/// Element count sanity helper.
pub fn element_count(l: &Literal) -> usize {
    l.element_count()
}

/// Build a literal of an arbitrary supported dtype from f32-ish data
/// (artifact inputs are all f32 or i32 per the manifest).
pub fn from_spec_data<T: ArrayElement + xla::NativeType>(
    data: &[T],
    shape: &[usize],
) -> Result<Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("literal reshape {shape:?}: {e}"))
}
