//! # PIM-QAT — neural network quantization for processing-in-memory systems
//!
//! Reproduction of Jin et al. (2022).  Three-layer architecture:
//!
//! * **L1/L2 (build time, python)** — Pallas PIM-MAC kernel + JAX quantized
//!   model, AOT-lowered to HLO text under `artifacts/` (`make artifacts`).
//! * **L3 (run time, this crate)** — training/experiment coordinator: loads
//!   the HLO artifacts through the PJRT CPU client ([`runtime`]), drives
//!   training ([`train`]), evaluates checkpoints on a bit-accurate chip
//!   simulator ([`pim`], [`chip`], [`nn`]), and regenerates every table and
//!   figure of the paper ([`experiments`]).
//!
//! Python never runs on the request path: once artifacts exist, the
//! `pim-qat` binary is self-contained.  See DESIGN.md for the substrate
//! inventory and the per-experiment index, and EXPERIMENTS.md §Perf for the
//! engine's performance trajectory.
//!
//! The PJRT client is gated behind the off-by-default `pjrt` cargo feature
//! (the `xla` bindings are not in the offline crate cache); the default
//! build has zero external dependencies and covers the chip simulator, the
//! PIM MAC engine, and the analysis experiments.

pub mod chip;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod nn;
pub mod pim;
pub mod report;
pub mod runtime;
pub mod tensor;
pub mod train;
pub mod util;

/// Crate version (CLI `--version`).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
