//! # PIM-QAT — neural network quantization for processing-in-memory systems
//!
//! Reproduction of Jin et al. (2022).  The default build is a complete,
//! zero-dependency PIM-QAT system: training, chip-sim evaluation, and
//! every paper experiment run natively in this crate.
//!
//! * **Training** ([`train`]) — jobs run behind the [`train::Backend`]
//!   trait.  The default [`train::NativeBackend`] hand-rolls the quantized
//!   forward + backward ([`nn::grad`]): PIM-mapped convs execute the
//!   integer MAC engine at the training resolution with the generalized
//!   STE backward (Theorem 1, Eqn. 8), plus forward rescaling η, BN
//!   calibration, and adjusted-precision training.  The alternative PJRT
//!   backend ([`runtime`], behind the off-by-default `pjrt` feature)
//!   executes AOT-lowered HLO artifacts built by the python layer
//!   (`make artifacts`).
//! * **Chip simulator** ([`pim`], [`chip`], [`nn`]) — bit-accurate
//!   integer-native model of Eqn. 1 / Appendix A1: decomposition schemes,
//!   DAC slicing, measured ADC curves, thermal noise, BN calibration.
//! * **Experiments** ([`experiments`]) — regenerates every table and
//!   figure of the paper's evaluation via the [`coordinator`].
//!
//! Python never runs on the request path; with the native backend it never
//! runs at all.  See DESIGN.md for the substrate inventory and the
//! per-experiment index, and EXPERIMENTS.md §Perf for the performance
//! trajectory.

pub mod chip;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod nn;
pub mod pim;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod train;
pub mod util;

/// Crate version (CLI `--version`).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
