//! Post-deployment self-tuning: BN calibration (§3.4) as a standalone
//! field-repair pass for degraded and drifting PIM hardware.
//!
//! The paper uses BN calibration at deployment time to absorb the gap
//! between the ideal training-time chip and the real inference chip.  The
//! same mechanism doubles as a *self-tuning* repair: when a fielded chip
//! degrades (device-to-device spread, drift, stuck columns — the
//! [`crate::chip::faults`] subsystem), streaming a few calibration batches
//! through the **injured** forward path and re-estimating the BN running
//! statistics recovers much of the lost accuracy without touching a single
//! weight.  Gain/offset errors in the ADC columns are, from BN's point of
//! view, just a shifted/scaled activation distribution — exactly what the
//! running statistics normalize away.  (Stuck columns are information loss
//! and stay lost; the recovery is partial by construction.)
//!
//! Exposed as the `pim-qat calibrate` CLI subcommand and used by the
//! experiment ledger to report clean / injured / self-tuned accuracy.

use crate::chip::{ChipModel, FaultModel, FaultProfile};
use crate::config::Scheme;
use crate::data::Dataset;
use crate::nn::{ExecSpec, Network};
use crate::runtime::Manifest;
use crate::util::error::Result;
use crate::util::rng::Rng;

use super::{network_from_ckpt, Checkpoint};

/// What to measure and how hard to calibrate.
#[derive(Debug, Clone, Copy)]
pub struct SelfTuneCfg {
    pub scheme: Scheme,
    pub unit_channels: usize,
    /// Calibration batches streamed through the injured chip (§3.4 uses a
    /// handful; more buys stability, not accuracy).
    pub calib_batches: usize,
    pub batch: usize,
    /// Evaluation subset size (0 = full test set).
    pub test_size: usize,
    pub seed: u64,
}

impl Default for SelfTuneCfg {
    fn default() -> Self {
        SelfTuneCfg {
            scheme: Scheme::BitSerial,
            unit_channels: 8,
            calib_batches: 4,
            batch: 32,
            test_size: 0,
            seed: 1,
        }
    }
}

/// Outcome of one self-tuning pass: the three accuracies of the story and
/// the repaired checkpoint (same weights, re-estimated BN state).
#[derive(Debug, Clone)]
pub struct SelfTuneReport {
    /// Accuracy on the healthy chip (no faults) — the deployment baseline.
    pub clean_acc: f64,
    /// Accuracy on the injured chip, stale BN statistics.
    pub injured_acc: f64,
    /// Accuracy on the injured chip after BN self-tuning.
    pub tuned_acc: f64,
    pub ckpt: Checkpoint,
}

impl SelfTuneReport {
    /// Fraction of the fault-induced accuracy drop recovered by tuning
    /// (0 when nothing was lost).
    pub fn recovered(&self) -> f64 {
        let lost = self.clean_acc - self.injured_acc;
        if lost <= 0.0 {
            0.0
        } else {
            ((self.tuned_acc - self.injured_acc) / lost).clamp(0.0, 1.0)
        }
    }
}

/// The self-tuning core, shared by the offline [`self_tune`] ladder and the
/// serving layer's in-service recovery (`serve::health`): stream `batches`
/// calibration batches of `batch` images through the network's **own**
/// forward path under `chip` and re-estimate every BN layer's running
/// statistics.  The injury is whatever the network already carries — a
/// `ChipModel::faults` binding or, on a serving replica, the per-replica
/// fault model bound through its engine cache (which takes precedence over
/// `chip`) — so a quarantined replica recalibrates through exactly the
/// injured engines it will keep serving on.  No weight is touched.
pub fn recalibrate_network(
    net: &mut Network,
    chip: &ChipModel,
    scheme: Scheme,
    unit_channels: usize,
    calib: &Dataset,
    batch: usize,
    batches: usize,
    rng: &mut Rng,
) -> Result<()> {
    let exec = ExecSpec::Pim { scheme, unit_channels, chip };
    net.calibrate_bn(calib, batch, batches, &exec, rng)
}

/// Run the clean → injured → self-tuned ladder for one checkpoint on one
/// chip + fault profile.  `chip` is the healthy deployment chip (its own
/// `faults` field is ignored); `faults` is the injury under test.  The
/// returned checkpoint carries the tuned BN statistics, so saving it IS the
/// field repair.
pub fn self_tune(
    manifest: &Manifest,
    ckpt: &Checkpoint,
    chip: &ChipModel,
    faults: &FaultProfile,
    cfg: &SelfTuneCfg,
    train_ds: &Dataset,
    test_ds: &Dataset,
) -> Result<SelfTuneReport> {
    let mut healthy = chip.clone();
    healthy.faults = None;
    let mut injured = chip.clone();
    injured.faults = Some(FaultModel::new(*faults));

    let eval_ds;
    let test_ds = if cfg.test_size > 0 && cfg.test_size < test_ds.len() {
        let n = cfg.test_size;
        eval_ds = Dataset {
            images: test_ds.images[..n].to_vec(),
            labels: test_ds.labels[..n].to_vec(),
            classes: test_ds.classes,
        };
        &eval_ds
    } else {
        test_ds
    };

    let mut net = network_from_ckpt(manifest, ckpt)?;
    let mut rng = Rng::new(cfg.seed);

    let clean_exec = ExecSpec::Pim {
        scheme: cfg.scheme,
        unit_channels: cfg.unit_channels,
        chip: &healthy,
    };
    let injured_exec = ExecSpec::Pim {
        scheme: cfg.scheme,
        unit_channels: cfg.unit_channels,
        chip: &injured,
    };

    let clean_acc = net.evaluate(test_ds, cfg.batch, &clean_exec, &mut rng)?;
    let injured_acc = net.evaluate(test_ds, cfg.batch, &injured_exec, &mut rng)?;
    // the self-tuning step: calibration data flows through the SAME
    // injured path the chip will serve inference on (§3.4's requirement,
    // applied to the fault model instead of the nominal chip)
    net.calibrate_bn(train_ds, cfg.batch, cfg.calib_batches, &injured_exec, &mut rng)?;
    let tuned_acc = net.evaluate(test_ds, cfg.batch, &injured_exec, &mut rng)?;

    // repaired checkpoint: same params, BN state overwritten in place
    let mut tuned = ckpt.clone();
    for (name, t) in tuned.state.iter_mut() {
        if let Some(base) = name.strip_suffix("/mean") {
            if let Some((m, _)) = net.bn_stats(base) {
                t.data.clone_from(m);
            }
        } else if let Some(base) = name.strip_suffix("/var") {
            if let Some((_, v)) = net.bn_stats(base) {
                t.data.clone_from(v);
            }
        }
    }
    tuned.meta.insert(
        "self_tuned".to_string(),
        format!("chip {} seed {:#x}", faults.chip_id, faults.seed),
    );

    Ok(SelfTuneReport { clean_acc, injured_acc, tuned_acc, ckpt: tuned })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{JobConfig, Mode};
    use crate::train::{Backend, NativeBackend};

    /// The calibration-recovers-accuracy smoke test: train a micro model,
    /// injure the chip with a BN-recoverable fault profile (gain/offset
    /// spread only — stuck columns are unrecoverable information loss),
    /// and pin the ladder ordering.  Gated behind `PIM_QAT_FAULTS=1`
    /// because it trains a model (seconds, not milliseconds).
    #[test]
    fn self_tuning_recovers_injured_accuracy() {
        if std::env::var("PIM_QAT_FAULTS").map_or(true, |v| v != "1") {
            return;
        }
        let mut manifest = Manifest::builtin();
        let mut e = manifest.models.get("tiny").unwrap().clone();
        e.width = 4;
        e.image = 8;
        e.classes = 4;
        manifest.models.insert("micro".to_string(), e);
        manifest.batch = 8;
        let backend = NativeBackend::new(manifest);

        let job = JobConfig {
            model: "micro".to_string(),
            mode: Mode::Ours,
            scheme: Scheme::BitSerial,
            unit_channels: 8,
            b_pim_train: 7,
            steps: 120,
            lr: 0.05,
            train_size: 256,
            test_size: 128,
            ..Default::default()
        };
        let entry = backend.manifest().model(&job.model).unwrap();
        let (train_ds, test_ds) = crate::data::load_default(
            entry.image,
            entry.classes,
            job.train_size,
            job.test_size,
            0xDA7A ^ job.seed,
        );
        let res = backend.train_job(&job, &train_ds, &test_ds, 50).unwrap();

        // BN-recoverable injury: heavy gain/offset spread, no stuck
        // columns, no noise on the chip so the ladder is deterministic
        let faults = FaultProfile {
            gain_std: 0.15,
            offset_std_lsb: 6.0,
            ..FaultProfile::none().on_chip(3)
        };
        let chip = ChipModel::ideal(7);
        let cfg = SelfTuneCfg {
            scheme: job.scheme,
            unit_channels: job.unit_channels,
            calib_batches: 6,
            batch: 16,
            test_size: 0,
            seed: 1,
        };
        let rep =
            self_tune(backend.manifest(), &res.ckpt, &chip, &faults, &cfg, &train_ds, &test_ds)
                .unwrap();
        // conservative ordering: the injury must not help, and tuning must
        // not hurt the injured chip
        assert!(
            rep.injured_acc <= rep.clean_acc,
            "injury helped? clean {:.1} injured {:.1}",
            rep.clean_acc,
            rep.injured_acc
        );
        assert!(
            rep.tuned_acc >= rep.injured_acc,
            "tuning hurt: injured {:.1} tuned {:.1}",
            rep.injured_acc,
            rep.tuned_acc
        );
        // the repaired checkpoint carries the provenance tag + fresh stats
        assert!(rep.ckpt.meta.contains_key("self_tuned"));
    }
}
