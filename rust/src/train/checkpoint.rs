//! Checkpoint format: `<dir>/ckpt.json` (metadata + tensor index) +
//! `<dir>/params.bin` (little-endian f32, concatenated in index order).

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::error::{anyhow, Context, Result};

use crate::tensor::Tensor;
use crate::util::json::{parse_file, Json};

/// A trained model snapshot: parameters + BN running state.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub model: String,
    /// Extra metadata recorded by the trainer (mode, scheme, b_pim, ...).
    pub meta: BTreeMap<String, String>,
    pub params: Vec<(String, Tensor)>,
    pub state: Vec<(String, Tensor)>,
}

impl Checkpoint {
    pub fn params_map(&self) -> BTreeMap<String, Tensor> {
        self.params.iter().cloned().collect()
    }

    pub fn state_map(&self) -> BTreeMap<String, Tensor> {
        self.state.iter().cloned().collect()
    }

    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let mut bin: Vec<u8> = Vec::new();
        let mut index = Vec::new();
        for (section, entries) in [("param", &self.params), ("state", &self.state)] {
            for (name, t) in entries.iter() {
                index.push(Json::obj(vec![
                    ("section", Json::str(section)),
                    ("name", Json::str(name)),
                    ("shape", Json::usizes(&t.shape)),
                    ("offset", Json::num((bin.len() / 4) as f64)),
                ]));
                for v in &t.data {
                    bin.extend_from_slice(&v.to_le_bytes());
                }
            }
        }
        let meta = Json::Obj(
            self.meta
                .iter()
                .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                .collect(),
        );
        let head = Json::obj(vec![
            ("model", Json::str(&self.model)),
            ("meta", meta),
            ("tensors", Json::Arr(index)),
        ]);
        std::fs::write(dir.join("ckpt.json"), head.to_string())?;
        std::fs::write(dir.join("params.bin"), bin)?;
        Ok(())
    }

    pub fn load(dir: &Path) -> Result<Checkpoint> {
        let head = parse_file(&dir.join("ckpt.json"))
            .with_context(|| format!("loading checkpoint {}", dir.display()))?;
        let bin = std::fs::read(dir.join("params.bin"))?;
        let floats: Vec<f32> = bin
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let mut params = Vec::new();
        let mut state = Vec::new();
        for e in head.get("tensors").as_arr().ok_or_else(|| anyhow!("tensors missing"))? {
            let shape = e.get("shape").as_usize_vec().ok_or_else(|| anyhow!("shape"))?;
            let off = e.get("offset").as_usize().ok_or_else(|| anyhow!("offset"))?;
            let n: usize = shape.iter().product();
            if off + n > floats.len() {
                return Err(anyhow!("checkpoint truncated"));
            }
            let t = Tensor::from_vec(&shape, floats[off..off + n].to_vec());
            let name = e.get("name").as_str().unwrap_or("").to_string();
            match e.get("section").as_str() {
                Some("param") => params.push((name, t)),
                Some("state") => state.push((name, t)),
                s => return Err(anyhow!("bad section {s:?}")),
            }
        }
        let meta = head
            .get("meta")
            .as_obj()
            .map(|o| {
                o.iter()
                    .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
                    .collect()
            })
            .unwrap_or_default();
        Ok(Checkpoint {
            model: head.get("model").as_str().unwrap_or("").to_string(),
            meta,
            params,
            state,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let ck = Checkpoint {
            model: "tiny".into(),
            meta: [("mode".to_string(), "ours".to_string())].into_iter().collect(),
            params: vec![
                ("conv0/w".into(), Tensor::from_vec(&[2, 2], vec![1.5, -2.0, 0.25, 4.0])),
                ("fc/b".into(), Tensor::from_vec(&[3], vec![0.0, 1.0, -1.0])),
            ],
            state: vec![("bn0/mean".into(), Tensor::from_vec(&[2], vec![0.5, 0.75]))],
        };
        let dir = std::env::temp_dir().join("pimqat_ckpt_test");
        ck.save(&dir).unwrap();
        let back = Checkpoint::load(&dir).unwrap();
        assert_eq!(back.model, "tiny");
        assert_eq!(back.meta.get("mode").unwrap(), "ours");
        assert_eq!(back.params.len(), 2);
        assert_eq!(back.params[0].1.data, ck.params[0].1.data);
        assert_eq!(back.state[0].1.data, ck.state[0].1.data);
    }

    #[test]
    fn truncation_detected() {
        let dir = std::env::temp_dir().join("pimqat_ckpt_trunc");
        let ck = Checkpoint {
            model: "t".into(),
            meta: Default::default(),
            params: vec![("w".into(), Tensor::from_vec(&[4], vec![1., 2., 3., 4.]))],
            state: vec![],
        };
        ck.save(&dir).unwrap();
        std::fs::write(dir.join("params.bin"), [0u8; 4]).unwrap();
        assert!(Checkpoint::load(&dir).is_err());
    }
}
