//! Checkpoint format: `<dir>/ckpt.json` (metadata + tensor index) +
//! `<dir>/params.bin` (little-endian f32, concatenated in index order).
//!
//! Writes are crash-safe: both files land as `*.tmp` siblings first and are
//! renamed into place, `params.bin` before `ckpt.json` — the JSON header is
//! the commit point, so a reader never sees a header that references bytes
//! which were not fully written.  An interrupted save leaves at worst stale
//! `*.tmp` litter next to the previous intact checkpoint.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::error::{anyhow, Context, Result};

use crate::tensor::Tensor;
use crate::util::json::{parse_file, Json};

/// Format version written into `meta["ckpt_version"]`.  v1 checkpoints
/// (PRs 1-6) carry no optimizer velocity; v2 adds the `velocity` section.
pub const CKPT_VERSION: &str = "2";

/// A trained model snapshot: parameters + BN running state + (since v2)
/// SGD momentum velocity buffers, so a resumed run continues the same
/// optimizer trajectory instead of restarting momentum from zero.
#[derive(Debug, Clone, Default)]
pub struct Checkpoint {
    pub model: String,
    /// Extra metadata recorded by the trainer (mode, scheme, b_pim, ...).
    pub meta: BTreeMap<String, String>,
    pub params: Vec<(String, Tensor)>,
    pub state: Vec<(String, Tensor)>,
    /// SGD velocity buffers, keyed like `params`.  Empty in v1 checkpoints
    /// and in inference-only snapshots; the section is omitted on disk when
    /// empty so eval-time checkpoints stay as small as before.
    pub velocity: Vec<(String, Tensor)>,
}

impl Checkpoint {
    pub fn params_map(&self) -> BTreeMap<String, Tensor> {
        self.params.iter().cloned().collect()
    }

    pub fn state_map(&self) -> BTreeMap<String, Tensor> {
        self.state.iter().cloned().collect()
    }

    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let mut bin: Vec<u8> = Vec::new();
        let mut index = Vec::new();
        let sections = [
            ("param", &self.params),
            ("state", &self.state),
            ("velocity", &self.velocity),
        ];
        for (section, entries) in sections {
            for (name, t) in entries.iter() {
                index.push(Json::obj(vec![
                    ("section", Json::str(section)),
                    ("name", Json::str(name)),
                    ("shape", Json::usizes(&t.shape)),
                    ("offset", Json::num((bin.len() / 4) as f64)),
                ]));
                for v in &t.data {
                    bin.extend_from_slice(&v.to_le_bytes());
                }
            }
        }
        let meta = Json::Obj(
            self.meta
                .iter()
                .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                .collect(),
        );
        let head = Json::obj(vec![
            ("model", Json::str(&self.model)),
            ("meta", meta),
            ("tensors", Json::Arr(index)),
        ]);
        // tmp + rename on the same directory (and thus filesystem): the
        // payload commits before the header that indexes it
        write_atomic(&dir.join("params.bin"), &bin)?;
        write_atomic(&dir.join("ckpt.json"), head.to_string().as_bytes())?;
        // best-effort directory fsync so the renames survive power loss;
        // ignored where directories can't be fsynced (some filesystems)
        if let Ok(d) = std::fs::File::open(dir) {
            let _ = d.sync_all();
        }
        Ok(())
    }

    /// Scan `root`'s subdirectories for saved checkpoints and load the most
    /// advanced one: highest `meta["step"]` (ties and step-less checkpoints
    /// fall back to directory-name order).  Corrupt or torn entries are
    /// skipped, which is what makes crash recovery a one-liner: point this
    /// at the checkpoint root and resume from whatever survived.
    pub fn load_latest(root: &Path) -> Option<(PathBuf, Checkpoint)> {
        let mut best: Option<(u64, PathBuf, Checkpoint)> = None;
        let entries = std::fs::read_dir(root).ok()?;
        let mut dirs: Vec<PathBuf> = entries
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.join("ckpt.json").is_file())
            .collect();
        dirs.sort();
        for dir in dirs {
            let Ok(ck) = Checkpoint::load(&dir) else { continue };
            let step = ck.meta.get("step").and_then(|s| s.parse::<u64>().ok()).unwrap_or(0);
            if best.as_ref().map_or(true, |(s, _, _)| step >= *s) {
                best = Some((step, dir, ck));
            }
        }
        best.map(|(_, dir, ck)| (dir, ck))
    }

    pub fn load(dir: &Path) -> Result<Checkpoint> {
        let head = parse_file(&dir.join("ckpt.json"))
            .with_context(|| format!("loading checkpoint {}", dir.display()))?;
        let bin = std::fs::read(dir.join("params.bin"))?;
        let floats: Vec<f32> = bin
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let mut params = Vec::new();
        let mut state = Vec::new();
        let mut velocity = Vec::new();
        for e in head.get("tensors").as_arr().ok_or_else(|| anyhow!("tensors missing"))? {
            let shape = e.get("shape").as_usize_vec().ok_or_else(|| anyhow!("shape"))?;
            let off = e.get("offset").as_usize().ok_or_else(|| anyhow!("offset"))?;
            let n: usize = shape.iter().product();
            if off + n > floats.len() {
                return Err(anyhow!("checkpoint truncated"));
            }
            let t = Tensor::from_vec(&shape, floats[off..off + n].to_vec());
            let name = e.get("name").as_str().unwrap_or("").to_string();
            match e.get("section").as_str() {
                Some("param") => params.push((name, t)),
                Some("state") => state.push((name, t)),
                Some("velocity") => velocity.push((name, t)),
                s => return Err(anyhow!("bad section {s:?}")),
            }
        }
        let meta = head
            .get("meta")
            .as_obj()
            .map(|o| {
                o.iter()
                    .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
                    .collect()
            })
            .unwrap_or_default();
        Ok(Checkpoint {
            model: head.get("model").as_str().unwrap_or("").to_string(),
            meta,
            params,
            state,
            velocity,
        })
    }
}

/// Write `bytes` to `path` via a `.tmp` sibling + rename (atomic on POSIX
/// within one filesystem).  The tmp file is fsynced before the rename so
/// the rename never publishes unflushed data.
fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let tmp = path.with_extension("tmp");
    {
        use std::io::Write;
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} into place", tmp.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let ck = Checkpoint {
            model: "tiny".into(),
            meta: [("mode".to_string(), "ours".to_string())].into_iter().collect(),
            params: vec![
                ("conv0/w".into(), Tensor::from_vec(&[2, 2], vec![1.5, -2.0, 0.25, 4.0])),
                ("fc/b".into(), Tensor::from_vec(&[3], vec![0.0, 1.0, -1.0])),
            ],
            state: vec![("bn0/mean".into(), Tensor::from_vec(&[2], vec![0.5, 0.75]))],
            velocity: vec![],
        };
        let dir = std::env::temp_dir().join("pimqat_ckpt_test");
        ck.save(&dir).unwrap();
        let back = Checkpoint::load(&dir).unwrap();
        assert_eq!(back.model, "tiny");
        assert_eq!(back.meta.get("mode").unwrap(), "ours");
        assert_eq!(back.params.len(), 2);
        assert_eq!(back.params[0].1.data, ck.params[0].1.data);
        assert_eq!(back.state[0].1.data, ck.state[0].1.data);
        assert!(back.velocity.is_empty());
    }

    #[test]
    fn velocity_section_roundtrips() {
        let ck = Checkpoint {
            model: "tiny".into(),
            meta: [("ckpt_version".to_string(), CKPT_VERSION.to_string())]
                .into_iter()
                .collect(),
            params: vec![("w".into(), Tensor::from_vec(&[2], vec![1.0, 2.0]))],
            state: vec![],
            velocity: vec![("w".into(), Tensor::from_vec(&[2], vec![0.125, -0.5]))],
        };
        let dir = std::env::temp_dir().join("pimqat_ckpt_vel");
        ck.save(&dir).unwrap();
        let back = Checkpoint::load(&dir).unwrap();
        assert_eq!(back.velocity.len(), 1);
        assert_eq!(back.velocity[0].0, "w");
        assert_eq!(back.velocity[0].1.data, vec![0.125, -0.5]);
        assert_eq!(back.meta.get("ckpt_version").unwrap(), CKPT_VERSION);
    }

    #[test]
    fn truncation_detected() {
        let dir = std::env::temp_dir().join("pimqat_ckpt_trunc");
        let ck = Checkpoint {
            model: "t".into(),
            meta: Default::default(),
            params: vec![("w".into(), Tensor::from_vec(&[4], vec![1., 2., 3., 4.]))],
            state: vec![],
            velocity: vec![],
        };
        ck.save(&dir).unwrap();
        std::fs::write(dir.join("params.bin"), [0u8; 4]).unwrap();
        assert!(Checkpoint::load(&dir).is_err());
    }

    fn ck(model: &str, step: u64, v: f32) -> Checkpoint {
        Checkpoint {
            model: model.into(),
            meta: [("step".to_string(), step.to_string())].into_iter().collect(),
            params: vec![("w".into(), Tensor::from_vec(&[2], vec![v, -v]))],
            state: vec![],
            velocity: vec![],
        }
    }

    #[test]
    fn save_leaves_no_tmp_litter_and_overwrites_cleanly() {
        let dir = std::env::temp_dir().join("pimqat_ckpt_atomic");
        ck("a", 1, 1.0).save(&dir).unwrap();
        ck("a", 2, 2.0).save(&dir).unwrap();
        assert!(!dir.join("ckpt.tmp").exists());
        assert!(!dir.join("params.tmp").exists());
        let back = Checkpoint::load(&dir).unwrap();
        assert_eq!(back.meta.get("step").unwrap(), "2");
        assert_eq!(back.params[0].1.data, vec![2.0, -2.0]);
    }

    #[test]
    fn load_latest_picks_highest_step_and_skips_torn() {
        let root = std::env::temp_dir().join("pimqat_ckpt_latest");
        let _ = std::fs::remove_dir_all(&root);
        ck("a", 10, 1.0).save(&root.join("run_a")).unwrap();
        ck("b", 30, 3.0).save(&root.join("run_b")).unwrap();
        ck("c", 20, 2.0).save(&root.join("run_c")).unwrap();
        // tear the highest-step checkpoint: it must be skipped, not crash
        std::fs::write(root.join("run_b").join("params.bin"), [0u8; 4]).unwrap();
        let (dir, best) = Checkpoint::load_latest(&root).unwrap();
        assert!(dir.ends_with("run_c"), "picked {}", dir.display());
        assert_eq!(best.model, "c");
        assert_eq!(best.meta.get("step").unwrap(), "20");
        // empty root → None
        let empty = root.join("nothing_here");
        std::fs::create_dir_all(&empty).unwrap();
        assert!(Checkpoint::load_latest(&empty).is_none());
    }
}
