//! Training driver (S7): runs PIM-QAT training jobs end-to-end behind the
//! [`Backend`] abstraction.
//!
//! The paper's algorithm (§3) trains *through* the PIM forward model:
//! every PIM-mapped conv executes the quantized grouped MAC of Eqn. 4a at
//! the training resolution `b_pim_train`, the backward pass is the
//! generalized straight-through estimator of Theorem 1 with the backward
//! rescaling `ξ = sqrt(VAR[y_PIM]/VAR[y])` (Eqn. 8), the forward is scaled by η
//! (Table A1, mirrored in [`crate::config::rescale`]), and BN calibration
//! (§3.4) re-estimates running statistics under the deployment chip.
//! Adjusted-precision training (§3.5) is just a `b_pim_train` below the
//! inference resolution.
//!
//! Two interchangeable backends implement [`Backend`]:
//!
//! * [`NativeBackend`] (default, zero dependencies) — hand-rolled forward
//!   + backward in [`crate::train::native`] / [`crate::nn::grad`], SGD with
//!   Nesterov momentum, multi-threaded through the same scoped-thread
//!   machinery as the chip simulator.  Works without any artifacts: model
//!   geometry comes from [`crate::runtime::Manifest::builtin`].
//! * the PJRT [`Runtime`] (behind the off-by-default `pjrt` cargo feature)
//!   — all compute (fwd/bwd/SGD) runs inside the AOT-lowered train-step
//!   executable; this module keeps state, data, schedule and logging.
//!
//! Select with `pim-qat --backend native|pjrt|auto` or the
//! `PIM_QAT_BACKEND` env var (see DESIGN.md §CLI surface); `auto` prefers
//! PJRT when it is compiled in *and* artifacts exist, else native.

pub mod arena;
pub mod calib;
pub mod checkpoint;
pub mod native;
pub mod parallel;
pub mod schedule;

pub use arena::TrainArena;
pub use calib::{recalibrate_network, self_tune, SelfTuneCfg, SelfTuneReport};
pub use checkpoint::Checkpoint;
pub use native::NativeBackend;
pub use parallel::{run_job_parallel, with_parallel, ParallelCfg};

use crate::util::error::{anyhow, Result};
use crate::runtime::literal::Literal;

use crate::config::{rescale, JobConfig, Mode, Scheme};
use crate::data::{Dataset, EpochIter};
use crate::pim::QuantBits;
use crate::runtime::literal::{
    scalar_f32, scalar_i32, tensor_to_literal, to_scalar_f32, to_vec_f32, vec_i32,
};
use crate::runtime::{Kind, Manifest, Runtime};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Per-step log record.
#[derive(Debug, Clone, Copy)]
pub struct StepLog {
    pub step: usize,
    pub loss: f32,
    pub acc: f32,
    pub lr: f32,
}

/// Result of one training job.
pub struct TrainResult {
    pub ckpt: Checkpoint,
    pub history: Vec<StepLog>,
    /// Digital ("Software") test accuracy via the eval artifact.
    pub software_acc: f64,
}

/// A training backend: everything the coordinator, the experiments and the
/// CLI need to run a [`JobConfig`] end-to-end.  Implemented by
/// [`NativeBackend`] (default) and the PJRT [`Runtime`].
pub trait Backend {
    /// Short identifier ("native" / "pjrt"), recorded in checkpoints.
    fn name(&self) -> &'static str;
    /// Human-readable execution-platform line for `pim-qat list`.
    fn platform(&self) -> String;
    /// Model registry (geometry + parameter layout).
    fn manifest(&self) -> &Manifest;
    /// Train one job end-to-end (init → SGD loop → checkpoint → software
    /// eval).
    fn train_job(
        &self,
        job: &JobConfig,
        train_ds: &Dataset,
        test_ds: &Dataset,
        log_every: usize,
    ) -> Result<TrainResult>;
    /// Digital ("Software") test accuracy of a checkpoint.
    fn eval_software(&self, ckpt: &Checkpoint, test_ds: &Dataset) -> Result<f64>;
}

impl Backend for Runtime {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn platform(&self) -> String {
        Runtime::platform(self)
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn train_job(
        &self,
        job: &JobConfig,
        train_ds: &Dataset,
        test_ds: &Dataset,
        log_every: usize,
    ) -> Result<TrainResult> {
        run_job(self, job, train_ds, test_ds, log_every)
    }

    fn eval_software(&self, ckpt: &Checkpoint, test_ds: &Dataset) -> Result<f64> {
        eval_software(self, ckpt, test_ds)
    }
}

/// Which backend to open (CLI `--backend`, `PIM_QAT_BACKEND`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendChoice {
    /// PJRT when compiled in and artifacts exist, else native.
    #[default]
    Auto,
    Native,
    Pjrt,
}

impl std::str::FromStr for BackendChoice {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "auto" => Ok(BackendChoice::Auto),
            "native" => Ok(BackendChoice::Native),
            "pjrt" => Ok(BackendChoice::Pjrt),
            _ => Err(format!("unknown backend {s:?} (auto|native|pjrt)")),
        }
    }
}

/// Open a training backend.  `Auto` resolves to PJRT only when the `pjrt`
/// feature is compiled in *and* lowered artifacts are present; otherwise
/// the zero-dependency native backend.
pub fn open_backend(choice: BackendChoice) -> Result<Box<dyn Backend>> {
    let choice = match choice {
        BackendChoice::Auto => {
            let dir = crate::runtime::manifest::default_artifacts_dir();
            if cfg!(feature = "pjrt") && dir.join("manifest.json").exists() {
                BackendChoice::Pjrt
            } else {
                BackendChoice::Native
            }
        }
        c => c,
    };
    match choice {
        BackendChoice::Native => Ok(Box::new(NativeBackend::open_default()?)),
        BackendChoice::Pjrt => {
            if !cfg!(feature = "pjrt") {
                return Err(anyhow!(
                    "backend \"pjrt\" requested but this binary was built without the \
                     `pjrt` cargo feature — rebuild with --features pjrt (see rust/Cargo.toml), \
                     or use --backend native"
                ));
            }
            Ok(Box::new(crate::runtime::open_default()?))
        }
        BackendChoice::Auto => unreachable!("resolved above"),
    }
}

/// Open the default backend: `PIM_QAT_BACKEND` env var when set, else
/// [`BackendChoice::Auto`].
pub fn open_default_backend() -> Result<Box<dyn Backend>> {
    let choice = match std::env::var("PIM_QAT_BACKEND") {
        Ok(v) => v.parse().map_err(|e: String| anyhow!(e))?,
        Err(_) => BackendChoice::Auto,
    };
    open_backend(choice)
}

/// The AMS additive-noise std (Rekhi et al. 2019) in unit output scale:
/// the RMS of the ideal PIM quantization error of the recombined output,
/// treated as one Gaussian source (their ENOB abstraction).
pub fn ams_sigma(scheme: Scheme, bits: &QuantBits, n: usize, b_pim: u32) -> f32 {
    let levels = ((1u64 << b_pim) - 1) as f64;
    let delta = bits.delta() as f64;
    let fs_base = n as f64 * (delta - 1.0);
    let wl = bits.w_levels() as f64;
    let al = bits.a_levels() as f64;
    // sum over planes of (plane_weight · LSB/√12)²
    let mut var = 0.0f64;
    match scheme {
        Scheme::BitSerial => {
            let lsb = fs_base / levels;
            for k in 0..bits.b_w {
                for l in 0..bits.n_slices() {
                    let pw = 2f64.powi(k as i32) * delta.powi(l as i32);
                    var += (pw * lsb).powi(2) / 12.0;
                }
            }
        }
        Scheme::Native => {
            let lsb = wl * fs_base / levels;
            for l in 0..bits.n_slices() {
                var += (delta.powi(l as i32) * lsb).powi(2) / 12.0;
            }
        }
        Scheme::Differential => {
            let lsb = wl * fs_base / levels;
            for l in 0..bits.n_slices() {
                // two independent conversions per slice
                var += 2.0 * (delta.powi(l as i32) * lsb).powi(2) / 12.0;
            }
        }
    }
    (var.sqrt() / (wl * al)) as f32
}

/// Run one training job end-to-end.
pub fn run_job(
    rt: &Runtime,
    job: &JobConfig,
    train_ds: &Dataset,
    test_ds: &Dataset,
    log_every: usize,
) -> Result<TrainResult> {
    let entry = rt.manifest.model(&job.model)?.clone();
    let bits = QuantBits { b_w: rt.manifest.b_w, b_a: rt.manifest.b_a, m: rt.manifest.m_dac };

    let init = rt.load(&format!("{}_init", job.model))?;
    let train = rt.load(&job.artifact_name())?;
    let spec = train.spec.clone();
    if spec.kind != Kind::Train {
        return Err(anyhow!("{} is not a train artifact", spec.name));
    }
    let (n_p, n_s) = (spec.n_params, spec.n_state);
    let bs = spec.batch;

    // ---- init params/state/momentum inside the lowered init artifact
    let outs = init.run(&[scalar_i32(job.seed as i32)])?;
    if outs.len() != 2 * n_p + n_s {
        return Err(anyhow!("init output arity {}", outs.len()));
    }
    let mut carry: Vec<Literal> = outs; // params ++ state ++ momentum

    // ---- hyper-scalars
    let levels = ((1u64 << job.b_pim_train) - 1) as f32;
    let eta = job
        .eta_override
        .unwrap_or_else(|| rescale::forward_eta(job.scheme, job.b_pim_train));
    // N of the widest PIM-mapped layer geometry (AMS noise scale)
    let n_macs = crate::pim::layout::plan_groups(entry.width, 3, job.unit_channels).n;
    let sigma = if job.mode == Mode::Ams {
        ams_sigma(job.scheme, &bits, n_macs, job.b_pim_train)
    } else {
        0.0
    };
    let lr_sched = schedule::MultiStepLr::new(job.lr, job.milestones, job.steps);

    // ---- training loop
    let mut rng = Rng::new(job.seed ^ 0x7EAC);
    let mut history = Vec::new();
    let mut epoch = EpochIter::new(train_ds.len(), bs, &mut rng);
    for step in 0..job.steps {
        let idx: Vec<usize> = match epoch.next_indices() {
            Some(ix) => ix.to_vec(),
            None => {
                epoch = EpochIter::new(train_ds.len(), bs, &mut rng);
                epoch
                    .next_indices()
                    .ok_or_else(|| anyhow!("dataset smaller than one batch"))?
                    .to_vec()
            }
        };
        let batch = train_ds.batch(&idx, true, &mut rng);
        let lr = lr_sched.at(step);

        let mut inputs: Vec<Literal> = Vec::with_capacity(2 * n_p + n_s + 7);
        inputs.extend(carry.drain(..));
        inputs.push(tensor_to_literal(&batch.x)?);
        inputs.push(vec_i32(&batch.y));
        inputs.push(scalar_f32(lr));
        inputs.push(scalar_f32(levels));
        inputs.push(scalar_f32(eta));
        inputs.push(scalar_f32(sigma));
        inputs.push(scalar_i32(step as i32 ^ ((job.seed as i32) << 8)));

        let mut outs = train.run(&inputs)?;
        let acc_cnt = to_scalar_f32(&outs.pop().unwrap())?;
        let loss = to_scalar_f32(&outs.pop().unwrap())?;
        carry = outs;

        if !loss.is_finite() {
            // diverged (the rescaling-ablation rows do this) — record & stop
            history.push(StepLog { step, loss, acc: 0.0, lr });
            break;
        }
        if step % log_every == 0 || step + 1 == job.steps {
            history.push(StepLog { step, loss, acc: 100.0 * acc_cnt / bs as f32, lr });
        }
    }

    // ---- package checkpoint
    let mut params = Vec::with_capacity(n_p);
    for (i, name) in entry.param_paths.iter().enumerate() {
        let t = Tensor::from_vec(&entry.param_shapes[i], to_vec_f32(&carry[i])?);
        params.push((name.clone(), t));
    }
    let mut state = Vec::with_capacity(n_s);
    for (i, name) in entry.state_paths.iter().enumerate() {
        let t = Tensor::from_vec(&entry.state_shapes[i], to_vec_f32(&carry[n_p + i])?);
        state.push((name.clone(), t));
    }
    let mut meta = std::collections::BTreeMap::new();
    meta.insert("backend".into(), "pjrt".to_string());
    meta.insert("mode".into(), job.mode.to_string());
    meta.insert("scheme".into(), job.scheme.to_string());
    meta.insert("unit_channels".into(), job.unit_channels.to_string());
    meta.insert("b_pim_train".into(), job.b_pim_train.to_string());
    meta.insert("steps".into(), job.steps.to_string());
    let ckpt = Checkpoint { model: job.model.clone(), meta, params, state, velocity: vec![] };

    // ---- software (digital) evaluation through the eval artifact
    let software_acc = eval_software(rt, &ckpt, test_ds)?;

    Ok(TrainResult { ckpt, history, software_acc })
}

/// Digital test accuracy of a checkpoint via the lowered eval artifact.
pub fn eval_software(rt: &Runtime, ckpt: &Checkpoint, test_ds: &Dataset) -> Result<f64> {
    let eval = rt.load(&format!("{}_eval", ckpt.model))?;
    let bs = eval.spec.batch;
    let mut correct = 0.0f64;
    let mut total = 0usize;
    let mut rng = Rng::new(0);
    let n = test_ds.len() / bs * bs;
    for start in (0..n).step_by(bs) {
        let idx: Vec<usize> = (start..start + bs).collect();
        let batch = test_ds.batch(&idx, false, &mut rng);
        let mut inputs: Vec<Literal> = Vec::with_capacity(ckpt.params.len() + ckpt.state.len() + 4);
        for (_, t) in ckpt.params.iter().chain(ckpt.state.iter()) {
            inputs.push(tensor_to_literal(t)?);
        }
        inputs.push(tensor_to_literal(&batch.x)?);
        inputs.push(vec_i32(&batch.y));
        inputs.push(scalar_f32(((1u64 << 20) - 1) as f32));
        inputs.push(scalar_f32(1.0));
        let outs = eval.run(&inputs)?;
        correct += to_scalar_f32(&outs[1])? as f64;
        total += bs;
    }
    Ok(100.0 * correct / total.max(1) as f64)
}

/// Build an `nn::Network` from a checkpoint for chip-sim evaluation.
pub fn network_from_ckpt(manifest: &Manifest, ckpt: &Checkpoint) -> Result<crate::nn::Network> {
    let entry = manifest.model(&ckpt.model)?.clone();
    let bits = QuantBits { b_w: manifest.b_w, b_a: manifest.b_a, m: manifest.m_dac };
    crate::nn::Network::new(entry, bits, ckpt.params_map(), ckpt.state_map())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ams_sigma_shrinks_with_resolution() {
        let bits = QuantBits::default();
        let s3 = ams_sigma(Scheme::BitSerial, &bits, 72, 3);
        let s7 = ams_sigma(Scheme::BitSerial, &bits, 72, 7);
        assert!(s3 > s7 * 10.0, "{s3} vs {s7}");
        assert!(s7 > 0.0);
    }

    #[test]
    fn ams_sigma_grows_with_n() {
        let bits = QuantBits::default();
        assert!(
            ams_sigma(Scheme::BitSerial, &bits, 144, 5)
                > ams_sigma(Scheme::BitSerial, &bits, 72, 5)
        );
    }

    #[test]
    fn ams_sigma_magnitude_sane() {
        // at 7 bits the unit-scale MAC noise should be well below 1
        let bits = QuantBits::default();
        let s = ams_sigma(Scheme::Native, &bits, 9, 7);
        assert!(s < 0.2, "{s}");
    }
}
