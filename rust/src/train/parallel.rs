//! In-process data-parallel training (§Perf L3.10): N replica trainers
//! over disjoint shard streams, a lock-free gradient bus, and a
//! fixed-order deterministic tree all-reduce.
//!
//! ## Execution model
//!
//! A global step processes `M` **microbatch slots** (global batch `M·B`).
//! Slot `m` is handled by physical replica `m % R` (`M % R == 0`), each
//! replica being a full [`NativeTrainer`] with its own
//! [`TrainArena`](super::TrainArena) (engine cache + grown-once buffer
//! pool, the L3.5 contracts hold per replica) and its own [`BatchLoader`]
//! sharded over the global batch stream (`LoaderCfg::sharded(r, R)` —
//! every loader advances the same shuffle stream and materializes a
//! disjoint subset, so each dataset index is seen exactly once per global
//! epoch for any `R`).  Per step, each replica runs forward+backward on
//! its slots (`NativeTrainer::grad_step`), writes each slot's gradients,
//! BN batch statistics and loss/correct scalars into that slot's own flat
//! bus buffer, the slots are tree-reduced, and the **leader replica
//! applies one optimizer update** (`NativeTrainer::apply_reduced`) which
//! is then broadcast in place into every other replica's buffers
//! (`NativeTrainer::adopt_state_from` — engine caches reprogram from the
//! new weights on the next forward, skipping unchanged groups).
//!
//! ## Determinism contract
//!
//! The trajectory is a pure function of the **slot count `M`**, never of
//! the replica count, thread count, or prefetch depth:
//!
//! * every per-slot random stream is keyed by the *global microbatch
//!   counter* `g = step·M + m` — the loader's shuffle/augmentation
//!   coordinates, the per-microbatch noise seed
//!   (`Rng::new(g ^ (seed << 8) ^ 0x5EED)`, the serial trainer's formula
//!   with `g` in place of `step`), and the variability-training fault
//!   replica (`NativeTrainer::set_slot_faults` — keyed by (slot, step),
//!   never by which physical replica ran the slot);
//! * the **GradBus** ([`SlotBank`]) gives each slot its own buffer (one
//!   writer per slot — lock-free by disjoint ownership), and the
//!   all-reduce is the fixed recursive-halving schedule over slot indices:
//!   the floating-point association is a pure function of (layer offset,
//!   slot), never arrival order (`tensor::arena::SlotBank::reduce_tree`);
//! * the reduced sums are scaled by `1/M` and applied once, so at `M = 1`
//!   the whole path is bitwise the serial trainer's (`×1.0` is an f32
//!   identity), and at fixed `M` the trajectories for every valid `R`
//!   (including `R = 1` — "N=1 at global batch M·B") are bitwise
//!   identical.  `tests/train_parallel.rs` pins all of this.
//!
//! The divergence guard and crash-safe resume of the serial
//! [`super::native::run_job_native`] are *not* replicated here: a
//! non-finite mean loss records a [`StepLog`] and stops (the serial driver
//! behaves identically when the guard is out of retries).
//!
//! ## Soundness
//!
//! [`ParallelTrainer`] owns `BatchLoader`s, whose in-flight assembly jobs
//! borrow the dataset with erased lifetimes; the loader's `Drop` joins
//! them.  The public entry points are therefore **scoped**
//! ([`with_parallel`], [`run_job_parallel`]): the trainer value lives on
//! this module's stack frame and callers only ever see `&mut
//! ParallelTrainer`, which cannot be leaked past the dataset borrow
//! (the same contract as `data::loader::with_loader`).

use std::collections::BTreeMap;

use crate::config::JobConfig;
use crate::data::loader::{BatchLoader, LoaderCfg, MAX_PREFETCH};
use crate::data::Dataset;
use crate::runtime::Manifest;
use crate::tensor::arena::SlotBank;
use crate::tensor::{ops, Tensor};
use crate::util::error::{anyhow, Error, Result};
use crate::util::pool;
use crate::util::rng::Rng;

use super::native::{eval_software_native, BnStats, NativeTrainer};
use super::{schedule, Checkpoint, StepLog, TrainResult};

/// Data-parallel execution shape.
#[derive(Debug, Clone)]
pub struct ParallelCfg {
    /// Physical replica trainers (own arena, engine cache, loader each).
    pub replicas: usize,
    /// Global microbatch slots per step (global batch = `slots × B`).
    /// Must be a multiple of `replicas`; the trajectory is a pure function
    /// of this number alone.  [`ParallelCfg::new`] sets `slots = replicas`.
    pub slots: usize,
    /// Loader prefetch override per replica (`None` = env-resolved
    /// default, like the serial driver).
    pub prefetch: Option<usize>,
}

impl ParallelCfg {
    /// `replicas` trainers, one slot each (the common shape: global batch
    /// `replicas × B`).
    pub fn new(replicas: usize) -> ParallelCfg {
        let r = replicas.max(1);
        ParallelCfg { replicas: r, slots: r, prefetch: None }
    }

    /// Validated (replicas, slots).
    fn resolved(&self) -> Result<(usize, usize)> {
        let r = self.replicas.max(1);
        let m = self.slots.max(1);
        if m % r != 0 {
            return Err(anyhow!("slots {m} must be a multiple of replicas {r}"));
        }
        Ok((r, m))
    }
}

/// `$PIM_QAT_REPLICAS` when set and parseable (the env twin of
/// `--replicas`).
pub fn replicas_from_env() -> Option<usize> {
    std::env::var("PIM_QAT_REPLICAS").ok().and_then(|v| v.parse::<usize>().ok())
}

/// Flat-buffer layout of the gradient bus: every parameter gradient (in
/// the fixed sorted order of the parameter map), then each BN layer's
/// (batch-mean, batch-var) pair, then two trailing scalars (loss, correct
/// count).  One such buffer per slot; identical offsets in every slot, so
/// the tree reduce sums corresponding quantities and the reduction order
/// per element is (layer offset, slot) — fixed by construction.
struct BusLayout {
    /// (param name, offset, element count) in `BTreeMap` iteration order.
    params: Vec<(String, usize, usize)>,
    /// (bn name, offset, channels); batch mean at `offset`, batch var at
    /// `offset + channels`.
    bn: Vec<(String, usize, usize)>,
    /// Offset of the two trailing scalars.
    scalar_off: usize,
}

impl BusLayout {
    fn new(
        params: &BTreeMap<String, Tensor>,
        bn: &BTreeMap<String, (Vec<f32>, Vec<f32>)>,
    ) -> BusLayout {
        let mut off = 0usize;
        let mut pv = Vec::with_capacity(params.len());
        for (name, t) in params {
            pv.push((name.clone(), off, t.len()));
            off += t.len();
        }
        let mut bv = Vec::with_capacity(bn.len());
        for (name, (mean, _)) in bn {
            bv.push((name.clone(), off, mean.len()));
            off += 2 * mean.len();
        }
        BusLayout { params: pv, bn: bv, scalar_off: off }
    }

    /// Total bus elements per slot.
    fn len(&self) -> usize {
        self.scalar_off + 2
    }

    /// Serialize one microbatch's contribution into its slot buffer.
    fn write(
        &self,
        grads: &BTreeMap<String, Tensor>,
        stats: &BnStats,
        loss: f32,
        correct: usize,
        buf: &mut [f32],
    ) {
        buf.fill(0.0);
        for (name, off, len) in &self.params {
            let (off, len) = (*off, *len);
            match grads.get(name) {
                Some(g) => {
                    debug_assert_eq!(g.len(), len, "gradient size for {name:?}");
                    buf[off..off + len].copy_from_slice(&g.data);
                }
                None => debug_assert!(false, "no gradient for param {name:?}"),
            }
        }
        for (name, off, c) in &self.bn {
            let (off, c) = (*off, *c);
            match stats.iter().find(|(n, _)| n == name) {
                Some((_, (bm, bv))) => {
                    buf[off..off + c].copy_from_slice(bm);
                    buf[off + c..off + 2 * c].copy_from_slice(bv);
                }
                None => debug_assert!(false, "no batch stats for bn {name:?}"),
            }
        }
        buf[self.scalar_off] = loss;
        buf[self.scalar_off + 1] = correct as f32;
    }

    /// Scatter the reduced sum back out as means (`× inv`, `inv = 1/M` —
    /// at `M = 1` a bitwise identity).  Returns (mean loss, summed correct
    /// count — a count, not an average).
    fn read_into(
        &self,
        sum: &[f32],
        inv: f32,
        grads: &mut BTreeMap<String, Tensor>,
        stats: &mut BnStats,
    ) -> (f32, f32) {
        for (name, off, len) in &self.params {
            let (off, len) = (*off, *len);
            let g = grads.get_mut(name).expect("grads buffer built from the same template");
            for (d, s) in g.data.iter_mut().zip(&sum[off..off + len]) {
                *d = *s * inv;
            }
        }
        for ((name, off, c), (sname, (bm, bv))) in self.bn.iter().zip(stats.iter_mut()) {
            let (off, c) = (*off, *c);
            debug_assert_eq!(name, sname, "stats buffer order");
            for (d, s) in bm.iter_mut().zip(&sum[off..off + c]) {
                *d = *s * inv;
            }
            for (d, s) in bv.iter_mut().zip(&sum[off + c..off + 2 * c]) {
                *d = *s * inv;
            }
        }
        (sum[self.scalar_off] * inv, sum[self.scalar_off + 1])
    }
}

/// The data-parallel driver state: `R` replica trainers + loaders, the
/// slot-sharded gradient bus, and the reduced-gradient staging buffers.
/// Construct through [`with_parallel`] (scoped — see the module docs).
pub struct ParallelTrainer<'ds> {
    trainers: Vec<NativeTrainer>,
    loaders: Vec<BatchLoader<'ds>>,
    layout: BusLayout,
    bank: SlotBank,
    /// Reduced mean gradients, reused every step (template shapes).
    grads_buf: BTreeMap<String, Tensor>,
    /// Reduced mean BN batch statistics, reused every step.
    stats_buf: BnStats,
    step: usize,
    slots: usize,
    seed: u64,
}

impl ParallelTrainer<'_> {
    /// One global step at learning rate `lr`: every slot's microbatch
    /// through its replica (forward + backward, replica-parallel on the
    /// worker pool), tree all-reduce, one leader apply, in-place
    /// broadcast.  Returns (mean loss over slots, correct predictions in
    /// the global batch).  On a non-finite mean loss the apply and
    /// broadcast are skipped, exactly like the serial trainer.
    pub fn step(&mut self, lr: f32) -> Result<(f32, usize)> {
        let (reps, slots) = (self.trainers.len(), self.slots);
        let step = self.step;
        let seed = self.seed;
        let layout = &self.layout;
        let mut errs: Vec<Option<Error>> = Vec::new();
        errs.resize_with(reps, || None);
        {
            let mut slot_bufs: Vec<Option<&mut Vec<f32>>> =
                self.bank.slots_mut().iter_mut().map(Some).collect();
            let mut jobs: Vec<pool::ScopedJob<'_>> = Vec::with_capacity(reps);
            for (r, ((trainer, loader), err)) in self
                .trainers
                .iter_mut()
                .zip(self.loaders.iter_mut())
                .zip(errs.iter_mut())
                .enumerate()
            {
                // slots m ≡ r (mod R), in increasing m order — the order
                // this replica's sharded loader yields them
                let mine: Vec<(usize, &mut Vec<f32>)> = slot_bufs
                    .iter_mut()
                    .enumerate()
                    .skip(r)
                    .step_by(reps)
                    .map(|(m, b)| (m, b.take().expect("each slot has one owner")))
                    .collect();
                jobs.push(Box::new(move || {
                    for (m, buf) in mine {
                        let g = (step * slots + m) as u64;
                        let run = || -> Result<()> {
                            trainer.set_slot_faults(step, m);
                            let (x, y) = loader.next()?;
                            // the serial per-step noise-seed formula, with
                            // the global microbatch counter as the key
                            let mut srng = Rng::new(g ^ (seed << 8) ^ 0x5EED);
                            let (loss, correct, grads, stats) = trainer.grad_step(x, y, &mut srng)?;
                            layout.write(&grads, &stats, loss, correct, buf);
                            Ok(())
                        };
                        if let Err(e) = run() {
                            *err = Some(e);
                            return;
                        }
                    }
                }));
            }
            pool::run_scoped(jobs);
        }
        if let Some(e) = errs.into_iter().flatten().next() {
            return Err(e);
        }
        self.step += 1;

        // fixed-order tree all-reduce, then scatter the means
        let inv = 1.0 / slots as f32;
        let sum = self.bank.reduce_tree();
        let (loss, correct) =
            layout.read_into(sum, inv, &mut self.grads_buf, &mut self.stats_buf);
        let correct = correct as usize;
        if !loss.is_finite() {
            return Ok((loss, correct));
        }

        // one optimizer update on the leader, broadcast in place
        let (leader, rest) = self.trainers.split_at_mut(1);
        leader[0].apply_reduced(&self.grads_buf, &self.stats_buf, lr)?;
        for t in rest.iter_mut() {
            t.adopt_state_from(&leader[0]);
        }
        Ok((loss, correct))
    }

    /// Global steps completed.
    pub fn steps_done(&self) -> usize {
        self.step
    }

    /// Snapshot the leader replica into a checkpoint (all replicas hold
    /// identical state between steps — the broadcast invariant).
    pub fn checkpoint(&self, job: &JobConfig) -> Checkpoint {
        self.trainers[0].checkpoint(job)
    }
}

/// Run `f` with a [`ParallelTrainer`] over `train_ds` — the sound scoped
/// entry point (module docs §Soundness).  Builds `R` replica trainers and
/// sharded loaders, pre-grows the worker pool for `R` concurrent replicas
/// at the per-replica `$PIM_QAT_THREADS` budget
/// (`pool::reserve_for`), and lends `f` the driver.
pub fn with_parallel<R>(
    manifest: &Manifest,
    job: &JobConfig,
    train_ds: &Dataset,
    pcfg: &ParallelCfg,
    f: impl FnOnce(&mut ParallelTrainer<'_>) -> R,
) -> Result<R> {
    let (reps, slots) = pcfg.resolved()?;
    let bs = manifest.batch.max(1);
    pool::reserve_for(reps, ops::resolve_threads(0));
    let trainers: Vec<NativeTrainer> =
        (0..reps).map(|_| NativeTrainer::new(manifest, job)).collect::<Result<_>>()?;
    let mut loaders = Vec::with_capacity(reps);
    for r in 0..reps {
        let mut cfg = LoaderCfg::for_training(bs, job.seed ^ 0x7EAC).sharded(r, reps);
        if let Some(p) = pcfg.prefetch {
            cfg.prefetch = p.min(MAX_PREFETCH);
        }
        loaders.push(BatchLoader::new(train_ds, cfg)?);
    }
    let layout = BusLayout::new(trainers[0].param_template(), trainers[0].bn_template());
    let bank = SlotBank::new(slots, layout.len());
    let grads_buf = trainers[0].param_template().clone();
    let stats_buf: BnStats = trainers[0]
        .bn_template()
        .iter()
        .map(|(k, (m, _))| (k.clone(), (vec![0.0; m.len()], vec![0.0; m.len()])))
        .collect();
    let mut pt = ParallelTrainer {
        trainers,
        loaders,
        layout,
        bank,
        grads_buf,
        stats_buf,
        step: 0,
        slots,
        seed: job.seed,
    };
    Ok(f(&mut pt))
}

/// Run one training job under the data-parallel driver — the replicated
/// twin of [`super::native::run_job_native`].  At `replicas = slots = 1`
/// the produced history and checkpoint are bitwise the serial driver's
/// (pinned in `tests/train_parallel.rs`); at higher slot counts the
/// trajectory is the fixed global-batch-`slots·B` trajectory, whatever
/// the replica count.
pub fn run_job_parallel(
    manifest: &Manifest,
    job: &JobConfig,
    train_ds: &Dataset,
    test_ds: &Dataset,
    log_every: usize,
    pcfg: &ParallelCfg,
) -> Result<TrainResult> {
    let log_every = log_every.max(1);
    let (reps, slots) = pcfg.resolved()?;
    let bs = manifest.batch.max(1);
    let lr_sched = schedule::MultiStepLr::new(job.lr, job.milestones, job.steps);
    println!(
        "data-parallel: {reps} replica trainer(s) x batch {bs} ({slots} slot(s), \
         global batch {}), fixed-order tree all-reduce",
        slots * bs
    );
    let mut history = Vec::new();
    let ckpt = with_parallel(manifest, job, train_ds, pcfg, |pt| -> Result<Checkpoint> {
        for step in 0..job.steps {
            let lr = lr_sched.at(step);
            let (loss, correct) = pt.step(lr)?;
            if !loss.is_finite() {
                eprintln!("warning: training diverged at step {step} (loss {loss}); stopping");
                history.push(StepLog { step, loss, acc: 0.0, lr });
                break;
            }
            if step % log_every == 0 || step + 1 == job.steps {
                let acc = 100.0 * correct as f32 / (slots * bs) as f32;
                history.push(StepLog { step, loss, acc, lr });
            }
        }
        Ok(pt.checkpoint(job))
    })??;
    let software_acc = eval_software_native(manifest, &ckpt, test_ds)?;
    Ok(TrainResult { ckpt, history, software_acc })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cfg_validation() {
        assert_eq!(ParallelCfg::new(0).resolved().unwrap(), (1, 1));
        assert_eq!(ParallelCfg::new(3).resolved().unwrap(), (3, 3));
        let mut c = ParallelCfg::new(2);
        c.slots = 4;
        assert_eq!(c.resolved().unwrap(), (2, 4));
        c.slots = 3;
        assert!(c.resolved().is_err());
    }

    #[test]
    fn bus_layout_roundtrips_grads_stats_and_scalars() {
        let mut params = BTreeMap::new();
        params.insert("a/w".to_string(), Tensor::from_vec(&[2, 2], vec![0.0; 4]));
        params.insert("b/w".to_string(), Tensor::from_vec(&[3], vec![0.0; 3]));
        let mut bn = BTreeMap::new();
        bn.insert("a/bn".to_string(), (vec![0.0; 2], vec![0.0; 2]));
        let layout = BusLayout::new(&params, &bn);
        assert_eq!(layout.len(), 4 + 3 + 2 * 2 + 2);

        let mut grads = params.clone();
        grads.get_mut("a/w").unwrap().data.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        grads.get_mut("b/w").unwrap().data.copy_from_slice(&[5.0, 6.0, 7.0]);
        // recording order differs from sorted order on purpose: the bus is
        // keyed by name, not by arrival
        let stats: BnStats = vec![("a/bn".to_string(), (vec![0.5, 0.25], vec![1.5, 2.5]))];
        let mut buf = vec![f32::NAN; layout.len()];
        layout.write(&grads, &stats, 0.75, 6, &mut buf);

        let mut out_g = params.clone();
        let mut out_s: BnStats = vec![("a/bn".to_string(), (vec![0.0; 2], vec![0.0; 2]))];
        let (loss, correct) = layout.read_into(&buf, 0.5, &mut out_g, &mut out_s);
        assert_eq!(out_g.get("a/w").unwrap().data, vec![0.5, 1.0, 1.5, 2.0]);
        assert_eq!(out_g.get("b/w").unwrap().data, vec![2.5, 3.0, 3.5]);
        assert_eq!(out_s[0].1 .0, vec![0.25, 0.125]);
        assert_eq!(out_s[0].1 .1, vec![0.75, 1.25]);
        assert_eq!(loss, 0.375);
        assert_eq!(correct, 6.0, "correct is a summed count, never averaged");
    }

    #[test]
    fn read_at_unit_inverse_is_bitwise_identity() {
        let mut params = BTreeMap::new();
        params.insert("w".to_string(), Tensor::from_vec(&[3], vec![0.0; 3]));
        let bn = BTreeMap::new();
        let layout = BusLayout::new(&params, &bn);
        let mut grads = params.clone();
        let vals = [1.0e-30f32, -3.5, 7.0 / 3.0];
        grads.get_mut("w").unwrap().data.copy_from_slice(&vals);
        let mut buf = vec![0.0; layout.len()];
        layout.write(&grads, &Vec::new(), 1.0 / 3.0, 2, &mut buf);
        let mut out = params.clone();
        let (loss, _) = layout.read_into(&buf, 1.0, &mut out, &mut Vec::new());
        assert_eq!(out.get("w").unwrap().data.as_slice(), &vals, "×1.0 must be exact");
        assert_eq!(loss.to_bits(), (1.0f32 / 3.0).to_bits());
    }
}
