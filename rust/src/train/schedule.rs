//! Learning-rate schedule (paper §A2.1: multi-step, ×0.1 at 50% and 75% of
//! the budget — 100/150 of 200 epochs, expressed as fractions here so short
//! reproduction schedules keep the same shape).

/// Multi-step LR: `base` until `m1·steps`, ×0.1 until `m2·steps`, ×0.01 after.
#[derive(Debug, Clone, Copy)]
pub struct MultiStepLr {
    pub base: f32,
    pub m1: f64,
    pub m2: f64,
    pub steps: usize,
}

impl MultiStepLr {
    pub fn new(base: f32, milestones: (f64, f64), steps: usize) -> Self {
        MultiStepLr { base, m1: milestones.0, m2: milestones.1, steps }
    }

    pub fn at(&self, step: usize) -> f32 {
        let f = step as f64 / self.steps.max(1) as f64;
        if f < self.m1 {
            self.base
        } else if f < self.m2 {
            self.base * 0.1
        } else {
            self.base * 0.01
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_shape() {
        let s = MultiStepLr::new(0.1, (0.5, 0.75), 200);
        assert_eq!(s.at(0), 0.1);
        assert_eq!(s.at(99), 0.1);
        assert!((s.at(100) - 0.01).abs() < 1e-9);
        assert!((s.at(149) - 0.01).abs() < 1e-9);
        assert!((s.at(150) - 0.001).abs() < 1e-9);
        assert!((s.at(199) - 0.001).abs() < 1e-9);
    }

    #[test]
    fn zero_steps_safe() {
        let s = MultiStepLr::new(0.1, (0.5, 0.75), 0);
        let _ = s.at(0);
    }
}
