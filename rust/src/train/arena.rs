//! Per-job persistent training state that outlives a step (EXPERIMENTS.md
//! §Perf L3.5): the grown-once buffer pool and the cached per-layer PIM
//! engines.
//!
//! Ownership rules (DESIGN.md §Arena): a buffer taken from the pool either
//! returns to it within the same step (transients — quantized u8 grids,
//! integer-weight staging, scaled-gradient copies, transposed-GEMM
//! outputs) or rides inside a tape and is reclaimed when the backward pass
//! consumes that tape (im2col patches).  Engines are keyed by layer name
//! and reprogrammed in place each step; a geometry, scheme or bit-width
//! change rebuilds them.

use crate::config::Scheme;
use crate::pim::{EngineCache, QuantBits};
use crate::tensor::arena::BufPool;

/// Reusable state threaded through the native trainer's hot loop.
#[derive(Default)]
pub struct TrainArena {
    /// Grown-once flat buffers (patches, u8 grids, GEMM scratch, …).
    pub pool: BufPool,
    /// One persistent engine per PIM conv layer, reprogrammed in place —
    /// the same [`EngineCache`] keying the evaluation path uses
    /// (`pim::cache`).
    pub engines: EngineCache,
}

impl TrainArena {
    pub fn new() -> Self {
        TrainArena::default()
    }

    /// Make sure the cached engine for layer `name` exists, matches the
    /// layer geometry, and carries this step's integer weights `w_int`
    /// ([C·k·k, O], im2col column order).  Cache hit → in-place
    /// [`crate::pim::PimEngine::reprogram`] (unchanged groups skipped);
    /// miss, or a scheme / bits / shape change → fresh
    /// [`crate::pim::PimEngine::prepare_cols`].  Delegates to
    /// [`EngineCache::ensure_engine`].
    #[allow(clippy::too_many_arguments)]
    pub fn ensure_engine(
        &mut self,
        name: &str,
        scheme: Scheme,
        bits: QuantBits,
        w_int: &[f32],
        out: usize,
        c_in: usize,
        kernel: usize,
        unit_channels: usize,
    ) {
        self.engines.ensure_engine(name, scheme, bits, w_int, out, c_in, kernel, unit_channels);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chip::ChipModel;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    #[test]
    fn ensure_engine_caches_and_invalidates() {
        let mut arena = TrainArena::new();
        let bits = QuantBits::default();
        let mut rng = Rng::new(3);
        let (c, k, o, uc) = (2usize, 3usize, 4usize, 1usize);
        let w: Vec<f32> = (0..c * k * k * o).map(|_| rng.int_in(-7, 7) as f32).collect();
        arena.ensure_engine("l0", Scheme::BitSerial, bits, &w, o, c, k, uc);
        assert_eq!(arena.engines.len(), 1);
        // same geometry: cache hit, engine reprogrammed in place
        arena.ensure_engine("l0", Scheme::BitSerial, bits, &w, o, c, k, uc);
        assert_eq!(arena.engines.len(), 1);
        // scheme change invalidates (rebuild under the same key)
        arena.ensure_engine("l0", Scheme::Native, bits, &w, o, c, k, uc);
        assert_eq!(arena.engines.len(), 1);
        assert_eq!(arena.engines.get("l0").unwrap().scheme, Scheme::Native);
        // the cached engine executes
        let a = Tensor::from_vec(
            &[2, c * k * k],
            (0..2 * c * k * k).map(|_| rng.int_in(0, 15) as f32).collect(),
        );
        let mut nrng = Rng::new(0);
        let y = arena.engines.get("l0").unwrap().matmul(&a, &ChipModel::ideal(7), &mut nrng);
        assert_eq!(y.shape, vec![2, o]);
    }
}
