//! The native training backend: hand-rolled forward + backward for the
//! `nn::Network` topologies, zero external dependencies.
//!
//! This is the default implementation of [`super::Backend`].  One train
//! step mirrors `python/compile/train.py::make_train_step` exactly:
//!
//! * forward in training mode — digital first conv / shortcuts / FC
//!   (modified DoReFa, Eqn. A20), PIM-mapped convs through the integer
//!   [`crate::pim::PimEngine`] at the training resolution (`mode=ours`, Eqn. 4a) or the
//!   digital product (`baseline`; `ams` adds the Rekhi-et-al additive
//!   Gaussian), batch-statistics BN with running-stat momentum updates;
//! * backward — straight-through estimators for every quantizer
//!   ([`crate::nn::grad`]); the PIM matmul uses the generalized STE of
//!   Theorem 1: the exact-matmul backward scaled by η·ξ with
//!   `ξ = sqrt(VAR[y_PIM]/VAR[y])` (Eqn. 8, recomputed per layer per step);
//! * update — SGD with Nesterov momentum 0.9, weight decay 1e-4, and the
//!   multi-step LR schedule owned by the caller.
//!
//! ## Step lifecycle (§Perf L3.7)
//!
//! Training is staged as an explicit `acquire → forward → backward →
//! apply` pipeline (DESIGN.md §Data pipeline):
//!
//! * **acquire** — [`run_job_native`] pulls batches from a
//!   [`crate::data::loader::BatchLoader`], which shards next-batch
//!   assembly + augmentation across the worker pool and (at
//!   `PIM_QAT_PREFETCH ≥ 1`, the default) overlaps it with this step's
//!   compute.  Counter-keyed augmentation makes the pipelined loop
//!   bit-identical to the serial one.
//! * **forward / backward / apply** — [`NativeTrainer::train_step`], now a
//!   thin driver over three named stages: the training-mode network pass
//!   saving tapes, the tape-consuming gradient pass, and the BN-stat +
//!   Nesterov-SGD update.
//!
//! Heavy ops (im2col/col2im, the PIM plane GEMMs, the ξ digital twin, batch
//! assembly) run multi-threaded on the shared worker pool (`util::pool`);
//! set `PIM_QAT_THREADS` to pin the worker count.
//!
//! §Perf L3.5 + L3.7 (EXPERIMENTS.md): the hot loop is built around
//! persistent, incrementally-updated state in a [`TrainArena`] — one
//! cached [`crate::pim::PimEngine`] per PIM conv, reprogrammed in place each step
//! with unchanged groups skipped, plus a grown-once buffer pool that since
//! L3.7 owns **every** step-scale temporary: patch buffers *and* the
//! feature-map intermediates (conv/BN/activation outputs, STE masks,
//! gradient maps).  From step 2 on, a train step performs zero large
//! allocations end to end (pinned by the `alloc`-counter test below).

use std::collections::BTreeMap;

use crate::util::error::{anyhow, Result};

use crate::chip::{ChipModel, FaultProfile};
use crate::config::{rescale, JobConfig, Mode, Scheme};
use crate::data::loader::{self, LoaderCfg};
use crate::data::Dataset;
use crate::nn::{grad, init, quant, vgg11_plan, ExecSpec};
use crate::pim::QuantBits;
use crate::runtime::Manifest;
use crate::runtime::ModelEntry;
use crate::tensor::arena::BufPool;
use crate::tensor::gemm::{gemm, gemm_acc, gemm_into, gemm_nt, gemm_tn, gemm_tn_into};
use crate::tensor::{ops, Tensor};
use crate::util::pool;
use crate::util::rng::Rng;
use crate::util::Welford;

use super::{schedule, Backend, Checkpoint, StepLog, TrainArena, TrainResult};

/// The zero-dependency training backend (default).  Holds only the model
/// registry; per-job state lives in [`NativeTrainer`].
pub struct NativeBackend {
    manifest: Manifest,
}

impl NativeBackend {
    pub fn new(manifest: Manifest) -> Self {
        NativeBackend { manifest }
    }

    /// Open with the artifact manifest when present (`$PIM_QAT_ARTIFACTS`
    /// or `./artifacts`), else the built-in model registry — the native
    /// backend needs geometry only, never lowered HLO.
    pub fn open_default() -> Result<Self> {
        let dir = crate::runtime::manifest::default_artifacts_dir();
        Ok(NativeBackend { manifest: Manifest::load_or_builtin(&dir)? })
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn platform(&self) -> String {
        "native (in-crate fwd/bwd, zero dependencies)".to_string()
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn train_job(
        &self,
        job: &JobConfig,
        train_ds: &Dataset,
        test_ds: &Dataset,
        log_every: usize,
    ) -> Result<TrainResult> {
        run_job_native(&self.manifest, job, train_ds, test_ds, log_every)
    }

    fn eval_software(&self, ckpt: &Checkpoint, test_ds: &Dataset) -> Result<f64> {
        eval_software_native(&self.manifest, ckpt, test_ds)
    }
}

/// Interval (steps) between the divergence guard's in-memory snapshots and
/// — when `PIM_QAT_RESUME` is set — the periodic crash-safe checkpoints.
const SNAP_EVERY: usize = 25;

/// Rollback attempts before the guard gives up and records the divergence.
const MAX_ROLLBACKS: usize = 3;

/// Bounded-retry divergence guard: on a non-finite loss, roll the trainer
/// back to the last in-memory snapshot with a decayed LR, up to
/// [`MAX_ROLLBACKS`] times.  Disabled for the rescaling-ablation variants
/// (Table A3's `norescale`/`nofwd` rows), whose divergence IS the
/// measurement and must be recorded, not rescued.
struct DivergenceGuard {
    enabled: bool,
    lr_scale: f32,
    rollbacks: usize,
}

impl DivergenceGuard {
    fn new(enabled: bool) -> Self {
        DivergenceGuard { enabled, lr_scale: 1.0, rollbacks: 0 }
    }

    /// A non-finite loss was observed: halve the LR scale and approve one
    /// rollback, or `None` when the guard is disabled / out of attempts.
    fn on_divergence(&mut self) -> Option<f32> {
        if !self.enabled || self.rollbacks >= MAX_ROLLBACKS {
            return None;
        }
        self.rollbacks += 1;
        self.lr_scale *= 0.5;
        Some(self.lr_scale)
    }
}

/// Run one training job on the native backend (the native twin of
/// [`super::run_job`]), staged as the explicit step lifecycle: the
/// [`crate::data::loader::BatchLoader`] is the *acquire* stage (shuffling,
/// augmentation, prefetch — with `PIM_QAT_PREFETCH ≥ 1` the next batch
/// assembles on the worker pool while this step's backward runs), and
/// [`NativeTrainer::train_step`] is forward → backward → apply.
///
/// Robustness plumbing (this layer, not the trainer): the divergence guard
/// above, and — when `PIM_QAT_RESUME=<dir>` is set — crash-safe operation:
/// the job resumes from the most advanced intact checkpoint under that
/// directory and writes an atomic checkpoint there every [`SNAP_EVERY`]
/// steps.
pub fn run_job_native(
    manifest: &Manifest,
    job: &JobConfig,
    train_ds: &Dataset,
    test_ds: &Dataset,
    log_every: usize,
) -> Result<TrainResult> {
    let log_every = log_every.max(1);
    let mut trainer = NativeTrainer::new(manifest, job)?;
    let bs = manifest.batch.max(1);
    let lr_sched = schedule::MultiStepLr::new(job.lr, job.milestones, job.steps);

    let resume_dir = std::env::var("PIM_QAT_RESUME").ok().map(std::path::PathBuf::from);
    let mut start_step = 0usize;
    if let Some(root) = &resume_dir {
        if let Some((dir, ck)) = Checkpoint::load_latest(root) {
            if ck.model == job.model {
                start_step = trainer.restore_from_checkpoint(&ck)?;
                eprintln!(
                    "resuming {} from {} at step {start_step}",
                    job.model,
                    dir.display()
                );
            }
        }
    }

    let mut guard = DivergenceGuard::new(job.variant.is_empty());
    let mut snapshot: Option<(usize, TrainerSnapshot)> = None;

    let mut history = Vec::new();
    let cfg = LoaderCfg::for_training(bs, job.seed ^ 0x7EAC);
    // the scoped loader entry point joins any in-flight assembly before
    // the dataset borrow ends (data::loader module docs)
    loader::with_loader(train_ds, cfg, |loader| -> Result<()> {
        let mut step = start_step;
        while step < job.steps {
            if guard.enabled && (snapshot.is_none() || step % SNAP_EVERY == 0) {
                snapshot = Some((step, trainer.snapshot()));
            }
            if let Some(root) = &resume_dir {
                if step > start_step && step % SNAP_EVERY == 0 {
                    let mut ck = trainer.checkpoint(job);
                    ck.meta.insert("step".to_string(), step.to_string());
                    ck.save(&root.join("latest"))?;
                }
            }
            // -- acquire (stage 1): batch slot, assembled ahead under
            // prefetch
            let (x, y) = loader.next()?;
            let lr = lr_sched.at(step) * guard.lr_scale;
            // variability-aware training: fresh fault replica per step
            trainer.set_step_faults(step);
            // per-step noise stream (AMS mode), mirroring the per-step
            // seed of the lowered train artifact
            let mut srng = Rng::new((step as u64) ^ (job.seed << 8) ^ 0x5EED);
            // -- forward / backward / apply (stages 2–4)
            let (loss, correct) = trainer.train_step(x, y, lr, &mut srng)?;

            if !loss.is_finite() {
                if let (Some(scale), Some((snap_step, snap))) =
                    (guard.on_divergence(), &snapshot)
                {
                    // roll back to the last good state with a smaller LR.
                    // The loader is deliberately NOT rewound: the retry
                    // sees fresh batches, which is part of the escape.
                    eprintln!(
                        "warning: non-finite loss at step {step}; rolling back to \
                         step {snap_step} with lr scale {scale}"
                    );
                    trainer.restore_snapshot(snap);
                    step = *snap_step;
                    continue;
                }
                // diverged for real (the rescaling-ablation rows do this,
                // with the guard off) — record & stop
                eprintln!("warning: training diverged at step {step} (loss {loss}); stopping");
                history.push(StepLog { step, loss, acc: 0.0, lr });
                break;
            }
            if step % log_every == 0 || step + 1 == job.steps {
                let acc = 100.0 * correct as f32 / bs as f32;
                history.push(StepLog { step, loss, acc, lr });
            }
            step += 1;
        }
        Ok(())
    })??;

    let ckpt = trainer.into_checkpoint(job);
    let software_acc = eval_software_native(manifest, &ckpt, test_ds)?;
    Ok(TrainResult { ckpt, history, software_acc })
}

/// Digital test accuracy of a checkpoint on the native path (the
/// `ExecSpec::Software` forward — the b_PIM = +∞ limit the eval artifact
/// approximates with `levels = 2^20 - 1`).
pub fn eval_software_native(
    manifest: &Manifest,
    ckpt: &Checkpoint,
    test_ds: &Dataset,
) -> Result<f64> {
    let net = super::network_from_ckpt(manifest, ckpt)?;
    let bs = manifest.batch.max(1).min(test_ds.len().max(1));
    let mut rng = Rng::new(0);
    net.evaluate(test_ds, bs, &ExecSpec::Software, &mut rng)
}

// ---------------------------------------------------------------------------
// Per-layer tapes
// ---------------------------------------------------------------------------

/// Saved forward state of one conv (digital or PIM-mapped): everything the
/// backward needs.  Digital and PIM convs share the same backward — they
/// differ only in `coef_bwd` (s vs η·ξ·s, Theorem 1).
struct ConvTape {
    name: String,
    kernel: usize,
    stride: usize,
    x_shape: Vec<usize>,
    w_shape: Vec<usize>,
    ctx: grad::ConvCtx,
    /// Quantized unit-grid weights in im2col column layout [C·k·k, O].
    cols_unit: Tensor,
    wq: grad::WQuantCtx,
    coef_bwd: f32,
}

struct BnTape {
    name: String,
    ctx: grad::BnCtx,
}

struct FcTape {
    x: Tensor,
    wq: grad::WQuantCtx,
}

struct BlockTape {
    t1: ConvTape,
    tb1: BnTape,
    m1: Vec<u8>,
    t2: ConvTape,
    tb2: BnTape,
    /// Projection shortcut (conv + BN) when cin ≠ cout.
    sc: Option<(ConvTape, BnTape)>,
    /// Mask of the post-add activation.
    ma: Vec<u8>,
}

struct VggTape {
    conv: ConvTape,
    bn: BnTape,
    mask: Vec<u8>,
    /// (argmax indices, pre-pool shape) when the plan pools here.
    pool: Option<(Vec<u32>, Vec<usize>)>,
}

/// Per-channel batch statistics recorded by the forward stage, consumed by
/// the apply stage's running-stat update (and, data-parallel, reduced
/// across replicas on the gradient bus — `train::parallel`).
pub(crate) type BnStats = Vec<(String, (Vec<f32>, Vec<f32>))>;

/// Everything the forward stage hands to the backward stage: the saved
/// per-layer tapes plus what the loss head needs.  Tapes own pooled
/// buffers (patches, x̂, masks, argmax indices); the backward stage
/// consumes them layer by layer and returns each buffer to the arena the
/// moment its gradient is done.
struct ResnetTapes {
    t_c0: ConvTape,
    t_b0: BnTape,
    m_a0: Vec<u8>,
    blocks: Vec<BlockTape>,
    h_shape: Vec<usize>,
    fct: FcTape,
}

struct VggTapes {
    layers: Vec<VggTape>,
    h_shape: Vec<usize>,
    fct: FcTape,
}

/// The forward stage's tape, dispatched per architecture.
enum StepTape {
    Resnet(ResnetTapes),
    Vgg(VggTapes),
}

/// Row tile of the fused ξ twin: small enough that the per-worker scratch
/// (TILE·O floats) stays cache-resident, large enough to amortize the GEMM
/// setup.
const XI_TILE: usize = 64;

/// ξ statistics for the GSTE backward (Eqn. 8), fused: one pass computes
/// the biased (population) variances of the PIM output `y_pim` and of the
/// exact digital twin `patches · wcols` together.  The twin is evaluated
/// tile-by-tile into pooled scratch and fed straight into per-tile Welford
/// accumulators — it is never materialized.  Tiles are a *fixed* XI_TILE
/// rows regardless of worker count and are merged in tile order, so the
/// result is bit-identical for any thread count / `PIM_QAT_THREADS`
/// setting — the trainer's cross-machine reproducibility contract.
/// Returns (VAR[y_PIM], VAR[y]).
fn xi_variance_fused(
    m: usize,
    kc: usize,
    o: usize,
    patches: &[f32],
    wcols: &[f32],
    y_pim: &[f32],
    pool_bufs: &mut BufPool,
) -> (f64, f64) {
    let n_tiles = (m + XI_TILE - 1) / XI_TILE;
    let threads = ops::work_threads(0, m * o, n_tiles);
    let mut scratch = pool_bufs.take_f32(threads * XI_TILE * o);
    scratch.resize(threads * XI_TILE * o, 0.0);
    let mut parts: Vec<(Welford, Welford)> = vec![Default::default(); n_tiles];
    if threads <= 1 {
        for (t, part) in parts.iter_mut().enumerate() {
            *part = twin_welford_tile(t, m, kc, o, patches, wcols, y_pim, &mut scratch);
        }
    } else {
        let per = (n_tiles + threads - 1) / threads;
        let mut jobs: Vec<pool::ScopedJob<'_>> = Vec::with_capacity(threads);
        for (w, (block, tile)) in
            parts.chunks_mut(per).zip(scratch.chunks_mut(XI_TILE * o)).enumerate()
        {
            jobs.push(Box::new(move || {
                for (off, part) in block.iter_mut().enumerate() {
                    let t = w * per + off;
                    *part = twin_welford_tile(t, m, kc, o, patches, wcols, y_pim, tile);
                }
            }));
        }
        pool::run_scoped(jobs);
    }
    pool_bufs.put_f32(scratch);
    let mut wp = Welford::default();
    let mut wx = Welford::default();
    for (p, x) in &parts {
        wp.merge(p);
        wx.merge(x);
    }
    (wp.var(), wx.var())
}

/// One fixed tile of [`xi_variance_fused`]: rows
/// [t·XI_TILE, min((t+1)·XI_TILE, m)), through `tile` ([XI_TILE·o]
/// scratch).  Returns (Welford over y_pim, Welford over the exact twin)
/// for exactly this tile — self-contained, so the caller's tile-order
/// merge is independent of which worker ran it.
#[allow(clippy::too_many_arguments)]
fn twin_welford_tile(
    t: usize,
    m: usize,
    kc: usize,
    o: usize,
    patches: &[f32],
    wcols: &[f32],
    y_pim: &[f32],
    tile: &mut [f32],
) -> (Welford, Welford) {
    let r0 = t * XI_TILE;
    let tr = XI_TILE.min(m - r0);
    let s = &mut tile[..tr * o];
    s.fill(0.0);
    gemm_acc(tr, kc, o, &patches[r0 * kc..(r0 + tr) * kc], wcols, s);
    let mut wp = Welford::default();
    let mut wx = Welford::default();
    for &v in s.iter() {
        wx.push(v as f64);
    }
    for &v in &y_pim[r0 * o..(r0 + tr) * o] {
        wp.push(v as f64);
    }
    (wp, wx)
}

// ---------------------------------------------------------------------------
// The trainer
// ---------------------------------------------------------------------------

/// In-memory copy of the trainer's mutable state, taken every
/// [`SNAP_EVERY`] steps so the divergence guard can roll back without
/// touching disk.
struct TrainerSnapshot {
    params: BTreeMap<String, Tensor>,
    vel: BTreeMap<String, Tensor>,
    bn_state: BTreeMap<String, (Vec<f32>, Vec<f32>)>,
}

/// Per-job training state of the native backend: parameters, SGD momentum,
/// BN running statistics, and the resolved hyper-parameters.  Public so
/// benches can time a single [`NativeTrainer::train_step`].
pub struct NativeTrainer {
    entry: ModelEntry,
    bits: QuantBits,
    mode: Mode,
    scheme: Scheme,
    unit_channels: usize,
    /// Forward rescale η (1.0 unless mode=ours with fwd rescaling, §3.3).
    eta: f32,
    /// Apply the backward rescaling ξ of Eqn. 8 (Table A3 ablation knob).
    bwd_rescale: bool,
    /// AMS additive-noise std (mode=ams only).
    sigma: f32,
    /// The training-resolution chip (ideal, noiseless — Eqn. 4a).  When
    /// `train_faults` is set, a fresh fault replica is bound onto it every
    /// step (variability-aware training).
    chip: ChipModel,
    /// Base fault profile for variability-aware training (`job.faults`),
    /// or `None` for the paper's clean-chip training.
    train_faults: Option<FaultProfile>,
    momentum: f32,
    weight_decay: f32,
    nesterov: bool,
    bn_momentum: f32,
    params: BTreeMap<String, Tensor>,
    vel: BTreeMap<String, Tensor>,
    bn_state: BTreeMap<String, (Vec<f32>, Vec<f32>)>,
    /// Persistent hot-loop state (§Perf L3.5): cached per-layer engines +
    /// the grown-once buffer pool.  Taken out of `self` for the duration
    /// of each step and restored after.
    arena: TrainArena,
}

impl NativeTrainer {
    /// Initialize a job: Kaiming parameters (seeded), zero momentum, unit
    /// BN state, hyper-parameters resolved from the job config exactly as
    /// the lowered artifacts bake them in.
    pub fn new(manifest: &Manifest, job: &JobConfig) -> Result<NativeTrainer> {
        let entry = manifest.model(&job.model)?.clone();
        let bits = QuantBits { b_w: manifest.b_w, b_a: manifest.b_a, m: manifest.m_dac };
        let (fwd_rescale, bwd_rescale) = match job.variant.as_str() {
            "" => (true, true),
            "nofwd" => (false, true),
            "norescale" => (false, false),
            v => return Err(anyhow!("unknown rescaling variant {v:?}")),
        };
        let eta = if job.mode == Mode::Ours && fwd_rescale {
            job.eta_override
                .unwrap_or_else(|| rescale::forward_eta(job.scheme, job.b_pim_train))
        } else {
            1.0
        };
        let n_macs = crate::pim::layout::plan_groups(entry.width, 3, job.unit_channels).n;
        let sigma = if job.mode == Mode::Ams {
            super::ams_sigma(job.scheme, &bits, n_macs, job.b_pim_train)
        } else {
            0.0
        };
        let train_faults = if job.faults.is_empty() {
            None
        } else {
            Some(FaultProfile::parse(&job.faults)?)
        };
        let (params, state) = init::init_params(&entry, job.seed);
        let vel: BTreeMap<String, Tensor> =
            params.iter().map(|(k, t)| (k.clone(), Tensor::zeros(&t.shape))).collect();
        let mut bn_state = BTreeMap::new();
        for (k, v) in &state {
            if let Some(base) = k.strip_suffix("/mean") {
                let var = state
                    .get(&format!("{base}/var"))
                    .ok_or_else(|| anyhow!("state {base}/var missing"))?;
                bn_state.insert(base.to_string(), (v.data.clone(), var.data.clone()));
            }
        }
        Ok(NativeTrainer {
            entry,
            bits,
            mode: job.mode,
            scheme: job.scheme,
            unit_channels: job.unit_channels,
            eta,
            bwd_rescale,
            sigma,
            chip: ChipModel::ideal(job.b_pim_train),
            train_faults,
            momentum: 0.9,
            weight_decay: 1e-4,
            nesterov: true,
            bn_momentum: 0.1,
            params,
            vel,
            bn_state,
            arena: TrainArena::new(),
        })
    }

    /// One SGD step on a batch: the compute/update stages of the step
    /// lifecycle (`forward → backward → apply`; the *acquire* stage lives
    /// in the caller's [`crate::data::loader::BatchLoader`]).  Returns
    /// (mean loss, correct predictions in the batch).
    pub fn train_step(
        &mut self,
        x: &Tensor,
        y: &[i32],
        lr: f32,
        rng: &mut Rng,
    ) -> Result<(f32, usize)> {
        // the arena leaves `self` for the step so the stage functions can
        // borrow parameters (&self) and the arena (&mut) independently
        let mut arena = std::mem::take(&mut self.arena);
        let result = self.step_stages(x, y, lr, rng, &mut arena);
        self.arena = arena;
        result
    }

    /// The three compute stages, in order.  Split out of [`Self::train_step`]
    /// so the arena swap-out wraps them uniformly.
    fn step_stages(
        &mut self,
        x: &Tensor,
        y_lab: &[i32],
        lr: f32,
        rng: &mut Rng,
        arena: &mut TrainArena,
    ) -> Result<(f32, usize)> {
        // -- forward: training-mode network pass, tapes saved
        let mut stats = BnStats::new();
        let (logits, tape) = self.forward(x, rng, arena, &mut stats)?;
        let (loss, correct, dlogits) = grad::softmax_xent(&logits, y_lab);
        // always-on guard: a non-finite loss means the backward pass can
        // only produce garbage gradients — skip the update and hand the
        // loss to the caller's divergence guard instead of silently
        // training on it.  The backward still runs so the tape's pooled
        // buffers return to the arena.
        if !loss.is_finite() {
            let _ = self.backward(tape, &dlogits, arena);
            return Ok((loss, correct));
        }
        // -- backward: consume the tapes into parameter gradients
        let grads = self.backward(tape, &dlogits, arena);
        // -- apply: BN running stats + Nesterov SGD
        self.apply(grads, stats, lr)?;
        Ok((loss, correct))
    }

    /// The replica-local half of a data-parallel step (`train::parallel`):
    /// forward + loss + backward on this replica's own arena, **without**
    /// the apply stage.  Returns the microbatch's (mean loss, correct
    /// count, parameter gradients, BN batch statistics) for the caller to
    /// reduce across replicas and apply once via [`Self::apply_reduced`].
    /// The gradients and statistics are bitwise those [`Self::train_step`]
    /// would have applied — the stages are shared, only the apply is
    /// deferred.
    pub(crate) fn grad_step(
        &mut self,
        x: &Tensor,
        y: &[i32],
        rng: &mut Rng,
    ) -> Result<(f32, usize, BTreeMap<String, Tensor>, BnStats)> {
        let mut arena = std::mem::take(&mut self.arena);
        let result = self.grad_stages(x, y, rng, &mut arena);
        self.arena = arena;
        result
    }

    /// Forward + loss + backward for [`Self::grad_step`], under the same
    /// arena swap-out as [`Self::step_stages`].
    fn grad_stages(
        &mut self,
        x: &Tensor,
        y_lab: &[i32],
        rng: &mut Rng,
        arena: &mut TrainArena,
    ) -> Result<(f32, usize, BTreeMap<String, Tensor>, BnStats)> {
        let mut stats = BnStats::new();
        let (logits, tape) = self.forward(x, rng, arena, &mut stats)?;
        let (loss, correct, dlogits) = grad::softmax_xent(&logits, y_lab);
        // the backward runs even on a non-finite loss so the tape's pooled
        // buffers return to the arena; the caller skips the apply
        let grads = self.backward(tape, &dlogits, arena);
        Ok((loss, correct, grads, stats))
    }

    /// Forward stage: run the training-mode network on `x`, returning the
    /// logits and the tape the backward stage consumes.
    fn forward(
        &self,
        x: &Tensor,
        rng: &mut Rng,
        arena: &mut TrainArena,
        stats: &mut BnStats,
    ) -> Result<(Tensor, StepTape)> {
        match self.entry.arch.as_str() {
            "resnet" => {
                let (logits, t) = self.resnet_forward(x, rng, arena, stats)?;
                Ok((logits, StepTape::Resnet(t)))
            }
            "vgg11" => {
                let (logits, t) = self.vgg_forward(x, rng, arena, stats)?;
                Ok((logits, StepTape::Vgg(t)))
            }
            a => Err(anyhow!("unknown arch {a:?}")),
        }
    }

    /// Backward stage: consume the forward tape into parameter gradients,
    /// returning every pooled buffer to the arena as it goes.
    fn backward(
        &self,
        tape: StepTape,
        dlogits: &Tensor,
        arena: &mut TrainArena,
    ) -> BTreeMap<String, Tensor> {
        match tape {
            StepTape::Resnet(t) => self.resnet_backward(t, dlogits, arena),
            StepTape::Vgg(t) => self.vgg_backward(t, dlogits, arena),
        }
    }

    /// Apply stage: BN running-statistic momentum update + SGD with
    /// Nesterov momentum and weight decay (TrainConfig defaults).
    fn apply(&mut self, grads: BTreeMap<String, Tensor>, stats: BnStats, lr: f32) -> Result<()> {
        self.apply_reduced(&grads, &stats, lr)
    }

    /// The shared-apply half of a step, borrowed form: the single-trainer
    /// [`Self::apply`] delegates here, and the data-parallel driver
    /// (`train::parallel`) calls it directly with the tree-reduced mean
    /// gradients and statistics — one optimizer update per global step,
    /// whatever the replica count.
    pub(crate) fn apply_reduced(
        &mut self,
        grads: &BTreeMap<String, Tensor>,
        stats: &BnStats,
        lr: f32,
    ) -> Result<()> {
        // BN running statistics: (1-m)·old + m·batch (training-mode BN)
        let mom = self.bn_momentum;
        for (name, (bm, bv)) in stats {
            let ent = self
                .bn_state
                .get_mut(name)
                .ok_or_else(|| anyhow!("bn state {name:?} missing"))?;
            for (o, n) in ent.0.iter_mut().zip(bm) {
                *o = (1.0 - mom) * *o + mom * *n;
            }
            for (o, n) in ent.1.iter_mut().zip(bv) {
                *o = (1.0 - mom) * *o + mom * *n;
            }
        }

        #[cfg(debug_assertions)]
        for (name, g) in grads {
            let norm2: f64 = g.data.iter().map(|&v| (v as f64) * (v as f64)).sum();
            debug_assert!(norm2.is_finite(), "non-finite gradient norm for layer {name:?}");
        }

        for (name, g) in grads {
            let p = self
                .params
                .get_mut(name)
                .ok_or_else(|| anyhow!("param {name:?} missing"))?;
            let v = self
                .vel
                .get_mut(name)
                .ok_or_else(|| anyhow!("momentum {name:?} missing"))?;
            for i in 0..g.data.len() {
                let gi = g.data[i] + self.weight_decay * p.data[i];
                let m = self.momentum * v.data[i] + gi;
                v.data[i] = m;
                let upd = if self.nesterov { gi + self.momentum * m } else { m };
                p.data[i] -= lr * upd;
            }
        }
        Ok(())
    }

    /// Variability-aware training: when the job carries a fault profile,
    /// bind a fresh per-step fault replica onto the training chip so each
    /// step's PIM forward sees a different injured device (the hardware
    /// population the deployed model must survive).  No-op otherwise.
    pub fn set_step_faults(&mut self, step: usize) {
        if let Some(p) = self.train_faults {
            self.chip.faults = Some(p.training_sample(step as u64));
        }
    }

    /// Data-parallel variability-aware training (`train::parallel`): bind
    /// the injured device for global microbatch slot `slot` at step
    /// `step`.  The slot offsets the profile's base chip id (the farm's
    /// `on_chip(i)` fingerprint convention, PR 6), so each slot trains
    /// against its own chip instance of the population; a pure function of
    /// (slot, step) — never of which physical replica runs the slot.
    /// Slot 0 is bitwise [`Self::set_step_faults`].  No-op without a
    /// profile.
    pub(crate) fn set_slot_faults(&mut self, step: usize, slot: usize) {
        if let Some(p) = self.train_faults {
            let p = p.on_chip(p.chip_id.wrapping_add(slot as u64));
            self.chip.faults = Some(p.training_sample(step as u64));
        }
    }

    /// In-place weight broadcast (`train::parallel`): copy `src`'s
    /// parameters, SGD velocity, and BN running state into this replica's
    /// existing buffers.  Engine caches in the arena are left alone — they
    /// reprogram from `params` on the next forward, skipping unchanged
    /// groups, so the broadcast costs no reallocation and no cache loss.
    pub(crate) fn adopt_state_from(&mut self, src: &NativeTrainer) {
        debug_assert_eq!(self.params.len(), src.params.len(), "replica param sets differ");
        for (d, s) in self.params.values_mut().zip(src.params.values()) {
            d.data.clone_from(&s.data);
        }
        for (d, s) in self.vel.values_mut().zip(src.vel.values()) {
            d.data.clone_from(&s.data);
        }
        for (d, s) in self.bn_state.values_mut().zip(src.bn_state.values()) {
            d.0.clone_from(&s.0);
            d.1.clone_from(&s.1);
        }
    }

    /// Parameter shape template, in the fixed (sorted) iteration order the
    /// gradient maps share — the `train::parallel` bus layout is built
    /// from this.
    pub(crate) fn param_template(&self) -> &BTreeMap<String, Tensor> {
        &self.params
    }

    /// BN running-state template (name → per-channel buffers), fixed order
    /// — sizes the bus's statistics ranges.
    pub(crate) fn bn_template(&self) -> &BTreeMap<String, (Vec<f32>, Vec<f32>)> {
        &self.bn_state
    }

    /// Snapshot the mutable training state (parameters, momentum, BN
    /// running stats) for the divergence guard's in-memory rollback.
    fn snapshot(&self) -> TrainerSnapshot {
        TrainerSnapshot {
            params: self.params.clone(),
            vel: self.vel.clone(),
            bn_state: self.bn_state.clone(),
        }
    }

    /// Restore a [`Self::snapshot`] — the inverse rollback of the
    /// divergence guard.  Engines in the arena are left alone: they are
    /// reprogrammed from `params` on the next step anyway.
    fn restore_snapshot(&mut self, s: &TrainerSnapshot) {
        self.params.clone_from(&s.params);
        self.vel.clone_from(&s.vel);
        self.bn_state.clone_from(&s.bn_state);
    }

    /// Adopt a saved checkpoint's parameters, BN state, and (v2+) SGD
    /// momentum velocity — a resumed run continues the exact optimizer
    /// trajectory of the interrupted one.  v1 checkpoints carry no
    /// velocity: momentum restarts at zero with a logged warning.
    /// Returns the step recorded in the checkpoint meta (0 when absent).
    pub fn restore_from_checkpoint(&mut self, ck: &Checkpoint) -> Result<usize> {
        for (name, t) in &ck.params {
            let p = self
                .params
                .get_mut(name)
                .ok_or_else(|| anyhow!("checkpoint param {name:?} unknown to this job"))?;
            if p.shape != t.shape {
                return Err(anyhow!(
                    "checkpoint param {name:?} shape {:?} != job shape {:?}",
                    t.shape,
                    p.shape
                ));
            }
            p.data.clone_from(&t.data);
        }
        if ck.velocity.is_empty() {
            eprintln!(
                "[resume] checkpoint has no velocity section (v1 format): \
                 momentum restarts at zero"
            );
            for v in self.vel.values_mut() {
                v.data.fill(0.0);
            }
        } else {
            for (name, t) in &ck.velocity {
                let v = self
                    .vel
                    .get_mut(name)
                    .ok_or_else(|| anyhow!("checkpoint velocity {name:?} unknown to this job"))?;
                if v.shape != t.shape {
                    return Err(anyhow!(
                        "checkpoint velocity {name:?} shape {:?} != job shape {:?}",
                        t.shape,
                        v.shape
                    ));
                }
                v.data.clone_from(&t.data);
            }
        }
        let state = ck.state_map();
        for (k, v) in &state {
            if let Some(base) = k.strip_suffix("/mean") {
                let var = state
                    .get(&format!("{base}/var"))
                    .ok_or_else(|| anyhow!("checkpoint state {base}/var missing"))?;
                self.bn_state.insert(base.to_string(), (v.data.clone(), var.data.clone()));
            }
        }
        Ok(ck.meta.get("step").and_then(|s| s.parse().ok()).unwrap_or(0))
    }

    /// Snapshot the trainer into a checkpoint without consuming it
    /// (periodic crash-safe saves mid-run).
    pub fn checkpoint(&self, job: &JobConfig) -> Checkpoint {
        let params: Vec<(String, Tensor)> =
            self.params.iter().map(|(k, t)| (k.clone(), t.clone())).collect();
        let mut state = Vec::new();
        for (name, (mean, var)) in &self.bn_state {
            let c = mean.len();
            state.push((format!("{name}/mean"), Tensor::from_vec(&[c], mean.clone())));
            state.push((format!("{name}/var"), Tensor::from_vec(&[c], var.clone())));
        }
        let velocity: Vec<(String, Tensor)> =
            self.vel.iter().map(|(k, t)| (k.clone(), t.clone())).collect();
        let mut meta = BTreeMap::new();
        meta.insert("mode".to_string(), job.mode.to_string());
        meta.insert("scheme".to_string(), job.scheme.to_string());
        meta.insert("unit_channels".to_string(), job.unit_channels.to_string());
        meta.insert("b_pim_train".to_string(), job.b_pim_train.to_string());
        meta.insert("steps".to_string(), job.steps.to_string());
        meta.insert("backend".to_string(), "native".to_string());
        meta.insert("ckpt_version".to_string(), crate::train::checkpoint::CKPT_VERSION.to_string());
        Checkpoint { model: job.model.clone(), meta, params, state, velocity }
    }

    /// Consume the trainer into a checkpoint (params + BN running state).
    pub fn into_checkpoint(self, job: &JobConfig) -> Checkpoint {
        self.checkpoint(job)
    }

    // -- layers -------------------------------------------------------------

    fn param(&self, name: &str) -> Result<&Tensor> {
        self.params.get(name).ok_or_else(|| anyhow!("param {name:?} missing"))
    }

    /// Digital-system conv (first layer / shortcuts): quantized weights,
    /// exact accumulation, plain STE backward.
    fn conv_digital_fwd(
        &self,
        x: &Tensor,
        name: &str,
        stride: usize,
        pool_bufs: &mut BufPool,
    ) -> Result<(Tensor, ConvTape)> {
        let w = self.param(name)?;
        let (kh, o) = (w.shape[0], w.shape[3]);
        let wq = grad::weight_quant_fwd(w, &self.bits, o);
        let cols = ops::weights_to_cols(&wq.q_unit);
        let (mut y, ctx) = grad::conv_cols_fwd(x, &cols, kh, stride, pool_bufs);
        let s = wq.scale;
        for v in &mut y.data {
            *v *= s;
        }
        Ok((
            y,
            ConvTape {
                name: name.to_string(),
                kernel: kh,
                stride,
                x_shape: x.shape.clone(),
                w_shape: w.shape.clone(),
                ctx,
                cols_unit: cols,
                wq,
                coef_bwd: s,
            },
        ))
    }

    /// A PIM-mapped conv in training mode.  `mode=ours` executes the
    /// grouped quantized MAC (Eqn. 4a) on the ideal training-resolution
    /// chip, scaled by η; its backward coefficient carries the GSTE ξ
    /// (Eqn. 8).  `baseline` is the digital product; `ams` adds the
    /// additive-Gaussian AMS model during training.
    fn conv_pim_fwd(
        &self,
        x: &Tensor,
        name: &str,
        stride: usize,
        rng: &mut Rng,
        arena: &mut TrainArena,
    ) -> Result<(Tensor, ConvTape)> {
        let w = self.param(name)?;
        let (kh, c_in, o) = (w.shape[0], w.shape[2], w.shape[3]);
        let wq = grad::weight_quant_fwd(w, &self.bits, o);
        let cols = ops::weights_to_cols(&wq.q_unit);
        let kc = cols.shape[0];
        let (patches, oh, ow) = grad::pooled_im2col(x, kh, stride, kc, &mut arena.pool);
        let m = patches.shape[0];
        let (y, coef_bwd) = match self.mode {
            Mode::Ours => {
                let wl = self.bits.w_levels() as f32;
                let al = self.bits.a_levels() as f32;
                // integer weights, staged in a pooled buffer; the cached
                // engine reprograms in place, skipping unchanged groups
                let mut wint = arena.pool.take_f32(cols.len());
                wint.extend(cols.data.iter().map(|&v| crate::chip::round_ties_even(v * wl)));
                arena.ensure_engine(
                    name,
                    self.scheme,
                    self.bits,
                    &wint,
                    o,
                    c_in,
                    kh,
                    self.unit_channels,
                );
                arena.pool.put_f32(wint);
                // u8 activation grid + output feature map, both pooled
                let mut pint = arena.pool.take_u8(patches.len());
                ops::quantize_into_u8(&patches.data, al, &mut pint);
                let mut y = arena.pool.take_f32(m * o);
                let engine = arena.engines.get(name).expect("engine ensured above");
                engine.matmul_u8_into(&pint, &self.chip, rng, &mut y);
                arena.pool.put_u8(pint);
                let xi = if self.bwd_rescale {
                    let pb = &mut arena.pool;
                    let (var_pim, var_ex) =
                        xi_variance_fused(m, kc, o, &patches.data, &cols.data, &y, pb);
                    ((var_pim + 1e-12) / (var_ex + 1e-12)).sqrt() as f32
                } else {
                    1.0
                };
                let cf = self.eta * wq.scale;
                for v in &mut y {
                    *v *= cf;
                }
                (y, self.eta * xi * wq.scale)
            }
            Mode::Baseline | Mode::Ams => {
                let mut y = arena.pool.take_f32(m * o);
                gemm_into(m, kc, o, &patches.data, &cols.data, &mut y);
                if self.mode == Mode::Ams && self.sigma > 0.0 {
                    for v in &mut y {
                        *v += self.sigma * rng.normal() as f32;
                    }
                }
                let s = wq.scale;
                for v in &mut y {
                    *v *= s;
                }
                (y, wq.scale)
            }
        };
        let out = Tensor::from_vec(&[x.shape[0], oh, ow, o], y);
        Ok((
            out,
            ConvTape {
                name: name.to_string(),
                kernel: kh,
                stride,
                x_shape: x.shape.clone(),
                w_shape: w.shape.clone(),
                ctx: grad::ConvCtx { patches, oh, ow },
                cols_unit: cols,
                wq,
                coef_bwd,
            },
        ))
    }

    /// Shared conv backward (digital and PIM — Theorem 1 makes them the
    /// same up to `coef_bwd`).  Accumulates dW into `grads`, returns dx.
    /// Every patch-scale intermediate (scaled dy, dW columns, the patch
    /// gradient inside `conv_cols_bwd`) lives in pooled buffers.
    fn conv_bwd(
        &self,
        tape: &ConvTape,
        dy: &Tensor,
        grads: &mut BTreeMap<String, Tensor>,
        pool_bufs: &mut BufPool,
    ) -> Tensor {
        let mut dy2 = pool_bufs.take_f32(dy.len());
        dy2.extend(dy.data.iter().map(|&v| v * tape.coef_bwd));
        let mut dwcols = pool_bufs.take_f32(tape.cols_unit.len());
        let dx = grad::conv_cols_bwd(
            &tape.ctx,
            &tape.cols_unit,
            &tape.x_shape,
            tape.kernel,
            tape.stride,
            &dy2,
            pool_bufs,
            &mut dwcols,
        );
        pool_bufs.put_f32(dy2);
        let (kh, kw, c, o) =
            (tape.w_shape[0], tape.w_shape[1], tape.w_shape[2], tape.w_shape[3]);
        let dq = ops::cols_to_weights_from(&dwcols, kh, kw, c, o);
        pool_bufs.put_f32(dwcols);
        let dw = grad::weight_quant_bwd(&tape.wq, &dq);
        grads.insert(tape.name.clone(), dw);
        dx
    }

    /// Weight-gradient-only conv backward for the network's first layer:
    /// the input gradient is never used there, and skipping it saves a
    /// full GEMM + col2im on the largest feature map every step.
    fn conv_bwd_w_only(
        &self,
        tape: &ConvTape,
        dy: &Tensor,
        grads: &mut BTreeMap<String, Tensor>,
        pool_bufs: &mut BufPool,
    ) {
        let mut dy2 = pool_bufs.take_f32(dy.len());
        dy2.extend(dy.data.iter().map(|&v| v * tape.coef_bwd));
        let m = tape.ctx.patches.shape[0];
        let kc = tape.ctx.patches.shape[1];
        let o = tape.cols_unit.shape[1];
        let mut dwcols = pool_bufs.take_f32(kc * o);
        gemm_tn_into(m, kc, o, &tape.ctx.patches.data, &dy2, &mut dwcols);
        pool_bufs.put_f32(dy2);
        let (kh, kw, c, ocnt) =
            (tape.w_shape[0], tape.w_shape[1], tape.w_shape[2], tape.w_shape[3]);
        let dq = ops::cols_to_weights_from(&dwcols, kh, kw, c, ocnt);
        pool_bufs.put_f32(dwcols);
        let dw = grad::weight_quant_bwd(&tape.wq, &dq);
        grads.insert(tape.name.clone(), dw);
    }

    /// Training-mode BN forward: y and the tape's x̂ live in pooled
    /// storage (the tape is consumed — and its x̂ reclaimed — by
    /// [`Self::bn_bwd`]).
    fn bn_fwd(
        &self,
        x: &Tensor,
        name: &str,
        stats: &mut BnStats,
        pool: &mut BufPool,
    ) -> Result<(Tensor, BnTape)> {
        let gamma = self.param(&format!("{name}/gamma"))?;
        let beta = self.param(&format!("{name}/beta"))?;
        let (y, ctx) = grad::bn_train_fwd_pooled(x, &gamma.data, &beta.data, pool);
        stats.push((name.to_string(), (ctx.mean.clone(), ctx.var.clone())));
        Ok((y, BnTape { name: name.to_string(), ctx }))
    }

    /// BN backward, consuming the tape: dx comes from the pool, the
    /// tape's x̂ goes back to it.
    fn bn_bwd(
        &self,
        tape: BnTape,
        dy: &Tensor,
        grads: &mut BTreeMap<String, Tensor>,
        pool: &mut BufPool,
    ) -> Tensor {
        let gamma = self
            .params
            .get(&format!("{}/gamma", tape.name))
            .expect("bn gamma vanished mid-step");
        let (dx, dgamma, dbeta) = grad::bn_train_bwd_pooled(&tape.ctx, &gamma.data, dy, pool);
        let c = dgamma.len();
        grads.insert(format!("{}/gamma", tape.name), Tensor::from_vec(&[c], dgamma));
        grads.insert(format!("{}/beta", tape.name), Tensor::from_vec(&[c], dbeta));
        tape.ctx.recycle(pool);
        dx
    }

    fn fc_fwd(&self, x: &Tensor) -> Result<(Tensor, FcTape)> {
        let w = self.param("fc/w")?;
        let b = self.param("fc/b")?;
        let (bsz, cin) = (x.shape[0], x.shape[1]);
        let o = w.shape[1];
        let wq = grad::weight_quant_fwd(w, &self.bits, o);
        let s = wq.scale;
        let mut y = gemm(bsz, cin, o, &x.data, &wq.q_unit.data);
        for i in 0..bsz {
            for j in 0..o {
                y[i * o + j] = y[i * o + j] * s + b.data[j];
            }
        }
        Ok((Tensor::from_vec(&[bsz, o], y), FcTape { x: x.clone(), wq }))
    }

    fn fc_bwd(&self, tape: &FcTape, dy: &Tensor, grads: &mut BTreeMap<String, Tensor>) -> Tensor {
        let (bsz, cin) = (tape.x.shape[0], tape.x.shape[1]);
        let o = dy.shape[1];
        let s = tape.wq.scale;
        let mut db = vec![0.0f32; o];
        for i in 0..bsz {
            for j in 0..o {
                db[j] += dy.data[i * o + j];
            }
        }
        grads.insert("fc/b".to_string(), Tensor::from_vec(&[o], db));
        let mut dq = gemm_tn(bsz, cin, o, &tape.x.data, &dy.data);
        for v in &mut dq {
            *v *= s;
        }
        let dw = grad::weight_quant_bwd(&tape.wq, &Tensor::from_vec(&[cin, o], dq));
        grads.insert("fc/w".to_string(), dw);
        let mut dx = gemm_nt(bsz, o, cin, &dy.data, &tape.wq.q_unit.data);
        for v in &mut dx {
            *v *= s;
        }
        Tensor::from_vec(&[bsz, cin], dx)
    }

    // -- full model stages --------------------------------------------------

    /// Resnet forward stage.  Every feature map is a pooled tensor: a
    /// layer's input is returned to the arena the moment its consumer has
    /// produced the next map, so at any instant only the live maps (plus
    /// the tapes) hold pool buffers.
    fn resnet_forward(
        &self,
        x: &Tensor,
        rng: &mut Rng,
        arena: &mut TrainArena,
        stats: &mut BnStats,
    ) -> Result<(Tensor, ResnetTapes)> {
        let (width, depth_n) = (self.entry.width, self.entry.depth_n);
        // 8-bit first-layer inputs (§A2.1), quantized in a pooled copy
        let x8 = quant::act_quant_bits(arena.pool.take_like(x), 8);
        let (h0, t_c0) = self.conv_digital_fwd(&x8, "conv0/w", 1, &mut arena.pool)?;
        arena.pool.put_tensor(x8);
        let (hb, t_b0) = self.bn_fwd(&h0, "bn0", stats, &mut arena.pool)?;
        arena.pool.put_tensor(h0);
        let (mut h, m_a0) = grad::act_fwd_pooled(&hb, &self.bits, &mut arena.pool);
        arena.pool.put_tensor(hb);
        let mut blocks: Vec<BlockTape> = Vec::new();
        let mut cin = width;
        for s in 0..3 {
            let cout = width * (1 << s);
            for b in 0..depth_n {
                let blk = format!("s{s}b{b}");
                let stride = if s > 0 && b == 0 { 2 } else { 1 };
                let (z, t1) = self.conv_pim_fwd(&h, &format!("{blk}/conv1/w"), stride, rng, arena)?;
                let (zb, tb1) = self.bn_fwd(&z, &format!("{blk}/bn1"), stats, &mut arena.pool)?;
                arena.pool.put_tensor(z);
                let (za, m1) = grad::act_fwd_pooled(&zb, &self.bits, &mut arena.pool);
                arena.pool.put_tensor(zb);
                let (z2, t2) = self.conv_pim_fwd(&za, &format!("{blk}/conv2/w"), 1, rng, arena)?;
                arena.pool.put_tensor(za);
                let (mut zsum, tb2) =
                    self.bn_fwd(&z2, &format!("{blk}/bn2"), stats, &mut arena.pool)?;
                arena.pool.put_tensor(z2);
                let sc = if cin != cout || stride != 1 {
                    let name = format!("{blk}/convs/w");
                    let (sraw, ts) = self.conv_digital_fwd(&h, &name, stride, &mut arena.pool)?;
                    let (sbn, tbs) =
                        self.bn_fwd(&sraw, &format!("{blk}/bns"), stats, &mut arena.pool)?;
                    arena.pool.put_tensor(sraw);
                    zsum.add_assign(&sbn);
                    arena.pool.put_tensor(sbn);
                    Some((ts, tbs))
                } else {
                    zsum.add_assign(&h);
                    None
                };
                arena.pool.put_tensor(h); // block input dead after the residual add
                let (hn, ma) = grad::act_fwd_pooled(&zsum, &self.bits, &mut arena.pool);
                arena.pool.put_tensor(zsum);
                blocks.push(BlockTape { t1, tb1, m1, t2, tb2, sc, ma });
                h = hn;
                cin = cout;
            }
        }
        let h_shape = h.shape.clone();
        let pooled = ops::global_avg_pool(&h);
        arena.pool.put_tensor(h);
        let (logits, fct) = self.fc_fwd(&pooled)?;
        Ok((logits, ResnetTapes { t_c0, t_b0, m_a0, blocks, h_shape, fct }))
    }

    /// Resnet backward stage: tapes are consumed so their pooled buffers
    /// (patches, x̂, masks) return to the arena as soon as each layer's
    /// gradient is done, and every gradient feature map is pooled too.
    fn resnet_backward(
        &self,
        tapes: ResnetTapes,
        dlogits: &Tensor,
        arena: &mut TrainArena,
    ) -> BTreeMap<String, Tensor> {
        let ResnetTapes { t_c0, t_b0, m_a0, blocks, h_shape, fct } = tapes;
        let mut grads = BTreeMap::new();
        let pool = &mut arena.pool;
        let dpooled = self.fc_bwd(&fct, dlogits, &mut grads);
        let mut dh = grad::global_avg_pool_bwd_pooled(&h_shape, &dpooled, pool);
        for bt in blocks.into_iter().rev() {
            let BlockTape { t1, tb1, m1, t2, tb2, sc, ma } = bt;
            grad::act_bwd_inplace(&ma, &mut dh);
            pool.put_u8(ma);
            let dsum = dh; // feeds both the main path and the shortcut
            let dz = self.bn_bwd(tb2, &dsum, &mut grads, pool);
            let mut dz2 = self.conv_bwd(&t2, &dz, &mut grads, pool);
            pool.put_tensor(dz);
            pool.put_f32(t2.ctx.patches.data);
            grad::act_bwd_inplace(&m1, &mut dz2);
            pool.put_u8(m1);
            let dz3 = self.bn_bwd(tb1, &dz2, &mut grads, pool);
            pool.put_tensor(dz2);
            let mut dx_main = self.conv_bwd(&t1, &dz3, &mut grads, pool);
            pool.put_tensor(dz3);
            pool.put_f32(t1.ctx.patches.data);
            match sc {
                Some((ts, tbs)) => {
                    let d = self.bn_bwd(tbs, &dsum, &mut grads, pool);
                    pool.put_tensor(dsum);
                    let dxs = self.conv_bwd(&ts, &d, &mut grads, pool);
                    pool.put_tensor(d);
                    pool.put_f32(ts.ctx.patches.data);
                    dx_main.add_assign(&dxs);
                    pool.put_tensor(dxs);
                }
                None => {
                    dx_main.add_assign(&dsum);
                    pool.put_tensor(dsum);
                }
            }
            dh = dx_main;
        }
        grad::act_bwd_inplace(&m_a0, &mut dh);
        pool.put_u8(m_a0);
        let dh2 = self.bn_bwd(t_b0, &dh, &mut grads, pool);
        pool.put_tensor(dh);
        self.conv_bwd_w_only(&t_c0, &dh2, &mut grads, pool); // input gradient unused
        pool.put_tensor(dh2);
        pool.put_f32(t_c0.ctx.patches.data);
        grads
    }

    /// VGG forward stage (pooled feature maps — same ownership discipline
    /// as [`Self::resnet_forward`]).
    fn vgg_forward(
        &self,
        x: &Tensor,
        rng: &mut Rng,
        arena: &mut TrainArena,
        stats: &mut BnStats,
    ) -> Result<(Tensor, VggTapes)> {
        let plan = vgg11_plan(self.entry.width, self.entry.image);
        let mut h = quant::act_quant_bits(arena.pool.take_like(x), 8);
        let mut layers: Vec<VggTape> = Vec::new();
        for (i, &(_cout, pool_here)) in plan.iter().enumerate() {
            let name = format!("conv{i}/w");
            let (z, conv) = if i == 0 {
                self.conv_digital_fwd(&h, &name, 1, &mut arena.pool)?
            } else {
                self.conv_pim_fwd(&h, &name, 1, rng, arena)?
            };
            arena.pool.put_tensor(h);
            let (zb, bn) = self.bn_fwd(&z, &format!("bn{i}"), stats, &mut arena.pool)?;
            arena.pool.put_tensor(z);
            let (za, mask) = grad::act_fwd_pooled(&zb, &self.bits, &mut arena.pool);
            arena.pool.put_tensor(zb);
            let (hn, pool_tape) = if pool_here {
                let pre_shape = za.shape.clone();
                let (p, idx) = grad::maxpool2_fwd_pooled(&za, &mut arena.pool);
                arena.pool.put_tensor(za);
                (p, Some((idx, pre_shape)))
            } else {
                (za, None)
            };
            layers.push(VggTape { conv, bn, mask, pool: pool_tape });
            h = hn;
        }
        let h_shape = h.shape.clone();
        let pooled = ops::global_avg_pool(&h);
        arena.pool.put_tensor(h);
        let (logits, fct) = self.fc_fwd(&pooled)?;
        Ok((logits, VggTapes { layers, h_shape, fct }))
    }

    /// VGG backward stage (tapes consumed; all buffers return to the
    /// arena).
    fn vgg_backward(
        &self,
        tapes: VggTapes,
        dlogits: &Tensor,
        arena: &mut TrainArena,
    ) -> BTreeMap<String, Tensor> {
        let VggTapes { layers, h_shape, fct } = tapes;
        let mut grads = BTreeMap::new();
        let pool = &mut arena.pool;
        let dpooled = self.fc_bwd(&fct, dlogits, &mut grads);
        let mut dh = grad::global_avg_pool_bwd_pooled(&h_shape, &dpooled, pool);
        for (li, t) in layers.into_iter().enumerate().rev() {
            let VggTape { conv, bn, mask, pool: pool_tape } = t;
            if let Some((idx, pre_shape)) = pool_tape {
                let dpre = grad::maxpool2_bwd_pooled(&idx, &pre_shape, &dh, pool);
                pool.put_u32(idx);
                pool.put_tensor(dh);
                dh = dpre;
            }
            grad::act_bwd_inplace(&mask, &mut dh);
            pool.put_u8(mask);
            let d = self.bn_bwd(bn, &dh, &mut grads, pool);
            pool.put_tensor(dh);
            if li == 0 {
                // first layer: input gradient unused
                self.conv_bwd_w_only(&conv, &d, &mut grads, pool);
                dh = d;
            } else {
                dh = self.conv_bwd(&conv, &d, &mut grads, pool);
                pool.put_tensor(d);
            }
            pool.put_f32(conv.ctx.patches.data);
        }
        pool.put_tensor(dh); // the spent gradient of the earliest layer
        grads
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::loader::BatchLoader;
    use crate::data::synth;

    /// Stage split sanity: the public `train_step` must drive all three
    /// compute stages — params move (apply ran on backward's grads) and BN
    /// running stats move (apply consumed forward's batch stats).
    #[test]
    fn lifecycle_stages_compose() {
        let m = micro_manifest();
        let job = micro_job(Mode::Baseline, 1);
        let mut t = NativeTrainer::new(&m, &job).unwrap();
        let ds = synth::generate(8, 4, 16, 3);
        let mut rng = Rng::new(1);
        let batch = ds.batch(&(0..8).collect::<Vec<_>>(), false, &mut rng);
        let mut arena = std::mem::take(&mut t.arena);
        let mut stats = BnStats::new();
        let (logits, tape) = t.forward(&batch.x, &mut rng, &mut arena, &mut stats).unwrap();
        assert_eq!(logits.shape, vec![8, 4]);
        assert!(!stats.is_empty(), "forward must record BN batch stats");
        let (_, _, dlogits) = grad::softmax_xent(&logits, &batch.y);
        let grads = t.backward(tape, &dlogits, &mut arena);
        assert!(grads.contains_key("conv0/w") && grads.contains_key("fc/w"));
        let before = t.params.get("s0b0/conv1/w").unwrap().clone();
        t.apply(grads, stats, 0.05).unwrap();
        t.arena = arena;
        assert_ne!(before.data, t.params.get("s0b0/conv1/w").unwrap().data);
        assert!(t.bn_state.get("bn0").unwrap().0.iter().any(|&v| v != 0.0));
    }

    /// A down-scaled resnet geometry so debug-mode tests stay fast.
    fn micro_manifest() -> Manifest {
        let mut m = Manifest::builtin();
        let mut e = m.models.get("tiny").unwrap().clone();
        e.width = 4;
        e.image = 8;
        e.classes = 4;
        m.models.insert("micro".to_string(), e);
        m.batch = 8;
        m
    }

    fn micro_job(mode: Mode, steps: usize) -> JobConfig {
        JobConfig {
            model: "micro".to_string(),
            mode,
            scheme: Scheme::BitSerial,
            unit_channels: 8,
            b_pim_train: 7,
            steps,
            lr: 0.05,
            train_size: 64,
            test_size: 32,
            ..Default::default()
        }
    }

    #[test]
    fn trainer_initializes_all_layers() {
        let m = micro_manifest();
        let t = NativeTrainer::new(&m, &micro_job(Mode::Ours, 1)).unwrap();
        assert!(t.params.contains_key("conv0/w"));
        assert!(t.params.contains_key("s2b0/convs/w"));
        assert!(t.bn_state.contains_key("bn0"));
        assert_eq!(t.params.len(), t.vel.len());
        assert!((t.eta - rescale::forward_eta(Scheme::BitSerial, 7)).abs() < 1e-6);
    }

    #[test]
    fn one_step_produces_finite_loss_and_moves_params() {
        let m = micro_manifest();
        let job = micro_job(Mode::Ours, 1);
        let mut t = NativeTrainer::new(&m, &job).unwrap();
        let before = t.params.get("s0b0/conv1/w").unwrap().clone();
        let ds = synth::generate(8, 4, 16, 1);
        let mut rng = Rng::new(0);
        let batch = ds.batch(&(0..8).collect::<Vec<_>>(), false, &mut rng);
        let (loss, correct) = t.train_step(&batch.x, &batch.y, 0.05, &mut rng).unwrap();
        assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
        assert!(correct <= 8);
        let after = t.params.get("s0b0/conv1/w").unwrap();
        assert_ne!(before.data, after.data, "PIM conv weights must receive gradient");
        // BN running stats moved off the init values
        let (mean, _) = t.bn_state.get("bn0").unwrap();
        assert!(mean.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn ablation_variants_resolve() {
        let m = micro_manifest();
        let mut job = micro_job(Mode::Ours, 1);
        job.variant = "norescale".to_string();
        let t = NativeTrainer::new(&m, &job).unwrap();
        assert_eq!(t.eta, 1.0);
        assert!(!t.bwd_rescale);
        job.variant = "bogus".to_string();
        assert!(NativeTrainer::new(&m, &job).is_err());
    }

    #[test]
    fn fused_xi_variance_matches_direct() {
        let mut rng = Rng::new(17);
        let (m, kc, o) = (37usize, 18usize, 5usize);
        let patches: Vec<f32> = (0..m * kc).map(|_| rng.normal_in(0.0, 1.0)).collect();
        let wcols: Vec<f32> = (0..kc * o).map(|_| rng.normal_in(0.0, 0.5)).collect();
        let y_pim: Vec<f32> = (0..m * o).map(|_| rng.normal_in(0.1, 2.0)).collect();
        let mut pool_bufs = BufPool::new();
        let (vp, vx) = xi_variance_fused(m, kc, o, &patches, &wcols, &y_pim, &mut pool_bufs);
        let direct = |v: &[f32]| {
            let n = v.len() as f64;
            let mean = v.iter().map(|&x| x as f64).sum::<f64>() / n;
            v.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n
        };
        let y_ex = gemm(m, kc, o, &patches, &wcols);
        assert!((vp - direct(&y_pim)).abs() < 1e-9 * direct(&y_pim).max(1.0), "{vp}");
        assert!((vx - direct(&y_ex)).abs() < 1e-9 * direct(&y_ex).max(1.0), "{vx}");
        assert_eq!(pool_bufs.pooled(), 1, "the tile scratch must return to the pool");
    }

    #[test]
    fn engine_cache_persists_across_steps() {
        let m = micro_manifest();
        let job = micro_job(Mode::Ours, 2);
        let mut t = NativeTrainer::new(&m, &job).unwrap();
        assert!(t.arena.engines.is_empty());
        let ds = synth::generate(8, 4, 16, 1);
        let mut rng = Rng::new(0);
        let batch = ds.batch(&(0..8).collect::<Vec<_>>(), false, &mut rng);
        t.train_step(&batch.x, &batch.y, 0.05, &mut rng).unwrap();
        // micro resnet: 3 stages × depth 1 × 2 PIM convs per block
        assert_eq!(t.arena.engines.len(), 6, "one cached engine per PIM conv");
        t.train_step(&batch.x, &batch.y, 0.05, &mut rng).unwrap();
        assert_eq!(t.arena.engines.len(), 6, "steady state must reuse cached engines");
        assert!(t.arena.pool.pooled() > 0, "step buffers must return to the arena");
    }

    #[test]
    fn steady_state_step_makes_no_large_allocations() {
        // batch 32 puts every feature map above the threshold (the largest
        // BN/activation maps are 32·8·8·4 floats = 32 KiB, the quantized
        // input copy 24 KiB) while weight-scale temporaries stay ≤ ~9 KiB
        // — so 16 KiB now pins the WHOLE armed window: batch acquisition,
        // patch buffers, the L3.7 pooled feature-map intermediates AND the
        // L3.9 packed GEMM panels (the blocked driver's per-thread panel
        // arena: an MC×KC A block alone is ≥ 16 KiB at the default tile,
        // and the micro geometry has k > KC, so the armed step walks the
        // real packing path — a per-call panel allocation would trip this).
        // The autotune probe and the panel-arena grow both happen during
        // the warmup steps below (the probe at the first dispatched call).
        let mut m = micro_manifest();
        m.batch = 32;
        let job = micro_job(Mode::Ours, 3);
        let mut t = NativeTrainer::new(&m, &job).unwrap();
        let ds = synth::generate(8, 4, 64, 1);
        let cfg = LoaderCfg {
            batch: 32,
            augment: true,
            flip: false,
            seed: 5,
            prefetch: 0, // serial: assembly runs inside the armed window
            shards: 1,
            stream_stride: 1,
            stream_offset: 0,
        };
        let mut loader = BatchLoader::new(&ds, cfg).unwrap();
        let mut rng = Rng::new(0);
        // step 1 grows the arena, the loader slot and the worker pool;
        // step 2 lets any remaining lazily-grown buffer reach final size
        for _ in 0..2 {
            let (x, y) = loader.next().unwrap();
            t.train_step(x, y, 0.05, &mut rng).unwrap();
        }
        crate::util::alloc::arm(16 * 1024);
        let (x, y) = loader.next().unwrap();
        t.train_step(x, y, 0.05, &mut rng).unwrap();
        let large = crate::util::alloc::disarm();
        assert_eq!(large, 0, "steady-state acquire+step made {large} large allocation(s)");
    }

    #[test]
    fn training_is_deterministic_across_fresh_trainers() {
        let m = micro_manifest();
        let job = micro_job(Mode::Ours, 4);
        let ds = synth::generate(8, 4, 16, 1);
        let run = || {
            let mut t = NativeTrainer::new(&m, &job).unwrap();
            let mut rng = Rng::new(7);
            let batch = ds.batch(&(0..8).collect::<Vec<_>>(), false, &mut rng);
            let mut losses = Vec::new();
            for _ in 0..4 {
                let mut srng = Rng::new(9);
                let (loss, _) = t.train_step(&batch.x, &batch.y, 0.05, &mut srng).unwrap();
                losses.push(loss);
            }
            losses
        };
        assert_eq!(run(), run(), "engine cache + arena must not perturb the trajectory");
    }

    #[test]
    fn run_job_native_baseline_end_to_end() {
        let m = micro_manifest();
        let job = micro_job(Mode::Baseline, 6);
        let tr = synth::generate(8, 4, 64, 1);
        let te = synth::generate(8, 4, 32, 2);
        let res = run_job_native(&m, &job, &tr, &te, 2).unwrap();
        assert!(!res.history.is_empty());
        assert!(res.history.iter().all(|l| l.loss.is_finite()));
        assert!(res.software_acc.is_finite());
        assert_eq!(res.ckpt.meta.get("backend").unwrap(), "native");
        // checkpoint rebuilds into a Network (all params/state present)
        let net = super::super::network_from_ckpt(&m, &res.ckpt).unwrap();
        let mut rng = Rng::new(1);
        let logits = net
            .forward(&te.batch(&[0, 1], false, &mut rng).x, &ExecSpec::Software, &mut rng)
            .unwrap();
        assert_eq!(logits.shape, vec![2, 4]);
    }

    /// Satellite guard: a non-finite loss must not train on garbage — the
    /// apply stage is skipped, every parameter (and the momentum) stays
    /// exactly where it was.
    #[test]
    fn non_finite_loss_skips_the_update() {
        let m = micro_manifest();
        let job = micro_job(Mode::Baseline, 1);
        let mut t = NativeTrainer::new(&m, &job).unwrap();
        // poison the FC bias: it feeds the logits unquantized, so the NaN
        // reaches the loss directly instead of being laundered through an
        // integer activation cast
        t.params.get_mut("fc/b").unwrap().data[0] = f32::NAN;
        let w_before = t.params.get("conv0/w").unwrap().clone();
        let v_before = t.vel.get("conv0/w").unwrap().clone();
        let ds = synth::generate(8, 4, 16, 1);
        let mut rng = Rng::new(0);
        let batch = ds.batch(&(0..8).collect::<Vec<_>>(), false, &mut rng);
        let (loss, _) = t.train_step(&batch.x, &batch.y, 0.05, &mut rng).unwrap();
        assert!(!loss.is_finite(), "poisoned logits must surface a non-finite loss");
        assert_eq!(t.params.get("conv0/w").unwrap().data, w_before.data);
        assert_eq!(t.vel.get("conv0/w").unwrap().data, v_before.data);
        // the trainer stays usable: healing the poison heals the step
        t.params.get_mut("fc/b").unwrap().data[0] = 0.0;
        let (loss, _) = t.train_step(&batch.x, &batch.y, 0.05, &mut rng).unwrap();
        assert!(loss.is_finite());
        assert_ne!(t.params.get("conv0/w").unwrap().data, w_before.data);
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let m = micro_manifest();
        let mut t = NativeTrainer::new(&m, &micro_job(Mode::Baseline, 1)).unwrap();
        let snap = t.snapshot();
        let ds = synth::generate(8, 4, 16, 1);
        let mut rng = Rng::new(0);
        let batch = ds.batch(&(0..8).collect::<Vec<_>>(), false, &mut rng);
        t.train_step(&batch.x, &batch.y, 0.05, &mut rng).unwrap();
        assert_ne!(t.params.get("conv0/w").unwrap().data, snap.params["conv0/w"].data);
        t.restore_snapshot(&snap);
        assert_eq!(t.params.get("conv0/w").unwrap().data, snap.params["conv0/w"].data);
        assert_eq!(t.vel.get("conv0/w").unwrap().data, snap.vel["conv0/w"].data);
        assert_eq!(t.bn_state.get("bn0").unwrap(), &snap.bn_state["bn0"]);
    }

    #[test]
    fn divergence_guard_decays_lr_and_bounds_retries() {
        let mut g = DivergenceGuard::new(true);
        assert_eq!(g.on_divergence(), Some(0.5));
        assert_eq!(g.on_divergence(), Some(0.25));
        assert_eq!(g.on_divergence(), Some(0.125));
        assert_eq!(g.on_divergence(), None, "bounded attempts");
        // ablation variants keep their divergence: guard off means no help
        let mut off = DivergenceGuard::new(false);
        assert_eq!(off.on_divergence(), None);
        assert_eq!(off.lr_scale, 1.0);
    }

    #[test]
    fn step_faults_bind_fresh_replica_per_step() {
        let m = micro_manifest();
        let mut job = micro_job(Mode::Ours, 1);
        job.faults = "mild:9".to_string();
        let mut t = NativeTrainer::new(&m, &job).unwrap();
        assert!(t.chip.faults.is_none());
        t.set_step_faults(0);
        let f0 = t.chip.faults.expect("step fault replica bound");
        t.set_step_faults(1);
        let f1 = t.chip.faults.unwrap();
        assert_ne!(f0.profile.chip_id, f1.profile.chip_id, "fresh replica per step");
        // no profile → the clean training chip stays clean
        let mut clean = NativeTrainer::new(&m, &micro_job(Mode::Ours, 1)).unwrap();
        clean.set_step_faults(0);
        assert!(clean.chip.faults.is_none());
        // bad specs surface at construction, not mid-training
        job.faults = "catastrophic".to_string();
        assert!(NativeTrainer::new(&m, &job).is_err());
    }

    #[test]
    fn variability_aware_training_runs_and_shifts_the_trajectory() {
        let m = micro_manifest();
        let tr = synth::generate(8, 4, 64, 1);
        let te = synth::generate(8, 4, 32, 2);
        let clean = run_job_native(&m, &micro_job(Mode::Ours, 2), &tr, &te, 1).unwrap();
        let mut fj = micro_job(Mode::Ours, 2);
        fj.faults = "mild".to_string();
        let faulty = run_job_native(&m, &fj, &tr, &te, 1).unwrap();
        assert!(faulty.history.iter().all(|l| l.loss.is_finite()));
        let c: Vec<f32> = clean.history.iter().map(|l| l.loss).collect();
        let f: Vec<f32> = faulty.history.iter().map(|l| l.loss).collect();
        assert_ne!(c, f, "per-step fault replicas must perturb the forward");
    }

    #[test]
    fn restore_from_checkpoint_resumes_params_and_step() {
        let m = micro_manifest();
        let job = micro_job(Mode::Baseline, 1);
        let mut a = NativeTrainer::new(&m, &job).unwrap();
        let ds = synth::generate(8, 4, 16, 1);
        let mut rng = Rng::new(0);
        let batch = ds.batch(&(0..8).collect::<Vec<_>>(), false, &mut rng);
        a.train_step(&batch.x, &batch.y, 0.05, &mut rng).unwrap();
        let mut ck = a.checkpoint(&job);
        ck.meta.insert("step".to_string(), "17".to_string());

        let mut b = NativeTrainer::new(&m, &job).unwrap();
        assert_ne!(b.params.get("conv0/w").unwrap().data, a.params.get("conv0/w").unwrap().data);
        let step = b.restore_from_checkpoint(&ck).unwrap();
        assert_eq!(step, 17);
        assert_eq!(b.params.get("conv0/w").unwrap().data, a.params.get("conv0/w").unwrap().data);
        assert_eq!(b.bn_state.get("bn0").unwrap(), a.bn_state.get("bn0").unwrap());
        // v2 checkpoints carry momentum: the restored trainer continues the
        // same optimizer trajectory instead of restarting velocity at zero
        assert_eq!(b.vel.get("conv0/w").unwrap().data, a.vel.get("conv0/w").unwrap().data);
        assert!(b.vel.get("conv0/w").unwrap().data.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn v1_checkpoint_without_velocity_still_loads_with_zero_momentum() {
        let m = micro_manifest();
        let job = micro_job(Mode::Baseline, 1);
        let mut a = NativeTrainer::new(&m, &job).unwrap();
        let ds = synth::generate(8, 4, 16, 1);
        let mut rng = Rng::new(0);
        let batch = ds.batch(&(0..8).collect::<Vec<_>>(), false, &mut rng);
        a.train_step(&batch.x, &batch.y, 0.05, &mut rng).unwrap();
        let mut ck = a.checkpoint(&job);
        // strip the velocity section to simulate a pre-v2 checkpoint
        ck.velocity.clear();
        ck.meta.remove("ckpt_version");
        let mut b = NativeTrainer::new(&m, &job).unwrap();
        b.vel.get_mut("conv0/w").unwrap().data.fill(0.5);
        b.restore_from_checkpoint(&ck).unwrap();
        assert_eq!(b.params.get("conv0/w").unwrap().data, a.params.get("conv0/w").unwrap().data);
        assert!(b.vel.get("conv0/w").unwrap().data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn resume_with_momentum_matches_uninterrupted_run() {
        // Train 4 steps straight through; separately train 2 steps, round-trip
        // through a v2 checkpoint on disk, and train 2 more.  With velocity
        // serialized the two trajectories are bitwise identical — the whole
        // point of the v2 format.  (Noiseless training chip + identical
        // per-step RNG seeds make train_step deterministic.)
        let m = micro_manifest();
        let job = micro_job(Mode::Baseline, 4);
        let ds = synth::generate(8, 4, 32, 1);

        let step_of = |t: &mut NativeTrainer, step: usize| {
            let mut rng = Rng::new(100 + step as u64);
            let idx: Vec<usize> = (0..8).map(|i| (step * 8 + i) % ds.len()).collect();
            let batch = ds.batch(&idx, false, &mut rng);
            t.train_step(&batch.x, &batch.y, 0.05, &mut rng).unwrap();
        };

        let mut gold = NativeTrainer::new(&m, &job).unwrap();
        for s in 0..4 {
            step_of(&mut gold, s);
        }

        let mut first = NativeTrainer::new(&m, &job).unwrap();
        for s in 0..2 {
            step_of(&mut first, s);
        }
        let dir = std::env::temp_dir().join("pimqat_resume_momentum");
        let _ = std::fs::remove_dir_all(&dir);
        first.checkpoint(&job).save(&dir).unwrap();

        let ck = Checkpoint::load(&dir).unwrap();
        assert!(!ck.velocity.is_empty(), "v2 checkpoint must carry velocity");
        let mut resumed = NativeTrainer::new(&m, &job).unwrap();
        resumed.restore_from_checkpoint(&ck).unwrap();
        for s in 2..4 {
            step_of(&mut resumed, s);
        }

        for (name, p) in &gold.params {
            assert_eq!(
                p.data,
                resumed.params.get(name).unwrap().data,
                "param {name} diverged after resume"
            );
        }
        for (name, v) in &gold.vel {
            assert_eq!(
                v.data,
                resumed.vel.get(name).unwrap().data,
                "velocity {name} diverged after resume"
            );
        }
    }
}
