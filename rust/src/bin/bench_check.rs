//! Bench-regression gate: compare a fresh `BENCH_*.json` against the
//! committed baseline and fail when any case's `ns_per_iter` regressed by
//! more than the allowed factor (ROADMAP.md records the baseline
//! convention; the CI `rust` job's bench-regression gate step runs this
//! after its quick-mode bench pass).
//!
//! Usage: `bench_check <baseline.json> <current.json> [max_ratio]`
//! (default max_ratio 1.3).  Cases present on only one side are reported
//! and skipped.  Exits 1 on regression, 2 on usage/parse errors.
//!
//! `bench_check --report <baseline.json> <current.json>` never gates: it
//! prints each case's headroom against the committed baseline
//! (measured/committed ratio — how much of the allowance a healthy run
//! actually uses), the figure needed to tighten carried-over
//! seeded-estimate baselines from a real CI `BENCH-records` artifact with
//! informed margins.
//!
//! `bench_check --emit-baseline <current.json> <out.json>` writes a
//! *suggested* committed baseline from a fresh measurement: every case's
//! `ns_per_iter` ceiling set to 1.2x the measured figure, tagged
//! `"provenance": "ci-measured"`.  CI uploads these next to the raw
//! `BENCH-records` artifact; refreshing a baseline is then a reviewed
//! copy into `rust/baselines/`, never a hand-typed number.

use std::process::exit;

use pim_qat::util::json::{self, Json};

fn cases(j: &Json) -> Vec<(String, f64)> {
    let mut v = Vec::new();
    if let Some(arr) = j.get("benches").as_arr() {
        for b in arr {
            if let (Some(name), Some(ns)) = (b.get("name").as_str(), b.get("ns_per_iter").as_f64())
            {
                v.push((name.to_string(), ns));
            }
        }
    }
    v
}

fn load(path: &str) -> Json {
    match json::parse_file(std::path::Path::new(path)) {
        Ok(j) => j,
        Err(e) => {
            eprintln!("bench_check: cannot read {path}: {e}");
            exit(2);
        }
    }
}

/// `--report`: informational headroom table, no gate, always exits 0
/// (parse errors still exit 2).
fn report(baseline: &str, current: &str) {
    let base_cases = cases(&load(baseline));
    let cur_cases = cases(&load(current));
    println!("bench headroom vs committed baseline ({baseline}):");
    for (name, ns) in &cur_cases {
        match base_cases.iter().find(|(n, _)| n == name) {
            Some((_, base_ns)) if *base_ns > 0.0 => {
                let ratio = ns / base_ns;
                println!(
                    "{name:<44} measured {ns:>14.0} ns  committed {base_ns:>14.0} ns  \
                     ratio {ratio:>5.2}  headroom {:>5.1}%",
                    100.0 * (1.0 - ratio)
                );
            }
            _ => println!("{name:<44} (no committed baseline)"),
        }
    }
    for (name, _) in &base_cases {
        if !cur_cases.iter().any(|(n, _)| n == name) {
            println!("{name:<44} (baseline case missing from current run)");
        }
    }
    println!(
        "(ratio = measured/committed; a seeded-estimate baseline can be tightened \
         toward measured * margin once CI runs are healthy)"
    );
}

/// `--emit-baseline`: write a suggested committed baseline from a fresh
/// measurement — 1.2x ceilings, `"provenance": "ci-measured"`.
fn emit_baseline(current: &str, out: &str) {
    const MARGIN: f64 = 1.2;
    let cur_cases = cases(&load(current));
    if cur_cases.is_empty() {
        eprintln!("bench_check: no bench cases in {current}");
        exit(2);
    }
    let benches: Vec<Json> = cur_cases
        .iter()
        .map(|(name, ns)| {
            let ceil = (ns * MARGIN).ceil();
            Json::obj(vec![
                ("name", Json::str(name)),
                ("iters", Json::num(0.0)),
                ("ns_per_iter", Json::num(ceil)),
                ("median_ns", Json::num(ceil)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        (
            "note",
            Json::str(&format!(
                "Suggested committed baseline emitted by `bench_check --emit-baseline` \
                 from {current}: ns_per_iter ceilings at {MARGIN}x the quick-mode figures \
                 measured on this run. Review on a healthy commit, then copy into \
                 rust/baselines/ — see ROADMAP.md, bench-baseline convention."
            )),
        ),
        ("provenance", Json::str("ci-measured")),
        ("benches", Json::Arr(benches)),
    ]);
    if let Err(e) = std::fs::write(out, format!("{doc}\n")) {
        eprintln!("bench_check: cannot write {out}: {e}");
        exit(2);
    }
    println!(
        "bench_check: wrote suggested baseline ({} case(s), {MARGIN}x margin) to {out}",
        cur_cases.len()
    );
}

fn main() {
    let mut args: Vec<String> = std::env::args().collect();
    let report_mode = args.iter().any(|a| a == "--report");
    let emit_mode = args.iter().any(|a| a == "--emit-baseline");
    args.retain(|a| a != "--report" && a != "--emit-baseline");
    if args.len() < 3 || (report_mode && emit_mode) {
        eprintln!(
            "usage: bench_check [--report] <baseline.json> <current.json> [max_ratio]\n\
                    bench_check --emit-baseline <current.json> <out.json>"
        );
        exit(2);
    }
    if emit_mode {
        emit_baseline(&args[1], &args[2]);
        return;
    }
    if report_mode {
        report(&args[1], &args[2]);
        return;
    }
    let max_ratio: f64 = match args.get(3) {
        Some(s) => s.parse().unwrap_or_else(|_| {
            eprintln!("bench_check: bad max_ratio {s:?}");
            exit(2);
        }),
        None => 1.3,
    };
    let base_cases = cases(&load(&args[1]));
    let cur_cases = cases(&load(&args[2]));
    let mut failed = false;
    let mut matched = 0usize;
    for (name, ns) in &cur_cases {
        match base_cases.iter().find(|(n, _)| n == name) {
            Some((_, base_ns)) if *base_ns > 0.0 => {
                matched += 1;
                let ratio = ns / base_ns;
                let flag = if ratio > max_ratio {
                    failed = true;
                    "REGRESSED"
                } else {
                    "ok"
                };
                println!(
                    "{name:<44} base {base_ns:>14.0} ns  now {ns:>14.0} ns  \
                     ratio {ratio:>5.2}  {flag}"
                );
            }
            _ => println!("{name:<44} (no baseline — skipped)"),
        }
    }
    for (name, _) in &base_cases {
        if !cur_cases.iter().any(|(n, _)| n == name) {
            println!("{name:<44} (baseline case missing from current run)");
        }
    }
    if failed {
        eprintln!("bench regression: ns_per_iter worse than {max_ratio}x the committed baseline");
        exit(1);
    }
    if matched == 0 && !base_cases.is_empty() {
        // zero overlap would make the gate vacuous — treat renamed/drifted
        // case names as an error, not a silent pass
        eprintln!("bench_check: no case names matched the baseline — refresh the baseline");
        exit(2);
    }
    println!("bench_check: {matched} case(s) within {max_ratio}x of baseline");
}
