//! Bench-regression gate: compare a fresh `BENCH_*.json` against the
//! committed baseline and fail when any case's `ns_per_iter` regressed by
//! more than the allowed factor (ROADMAP.md records the baseline
//! convention; the CI `rust` job's bench-regression gate step runs this
//! after its quick-mode bench pass).
//!
//! Usage: `bench_check <baseline.json> <current.json> [max_ratio]`
//! (default max_ratio 1.3).  Cases present on only one side are reported
//! and skipped.  Exits 1 on regression, 2 on usage/parse errors.
//!
//! `bench_check --report <baseline.json> <current.json>` never gates: it
//! prints each case's headroom against the committed baseline
//! (measured/committed ratio — how much of the allowance a healthy run
//! actually uses), the figure needed to tighten carried-over
//! seeded-estimate baselines from a real CI `BENCH-records` artifact with
//! informed margins.

use std::process::exit;

use pim_qat::util::json::{self, Json};

fn cases(j: &Json) -> Vec<(String, f64)> {
    let mut v = Vec::new();
    if let Some(arr) = j.get("benches").as_arr() {
        for b in arr {
            if let (Some(name), Some(ns)) = (b.get("name").as_str(), b.get("ns_per_iter").as_f64())
            {
                v.push((name.to_string(), ns));
            }
        }
    }
    v
}

fn load(path: &str) -> Json {
    match json::parse_file(std::path::Path::new(path)) {
        Ok(j) => j,
        Err(e) => {
            eprintln!("bench_check: cannot read {path}: {e}");
            exit(2);
        }
    }
}

/// `--report`: informational headroom table, no gate, always exits 0
/// (parse errors still exit 2).
fn report(baseline: &str, current: &str) {
    let base_cases = cases(&load(baseline));
    let cur_cases = cases(&load(current));
    println!("bench headroom vs committed baseline ({baseline}):");
    for (name, ns) in &cur_cases {
        match base_cases.iter().find(|(n, _)| n == name) {
            Some((_, base_ns)) if *base_ns > 0.0 => {
                let ratio = ns / base_ns;
                println!(
                    "{name:<44} measured {ns:>14.0} ns  committed {base_ns:>14.0} ns  \
                     ratio {ratio:>5.2}  headroom {:>5.1}%",
                    100.0 * (1.0 - ratio)
                );
            }
            _ => println!("{name:<44} (no committed baseline)"),
        }
    }
    for (name, _) in &base_cases {
        if !cur_cases.iter().any(|(n, _)| n == name) {
            println!("{name:<44} (baseline case missing from current run)");
        }
    }
    println!(
        "(ratio = measured/committed; a seeded-estimate baseline can be tightened \
         toward measured * margin once CI runs are healthy)"
    );
}

fn main() {
    let mut args: Vec<String> = std::env::args().collect();
    let report_mode = args.iter().any(|a| a == "--report");
    args.retain(|a| a != "--report");
    if args.len() < 3 {
        eprintln!("usage: bench_check [--report] <baseline.json> <current.json> [max_ratio]");
        exit(2);
    }
    if report_mode {
        report(&args[1], &args[2]);
        return;
    }
    let max_ratio: f64 = match args.get(3) {
        Some(s) => s.parse().unwrap_or_else(|_| {
            eprintln!("bench_check: bad max_ratio {s:?}");
            exit(2);
        }),
        None => 1.3,
    };
    let base_cases = cases(&load(&args[1]));
    let cur_cases = cases(&load(&args[2]));
    let mut failed = false;
    let mut matched = 0usize;
    for (name, ns) in &cur_cases {
        match base_cases.iter().find(|(n, _)| n == name) {
            Some((_, base_ns)) if *base_ns > 0.0 => {
                matched += 1;
                let ratio = ns / base_ns;
                let flag = if ratio > max_ratio {
                    failed = true;
                    "REGRESSED"
                } else {
                    "ok"
                };
                println!(
                    "{name:<44} base {base_ns:>14.0} ns  now {ns:>14.0} ns  \
                     ratio {ratio:>5.2}  {flag}"
                );
            }
            _ => println!("{name:<44} (no baseline — skipped)"),
        }
    }
    for (name, _) in &base_cases {
        if !cur_cases.iter().any(|(n, _)| n == name) {
            println!("{name:<44} (baseline case missing from current run)");
        }
    }
    if failed {
        eprintln!("bench regression: ns_per_iter worse than {max_ratio}x the committed baseline");
        exit(1);
    }
    if matched == 0 && !base_cases.is_empty() {
        // zero overlap would make the gate vacuous — treat renamed/drifted
        // case names as an error, not a silent pass
        eprintln!("bench_check: no case names matched the baseline — refresh the baseline");
        exit(2);
    }
    println!("bench_check: {matched} case(s) within {max_ratio}x of baseline");
}
