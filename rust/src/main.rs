//! `pim-qat` — leader binary: training, chip-sim evaluation, BN
//! calibration, sweeps, and paper-reproduction experiments.
//!
//! The CLI parser is hand-rolled (clap is not in the offline crate cache);
//! subcommands mirror DESIGN.md §CLI surface.

use std::path::PathBuf;
use std::process::ExitCode;

use pim_qat::util::error::{anyhow, Result};

use pim_qat::chip::{enob, ChipModel, FaultProfile};
use pim_qat::config::JobConfig;
use pim_qat::coordinator::{sweep, SweepRunner};
use pim_qat::experiments::{self, Scale};
use pim_qat::nn::ExecSpec;
use pim_qat::report;
use pim_qat::train::{self, Backend, BackendChoice, Checkpoint};
use pim_qat::util::rng::Rng;

const USAGE: &str = "\
pim-qat — PIM-QAT reproduction (Jin et al. 2022)

USAGE:
  pim-qat train [key=val ...] [--replicas N]   one training job (N = in-process
                                               data-parallel replica trainers with a
                                               deterministic tree all-reduce;
                                               $PIM_QAT_REPLICAS; native backend)
  pim-qat eval --ckpt DIR [--chip SPEC] [--faults PROFILE] [--calibrate] [key=val ...]
  pim-qat calibrate --ckpt DIR [--chip SPEC] [--faults PROFILE] [--out DIR] [key=val ...]
                                               self-tune BN stats on an injured chip
  pim-qat sweep --grid \"k=v1,v2;k2=v3..v4\" [key=val ...]
  pim-qat serve --ckpt DIR [--replicas N] [--batch B] [--latency-budget-us U]
                [--requests R] [--interarrival-us G] [--producers P]
                [--queue-cap Q] [--chip SPEC] [--faults PROFILE] [--fault-chip I]
                [--ttl-us T] [--hedge-after-us H]
                [--health-probe-every N] [--quarantine-threshold F]
                                               chip-farm inference serving demo
                                               (health flags enable the monitor)
  pim-qat experiment <id|all> [--full]         regenerate paper tables/figures
  pim-qat chip-info [--b-pim B] [--noise S]    curve bank + ENOB report
  pim-qat list                                 models + artifacts in the manifest
  pim-qat --version | --help

Global: --backend auto|native|pjrt (or $PIM_QAT_BACKEND).  `native` is the
zero-dependency in-crate trainer (default); `pjrt` executes AOT HLO
artifacts and needs the `pjrt` cargo feature plus `make artifacts`.
Chip SPEC for eval:  ideal:<bits>[:noise]  |  real[:noise]  |  <curves.json>[:noise]
Fault PROFILE:  none | mild | moderate | severe  (optionally :chip_id) | <profile.json>
Common keys: model, mode(ours|baseline|ams), scheme, uc, b_pim, steps, lr,
seed, train_size, test_size, faults.  Artifacts dir: $PIM_QAT_ARTIFACTS (default ./artifacts).
Experiments: table1 table2 table3 table4 fig3 fig4 fig5 figA2 figA3 tableA2 tableA3 figA6 tableA4 faults";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

/// Split args into flags (`--x [val]`) and positional/key=value parts.
struct Cli {
    positional: Vec<String>,
    kv: Vec<String>,
    flags: Vec<(String, Option<String>)>,
}

fn parse_cli(args: &[String]) -> Cli {
    let mut cli = Cli { positional: vec![], kv: vec![], flags: vec![] };
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(name) = a.strip_prefix("--") {
            let takes_value = matches!(
                name,
                "grid"
                    | "ckpt"
                    | "chip"
                    | "b-pim"
                    | "noise"
                    | "out"
                    | "backend"
                    | "faults"
                    | "replicas"
                    | "batch"
                    | "latency-budget-us"
                    | "requests"
                    | "interarrival-us"
                    | "producers"
                    | "queue-cap"
                    | "fault-chip"
                    | "ttl-us"
                    | "hedge-after-us"
                    | "health-probe-every"
                    | "quarantine-threshold"
            );
            if takes_value && i + 1 < args.len() {
                cli.flags.push((name.to_string(), Some(args[i + 1].clone())));
                i += 2;
                continue;
            }
            cli.flags.push((name.to_string(), None));
        } else if a.contains('=') {
            cli.kv.push(a.clone());
        } else {
            cli.positional.push(a.clone());
        }
        i += 1;
    }
    cli
}

impl Cli {
    fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|(n, _)| n == name)
    }
    fn flag_value(&self, name: &str) -> Option<&str> {
        self.flags
            .iter()
            .find(|(n, _)| n == name)
            .and_then(|(_, v)| v.as_deref())
    }
}

/// Open the training backend: `--backend` flag > `PIM_QAT_BACKEND` env >
/// auto (PJRT when compiled in with artifacts present, else native).
fn open_backend(cli: &Cli) -> Result<Box<dyn Backend>> {
    match cli.flag_value("backend") {
        Some(v) => {
            let choice: BackendChoice = v.parse().map_err(|e: String| anyhow!(e))?;
            train::open_backend(choice)
        }
        None => train::open_default_backend(),
    }
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first().map(|s| s.as_str()) else {
        println!("{USAGE}");
        return Ok(());
    };
    let cli = parse_cli(&args[1..]);
    match cmd {
        "--help" | "help" | "-h" => println!("{USAGE}"),
        "--version" | "version" => println!("pim-qat {}", pim_qat::version()),
        "list" => cmd_list(&cli)?,
        "train" => cmd_train(&cli)?,
        "eval" => cmd_eval(&cli)?,
        "calibrate" => cmd_calibrate(&cli)?,
        "sweep" => cmd_sweep(&cli)?,
        "serve" => cmd_serve(&cli)?,
        "experiment" => cmd_experiment(&cli)?,
        "chip-info" => cmd_chip_info(&cli)?,
        other => return Err(anyhow!("unknown command {other:?}\n{USAGE}")),
    }
    Ok(())
}

fn cmd_list(cli: &Cli) -> Result<()> {
    let backend = open_backend(cli)?;
    println!("backend: {} — {}", backend.name(), backend.platform());
    println!("models:");
    for (k, m) in &backend.manifest().models {
        println!(
            "  {k}: {} depth_n={} width={} image={} classes={} ({} params)",
            m.arch, m.depth_n, m.width, m.image, m.classes, m.param_count()
        );
    }
    if backend.manifest().artifacts.is_empty() {
        println!("artifacts: (none — built-in model registry)");
    } else {
        println!("artifacts:");
        for name in backend.manifest().artifacts.keys() {
            println!("  {name}");
        }
    }
    Ok(())
}

fn job_from_cli(cli: &Cli) -> Result<JobConfig> {
    let mut job = JobConfig::default();
    job.apply_overrides(&cli.kv).map_err(|e| anyhow!(e))?;
    Ok(job)
}

fn cmd_train(cli: &Cli) -> Result<()> {
    let backend = open_backend(cli)?;
    let job = job_from_cli(cli)?;
    let replicas = match cli.flag_value("replicas") {
        Some(v) => Some(v.parse::<usize>()?.max(1)),
        None => train::parallel::replicas_from_env(),
    };
    if let Some(n) = replicas {
        return cmd_train_parallel(&job, backend.as_ref(), n);
    }
    let mut runner = SweepRunner::new(backend.as_ref());
    let out = runner.run(&job)?;
    println!("checkpoint: {}", runner.ckpt_root.join(sweep::fingerprint(&job)).display());
    println!("software accuracy: {:.2}%", out.software_acc);
    for l in &out.history {
        println!(
            "  step {:>5}  lr {:<7} loss {:<8.4} batch-acc {:.1}%",
            l.step, l.lr, l.loss, l.acc
        );
    }
    Ok(())
}

/// `pim-qat train --replicas N` (or `$PIM_QAT_REPLICAS`): route the job
/// through the data-parallel driver (`train::parallel`).  Native backend
/// only — the replicated trainers are in-crate state.  The checkpoint dir
/// gets a `_dpN` suffix for N > 1 (a different global batch is a different
/// trajectory); N = 1 shares the serial fingerprint, to which it is
/// bitwise identical.
fn cmd_train_parallel(job: &JobConfig, backend: &dyn Backend, replicas: usize) -> Result<()> {
    if backend.name() != "native" {
        return Err(anyhow!(
            "--replicas requires the native backend (got {:?}); use --backend native",
            backend.name()
        ));
    }
    let manifest = backend.manifest();
    let entry = manifest.model(&job.model)?;
    let (train_ds, test_ds) = pim_qat::data::load_default(
        entry.image, entry.classes, job.train_size, job.test_size, 0xDA7A ^ job.seed,
    );
    let pcfg = train::ParallelCfg::new(replicas);
    let mut res = train::run_job_parallel(manifest, job, &train_ds, &test_ds, 10, &pcfg)?;
    let fp = if replicas > 1 {
        format!("{}_dp{replicas}", sweep::fingerprint(job))
    } else {
        sweep::fingerprint(job)
    };
    let root = std::env::var_os("PIM_QAT_CKPTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("results/ckpts"));
    let dir = root.join(fp);
    res.ckpt.meta.insert("software_acc".into(), format!("{:.4}", res.software_acc));
    res.ckpt.save(&dir)?;
    println!("checkpoint: {}", dir.display());
    println!("software accuracy: {:.2}%", res.software_acc);
    for l in &res.history {
        println!(
            "  step {:>5}  lr {:<7} loss {:<8.4} batch-acc {:.1}%",
            l.step, l.lr, l.loss, l.acc
        );
    }
    Ok(())
}

fn parse_chip(spec: &str) -> Result<ChipModel> {
    let mut parts = spec.split(':');
    let head = parts.next().unwrap_or("ideal");
    let chip = match head {
        "ideal" => {
            let b: u32 = parts
                .next()
                .ok_or_else(|| anyhow!("ideal:<bits>[:noise]"))?
                .parse()?;
            ChipModel::ideal(b)
        }
        "real" => ChipModel::real(0xC819),
        path => {
            let bank = pim_qat::chip::CurveBank::load(&PathBuf::from(path))?;
            ChipModel {
                b_pim: bank.b_pim,
                noise_lsb: 0.0,
                bank: Some(bank),
                unit_out: 8,
                faults: None,
            }
        }
    };
    let chip = match parts.next() {
        Some(n) => chip.with_noise(n.parse()?),
        None => chip,
    };
    Ok(chip)
}

fn cmd_eval(cli: &Cli) -> Result<()> {
    let backend = open_backend(cli)?;
    let ckpt_dir = cli
        .flag_value("ckpt")
        .ok_or_else(|| anyhow!("--ckpt <dir> required"))?;
    let ckpt = Checkpoint::load(&PathBuf::from(ckpt_dir))?;
    let mut job = JobConfig::default();
    job.model = ckpt.model.clone();
    if let Some(s) = ckpt.meta.get("scheme") {
        job.scheme = s.parse().map_err(|e: String| anyhow!(e))?;
    }
    if let Some(u) = ckpt.meta.get("unit_channels") {
        job.unit_channels = u.parse()?;
    }
    job.apply_overrides(&cli.kv).map_err(|e| anyhow!(e))?;

    let entry = backend.manifest().model(&job.model)?;
    let (train_ds, test_ds) = pim_qat::data::load_default(
        entry.image, entry.classes, job.train_size, job.test_size, 0xDA7A ^ job.seed,
    );
    let mut net = train::network_from_ckpt(backend.manifest(), &ckpt)?;
    let mut rng = Rng::new(1);

    let sw = backend.eval_software(&ckpt, &test_ds)?;
    println!("software (digital) accuracy: {sw:.2}%");

    if let Some(spec) = cli.flag_value("chip") {
        let mut chip = parse_chip(spec)?;
        if let Some(f) = cli.flag_value("faults") {
            chip = chip.with_faults(FaultProfile::parse(f)?);
        }
        let exec = ExecSpec::Pim {
            scheme: job.scheme,
            unit_channels: job.unit_channels,
            chip: &chip,
        };
        if cli.flag("calibrate") {
            net.calibrate_bn(&train_ds, 32, 4, &exec, &mut rng)?;
            println!("BN calibrated on 4 training batches under the target chip");
        }
        let acc = net.evaluate(&test_ds, 32, &exec, &mut rng)?;
        println!(
            "chip accuracy ({spec}, scheme {}, uc {}): {acc:.2}%",
            job.scheme, job.unit_channels
        );
    }
    Ok(())
}

/// `pim-qat calibrate`: the self-tuning field repair.  Loads a checkpoint,
/// injures the deployment chip with a fault profile, reports the clean /
/// injured / self-tuned accuracy ladder, and (with `--out`) saves the
/// repaired checkpoint — same weights, BN statistics re-estimated through
/// the injured forward path (§3.4 applied post-deployment).
fn cmd_calibrate(cli: &Cli) -> Result<()> {
    let backend = open_backend(cli)?;
    let ckpt_dir = cli
        .flag_value("ckpt")
        .ok_or_else(|| anyhow!("--ckpt <dir> required"))?;
    let ckpt = Checkpoint::load(&PathBuf::from(ckpt_dir))?;
    let mut job = JobConfig::default();
    job.model = ckpt.model.clone();
    if let Some(s) = ckpt.meta.get("scheme") {
        job.scheme = s.parse().map_err(|e: String| anyhow!(e))?;
    }
    if let Some(u) = ckpt.meta.get("unit_channels") {
        job.unit_channels = u.parse()?;
    }
    job.apply_overrides(&cli.kv).map_err(|e| anyhow!(e))?;

    let chip = match cli.flag_value("chip") {
        Some(spec) => parse_chip(spec)?,
        None => ChipModel::ideal(7).with_noise(0.35),
    };
    let profile = FaultProfile::parse(cli.flag_value("faults").unwrap_or("moderate"))?;

    let entry = backend.manifest().model(&job.model)?;
    let (train_ds, test_ds) = pim_qat::data::load_default(
        entry.image, entry.classes, job.train_size, job.test_size, 0xDA7A ^ job.seed,
    );
    let cfg = train::SelfTuneCfg {
        scheme: job.scheme,
        unit_channels: job.unit_channels,
        ..Default::default()
    };
    println!(
        "self-tuning {} on chip b_PIM={} noise={} with fault profile {} (chip {})",
        ckpt.model,
        chip.b_pim,
        chip.noise_lsb,
        cli.flag_value("faults").unwrap_or("moderate"),
        profile.chip_id
    );
    let rep = train::self_tune(backend.manifest(), &ckpt, &chip, &profile, &cfg, &train_ds, &test_ds)?;
    println!("  clean chip      : {:.2}%", rep.clean_acc);
    println!("  injured chip    : {:.2}%", rep.injured_acc);
    println!("  self-tuned      : {:.2}%", rep.tuned_acc);
    println!("  drop recovered  : {:.0}%", 100.0 * rep.recovered());
    if let Some(out) = cli.flag_value("out") {
        rep.ckpt.save(&PathBuf::from(out))?;
        println!("repaired checkpoint saved to {out}");
    }
    Ok(())
}

/// `pim-qat serve`: stand up the chip-farm serving layer over a trained
/// checkpoint and drive it with a synthetic open-loop load generator,
/// then report sustained QPS and tail latency (DESIGN.md §Serving layer).
fn cmd_serve(cli: &Cli) -> Result<()> {
    use std::time::Duration;

    let backend = open_backend(cli)?;
    let ckpt_dir = cli
        .flag_value("ckpt")
        .ok_or_else(|| anyhow!("--ckpt <dir> required"))?;
    let ckpt = Checkpoint::load(&PathBuf::from(ckpt_dir))?;
    let mut job = JobConfig::default();
    job.model = ckpt.model.clone();
    if let Some(s) = ckpt.meta.get("scheme") {
        job.scheme = s.parse().map_err(|e: String| anyhow!(e))?;
    }
    if let Some(u) = ckpt.meta.get("unit_channels") {
        job.unit_channels = u.parse()?;
    }
    job.apply_overrides(&cli.kv).map_err(|e| anyhow!(e))?;

    let flag_num = |name: &str, default: usize| -> Result<usize> {
        match cli.flag_value(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    };
    let replicas = flag_num("replicas", 2)?.max(1);
    let batch = flag_num("batch", 8)?.max(1);
    let budget_us = flag_num("latency-budget-us", 2000)? as u64;
    let requests = flag_num("requests", 256)?.max(1);
    let interarrival_us = flag_num("interarrival-us", 0)? as u64;
    let producers = flag_num("producers", 2)?.max(1);
    let queue_cap = flag_num("queue-cap", 4 * batch)?.max(1);
    let ttl_us = flag_num("ttl-us", 0)? as u64;
    let hedge_after_us = flag_num("hedge-after-us", 0)? as u64;
    let fault_chip = match cli.flag_value("fault-chip") {
        Some(v) => Some(v.parse::<u64>()?),
        None => None,
    };
    // either health flag turns the monitor on; the other takes its default
    let health_on = cli.flag_value("health-probe-every").is_some()
        || cli.flag_value("quarantine-threshold").is_some();
    let probe_every = flag_num("health-probe-every", 8)? as u64;
    let quarantine_threshold: f64 = match cli.flag_value("quarantine-threshold") {
        Some(v) => v.parse()?,
        None => 0.25,
    };

    let chip = match cli.flag_value("chip") {
        Some(spec) => parse_chip(spec)?,
        None => ChipModel::ideal(7),
    };
    let faults = match cli.flag_value("faults") {
        Some(f) => {
            let p = FaultProfile::parse(f)?;
            // `none` means pristine chips, not a bound all-zero profile
            (p != FaultProfile::none()).then_some(p)
        }
        None => None,
    };

    let entry = backend.manifest().model(&job.model)?;
    let ds = pim_qat::data::synth::generate(entry.image, entry.classes, 256, 0x10AD ^ job.seed);

    let rcfg = pim_qat::serve::ReplicaCfg {
        scheme: job.scheme,
        unit_channels: job.unit_channels,
        chip,
        faults,
        faults_only: fault_chip,
        seed: job.seed,
    };
    let mut farm = pim_qat::serve::Farm::new(backend.manifest(), &ckpt, &rcfg, replicas)?;
    if health_on {
        let hcfg = pim_qat::serve::HealthCfg {
            probe_every,
            quarantine_threshold,
            ..Default::default()
        };
        // held-out shards: the probe batch and calibration data are drawn
        // from streams disjoint from the request traffic
        let probe_ds =
            pim_qat::data::synth::generate(entry.image, entry.classes, 32, 0x9B0B ^ job.seed);
        let calib =
            pim_qat::data::synth::generate(entry.image, entry.classes, 128, 0xCA11B ^ job.seed);
        let monitor = pim_qat::serve::HealthMonitor::new(
            backend.manifest(),
            &ckpt,
            &rcfg,
            replicas,
            &probe_ds,
            calib,
            hcfg,
        )?;
        farm.attach_health(monitor);
    }
    let scfg = pim_qat::serve::ServeCfg {
        batch,
        latency_budget: Duration::from_micros(budget_us),
        queue_cap,
        hedge_after: (hedge_after_us > 0).then_some(Duration::from_micros(hedge_after_us)),
    };
    println!(
        "serving {} on {replicas} replica chip(s): batch {batch}, budget {budget_us}us, \
         queue cap {queue_cap}, faults {}{}{}",
        ckpt.model,
        cli.flag_value("faults").unwrap_or("none"),
        match fault_chip {
            Some(i) => format!(" (chip {i} only)"),
            None => String::new(),
        },
        if health_on {
            format!(
                ", health on (probe every {probe_every} batches, threshold {quarantine_threshold})"
            )
        } else {
            String::new()
        },
    );
    let mut server = pim_qat::serve::FarmServer::start(farm, scfg);
    let lcfg = pim_qat::serve::LoadCfg {
        requests,
        interarrival: Duration::from_micros(interarrival_us),
        producers,
        ttl: (ttl_us > 0).then_some(Duration::from_micros(ttl_us)),
        ..Default::default()
    };
    let rep = pim_qat::serve::run_open_loop(&server, &ds, &lcfg);
    server.shutdown();

    let ms = |d: Option<Duration>| match d {
        Some(d) => format!("{:.2}", d.as_secs_f64() * 1e3),
        None => "-".to_string(),
    };
    println!(
        "served {} requests in {:.2}s — {:.1} QPS, mean batch {:.2}, \
         timeouts {}, failures {}",
        rep.requests,
        rep.wall.as_secs_f64(),
        rep.qps(),
        rep.mean_batch,
        rep.timeouts,
        rep.failures
    );
    println!(
        "latency ms: mean {}  p50 {}  p95 {}  p99 {}",
        ms(rep.mean_latency()),
        ms(rep.percentile(50.0)),
        ms(rep.percentile(95.0)),
        ms(rep.percentile(99.0))
    );
    for (chip_id, n) in &rep.per_chip {
        println!("  chip {chip_id}: {n} requests");
    }
    if let Some(snap) = server.health_snapshot() {
        println!("replica health:");
        for r in &snap.rows {
            println!(
                "  chip {}: {:?} — {} batches, {} probes, last disagreement {}, \
                 drift {:.3}, {} errors, {} recal attempts",
                r.chip,
                r.state,
                r.batches,
                r.probes,
                r.last_disagreement.map_or("-".into(), |d| format!("{d:.3}")),
                r.drift_score,
                r.errors,
                r.recal_attempts
            );
        }
        println!("  ({} state transitions logged above)", snap.transitions.len());
    }
    Ok(())
}

fn cmd_sweep(cli: &Cli) -> Result<()> {
    let backend = open_backend(cli)?;
    let grid = cli
        .flag_value("grid")
        .ok_or_else(|| anyhow!("--grid \"key=v1,v2;...\" required"))?;
    let base = job_from_cli(cli)?;
    let jobs = sweep::parse_grid(&base, grid).map_err(|e| anyhow!(e))?;
    println!("sweep: {} jobs", jobs.len());
    let mut runner = SweepRunner::new(backend.as_ref());
    let outcomes = runner.run_all(&jobs);
    let mut rep = report::Report::new(
        "sweep",
        &format!("sweep over {grid}"),
        &["job", "software acc", "cached", "wall (s)"],
    );
    for (job, o) in jobs.iter().zip(outcomes) {
        match o {
            Ok(o) => rep.row(vec![
                sweep::fingerprint(job),
                format!("{:.2}", o.software_acc),
                o.cached.to_string(),
                format!("{:.1}", o.wall_s),
            ]),
            Err(e) => rep.row(vec![
                sweep::fingerprint(job),
                format!("FAILED: {e}"),
                "-".into(),
                "-".into(),
            ]),
        }
    }
    println!("{}", rep.render());
    rep.save(&report::results_dir())?;
    Ok(())
}

fn cmd_experiment(cli: &Cli) -> Result<()> {
    let id = cli
        .positional
        .first()
        .ok_or_else(|| anyhow!("experiment id required (or `all`)"))?;
    let scale = if cli.flag("full") { Scale::Full } else { Scale::Quick };
    let ids: Vec<&str> = if id == "all" {
        experiments::ALL.to_vec()
    } else {
        vec![id.as_str()]
    };
    let needs_backend = ids.iter().any(|i| experiments::needs_runtime(i));
    let backend = if needs_backend { Some(open_backend(cli)?) } else { None };
    for id in ids {
        let t0 = std::time::Instant::now();
        let rep = experiments::run_one(id, backend.as_deref(), scale)?;
        println!("{}", rep.render());
        println!("  [{} in {:.1}s]\n", id, t0.elapsed().as_secs_f64());
        rep.save(&report::results_dir())?;
    }
    Ok(())
}

fn cmd_chip_info(cli: &Cli) -> Result<()> {
    let b: u32 = cli.flag_value("b-pim").unwrap_or("7").parse()?;
    let noise: f32 = cli.flag_value("noise").unwrap_or("0.35").parse()?;
    let chip = ChipModel::real(0xC819).with_noise(noise);
    println!("chip: b_PIM={b}, noise={noise} LSB, 32 synthesized measured curves");
    println!(
        "ENOB model: {:.2} bits (suggested training resolution {})",
        enob::enob(b, noise),
        enob::suggested_training_resolution(b, noise)
    );
    if let Some(bank) = &chip.bank {
        let gains: Vec<f32> = bank.curves.iter().map(|c| c.gain).collect();
        let offs: Vec<f32> = bank.curves.iter().map(|c| c.offset).collect();
        let stat = |v: &[f32]| {
            let m = v.iter().sum::<f32>() / v.len() as f32;
            let s = (v.iter().map(|x| (x - m) * (x - m)).sum::<f32>() / v.len() as f32).sqrt();
            (m, s)
        };
        let (gm, gs) = stat(&gains);
        let (om, os) = stat(&offs);
        println!("curve bank: gain {gm:.4}±{gs:.4}, offset {om:.3}±{os:.3} LSB");
        if let Some(out) = cli.flag_value("out") {
            bank.save(&PathBuf::from(out))?;
            println!("bank saved to {out}");
        }
    }
    println!("\nerror-std ratio vs noise (Fig. 3 protocol):");
    for s in [0.0f32, 0.2, 0.35, 0.5, 1.0] {
        println!(
            "  sigma={s:<5} ratio={:.3} ENOB={:.2}",
            enob::error_std_ratio(b, s, 50_000, 7),
            enob::enob(b, s)
        );
    }
    Ok(())
}
