//! Pipelined batch loading (§Perf L3.7): the *acquire* stage of the
//! training step lifecycle (`acquire batch → forward → backward → apply`,
//! see `crate::train::native`).
//!
//! [`BatchLoader`] owns epoch shuffling, batch-buffer reuse and
//! augmentation for the training loop.  With `prefetch ≥ 1` (default 1,
//! i.e. double-buffered; `$PIM_QAT_PREFETCH` overrides, `0` forces
//! serial), the *next* batch's assembly is sharded across the shared
//! worker pool (`util::pool::submit`) and runs concurrently with the
//! current step's forward/backward — by the time the trainer asks for the
//! batch, it is usually already sitting in its slot.
//!
//! ## Determinism contract
//!
//! The pipelined loop is **bit-identical** to the serial loop at any
//! prefetch depth, shard count and `$PIM_QAT_THREADS` setting
//! (`tests/train_pipeline.rs`):
//!
//! * **Shuffle stream** — epoch orders come from a sequential [`Rng`]
//!   advanced only at submission time, on the caller's thread, in step
//!   order.  Prefetch changes *when* a shuffle happens relative to
//!   compute, never the sequence of shuffles.
//! * **Augmentation stream** — per-sample crop/flip draws come from a
//!   positional [`CounterRng`] keyed by `(epoch, step, dataset index)`
//!   (DESIGN.md §Data pipeline).  A sample's augmentation is a pure
//!   function of those coordinates: it does not depend on which shard
//!   assembles it, which other samples share the batch, or how deep the
//!   pipeline runs.  (This replaces the sequential draw-order stream the
//!   pre-L3.7 loop used — same distribution, different draws, same RNG
//!   substitution precedent as the engine's thermal noise.)
//!
//! ## Buffer-slot ownership
//!
//! The loader owns `prefetch + 1` slots, each holding one grown-once batch
//! buffer (`x` tensor + labels + index snapshot) behind a `Box` (stable
//! address — assembly jobs write into it while the loader struct may
//! move).  Slot for step `s` is `s % (prefetch + 1)`; it is reused for
//! step `s + prefetch + 1`, by which time [`BatchLoader::next`] has waited
//! on the slot's ticket and the borrow handed to the trainer has ended.
//! Assembly jobs borrow the dataset and a slot's buffers with their
//! lifetimes erased to `'static`; this is sound because the loader waits
//! on the slot's ticket before every read, every reuse, and when the
//! owning value dies — the same wait-before-touch contract
//! `util::pool::run_scoped` enforces by blocking inline.
//!
//! Because that last wait lives in `Drop`, handing the *owned* loader to
//! arbitrary safe code would be unsound: `std::mem::forget` skips `Drop`,
//! ending the dataset borrow while assembly jobs still read it (the
//! pre-1.0 scoped-thread leak hazard).  The public construction path is
//! therefore **scoped**: [`with_loader`] owns the loader on its own stack
//! frame and lends callers only `&mut BatchLoader`, which cannot be
//! forgotten or swapped for another (no public constructor) — the drop,
//! and with it the final ticket wait, always runs before the dataset
//! borrow ends, on unwind included.  In-crate callers (unit tests, the
//! alloc-counter test) may use the `pub(crate)` `BatchLoader::new`
//! directly, upholding the never-forget contract by inspection.

use crate::tensor::Tensor;
use crate::util::error::{anyhow, Result};
use crate::util::pool;
use crate::util::rng::{CounterRng, Rng};

use super::{augment_shift_into, shift_params, Dataset};

/// Batches assembled ahead of the consumer when `$PIM_QAT_PREFETCH` is
/// unset: double-buffered.
pub const DEFAULT_PREFETCH: usize = 1;

/// Hard cap on the prefetch depth — beyond a few slots there is nothing
/// left to hide and the buffers just burn memory.
pub const MAX_PREFETCH: usize = 8;

/// Resolve the pipeline depth: `$PIM_QAT_PREFETCH` when set (0 forces the
/// serial loop), else [`DEFAULT_PREFETCH`]; clamped to [`MAX_PREFETCH`].
pub fn prefetch_from_env() -> usize {
    std::env::var("PIM_QAT_PREFETCH")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(DEFAULT_PREFETCH)
        .min(MAX_PREFETCH)
}

/// Loader configuration.  [`LoaderCfg::for_training`] is the trainer's
/// default (augment on, flips off, env-resolved prefetch, auto shards).
#[derive(Debug, Clone)]
pub struct LoaderCfg {
    /// Batch size (full batches only; the ragged epoch tail is dropped).
    pub batch: usize,
    /// Apply the random-crop augmentation (training loops).
    pub augment: bool,
    /// Allow horizontal flips (real-CIFAR only — see
    /// [`super::augment_image`] for why synth classes must not flip).
    pub flip: bool,
    /// Seed of both loader streams (shuffle + augmentation).
    pub seed: u64,
    /// Batches assembled ahead of the consumer; 0 = serial assembly in
    /// [`BatchLoader::next`].
    pub prefetch: usize,
    /// Worker shards per batch assembly; 0 = auto (sized like the other
    /// threaded ops, tiny workloads assemble in one piece).
    pub shards: usize,
    /// Shard-stream partition (data-parallel training, §Perf L3.10): of
    /// the *global* batch stream, this loader materializes only batches
    /// `g` with `g % stream_stride == stream_offset`, while advancing the
    /// shared shuffle/epoch bookkeeping through **every** batch.  All
    /// loaders built with the same seed therefore observe the same global
    /// epoch order and partition it disjointly — every dataset index is
    /// seen exactly once per epoch across the shard set, for any stride.
    /// `(1, 0)` (the default) is the unsharded stream.
    pub stream_stride: usize,
    /// This loader's shard slot in `0..stream_stride`.
    pub stream_offset: usize,
}

impl LoaderCfg {
    /// The training-loop configuration: augmented, no flips,
    /// `$PIM_QAT_PREFETCH`-resolved depth, auto shard count.
    pub fn for_training(batch: usize, seed: u64) -> LoaderCfg {
        LoaderCfg {
            batch,
            augment: true,
            flip: false,
            seed,
            prefetch: prefetch_from_env(),
            shards: 0,
            stream_stride: 1,
            stream_offset: 0,
        }
    }

    /// This configuration rebound to shard slot `offset` of a
    /// `stride`-way data-parallel partition of the global batch stream
    /// (see [`LoaderCfg::stream_stride`]).
    pub fn sharded(mut self, offset: usize, stride: usize) -> LoaderCfg {
        self.stream_stride = stride;
        self.stream_offset = offset;
        self
    }
}

/// One prefetch slot: a grown-once batch buffer plus the ticket of the
/// assembly that may still be writing it.  `SlotBuf` lives behind a `Box`
/// so in-flight jobs keep a stable address even if the loader moves.
struct Slot {
    buf: Box<SlotBuf>,
    ticket: Option<pool::Ticket>,
}

struct SlotBuf {
    x: Tensor,
    y: Vec<i32>,
    idx: Vec<usize>,
}

/// Double-buffered training batch source — see the module docs for the
/// pipeline and determinism contracts.
pub struct BatchLoader<'ds> {
    ds: &'ds Dataset,
    cfg: LoaderCfg,
    /// Sequential shuffle stream (advanced in step order at submit time).
    shuffle: Rng,
    /// Positional augmentation stream root (keyed per sample, never
    /// advanced).
    aug: CounterRng,
    /// Current epoch's index order, reshuffled in place (no per-epoch
    /// allocation).
    order: Vec<usize>,
    pos: usize,
    epoch: u64,
    /// Next **global** batch to be drawn from the shuffle stream (counts
    /// skipped-over batches of other shards; equals the local submit
    /// counter only at stride 1).  This is the positional fill key.
    gstep: u64,
    /// Per-sample element count (H·W·C).
    sample: usize,
    slots: Vec<Slot>,
    /// Next step whose assembly will be submitted.
    next_submit: u64,
    /// Next step whose batch will be handed out.
    next_take: u64,
}

/// Run `f` with a [`BatchLoader`] over `ds` — the sound public entry
/// point (see the module docs: the loader value stays owned by this
/// frame, so its final ticket wait cannot be skipped by safe code).
/// Returns `f`'s result, or the construction error when the dataset
/// cannot fill one batch.
pub fn with_loader<R>(
    ds: &Dataset,
    cfg: LoaderCfg,
    f: impl FnOnce(&mut BatchLoader<'_>) -> R,
) -> Result<R> {
    let mut loader = BatchLoader::new(ds, cfg)?;
    Ok(f(&mut loader))
}

impl<'ds> BatchLoader<'ds> {
    /// Build a loader over `ds`.  Fails when the dataset cannot fill one
    /// batch.  Slot buffers are allocated here, once — steady-state
    /// operation performs no batch-scale allocation (the prefetch path
    /// still allocates per-step submission bookkeeping: job boxes and a
    /// ticket, all far below the 16 KiB bar the alloc test pins).
    ///
    /// Crate-internal: callers must never `std::mem::forget` the loader
    /// (module docs §Buffer-slot ownership); external code goes through
    /// [`with_loader`], which makes that impossible.
    pub(crate) fn new(ds: &'ds Dataset, cfg: LoaderCfg) -> Result<BatchLoader<'ds>> {
        if cfg.batch == 0 {
            return Err(anyhow!("batch size 0"));
        }
        if cfg.stream_stride == 0 || cfg.stream_offset >= cfg.stream_stride {
            return Err(anyhow!(
                "shard stream offset {} out of range for stride {}",
                cfg.stream_offset,
                cfg.stream_stride
            ));
        }
        if ds.len() < cfg.batch {
            return Err(anyhow!("dataset smaller than one batch"));
        }
        let s = &ds.images[0].shape;
        let (h, w, c) = (s[0], s[1], s[2]);
        let mut shuffle = Rng::new(cfg.seed);
        let mut order: Vec<usize> = (0..ds.len()).collect();
        shuffle.shuffle(&mut order);
        let n_slots = cfg.prefetch + 1;
        let slots = (0..n_slots)
            .map(|_| Slot {
                buf: Box::new(SlotBuf {
                    x: Tensor::zeros(&[cfg.batch, h, w, c]),
                    y: vec![0; cfg.batch],
                    idx: vec![0; cfg.batch],
                }),
                ticket: None,
            })
            .collect();
        let aug = CounterRng::new(cfg.seed ^ 0xA06_5EED);
        Ok(BatchLoader {
            ds,
            cfg,
            shuffle,
            aug,
            order,
            pos: 0,
            epoch: 0,
            gstep: 0,
            sample: h * w * c,
            slots,
            next_submit: 0,
            next_take: 0,
        })
    }

    /// Acquire the next step's batch.  Tops the pipeline up to `prefetch`
    /// assemblies in flight, waits for this step's slot if its assembly is
    /// still running, and hands out the slot's buffers.  The returned
    /// borrow is valid until the next `&mut self` call; the slot is only
    /// rewritten `prefetch + 1` steps later.
    pub fn next(&mut self) -> Result<(&Tensor, &[i32])> {
        let horizon = self.next_take + self.cfg.prefetch as u64;
        while self.next_submit <= horizon {
            self.submit_one();
        }
        let si = (self.next_take % self.slots.len() as u64) as usize;
        self.next_take += 1;
        if let Some(t) = self.slots[si].ticket.take() {
            t.wait();
        }
        let buf = &*self.slots[si].buf;
        Ok((&buf.x, buf.y.as_slice()))
    }

    /// Global epochs completed so far (diagnostics / tests).  Advances
    /// with the *global* batch stream, including batches this shard
    /// skipped over.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Dataset indices of the most recently acquired batch (shard-coverage
    /// tests / diagnostics).  Valid until the next `&mut self` call, like
    /// the batch borrow itself; meaningless before the first
    /// [`BatchLoader::next`].
    pub fn last_batch_indices(&self) -> &[usize] {
        let si = (self.next_take.wrapping_sub(1) % self.slots.len() as u64) as usize;
        &self.slots[si].buf.idx
    }

    /// Advance the shared shuffle/epoch stream by one global batch
    /// (sequential shuffle stream — caller thread, global step order),
    /// returning the drawn range's start within `order`.
    fn advance_stream(&mut self) -> usize {
        if self.pos + self.cfg.batch > self.order.len() {
            self.epoch += 1;
            self.shuffle.shuffle(&mut self.order);
            self.pos = 0;
        }
        self.pos += self.cfg.batch;
        self.pos - self.cfg.batch
    }

    /// Draw this shard's next batch: advance the global stream past the
    /// batches owned by other shards, draw the one owned by this shard,
    /// and stage its indices into the slot.  Returns the batch's global
    /// step — the positional key `fill_samples` must be given so a
    /// sample's augmentation is independent of the shard partition.
    fn draw_indices(&mut self, si: usize) -> u64 {
        let (stride, offset) = (self.cfg.stream_stride as u64, self.cfg.stream_offset as u64);
        while self.gstep % stride != offset {
            self.advance_stream();
            self.gstep += 1;
        }
        let start = self.advance_stream();
        let g = self.gstep;
        self.gstep += 1;
        let buf = &mut *self.slots[si].buf;
        buf.idx.clear();
        buf.idx.extend_from_slice(&self.order[start..start + self.cfg.batch]);
        buf.y.clear();
        buf.y.extend(buf.idx.iter().map(|&i| self.ds.labels[i]));
        g
    }

    /// Submit (or, serial mode, run) the assembly of local step
    /// `next_submit` into its slot.
    fn submit_one(&mut self) {
        let local = self.next_submit;
        self.next_submit += 1;
        let si = (local % self.slots.len() as u64) as usize;
        debug_assert!(
            self.slots[si].ticket.is_none(),
            "slot reused while its assembly is in flight"
        );
        let step = self.draw_indices(si);
        let epoch = self.epoch;
        let (ds, aug) = (self.ds, self.aug);
        let (augment, flip, sample) = (self.cfg.augment, self.cfg.flip, self.sample);
        let shards = self.effective_shards();
        let buf = &mut *self.slots[si].buf;
        buf.x.data.resize(self.cfg.batch * sample, 0.0); // no-op after construction
        if self.cfg.prefetch == 0 {
            // serial reference path: same positional fill, inline
            fill_samples(ds, &buf.idx, epoch, step, &aug, augment, flip, &mut buf.x.data);
            return;
        }
        let per = (self.cfg.batch + shards - 1) / shards;
        let idx: &[usize] = &buf.idx;
        let mut jobs: Vec<pool::ScopedJob<'_>> = Vec::with_capacity(shards);
        for (ci, chunk) in buf.x.data.chunks_mut(per * sample).enumerate() {
            let ids = &idx[ci * per..ci * per + chunk.len() / sample];
            jobs.push(Box::new(move || {
                fill_samples(ds, ids, epoch, step, &aug, augment, flip, chunk);
            }));
        }
        // SAFETY: erases the borrows of the dataset and this slot's
        // buffers.  Sound because the ticket stored on the slot is waited
        // before the buffers are read (`next`), rewritten (the
        // `debug_assert` above guards the invariant that a reused slot's
        // ticket was already taken), or dropped (`Drop` below) — and the
        // dataset outlives the loader by the `'ds` bound, with `Drop`
        // barring in-flight jobs from outliving the loader itself.
        let jobs: Vec<pool::ScopedJob<'static>> = jobs
            .into_iter()
            .map(|j| {
                let j: pool::ScopedJob<'static> = unsafe { std::mem::transmute(j) };
                j
            })
            .collect();
        self.slots[si].ticket = Some(pool::submit(jobs));
    }

    /// Shard count for one batch assembly: explicit `cfg.shards` wins
    /// (capped at the batch size); auto sizes like the other threaded ops
    /// — tiny batches assemble in one piece.
    fn effective_shards(&self) -> usize {
        if self.cfg.shards > 0 {
            return self.cfg.shards.min(self.cfg.batch).max(1);
        }
        crate::tensor::ops::work_threads(0, self.cfg.batch * self.sample, self.cfg.batch)
    }
}

impl Drop for BatchLoader<'_> {
    fn drop(&mut self) {
        // the erased-lifetime contract: no assembly may outlive the slot
        // buffers or the dataset borrow.  A panicked never-consumed job
        // re-raises here like std::thread::scope would — except while
        // this thread is already unwinding, where a second panic would
        // abort the process, so only then is the payload swallowed.
        for s in &mut self.slots {
            if let Some(t) = s.ticket.take() {
                if std::thread::panicking() {
                    let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| t.wait()));
                } else {
                    t.wait();
                }
            }
        }
    }
}

/// Positional assembly core shared by the serial path, every shard job,
/// and the property tests: fill a contiguous run of batch samples, sample
/// `ids[j]`'s pixels landing at `x[j·sample ..]`.
///
/// Augmentation draws come from `aug.stream3(epoch, step, dataset index)`
/// in the fixed order (dy at counter 0, dx at 1, flip at 2), so a sample's
/// crop/flip depends **only** on the epoch, the step and its own dataset
/// index — never on batch composition, its position in the batch, shard
/// partitioning, or prefetch depth.
#[allow(clippy::too_many_arguments)]
pub fn fill_samples(
    ds: &Dataset,
    ids: &[usize],
    epoch: u64,
    step: u64,
    aug: &CounterRng,
    augment: bool,
    flip: bool,
    x: &mut [f32],
) {
    let sample = if ids.is_empty() { 0 } else { ds.images[ids[0]].len() };
    assert_eq!(x.len(), ids.len() * sample, "batch shard size");
    for (j, &di) in ids.iter().enumerate() {
        let img = &ds.images[di];
        let dst = &mut x[j * sample..(j + 1) * sample];
        if augment {
            let s = aug.stream3(epoch, step, di as u64);
            let (dy, dx, fl) = shift_params(|i, n| s.below_at(i, n), flip);
            augment_shift_into(img, dy, dx, fl, dst);
        } else {
            dst.copy_from_slice(&img.data);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn cfg(batch: usize, prefetch: usize, shards: usize, augment: bool) -> LoaderCfg {
        LoaderCfg {
            batch,
            augment,
            flip: false,
            seed: 11,
            prefetch,
            shards,
            stream_stride: 1,
            stream_offset: 0,
        }
    }

    #[test]
    fn rejects_undersized_dataset() {
        let ds = synth::generate(8, 2, 4, 0);
        assert!(BatchLoader::new(&ds, cfg(8, 1, 0, false)).is_err());
        assert!(BatchLoader::new(&ds, cfg(0, 1, 0, false)).is_err());
    }

    #[test]
    fn serial_and_pipelined_batches_are_bit_identical() {
        let ds = synth::generate(8, 4, 20, 3);
        let run = |prefetch: usize, shards: usize| {
            let mut l = BatchLoader::new(&ds, cfg(8, prefetch, shards, true)).unwrap();
            let mut out = Vec::new();
            for _ in 0..7 {
                // 7 batches over 20 samples: crosses epoch boundaries
                let (x, y) = l.next().unwrap();
                out.push((x.data.clone(), y.to_vec()));
            }
            out
        };
        let want = run(0, 1);
        for &(p, s) in &[(0usize, 4usize), (1, 1), (1, 4), (2, 3), (4, 2)] {
            assert_eq!(run(p, s), want, "prefetch={p} shards={s} diverged from serial");
        }
    }

    #[test]
    fn rejects_bad_shard_stream() {
        let ds = synth::generate(8, 2, 16, 0);
        assert!(BatchLoader::new(&ds, cfg(8, 0, 1, false).sharded(0, 0)).is_err());
        assert!(BatchLoader::new(&ds, cfg(8, 0, 1, false).sharded(2, 2)).is_err());
        assert!(BatchLoader::new(&ds, cfg(8, 0, 1, false).sharded(1, 2)).is_ok());
    }

    #[test]
    fn sharded_streams_partition_the_global_batch_sequence_bitwise() {
        let ds = synth::generate(8, 4, 24, 13);
        let take = |c: LoaderCfg, n: usize| {
            let mut l = BatchLoader::new(&ds, c).unwrap();
            let mut out = Vec::new();
            for _ in 0..n {
                let (x, y) = {
                    let (x, y) = l.next().unwrap();
                    (x.data.clone(), y.to_vec())
                };
                out.push((x, y, l.last_batch_indices().to_vec()));
            }
            out
        };
        // augment=true so the positional fill key (global step, not the
        // shard-local counter) is what the pixel comparison pins
        let global = take(cfg(8, 1, 0, true), 6);
        for stride in [2usize, 3] {
            let shards: Vec<_> = (0..stride)
                .map(|o| take(cfg(8, 1, 0, true).sharded(o, stride), 6 / stride))
                .collect();
            for (g, want) in global.iter().enumerate() {
                let got = &shards[g % stride][g / stride];
                assert_eq!(got, want, "global batch {g} diverged at stride {stride}");
            }
        }
    }

    #[test]
    fn sharded_epoch_coverage_is_exact() {
        // 24 samples / batch 8 = 3 global batches per epoch, no tail: for
        // any stride, the union of the shards' epoch-0 batches must be the
        // whole dataset, each index exactly once.
        let ds = synth::generate(8, 2, 24, 5);
        for stride in [1usize, 2, 3] {
            let mut seen: Vec<usize> = Vec::new();
            for o in 0..stride {
                let mut l = BatchLoader::new(&ds, cfg(8, 0, 1, false).sharded(o, stride)).unwrap();
                let mine = (3 - o + stride - 1) / stride; // this shard's epoch-0 batches
                for _ in 0..mine {
                    l.next().unwrap();
                    seen.extend_from_slice(l.last_batch_indices());
                }
            }
            seen.sort();
            assert_eq!(seen, (0..24).collect::<Vec<_>>(), "stride {stride} epoch coverage");
        }
    }

    #[test]
    fn epoch_reshuffle_covers_dataset_and_drops_tail() {
        let ds = synth::generate(8, 2, 10, 5);
        let mut l = BatchLoader::new(&ds, cfg(3, 0, 1, false)).unwrap();
        let mut first_epoch: Vec<usize> = Vec::new();
        for _ in 0..3 {
            l.next().unwrap();
            let si = ((l.next_take - 1) % l.slots.len() as u64) as usize;
            first_epoch.extend_from_slice(&l.slots[si].buf.idx);
        }
        assert_eq!(l.epoch(), 0);
        let mut uniq = first_epoch.clone();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), 9, "an epoch must not repeat samples");
        l.next().unwrap(); // 10th sample is the dropped tail → reshuffle
        assert_eq!(l.epoch(), 1);
    }

    #[test]
    fn labels_match_indices_and_buffers_are_reused() {
        let ds = synth::generate(8, 4, 16, 7);
        let mut l = BatchLoader::new(&ds, cfg(4, 2, 2, true)).unwrap();
        let mut ptrs = std::collections::BTreeSet::new();
        for _ in 0..9 {
            // copy out what the batch borrow provides before inspecting
            // the loader's internals (the borrow ties up &mut l)
            let (ptr, shape, ys) = {
                let (x, y) = l.next().unwrap();
                (x.data.as_ptr() as usize, x.shape.clone(), y.to_vec())
            };
            assert_eq!(shape, vec![4, 8, 8, 3]);
            ptrs.insert(ptr);
            let si = ((l.next_take - 1) % l.slots.len() as u64) as usize;
            for (j, &di) in l.slots[si].buf.idx.iter().enumerate() {
                assert_eq!(ys[j], ds.labels[di]);
            }
        }
        assert_eq!(ptrs.len(), 3, "prefetch=2 must cycle exactly 3 slot buffers");
    }

    #[test]
    fn augmentation_is_a_pure_function_of_epoch_step_and_index() {
        let ds = synth::generate(8, 4, 12, 9);
        let aug = CounterRng::new(42);
        let sample = ds.images[0].len();
        let fill = |ids: &[usize], epoch: u64, step: u64| {
            let mut x = vec![f32::NAN; ids.len() * sample];
            fill_samples(&ds, ids, epoch, step, &aug, true, false, &mut x);
            x
        };
        let a = fill(&[0, 1, 2, 3], 0, 5);
        // permuting the batch moves pixels with their sample, bit-for-bit
        let b = fill(&[3, 1, 0, 2], 0, 5);
        assert_eq!(&a[0..sample], &b[2 * sample..3 * sample], "sample 0 changed with order");
        assert_eq!(&a[sample..2 * sample], &b[sample..2 * sample], "sample 1 changed with order");
        // swapping in unrelated samples changes nothing for the survivors
        let c = fill(&[7, 1, 9, 3], 0, 5);
        assert_eq!(&a[sample..2 * sample], &c[sample..2 * sample]);
        assert_eq!(&a[3 * sample..], &c[3 * sample..]);
        // ... but epoch and step both move the draw
        let d = fill(&[0, 1, 2, 3], 1, 5);
        let e = fill(&[0, 1, 2, 3], 0, 6);
        assert_ne!(a, d, "epoch must key the augmentation stream");
        assert_ne!(a, e, "step must key the augmentation stream");
    }
}
