//! Dataset substrate (S6).
//!
//! The paper trains on CIFAR10/100; this testbed has no network access, so
//! the default corpus is **synth-CIFAR**: a deterministic class-conditional
//! image distribution (per-class smooth random Fourier templates + instance
//! jitter, shift, flip and pixel noise) that a small CNN must genuinely
//! learn (non-linearly separable, ~% accuracy tracks capacity) while staying
//! cheap.  If a real CIFAR-10 binary set is present at `data/cifar-10-
//! batches-bin`, it is used instead (same API).  See DESIGN.md
//! §Substitutions.

pub mod cifar;
pub mod loader;
pub mod synth;

use crate::tensor::Tensor;

/// A labeled image batch, NHWC in [0,1].
#[derive(Debug, Clone)]
pub struct Batch {
    pub x: Tensor,
    pub y: Vec<i32>,
}

/// An in-memory dataset of images [N,H,W,C] + labels.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub images: Vec<Tensor>,
    pub labels: Vec<i32>,
    pub classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.images.len()
    }

    pub fn is_empty(&self) -> bool {
        self.images.is_empty()
    }

    /// Assemble a batch from indices, optionally with train-time
    /// augmentation (random crop with 2px pad + horizontal flip — §A2.1
    /// scaled to the small image).  Samples are written straight into the
    /// batch buffer — no per-image intermediate on either path.  The
    /// training loop itself batches through [`loader::BatchLoader`], which
    /// additionally reuses its buffers and overlaps assembly with compute;
    /// this allocating form serves evaluation, tests and benches.
    pub fn batch(
        &self,
        idx: &[usize],
        augment: bool,
        rng: &mut crate::util::rng::Rng,
    ) -> Batch {
        let (h, w, c) = {
            let s = &self.images[0].shape;
            (s[0], s[1], s[2])
        };
        let mut x = Tensor::zeros(&[idx.len(), h, w, c]);
        let mut y = Vec::with_capacity(idx.len());
        for (bi, &i) in idx.iter().enumerate() {
            let dst = &mut x.data[bi * h * w * c..(bi + 1) * h * w * c];
            if augment {
                let (dy, dx, flip) = draw_shift(rng, false);
                augment_shift_into(&self.images[i], dy, dx, flip, dst);
            } else {
                dst.copy_from_slice(&self.images[i].data);
            }
            y.push(self.labels[i]);
        }
        Batch { x, y }
    }
}

/// Augmentation pad: random crop offsets are drawn from
/// [-AUG_PAD, AUG_PAD] per axis.
pub const AUG_PAD: usize = 2;

/// Map a draw source to one sample's augmentation parameters — the
/// single definition of the draw layout (bounds, order, offsets) shared
/// by the sequential-Rng paths here and the positional counter-RNG path
/// in [`loader`].  `draw(i, n)` returns the `i`-th uniform draw in
/// [0, n): `i` = 0 → dy, 1 → dx, 2 → flip; the flip draw is only
/// consumed when flips are allowed (the historical sequential stream
/// layout — positional sources simply never read counter 2).
pub fn shift_params(
    mut draw: impl FnMut(u64, usize) -> usize,
    allow_flip: bool,
) -> (isize, isize, bool) {
    let d = 2 * AUG_PAD + 1;
    let dy = draw(0, d) as isize - AUG_PAD as isize;
    let dx = draw(1, d) as isize - AUG_PAD as isize;
    let flip = allow_flip && draw(2, 2) == 1;
    (dy, dx, flip)
}

/// [`shift_params`] over a sequential stream (the counter index is
/// ignored — draws come in call order).
fn draw_shift(rng: &mut crate::util::rng::Rng, allow_flip: bool) -> (isize, isize, bool) {
    shift_params(|_, n| rng.below(n), allow_flip)
}

/// Random crop (pad 2, shift), mirroring the paper's CIFAR augmentation at
/// this image size.  NOTE: unlike CIFAR objects, the synthetic plaid
/// classes are *not* mirror-invariant, so horizontal flips would relabel
/// inputs inconsistently and poison training — flips are applied only when
/// `flip` is requested (real-CIFAR path).
pub fn augment_image(img: &Tensor, rng: &mut crate::util::rng::Rng) -> Tensor {
    augment_image_opts(img, rng, false)
}

/// Augmentation with explicit flip control.
pub fn augment_image_opts(
    img: &Tensor,
    rng: &mut crate::util::rng::Rng,
    allow_flip: bool,
) -> Tensor {
    let (dy, dx, flip) = draw_shift(rng, allow_flip);
    let mut out = Tensor::zeros(&img.shape);
    augment_shift_into(img, dy, dx, flip, &mut out.data);
    out
}

/// The augmentation core shared by every caller (sequential-Rng paths
/// above, the counter-RNG [`loader`] assembly): shifted copy of `img` into
/// `dst` with zero padding and optional horizontal flip.  `dst` is fully
/// overwritten (out-of-range pixels become 0), so callers may hand in a
/// dirty reused buffer.
pub fn augment_shift_into(img: &Tensor, dy: isize, dx: isize, flip: bool, dst: &mut [f32]) {
    let (h, w, c) = (img.shape[0], img.shape[1], img.shape[2]);
    assert_eq!(dst.len(), h * w * c, "augment destination size");
    dst.fill(0.0);
    for y in 0..h {
        for x in 0..w {
            let sy = y as isize + dy;
            let sx = x as isize + dx;
            if sy < 0 || sy >= h as isize || sx < 0 || sx >= w as isize {
                continue;
            }
            let sx = if flip { w - 1 - sx as usize } else { sx as usize };
            for ci in 0..c {
                dst[(y * w + x) * c + ci] = img.data[((sy as usize) * w + sx) * c + ci];
            }
        }
    }
}

/// Epoch iterator: shuffled full batches of size `bs` (drops the ragged
/// tail, like the training loader in the paper's setup).
pub struct EpochIter {
    order: Vec<usize>,
    pos: usize,
    bs: usize,
}

impl EpochIter {
    pub fn new(n: usize, bs: usize, rng: &mut crate::util::rng::Rng) -> Self {
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        EpochIter { order, pos: 0, bs }
    }

    pub fn next_indices(&mut self) -> Option<&[usize]> {
        if self.pos + self.bs > self.order.len() {
            return None;
        }
        let s = &self.order[self.pos..self.pos + self.bs];
        self.pos += self.bs;
        Some(s)
    }
}

/// Load the configured dataset: real CIFAR-10 when present, else synthetic.
pub fn load_default(
    image: usize,
    classes: usize,
    train_size: usize,
    test_size: usize,
    seed: u64,
) -> (Dataset, Dataset) {
    if classes == 10 && image == 32 {
        if let Ok(ds) = cifar::load_cifar10(std::path::Path::new("data/cifar-10-batches-bin")) {
            return ds;
        }
    }
    (
        synth::generate(image, classes, train_size, seed),
        synth::generate(image, classes, test_size, seed ^ 0x5EED_7E57),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn batch_assembly() {
        let ds = synth::generate(8, 4, 16, 0);
        let mut rng = Rng::new(0);
        let b = ds.batch(&[0, 3, 5], false, &mut rng);
        assert_eq!(b.x.shape, vec![3, 8, 8, 3]);
        assert_eq!(b.y.len(), 3);
        assert_eq!(b.y[0], ds.labels[0]);
    }

    #[test]
    fn augment_preserves_range_and_shape() {
        let ds = synth::generate(8, 2, 4, 1);
        let mut rng = Rng::new(2);
        let a = augment_image(&ds.images[0], &mut rng);
        assert_eq!(a.shape, ds.images[0].shape);
        assert!(a.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn epoch_iter_covers_everything_once() {
        let mut rng = Rng::new(3);
        let mut it = EpochIter::new(10, 3, &mut rng);
        let mut seen = Vec::new();
        while let Some(ix) = it.next_indices() {
            seen.extend_from_slice(ix);
        }
        assert_eq!(seen.len(), 9); // ragged tail dropped
        let mut uniq = seen.clone();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), seen.len());
    }
}
