//! Real CIFAR-10 loader (binary version 1 format).
//!
//! Used automatically by `data::load_default` when
//! `data/cifar-10-batches-bin/` exists — the reproduction then runs on the
//! paper's actual dataset.  Each record is 1 label byte + 3072 CHW pixel
//! bytes; we convert to NHWC f32 in [0,1].

use std::path::Path;

use crate::util::error::{anyhow, Result};

use super::Dataset;
use crate::tensor::Tensor;

const REC: usize = 1 + 3 * 32 * 32;

fn load_file(path: &Path, images: &mut Vec<Tensor>, labels: &mut Vec<i32>) -> Result<()> {
    let bytes = std::fs::read(path)?;
    if bytes.len() % REC != 0 {
        return Err(anyhow!("{}: size {} not a multiple of {REC}", path.display(), bytes.len()));
    }
    for rec in bytes.chunks_exact(REC) {
        let label = rec[0] as i32;
        if !(0..10).contains(&label) {
            return Err(anyhow!("bad label {label}"));
        }
        let mut img = Tensor::zeros(&[32, 32, 3]);
        // file is CHW (R plane, G plane, B plane)
        for c in 0..3 {
            for y in 0..32 {
                for x in 0..32 {
                    img.data[(y * 32 + x) * 3 + c] =
                        rec[1 + c * 1024 + y * 32 + x] as f32 / 255.0;
                }
            }
        }
        images.push(img);
        labels.push(label);
    }
    Ok(())
}

/// Load (train, test) from a cifar-10-batches-bin directory.
pub fn load_cifar10(dir: &Path) -> Result<(Dataset, Dataset)> {
    let mut tr_img = Vec::new();
    let mut tr_lab = Vec::new();
    for i in 1..=5 {
        load_file(&dir.join(format!("data_batch_{i}.bin")), &mut tr_img, &mut tr_lab)?;
    }
    let mut te_img = Vec::new();
    let mut te_lab = Vec::new();
    load_file(&dir.join("test_batch.bin"), &mut te_img, &mut te_lab)?;
    Ok((
        Dataset { images: tr_img, labels: tr_lab, classes: 10 },
        Dataset { images: te_img, labels: te_lab, classes: 10 },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_synthetic_record() {
        let dir = std::env::temp_dir().join("pimqat_cifar_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut rec = vec![0u8; REC * 2];
        rec[0] = 3; // label
        rec[1] = 255; // R(0,0)
        rec[REC] = 9;
        rec[REC + 1 + 2048] = 128; // B(0,0) of second record
        for i in 1..=5 {
            std::fs::write(dir.join(format!("data_batch_{i}.bin")), &rec).unwrap();
        }
        std::fs::write(dir.join("test_batch.bin"), &rec).unwrap();
        let (tr, te) = load_cifar10(&dir).unwrap();
        assert_eq!(tr.len(), 10);
        assert_eq!(te.len(), 2);
        assert_eq!(tr.labels[0], 3);
        assert_eq!(tr.labels[1], 9);
        assert!((tr.images[0].at4_free(0, 0, 0) - 1.0).abs() < 1e-6);
        assert!((te.images[1].at4_free(0, 0, 2) - 128.0 / 255.0).abs() < 1e-6);
    }

    #[test]
    fn rejects_truncated() {
        let dir = std::env::temp_dir().join("pimqat_cifar_bad");
        std::fs::create_dir_all(&dir).unwrap();
        for i in 1..=5 {
            std::fs::write(dir.join(format!("data_batch_{i}.bin")), [0u8; 100]).unwrap();
        }
        std::fs::write(dir.join("test_batch.bin"), [0u8; 100]).unwrap();
        assert!(load_cifar10(&dir).is_err());
    }
}

#[cfg(test)]
impl Tensor {
    /// 3-D HWC accessor used only by the tests above.
    fn at4_free(&self, h: usize, w: usize, c: usize) -> f32 {
        self.data[(h * self.shape[1] + w) * self.shape[2] + c]
    }
}
