//! synth-CIFAR: deterministic class-conditional image corpus.
//!
//! All classes share one global pool of 2-D sinusoidal plaid components;
//! a class is defined by its *mixing signs* over that pool.  Every class
//! therefore has the same marginal spectrum — a classifier has to detect
//! relative phase relationships, not just dominant frequencies — which
//! keeps the task capacity/training-limited (like CIFAR at small scale)
//! while remaining cheap and fully deterministic.  Instances get a random
//! translation, per-component amplitude jitter, and pixel noise.
//!
//! `seed` controls only instance sampling; the component pool and class
//! mixings are fixed global properties, so train/test splits drawn with
//! different seeds share class definitions.  See DESIGN.md §Substitutions.

use super::Dataset;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

const TEMPLATE_SEED: u64 = 0xC1A5_5E5;

/// Pool size grows with class count so distinct sign patterns exist
/// (2^{pool-1} usable signatures after negation-aliasing).
fn pool_size(classes: usize) -> usize {
    let mut p = 6usize;
    while (1usize << (p - 1)) < 2 * classes {
        p += 1;
    }
    p
}

/// Plaid component: frequency vector + per-channel phase.
#[derive(Debug, Clone)]
struct Plaid {
    fx: f32,
    fy: f32,
    phase: [f32; 3],
}

fn component_pool(pool: usize) -> Vec<Plaid> {
    let mut rng = Rng::new(TEMPLATE_SEED);
    (0..pool)
        .map(|_| {
            let f = rng.uniform_in(1.0, 3.5);
            let theta = rng.uniform_in(0.0, std::f32::consts::PI);
            Plaid {
                fx: f * theta.cos(),
                fy: f * theta.sin(),
                phase: [
                    rng.uniform_in(0.0, std::f32::consts::TAU),
                    rng.uniform_in(0.0, std::f32::consts::TAU),
                    rng.uniform_in(0.0, std::f32::consts::TAU),
                ],
            }
        })
        .collect()
}

/// Class mixing signs over the pool: entries in {-1, +1} (never 0, so all
/// classes carry energy in every component — only relative signs differ).
fn class_mixing(classes: usize, pool: usize) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(TEMPLATE_SEED ^ 0xBEEF);
    let mut seen: Vec<Vec<f32>> = Vec::new();
    while seen.len() < classes {
        let cand: Vec<f32> = (0..pool)
            .map(|_| if rng.below(2) == 0 { -1.0 } else { 1.0 })
            .collect();
        // ensure distinct class signatures (and not the global negation of
        // an existing one, which translation could alias)
        let neg: Vec<f32> = cand.iter().map(|v| -v).collect();
        if !seen.contains(&cand) && !seen.contains(&neg) {
            seen.push(cand);
        }
    }
    seen
}

/// Generate `n` samples of `classes` classes at `image`×`image`×3.
pub fn generate(image: usize, classes: usize, n: usize, seed: u64) -> Dataset {
    let psize = pool_size(classes);
    let pool = component_pool(psize);
    let mixing = class_mixing(classes, psize);
    let mut rng = Rng::new(seed);
    let mut images = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    let tau = std::f32::consts::TAU;
    let amp = 0.13f32;
    for i in 0..n {
        let class = i % classes; // balanced
        let shift_x = rng.uniform_in(0.0, 0.45);
        let shift_y = rng.uniform_in(0.0, 0.45);
        let jit: Vec<f32> = (0..psize).map(|_| rng.uniform_in(0.75, 1.25)).collect();
        let noise = 0.16f32;
        let mut img = Tensor::zeros(&[image, image, 3]);
        for y in 0..image {
            for x in 0..image {
                let u = x as f32 / image as f32 + shift_x;
                let v = y as f32 / image as f32 + shift_y;
                for ch in 0..3 {
                    let mut val = 0.5;
                    for (p, plaid) in pool.iter().enumerate() {
                        val += mixing[class][p]
                            * jit[p]
                            * amp
                            * (tau * (plaid.fx * u + plaid.fy * v) + plaid.phase[ch]).sin();
                    }
                    val += rng.normal_in(0.0, noise);
                    img.data[(y * image + x) * 3 + ch] = val.clamp(0.0, 1.0);
                }
            }
        }
        images.push(img);
        labels.push(class as i32);
    }
    Dataset { images, labels, classes }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = generate(8, 4, 8, 7);
        let b = generate(8, 4, 8, 7);
        for (x, y) in a.images.iter().zip(&b.images) {
            assert_eq!(x.data, y.data);
        }
    }

    #[test]
    fn train_test_share_class_definitions() {
        // same class, different seeds → images correlate above cross-class
        let a = generate(16, 4, 40, 1);
        let b = generate(16, 4, 40, 2);
        let mean = |c: usize, ds: &Dataset| -> Vec<f32> {
            let dim = ds.images[0].len();
            let mut m = vec![0.0f32; dim];
            let mut cnt = 0;
            for (img, &l) in ds.images.iter().zip(&ds.labels) {
                if l as usize == c {
                    for (mi, &v) in m.iter_mut().zip(&img.data) {
                        *mi += v;
                    }
                    cnt += 1;
                }
            }
            m.iter().map(|v| v / cnt as f32).collect()
        };
        let dist = |x: &[f32], y: &[f32]| -> f32 {
            x.iter().zip(y).map(|(a, b)| (a - b) * (a - b)).sum()
        };
        let same = dist(&mean(0, &a), &mean(0, &b));
        let cross = dist(&mean(0, &a), &mean(1, &b));
        assert!(same < cross, "same-class {same} should beat cross-class {cross}");
    }

    #[test]
    fn balanced_labels() {
        let ds = generate(8, 5, 50, 1);
        for c in 0..5 {
            assert_eq!(ds.labels.iter().filter(|&&l| l == c).count(), 10);
        }
    }

    #[test]
    fn values_in_unit_range() {
        let ds = generate(16, 10, 20, 2);
        for img in &ds.images {
            assert!(img.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
    }

    #[test]
    fn supports_100_classes() {
        let ds = generate(8, 100, 200, 3);
        assert_eq!(ds.classes, 100);
        let uniq: std::collections::BTreeSet<i32> = ds.labels.iter().cloned().collect();
        assert_eq!(uniq.len(), 100);
    }
}
