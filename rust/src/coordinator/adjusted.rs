//! Adjusted-precision training search (§3.5, Fig. 4).
//!
//! For a target inference chip (resolution, noise), train candidate models
//! at training resolutions around the chip's ENOB and pick the best
//! chip-evaluated accuracy (with BN calibration, as the paper evaluates).

use crate::util::error::Result;

use crate::chip::{enob, ChipModel};
use crate::config::JobConfig;
use crate::nn::ExecSpec;
use crate::train::network_from_ckpt;
use crate::util::rng::Rng;

use super::sweep::SweepRunner;

/// One candidate's result.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub train_resolution: u32,
    pub chip_acc: f64,
}

/// Search result for one (inference resolution, noise) cell of Fig. 4.
#[derive(Debug, Clone)]
pub struct AdjustedResult {
    pub b_pim_infer: u32,
    pub noise_lsb: f32,
    pub enob_suggestion: u32,
    pub candidates: Vec<Candidate>,
}

impl AdjustedResult {
    pub fn best(&self) -> &Candidate {
        self.candidates
            .iter()
            .max_by(|a, b| a.chip_acc.partial_cmp(&b.chip_acc).unwrap())
            .expect("at least one candidate")
    }
}

/// Candidate training resolutions for a chip: the ENOB suggestion, the
/// inference resolution itself, and one below the suggestion (deduped,
/// clamped to [3, b_pim]).
pub fn candidate_resolutions(b_pim_infer: u32, noise_lsb: f32) -> Vec<u32> {
    let sug = enob::suggested_training_resolution(b_pim_infer, noise_lsb);
    let mut cands = vec![b_pim_infer, sug];
    if sug < b_pim_infer {
        // noise already reduced the ENOB — also probe one step lower
        cands.push(sug.saturating_sub(1));
    }
    cands.retain(|&c| (3..=b_pim_infer).contains(&c));
    cands.sort_unstable();
    cands.dedup();
    cands
}

/// Run the search for one Fig. 4 cell.
pub fn search(
    runner: &mut SweepRunner,
    base: &JobConfig,
    b_pim_infer: u32,
    noise_lsb: f32,
    calib_batches: usize,
) -> Result<AdjustedResult> {
    let sug = enob::suggested_training_resolution(b_pim_infer, noise_lsb);
    let mut candidates = Vec::new();
    for tr in candidate_resolutions(b_pim_infer, noise_lsb) {
        let mut job = base.clone();
        job.b_pim_train = tr;
        let outcome = runner.run(&job)?;
        // evaluate on the target chip with BN calibration (§3.4)
        let chip = ChipModel::ideal(b_pim_infer).with_noise(noise_lsb);
        let exec = ExecSpec::Pim {
            scheme: job.scheme,
            unit_channels: job.unit_channels,
            chip: &chip,
        };
        let mut net = network_from_ckpt(runner.manifest(), &outcome.ckpt)?;
        // persistent eval engines: candidates share geometry, so each
        // checkpoint reprograms the cached planes instead of re-preparing
        net.set_engine_cache(std::mem::take(&mut runner.eval_engines));
        let mut rng = Rng::new(0xADAB ^ tr as u64);
        let acc = (|| {
            // borrow the cached datasets — no per-candidate deep clones
            let (train_ds, test_ds) = runner.datasets(&job)?;
            net.calibrate_bn(train_ds, 32, calib_batches, &exec, &mut rng)?;
            net.evaluate(test_ds, 32, &exec, &mut rng)
        })();
        runner.eval_engines = net.take_engine_cache();
        candidates.push(Candidate { train_resolution: tr, chip_acc: acc? });
    }
    Ok(AdjustedResult { b_pim_infer, noise_lsb, enob_suggestion: sug, candidates })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn candidates_sane() {
        let c = candidate_resolutions(7, 0.0);
        assert_eq!(c, vec![7]); // no noise → train at inference resolution
        let c = candidate_resolutions(7, 2.0);
        assert!(c.contains(&7));
        assert!(c.iter().all(|&t| (3..=7).contains(&t)));
        assert!(c.len() >= 2, "heavy noise must propose a lower resolution");
    }

    #[test]
    fn candidates_low_resolution() {
        let c = candidate_resolutions(3, 5.0);
        assert_eq!(c, vec![3]);
    }
}
