//! Coordinator (S8): sweep scheduling and the adjusted-precision-training
//! search (§3.5).
//!
//! Jobs run *sequentially* through a deterministic work queue on any
//! [`crate::train::Backend`] — the native trainer parallelizes inside a
//! step (im2col / plane GEMMs / col2im across worker threads), so running
//! jobs concurrently would only fight it for cores, and the PJRT client is
//! not Sync-shareable through our wrapper anyway.  The queue has
//! dependency-free ordering, progress reporting, and a result cache keyed
//! by job fingerprint (a sweep re-run only trains what changed).

pub mod adjusted;
pub mod sweep;

pub use sweep::{JobOutcome, SweepRunner};
