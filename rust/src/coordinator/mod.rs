//! Coordinator (S8): sweep scheduling and the adjusted-precision-training
//! search (§3.5).
//!
//! The PJRT CPU client is not Sync-shareable across threads through our
//! wrapper, and this testbed is single-core anyway, so the scheduler runs
//! jobs *sequentially* through a deterministic work queue with dependency-
//! free ordering, progress reporting, and a result cache keyed by job
//! fingerprint (a sweep re-run only trains what changed).  The queueing /
//! caching machinery is exercised by unit tests with mock runners; real
//! sweeps go through `run_sweep`.

pub mod adjusted;
pub mod sweep;

pub use sweep::{JobOutcome, SweepRunner};
