//! Sweep runner: deterministic job queue + checkpoint cache.

use std::collections::HashMap;
use std::path::PathBuf;
use std::time::Instant;

use crate::util::error::Result;

use crate::config::JobConfig;
use crate::data::Dataset;
use crate::runtime::Manifest;
use crate::train::{Backend, Checkpoint, StepLog};

/// Result of one job (trained or loaded from cache).
pub struct JobOutcome {
    pub job: JobConfig,
    pub ckpt: Checkpoint,
    pub software_acc: f64,
    pub history: Vec<StepLog>,
    pub cached: bool,
    pub wall_s: f64,
}

/// Cache key: every field that changes the trained weights.
pub fn fingerprint(job: &JobConfig) -> String {
    let eta = job
        .eta_override
        .map(|e| format!("_eta{e}"))
        .unwrap_or_default();
    // variability-aware training changes the weights, so the fault spec is
    // part of the identity of the trained model (sanitized: specs can be
    // file paths)
    let flt = if job.faults.is_empty() {
        String::new()
    } else {
        let tag: String = job
            .faults
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() { c } else { '-' })
            .collect();
        format!("_flt{tag}")
    };
    format!(
        "{}_b{}_st{}_lr{}_seed{}_n{}{eta}{flt}",
        job.artifact_name(),
        job.b_pim_train,
        job.steps,
        job.lr,
        job.seed,
        job.train_size,
    )
}

/// Runs jobs sequentially with dataset + checkpoint caching, on any
/// training [`Backend`] (native by default, PJRT behind the feature).
pub struct SweepRunner<'a> {
    pub backend: &'a dyn Backend,
    pub ckpt_root: PathBuf,
    pub verbose: bool,
    /// Persistent per-layer PIM engines for the evaluation side: chip
    /// sweeps hand this cache to each checkpoint's `Network`
    /// (`experiments::common::chip_eval`, `coordinator::adjusted`), so a
    /// grid of chip configurations reprograms cached engines in place
    /// instead of re-deriving every layer's weight planes per point.
    pub eval_engines: crate::pim::EngineCache,
    datasets: HashMap<(usize, usize, usize, usize, u64), (Dataset, Dataset)>,
}

impl<'a> SweepRunner<'a> {
    pub fn new(backend: &'a dyn Backend) -> Self {
        let root = std::env::var_os("PIM_QAT_CKPTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("results/ckpts"));
        SweepRunner {
            backend,
            ckpt_root: root,
            verbose: true,
            eval_engines: crate::pim::EngineCache::new(),
            datasets: HashMap::new(),
        }
    }

    /// The backend's model registry.
    pub fn manifest(&self) -> &Manifest {
        self.backend.manifest()
    }

    /// Datasets are derived from the model geometry; cached per geometry.
    pub fn datasets(&mut self, job: &JobConfig) -> Result<&(Dataset, Dataset)> {
        let e = self.backend.manifest().model(&job.model)?;
        let key = (e.image, e.classes, job.train_size, job.test_size, job.seed);
        if !self.datasets.contains_key(&key) {
            let pair = crate::data::load_default(
                e.image,
                e.classes,
                job.train_size,
                job.test_size,
                0xDA7A ^ job.seed,
            );
            self.datasets.insert(key, pair);
        }
        Ok(self.datasets.get(&key).unwrap())
    }

    /// Train (or load from cache) one job.
    pub fn run(&mut self, job: &JobConfig) -> Result<JobOutcome> {
        let fp = fingerprint(job);
        let dir = self.ckpt_root.join(&fp);
        let t0 = Instant::now();
        if dir.join("ckpt.json").exists() {
            if let Ok(ckpt) = Checkpoint::load(&dir) {
                // the fingerprint does not encode the backend; never hand a
                // checkpoint trained by one backend out as the other's result
                let same_backend = ckpt
                    .meta
                    .get("backend")
                    .map(|b| b == self.backend.name())
                    .unwrap_or(false);
                if same_backend {
                    let software_acc = ckpt
                        .meta
                        .get("software_acc")
                        .and_then(|s| s.parse::<f64>().ok())
                        .unwrap_or(f64::NAN);
                    if self.verbose {
                        println!("[sweep] {fp}: cached (software {software_acc:.1}%)");
                    }
                    return Ok(JobOutcome {
                        job: job.clone(),
                        ckpt,
                        software_acc,
                        history: Vec::new(),
                        cached: true,
                        wall_s: 0.0,
                    });
                } else if self.verbose {
                    println!(
                        "[sweep] {fp}: cached checkpoint is from backend {:?}, retraining on {}",
                        ckpt.meta.get("backend").map(String::as_str).unwrap_or("unknown"),
                        self.backend.name()
                    );
                }
            }
        }
        let (train_ds, test_ds) = {
            let pair = self.datasets(job)?;
            (pair.0.clone(), pair.1.clone())
        };
        if self.verbose {
            println!("[sweep] {fp}: training {} steps ...", job.steps);
        }
        let mut res = self.backend.train_job(job, &train_ds, &test_ds, 10)?;
        res.ckpt
            .meta
            .insert("software_acc".into(), format!("{:.4}", res.software_acc));
        res.ckpt.save(&dir)?;
        let wall = t0.elapsed().as_secs_f64();
        if self.verbose {
            let last = res.history.last().map(|l| l.loss).unwrap_or(f32::NAN);
            println!(
                "[sweep] {fp}: done in {wall:.1}s, final loss {last:.3}, software {:.1}%",
                res.software_acc
            );
        }
        Ok(JobOutcome {
            job: job.clone(),
            ckpt: res.ckpt,
            software_acc: res.software_acc,
            history: res.history,
            cached: false,
            wall_s: wall,
        })
    }

    /// Run a whole grid; failures are reported inline, not fatal.
    pub fn run_all(&mut self, jobs: &[JobConfig]) -> Vec<Result<JobOutcome>> {
        jobs.iter()
            .enumerate()
            .map(|(i, j)| {
                if self.verbose {
                    println!("[sweep] job {}/{}", i + 1, jobs.len());
                }
                self.run(j)
            })
            .collect()
    }
}

/// Parse a sweep grid spec like
/// `"b_pim=3,4,5;scheme=native,bit_serial;mode=ours,baseline"` into the
/// cartesian product of job configs over a base config.
pub fn parse_grid(base: &JobConfig, spec: &str) -> Result<Vec<JobConfig>, String> {
    let mut axes: Vec<(String, Vec<String>)> = Vec::new();
    for part in spec.split(';').filter(|s| !s.trim().is_empty()) {
        let (key, vals) = part
            .split_once('=')
            .ok_or_else(|| format!("bad grid axis {part:?}"))?;
        let vals: Vec<String> = if vals.contains("..") {
            let (a, b) = vals.split_once("..").unwrap();
            let a: i64 = a.trim().parse().map_err(|e| format!("{e}"))?;
            let b: i64 = b.trim().parse().map_err(|e| format!("{e}"))?;
            (a..=b).map(|v| v.to_string()).collect()
        } else {
            vals.split(',').map(|v| v.trim().to_string()).collect()
        };
        axes.push((key.trim().to_string(), vals));
    }
    let mut jobs = vec![base.clone()];
    for (key, vals) in axes {
        let mut next = Vec::with_capacity(jobs.len() * vals.len());
        for j in &jobs {
            for v in &vals {
                let mut nj = j.clone();
                nj.set(&key, v)?;
                next.push(nj);
            }
        }
        jobs = next;
    }
    Ok(jobs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fingerprint_distinguishes_jobs() {
        let a = JobConfig::default();
        let mut b = a.clone();
        b.b_pim_train = 5;
        assert_ne!(fingerprint(&a), fingerprint(&b));
        let mut c = a.clone();
        c.seed = 1;
        assert_ne!(fingerprint(&a), fingerprint(&c));
        let mut d = a.clone();
        d.faults = "moderate:7".into();
        assert_ne!(fingerprint(&a), fingerprint(&d));
        assert!(!fingerprint(&d).contains(':'), "{}", fingerprint(&d));
    }

    #[test]
    fn grid_cartesian_product() {
        let base = JobConfig::default();
        let jobs = parse_grid(&base, "b_pim=3,5,7;mode=ours,baseline").unwrap();
        assert_eq!(jobs.len(), 6);
        assert_eq!(jobs[0].b_pim_train, 3);
        assert_eq!(jobs[5].b_pim_train, 7);
        assert_eq!(jobs[5].mode, crate::config::Mode::Baseline);
    }

    #[test]
    fn grid_range_syntax() {
        let jobs = parse_grid(&JobConfig::default(), "b_pim=3..7").unwrap();
        assert_eq!(jobs.len(), 5);
    }

    #[test]
    fn grid_rejects_bad_axis() {
        assert!(parse_grid(&JobConfig::default(), "nope=1").is_err());
        assert!(parse_grid(&JobConfig::default(), "b_pim").is_err());
    }
}
