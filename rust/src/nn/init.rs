//! Parameter/state layout and initialization for the native training
//! backend — the rust twin of `python/compile/model.py::model_init`
//! (Kaiming conv init, unit BN affine, zero bias).
//!
//! The PJRT path gets its initialization from the lowered `init` artifact;
//! the native backend initializes here, with the crate RNG.  The (name,
//! shape) listing doubles as the parameter contract for the built-in
//! manifest entries ([`crate::runtime::Manifest::builtin`]).

use std::collections::BTreeMap;

use crate::runtime::ModelEntry;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// (path, shape) listings for a model family: `(params, state)`, sorted by
/// path — the same depth-first sorted order as python `flatten_tree`.
pub fn param_specs(e: &ModelEntry) -> (Vec<(String, Vec<usize>)>, Vec<(String, Vec<usize>)>) {
    let mut params: Vec<(String, Vec<usize>)> = Vec::new();
    let mut state: Vec<(String, Vec<usize>)> = Vec::new();
    type Specs = Vec<(String, Vec<usize>)>;
    let bn = |name: &str, c: usize, params: &mut Specs, state: &mut Specs| {
        params.push((format!("{name}/gamma"), vec![c]));
        params.push((format!("{name}/beta"), vec![c]));
        state.push((format!("{name}/mean"), vec![c]));
        state.push((format!("{name}/var"), vec![c]));
    };
    match e.arch.as_str() {
        "resnet" => {
            params.push(("conv0/w".into(), vec![3, 3, e.in_channels, e.width]));
            bn("bn0", e.width, &mut params, &mut state);
            let mut cin = e.width;
            for s in 0..3 {
                let cout = e.width * (1 << s);
                for b in 0..e.depth_n {
                    let blk = format!("s{s}b{b}");
                    params.push((format!("{blk}/conv1/w"), vec![3, 3, cin, cout]));
                    bn(&format!("{blk}/bn1"), cout, &mut params, &mut state);
                    params.push((format!("{blk}/conv2/w"), vec![3, 3, cout, cout]));
                    bn(&format!("{blk}/bn2"), cout, &mut params, &mut state);
                    if cin != cout {
                        params.push((format!("{blk}/convs/w"), vec![1, 1, cin, cout]));
                        bn(&format!("{blk}/bns"), cout, &mut params, &mut state);
                    }
                    cin = cout;
                }
            }
            params.push(("fc/w".into(), vec![cin, e.classes]));
            params.push(("fc/b".into(), vec![e.classes]));
        }
        "vgg11" => {
            let plan = super::vgg11_plan(e.width, e.image);
            let mut cin = e.in_channels;
            for (i, &(cout, _)) in plan.iter().enumerate() {
                params.push((format!("conv{i}/w"), vec![3, 3, cin, cout]));
                bn(&format!("bn{i}"), cout, &mut params, &mut state);
                cin = cout;
            }
            params.push(("fc/w".into(), vec![cin, e.classes]));
            params.push(("fc/b".into(), vec![e.classes]));
        }
        a => panic!("unknown arch {a:?}"),
    }
    params.sort_by(|a, b| a.0.cmp(&b.0));
    state.sort_by(|a, b| a.0.cmp(&b.0));
    (params, state)
}

/// Initialize parameters and BN state for a model family (Kaiming conv
/// weights, γ=1/β=0, zero FC bias, mean=0/var=1 running stats).
/// Deterministic per seed; different seeds give different weights.
pub fn init_params(
    e: &ModelEntry,
    seed: u64,
) -> (BTreeMap<String, Tensor>, BTreeMap<String, Tensor>) {
    let (pspecs, sspecs) = param_specs(e);
    let mut rng = Rng::new(seed.wrapping_mul(0x9E3779B97F4A7C15) ^ 0x1217);
    let mut params = BTreeMap::new();
    for (name, shape) in pspecs {
        let n: usize = shape.iter().product();
        let t = if name.ends_with("/w") {
            // Kaiming: fan_in = k·k·c_in for convs, c_in for the FC matrix.
            let fan_in: usize = shape[..shape.len() - 1].iter().product();
            let std = (2.0 / fan_in as f32).sqrt();
            Tensor::from_vec(&shape, (0..n).map(|_| rng.normal_in(0.0, std)).collect())
        } else if name.ends_with("/gamma") {
            Tensor::full(&shape, 1.0)
        } else {
            Tensor::zeros(&shape)
        };
        params.insert(name, t);
    }
    let mut state = BTreeMap::new();
    for (name, shape) in sspecs {
        let t = if name.ends_with("/var") {
            Tensor::full(&shape, 1.0)
        } else {
            Tensor::zeros(&shape)
        };
        state.insert(name, t);
    }
    (params, state)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(arch: &str) -> ModelEntry {
        ModelEntry {
            arch: arch.into(),
            depth_n: 1,
            width: 8,
            image: 16,
            classes: 10,
            in_channels: 3,
            param_paths: vec![],
            param_shapes: vec![],
            state_paths: vec![],
            state_shapes: vec![],
        }
    }

    #[test]
    fn resnet_specs_cover_forward_names() {
        let (p, s) = param_specs(&entry("resnet"));
        let names: Vec<&str> = p.iter().map(|(n, _)| n.as_str()).collect();
        for want in [
            "conv0/w", "bn0/gamma", "bn0/beta", "fc/w", "fc/b", "s0b0/conv1/w", "s0b0/conv2/w",
            "s1b0/convs/w", "s2b0/bns/gamma",
        ] {
            assert!(names.contains(&want), "{want} missing");
        }
        // s0b0 keeps cin == cout: no shortcut conv
        assert!(!names.contains(&"s0b0/convs/w"));
        assert!(s.iter().any(|(n, _)| n == "s1b0/bns/mean"));
        // sorted order (the flatten_tree contract)
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted);
    }

    #[test]
    fn init_is_seeded_and_shaped() {
        let e = entry("resnet");
        let (p1, s1) = init_params(&e, 7);
        let (p2, _) = init_params(&e, 7);
        let (p3, _) = init_params(&e, 8);
        assert_eq!(p1["conv0/w"].data, p2["conv0/w"].data);
        assert_ne!(p1["conv0/w"].data, p3["conv0/w"].data);
        assert_eq!(p1["conv0/w"].shape, vec![3, 3, 3, 8]);
        assert!(p1["bn0/gamma"].data.iter().all(|&v| v == 1.0));
        assert!(s1["bn0/var"].data.iter().all(|&v| v == 1.0));
        assert!(s1["bn0/mean"].data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn vgg_specs_shaped() {
        let (p, _) = param_specs(&entry("vgg11"));
        assert!(p.iter().any(|(n, s)| n == "conv0/w" && s == &vec![3, 3, 3, 8]));
        assert!(p.iter().any(|(n, s)| n == "conv7/w" && s == &vec![3, 3, 64, 64]));
        assert!(p.iter().any(|(n, s)| n == "fc/w" && s == &vec![64, 10]));
    }
}
