//! Quantized NN inference engine (S5): runs trained checkpoints on the
//! digital path ("Software" rows) or on the PIM chip simulator (ideal or
//! real-curve), and implements BN calibration (§3.4).
//!
//! The forward pass is a structural mirror of `python/compile/model.py`
//! (layer placement per §A2.1: first conv / shortcuts / FC digital, all
//! other convs PIM-mapped).  The `model_tiny.json` golden pins the two
//! implementations against each other end-to-end.

pub mod model;
pub mod quant;

pub use model::{ExecSpec, Network};
