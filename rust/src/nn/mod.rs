//! Quantized NN engine (S5): inference, BN calibration, and the
//! differentiable layer primitives of the native trainer.
//!
//! * [`model`] — runs trained checkpoints on the digital path ("Software"
//!   rows) or on the PIM chip simulator (ideal or real-curve), and
//!   implements BN calibration (§3.4).  The forward pass is a structural
//!   mirror of `python/compile/model.py` (layer placement per §A2.1: first
//!   conv / shortcuts / FC digital, all other convs PIM-mapped); the
//!   `model_tiny.json` golden pins the two implementations against each
//!   other end-to-end.
//! * [`quant`] — the modified-DoReFa digital quantizers (Eqn. A20).
//! * [`grad`] — hand-rolled backward passes (conv/BN/FC/pooling/loss) with
//!   straight-through-estimator gradients for every quantizer; used by
//!   [`crate::train::NativeBackend`].
//! * [`init`] — Kaiming parameter initialization (the native twin of the
//!   lowered `init` artifact).

pub mod grad;
pub mod init;
pub mod model;
pub mod quant;

pub use model::{vgg11_plan, ExecSpec, Network};
