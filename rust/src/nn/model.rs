//! The network: parameter container + forward pass + BN calibration.

use std::collections::{BTreeMap, HashMap};

use crate::util::error::{anyhow, Result};

use crate::chip::ChipModel;
use crate::config::Scheme;
use crate::pim::{EngineCache, QuantBits};
use crate::runtime::ModelEntry;
use crate::tensor::ops;
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use crate::util::Welford;

use super::quant;

/// How to execute the PIM-mapped convolutions.
#[derive(Clone)]
pub enum ExecSpec<'a> {
    /// Digital everywhere — the paper's "Software" rows (b_PIM = +∞).
    Software,
    /// PIM-mapped convs on the chip simulator.
    Pim { scheme: Scheme, unit_channels: usize, chip: &'a ChipModel },
}

/// One conv's prepared weights.
struct ConvW {
    /// [C*k*k, O] digitally quantized & scaled (software path).
    cols_scaled: Tensor,
    /// [C*k*k, O] integer weights on the signed grid (PIM path).
    cols_int: Tensor,
    /// Eqn. A20b digital scale s.
    scale: f32,
    c_in: usize,
    kernel: usize,
}

/// A loaded, executable network.
pub struct Network {
    pub entry: ModelEntry,
    pub bits: QuantBits,
    params: BTreeMap<String, Tensor>,
    /// BN running stats, mutated by `calibrate_bn`.
    bn_state: BTreeMap<String, (Vec<f32>, Vec<f32>)>,
    convs: HashMap<String, ConvW>,
    /// Per-layer PIM engine cache (same keying as the trainer's
    /// `TrainArena`): engines persist across forwards and — via
    /// [`Network::set_engine_cache`] / [`Network::take_engine_cache`] —
    /// across the Networks a sweep builds, so evaluation stops re-deriving
    /// weight planes per checkpoint/chip point.
    engines: std::cell::RefCell<EngineCache>,
}

impl Network {
    /// Build from flat parameter/state maps (checkpoint or golden).
    pub fn new(
        entry: ModelEntry,
        bits: QuantBits,
        params: BTreeMap<String, Tensor>,
        state: BTreeMap<String, Tensor>,
    ) -> Result<Self> {
        // fold state tensors into (mean, var) pairs per bn path
        let mut bn_state = BTreeMap::new();
        for (k, v) in &state {
            if let Some(base) = k.strip_suffix("/mean") {
                let var = state
                    .get(&format!("{base}/var"))
                    .ok_or_else(|| anyhow!("state {base}/var missing"))?;
                bn_state.insert(base.to_string(), (v.data.clone(), var.data.clone()));
            }
        }
        let mut net = Network {
            entry,
            bits,
            params,
            bn_state,
            convs: HashMap::new(),
            engines: Default::default(),
        };
        net.prepare_convs()?;
        Ok(net)
    }

    fn prepare_convs(&mut self) -> Result<()> {
        let names: Vec<String> = self
            .params
            .keys()
            .filter(|k| k.ends_with("/w") && k.contains("conv"))
            .cloned()
            .collect();
        for name in names {
            let w = &self.params[&name];
            if w.rank() != 4 {
                continue;
            }
            let (kh, _kw, c, o) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
            let q_unit = quant::weight_quant_unit(w, &self.bits);
            let scale = quant::weight_scale(&q_unit, o);
            let cols_scaled = ops::weights_to_cols(&q_unit).map(|v| v * scale);
            let q_int = quant::weight_quant_int(w, &self.bits);
            let cols_int = ops::weights_to_cols(&q_int);
            self.convs.insert(
                name,
                ConvW { cols_scaled, cols_int, scale, c_in: c, kernel: kh },
            );
        }
        Ok(())
    }

    pub fn param(&self, name: &str) -> Result<&Tensor> {
        self.params
            .get(name)
            .ok_or_else(|| anyhow!("param {name:?} missing"))
    }

    /// Replace BN running stats (used by BN calibration and tests).
    pub fn set_bn_state(&mut self, name: &str, mean: Vec<f32>, var: Vec<f32>) {
        self.bn_state.insert(name.to_string(), (mean, var));
    }

    /// Hand this network a persistent engine cache (e.g. the sweep
    /// runner's): PIM convs whose geometry matches a cached engine
    /// reprogram it in place instead of re-deriving their weight planes.
    pub fn set_engine_cache(&mut self, cache: EngineCache) {
        self.engines = std::cell::RefCell::new(cache);
    }

    /// Take the engine cache back out (leaving an empty one) to pass it to
    /// the next checkpoint's network.
    pub fn take_engine_cache(&mut self) -> EngineCache {
        self.engines.take()
    }

    pub fn bn_names(&self) -> Vec<String> {
        self.bn_state.keys().cloned().collect()
    }

    /// Read a BN layer's running (mean, var) — experiments/tests/debugging.
    pub fn bn_stats(&self, name: &str) -> Option<&(Vec<f32>, Vec<f32>)> {
        self.bn_state.get(name)
    }

    // -- layer helpers ------------------------------------------------------

    /// `sparse_input`: the input carries many exact zeros (post-ReLU
    /// quantized activations — shortcut convs), so the zero-skip GEMM wins;
    /// dense inputs (the raw-image first layer) use the blocked kernel.
    fn conv_digital(
        &self,
        x: &Tensor,
        name: &str,
        stride: usize,
        sparse_input: bool,
    ) -> Result<Tensor> {
        let cw = self.convs.get(name).ok_or_else(|| anyhow!("conv {name} missing"))?;
        let (patches, oh, ow) = ops::im2col_threaded(x, cw.kernel, stride, 0);
        let m = patches.shape[0];
        let k = patches.shape[1];
        let o = cw.cols_scaled.shape[1];
        let y = if sparse_input {
            crate::tensor::gemm::gemm_sparse(m, k, o, &patches.data, &cw.cols_scaled.data)
        } else {
            crate::tensor::gemm::gemm(m, k, o, &patches.data, &cw.cols_scaled.data)
        };
        Ok(Tensor::from_vec(&[x.shape[0], oh, ow, o], y))
    }

    fn conv_exec(
        &self,
        x: &Tensor,
        name: &str,
        stride: usize,
        exec: &ExecSpec,
        rng: &mut Rng,
    ) -> Result<Tensor> {
        match exec {
            ExecSpec::Software => self.conv_digital(x, name, stride, true),
            ExecSpec::Pim { scheme, unit_channels, chip } => {
                let cw = self.convs.get(name).ok_or_else(|| anyhow!("conv {name} missing"))?;
                let (patches, oh, ow) = ops::im2col_threaded(x, cw.kernel, stride, 0);
                // patches hold quantized activations in [0,1] — scale to ints
                let al = self.bits.a_levels() as f32;
                let pint = patches.map(|v| crate::chip::round_ties_even(v * al));
                // cache hit → in-place reprogram (all groups skip when the
                // weights are this engine's); miss / geometry change →
                // fresh prepare.  The borrow is held across the matmul —
                // nothing below re-enters the cache.
                let mut cache = self.engines.borrow_mut();
                let engine = cache.ensure_engine(
                    name,
                    *scheme,
                    self.bits,
                    &cw.cols_int.data,
                    cw.cols_int.shape[1],
                    cw.c_in,
                    cw.kernel,
                    *unit_channels,
                );
                let y = engine.matmul(&pint, chip, rng);
                drop(cache);
                let o = y.shape[1];
                Ok(y
                    .map(|v| v * cw.scale)
                    .reshape(&[x.shape[0], oh, ow, o]))
            }
        }
    }

    fn bn(&self, x: Tensor, name: &str, collect: &mut Option<&mut BTreeMap<String, Welford3>>) -> Result<Tensor> {
        let gamma = &self.param(&format!("{name}/gamma"))?.data;
        let beta = &self.param(&format!("{name}/beta"))?.data;
        if let Some(c) = collect.as_deref_mut() {
            // Calibration pass (§3.4): run in *training-mode* BN — normalize
            // with THIS batch's statistics while accumulating them.  Each
            // layer's stats are then collected under already-consistent
            // upstream normalization (replacing all running stats from a
            // single eval-mode pass compounds stale-downstream error and
            // wrecks accuracy).
            c.entry(name.to_string()).or_default().push(&x);
            let (mean, var) = ops::channel_stats(&x);
            return Ok(ops::batch_norm(&x, gamma, beta, &mean, &var));
        }
        let (mean, var) = self
            .bn_state
            .get(name)
            .ok_or_else(|| anyhow!("bn state {name:?} missing"))?;
        Ok(ops::batch_norm(&x, gamma, beta, mean, var))
    }

    fn act(&self, x: Tensor) -> Tensor {
        quant::act_quant(ops::relu(x), &self.bits)
    }

    // -- forward ------------------------------------------------------------

    /// Full forward pass → logits [B, classes].
    pub fn forward(&self, x: &Tensor, exec: &ExecSpec, rng: &mut Rng) -> Result<Tensor> {
        self.forward_impl(x, exec, rng, &mut None)
    }

    fn forward_impl(
        &self,
        x: &Tensor,
        exec: &ExecSpec,
        rng: &mut Rng,
        collect: &mut Option<&mut BTreeMap<String, Welford3>>,
    ) -> Result<Tensor> {
        match self.entry.arch.as_str() {
            "resnet" => self.forward_resnet(x, exec, rng, collect),
            "vgg11" => self.forward_vgg(x, exec, rng, collect),
            a => Err(anyhow!("unknown arch {a:?}")),
        }
    }

    fn forward_resnet(
        &self,
        x: &Tensor,
        exec: &ExecSpec,
        rng: &mut Rng,
        collect: &mut Option<&mut BTreeMap<String, Welford3>>,
    ) -> Result<Tensor> {
        let e = &self.entry;
        let mut h = quant::act_quant_bits(x.clone(), 8);
        h = self.conv_digital(&h, "conv0/w", 1, false)?; // first layer: digital (§A2.1)
        h = self.bn(h, "bn0", collect)?;
        h = self.act(h);
        let mut cin = e.width;
        for s in 0..3 {
            let cout = e.width * (1 << s);
            for b in 0..e.depth_n {
                let blk = format!("s{s}b{b}");
                let stride = if s > 0 && b == 0 { 2 } else { 1 };
                let mut z = self.conv_exec(&h, &format!("{blk}/conv1/w"), stride, exec, rng)?;
                z = self.bn(z, &format!("{blk}/bn1"), collect)?;
                z = self.act(z);
                z = self.conv_exec(&z, &format!("{blk}/conv2/w"), 1, exec, rng)?;
                z = self.bn(z, &format!("{blk}/bn2"), collect)?;
                let sc = if cin != cout || stride != 1 {
                    let s_ = self.conv_digital(&h, &format!("{blk}/convs/w"), stride, true)?;
                    self.bn(s_, &format!("{blk}/bns"), collect)?
                } else {
                    h.clone()
                };
                h = self.act(z.zip(&sc, |a, b| a + b));
                cin = cout;
            }
        }
        let pooled = ops::global_avg_pool(&h);
        self.fc(&pooled)
    }

    fn forward_vgg(
        &self,
        x: &Tensor,
        exec: &ExecSpec,
        rng: &mut Rng,
        collect: &mut Option<&mut BTreeMap<String, Welford3>>,
    ) -> Result<Tensor> {
        let e = &self.entry;
        let plan = vgg11_plan(e.width, e.image);
        let mut h = quant::act_quant_bits(x.clone(), 8);
        for (i, &(_cout, pool)) in plan.iter().enumerate() {
            let name = format!("conv{i}/w");
            h = if i == 0 {
                self.conv_digital(&h, &name, 1, false)?
            } else {
                self.conv_exec(&h, &name, 1, exec, rng)?
            };
            h = self.bn(h, &format!("bn{i}"), collect)?;
            h = self.act(h);
            if pool {
                h = ops::maxpool2(&h);
            }
        }
        let pooled = ops::global_avg_pool(&h);
        self.fc(&pooled)
    }

    fn fc(&self, x: &Tensor) -> Result<Tensor> {
        let w = self.param("fc/w")?;
        let b = self.param("fc/b")?;
        let q_unit = quant::weight_quant_unit(w, &self.bits);
        let s = quant::weight_scale(&q_unit, self.entry.classes);
        let (m, k) = (x.shape[0], x.shape[1]);
        let o = w.shape[1];
        let wq: Vec<f32> = q_unit.data.iter().map(|v| v * s).collect();
        let mut y = crate::tensor::gemm::gemm(m, k, o, &x.data, &wq);
        for i in 0..m {
            for j in 0..o {
                y[i * o + j] += b.data[j];
            }
        }
        Ok(Tensor::from_vec(&[m, o], y))
    }

    // -- evaluation & calibration -------------------------------------------

    /// Top-1 accuracy over a dataset (full batches of `bs`).
    pub fn evaluate(
        &self,
        ds: &crate::data::Dataset,
        bs: usize,
        exec: &ExecSpec,
        rng: &mut Rng,
    ) -> Result<f64> {
        let mut correct = 0usize;
        let mut total = 0usize;
        let n = ds.len() / bs * bs;
        let mut drng = Rng::new(0);
        for start in (0..n).step_by(bs) {
            let idx: Vec<usize> = (start..start + bs).collect();
            let batch = ds.batch(&idx, false, &mut drng);
            let logits = self.forward(&batch.x, exec, rng)?;
            for (p, &t) in ops::argmax_rows(&logits).iter().zip(&batch.y) {
                correct += (*p == t as usize) as usize;
                total += 1;
            }
        }
        Ok(100.0 * correct as f64 / total.max(1) as f64)
    }

    /// BN calibration (§3.4): re-estimate every BN layer's running stats
    /// from `batches` training batches executed with the *target* exec spec
    /// (the same non-idealities used at inference), then overwrite the
    /// running statistics.
    pub fn calibrate_bn(
        &mut self,
        ds: &crate::data::Dataset,
        bs: usize,
        batches: usize,
        exec: &ExecSpec,
        rng: &mut Rng,
    ) -> Result<()> {
        let mut stats: BTreeMap<String, Welford3> = BTreeMap::new();
        let mut drng = rng.fork(0xCA11B);
        for bi in 0..batches {
            let idx: Vec<usize> =
                (0..bs).map(|_| drng.below(ds.len())).collect();
            let batch = ds.batch(&idx, false, &mut drng);
            let mut collect = Some(&mut stats);
            let _ = self.forward_impl(&batch.x, exec, rng, &mut collect)?;
            let _ = bi;
        }
        for (name, w) in stats {
            let (mean, var) = w.finish();
            self.bn_state.insert(name, (mean, var));
        }
        Ok(())
    }
}

/// Per-channel Welford accumulator for BN calibration.
#[derive(Default)]
pub struct Welford3 {
    per_channel: Vec<Welford>,
}

impl Welford3 {
    fn push(&mut self, x: &Tensor) {
        let c = *x.shape.last().unwrap();
        if self.per_channel.is_empty() {
            self.per_channel = vec![Welford::default(); c];
        }
        for (i, &v) in x.data.iter().enumerate() {
            self.per_channel[i % c].push(v as f64);
        }
    }

    fn finish(&self) -> (Vec<f32>, Vec<f32>) {
        (
            self.per_channel.iter().map(|w| w.mean as f32).collect(),
            self.per_channel.iter().map(|w| w.var() as f32).collect(),
        )
    }
}

/// VGG11 plan mirror of python `vgg11_plan`: (out_channels, pool_after).
pub fn vgg11_plan(width: usize, image: usize) -> Vec<(usize, bool)> {
    let mults = [1, 2, 4, 4, 8, 8, 8, 8];
    let max_pools = ((image as f64).log2() as isize - 1).max(2) as usize;
    let pool_after = [0usize, 1, 3, 5, 7];
    let mut pools = 0;
    mults
        .iter()
        .enumerate()
        .map(|(i, &m)| {
            let do_pool = pool_after.contains(&i) && pools < max_pools;
            pools += do_pool as usize;
            (width * m, do_pool)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_entry() -> ModelEntry {
        ModelEntry {
            arch: "resnet".into(),
            depth_n: 1,
            width: 8,
            image: 16,
            classes: 10,
            in_channels: 3,
            param_paths: vec![],
            param_shapes: vec![],
            state_paths: vec![],
            state_shapes: vec![],
        }
    }

    /// Random-parameter network of the tiny geometry.
    fn random_net(seed: u64) -> Network {
        let mut rng = Rng::new(seed);
        let mut params = BTreeMap::new();
        let mut state = BTreeMap::new();
        let mut conv = |name: &str, k: usize, ci: usize, co: usize, rng: &mut Rng| {
            let t = Tensor::from_vec(
                &[k, k, ci, co],
                (0..k * k * ci * co)
                    .map(|_| rng.normal_in(0.0, (2.0 / (k * k * ci) as f32).sqrt()))
                    .collect(),
            );
            (name.to_string(), t)
        };
        let mut bn = |name: &str, c: usize| {
            vec![
                (format!("{name}/gamma"), Tensor::full(&[c], 1.0)),
                (format!("{name}/beta"), Tensor::zeros(&[c])),
            ]
        };
        let mut bn_st = |name: &str, c: usize| {
            vec![
                (format!("{name}/mean"), Tensor::zeros(&[c])),
                (format!("{name}/var"), Tensor::full(&[c], 1.0)),
            ]
        };
        let (k, mut add) = (3usize, |v: Vec<(String, Tensor)>, m: &mut BTreeMap<String, Tensor>| {
            for (n, t) in v {
                m.insert(n, t);
            }
        });
        let w = 8usize;
        params.extend([conv("conv0/w", k, 3, w, &mut rng)]);
        add(bn("bn0", w), &mut params);
        add(bn_st("bn0", w), &mut state);
        let mut cin = w;
        for s in 0..3 {
            let cout = w * (1 << s);
            let blk = format!("s{s}b0");
            params.extend([conv(&format!("{blk}/conv1/w"), k, cin, cout, &mut rng)]);
            params.extend([conv(&format!("{blk}/conv2/w"), k, cout, cout, &mut rng)]);
            add(bn(&format!("{blk}/bn1"), cout), &mut params);
            add(bn(&format!("{blk}/bn2"), cout), &mut params);
            add(bn_st(&format!("{blk}/bn1"), cout), &mut state);
            add(bn_st(&format!("{blk}/bn2"), cout), &mut state);
            if cin != cout {
                params.extend([conv(&format!("{blk}/convs/w"), 1, cin, cout, &mut rng)]);
                add(bn(&format!("{blk}/bns"), cout), &mut params);
                add(bn_st(&format!("{blk}/bns"), cout), &mut state);
            }
            cin = cout;
        }
        params.insert(
            "fc/w".into(),
            Tensor::from_vec(&[cin, 10], (0..cin * 10).map(|_| rng.normal_in(0.0, 0.25)).collect()),
        );
        params.insert("fc/b".into(), Tensor::zeros(&[10]));
        Network::new(tiny_entry(), QuantBits::default(), params, state).unwrap()
    }

    #[test]
    fn forward_shapes() {
        let net = random_net(1);
        let mut rng = Rng::new(0);
        let x = Tensor::full(&[2, 16, 16, 3], 0.5);
        let y = net.forward(&x, &ExecSpec::Software, &mut rng).unwrap();
        assert_eq!(y.shape, vec![2, 10]);
        assert!(y.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn pim_high_resolution_close_to_software() {
        let net = random_net(2);
        let mut rng = Rng::new(0);
        let x = Tensor::from_vec(
            &[2, 16, 16, 3],
            (0..2 * 16 * 16 * 3).map(|i| ((i * 37) % 256) as f32 / 255.0).collect(),
        );
        let sw = net.forward(&x, &ExecSpec::Software, &mut rng).unwrap();
        let chip = ChipModel::ideal(16);
        let pim = net
            .forward(
                &x,
                &ExecSpec::Pim { scheme: Scheme::BitSerial, unit_channels: 8, chip: &chip },
                &mut rng,
            )
            .unwrap();
        // b_PIM=16 introduces tiny quantization; logits should agree closely
        assert!(sw.max_abs_diff(&pim) < 0.05, "diff {}", sw.max_abs_diff(&pim));
    }

    #[test]
    fn pim_low_resolution_differs() {
        let net = random_net(3);
        let mut rng = Rng::new(0);
        let x = Tensor::full(&[1, 16, 16, 3], 0.4);
        let sw = net.forward(&x, &ExecSpec::Software, &mut rng).unwrap();
        let chip = ChipModel::ideal(3);
        let pim = net
            .forward(
                &x,
                &ExecSpec::Pim { scheme: Scheme::BitSerial, unit_channels: 8, chip: &chip },
                &mut rng,
            )
            .unwrap();
        assert!(sw.max_abs_diff(&pim) > 1e-3);
    }

    #[test]
    fn engine_cache_transfers_between_networks() {
        let chip = ChipModel::ideal(7);
        let exec = ExecSpec::Pim { scheme: Scheme::BitSerial, unit_channels: 8, chip: &chip };
        let x = Tensor::from_vec(
            &[1, 16, 16, 3],
            (0..16 * 16 * 3).map(|i| ((i * 13) % 256) as f32 / 255.0).collect(),
        );
        // same weights: the handed-over cache takes the all-groups-skip path
        let mut net1 = random_net(5);
        let y1 = net1.forward(&x, &exec, &mut Rng::new(0)).unwrap();
        let cache = net1.take_engine_cache();
        assert!(!cache.is_empty(), "PIM forward must populate the engine cache");
        let n_engines = cache.len();
        let mut net2 = random_net(5);
        net2.set_engine_cache(cache);
        let y2 = net2.forward(&x, &exec, &mut Rng::new(0)).unwrap();
        assert_eq!(y1.data, y2.data, "shared cache must not change results");
        // different weights: reprogram rewrites in place; results must
        // match a network that prepared from scratch
        let mut net3 = random_net(6);
        let y3 = net3.forward(&x, &exec, &mut Rng::new(0)).unwrap();
        let mut net4 = random_net(6);
        net4.set_engine_cache(net2.take_engine_cache());
        let y4 = net4.forward(&x, &exec, &mut Rng::new(0)).unwrap();
        assert_eq!(y3.data, y4.data, "reprogrammed cache must match fresh prepare");
        assert_eq!(net4.take_engine_cache().len(), n_engines);
    }

    #[test]
    fn calibration_changes_bn_stats_and_is_idempotentish() {
        let mut net = random_net(4);
        let ds = crate::data::synth::generate(16, 10, 64, 9);
        let mut rng = Rng::new(1);
        let chip = ChipModel::real(3);
        let exec = ExecSpec::Pim { scheme: Scheme::BitSerial, unit_channels: 8, chip: &chip };
        let before = net.bn_state.get("bn0").unwrap().clone();
        net.calibrate_bn(&ds, 8, 4, &exec, &mut rng).unwrap();
        let after = net.bn_state.get("bn0").unwrap().clone();
        assert_ne!(before, after, "calibration must move the running stats");
        // stats should be finite and variances positive
        assert!(after.1.iter().all(|&v| v.is_finite() && v >= 0.0));
    }

    #[test]
    fn vgg_plan_pools_bounded() {
        let plan = vgg11_plan(8, 16);
        let pools = plan.iter().filter(|(_, p)| *p).count();
        assert_eq!(plan.len(), 8);
        assert!(pools <= 3, "16px image must keep >=2px map, got {pools} pools");
    }
}
