//! Digital quantizers — rust mirror of `python/compile/quant.py`
//! (modified DoReFa, Eqn. A20).  Pinned against the python implementation by
//! the `quant.json` golden (rust/tests/golden_cross.rs).

use crate::chip::round_ties_even;
use crate::pim::QuantBits;
use crate::tensor::Tensor;

/// Weight quantization onto the [-1,1] grid (what the PIM array stores):
/// round ties-to-even of (2^{b_w-1}-1)·tanh(w)/max|tanh(w)|.
pub fn weight_quant_unit(w: &Tensor, bits: &QuantBits) -> Tensor {
    let mut max_t = 0.0f32;
    for &v in &w.data {
        max_t = max_t.max(v.tanh().abs());
    }
    let denom = max_t + 1e-12;
    let lv = bits.w_levels() as f32;
    let mut out = w.clone();
    for v in &mut out.data {
        *v = round_ties_even(v.tanh() / denom * lv) / lv;
    }
    out
}

/// Integer weights on the signed grid (weight_quant_unit × w_levels).
pub fn weight_quant_int(w: &Tensor, bits: &QuantBits) -> Tensor {
    let lv = bits.w_levels() as f32;
    let mut q = weight_quant_unit(w, bits);
    for v in &mut q.data {
        *v = round_ties_even(*v * lv);
    }
    q
}

/// The scale-adjusted-training factor `s = 1/sqrt(n_out*VAR[q])` (Eqn. A20b).
pub fn weight_scale(q_unit: &Tensor, n_out: usize) -> f32 {
    let n = q_unit.len() as f64;
    let mean: f64 = q_unit.data.iter().map(|&v| v as f64).sum::<f64>() / n;
    let var: f64 =
        q_unit.data.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / n;
    (1.0 / (n_out as f64 * (var + 1e-12)).sqrt()) as f32
}

/// DoReFa activation quantizer onto {0, 1/a_levels, ..., 1}.
pub fn act_quant(x: Tensor, bits: &QuantBits) -> Tensor {
    let lv = bits.a_levels() as f32;
    x.map(|v| round_ties_even(v.clamp(0.0, 1.0) * lv) / lv)
}

/// Integer activations on the [0, a_levels] grid (for the PIM engine).
pub fn act_quant_int(x: &Tensor, bits: &QuantBits) -> Tensor {
    let lv = bits.a_levels() as f32;
    let mut out = x.clone();
    for v in &mut out.data {
        *v = round_ties_even(v.clamp(0.0, 1.0) * lv);
    }
    out
}

/// Explicit-bit-width activation quantizer (first layer: 8 bit).
pub fn act_quant_bits(x: Tensor, bits: u32) -> Tensor {
    let lv = ((1u64 << bits) - 1) as f32;
    x.map(|v| round_ties_even(v.clamp(0.0, 1.0) * lv) / lv)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bits() -> QuantBits {
        QuantBits::default()
    }

    #[test]
    fn weights_on_grid_and_bounded() {
        let w = Tensor::from_vec(&[6], vec![0.3, -2.5, 0.1, 1.0, -0.2, 0.9]);
        let q = weight_quant_unit(&w, &bits());
        for &v in &q.data {
            assert!((-1.0..=1.0).contains(&v));
            let i = v * 7.0;
            assert!((i - i.round()).abs() < 1e-5);
        }
        // max |tanh| element hits full scale
        assert!((q.data[1].abs() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn int_matches_unit() {
        let w = Tensor::from_vec(&[4], vec![0.5, -0.7, 0.05, 2.0]);
        let qu = weight_quant_unit(&w, &bits());
        let qi = weight_quant_int(&w, &bits());
        for (u, i) in qu.data.iter().zip(&qi.data) {
            assert!((u * 7.0 - i).abs() < 1e-5);
        }
    }

    #[test]
    fn act_quant_clips_and_grids() {
        let x = Tensor::from_vec(&[4], vec![-0.5, 0.5, 1.5, 7.0 / 15.0]);
        let q = act_quant(x, &bits());
        assert_eq!(q.data[0], 0.0);
        assert_eq!(q.data[2], 1.0);
        assert!((q.data[3] - 7.0 / 15.0).abs() < 1e-6);
    }

    #[test]
    fn scale_formula() {
        let q = Tensor::from_vec(&[4], vec![1.0, -1.0, 1.0, -1.0]);
        // var = 1 → s = 1/sqrt(n_out)
        assert!((weight_scale(&q, 16) - 0.25).abs() < 1e-6);
    }
}
