//! Backward passes for the native training backend (paper §3, Appendix A4).
//!
//! Every quantizer in the forward pass is a straight-through estimator:
//! the digital weight/activation quantizers of `super::quant` use the plain
//! STE (GSTE with ξ = 1, Eqn. A20), while the PIM quantized matmul uses the
//! generalized STE of Theorem 1 — its backward is the exact-matmul backward
//! scaled by η·ξ with `ξ = sqrt(VAR[y_PIM]/VAR[y])` (Eqn. 8); that scaling is
//! applied by the trainer (`crate::train::native`), which owns the PIM
//! forward.  This module provides the differentiable layer primitives:
//!
//! * [`conv_cols_fwd`]/[`conv_cols_bwd`] — im2col conv and its adjoint
//!   (`tensor::ops::col2im` + transposed GEMMs);
//! * [`weight_quant_fwd`]/[`weight_quant_bwd`] — the modified-DoReFa weight
//!   quantizer with the STE through the round and the analytic gradient of
//!   the tanh normalization (including the max-|tanh| path);
//! * [`bn_train_fwd`]/[`bn_train_bwd`] — training-mode batch norm over
//!   batch statistics;
//! * [`act_fwd`]/[`act_bwd`] — ReLU → DoReFa activation quantizer with the
//!   clip-range STE mask;
//! * pooling backwards and the fused softmax + cross-entropy gradient.
//!
//! All of these are finite-difference-checked (against the smooth STE
//! surrogates where a round is involved) in `rust/tests/grad_check.rs`.
//!
//! Since §Perf L3.7 every feature-map-sized output here has a `_pooled`
//! variant whose storage comes from the caller's [`BufPool`] — the trainer
//! uses only those, so the whole step (BN/activation intermediates
//! included, not just patch scale) is allocation-free in steady state
//! (DESIGN.md §Arena).  The plain variants are thin wrappers over a
//! throwaway pool, kept for the finite-difference tests and small one-off
//! callers.

use crate::chip::round_ties_even;
use crate::pim::QuantBits;
use crate::tensor::arena::BufPool;
use crate::tensor::gemm::{gemm_into, gemm_nt_into, gemm_tn_into};
use crate::tensor::{ops, Tensor};

// ---------------------------------------------------------------------------
// Weight quantizer (modified DoReFa, Eqn. A20) with STE backward
// ---------------------------------------------------------------------------

/// Saved forward state of one weight quantization (per layer per step).
pub struct WQuantCtx {
    /// tanh(w), flattened in `w`'s layout.
    t: Vec<f32>,
    /// max|tanh(w)| + 1e-12.
    denom: f32,
    /// Index of the max-|tanh| element (the normalization's argmax path).
    imax: usize,
    /// Eqn. A20b digital scale `s = 1/sqrt(n_out*VAR[q])` — stop-gradient.
    pub scale: f32,
    /// Quantized weights on the [-1, 1] grid, same layout as `w`.
    pub q_unit: Tensor,
}

/// Forward of the modified-DoReFa weight quantizer, keeping what the
/// backward needs.  `q_unit` is bit-identical to
/// [`super::quant::weight_quant_unit`]; `scale` to
/// [`super::quant::weight_scale`].
pub fn weight_quant_fwd(w: &Tensor, bits: &QuantBits, n_out: usize) -> WQuantCtx {
    let mut t = Vec::with_capacity(w.len());
    let mut max_t = 0.0f32;
    let mut imax = 0usize;
    for (i, &v) in w.data.iter().enumerate() {
        let tv = v.tanh();
        if tv.abs() > max_t {
            max_t = tv.abs();
            imax = i;
        }
        t.push(tv);
    }
    let denom = max_t + 1e-12;
    let lv = bits.w_levels() as f32;
    let mut q = w.clone();
    for (qv, &tv) in q.data.iter_mut().zip(&t) {
        *qv = round_ties_even(tv / denom * lv) / lv;
    }
    let scale = super::quant::weight_scale(&q, n_out);
    WQuantCtx { t, denom, imax, scale, q_unit: q }
}

/// Backward of the weight quantizer: given dL/dq_unit, return dL/dw.
///
/// The round is an STE (identity gradient); tanh and the max-normalization
/// are differentiated analytically.  With t = tanh(w), D = max|t| + ε and
/// the surrogate q̃ᵢ = tᵢ/D:
///
/// dL/dwⱼ = gⱼ·(1-tⱼ²)/D − [j = argmax] · sign(t*)·(1-t*²)·(Σᵢ gᵢtᵢ)/D²
///
/// The scale s is a stop-gradient (Eqn. A20b), so it never enters here —
/// callers fold it into `g_q`.
pub fn weight_quant_bwd(ctx: &WQuantCtx, g_q: &Tensor) -> Tensor {
    assert_eq!(g_q.len(), ctx.t.len());
    let d = ctx.denom;
    let mut dot = 0.0f64;
    for (g, t) in g_q.data.iter().zip(&ctx.t) {
        dot += (*g as f64) * (*t as f64);
    }
    let mut out = g_q.clone();
    for (i, o) in out.data.iter_mut().enumerate() {
        let ti = ctx.t[i];
        *o *= (1.0 - ti * ti) / d;
    }
    let ts = ctx.t[ctx.imax];
    let sgn = if ts >= 0.0 { 1.0f32 } else { -1.0 };
    out.data[ctx.imax] -= sgn * (1.0 - ts * ts) * (dot / ((d as f64) * (d as f64))) as f32;
    out
}

// ---------------------------------------------------------------------------
// Convolution via im2col columns
// ---------------------------------------------------------------------------

/// Saved forward state of one conv (the patches are reused by the PIM path
/// and by the backward).
pub struct ConvCtx {
    /// im2col patches [B·oh·ow, C·k·k].
    pub patches: Tensor,
    pub oh: usize,
    pub ow: usize,
}

/// Forward conv from precomputed column weights [C·k·k, O]: returns the
/// NHWC output and the saved context.  The caller applies any scalar
/// coefficient (digital scale s, forward rescale η) to the result.  The
/// patch buffer comes from the arena `pool`; ownership transfers into the
/// returned [`ConvCtx`] and is reclaimed when the caller consumes the tape
/// (DESIGN.md §Arena).
pub fn conv_cols_fwd(
    x: &Tensor,
    wcols: &Tensor,
    k: usize,
    stride: usize,
    pool: &mut BufPool,
) -> (Tensor, ConvCtx) {
    let kc = wcols.shape[0];
    let (patches, oh, ow) = pooled_im2col(x, k, stride, kc, pool);
    let m = patches.shape[0];
    let o = wcols.shape[1];
    let mut y = pool.take_f32(m * o);
    gemm_into(m, kc, o, &patches.data, &wcols.data, &mut y);
    let out = Tensor::from_vec(&[x.shape[0], oh, ow, o], y);
    (out, ConvCtx { patches, oh, ow })
}

/// im2col into an arena buffer: patches [B·oh·ow, kc] whose storage comes
/// from `pool`.  Ownership of the buffer transfers into the returned
/// tensor — it is expected to ride a tape and be `put_f32`-returned by
/// whoever consumes that tape (DESIGN.md §Arena).  `kc` must equal C·k²
/// for `x`'s channel count (checked by the tensor constructor).
pub fn pooled_im2col(
    x: &Tensor,
    k: usize,
    stride: usize,
    kc: usize,
    pool: &mut BufPool,
) -> (Tensor, usize, usize) {
    let (b, h, w) = (x.shape[0], x.shape[1], x.shape[2]);
    let (eh, ew) = ops::conv_out_dims(h, w, k, stride);
    let mut pbuf = pool.take_f32(b * eh * ew * kc);
    let (oh, ow) = ops::im2col_into(x, k, stride, 0, &mut pbuf);
    (Tensor::from_vec(&[b * oh * ow, kc], pbuf), oh, ow)
}

/// Backward of [`conv_cols_fwd`]: `dy` is the flat [M·O] output gradient,
/// already multiplied by any scalar backward coefficient.  Returns dL/dx
/// (pooled storage — the caller owes it back) and writes dL/dwcols into
/// `dwcols` ([K·O], cleared and resized); the patch-gradient intermediate
/// lives in a pooled buffer and never escapes.
#[allow(clippy::too_many_arguments)]
pub fn conv_cols_bwd(
    ctx: &ConvCtx,
    wcols: &Tensor,
    x_shape: &[usize],
    k: usize,
    stride: usize,
    dy: &[f32],
    pool: &mut BufPool,
    dwcols: &mut Vec<f32>,
) -> Tensor {
    let m = ctx.patches.shape[0];
    let kc = ctx.patches.shape[1];
    let o = wcols.shape[1];
    assert_eq!(dy.len(), m * o, "conv output gradient size");
    gemm_tn_into(m, kc, o, &ctx.patches.data, dy, dwcols);
    let mut dpatches = pool.take_f32(m * kc);
    gemm_nt_into(m, o, kc, dy, &wcols.data, &mut dpatches);
    let mut dxbuf = pool.take_f32(x_shape.iter().product());
    ops::col2im_into(&dpatches, x_shape, k, stride, &mut dxbuf);
    pool.put_f32(dpatches);
    Tensor::from_vec(x_shape, dxbuf)
}

// ---------------------------------------------------------------------------
// Batch norm (training mode: batch statistics)
// ---------------------------------------------------------------------------

/// Saved forward state of one training-mode BN layer.
pub struct BnCtx {
    /// This batch's per-channel mean (feeds the running-stat update).
    pub mean: Vec<f32>,
    /// This batch's per-channel biased variance.
    pub var: Vec<f32>,
    inv: Vec<f32>,
    xhat: Tensor,
}

impl BnCtx {
    /// Return the context's feature-map-sized storage (x̂) to the pool.
    /// The backward loops call this when they consume a BN tape.
    pub fn recycle(self, pool: &mut BufPool) {
        pool.put_f32(self.xhat.data);
    }
}

/// Training-mode batch norm: normalize with THIS batch's statistics
/// (biased variance over B·H·W, eps 1e-5 — the jax model's convention).
pub fn bn_train_fwd(x: &Tensor, gamma: &[f32], beta: &[f32]) -> (Tensor, BnCtx) {
    bn_train_fwd_pooled(x, gamma, beta, &mut BufPool::new())
}

/// [`bn_train_fwd`] with y and x̂ in pooled storage (x̂ rides the returned
/// [`BnCtx`]; reclaim it with [`BnCtx::recycle`]).
pub fn bn_train_fwd_pooled(
    x: &Tensor,
    gamma: &[f32],
    beta: &[f32],
    pool: &mut BufPool,
) -> (Tensor, BnCtx) {
    let c = *x.shape.last().unwrap();
    assert!(gamma.len() == c && beta.len() == c);
    let (mean, var) = ops::channel_stats(x);
    let inv: Vec<f32> = var.iter().map(|v| 1.0 / (v + 1e-5).sqrt()).collect();
    let mut xh = pool.take_f32(x.len());
    xh.extend(x.data.iter().enumerate().map(|(i, v)| {
        let ci = i % c;
        (*v - mean[ci]) * inv[ci]
    }));
    let mut yb = pool.take_f32(x.len());
    yb.extend(xh.iter().enumerate().map(|(i, v)| {
        let ci = i % c;
        gamma[ci] * *v + beta[ci]
    }));
    let y = Tensor::from_vec(&x.shape, yb);
    let xhat = Tensor::from_vec(&x.shape, xh);
    (y, BnCtx { mean, var, inv, xhat })
}

/// Backward of training-mode BN: returns (dx, dgamma, dbeta).  Standard
/// batch-statistics backward: with N = B·H·W per channel and x̂ the
/// normalized input,
/// dx = γ·inv/N · (N·dy − Σdy − x̂·Σ(dy·x̂)).
pub fn bn_train_bwd(ctx: &BnCtx, gamma: &[f32], dy: &Tensor) -> (Tensor, Vec<f32>, Vec<f32>) {
    bn_train_bwd_pooled(ctx, gamma, dy, &mut BufPool::new())
}

/// [`bn_train_bwd`] with dx in pooled storage.
pub fn bn_train_bwd_pooled(
    ctx: &BnCtx,
    gamma: &[f32],
    dy: &Tensor,
    pool: &mut BufPool,
) -> (Tensor, Vec<f32>, Vec<f32>) {
    let c = *dy.shape.last().unwrap();
    assert_eq!(gamma.len(), c);
    let n = (dy.len() / c) as f32;
    let mut dbeta = vec![0.0f32; c];
    let mut dgamma = vec![0.0f32; c];
    for (i, &g) in dy.data.iter().enumerate() {
        let ci = i % c;
        dbeta[ci] += g;
        dgamma[ci] += g * ctx.xhat.data[i];
    }
    let mut dxb = pool.take_f32(dy.len());
    dxb.extend(dy.data.iter().enumerate().map(|(i, g)| {
        let ci = i % c;
        gamma[ci] * ctx.inv[ci] / n * (n * *g - dbeta[ci] - ctx.xhat.data[i] * dgamma[ci])
    }));
    (Tensor::from_vec(&dy.shape, dxb), dgamma, dbeta)
}

// ---------------------------------------------------------------------------
// Activation: ReLU → DoReFa quantizer with the clip-range STE mask
// ---------------------------------------------------------------------------

/// Forward of `act_quant(relu(x))` saving the STE mask: the gradient is 1
/// exactly where the pre-activation is in (0, 1] (ReLU passes and the clip
/// does not saturate), else 0.
pub fn act_fwd(x: &Tensor, bits: &QuantBits) -> (Tensor, Vec<u8>) {
    act_fwd_pooled(x, bits, &mut BufPool::new())
}

/// [`act_fwd`] with the output and the mask in pooled storage (the caller
/// owes both back: the tensor via `put_tensor`, the mask via `put_u8`).
pub fn act_fwd_pooled(x: &Tensor, bits: &QuantBits, pool: &mut BufPool) -> (Tensor, Vec<u8>) {
    let lv = bits.a_levels() as f32;
    let mut mask = pool.take_u8(x.len());
    let mut yb = pool.take_f32(x.len());
    for &xi in &x.data {
        mask.push((xi > 0.0 && xi <= 1.0) as u8);
        yb.push(round_ties_even(xi.clamp(0.0, 1.0) * lv) / lv);
    }
    (Tensor::from_vec(&x.shape, yb), mask)
}

/// Backward of [`act_fwd`]: dy masked by the saved STE mask.
pub fn act_bwd(mask: &[u8], dy: &Tensor) -> Tensor {
    let mut dx = dy.clone();
    act_bwd_inplace(mask, &mut dx);
    dx
}

/// [`act_bwd`] in place — the STE mask zeroes `dy` directly, no
/// allocation at all (the trainer owns its gradient feature maps, so
/// masking never needs a copy).
pub fn act_bwd_inplace(mask: &[u8], dy: &mut Tensor) {
    assert_eq!(mask.len(), dy.len());
    for (v, &m) in dy.data.iter_mut().zip(mask) {
        if m == 0 {
            *v = 0.0;
        }
    }
}

// ---------------------------------------------------------------------------
// Pooling
// ---------------------------------------------------------------------------

/// 2×2 max pool saving per-output argmax indices into `x.data`.
pub fn maxpool2_fwd(x: &Tensor) -> (Tensor, Vec<u32>) {
    maxpool2_fwd_pooled(x, &mut BufPool::new())
}

/// [`maxpool2_fwd`] with the output and the argmax indices in pooled
/// storage (owed back via `put_tensor` / `put_u32`).
pub fn maxpool2_fwd_pooled(x: &Tensor, pool: &mut BufPool) -> (Tensor, Vec<u32>) {
    let (b, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (oh, ow) = (h / 2, w / 2);
    let mut ob = pool.take_f32(b * oh * ow * c);
    ob.resize(b * oh * ow * c, 0.0);
    let mut out = Tensor::from_vec(&[b, oh, ow, c], ob);
    let mut idx = pool.take_u32(b * oh * ow * c);
    idx.resize(b * oh * ow * c, 0);
    for bi in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                for ci in 0..c {
                    let mut best = f32::NEG_INFINITY;
                    let mut bat = 0usize;
                    for dy in 0..2 {
                        for dx in 0..2 {
                            let src = ((bi * h + 2 * oy + dy) * w + 2 * ox + dx) * c + ci;
                            if x.data[src] > best {
                                best = x.data[src];
                                bat = src;
                            }
                        }
                    }
                    let dst = ((bi * oh + oy) * ow + ox) * c + ci;
                    out.data[dst] = best;
                    idx[dst] = bat as u32;
                }
            }
        }
    }
    (out, idx)
}

/// Backward of [`maxpool2_fwd`]: route each output gradient to its argmax.
pub fn maxpool2_bwd(idx: &[u32], x_shape: &[usize], dy: &Tensor) -> Tensor {
    maxpool2_bwd_pooled(idx, x_shape, dy, &mut BufPool::new())
}

/// [`maxpool2_bwd`] with dx in pooled storage.
pub fn maxpool2_bwd_pooled(
    idx: &[u32],
    x_shape: &[usize],
    dy: &Tensor,
    pool: &mut BufPool,
) -> Tensor {
    assert_eq!(idx.len(), dy.len());
    let mut dx = Tensor::from_vec(x_shape, pool.take_zeroed_f32(x_shape.iter().product()));
    for (i, &g) in dy.data.iter().enumerate() {
        dx.data[idx[i] as usize] += g;
    }
    dx
}

/// Backward of [`ops::global_avg_pool`]: broadcast dY[B,C]/(H·W).
pub fn global_avg_pool_bwd(x_shape: &[usize], dy: &Tensor) -> Tensor {
    global_avg_pool_bwd_pooled(x_shape, dy, &mut BufPool::new())
}

/// [`global_avg_pool_bwd`] with dx in pooled storage.
pub fn global_avg_pool_bwd_pooled(x_shape: &[usize], dy: &Tensor, pool: &mut BufPool) -> Tensor {
    let (b, h, w, c) = (x_shape[0], x_shape[1], x_shape[2], x_shape[3]);
    assert_eq!(dy.shape, vec![b, c]);
    let inv = 1.0 / (h * w) as f32;
    let mut dx = Tensor::from_vec(x_shape, pool.take_zeroed_f32(x_shape.iter().product()));
    for bi in 0..b {
        for hi in 0..h {
            for wi in 0..w {
                let dst = ((bi * h + hi) * w + wi) * c;
                for ci in 0..c {
                    dx.data[dst + ci] = dy.data[bi * c + ci] * inv;
                }
            }
        }
    }
    dx
}

// ---------------------------------------------------------------------------
// Loss
// ---------------------------------------------------------------------------

/// Fused softmax + mean cross-entropy: returns (mean loss, correct count,
/// dL/dlogits = (softmax − onehot)/B).
pub fn softmax_xent(logits: &Tensor, labels: &[i32]) -> (f32, usize, Tensor) {
    let (b, k) = (logits.shape[0], logits.shape[1]);
    assert_eq!(labels.len(), b);
    let mut dl = logits.clone();
    let mut total = 0.0f64;
    let mut correct = 0usize;
    for i in 0..b {
        let row = &logits.data[i * k..(i + 1) * k];
        let mut mx = f32::NEG_INFINITY;
        let mut arg = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > mx {
                mx = v;
                arg = j;
            }
        }
        let y = labels[i] as usize;
        correct += (arg == y) as usize;
        let mut denom = 0.0f64;
        for &v in row {
            denom += ((v - mx) as f64).exp();
        }
        total += denom.ln() + mx as f64 - row[y] as f64;
        let drow = &mut dl.data[i * k..(i + 1) * k];
        for (j, v) in drow.iter_mut().enumerate() {
            let p = ((*v - mx) as f64).exp() / denom;
            *v = (p as f32 - (j == y) as usize as f32) / b as f32;
        }
    }
    ((total / b as f64) as f32, correct, dl)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn weight_quant_fwd_matches_quantizer() {
        let mut rng = Rng::new(1);
        let w = Tensor::from_vec(&[3, 3, 2, 4], (0..72).map(|_| rng.normal_in(0.0, 0.7)).collect());
        let bits = QuantBits::default();
        let ctx = weight_quant_fwd(&w, &bits, 4);
        let q = super::super::quant::weight_quant_unit(&w, &bits);
        assert_eq!(ctx.q_unit.data, q.data);
        let s = super::super::quant::weight_scale(&q, 4);
        assert!((ctx.scale - s).abs() < 1e-9);
    }

    #[test]
    fn softmax_xent_matches_cross_entropy() {
        let logits = Tensor::from_vec(&[2, 3], vec![2.0, -1.0, 0.5, 0.0, 3.0, 1.0]);
        let (loss, correct, dl) = softmax_xent(&logits, &[0, 1]);
        let want = ops::cross_entropy(&logits, &[0, 1]);
        assert!((loss - want).abs() < 1e-5);
        assert_eq!(correct, 2);
        // gradient rows sum to zero (softmax minus onehot)
        for i in 0..2 {
            let s: f32 = dl.data[i * 3..(i + 1) * 3].iter().sum();
            assert!(s.abs() < 1e-6);
        }
    }

    #[test]
    fn maxpool_bwd_routes_to_argmax() {
        let x = Tensor::from_vec(&[1, 2, 2, 1], vec![1.0, 5.0, 2.0, 3.0]);
        let (y, idx) = maxpool2_fwd(&x);
        assert_eq!(y.data, vec![5.0]);
        let dy = Tensor::from_vec(&[1, 1, 1, 1], vec![2.5]);
        let dx = maxpool2_bwd(&idx, &x.shape, &dy);
        assert_eq!(dx.data, vec![0.0, 2.5, 0.0, 0.0]);
    }

    #[test]
    fn act_mask_zeroes_saturated_and_negative() {
        let x = Tensor::from_vec(&[4], vec![-0.3, 0.4, 0.9, 1.7]);
        let (_, mask) = act_fwd(&x, &QuantBits::default());
        assert_eq!(mask, vec![0, 1, 1, 0]);
        let dy = Tensor::from_vec(&[4], vec![1.0; 4]);
        assert_eq!(act_bwd(&mask, &dy).data, vec![0.0, 1.0, 1.0, 0.0]);
    }

    #[test]
    fn bn_bwd_zero_mean_gradient() {
        // BN output is invariant to adding a constant per channel, so dx
        // must sum to ~0 per channel.
        let mut rng = Rng::new(3);
        let x = Tensor::from_vec(&[2, 3, 3, 2], (0..36).map(|_| rng.normal_in(0.5, 2.0)).collect());
        let gamma = vec![1.3, 0.7];
        let beta = vec![0.1, -0.2];
        let (_, ctx) = bn_train_fwd(&x, &gamma, &beta);
        let dy = Tensor::from_vec(&x.shape, (0..36).map(|_| rng.normal_in(0.0, 1.0)).collect());
        let (dx, _, _) = bn_train_bwd(&ctx, &gamma, &dy);
        for ci in 0..2 {
            let s: f32 = dx
                .data
                .iter()
                .enumerate()
                .filter(|(i, _)| i % 2 == ci)
                .map(|(_, v)| v)
                .sum();
            assert!(s.abs() < 1e-3, "channel {ci} dx sum {s}");
        }
    }
}
