//! Inference serving layer (DESIGN.md §Serving layer): a chip-farm
//! front-end over the trained checkpoint.
//!
//! Pipeline: producers → [`queue::BoundedQueue`] (bounded admission,
//! backpressure) → [`batcher`] (coalesce to engine-sized batches under a
//! latency budget) → [`farm::Farm`] (N isolated chip replicas, one
//! in-flight batch each, scheduled on the global worker pool) → per-request
//! [`farm::Response`]s.
//!
//! Determinism contract: replicas share nothing mutable, and on a
//! *noiseless* chip a replica's answer for an image is bitwise independent
//! of how requests were coalesced — the f32/integer kernels accumulate
//! each batch row in a batch-size-invariant order, faults are per-column,
//! and no RNG is drawn.  With thermal noise enabled, results are instead
//! reproducible per (replica, batch composition, seed).  See
//! `tests/serve.rs` for the pinned properties.

pub mod batcher;
pub mod farm;
pub mod load;
pub mod queue;

pub use batcher::{next_batch, BatcherCfg};
pub use farm::{Farm, FarmServer, Pending, Replica, ReplicaCfg, Response, ServeCfg};
pub use load::{run_open_loop, LoadCfg, LoadReport};
pub use queue::{BoundedQueue, Pop};
