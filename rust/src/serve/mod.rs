//! Inference serving layer (DESIGN.md §Serving layer): a chip-farm
//! front-end over the trained checkpoint.
//!
//! Pipeline: producers → [`queue::BoundedQueue`] (bounded admission,
//! backpressure) → [`batcher`] (coalesce to engine-sized batches under a
//! latency budget) → [`farm::Farm`] (N isolated chip replicas, one
//! in-flight batch each, scheduled on the global worker pool) → per-request
//! [`farm::Reply`]s.  The [`health`] module closes the robustness loop:
//! online drift detection, replica quarantine, in-service BN recalibration
//! (§3.4), and reinstatement — plus request TTLs and batch hedging in the
//! dispatcher.
//!
//! Determinism contract: replicas share nothing mutable, and on a
//! *noiseless* chip a replica's answer for an image is bitwise independent
//! of how requests were coalesced — the f32/integer kernels accumulate
//! each batch row in a batch-size-invariant order, faults are per-column,
//! and no RNG is drawn.  With thermal noise enabled, results are instead
//! reproducible per (replica, batch composition, seed).  Under hedging,
//! *which* replica answers is a race, but the answer is still bitwise that
//! replica's standalone answer (per-response `chip_id` names the winner).
//! See `tests/serve.rs` for the pinned properties.

pub mod batcher;
pub mod farm;
pub mod health;
pub mod load;
pub mod queue;

pub use batcher::{next_batch, next_batch_poll, BatchPoll, BatcherCfg};
pub use farm::{
    BatchStats, Farm, FarmServer, Pending, Replica, ReplicaCfg, Reply, Request, Response, ServeCfg,
};
pub use health::{
    probe_step, HealthCfg, HealthLedger, HealthMonitor, HealthShared, HealthSnapshot,
    ReplicaHealth, ReplicaState, Transition,
};
pub use load::{run_open_loop, LoadCfg, LoadReport};
pub use queue::{BoundedQueue, Pop};
