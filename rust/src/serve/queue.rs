//! Bounded MPSC request queue: the admission edge of the serving layer.
//!
//! Capacity is the backpressure mechanism — [`BoundedQueue::push`] *blocks*
//! when the queue is full instead of dropping, so an over-driven open-loop
//! load generator degrades into a closed loop rather than losing requests
//! (DESIGN.md §Serving layer).  [`BoundedQueue::close`] starts shutdown:
//! producers get their item back, the consumer drains what is already
//! queued and then sees [`Pop::Closed`].

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

/// Outcome of a consumer pop.
pub enum Pop<T> {
    /// An item was dequeued.
    Item(T),
    /// The deadline passed with the queue still empty (and open).
    TimedOut,
    /// The queue is closed *and* fully drained — no item will ever appear.
    Closed,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// A blocking bounded multi-producer queue (single consumer by convention:
/// the batcher thread; nothing breaks with several consumers).
pub struct BoundedQueue<T> {
    cap: usize,
    inner: Mutex<Inner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
}

impl<T> BoundedQueue<T> {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0, "queue capacity must be positive");
        BoundedQueue {
            cap,
            inner: Mutex::new(Inner { items: VecDeque::new(), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Queued items right now (racy by nature; diagnostics only).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }

    /// Enqueue `item`, blocking while the queue is at capacity
    /// (backpressure, never drops).  Returns the item back if the queue
    /// was closed before space opened up.
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed {
                return Err(item);
            }
            if g.items.len() < self.cap {
                break;
            }
            g = self.not_full.wait(g).unwrap();
        }
        g.items.push_back(item);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Dequeue, blocking until an item arrives or the queue is closed and
    /// drained.  Items queued before `close` are still delivered.
    pub fn pop(&self) -> Pop<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(it) = g.items.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Pop::Item(it);
            }
            if g.closed {
                return Pop::Closed;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Dequeue with a deadline: an item if one arrives in time,
    /// [`Pop::TimedOut`] once `deadline` passes, [`Pop::Closed`] when the
    /// queue is closed and drained.  The batcher's latency budget lives
    /// here — a partial batch stops waiting the moment the deadline hits.
    pub fn pop_deadline(&self, deadline: Instant) -> Pop<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(it) = g.items.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Pop::Item(it);
            }
            if g.closed {
                return Pop::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return Pop::TimedOut;
            }
            // spurious wakes are fine: the loop re-checks everything
            let (guard, _timeout) = self.not_empty.wait_timeout(g, deadline - now).unwrap();
            g = guard;
        }
    }

    /// Close the queue: producers unblock with their item returned,
    /// the consumer drains the backlog and then sees [`Pop::Closed`].
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn fifo_roundtrip_and_len() {
        let q = BoundedQueue::new(4);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.len(), 2);
        assert!(matches!(q.pop(), Pop::Item(1)));
        assert!(matches!(q.pop(), Pop::Item(2)));
        assert!(q.is_empty());
    }

    #[test]
    fn pop_deadline_times_out_on_empty_open_queue() {
        let q: BoundedQueue<u32> = BoundedQueue::new(2);
        let t0 = Instant::now();
        let r = q.pop_deadline(t0 + Duration::from_millis(20));
        assert!(matches!(r, Pop::TimedOut));
        assert!(t0.elapsed() >= Duration::from_millis(20));
    }

    #[test]
    fn close_drains_backlog_then_reports_closed() {
        let q = BoundedQueue::new(4);
        q.push(7).unwrap();
        q.close();
        assert!(q.push(8).is_err(), "push after close returns the item");
        assert!(matches!(q.pop(), Pop::Item(7)), "backlog still delivered");
        assert!(matches!(q.pop(), Pop::Closed));
        assert!(matches!(q.pop_deadline(Instant::now()), Pop::Closed));
    }

    #[test]
    fn full_queue_blocks_producer_until_space_or_close() {
        let q = Arc::new(BoundedQueue::new(1));
        q.push(0).unwrap();
        let q2 = Arc::clone(&q);
        let producer = std::thread::spawn(move || q2.push(1));
        // the producer is parked on the full queue; popping frees it
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(q.len(), 1, "second push must not have landed yet");
        assert!(matches!(q.pop(), Pop::Item(0)));
        producer.join().unwrap().unwrap();
        assert!(matches!(q.pop(), Pop::Item(1)));
        // and a producer parked at close() gets its item back
        q.push(2).unwrap();
        let q3 = Arc::clone(&q);
        let parked = std::thread::spawn(move || q3.push(3));
        std::thread::sleep(Duration::from_millis(10));
        q.close();
        assert_eq!(parked.join().unwrap().unwrap_err(), 3);
    }
}
