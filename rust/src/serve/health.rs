//! Replica health: online drift detection, quarantine, and in-service
//! recalibration for the chip farm (DESIGN.md §Serving layer).
//!
//! The paper's robustness story (§3.4) is that BN calibration absorbs
//! chip non-idealities; PR 6 wired that as *offline* field repair, but a
//! fielded farm degrades *while serving* — the fault subsystem's drift
//! random-walk grows without bound, and nothing notices.  This module
//! closes the loop with three cheap online signals per replica:
//!
//! * **logit-magnitude drift** — an EMA of mean |logit| per served batch,
//!   compared against the value committed from a pristine reference
//!   replica at startup.  Free (computed from answers already produced),
//!   coarse, and only used to *flag* a replica for an early probe.
//! * **probe disagreement** — a fixed shadow batch replayed periodically
//!   on both the suspect replica and a designated pristine reference
//!   replica; the fraction of differing argmax classes is the decision
//!   signal.  Costs one inference per probed replica per round.
//! * **error/latency counters** — forward failures flag immediately;
//!   service-time EMA rides along for reporting.
//!
//! Decisions run a hysteresis state machine per replica:
//!
//! ```text
//! Healthy -> Suspect -> Quarantined -> Recalibrating -> Reinstated -> Healthy
//!    ^          |                           |
//!    +----------+ (clean probe)             +--> Retired (retries exhausted)
//! ```
//!
//! One breach (disagreement > threshold) makes a replica `Suspect`;
//! `quarantine_after` *consecutive* breaches quarantine it — removed from
//! dispatch rotation without touching its in-flight batch.  A quarantined
//! replica immediately enters `Recalibrating`: a worker-pool job streams a
//! held-out calibration shard through its **injured** engines
//! ([`crate::train::recalibrate_network`], the §3.4 mechanism) and
//! re-probes; it is `Reinstated` only when disagreement falls back under
//! the threshold, and permanently `Retired` (terminal log line) after
//! `recal_retries` failed attempts.  The farm never quarantines the last
//! in-rotation replica — detection defers rather than emptying the farm.
//!
//! All state lives in the [`HealthLedger`] behind one short-hold mutex
//! ([`HealthShared`]); batch serving jobs append observations, the batcher
//! thread reads and decides, recalibration jobs report their outcome.

use std::sync::{Arc, Mutex};

use crate::data::Dataset;
use crate::runtime::Manifest;
use crate::tensor::{ops, Tensor};
use crate::train::Checkpoint;
use crate::util::error::{anyhow, Result};
use crate::util::pool::ScopedJob;

use super::farm::{BatchStats, Replica, ReplicaCfg};

/// Per-replica lifecycle state.  Only [`ReplicaState::in_rotation`] states
/// receive dispatched batches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaState {
    /// Serving; probes clean.
    Healthy,
    /// Breached the disagreement threshold once; still serving (hysteresis
    /// against one-off flukes), probed every round until resolved.
    Suspect,
    /// Removed from dispatch rotation after consecutive breaches.
    Quarantined,
    /// Recalibration job running on the worker pool.
    Recalibrating,
    /// Recalibrated and probing clean again; serving.  Transitions to
    /// [`ReplicaState::Healthy`] on its next clean probe.
    Reinstated,
    /// Recalibration retries exhausted — permanently out of rotation.
    Retired,
}

impl ReplicaState {
    /// Does the dispatcher send batches to a replica in this state?
    pub fn in_rotation(self) -> bool {
        matches!(self, ReplicaState::Healthy | ReplicaState::Suspect | ReplicaState::Reinstated)
    }
}

/// Health-monitor knobs (`pim-qat serve --health-probe-every`,
/// `--quarantine-threshold`).
#[derive(Debug, Clone, Copy)]
pub struct HealthCfg {
    /// Run a probe round every this many dispatched batches (0 = probe
    /// only when a replica is flagged by drift/errors).
    pub probe_every: u64,
    /// Probe disagreement fraction above which a probe counts as a breach.
    pub quarantine_threshold: f64,
    /// Consecutive breaches before quarantine (hysteresis; min 1).
    pub quarantine_after: u32,
    /// Recalibration attempts before a replica is permanently retired.
    pub recal_retries: u32,
    /// Images in the shadow probe batch.
    pub probe_images: usize,
    /// Calibration batch size for in-service recalibration.
    pub calib_batch: usize,
    /// Calibration batches streamed per recalibration attempt.
    pub calib_batches: usize,
    /// Seed of the recalibration batch sampler (attempt `k` uses
    /// `recal_seed + k`, so retries see different calibration data).
    pub recal_seed: u64,
    /// Relative deviation of the logit-magnitude EMA from the committed
    /// reference that flags a replica for an early probe.
    pub drift_alert: f64,
}

impl Default for HealthCfg {
    fn default() -> Self {
        HealthCfg {
            probe_every: 8,
            quarantine_threshold: 0.25,
            quarantine_after: 2,
            recal_retries: 2,
            probe_images: 8,
            calib_batch: 8,
            calib_batches: 4,
            recal_seed: 0x0CA1B,
            drift_alert: 0.75,
        }
    }
}

/// One probe decision of the hysteresis state machine: `(state, breaches)`
/// before the probe plus whether it breached → after.  Pure, so the
/// transition table is unit-testable without a farm.  States out of
/// rotation are never probed; they pass through unchanged.
pub fn probe_step(
    state: ReplicaState,
    breaches: u32,
    quarantine_after: u32,
    breach: bool,
) -> (ReplicaState, u32) {
    use ReplicaState::*;
    match (state, breach) {
        // a clean probe clears suspicion entirely (and completes the
        // Reinstated -> Healthy leg of the recovery ladder)
        (Healthy | Suspect | Reinstated, false) => (Healthy, 0),
        (Healthy | Reinstated, true) => {
            if quarantine_after <= 1 {
                (Quarantined, 1)
            } else {
                (Suspect, 1)
            }
        }
        (Suspect, true) => {
            let b = breaches.saturating_add(1);
            if b >= quarantine_after.max(1) {
                (Quarantined, b)
            } else {
                (Suspect, b)
            }
        }
        (s, _) => (s, breaches),
    }
}

/// One row of the ledger: everything the monitor knows about a replica.
#[derive(Debug, Clone)]
pub struct ReplicaHealth {
    pub chip: u64,
    pub state: ReplicaState,
    /// Consecutive probe breaches (hysteresis counter).
    pub breaches: u32,
    /// Probe rounds this replica has been through.
    pub probes: u64,
    /// Disagreement fraction of the most recent probe.
    pub last_disagreement: Option<f64>,
    /// Relative deviation of the logit EMA from the committed reference.
    pub drift_score: f64,
    /// Batches / requests served (including while Suspect).
    pub batches: u64,
    pub requests: u64,
    /// Forward failures observed while serving.
    pub errors: u64,
    pub last_error: Option<String>,
    /// EMA of mean |logit| over served batches.
    pub ema_abs_logit: f64,
    /// EMA of per-batch service time, nanoseconds.
    pub ema_service_ns: f64,
    /// Drift/error signal fired: probe this replica at the next tick
    /// instead of waiting out the cadence.
    pub flagged: bool,
    /// Recalibration attempts consumed so far.
    pub recal_attempts: u32,
}

impl ReplicaHealth {
    fn new(chip: u64) -> ReplicaHealth {
        ReplicaHealth {
            chip,
            state: ReplicaState::Healthy,
            breaches: 0,
            probes: 0,
            last_disagreement: None,
            drift_score: 0.0,
            batches: 0,
            requests: 0,
            errors: 0,
            last_error: None,
            ema_abs_logit: 0.0,
            ema_service_ns: 0.0,
            flagged: false,
            recal_attempts: 0,
        }
    }
}

/// One recorded state-machine transition (the chaos tests assert the
/// recovery ladder on this log).
#[derive(Debug, Clone)]
pub struct Transition {
    /// Monotone sequence number (global order across replicas).
    pub seq: u64,
    pub chip: u64,
    pub from: ReplicaState,
    pub to: ReplicaState,
    pub why: String,
}

/// Owning copy of the ledger for reporting after (or during) a run.
#[derive(Debug, Clone)]
pub struct HealthSnapshot {
    pub rows: Vec<ReplicaHealth>,
    pub transitions: Vec<Transition>,
}

impl HealthSnapshot {
    /// Transitions of one chip, in order.
    pub fn ladder(&self, chip: u64) -> Vec<(ReplicaState, ReplicaState)> {
        self.transitions.iter().filter(|t| t.chip == chip).map(|t| (t.from, t.to)).collect()
    }
}

/// The mutable health state: one row per replica plus the transition log.
/// Lock-hold discipline: every access is short (no inference, no ticket
/// wait, no replica lock while holding this).
pub struct HealthLedger {
    rows: Vec<ReplicaHealth>,
    transitions: Vec<Transition>,
    seq: u64,
    /// Mean |logit| of the probe batch on the pristine reference replica,
    /// committed at startup — the drift signal's fixed point.
    ref_abs_logit: f64,
    drift_alert: f64,
}

impl HealthLedger {
    fn new(replicas: usize, ref_abs_logit: f64, drift_alert: f64) -> HealthLedger {
        HealthLedger {
            rows: (0..replicas).map(|i| ReplicaHealth::new(i as u64)).collect(),
            transitions: Vec::new(),
            seq: 0,
            ref_abs_logit,
            drift_alert,
        }
    }

    pub fn rows(&self) -> &[ReplicaHealth] {
        &self.rows
    }

    pub(super) fn row_mut(&mut self, chip: u64) -> &mut ReplicaHealth {
        &mut self.rows[chip as usize]
    }

    /// Record one served batch's cheap signals for `chip`.
    pub fn record_batch(&mut self, chip: u64, stats: &BatchStats) {
        let reference = self.ref_abs_logit;
        let alert = self.drift_alert;
        let r = &mut self.rows[chip as usize];
        r.batches += 1;
        r.requests += stats.batch as u64;
        const ALPHA: f64 = 0.2;
        let ema = |prev: f64, x: f64, first: bool| {
            if first {
                x
            } else {
                (1.0 - ALPHA) * prev + ALPHA * x
            }
        };
        let first = r.batches == 1;
        r.ema_abs_logit = ema(r.ema_abs_logit, stats.mean_abs_logit, first);
        r.ema_service_ns = ema(r.ema_service_ns, stats.service.as_nanos() as f64, first);
        if let Some(e) = &stats.error {
            r.errors += 1;
            r.last_error = Some(e.clone());
            r.flagged = true;
        }
        if reference > 0.0 && r.state.in_rotation() {
            r.drift_score = (r.ema_abs_logit - reference).abs() / reference;
            if r.drift_score > alert {
                r.flagged = true;
            }
        }
    }

    /// Move `chip` to `to`, record it, and emit the operator log line.
    pub(super) fn transition(&mut self, chip: u64, to: ReplicaState, why: &str) {
        let from = self.rows[chip as usize].state;
        self.rows[chip as usize].state = to;
        self.seq += 1;
        self.transitions.push(Transition { seq: self.seq, chip, from, to, why: why.to_string() });
        println!("[health] chip {chip}: {from:?} -> {to:?} ({why})");
    }

    /// Operator log line without a state change (e.g. a deferred
    /// quarantine on the last in-rotation replica).
    pub(super) fn note(&self, chip: u64, why: &str) {
        println!("[health] chip {chip}: {why}");
    }

    /// Which replicas may receive dispatched batches right now.
    pub(super) fn rotation_mask(&self) -> Vec<bool> {
        self.rows.iter().map(|r| r.state.in_rotation()).collect()
    }

    pub(super) fn any_flagged(&self) -> bool {
        self.rows.iter().any(|r| r.flagged && r.state.in_rotation())
    }

    pub fn snapshot(&self) -> HealthSnapshot {
        HealthSnapshot { rows: self.rows.clone(), transitions: self.transitions.clone() }
    }
}

/// The ledger behind its mutex — shared by serving jobs (append), the
/// batcher thread (decide), recalibration jobs (report), and the server
/// handle (snapshot).
pub struct HealthShared {
    pub ledger: Mutex<HealthLedger>,
}

/// The committed shadow probe: a fixed batch of images with the pristine
/// reference replica's answers frozen at startup.
pub struct ProbeSet {
    x: Tensor,
    /// Reference argmax classes committed at startup.  On a noiseless chip
    /// a fresh reference replay reproduces these bitwise; recalibration
    /// jobs (which cannot borrow the live reference replica) probe against
    /// this committed copy.
    pub ref_classes: Vec<usize>,
    /// Mean |logit| of the probe batch on the reference — the drift
    /// signal's fixed point.
    pub ref_abs_logit: f64,
}

impl ProbeSet {
    /// Stack the first `n` images of `ds` and commit the reference answers.
    fn commit(ds: &Dataset, n: usize, reference: &mut Replica) -> Result<ProbeSet> {
        if ds.is_empty() {
            return Err(anyhow!("health probe dataset is empty"));
        }
        let n = n.clamp(1, ds.len());
        let (h, w, c) = {
            let s = &ds.images[0].shape;
            (s[0], s[1], s[2])
        };
        let px = h * w * c;
        let mut x = Tensor::zeros(&[n, h, w, c]);
        for i in 0..n {
            x.data[i * px..(i + 1) * px].copy_from_slice(&ds.images[i].data);
        }
        let (logits, _) = reference.try_infer(&x)?;
        let ref_classes = ops::argmax_rows(&logits);
        let ref_abs_logit = mean_abs(&logits.data);
        Ok(ProbeSet { x, ref_classes, ref_abs_logit })
    }

    /// Replay the probe batch on `rep` → its argmax classes.
    pub(super) fn replay(&self, rep: &mut Replica) -> Result<Vec<usize>> {
        let (logits, _) = rep.try_infer(&self.x)?;
        Ok(ops::argmax_rows(&logits))
    }

    /// Fraction of probe images where `rep` disagrees with `ref_classes`.
    /// A replica that cannot even run the probe counts as fully disagreeing.
    pub(super) fn disagreement_vs(&self, rep: &mut Replica, ref_classes: &[usize]) -> f64 {
        match self.replay(rep) {
            Ok(classes) => {
                let n = classes.len().min(ref_classes.len());
                if n == 0 {
                    return 1.0;
                }
                let diff = classes.iter().zip(ref_classes).filter(|(a, b)| a != b).count();
                diff as f64 / n as f64
            }
            Err(_) => 1.0,
        }
    }

    /// Disagreement against the committed startup reference.
    pub(super) fn disagreement(&self, rep: &mut Replica) -> f64 {
        let reference = self.ref_classes.clone();
        self.disagreement_vs(rep, &reference)
    }
}

fn mean_abs(v: &[f32]) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    v.iter().map(|x| x.abs() as f64).sum::<f64>() / v.len() as f64
}

/// The farm-side health driver: ledger + reference replica + probe set +
/// calibration shard.  Owned by the [`super::Farm`] and driven from the
/// batcher thread between batches; recalibration runs on the worker pool.
pub struct HealthMonitor {
    pub(super) shared: Arc<HealthShared>,
    pub(super) cfg: HealthCfg,
    /// The designated reference replica: pristine (no fault replica),
    /// never in the dispatch rotation, replays the shadow probe each round.
    pub(super) reference: Replica,
    pub(super) probe: Arc<ProbeSet>,
    /// Held-out calibration shard for in-service recalibration.
    pub(super) calib: Arc<Dataset>,
    /// Dispatch count at the last probe round.
    pub(super) last_probe: u64,
}

impl HealthMonitor {
    /// Build the monitor for a farm of `replicas` chips served from
    /// (`manifest`, `ckpt`) under `rcfg`.  The reference replica is the
    /// same stack with faults stripped (chip id `replicas`, outside the
    /// farm); `probe_ds` supplies the shadow batch, `calib` the held-out
    /// recalibration shard.
    pub fn new(
        manifest: &Manifest,
        ckpt: &Checkpoint,
        rcfg: &ReplicaCfg,
        replicas: usize,
        probe_ds: &Dataset,
        calib: Dataset,
        cfg: HealthCfg,
    ) -> Result<HealthMonitor> {
        if calib.is_empty() {
            return Err(anyhow!("health calibration shard is empty"));
        }
        let mut ref_cfg = rcfg.clone();
        ref_cfg.faults = None;
        let mut reference = Replica::new(manifest, ckpt, &ref_cfg, replicas as u64)?;
        let probe = ProbeSet::commit(probe_ds, cfg.probe_images, &mut reference)?;
        let ledger = HealthLedger::new(replicas, probe.ref_abs_logit, cfg.drift_alert);
        Ok(HealthMonitor {
            shared: Arc::new(HealthShared { ledger: Mutex::new(ledger) }),
            cfg,
            reference,
            probe: Arc::new(probe),
            calib: Arc::new(calib),
            last_probe: 0,
        })
    }

    pub fn shared(&self) -> Arc<HealthShared> {
        Arc::clone(&self.shared)
    }

    /// The recalibration job for a quarantined replica: runs PR 6's BN
    /// self-tuning through the replica's injured engine cache, re-probes
    /// against the committed reference, reinstates under threshold or
    /// retires after bounded retries.  Holds the replica mutex for the
    /// whole job — safe because a quarantined replica is out of rotation
    /// and never probed by the batcher thread.
    pub(super) fn recal_job(
        &self,
        chip: u64,
        state: Arc<Mutex<Replica>>,
    ) -> ScopedJob<'static> {
        let shared = Arc::clone(&self.shared);
        let probe = Arc::clone(&self.probe);
        let calib = Arc::clone(&self.calib);
        let cfg = self.cfg;
        Box::new(move || {
            let mut rep = state.lock().unwrap();
            let attempts = cfg.recal_retries.max(1);
            for attempt in 0..attempts {
                let seed = cfg.recal_seed.wrapping_add(attempt as u64);
                let recal =
                    rep.recalibrate(&calib, cfg.calib_batch.max(1), cfg.calib_batches.max(1), seed);
                let d = match recal {
                    Ok(()) => probe.disagreement(&mut rep),
                    Err(_) => 1.0,
                };
                {
                    let mut led = shared.ledger.lock().unwrap();
                    let row = led.row_mut(chip);
                    row.recal_attempts += 1;
                    row.last_disagreement = Some(d);
                }
                if d <= cfg.quarantine_threshold {
                    shared.ledger.lock().unwrap().transition(
                        chip,
                        ReplicaState::Reinstated,
                        &format!(
                            "probe disagreement {d:.3} <= {:.3} after attempt {}",
                            cfg.quarantine_threshold,
                            attempt + 1
                        ),
                    );
                    return;
                }
            }
            shared.ledger.lock().unwrap().transition(
                chip,
                ReplicaState::Retired,
                &format!("permanently retired after {attempts} recalibration attempts"),
            );
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;
    use ReplicaState::*;

    #[test]
    fn hysteresis_requires_consecutive_breaches() {
        // one fluke does not quarantine
        let (s, b) = probe_step(Healthy, 0, 2, true);
        assert_eq!((s, b), (Suspect, 1));
        // a clean probe resets the counter entirely
        let (s, b) = probe_step(s, b, 2, false);
        assert_eq!((s, b), (Healthy, 0));
        // two consecutive breaches do
        let (s, b) = probe_step(Healthy, 0, 2, true);
        let (s, b) = probe_step(s, b, 2, true);
        assert_eq!((s, b), (Quarantined, 2));
        // quarantine_after = 1 skips the Suspect stage
        assert_eq!(probe_step(Healthy, 0, 1, true), (Quarantined, 1));
    }

    #[test]
    fn reinstated_completes_the_ladder_or_relapses() {
        assert_eq!(probe_step(Reinstated, 0, 2, false), (Healthy, 0));
        assert_eq!(probe_step(Reinstated, 0, 2, true), (Suspect, 1));
        // out-of-rotation states pass through untouched
        for s in [Quarantined, Recalibrating, Retired] {
            assert_eq!(probe_step(s, 3, 2, true), (s, 3));
        }
    }

    #[test]
    fn ledger_flags_drift_and_errors_and_logs_transitions() {
        let mut led = HealthLedger::new(2, 1.0, 0.5);
        let ok = BatchStats {
            batch: 4,
            mean_abs_logit: 1.02,
            service: Duration::from_micros(80),
            error: None,
        };
        led.record_batch(0, &ok);
        assert!(!led.any_flagged(), "2% drift is under the 50% alert");
        assert_eq!(led.rows()[0].requests, 4);
        // a drifted replica flags itself for an early probe
        let drifted = BatchStats { mean_abs_logit: 9.0, ..ok.clone() };
        led.record_batch(1, &drifted);
        assert!(led.any_flagged());
        assert!(led.rows()[1].drift_score > 0.5);
        // errors flag too, and the rotation mask tracks transitions
        let failed = BatchStats { error: Some("boom".into()), ..ok };
        led.record_batch(0, &failed);
        assert_eq!(led.rows()[0].errors, 1);
        led.transition(1, Quarantined, "test");
        assert_eq!(led.rotation_mask(), vec![true, false]);
        let snap = led.snapshot();
        assert_eq!(snap.ladder(1), vec![(Healthy, Quarantined)]);
    }
}
