//! Dynamic batcher: coalesce single-image requests into engine-sized
//! batches under a latency budget.
//!
//! The policy is the standard two-trigger flush: a batch ships when it is
//! *full* (`batch` requests) or when the *deadline* — first request's
//! arrival plus `budget` — passes, whichever comes first.  A partial batch
//! therefore never waits for stragglers longer than the budget, and an
//! idle server burns no CPU (the wait for the batch's first request has no
//! deadline at all).
//!
//! A server that also runs periodic background work (health probes,
//! hedging scans — `serve::health`) cannot afford the deadline-less first
//! wait: it uses [`next_batch_poll`] with an idle tick, which bounds the
//! wait for the opening request and reports [`BatchPoll::Idle`] so the
//! caller can run its tick and come back.

use std::time::{Duration, Instant};

use super::queue::{BoundedQueue, Pop};

/// Batcher knobs (`--batch`, `--latency-budget-us`).
#[derive(Debug, Clone, Copy)]
pub struct BatcherCfg {
    /// Flush when this many requests have coalesced.
    pub batch: usize,
    /// Flush a partial batch this long after its first request arrived.
    pub budget: Duration,
}

/// What one polling round of the batcher produced.
#[derive(Debug)]
pub enum BatchPoll<T> {
    /// A coalesced batch, ready to dispatch.
    Batch(Vec<T>),
    /// No request arrived within the idle tick — run background work and
    /// poll again.
    Idle,
    /// Queue closed *and* drained: the batcher's termination condition.
    Closed,
}

/// Block for the next batch: the first request opens the batch and starts
/// the budget clock; further requests join until the batch is full or the
/// deadline hits.  `None` means the queue is closed *and* drained — the
/// batcher's termination condition, guaranteeing every accepted request
/// was part of some returned batch.
pub fn next_batch<T>(q: &BoundedQueue<T>, cfg: &BatcherCfg) -> Option<Vec<T>> {
    match next_batch_poll(q, cfg, None) {
        BatchPoll::Batch(b) => Some(b),
        BatchPoll::Closed => None,
        BatchPoll::Idle => unreachable!("tick-less poll cannot go idle"),
    }
}

/// [`next_batch`] with a bounded wait for the *opening* request: if no
/// request arrives within `idle_tick`, returns [`BatchPoll::Idle`] instead
/// of blocking forever.  `None` tick degenerates to the blocking wait.
/// Once a batch opens, the fill policy (full-or-deadline) is identical to
/// [`next_batch`] — the tick bounds idleness, not batch latency.
pub fn next_batch_poll<T>(
    q: &BoundedQueue<T>,
    cfg: &BatcherCfg,
    idle_tick: Option<Duration>,
) -> BatchPoll<T> {
    debug_assert!(cfg.batch > 0);
    let first = match idle_tick {
        None => match q.pop() {
            Pop::Item(t) => t,
            Pop::Closed => return BatchPoll::Closed,
            Pop::TimedOut => unreachable!("deadline-less pop cannot time out"),
        },
        Some(tick) => match q.pop_deadline(Instant::now() + tick) {
            Pop::Item(t) => t,
            Pop::Closed => return BatchPoll::Closed,
            Pop::TimedOut => return BatchPoll::Idle,
        },
    };
    let deadline = Instant::now() + cfg.budget;
    let mut out = Vec::with_capacity(cfg.batch);
    out.push(first);
    while out.len() < cfg.batch {
        match q.pop_deadline(deadline) {
            Pop::Item(t) => out.push(t),
            // deadline: ship what we have; closed: ship, the *next*
            // next_batch call picks up any remaining backlog until drained
            Pop::TimedOut | Pop::Closed => break,
        }
    }
    BatchPoll::Batch(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn cfg(batch: usize, budget_ms: u64) -> BatcherCfg {
        BatcherCfg { batch, budget: Duration::from_millis(budget_ms) }
    }

    #[test]
    fn full_batch_ships_without_waiting_for_the_deadline() {
        let q = BoundedQueue::new(8);
        for i in 0..4 {
            q.push(i).unwrap();
        }
        let t0 = Instant::now();
        let b = next_batch(&q, &cfg(4, 10_000)).unwrap();
        assert_eq!(b, vec![0, 1, 2, 3]);
        assert!(t0.elapsed() < Duration::from_secs(5), "must not sit out the budget");
    }

    #[test]
    fn partial_batch_flushes_at_deadline() {
        let q = BoundedQueue::new(8);
        q.push(41).unwrap();
        q.push(42).unwrap();
        let t0 = Instant::now();
        let b = next_batch(&q, &cfg(16, 30)).unwrap();
        assert_eq!(b, vec![41, 42], "ships what arrived, not a full batch");
        assert!(t0.elapsed() >= Duration::from_millis(30));
    }

    #[test]
    fn closed_drained_queue_terminates_the_batcher() {
        let q: BoundedQueue<u32> = BoundedQueue::new(4);
        q.close();
        assert!(next_batch(&q, &cfg(4, 5)).is_none());
    }

    #[test]
    fn idle_tick_reports_idle_then_batches_when_work_arrives() {
        let q: BoundedQueue<u32> = BoundedQueue::new(4);
        let tick = Some(Duration::from_millis(10));
        let t0 = Instant::now();
        assert!(matches!(next_batch_poll(&q, &cfg(4, 5), tick), BatchPoll::Idle));
        assert!(t0.elapsed() >= Duration::from_millis(10), "idle must wait out the tick");
        q.push(7).unwrap();
        match next_batch_poll(&q, &cfg(4, 5), tick) {
            BatchPoll::Batch(b) => assert_eq!(b, vec![7]),
            other => panic!("expected a batch, got {other:?}"),
        }
        q.close();
        assert!(matches!(next_batch_poll(&q, &cfg(4, 5), tick), BatchPoll::Closed));
    }

    #[test]
    fn close_with_backlog_still_yields_every_item() {
        let q = Arc::new(BoundedQueue::new(8));
        for i in 0..6 {
            q.push(i).unwrap();
        }
        q.close();
        let mut seen = Vec::new();
        while let Some(mut b) = next_batch(&q, &cfg(4, 5)) {
            seen.append(&mut b);
        }
        assert_eq!(seen, vec![0, 1, 2, 3, 4, 5]);
    }
}
