//! Dynamic batcher: coalesce single-image requests into engine-sized
//! batches under a latency budget.
//!
//! The policy is the standard two-trigger flush: a batch ships when it is
//! *full* (`batch` requests) or when the *deadline* — first request's
//! arrival plus `budget` — passes, whichever comes first.  A partial batch
//! therefore never waits for stragglers longer than the budget, and an
//! idle server burns no CPU (the wait for the batch's first request has no
//! deadline at all).

use std::time::{Duration, Instant};

use super::queue::{BoundedQueue, Pop};

/// Batcher knobs (`--batch`, `--latency-budget-us`).
#[derive(Debug, Clone, Copy)]
pub struct BatcherCfg {
    /// Flush when this many requests have coalesced.
    pub batch: usize,
    /// Flush a partial batch this long after its first request arrived.
    pub budget: Duration,
}

/// Block for the next batch: the first request opens the batch and starts
/// the budget clock; further requests join until the batch is full or the
/// deadline hits.  `None` means the queue is closed *and* drained — the
/// batcher's termination condition, guaranteeing every accepted request
/// was part of some returned batch.
pub fn next_batch<T>(q: &BoundedQueue<T>, cfg: &BatcherCfg) -> Option<Vec<T>> {
    debug_assert!(cfg.batch > 0);
    let first = match q.pop() {
        Pop::Item(t) => t,
        Pop::Closed => return None,
        Pop::TimedOut => unreachable!("deadline-less pop cannot time out"),
    };
    let deadline = Instant::now() + cfg.budget;
    let mut out = Vec::with_capacity(cfg.batch);
    out.push(first);
    while out.len() < cfg.batch {
        match q.pop_deadline(deadline) {
            Pop::Item(t) => out.push(t),
            // deadline: ship what we have; closed: ship, the *next*
            // next_batch call picks up any remaining backlog until drained
            Pop::TimedOut | Pop::Closed => break,
        }
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn cfg(batch: usize, budget_ms: u64) -> BatcherCfg {
        BatcherCfg { batch, budget: Duration::from_millis(budget_ms) }
    }

    #[test]
    fn full_batch_ships_without_waiting_for_the_deadline() {
        let q = BoundedQueue::new(8);
        for i in 0..4 {
            q.push(i).unwrap();
        }
        let t0 = Instant::now();
        let b = next_batch(&q, &cfg(4, 10_000)).unwrap();
        assert_eq!(b, vec![0, 1, 2, 3]);
        assert!(t0.elapsed() < Duration::from_secs(5), "must not sit out the budget");
    }

    #[test]
    fn partial_batch_flushes_at_deadline() {
        let q = BoundedQueue::new(8);
        q.push(41).unwrap();
        q.push(42).unwrap();
        let t0 = Instant::now();
        let b = next_batch(&q, &cfg(16, 30)).unwrap();
        assert_eq!(b, vec![41, 42], "ships what arrived, not a full batch");
        assert!(t0.elapsed() >= Duration::from_millis(30));
    }

    #[test]
    fn closed_drained_queue_terminates_the_batcher() {
        let q: BoundedQueue<u32> = BoundedQueue::new(4);
        q.close();
        assert!(next_batch(&q, &cfg(4, 5)).is_none());
    }

    #[test]
    fn close_with_backlog_still_yields_every_item() {
        let q = Arc::new(BoundedQueue::new(8));
        for i in 0..6 {
            q.push(i).unwrap();
        }
        q.close();
        let mut seen = Vec::new();
        while let Some(mut b) = next_batch(&q, &cfg(4, 5)) {
            seen.append(&mut b);
        }
        assert_eq!(seen, vec![0, 1, 2, 3, 4, 5]);
    }
}
