//! Chip farm + serving front-end: N simulated chip replicas behind the
//! dynamic batcher.
//!
//! Each [`Replica`] is a full inference stack — its own [`Network`] (and
//! thus its own lazily-warmed `EngineCache`), its own [`ChipModel`], its
//! own per-chip [`FaultProfile`] replica bound through
//! `EngineCache::set_faults_all`, and its own noise stream seeded from
//! `CounterRng::stream(chip_id)`.  Replicas share *nothing* mutable, which
//! is the replica-isolation contract the parity tests pin: a batch served
//! by chip `i` is bitwise what a standalone engine carrying chip `i`'s
//! fault replica would produce, whatever else the farm is doing.
//!
//! Dispatch rides the global worker pool's detached [`pool::submit`] seam:
//! one job per batch, one in-flight batch per replica (per-replica FIFO),
//! idle replicas found with the non-blocking `Ticket::is_complete` probe
//! and a round-robin fallback that bounds the wait when all are busy.

use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::util::error::Result;

use crate::chip::{ChipModel, FaultModel, FaultProfile};
use crate::config::Scheme;
use crate::nn::{ExecSpec, Network};
use crate::runtime::Manifest;
use crate::tensor::{ops, Tensor};
use crate::train::{network_from_ckpt, Checkpoint};
use crate::util::pool::{self, ScopedJob, Ticket};
use crate::util::rng::{CounterRng, Rng};

use super::batcher::{next_batch, BatcherCfg};
use super::queue::BoundedQueue;

/// Per-replica execution config, shared by every chip in the farm; the
/// replica index individualizes it (`FaultProfile::on_chip`, noise seed).
#[derive(Debug, Clone)]
pub struct ReplicaCfg {
    pub scheme: Scheme,
    pub unit_channels: usize,
    pub chip: ChipModel,
    /// Fault family: replica `i` carries `profile.on_chip(i)`.  `None`
    /// serves on pristine chips.
    pub faults: Option<FaultProfile>,
    /// Base seed of the farm's noise streams (replica `i` draws from
    /// `CounterRng::new(seed).stream(i)`).
    pub seed: u64,
}

impl Default for ReplicaCfg {
    fn default() -> Self {
        ReplicaCfg {
            scheme: Scheme::BitSerial,
            unit_channels: 8,
            chip: ChipModel::ideal(7),
            faults: None,
            seed: 0x5EED,
        }
    }
}

/// The answer to one request.
#[derive(Debug, Clone)]
pub struct Response {
    pub logits: Vec<f32>,
    /// Argmax class.
    pub class: usize,
    /// Which chip replica served this request.
    pub chip_id: u64,
    /// How many requests were coalesced into the batch that served it.
    pub batch_size: usize,
    /// Enqueue → response-ready.
    pub latency: Duration,
}

struct Oneshot {
    slot: Mutex<Option<Response>>,
    ready: Condvar,
}

/// Client-side completion handle of a submitted request.  The server's
/// shutdown path drains every accepted request, so `wait` always returns.
#[must_use = "a Pending that is never waited discards its Response"]
pub struct Pending {
    cell: Arc<Oneshot>,
}

impl Pending {
    /// Block until the request's response is ready.
    pub fn wait(self) -> Response {
        let mut g = self.cell.slot.lock().unwrap();
        loop {
            if let Some(r) = g.take() {
                return r;
            }
            g = self.cell.ready.wait(g).unwrap();
        }
    }
}

/// One queued inference request: a single [H, W, C] image.
pub struct Request {
    image: Tensor,
    enqueued: Instant,
    cell: Arc<Oneshot>,
}

impl Request {
    fn fulfill(self, mut resp: Response) {
        resp.latency = self.enqueued.elapsed();
        *self.cell.slot.lock().unwrap() = Some(resp);
        self.cell.ready.notify_all();
    }
}

/// One simulated chip: network + chip model + fault replica + noise
/// stream.  Usable standalone (the parity tests' reference path) or as a
/// farm member.
pub struct Replica {
    pub chip_id: u64,
    net: Network,
    chip: ChipModel,
    scheme: Scheme,
    unit_channels: usize,
    rng: Rng,
}

impl Replica {
    pub fn new(
        manifest: &Manifest,
        ckpt: &Checkpoint,
        cfg: &ReplicaCfg,
        chip_id: u64,
    ) -> Result<Replica> {
        let mut net = network_from_ckpt(manifest, ckpt)?;
        if let Some(profile) = cfg.faults {
            // bind the replica identity up front; EngineCache's default
            // carries it onto the engines the first forward will build
            let fm = FaultModel::new(profile.on_chip(chip_id)).at_step(0);
            let mut cache = net.take_engine_cache();
            cache.set_faults_all(Some(fm));
            net.set_engine_cache(cache);
        }
        let rng = Rng::new(CounterRng::new(cfg.seed).stream(chip_id).u64_at(0));
        Ok(Replica {
            chip_id,
            net,
            chip: cfg.chip.clone(),
            scheme: cfg.scheme,
            unit_channels: cfg.unit_channels,
            rng,
        })
    }

    /// Run one coalesced batch and fulfill every request in it.
    fn serve_batch(&mut self, reqs: Vec<Request>) {
        let b = reqs.len();
        let (h, w, c) = {
            let s = &reqs[0].image.shape;
            (s[0], s[1], s[2])
        };
        let mut x = Tensor::zeros(&[b, h, w, c]);
        let px = h * w * c;
        for (i, r) in reqs.iter().enumerate() {
            x.data[i * px..(i + 1) * px].copy_from_slice(&r.image.data);
        }
        let (logits, classes) = self.infer(&x);
        let preds = ops::argmax_rows(&logits);
        for (i, r) in reqs.into_iter().enumerate() {
            r.fulfill(Response {
                logits: logits.data[i * classes..(i + 1) * classes].to_vec(),
                class: preds[i],
                chip_id: self.chip_id,
                batch_size: b,
                latency: Duration::ZERO, // overwritten by fulfill
            });
        }
    }

    /// Forward a prepared [B, H, W, C] batch → (logits [B, classes],
    /// classes).  The reference path of the parity tests: one request at a
    /// time through here must match the farm's coalesced answer bitwise on
    /// a noiseless chip.
    pub fn infer(&mut self, x: &Tensor) -> (Tensor, usize) {
        let exec = ExecSpec::Pim {
            scheme: self.scheme,
            unit_channels: self.unit_channels,
            chip: &self.chip,
        };
        let logits = self.net.forward(x, &exec, &mut self.rng).expect("replica forward");
        let classes = logits.shape[1];
        (logits, classes)
    }

    /// Single-image convenience wrapper over [`Replica::infer`].
    pub fn infer_one(&mut self, image: &Tensor) -> Vec<f32> {
        let (h, w, c) = (image.shape[0], image.shape[1], image.shape[2]);
        let x = Tensor::from_vec(&[1, h, w, c], image.data.clone());
        let (logits, _) = self.infer(&x);
        logits.data
    }
}

struct Slot {
    state: Arc<Mutex<Replica>>,
    ticket: Option<Ticket>,
}

/// The chip farm: N replicas, each with at most one batch in flight on the
/// global worker pool.
pub struct Farm {
    slots: Vec<Slot>,
    rr: usize,
}

impl Farm {
    /// Build `replicas` chips from one checkpoint.  Replica `i` gets chip
    /// id `i`, fault replica `profile.on_chip(i)` and noise stream
    /// `CounterRng::new(seed).stream(i)`.
    pub fn new(
        manifest: &Manifest,
        ckpt: &Checkpoint,
        cfg: &ReplicaCfg,
        replicas: usize,
    ) -> Result<Farm> {
        assert!(replicas > 0, "a farm needs at least one replica");
        // one in-flight batch per replica: make sure the pool can actually
        // run them side by side instead of serializing on a smaller pool
        pool::reserve(replicas);
        let mut slots = Vec::with_capacity(replicas);
        for i in 0..replicas {
            let r = Replica::new(manifest, ckpt, cfg, i as u64)?;
            slots.push(Slot { state: Arc::new(Mutex::new(r)), ticket: None });
        }
        Ok(Farm { slots, rr: 0 })
    }

    pub fn replicas(&self) -> usize {
        self.slots.len()
    }

    /// Ship one batch to a replica: the first idle one at or after the
    /// round-robin cursor, else the cursor's replica (waiting for its
    /// previous batch first — per-replica FIFO, bounded wait).
    fn dispatch(&mut self, reqs: Vec<Request>) {
        if reqs.is_empty() {
            return;
        }
        let n = self.slots.len();
        let mut pick = self.rr;
        for off in 0..n {
            let i = (self.rr + off) % n;
            if self.slots[i].ticket.as_ref().map_or(true, |t| t.is_complete()) {
                pick = i;
                break;
            }
        }
        self.rr = (pick + 1) % n;
        let slot = &mut self.slots[pick];
        if let Some(t) = slot.ticket.take() {
            t.wait();
        }
        let state = Arc::clone(&slot.state);
        let job: ScopedJob<'static> = Box::new(move || {
            state.lock().unwrap().serve_batch(reqs);
        });
        slot.ticket = Some(pool::submit(vec![job]));
    }

    /// Wait out every in-flight batch (shutdown barrier).
    fn drain(&mut self) {
        for s in &mut self.slots {
            if let Some(t) = s.ticket.take() {
                t.wait();
            }
        }
    }
}

/// Serving-layer knobs (`pim-qat serve` flags).
#[derive(Debug, Clone, Copy)]
pub struct ServeCfg {
    /// Coalesce up to this many requests per dispatch.
    pub batch: usize,
    /// Flush a partial batch this long after its first request.
    pub latency_budget: Duration,
    /// Admission queue capacity (backpressure threshold).
    pub queue_cap: usize,
}

impl Default for ServeCfg {
    fn default() -> Self {
        ServeCfg {
            batch: 8,
            latency_budget: Duration::from_micros(2000),
            queue_cap: 64,
        }
    }
}

/// The running server: bounded queue + batcher thread + farm.
///
/// Shutdown discipline (tested): `shutdown` (or drop) closes the queue,
/// the batcher drains the backlog into final (possibly partial) batches,
/// waits out every replica ticket, and exits — every accepted request gets
/// its [`Response`], and the batcher thread is joined, not leaked.
pub struct FarmServer {
    queue: Arc<BoundedQueue<Request>>,
    batcher: Option<JoinHandle<()>>,
}

impl FarmServer {
    pub fn start(farm: Farm, cfg: ServeCfg) -> FarmServer {
        let queue = Arc::new(BoundedQueue::new(cfg.queue_cap));
        let q = Arc::clone(&queue);
        let bcfg = BatcherCfg { batch: cfg.batch.max(1), budget: cfg.latency_budget };
        let batcher = std::thread::Builder::new()
            .name("pim-qat-batcher".into())
            .spawn(move || {
                let mut farm = farm;
                while let Some(reqs) = next_batch(&q, &bcfg) {
                    farm.dispatch(reqs);
                }
                farm.drain();
            })
            .expect("spawn batcher thread");
        FarmServer { queue, batcher: Some(batcher) }
    }

    /// Submit one [H, W, C] image.  Blocks while the queue is at capacity
    /// (backpressure); `None` after shutdown began.
    pub fn submit(&self, image: Tensor) -> Option<Pending> {
        let cell = Arc::new(Oneshot { slot: Mutex::new(None), ready: Condvar::new() });
        let req = Request { image, enqueued: Instant::now(), cell: Arc::clone(&cell) };
        match self.queue.push(req) {
            Ok(()) => Some(Pending { cell }),
            Err(_rejected) => None,
        }
    }

    /// Requests admitted but not yet picked up by the batcher.
    pub fn backlog(&self) -> usize {
        self.queue.len()
    }

    /// Close admission, serve out everything accepted, join the batcher.
    /// Idempotent; also runs on drop.
    pub fn shutdown(&mut self) {
        self.queue.close();
        if let Some(h) = self.batcher.take() {
            h.join().expect("batcher thread panicked");
        }
    }
}

impl Drop for FarmServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}
