//! Chip farm + serving front-end: N simulated chip replicas behind the
//! dynamic batcher, with health monitoring, quarantine, hedging, and
//! request deadlines.
//!
//! Each [`Replica`] is a full inference stack — its own [`Network`] (and
//! thus its own lazily-warmed `EngineCache`), its own [`ChipModel`], its
//! own per-chip [`FaultProfile`] replica bound through
//! `EngineCache::set_faults_all`, and its own noise stream seeded from
//! `CounterRng::stream(chip_id)`.  Replicas share *nothing* mutable, which
//! is the replica-isolation contract the parity tests pin: a batch served
//! by chip `i` is bitwise what a standalone engine carrying chip `i`'s
//! fault replica would produce, whatever else the farm is doing.
//!
//! Dispatch rides the global worker pool's detached [`pool::submit`] seam:
//! one job per batch, one in-flight batch per replica (per-replica FIFO),
//! idle replicas found with the non-blocking `Ticket::is_complete` probe
//! and a round-robin fallback that bounds the wait when all are busy.
//! Replicas quarantined by the health monitor (`super::health`) drop out
//! of the rotation without touching their in-flight batch; backpressure is
//! unchanged (the bounded queue, not the replica count, is the admission
//! limit), so a farm running at N−1 replicas serves every accepted
//! request, just slower.
//!
//! Requests may carry a TTL: a request that would start service after its
//! deadline gets an explicit [`Reply::Timeout`] instead of a stale answer.
//! With hedging enabled, a batch whose replica exceeds the hedge budget is
//! re-submitted to a second idle replica and each request takes the first
//! answer that lands (first-wins).  Which replica wins is a race, but the
//! winning answer is still bitwise that replica's standalone answer under
//! the noiseless-chip contract — per-request `chip_id` records the winner,
//! so the parity invariant stays checkable under hedging.

use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::util::error::Result;

use crate::chip::{ChipModel, FaultModel, FaultProfile};
use crate::config::Scheme;
use crate::data::Dataset;
use crate::nn::{ExecSpec, Network};
use crate::runtime::Manifest;
use crate::tensor::{ops, Tensor};
use crate::train::{network_from_ckpt, recalibrate_network, Checkpoint};
use crate::util::pool::{self, ScopedJob, Ticket};
use crate::util::rng::{CounterRng, Rng};

use super::batcher::{next_batch_poll, BatchPoll, BatcherCfg};
use super::health::{probe_step, HealthMonitor, HealthShared, HealthSnapshot, ReplicaState};
use super::queue::BoundedQueue;

/// Per-replica execution config, shared by every chip in the farm; the
/// replica index individualizes it (`FaultProfile::on_chip`, noise seed).
#[derive(Debug, Clone)]
pub struct ReplicaCfg {
    pub scheme: Scheme,
    pub unit_channels: usize,
    pub chip: ChipModel,
    /// Fault family: replica `i` carries `profile.on_chip(i)`.  `None`
    /// serves on pristine chips.
    pub faults: Option<FaultProfile>,
    /// When set, only this chip id carries the fault replica — the
    /// one-injured-chip-in-a-healthy-farm scenario (`--fault-chip`).
    pub faults_only: Option<u64>,
    /// Base seed of the farm's noise streams (replica `i` draws from
    /// `CounterRng::new(seed).stream(i)`).
    pub seed: u64,
}

impl Default for ReplicaCfg {
    fn default() -> Self {
        ReplicaCfg {
            scheme: Scheme::BitSerial,
            unit_channels: 8,
            chip: ChipModel::ideal(7),
            faults: None,
            faults_only: None,
            seed: 0x5EED,
        }
    }
}

/// The answer to one request.
#[derive(Debug, Clone)]
pub struct Response {
    pub logits: Vec<f32>,
    /// Argmax class.
    pub class: usize,
    /// Which chip replica served this request.
    pub chip_id: u64,
    /// How many requests were coalesced into the batch that served it.
    pub batch_size: usize,
    /// Enqueue → response-ready.
    pub latency: Duration,
}

/// What a request resolved to.  Every accepted request resolves to exactly
/// one of these — including across shutdown, quarantine, and hedging.
#[derive(Debug, Clone)]
pub enum Reply {
    /// Served.
    Answer(Response),
    /// The request's TTL expired before service began; no stale answer.
    Timeout {
        /// Enqueue → expiry detection.
        waited: Duration,
    },
    /// The serving replica's forward pass failed.
    Failed { error: String },
}

impl Reply {
    /// The response, panicking on [`Reply::Timeout`] / [`Reply::Failed`] —
    /// the ergonomic accessor for clients that did not set a TTL (without
    /// one, every accepted request is answered or the farm panics loudly).
    pub fn answer(self) -> Response {
        match self {
            Reply::Answer(r) => r,
            Reply::Timeout { waited } => panic!("request timed out after {waited:?}"),
            Reply::Failed { error } => panic!("request failed: {error}"),
        }
    }

    pub fn is_answer(&self) -> bool {
        matches!(self, Reply::Answer(_))
    }

    pub fn is_timeout(&self) -> bool {
        matches!(self, Reply::Timeout { .. })
    }
}

struct Oneshot {
    slot: Mutex<Option<Reply>>,
    ready: Condvar,
}

/// Client-side completion handle of a submitted request.  The server's
/// shutdown path drains every accepted request, so `wait` always returns.
#[must_use = "a Pending that is never waited discards its Reply"]
pub struct Pending {
    cell: Arc<Oneshot>,
}

impl Pending {
    /// Block until the request resolves.
    pub fn wait(self) -> Reply {
        let mut g = self.cell.slot.lock().unwrap();
        loop {
            if let Some(r) = g.take() {
                return r;
            }
            g = self.cell.ready.wait(g).unwrap();
        }
    }

    /// [`Pending::wait`] with a client-side escape hatch: `None` after
    /// `patience` with no resolution — the wedged-farm failure mode
    /// (batcher thread dead with the request still queued), which the
    /// plain `wait` would turn into an eternal hang.  Consumes the handle
    /// either way; an abandoned request's eventual reply is discarded.
    pub fn wait_timeout(self, patience: Duration) -> Option<Reply> {
        let deadline = Instant::now() + patience;
        let mut g = self.cell.slot.lock().unwrap();
        loop {
            if let Some(r) = g.take() {
                return Some(r);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (g2, _timed_out) = self.cell.ready.wait_timeout(g, deadline - now).unwrap();
            g = g2;
        }
    }
}

/// One queued inference request: a single [H, W, C] image.
pub struct Request {
    image: Tensor,
    enqueued: Instant,
    /// TTL deadline; a request not yet in service by this point resolves
    /// to [`Reply::Timeout`].
    deadline: Option<Instant>,
    cell: Arc<Oneshot>,
}

impl Request {
    /// Resolve this request — first writer wins, later resolutions are
    /// dropped (the hedging contract: both replicas fulfill the same
    /// shared batch, each request keeps whichever answer landed first).
    fn complete(&self, reply: Reply) {
        let mut g = self.cell.slot.lock().unwrap();
        if g.is_none() {
            *g = Some(reply);
            self.cell.ready.notify_all();
        }
    }

    fn fulfill(&self, mut resp: Response) {
        resp.latency = self.enqueued.elapsed();
        self.complete(Reply::Answer(resp));
    }

    fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// Cheap per-batch observations handed to the health ledger.
#[derive(Debug, Clone)]
pub struct BatchStats {
    /// Requests in the batch.
    pub batch: usize,
    /// Mean |logit| over the batch (0 when the forward failed).
    pub mean_abs_logit: f64,
    /// Wall time of the forward pass.
    pub service: Duration,
    /// Forward failure, if any (every request got [`Reply::Failed`]).
    pub error: Option<String>,
}

/// One simulated chip: network + chip model + fault replica + noise
/// stream.  Usable standalone (the parity tests' reference path) or as a
/// farm member.
pub struct Replica {
    pub chip_id: u64,
    net: Network,
    chip: ChipModel,
    scheme: Scheme,
    unit_channels: usize,
    rng: Rng,
}

impl Replica {
    pub fn new(
        manifest: &Manifest,
        ckpt: &Checkpoint,
        cfg: &ReplicaCfg,
        chip_id: u64,
    ) -> Result<Replica> {
        let mut net = network_from_ckpt(manifest, ckpt)?;
        let injured = cfg.faults_only.is_none_or(|only| only == chip_id);
        if let Some(profile) = cfg.faults.filter(|_| injured) {
            // bind the replica identity up front; EngineCache's default
            // carries it onto the engines the first forward will build
            let fm = FaultModel::new(profile.on_chip(chip_id)).at_step(0);
            let mut cache = net.take_engine_cache();
            cache.set_faults_all(Some(fm));
            net.set_engine_cache(cache);
        }
        let rng = Rng::new(CounterRng::new(cfg.seed).stream(chip_id).u64_at(0));
        Ok(Replica {
            chip_id,
            net,
            chip: cfg.chip.clone(),
            scheme: cfg.scheme,
            unit_channels: cfg.unit_channels,
            rng,
        })
    }

    /// Run one coalesced batch, fulfill every request in it (first-wins —
    /// requests already answered by a hedge partner are left alone), and
    /// report the batch's health signals.  A forward failure resolves
    /// every request to [`Reply::Failed`] instead of panicking the worker.
    pub(super) fn serve_batch(&mut self, reqs: &[Request]) -> BatchStats {
        let b = reqs.len();
        let (h, w, c) = {
            let s = &reqs[0].image.shape;
            (s[0], s[1], s[2])
        };
        let mut x = Tensor::zeros(&[b, h, w, c]);
        let px = h * w * c;
        for (i, r) in reqs.iter().enumerate() {
            x.data[i * px..(i + 1) * px].copy_from_slice(&r.image.data);
        }
        let t0 = Instant::now();
        let (logits, classes) = match self.try_infer(&x) {
            Ok(out) => out,
            Err(e) => {
                let error = format!("chip {} forward failed: {e}", self.chip_id);
                for r in reqs {
                    r.complete(Reply::Failed { error: error.clone() });
                }
                return BatchStats {
                    batch: b,
                    mean_abs_logit: 0.0,
                    service: t0.elapsed(),
                    error: Some(error),
                };
            }
        };
        let service = t0.elapsed();
        let preds = ops::argmax_rows(&logits);
        for (i, r) in reqs.iter().enumerate() {
            r.fulfill(Response {
                logits: logits.data[i * classes..(i + 1) * classes].to_vec(),
                class: preds[i],
                chip_id: self.chip_id,
                batch_size: b,
                latency: Duration::ZERO, // overwritten by fulfill
            });
        }
        let mean_abs_logit = if logits.data.is_empty() {
            0.0
        } else {
            logits.data.iter().map(|v| v.abs() as f64).sum::<f64>() / logits.data.len() as f64
        };
        BatchStats { batch: b, mean_abs_logit, service, error: None }
    }

    /// Fallible forward of a prepared [B, H, W, C] batch → (logits
    /// [B, classes], classes) — the health monitor's probe entry point.
    pub fn try_infer(&mut self, x: &Tensor) -> Result<(Tensor, usize)> {
        let exec = ExecSpec::Pim {
            scheme: self.scheme,
            unit_channels: self.unit_channels,
            chip: &self.chip,
        };
        let logits = self.net.forward(x, &exec, &mut self.rng)?;
        let classes = logits.shape[1];
        Ok((logits, classes))
    }

    /// Forward a prepared [B, H, W, C] batch → (logits [B, classes],
    /// classes).  The reference path of the parity tests: one request at a
    /// time through here must match the farm's coalesced answer bitwise on
    /// a noiseless chip.
    pub fn infer(&mut self, x: &Tensor) -> (Tensor, usize) {
        self.try_infer(x).expect("replica forward")
    }

    /// Single-image convenience wrapper over [`Replica::infer`].
    pub fn infer_one(&mut self, image: &Tensor) -> Vec<f32> {
        let (h, w, c) = (image.shape[0], image.shape[1], image.shape[2]);
        let x = Tensor::from_vec(&[1, h, w, c], image.data.clone());
        let (logits, _) = self.infer(&x);
        logits.data
    }

    /// In-service BN recalibration (§3.4 / PR 6's self-tuning core):
    /// stream a held-out calibration shard through this replica's own —
    /// injured — engines and re-estimate the BN running statistics.  The
    /// engine cache's fault binding overrides the chip model, so the
    /// calibration sees exactly the degradation it must absorb.
    pub fn recalibrate(
        &mut self,
        calib: &Dataset,
        batch: usize,
        batches: usize,
        seed: u64,
    ) -> Result<()> {
        let mut rng = Rng::new(seed);
        recalibrate_network(
            &mut self.net,
            &self.chip,
            self.scheme,
            self.unit_channels,
            calib,
            batch,
            batches,
            &mut rng,
        )
    }
}

/// One batch on the pool, traceable for hedging.
struct InFlight {
    ticket: Ticket,
    since: Instant,
    /// The batch, shared so a hedge partner can serve the same requests.
    batch: Arc<Vec<Request>>,
    /// Already hedged (or is itself a hedge) — never hedged again.
    hedged: bool,
}

struct Slot {
    state: Arc<Mutex<Replica>>,
    inflight: Option<InFlight>,
    /// In-progress recalibration job (quarantined replicas only).
    recal: Option<Ticket>,
}

impl Slot {
    /// Free to take a new batch right now (no blocking work pending).
    fn idle(&self) -> bool {
        self.inflight.as_ref().is_none_or(|f| f.ticket.is_complete())
            && self.recal.as_ref().is_none_or(|t| t.is_complete())
    }
}

/// The chip farm: N replicas, each with at most one batch in flight on the
/// global worker pool, plus the optional health monitor and hedging.
pub struct Farm {
    slots: Vec<Slot>,
    rr: usize,
    /// Batches dispatched (primary only, not hedges) — the health probe
    /// cadence clock.
    dispatches: u64,
    /// Hedge a batch onto a second idle replica once its primary ticket
    /// is older than this.
    hedge_after: Option<Duration>,
    health: Option<HealthMonitor>,
}

impl Farm {
    /// Build `replicas` chips from one checkpoint.  Replica `i` gets chip
    /// id `i`, fault replica `profile.on_chip(i)` and noise stream
    /// `CounterRng::new(seed).stream(i)`.
    pub fn new(
        manifest: &Manifest,
        ckpt: &Checkpoint,
        cfg: &ReplicaCfg,
        replicas: usize,
    ) -> Result<Farm> {
        assert!(replicas > 0, "a farm needs at least one replica");
        // one in-flight batch per replica: make sure the pool can actually
        // run them side by side instead of serializing on a smaller pool
        pool::reserve_for(replicas, 1);
        let mut slots = Vec::with_capacity(replicas);
        for i in 0..replicas {
            let r = Replica::new(manifest, ckpt, cfg, i as u64)?;
            slots.push(Slot { state: Arc::new(Mutex::new(r)), inflight: None, recal: None });
        }
        Ok(Farm { slots, rr: 0, dispatches: 0, hedge_after: None, health: None })
    }

    pub fn replicas(&self) -> usize {
        self.slots.len()
    }

    /// Attach the health monitor (built by [`HealthMonitor::new`] for this
    /// farm's replica count).  One extra pool worker covers a concurrent
    /// recalibration job without starving the serving batches.
    pub fn attach_health(&mut self, monitor: HealthMonitor) {
        assert_eq!(
            monitor.shared.ledger.lock().unwrap().rows().len(),
            self.slots.len(),
            "health monitor sized for a different farm"
        );
        pool::reserve_for(self.slots.len() + 1, 1);
        self.health = Some(monitor);
    }

    /// The shared health state, for snapshots from outside the batcher
    /// thread (the server handle keeps one).
    pub fn health_shared(&self) -> Option<Arc<HealthShared>> {
        self.health.as_ref().map(|m| m.shared())
    }

    /// Which slots may receive dispatched batches right now.
    fn rotation_mask(&self) -> Vec<bool> {
        match &self.health {
            Some(m) => m.shared.ledger.lock().unwrap().rotation_mask(),
            None => vec![true; self.slots.len()],
        }
    }

    /// Ship one batch to a replica: the first *in-rotation* idle one at or
    /// after the round-robin cursor, else the first in-rotation one
    /// (waiting for its previous batch first — per-replica FIFO, bounded
    /// wait).  Requests whose TTL already expired resolve to
    /// [`Reply::Timeout`] here, before any chip time is spent on them.
    fn dispatch(&mut self, reqs: Vec<Request>) {
        let now = Instant::now();
        let (live, expired): (Vec<Request>, Vec<Request>) =
            reqs.into_iter().partition(|r| !r.expired(now));
        for r in expired {
            r.complete(Reply::Timeout { waited: r.enqueued.elapsed() });
        }
        if live.is_empty() {
            return;
        }
        let n = self.slots.len();
        let rotation = self.rotation_mask();
        let mut pick = None;
        for off in 0..n {
            let i = (self.rr + off) % n;
            if rotation[i] && self.slots[i].idle() {
                pick = Some(i);
                break;
            }
        }
        // all in-rotation replicas busy: queue behind the cursor's; if the
        // rotation is somehow empty (defensively — the monitor never
        // empties it), serve degraded on the cursor rather than hang
        let pick = pick
            .or_else(|| (0..n).map(|off| (self.rr + off) % n).find(|&i| rotation[i]))
            .unwrap_or(self.rr);
        self.rr = (pick + 1) % n;
        self.dispatches += 1;
        self.submit_to(pick, Arc::new(live), false);
    }

    /// Put `batch` on slot `i`'s replica (waiting out any previous ticket
    /// — per-replica FIFO).
    fn submit_to(&mut self, i: usize, batch: Arc<Vec<Request>>, hedged: bool) {
        let slot = &mut self.slots[i];
        if let Some(f) = slot.inflight.take() {
            f.ticket.wait();
        }
        if let Some(t) = slot.recal.take() {
            t.wait();
        }
        let state = Arc::clone(&slot.state);
        let chip = i as u64;
        let shared = self.health.as_ref().map(|m| m.shared());
        let jb = Arc::clone(&batch);
        let job: ScopedJob<'static> = Box::new(move || {
            let stats = state.lock().unwrap().serve_batch(&jb);
            if let Some(sh) = shared {
                sh.ledger.lock().unwrap().record_batch(chip, &stats);
            }
        });
        self.slots[i].inflight = Some(InFlight {
            ticket: pool::submit(vec![job]),
            since: Instant::now(),
            batch,
            hedged,
        });
    }

    /// Background work between batches: hedge overdue in-flight batches,
    /// then run the health monitor (harvest recalibrations, probe rounds).
    fn tick(&mut self) {
        self.hedge_tick();
        self.health_tick();
    }

    /// Re-submit any unhedged in-flight batch older than the hedge budget
    /// onto a second idle in-rotation replica.  First answer wins per
    /// request ([`Request::complete`]); each batch is hedged at most once.
    fn hedge_tick(&mut self) {
        let Some(after) = self.hedge_after else { return };
        let n = self.slots.len();
        if n < 2 {
            return;
        }
        let rotation = self.rotation_mask();
        for i in 0..n {
            let due = matches!(
                &self.slots[i].inflight,
                Some(f) if !f.hedged && !f.ticket.is_complete() && f.since.elapsed() >= after
            );
            if !due {
                continue;
            }
            let Some(j) = (0..n).find(|&j| j != i && rotation[j] && self.slots[j].idle()) else {
                continue;
            };
            let batch = {
                let f = self.slots[i].inflight.as_mut().expect("checked in-flight above");
                f.hedged = true;
                Arc::clone(&f.batch)
            };
            self.submit_to(j, batch, true);
        }
    }

    /// One round of the health monitor, on the batcher thread: harvest
    /// finished recalibration tickets, and — every `probe_every` dispatches
    /// or immediately for drift/error-flagged replicas — replay the shadow
    /// probe on the reference replica and every in-rotation replica, then
    /// run the quarantine state machine on the disagreement.
    fn health_tick(&mut self) {
        // take/restore so the monitor and the slots can be borrowed
        // together; nothing observes `self.health` while it is out
        let Some(mut mon) = self.health.take() else { return };
        self.run_health_tick(&mut mon);
        self.health = Some(mon);
    }

    fn run_health_tick(&mut self, mon: &mut HealthMonitor) {
        for s in &mut self.slots {
            if s.recal.as_ref().is_some_and(|t| t.is_complete()) {
                // wait() re-raises a panicked recalibration job
                s.recal.take().expect("checked above").wait();
            }
        }
        let due_cadence = mon.cfg.probe_every > 0
            && self.dispatches.saturating_sub(mon.last_probe) >= mon.cfg.probe_every;
        let flagged = mon.shared.ledger.lock().unwrap().any_flagged();
        if !due_cadence && !flagged {
            return;
        }
        mon.last_probe = self.dispatches;
        // fresh shadow replay on the designated reference replica (bitwise
        // the committed startup answers on a noiseless chip); fall back to
        // the committed copy if the reference itself cannot run
        let ref_classes = match mon.probe.replay(&mut mon.reference) {
            Ok(classes) => classes,
            Err(_) => mon.probe.ref_classes.clone(),
        };
        for i in 0..self.slots.len() {
            let chip = i as u64;
            let (state0, breaches0) = {
                let led = mon.shared.ledger.lock().unwrap();
                let row = &led.rows()[i];
                (row.state, row.breaches)
            };
            if !state0.in_rotation() {
                continue;
            }
            // the probe needs the replica quiescent: wait out its
            // in-flight batch (bounded — at most one batch, per-replica
            // FIFO), never a recalibration (not in rotation)
            if let Some(f) = self.slots[i].inflight.take() {
                f.ticket.wait();
            }
            let disagreement = {
                let mut rep = self.slots[i].state.lock().unwrap();
                mon.probe.disagreement_vs(&mut rep, &ref_classes)
            };
            let others_in_rotation = {
                let led = mon.shared.ledger.lock().unwrap();
                led.rows()
                    .iter()
                    .enumerate()
                    .filter(|(j, r)| *j != i && r.state.in_rotation())
                    .count()
            };
            let breach = disagreement > mon.cfg.quarantine_threshold;
            let (next, breaches) =
                probe_step(state0, breaches0, mon.cfg.quarantine_after, breach);
            let mut led = mon.shared.ledger.lock().unwrap();
            {
                let row = led.row_mut(chip);
                row.probes += 1;
                row.last_disagreement = Some(disagreement);
                row.breaches = breaches;
                row.flagged = false;
            }
            if next == ReplicaState::Quarantined && others_in_rotation == 0 {
                // never empty the rotation: hold at Suspect and re-probe
                // next round (recovery needs a serving farm to come back to)
                led.note(
                    chip,
                    &format!(
                        "quarantine deferred, last replica in rotation \
                         (disagreement {disagreement:.3})"
                    ),
                );
                led.row_mut(chip).state = ReplicaState::Suspect;
                continue;
            }
            if next != state0 {
                led.transition(chip, next, &format!("probe disagreement {disagreement:.3}"));
            }
            if next == ReplicaState::Quarantined {
                led.transition(chip, ReplicaState::Recalibrating, "recalibration scheduled");
                drop(led);
                let job = mon.recal_job(chip, Arc::clone(&self.slots[i].state));
                self.slots[i].recal = Some(pool::submit(vec![job]));
            }
        }
    }

    /// Wait out every in-flight batch and recalibration (shutdown barrier).
    fn drain(&mut self) {
        for s in &mut self.slots {
            if let Some(f) = s.inflight.take() {
                f.ticket.wait();
            }
            if let Some(t) = s.recal.take() {
                t.wait();
            }
        }
    }
}

/// Serving-layer knobs (`pim-qat serve` flags).
#[derive(Debug, Clone, Copy)]
pub struct ServeCfg {
    /// Coalesce up to this many requests per dispatch.
    pub batch: usize,
    /// Flush a partial batch this long after its first request.
    pub latency_budget: Duration,
    /// Admission queue capacity (backpressure threshold).
    pub queue_cap: usize,
    /// Hedge an in-flight batch onto a second idle replica after this long
    /// (`--hedge-after-us`); `None` disables hedging.
    pub hedge_after: Option<Duration>,
}

impl Default for ServeCfg {
    fn default() -> Self {
        ServeCfg {
            batch: 8,
            latency_budget: Duration::from_micros(2000),
            queue_cap: 64,
            hedge_after: None,
        }
    }
}

/// The running server: bounded queue + batcher thread + farm.
///
/// Shutdown discipline (tested): `shutdown` (or drop) closes the queue,
/// the batcher drains the backlog into final (possibly partial) batches,
/// waits out every replica ticket, and exits — every accepted request gets
/// its [`Reply`], and the batcher thread is joined, not leaked.
pub struct FarmServer {
    queue: Arc<BoundedQueue<Request>>,
    batcher: Option<JoinHandle<()>>,
    health: Option<Arc<HealthShared>>,
}

impl FarmServer {
    pub fn start(mut farm: Farm, cfg: ServeCfg) -> FarmServer {
        farm.hedge_after = cfg.hedge_after;
        let health = farm.health_shared();
        let queue = Arc::new(BoundedQueue::new(cfg.queue_cap));
        let q = Arc::clone(&queue);
        let bcfg = BatcherCfg { batch: cfg.batch.max(1), budget: cfg.latency_budget };
        // hedging and health probes need the serve loop to wake up while
        // idle; a plain pass-through server blocks on the queue instead
        let idle_tick = match (cfg.hedge_after, farm.health.is_some()) {
            (Some(h), _) => {
                Some((h / 4).clamp(Duration::from_micros(200), Duration::from_millis(5)))
            }
            (None, true) => Some(Duration::from_millis(2)),
            (None, false) => None,
        };
        let batcher = std::thread::Builder::new()
            .name("pim-qat-batcher".into())
            .spawn(move || {
                let mut farm = farm;
                loop {
                    match next_batch_poll(&q, &bcfg, idle_tick) {
                        BatchPoll::Batch(reqs) => {
                            farm.dispatch(reqs);
                            farm.tick();
                        }
                        BatchPoll::Idle => farm.tick(),
                        BatchPoll::Closed => break,
                    }
                }
                farm.drain();
            })
            .expect("spawn batcher thread");
        FarmServer { queue, batcher: Some(batcher), health }
    }

    /// Submit one [H, W, C] image.  Blocks while the queue is at capacity
    /// (backpressure); `None` after shutdown began.
    pub fn submit(&self, image: Tensor) -> Option<Pending> {
        self.submit_with_ttl(image, None)
    }

    /// [`FarmServer::submit`] with a TTL: if the request is still queued
    /// (not yet dispatched to a chip) when the TTL expires, it resolves to
    /// [`Reply::Timeout`] instead of being served stale.
    pub fn submit_with_ttl(&self, image: Tensor, ttl: Option<Duration>) -> Option<Pending> {
        let cell = Arc::new(Oneshot { slot: Mutex::new(None), ready: Condvar::new() });
        let now = Instant::now();
        let req = Request {
            image,
            enqueued: now,
            deadline: ttl.map(|t| now + t),
            cell: Arc::clone(&cell),
        };
        match self.queue.push(req) {
            Ok(()) => Some(Pending { cell }),
            Err(_rejected) => None,
        }
    }

    /// Requests admitted but not yet picked up by the batcher.
    pub fn backlog(&self) -> usize {
        self.queue.len()
    }

    /// Current health ledger state (`None` when serving without a
    /// monitor).  Live: may be called while the farm is serving.
    pub fn health_snapshot(&self) -> Option<HealthSnapshot> {
        self.health.as_ref().map(|h| h.ledger.lock().unwrap().snapshot())
    }

    /// Close admission, serve out everything accepted, join the batcher.
    /// Idempotent; also runs on drop.
    pub fn shutdown(&mut self) {
        self.queue.close();
        if let Some(h) = self.batcher.take() {
            h.join().expect("batcher thread panicked");
        }
    }
}

impl Drop for FarmServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}
