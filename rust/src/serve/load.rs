//! Synthetic open-loop load generator + latency ledger.
//!
//! Open-loop means arrivals follow a fixed schedule (one request every
//! `interarrival`), not the server's completion rate — the standard way to
//! surface queueing delay and tail latency.  When the bounded queue fills,
//! `submit` blocks and the generator degrades into a closed loop: the
//! backpressure contract, measured rather than hidden.
//!
//! Every wait goes through [`Pending::wait_timeout`] with a generous
//! patience budget: a wedged farm (batcher thread dead with requests still
//! queued) fails the run loudly instead of hanging the client forever.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::data::Dataset;

use super::farm::{FarmServer, Pending, Reply, Response};

/// Load-generator knobs.
#[derive(Debug, Clone, Copy)]
pub struct LoadCfg {
    /// Total requests to submit.
    pub requests: usize,
    /// Target gap between consecutive arrivals (zero = submit flat out).
    pub interarrival: Duration,
    /// Producer threads hammering the queue concurrently.
    pub producers: usize,
    /// Per-request TTL (`--ttl-us`); `None` = requests never expire.
    pub ttl: Option<Duration>,
    /// How long a producer waits on any single response before declaring
    /// the farm wedged and panicking (the loud-failure satellite; never a
    /// normal-operation path).
    pub give_up: Duration,
}

impl Default for LoadCfg {
    fn default() -> Self {
        LoadCfg {
            requests: 256,
            interarrival: Duration::ZERO,
            producers: 2,
            ttl: None,
            give_up: Duration::from_secs(60),
        }
    }
}

/// What the run measured.  Latency statistics cover *answered* requests
/// only; timeouts and failures are counted, not averaged in.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// Requests submitted (answered + timed out + failed).
    pub requests: usize,
    pub wall: Duration,
    /// Per-request enqueue→response latencies of answered requests,
    /// ascending.
    pub latencies: Vec<Duration>,
    /// Requests served per replica chip id (coalescing evidence).
    pub per_chip: Vec<(u64, usize)>,
    /// Mean coalesced batch size over answered requests.
    pub mean_batch: f64,
    /// Requests whose TTL expired while queued ([`Reply::Timeout`]).
    pub timeouts: usize,
    /// Requests resolved as [`Reply::Failed`].
    pub failures: usize,
}

impl LoadReport {
    pub fn qps(&self) -> f64 {
        self.requests as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    /// Latency at percentile `p` in [0, 100] (nearest-rank); `None` when
    /// no request was answered (e.g. every TTL expired) — the caller must
    /// not read a tail out of an empty distribution.
    pub fn percentile(&self, p: f64) -> Option<Duration> {
        if self.latencies.is_empty() {
            return None;
        }
        let n = self.latencies.len();
        let p = if p.is_finite() { p.clamp(0.0, 100.0) } else { 100.0 };
        let rank = ((p / 100.0) * n as f64).ceil().max(1.0) as usize;
        Some(self.latencies[rank.min(n) - 1])
    }

    /// Mean latency of answered requests; `None` when none were.
    pub fn mean_latency(&self) -> Option<Duration> {
        if self.latencies.is_empty() {
            return None;
        }
        Some(self.latencies.iter().sum::<Duration>() / self.latencies.len() as u32)
    }
}

/// Drive `server` with `cfg.requests` images cycled from `ds`, spread
/// round-robin over `cfg.producers` threads on one shared arrival
/// schedule, and wait out every response.
///
/// Panics if any response takes longer than `cfg.give_up` — the wedged
/// farm failure mode must be loud, not a hang.
pub fn run_open_loop(server: &FarmServer, ds: &Dataset, cfg: &LoadCfg) -> LoadReport {
    assert!(cfg.producers > 0 && cfg.requests > 0);
    let replies: Mutex<Vec<Reply>> = Mutex::new(Vec::with_capacity(cfg.requests));
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for p in 0..cfg.producers {
            let replies = &replies;
            s.spawn(move || {
                let mut got: Vec<Pending> = Vec::new();
                // producer p owns arrivals p, p+producers, ... of the
                // shared schedule: request q is due at t0 + q*interarrival
                for q in (p..cfg.requests).step_by(cfg.producers) {
                    let due = t0 + cfg.interarrival * q as u32;
                    if let Some(gap) = due.checked_duration_since(Instant::now()) {
                        std::thread::sleep(gap);
                    }
                    let img = ds.images[q % ds.len()].clone();
                    let pending =
                        server.submit_with_ttl(img, cfg.ttl).expect("server closed under load");
                    got.push(pending);
                }
                // waiting only at the end keeps the loop open (arrivals
                // never gate on completions; the bounded queue may)
                let mut out = replies.lock().unwrap();
                for pending in got {
                    match pending.wait_timeout(cfg.give_up) {
                        Some(reply) => out.push(reply),
                        None => panic!(
                            "farm wedged: no response within {:?} — batcher dead?",
                            cfg.give_up
                        ),
                    }
                }
            });
        }
    });
    let wall = t0.elapsed();
    let replies = replies.into_inner().unwrap();
    let total = replies.len();
    let mut timeouts = 0usize;
    let mut failures = 0usize;
    let mut responses: Vec<Response> = Vec::with_capacity(total);
    for r in replies {
        match r {
            Reply::Answer(resp) => responses.push(resp),
            Reply::Timeout { .. } => timeouts += 1,
            Reply::Failed { .. } => failures += 1,
        }
    }
    let mut latencies: Vec<Duration> = responses.iter().map(|r| r.latency).collect();
    latencies.sort();
    let mut per_chip: std::collections::BTreeMap<u64, usize> = Default::default();
    let mut batch_sum = 0usize;
    for r in &responses {
        *per_chip.entry(r.chip_id).or_default() += 1;
        batch_sum += r.batch_size;
    }
    LoadReport {
        requests: total,
        wall,
        latencies,
        per_chip: per_chip.into_iter().collect(),
        mean_batch: batch_sum as f64 / responses.len().max(1) as f64,
        timeouts,
        failures,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn empty_report() -> LoadReport {
        LoadReport {
            requests: 4,
            wall: Duration::from_millis(10),
            latencies: Vec::new(),
            per_chip: Vec::new(),
            mean_batch: 0.0,
            timeouts: 4,
            failures: 0,
        }
    }

    #[test]
    fn percentiles_of_zero_answered_requests_are_none_not_a_panic() {
        // the every-request-timed-out run: stats must degrade, not index
        // into an empty latency vector
        let rep = empty_report();
        assert_eq!(rep.percentile(50.0), None);
        assert_eq!(rep.percentile(99.0), None);
        assert_eq!(rep.mean_latency(), None);
        assert!(rep.qps() > 0.0, "throughput still well-defined");
    }

    #[test]
    fn percentile_is_nan_safe_and_clamped() {
        let rep = LoadReport {
            latencies: vec![
                Duration::from_micros(10),
                Duration::from_micros(20),
                Duration::from_micros(30),
            ],
            timeouts: 0,
            ..empty_report()
        };
        assert_eq!(rep.percentile(0.0), Some(Duration::from_micros(10)));
        assert_eq!(rep.percentile(50.0), Some(Duration::from_micros(20)));
        assert_eq!(rep.percentile(100.0), Some(Duration::from_micros(30)));
        // out-of-range and NaN degrade to the distribution's edges
        assert_eq!(rep.percentile(250.0), Some(Duration::from_micros(30)));
        assert_eq!(rep.percentile(-3.0), Some(Duration::from_micros(10)));
        assert_eq!(rep.percentile(f64::NAN), Some(Duration::from_micros(30)));
    }
}
