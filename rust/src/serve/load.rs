//! Synthetic open-loop load generator + latency ledger.
//!
//! Open-loop means arrivals follow a fixed schedule (one request every
//! `interarrival`), not the server's completion rate — the standard way to
//! surface queueing delay and tail latency.  When the bounded queue fills,
//! `submit` blocks and the generator degrades into a closed loop: the
//! backpressure contract, measured rather than hidden.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::data::Dataset;

use super::farm::{FarmServer, Response};

/// Load-generator knobs.
#[derive(Debug, Clone, Copy)]
pub struct LoadCfg {
    /// Total requests to submit.
    pub requests: usize,
    /// Target gap between consecutive arrivals (zero = submit flat out).
    pub interarrival: Duration,
    /// Producer threads hammering the queue concurrently.
    pub producers: usize,
}

impl Default for LoadCfg {
    fn default() -> Self {
        LoadCfg { requests: 256, interarrival: Duration::ZERO, producers: 2 }
    }
}

/// What the run measured.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub requests: usize,
    pub wall: Duration,
    /// Per-request enqueue→response latencies, ascending.
    pub latencies: Vec<Duration>,
    /// Requests served per replica chip id (coalescing evidence).
    pub per_chip: Vec<(u64, usize)>,
    /// Mean coalesced batch size over all responses.
    pub mean_batch: f64,
}

impl LoadReport {
    pub fn qps(&self) -> f64 {
        self.requests as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    /// Latency at percentile `p` in [0, 100] (nearest-rank).
    pub fn percentile(&self, p: f64) -> Duration {
        if self.latencies.is_empty() {
            return Duration::ZERO;
        }
        let n = self.latencies.len();
        let rank = ((p / 100.0) * n as f64).ceil().max(1.0) as usize;
        self.latencies[rank.min(n) - 1]
    }

    pub fn mean_latency(&self) -> Duration {
        if self.latencies.is_empty() {
            return Duration::ZERO;
        }
        self.latencies.iter().sum::<Duration>() / self.latencies.len() as u32
    }
}

/// Drive `server` with `cfg.requests` images cycled from `ds`, spread
/// round-robin over `cfg.producers` threads on one shared arrival
/// schedule, and wait out every response.
pub fn run_open_loop(server: &FarmServer, ds: &Dataset, cfg: &LoadCfg) -> LoadReport {
    assert!(cfg.producers > 0 && cfg.requests > 0);
    let responses: Mutex<Vec<Response>> = Mutex::new(Vec::with_capacity(cfg.requests));
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for p in 0..cfg.producers {
            let responses = &responses;
            s.spawn(move || {
                let mut got = Vec::new();
                // producer p owns arrivals p, p+producers, ... of the
                // shared schedule: request q is due at t0 + q*interarrival
                for q in (p..cfg.requests).step_by(cfg.producers) {
                    let due = t0 + cfg.interarrival * q as u32;
                    if let Some(gap) = due.checked_duration_since(Instant::now()) {
                        std::thread::sleep(gap);
                    }
                    let img = ds.images[q % ds.len()].clone();
                    let pending = server.submit(img).expect("server closed under load");
                    got.push(pending);
                }
                // waiting only at the end keeps the loop open (arrivals
                // never gate on completions; the bounded queue may)
                let mut out = responses.lock().unwrap();
                for pending in got {
                    out.push(pending.wait());
                }
            });
        }
    });
    let wall = t0.elapsed();
    let responses = responses.into_inner().unwrap();
    let mut latencies: Vec<Duration> = responses.iter().map(|r| r.latency).collect();
    latencies.sort();
    let mut per_chip: std::collections::BTreeMap<u64, usize> = Default::default();
    let mut batch_sum = 0usize;
    for r in &responses {
        *per_chip.entry(r.chip_id).or_default() += 1;
        batch_sum += r.batch_size;
    }
    LoadReport {
        requests: responses.len(),
        wall,
        latencies,
        per_chip: per_chip.into_iter().collect(),
        mean_batch: batch_sum as f64 / responses.len().max(1) as f64,
    }
}
