//! Hardware energy model — regenerates Table 1 (peak TOPS/W).
//!
//! The digital rows are the paper's cited numbers (V100 from Mujtaba 2017,
//! TPU from Jouppi et al. 2017, ReRAM PIM from Yao et al. 2020).  The SRAM
//! PIM row is *modeled*: per-MAC analog energy plus ADC conversion energy
//! amortized over the N MACs sharing one conversion, using standard
//! mixed-signal scaling (ADC energy ~ 4^b · E_conv_unit; Murmann's survey
//! figure-of-merit regime).  The model is calibrated so the paper's chip
//! configuration (N = 144 shared per conversion chain, b_PIM = 7) lands at
//! its reported 49.6 TOPS/W — and then lets the benches sweep N and b_PIM to
//! show the efficiency/accuracy trade-off the paper discusses (larger N →
//! more energy saving → more information loss).

/// Cited peak efficiencies (TOPS/W), Table 1.
pub const V100_TOPS_W: f64 = 0.1;
pub const TPU_TOPS_W: f64 = 2.3;
pub const RERAM_TOPS_W: f64 = 11.0;
pub const SRAM_PIM_TOPS_W: f64 = 49.6;

/// SRAM PIM energy model parameters (femtojoules).
#[derive(Debug, Clone, Copy)]
pub struct EnergyModel {
    /// Analog MAC energy per multiply-accumulate (fJ) — cap switching.
    pub e_mac_fj: f64,
    /// ADC conversion energy unit (fJ): E_adc = e_conv_unit · 4^b / 4^7,
    /// normalized so b=7 costs e_conv_unit.
    pub e_conv7_fj: f64,
    /// Digital recombination (shift-add) energy per output per plane (fJ).
    pub e_digital_fj: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        // Calibrated: pim_tops_w(N=144, b=7, planes=4) ≈ 49.6 (paper Table 1).
        EnergyModel { e_mac_fj: 1.1, e_conv7_fj: 5590.0, e_digital_fj: 60.0 }
    }
}

impl EnergyModel {
    /// Energy of one full PIM inner product over N MACs with `planes`
    /// conversions (bit-serial b_w=4, m=4 → 4 planes), in fJ.
    pub fn inner_product_fj(&self, n: usize, b_pim: u32, planes: usize) -> f64 {
        let e_adc = self.e_conv7_fj * 4f64.powi(b_pim as i32 - 7);
        planes as f64 * (n as f64 * self.e_mac_fj + e_adc + self.e_digital_fj)
    }

    /// Peak efficiency in TOPS/W (1 MAC = 2 ops).
    pub fn pim_tops_w(&self, n: usize, b_pim: u32, planes: usize) -> f64 {
        let ops = 2.0 * (n * planes) as f64;
        let joules = self.inner_product_fj(n, b_pim, planes) * 1e-15;
        ops / joules * 1e-12
    }
}

/// Table 1 rows: (hardware, TOPS/W, source).
pub fn table1() -> Vec<(&'static str, f64, &'static str)> {
    let m = EnergyModel::default();
    vec![
        ("V100 GPU", V100_TOPS_W, "cited (Mujtaba 2017)"),
        ("TPU", TPU_TOPS_W, "cited (Jouppi et al. 2017)"),
        ("ReRAM PIM", RERAM_TOPS_W, "cited (Yao et al. 2020)"),
        ("SRAM PIM (ours)", m.pim_tops_w(144, 7, 4), "energy model (calibrated)"),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibrated_to_paper() {
        let m = EnergyModel::default();
        let eff = m.pim_tops_w(144, 7, 4);
        assert!(
            (eff - SRAM_PIM_TOPS_W).abs() / SRAM_PIM_TOPS_W < 0.05,
            "model gives {eff}, paper reports {SRAM_PIM_TOPS_W}"
        );
    }

    #[test]
    fn larger_n_more_efficient() {
        // §2: "a larger N brings more energy savings"
        let m = EnergyModel::default();
        assert!(m.pim_tops_w(144, 7, 4) > m.pim_tops_w(72, 7, 4));
        assert!(m.pim_tops_w(72, 7, 4) > m.pim_tops_w(9, 7, 4));
    }

    #[test]
    fn higher_resolution_less_efficient() {
        let m = EnergyModel::default();
        assert!(m.pim_tops_w(144, 5, 4) > m.pim_tops_w(144, 8, 4));
    }

    #[test]
    fn pim_beats_digital_rows() {
        let rows = table1();
        let sram = rows.last().unwrap().1;
        assert!(sram > RERAM_TOPS_W && sram > TPU_TOPS_W && sram > V100_TOPS_W);
    }
}
