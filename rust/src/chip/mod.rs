//! Chip / ADC model substrate (S4): the "hardware-calibrated physical model"
//! the paper evaluates on (§A2.1), rebuilt from its published statistics.
//!
//! The prototype chip in the paper has 32 ADCs whose measured transfer
//! functions (Fig. A1) capture non-linearity and mismatch; thermal noise is
//! Gaussian with 0.35 LSB RMS; pre-calibration gain/offset variation is
//! gain ~ N(1, 0.024), offset ~ N(0, 2.04) LSB (Fig. A7).  We do not have
//! the silicon, so `curves::synthesize_bank` generates a 32-curve bank with
//! exactly those variation statistics plus smooth INL, and `ChipModel`
//! evaluates any plane sum through curve + noise — the same role the paper's
//! physical model plays.

pub mod curves;
pub mod energy;
pub mod enob;
pub mod faults;

pub use curves::{AdcCurve, CurveBank};
pub use faults::{ColumnFaults, FaultModel, FaultProfile};

use crate::util::rng::{CounterRng, Rng};

/// A complete PIM chip configuration for inference.
#[derive(Debug, Clone)]
pub struct ChipModel {
    /// ADC resolution b_PIM; the code grid is [0, 2^b - 1].
    pub b_pim: u32,
    /// Thermal-noise RMS in LSB (paper's chip: 0.35).
    pub noise_lsb: f32,
    /// One transfer curve per physical ADC; `None` = ideal quantizer.
    pub bank: Option<CurveBank>,
    /// Output channels served by one ADC (paper: unit output channel of 8).
    pub unit_out: usize,
    /// Injected degradation (None = healthy chip).  Engines may carry their
    /// own per-replica [`FaultModel`] which overrides this one.
    pub faults: Option<FaultModel>,
}

impl ChipModel {
    /// Perfectly linear, noiseless chip (training-time assumption).
    pub fn ideal(b_pim: u32) -> Self {
        ChipModel { b_pim, noise_lsb: 0.0, bank: None, unit_out: 8, faults: None }
    }

    /// The paper's real-chip setting: 7-bit, measured-curve bank, 0.35 LSB.
    pub fn real(seed: u64) -> Self {
        ChipModel {
            b_pim: 7,
            noise_lsb: 0.35,
            bank: Some(curves::synthesize_bank(7, 32, seed)),
            unit_out: 8,
            faults: None,
        }
    }

    pub fn with_noise(mut self, noise_lsb: f32) -> Self {
        self.noise_lsb = noise_lsb;
        self
    }

    /// Injure this chip with a fault profile (pinned at step 0; advance the
    /// drift/burst clock with [`ChipModel::at_step`]).
    pub fn with_faults(mut self, profile: FaultProfile) -> Self {
        self.faults = Some(FaultModel::new(profile));
        self
    }

    /// Advance the fault model's step clock (drift walk + burst windows).
    /// No-op on a healthy chip.
    pub fn at_step(mut self, step: u64) -> Self {
        if let Some(f) = self.faults {
            self.faults = Some(f.at_step(step));
        }
        self
    }

    pub fn levels(&self) -> f32 {
        ((1u32 << self.b_pim) - 1) as f32
    }

    /// Which curve converts output channel `oc`.
    pub fn curve_index(&self, oc: usize) -> usize {
        match &self.bank {
            Some(b) => (oc / self.unit_out) % b.curves.len(),
            None => 0,
        }
    }

    /// Convert one analog plane sum `s` (integer units, full-scale `fs`) to
    /// its dequantized value (integer units).  `signed` marks native-scheme
    /// conversions whose sums may be negative.
    #[inline]
    pub fn convert(&self, s: f32, fs: f32, oc: usize, signed: bool, rng: &mut Rng) -> f32 {
        let levels = self.levels();
        let lsb = fs / levels;
        let mut u = s / lsb; // ideal code, continuous
        if let Some(bank) = &self.bank {
            u = bank.curves[self.curve_index(oc)].distort(u, levels, signed);
        }
        if self.noise_lsb > 0.0 {
            u += rng.normal_in(0.0, self.noise_lsb);
        }
        let lo = if signed { -levels } else { 0.0 };
        let code = round_ties_even(u).clamp(lo, levels);
        code * lsb
    }
}

/// A conversion context prepared once per (layer, full-scale): hoists the
/// LSB constants, tabulates each curve's INL at integer codes (linear
/// interpolation between samples — the INL profile is a sum of ≤3 smooth
/// sinusoids, so sub-LSB sampling error is ~1e-3 LSB), and resolves the
/// per-output-column curve assignment once instead of per element.  §Perf
/// L3: removes the per-element sin() calls and curve-index modulo from the
/// hot loop (see EXPERIMENTS.md §Perf).
pub struct Converter<'a> {
    chip: &'a ChipModel,
    fs: f32,
    lsb: f32,
    inv_lsb: f32,
    levels: f32,
    /// Per-curve INL table sampled at codes 0..=levels (empty when ideal).
    inl_tables: Vec<Vec<f32>>,
    /// Curve index per output column (hoisted `curve_index`; empty when
    /// ideal).
    col_curve: Vec<u32>,
    /// Compiled per-column fault view (None = healthy conversion; the
    /// fault-free match arms below stay byte-for-byte what they were).
    faults: Option<ColumnFaults>,
}

impl<'a> Converter<'a> {
    /// `out` is the layer's output-column count; it sizes the per-column
    /// curve-assignment table.  Faults come from the chip's own model; use
    /// [`Converter::with_faults`] to override (per-engine replicas).
    pub fn new(chip: &'a ChipModel, fs: f32, out: usize) -> Self {
        let fm = chip.faults;
        Self::with_faults(chip, fs, out, fm.as_ref())
    }

    /// Build with an explicit fault model (which wins over `chip.faults`;
    /// pass `None` to force healthy conversion).
    pub fn with_faults(
        chip: &'a ChipModel,
        fs: f32,
        out: usize,
        faults: Option<&FaultModel>,
    ) -> Self {
        let levels = chip.levels();
        let (inl_tables, col_curve) = match &chip.bank {
            Some(bank) => (
                bank.curves
                    .iter()
                    .map(|c| {
                        (0..=levels as usize)
                            .map(|u| {
                                // INL component only (gain/offset exact)
                                let x = u as f32;
                                c.distort(x, levels, false) - c.gain * x - c.offset
                            })
                            .collect()
                    })
                    .collect(),
                (0..out).map(|o| chip.curve_index(o) as u32).collect(),
            ),
            None => (Vec::new(), Vec::new()),
        };
        Converter {
            chip,
            fs,
            lsb: fs / levels,
            inv_lsb: levels / fs,
            levels,
            inl_tables,
            col_curve,
            faults: faults.map(|f| f.column_faults(out)),
        }
    }

    /// Scalar conversion; bit-compatible with `ChipModel::convert` up to
    /// the tabulated-INL approximation.
    #[inline]
    pub fn convert(&self, s: f32, oc: usize, signed: bool, rng: &mut Rng) -> f32 {
        let mut u = s * self.inv_lsb;
        if self.chip.bank.is_some() {
            u = self.distort(u, oc);
        }
        if let Some(cf) = &self.faults {
            u = cf.gain[oc] * u + cf.offset[oc];
        }
        if self.chip.noise_lsb > 0.0 {
            let mult = self.faults.as_ref().map_or(1.0, |cf| cf.sigma_mult);
            u += rng.normal_in(0.0, self.chip.noise_lsb * mult);
        }
        let lo = if signed { -self.levels } else { 0.0 };
        let code = match self.faults.as_ref().map_or(0, |cf| cf.stuck[oc]) {
            1 => 0.0,
            2 => self.levels,
            _ => round_ties_even(u).clamp(lo, self.levels),
        };
        code * self.lsb
    }

    /// Curve distortion of a continuous ideal code (gain/offset exact,
    /// tabulated INL).  Caller must have checked `chip.bank.is_some()`.
    #[inline]
    fn distort(&self, u: f32, oc: usize) -> f32 {
        let bank = self.chip.bank.as_ref().unwrap();
        let ci = if self.col_curve.is_empty() {
            self.chip.curve_index(oc)
        } else {
            self.col_curve[oc] as usize
        };
        let c = &bank.curves[ci];
        let t = &self.inl_tables[ci];
        let x = u.abs().min(self.levels);
        let i = x as usize;
        let frac = x - i as f32;
        let inl = if i + 1 < t.len() {
            t[i] + (t[i + 1] - t[i]) * frac
        } else {
            t[t.len() - 1]
        };
        c.gain * u + c.offset + inl
    }

    /// Row-batched conversion (§Perf): dequantize one row of integer plane
    /// sums and accumulate `coef · adc(s)` into `y`.  `noise` carries the
    /// position-addressed stream for this row plus the noise std in LSB;
    /// draws are keyed by the output column, so results are independent of
    /// how rows are partitioned across threads.  Bit-compatible with the
    /// scalar `convert` path (identical arithmetic, hoisted constants).
    pub fn convert_row(
        &self,
        s: &[i32],
        signed: bool,
        coef: f32,
        noise: Option<(&CounterRng, f32)>,
        y: &mut [f32],
    ) {
        assert_eq!(s.len(), y.len());
        if let Some(cf) = &self.faults {
            return self.convert_row_faulty(cf, s, signed, coef, noise, y);
        }
        let levels = self.levels;
        let lo = if signed { -levels } else { 0.0 };
        let inv_lsb = self.inv_lsb;
        let lsb = self.lsb;
        let banked = self.chip.bank.is_some();
        match (banked, noise) {
            (false, None) => {
                for (&si, yv) in s.iter().zip(y.iter_mut()) {
                    let u = si as f32 * inv_lsb;
                    let code = round_ties_even(u).clamp(lo, levels);
                    *yv += coef * (code * lsb);
                }
            }
            (true, None) => {
                for (o, (&si, yv)) in s.iter().zip(y.iter_mut()).enumerate() {
                    let u = self.distort(si as f32 * inv_lsb, o);
                    let code = round_ties_even(u).clamp(lo, levels);
                    *yv += coef * (code * lsb);
                }
            }
            (false, Some((stream, sigma))) => {
                for (o, (&si, yv)) in s.iter().zip(y.iter_mut()).enumerate() {
                    let u = si as f32 * inv_lsb + sigma * stream.normal_at(o as u64) as f32;
                    let code = round_ties_even(u).clamp(lo, levels);
                    *yv += coef * (code * lsb);
                }
            }
            (true, Some((stream, sigma))) => {
                for (o, (&si, yv)) in s.iter().zip(y.iter_mut()).enumerate() {
                    let u = self.distort(si as f32 * inv_lsb, o)
                        + sigma * stream.normal_at(o as u64) as f32;
                    let code = round_ties_even(u).clamp(lo, levels);
                    *yv += coef * (code * lsb);
                }
            }
        }
    }

    /// The degraded twin of the match arms above: curve distortion, then
    /// per-column fault gain/offset, burst-scaled noise, and stuck-column
    /// pinning.  Noise draws stay keyed by output column, so faulty
    /// conversion keeps the any-thread-count bit-reproducibility contract.
    fn convert_row_faulty(
        &self,
        cf: &ColumnFaults,
        s: &[i32],
        signed: bool,
        coef: f32,
        noise: Option<(&CounterRng, f32)>,
        y: &mut [f32],
    ) {
        let levels = self.levels;
        let lo = if signed { -levels } else { 0.0 };
        let inv_lsb = self.inv_lsb;
        let lsb = self.lsb;
        let banked = self.chip.bank.is_some();
        for (o, (&si, yv)) in s.iter().zip(y.iter_mut()).enumerate() {
            let mut u = si as f32 * inv_lsb;
            if banked {
                u = self.distort(u, o);
            }
            u = cf.gain[o] * u + cf.offset[o];
            if let Some((stream, sigma)) = noise {
                u += sigma * cf.sigma_mult * stream.normal_at(o as u64) as f32;
            }
            let code = match cf.stuck[o] {
                1 => 0.0,
                2 => levels,
                _ => round_ties_even(u).clamp(lo, levels),
            };
            *yv += coef * (code * lsb);
        }
    }

    pub fn full_scale(&self) -> f32 {
        self.fs
    }
}

/// Banker's rounding, matching jnp.round / np.round so the ideal chip is
/// bit-identical to the python forward model.
#[inline]
pub fn round_ties_even(x: f32) -> f32 {
    let r = x.round(); // half-away-from-zero
    if (x - x.trunc()).abs() == 0.5 {
        // tie: pick the even neighbour
        let f = x.floor();
        if (f as i64) % 2 == 0 {
            f
        } else {
            f + 1.0
        }
    } else {
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ties_to_even() {
        assert_eq!(round_ties_even(0.5), 0.0);
        assert_eq!(round_ties_even(1.5), 2.0);
        assert_eq!(round_ties_even(2.5), 2.0);
        assert_eq!(round_ties_even(-0.5), -0.0);
        assert_eq!(round_ties_even(-1.5), -2.0);
        assert_eq!(round_ties_even(1.4), 1.0);
        assert_eq!(round_ties_even(-1.6), -2.0);
    }

    #[test]
    fn ideal_convert_is_quantizer() {
        let chip = ChipModel::ideal(3); // levels = 7
        let mut rng = Rng::new(0);
        // fs=70 → lsb=10; s=34 → code 3 (3.4 rounds to 3) → 30
        assert_eq!(chip.convert(34.0, 70.0, 0, false, &mut rng), 30.0);
        // exact grid point passes through
        assert_eq!(chip.convert(50.0, 70.0, 0, false, &mut rng), 50.0);
        // clamping at full scale
        assert_eq!(chip.convert(80.0, 70.0, 0, false, &mut rng), 70.0);
        // unsigned floor at 0
        assert_eq!(chip.convert(-5.0, 70.0, 0, false, &mut rng), 0.0);
    }

    #[test]
    fn signed_convert_for_native() {
        let chip = ChipModel::ideal(3);
        let mut rng = Rng::new(0);
        assert_eq!(chip.convert(-34.0, 70.0, 0, true, &mut rng), -30.0);
        assert_eq!(chip.convert(-90.0, 70.0, 0, true, &mut rng), -70.0);
    }

    #[test]
    fn noise_perturbs_codes() {
        let chip = ChipModel::ideal(7).with_noise(0.35);
        let mut rng = Rng::new(1);
        let mut diff = 0;
        for i in 0..200 {
            let s = 10.0 * i as f32;
            let y = chip.convert(s, 2160.0, 0, false, &mut rng);
            let y0 = ChipModel::ideal(7).convert(s, 2160.0, 0, false, &mut rng);
            if y != y0 {
                diff += 1;
            }
        }
        assert!(diff > 20, "noise should flip some codes, flipped {diff}");
    }

    #[test]
    fn convert_row_matches_scalar() {
        let mut rng = Rng::new(0);
        for chip in [ChipModel::ideal(5), ChipModel::real(7).with_noise(0.0)] {
            let out = 40;
            let conv = Converter::new(&chip, 2160.0, out);
            for signed in [false, true] {
                let s: Vec<i32> = (0..out as i32).map(|o| (o * 137) % 2300 - 600).collect();
                let mut y = vec![0.0f32; out];
                conv.convert_row(&s, signed, 2.0, None, &mut y);
                for o in 0..out {
                    let want = 2.0 * conv.convert(s[o] as f32, o, signed, &mut rng);
                    assert_eq!(y[o], want, "col {o} signed={signed}");
                }
            }
        }
    }

    #[test]
    fn convert_row_noise_is_positional() {
        let chip = ChipModel::ideal(7).with_noise(0.5);
        let out = 64;
        let conv = Converter::new(&chip, 2160.0, out);
        let field = CounterRng::new(9);
        let s: Vec<i32> = (0..out as i32).map(|i| i * 30).collect();
        let st = field.stream3(0, 1, 2);
        let mut y1 = vec![0.0f32; out];
        let mut y2 = vec![0.0f32; out];
        conv.convert_row(&s, false, 1.0, Some((&st, chip.noise_lsb)), &mut y1);
        conv.convert_row(&s, false, 1.0, Some((&st, chip.noise_lsb)), &mut y2);
        assert_eq!(y1, y2, "same position, same noise draws");
        let st2 = field.stream3(0, 1, 3);
        let mut y3 = vec![0.0f32; out];
        conv.convert_row(&s, false, 1.0, Some((&st2, chip.noise_lsb)), &mut y3);
        assert_ne!(y1, y3, "different row stream, different draws");
    }

    #[test]
    fn faulty_convert_row_matches_scalar() {
        let mut rng = Rng::new(0);
        let chip = ChipModel::real(7)
            .with_noise(0.0)
            .with_faults(FaultProfile::severe().on_chip(3))
            .at_step(5);
        let out = 40;
        let conv = Converter::new(&chip, 2160.0, out);
        for signed in [false, true] {
            let s: Vec<i32> = (0..out as i32).map(|o| (o * 137) % 2300 - 600).collect();
            let mut y = vec![0.0f32; out];
            conv.convert_row(&s, signed, 2.0, None, &mut y);
            for o in 0..out {
                let want = 2.0 * conv.convert(s[o] as f32, o, signed, &mut rng);
                assert_eq!(y[o], want, "col {o} signed={signed}");
            }
        }
    }

    #[test]
    fn none_faults_convert_identically_to_healthy() {
        let healthy = ChipModel::real(4);
        let injured = ChipModel::real(4).with_faults(FaultProfile::none());
        let out = 32;
        let ch = Converter::new(&healthy, 2160.0, out);
        let ci = Converter::new(&injured, 2160.0, out);
        let s: Vec<i32> = (0..out as i32).map(|i| i * 60 - 900).collect();
        let (mut y1, mut y2) = (vec![0.0f32; out], vec![0.0f32; out]);
        ch.convert_row(&s, true, 1.0, None, &mut y1);
        ci.convert_row(&s, true, 1.0, None, &mut y2);
        assert_eq!(y1, y2, "all-zero fault profile must be a no-op");
    }

    #[test]
    fn stuck_columns_pin_output() {
        let mut p = FaultProfile::none();
        p.stuck_rate = 0.3;
        let chip = ChipModel::ideal(5).with_faults(p);
        let out = 64;
        let cf = chip.faults.unwrap().column_faults(out);
        let conv = Converter::new(&chip, 310.0, out);
        let s: Vec<i32> = vec![150; out];
        let mut y = vec![0.0f32; out];
        conv.convert_row(&s, false, 1.0, None, &mut y);
        let lsb = 310.0 / 31.0;
        let mut pinned = 0;
        for o in 0..out {
            match cf.stuck[o] {
                1 => {
                    assert_eq!(y[o], 0.0, "col {o} must be stuck at zero");
                    pinned += 1;
                }
                2 => {
                    assert_eq!(y[o], 31.0 * lsb, "col {o} must be stuck at full-scale");
                    pinned += 1;
                }
                _ => assert_eq!(y[o], round_ties_even(150.0 / lsb) * lsb),
            }
        }
        assert!(pinned > 0, "stuck_rate 0.3 over 64 columns must pin some");
    }

    #[test]
    fn burst_scales_noise_draws() {
        let mut p = FaultProfile::none();
        p.burst_rate = 1.0; // every window bursts
        p.burst_window = 1;
        p.burst_sigma_mult = 50.0;
        let quiet = ChipModel::ideal(7).with_noise(0.05);
        let loud = quiet.clone().with_faults(p);
        let out = 128;
        let cq = Converter::new(&quiet, 2160.0, out);
        let cl = Converter::new(&loud, 2160.0, out);
        let field = CounterRng::new(3);
        let st = field.stream3(0, 0, 0);
        let s: Vec<i32> = (0..out as i32).map(|i| i * 15).collect();
        let (mut yq, mut yl) = (vec![0.0f32; out], vec![0.0f32; out]);
        cq.convert_row(&s, false, 1.0, Some((&st, 0.05)), &mut yq);
        cl.convert_row(&s, false, 1.0, Some((&st, 0.05)), &mut yl);
        let spread = |y: &[f32], s: &[i32]| -> f32 {
            y.iter()
                .zip(s)
                .map(|(&v, &si)| (v - si as f32).abs())
                .sum::<f32>()
        };
        assert!(
            spread(&yl, &s) > 4.0 * spread(&yq, &s),
            "burst σ×50 must visibly widen the code error: quiet {} loud {}",
            spread(&yq, &s),
            spread(&yl, &s)
        );
    }

    #[test]
    fn curve_assignment_unit_out() {
        let chip = ChipModel::real(0);
        assert_eq!(chip.curve_index(0), 0);
        assert_eq!(chip.curve_index(7), 0);
        assert_eq!(chip.curve_index(8), 1);
        assert_eq!(chip.curve_index(8 * 32), 0); // wraps around the bank
    }
}
