//! ADC transfer-curve synthesis and serialization.
//!
//! The paper measures 32 transfer functions on its prototype (Fig. A1) and
//! reports their variation statistics; absent the silicon we synthesize a
//! bank with the same statistics: per-curve gain ~ N(1, σ_gain), offset ~
//! N(0, σ_off) LSB, plus a smooth integral-non-linearity profile built from
//! a few random low-order sinusoids (the classic INL shape of SAR/flash
//! ADCs).  Banks serialize to JSON so an experiment can pin the exact
//! hardware instance it evaluated on.

use crate::util::json::Json;
use crate::util::rng::Rng;

/// One ADC's transfer function: code_out(u) = gain·u + offset + INL(u).
#[derive(Debug, Clone, PartialEq)]
pub struct AdcCurve {
    pub gain: f32,
    /// Offset in LSB.
    pub offset: f32,
    /// Sinusoid INL components: (amplitude_lsb, cycles, phase).
    pub inl: Vec<(f32, f32, f32)>,
}

impl AdcCurve {
    pub fn ideal() -> Self {
        AdcCurve { gain: 1.0, offset: 0.0, inl: Vec::new() }
    }

    /// Distort a continuous ideal code `u` (in [0, levels], or [-levels,
    /// levels] when `signed`).  The INL profile is evaluated on |u| so the
    /// signed (native) case sees a symmetric characteristic, as a
    /// differential ADC would.
    #[inline]
    pub fn distort(&self, u: f32, levels: f32, signed: bool) -> f32 {
        let mut v = self.gain * u + self.offset;
        let x = if signed { u.abs() } else { u };
        let t = (x / levels).clamp(0.0, 1.0);
        for &(a, cycles, phase) in &self.inl {
            v += a * (std::f32::consts::PI * cycles * t + phase).sin();
        }
        v
    }

    /// Peak INL magnitude in LSB (analytic upper bound).
    pub fn inl_bound(&self) -> f32 {
        self.inl.iter().map(|&(a, _, _)| a.abs()).sum()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("gain", Json::num(self.gain as f64)),
            ("offset", Json::num(self.offset as f64)),
            (
                "inl",
                Json::Arr(
                    self.inl
                        .iter()
                        .map(|&(a, c, p)| Json::f32s(&[a, c, p]))
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Option<Self> {
        let inl = j
            .get("inl")
            .as_arr()?
            .iter()
            .filter_map(|e| {
                let v = e.as_f32_vec()?;
                Some((v[0], v[1], v[2]))
            })
            .collect();
        Some(AdcCurve {
            gain: j.get("gain").as_f64()? as f32,
            offset: j.get("offset").as_f64()? as f32,
            inl,
        })
    }
}

/// A bank of per-ADC curves (the chip's 32 converters).
#[derive(Debug, Clone, PartialEq)]
pub struct CurveBank {
    pub b_pim: u32,
    pub curves: Vec<AdcCurve>,
}

/// Variation statistics. Defaults are the paper's measured values (§A2.1,
/// Fig. A7): noise is configured separately on `ChipModel`.
#[derive(Debug, Clone, Copy)]
pub struct CurveStats {
    pub gain_std: f32,
    pub offset_std_lsb: f32,
    /// Target peak INL in LSB (smooth non-linearity, Fig. A1's curvature).
    pub inl_peak_lsb: f32,
}

impl Default for CurveStats {
    fn default() -> Self {
        // calibrated-chip regime: small residual gain/offset error, ~1 LSB INL
        CurveStats { gain_std: 0.004, offset_std_lsb: 0.3, inl_peak_lsb: 1.0 }
    }
}

impl CurveStats {
    /// Pre-calibration variation measured on the real chip (Fig. A7):
    /// offset ~ N(0, 2.04) LSB, gain ~ N(1, 0.024).
    pub fn uncalibrated() -> Self {
        CurveStats { gain_std: 0.024, offset_std_lsb: 2.04, inl_peak_lsb: 1.0 }
    }
}

/// Synthesize a bank of `n` curves with the calibrated-chip statistics.
pub fn synthesize_bank(b_pim: u32, n: usize, seed: u64) -> CurveBank {
    synthesize_bank_with(b_pim, n, seed, CurveStats::default())
}

/// Synthesize with explicit statistics (Fig. A7 uses `uncalibrated()`).
pub fn synthesize_bank_with(b_pim: u32, n: usize, seed: u64, st: CurveStats) -> CurveBank {
    let mut rng = Rng::new(seed ^ 0xC0FFEE);
    let curves = (0..n)
        .map(|_| {
            let n_comp = 3;
            let mut inl = Vec::with_capacity(n_comp);
            // distribute the peak budget across components
            for c in 0..n_comp {
                let amp = rng.normal_in(0.0, st.inl_peak_lsb / (n_comp as f32).sqrt() / 2.0);
                let cycles = (c + 1) as f32 + rng.uniform_in(-0.3, 0.3);
                let phase = rng.uniform_in(0.0, std::f32::consts::TAU);
                inl.push((amp, cycles, phase));
            }
            AdcCurve {
                gain: rng.normal_in(1.0, st.gain_std),
                offset: rng.normal_in(0.0, st.offset_std_lsb),
                inl,
            }
        })
        .collect();
    CurveBank { b_pim, curves }
}

impl CurveBank {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("b_pim", Json::num(self.b_pim as f64)),
            ("curves", Json::Arr(self.curves.iter().map(|c| c.to_json()).collect())),
        ])
    }

    pub fn from_json(j: &Json) -> Option<Self> {
        Some(CurveBank {
            b_pim: j.get("b_pim").as_i64()? as u32,
            curves: j
                .get("curves")
                .as_arr()?
                .iter()
                .filter_map(AdcCurve::from_json)
                .collect(),
        })
    }

    pub fn save(&self, path: &std::path::Path) -> crate::util::error::Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> crate::util::error::Result<Self> {
        let j = crate::util::json::parse_file(path)?;
        Self::from_json(&j).ok_or_else(|| crate::anyhow!("malformed curve bank"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bank_statistics_match_request() {
        let st = CurveStats::uncalibrated();
        let bank = synthesize_bank_with(7, 256, 42, st);
        let gains: Vec<f32> = bank.curves.iter().map(|c| c.gain).collect();
        let offs: Vec<f32> = bank.curves.iter().map(|c| c.offset).collect();
        let mean = |v: &[f32]| v.iter().sum::<f32>() / v.len() as f32;
        let std = |v: &[f32]| {
            let m = mean(v);
            (v.iter().map(|x| (x - m) * (x - m)).sum::<f32>() / v.len() as f32).sqrt()
        };
        assert!((mean(&gains) - 1.0).abs() < 0.01, "gain mean {}", mean(&gains));
        assert!((std(&gains) - st.gain_std).abs() < 0.008);
        assert!(mean(&offs).abs() < 0.5);
        assert!((std(&offs) - st.offset_std_lsb).abs() < 0.5);
    }

    #[test]
    fn ideal_curve_is_identity() {
        let c = AdcCurve::ideal();
        for u in [0.0, 13.7, 127.0] {
            assert_eq!(c.distort(u, 127.0, false), u);
        }
    }

    #[test]
    fn distortion_is_bounded() {
        let bank = synthesize_bank(7, 32, 7);
        for c in &bank.curves {
            for i in 0..=127 {
                let u = i as f32;
                let d = (c.distort(u, 127.0, false) - u).abs();
                let bound = c.inl_bound() + c.offset.abs() + (c.gain - 1.0).abs() * 127.0 + 1e-3;
                assert!(d <= bound, "d={d} bound={bound}");
            }
        }
    }

    #[test]
    fn signed_symmetric_inl() {
        let bank = synthesize_bank(5, 1, 3);
        let c = &bank.curves[0];
        // INL component of distort(u) - gain*u - offset must be even in u
        let f = |u: f32| c.distort(u, 31.0, true) - c.gain * u - c.offset;
        assert!((f(10.0) - f(-10.0)).abs() < 1e-5);
    }

    #[test]
    fn json_roundtrip() {
        let bank = synthesize_bank(7, 4, 11);
        let j = bank.to_json().to_string();
        let back = CurveBank::from_json(&crate::util::json::parse(&j).unwrap()).unwrap();
        assert_eq!(bank.b_pim, back.b_pim);
        assert_eq!(bank.curves.len(), back.curves.len());
        for (a, b) in bank.curves.iter().zip(&back.curves) {
            assert!((a.gain - b.gain).abs() < 1e-6);
            assert!((a.offset - b.offset).abs() < 1e-6);
        }
    }

    #[test]
    fn deterministic_by_seed() {
        assert_eq!(synthesize_bank(7, 8, 5), synthesize_bank(7, 8, 5));
        assert_ne!(synthesize_bank(7, 8, 5), synthesize_bank(7, 8, 6));
    }
}
