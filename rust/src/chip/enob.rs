//! Effective number of bits (ENOB) analysis — the machinery behind Fig. 3
//! and the adjusted-precision-training rule of §3.5.
//!
//! Fig. 3 plots the std of MAC computing errors of the 7-bit chip as a
//! function of injected noise, normalized by the noiseless quantization
//! error std, and marks where it crosses the error of ideal lower-bit
//! systems.  `error_std_ratio` reproduces the measurement; `enob` converts a
//! noise level into the equivalent ideal resolution.

use super::ChipModel;
use crate::util::rng::Rng;
use crate::util::Welford;

/// Monte-Carlo std of (converted − analog) error, in LSB of the chip's own
/// grid, over uniformly random plane sums (the §A2.2 protocol).
pub fn error_std_lsb(chip: &ChipModel, fs: f32, samples: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let mut noise_rng = rng.fork(1);
    let lsb = fs / chip.levels();
    let mut w = Welford::default();
    for _ in 0..samples {
        let s = rng.uniform_in(0.0, fs);
        let y = chip.convert(s, fs, 0, false, &mut noise_rng);
        w.push(((y - s) / lsb) as f64);
    }
    w.std()
}

/// Fig. 3's y-axis: error std with noise σ, normalized by the noiseless
/// quantization error std of the same chip.
pub fn error_std_ratio(b_pim: u32, noise_lsb: f32, samples: usize, seed: u64) -> f64 {
    let fs = 2160.0; // N=144 bit-serial full scale; ratio is fs-invariant
    let noisy = error_std_lsb(&ChipModel::ideal(b_pim).with_noise(noise_lsb), fs, samples, seed);
    let clean = error_std_lsb(&ChipModel::ideal(b_pim), fs, samples, seed);
    noisy / clean
}

/// Ideal-quantizer error std is LSB/√12; a b-bit system with extra Gaussian
/// noise σ (in LSB) has error std ≈ √(1/12 + σ²)·LSB.  The equivalent ideal
/// resolution ("ENOB") solves  LSB(b')/√12 = that:
///     2^{b'} − 1 = (2^b − 1) / √(1 + 12σ²)
pub fn enob(b_pim: u32, noise_lsb: f32) -> f64 {
    let levels = ((1u32 << b_pim) - 1) as f64;
    let eff_levels = levels / (1.0 + 12.0 * (noise_lsb as f64).powi(2)).sqrt();
    (eff_levels + 1.0).log2()
}

/// The adjusted-precision-training rule (§3.5): train at the resolution
/// closest to the chip's effective resolution, never above b_pim.
pub fn suggested_training_resolution(b_pim: u32, noise_lsb: f32) -> u32 {
    (enob(b_pim, noise_lsb).round() as u32).clamp(2, b_pim)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noiseless_error_is_quantization_error() {
        // std of uniform quantization error = 1/sqrt(12) LSB ≈ 0.2887
        let e = error_std_lsb(&ChipModel::ideal(7), 2160.0, 200_000, 3);
        assert!((e - 1.0 / 12f64.sqrt()).abs() < 0.01, "{e}");
    }

    #[test]
    fn ratio_grows_with_noise_and_matches_model() {
        let mut prev = 0.0;
        for &(sigma, expect) in &[(0.0f32, 1.0f64), (0.35, (1.0 + 12.0 * 0.1225f64).sqrt()), (1.0, 13f64.sqrt())] {
            let r = error_std_ratio(7, sigma, 150_000, 5);
            assert!(r > prev - 1e-9);
            // clamping at the rails slightly shrinks the measured std; allow 10%
            assert!((r - expect).abs() / expect < 0.1, "σ={sigma}: {r} vs {expect}");
            prev = r;
        }
    }

    #[test]
    fn enob_limits() {
        assert!((enob(7, 0.0) - 7.0).abs() < 0.01);
        assert!(enob(7, 0.35) < 7.0);
        assert!(enob(7, 0.35) > 6.0);
        assert!(enob(7, 2.0) < 5.0);
    }

    #[test]
    fn training_resolution_rule() {
        // low noise: train at inference resolution (paper Fig. 4, bottom rows)
        assert_eq!(suggested_training_resolution(7, 0.0), 7);
        assert_eq!(suggested_training_resolution(5, 0.1), 5);
        // heavy noise: drop training resolution
        assert!(suggested_training_resolution(7, 2.0) < 7);
        // never above b_pim, never below 2
        assert!(suggested_training_resolution(3, 5.0) >= 2);
    }

    #[test]
    fn higher_resolution_more_noise_sensitive() {
        // Fig. 4's observation: the noise threshold where ENOB drops a full
        // bit comes earlier (in LSB) for higher inference resolutions when
        // measured on the absolute scale of the output.  In LSB units the
        // ENOB loss is resolution-independent; verify the absolute-scale
        // claim: at fixed *absolute* noise, higher-b chips lose more bits.
        let fs = 2160.0;
        let abs_noise = 10.0; // integer units
        let loss = |b: u32| {
            let lsb = fs / ((1u32 << b) - 1) as f32;
            b as f64 - enob(b, abs_noise / lsb)
        };
        assert!(loss(8) > loss(5));
    }
}
