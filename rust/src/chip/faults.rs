//! Chip fault injection: device-to-device variability, cycle-to-cycle
//! drift, stuck-at columns, and transient noise bursts.
//!
//! The curve bank (`chip/curves.rs`) models a *healthy* chip's static ADC
//! non-idealities.  This module layers *degradation* on top: the kinds of
//! faults a deployed PIM part accumulates in the field (arXiv 2111.06457
//! shows device-to-device and cycle-to-cycle variability are first-order
//! accuracy killers for analog PIM).  A [`FaultProfile`] is a small,
//! serializable spec; a [`FaultModel`] is a profile pinned to a step clock;
//! [`FaultModel::column_faults`] compiles the model into flat per-column
//! arrays the converter hot loop reads.
//!
//! ## RNG keying (determinism contract)
//!
//! Every draw is positional (DESIGN.md §RNG contract): the base field is
//! `CounterRng::new(seed).stream(chip_id)`, with one tagged substream per
//! fault class:
//!
//! | tag | class          | addressing                                    |
//! |-----|----------------|-----------------------------------------------|
//! | 0   | device-to-device | column `i`: gain at `2i`, offset at `2i+1`  |
//! | 1   | drift walk     | step `s`: gain inc at `2s`, offset at `2s+1`  |
//! | 2   | stuck columns  | column `i`: gate at `2i`, kind at `2i+1`      |
//! | 3   | noise bursts   | window `w = step / burst_window`: gate at `w` |
//!
//! Because `column_faults` is evaluated once per converter construction
//! (single-threaded) and the result is shared read-only by all row workers,
//! faulty evaluation is bit-identical at any thread count for free.

use crate::util::error::Result;
use crate::util::json::Json;
use crate::util::rng::CounterRng;

/// Serializable description of one injured chip instance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultProfile {
    /// Identity of the physical replica — distinct chips in a farm share a
    /// `seed` but differ in `chip_id`, so each engine replica can carry its
    /// own instance of the same statistical population.
    pub chip_id: u64,
    /// Base seed of the fault field.
    pub seed: u64,
    /// Device-to-device per-column gain spread (multiplicative, σ of N(1, σ)).
    pub gain_std: f32,
    /// Device-to-device per-column offset spread in LSB.
    pub offset_std_lsb: f32,
    /// Cycle-to-cycle drift: per-step σ of the chip-level gain random walk.
    pub drift_gain_std: f32,
    /// Cycle-to-cycle drift: per-step σ of the chip-level offset walk (LSB).
    pub drift_offset_std_lsb: f32,
    /// Probability that a column is stuck (output pinned to 0 or full-scale).
    pub stuck_rate: f32,
    /// Probability that any given step window is inside a noise burst.
    pub burst_rate: f32,
    /// Width of a burst window in steps (0 disables bursts).
    pub burst_window: u32,
    /// Thermal-noise σ multiplier while a burst is active.
    pub burst_sigma_mult: f32,
}

impl FaultProfile {
    /// A healthy chip: every fault class disabled.
    pub fn none() -> Self {
        FaultProfile {
            chip_id: 0,
            seed: 0xFA017,
            gain_std: 0.0,
            offset_std_lsb: 0.0,
            drift_gain_std: 0.0,
            drift_offset_std_lsb: 0.0,
            stuck_rate: 0.0,
            burst_rate: 0.0,
            burst_window: 0,
            burst_sigma_mult: 1.0,
        }
    }

    /// Light field aging: sub-percent gain spread, fraction-of-LSB offsets.
    pub fn mild() -> Self {
        FaultProfile {
            gain_std: 0.01,
            offset_std_lsb: 0.5,
            drift_gain_std: 1e-4,
            drift_offset_std_lsb: 5e-3,
            burst_rate: 0.05,
            burst_window: 16,
            burst_sigma_mult: 3.0,
            ..Self::none()
        }
    }

    /// Noticeably injured part: percent-level gain error, LSB-scale offsets,
    /// the occasional dead column.
    pub fn moderate() -> Self {
        FaultProfile {
            gain_std: 0.03,
            offset_std_lsb: 1.5,
            drift_gain_std: 3e-4,
            drift_offset_std_lsb: 0.01,
            stuck_rate: 0.01,
            burst_rate: 0.1,
            burst_window: 8,
            burst_sigma_mult: 5.0,
            ..Self::none()
        }
    }

    /// Heavily degraded chip — the regime where raw accuracy collapses and
    /// BN self-tuning has a large gap to close.
    pub fn severe() -> Self {
        FaultProfile {
            gain_std: 0.08,
            offset_std_lsb: 4.0,
            drift_gain_std: 1e-3,
            drift_offset_std_lsb: 0.02,
            stuck_rate: 0.05,
            burst_rate: 0.2,
            burst_window: 4,
            burst_sigma_mult: 8.0,
            ..Self::none()
        }
    }

    /// Rebind this profile to another chip replica.
    pub fn on_chip(mut self, chip_id: u64) -> Self {
        self.chip_id = chip_id;
        self
    }

    /// Parse a CLI spec: `mild|moderate|severe[:chip_id]` or a path to a
    /// profile JSON written by [`FaultProfile::save`].
    pub fn parse(spec: &str) -> Result<Self> {
        let (name, chip) = match spec.split_once(':') {
            Some((n, c)) => {
                let id = c
                    .parse::<u64>()
                    .map_err(|_| crate::anyhow!("bad fault chip id {c:?}"))?;
                (n, Some(id))
            }
            None => (spec, None),
        };
        let mut p = match name {
            "none" => Self::none(),
            "mild" => Self::mild(),
            "moderate" => Self::moderate(),
            "severe" => Self::severe(),
            path => Self::load(std::path::Path::new(path))?,
        };
        if let Some(id) = chip {
            p.chip_id = id;
        }
        Ok(p)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("chip_id", Json::num(self.chip_id as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("gain_std", Json::num(self.gain_std as f64)),
            ("offset_std_lsb", Json::num(self.offset_std_lsb as f64)),
            ("drift_gain_std", Json::num(self.drift_gain_std as f64)),
            ("drift_offset_std_lsb", Json::num(self.drift_offset_std_lsb as f64)),
            ("stuck_rate", Json::num(self.stuck_rate as f64)),
            ("burst_rate", Json::num(self.burst_rate as f64)),
            ("burst_window", Json::num(self.burst_window as f64)),
            ("burst_sigma_mult", Json::num(self.burst_sigma_mult as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Option<Self> {
        Some(FaultProfile {
            chip_id: j.get("chip_id").as_i64()? as u64,
            seed: j.get("seed").as_i64()? as u64,
            gain_std: j.get("gain_std").as_f64()? as f32,
            offset_std_lsb: j.get("offset_std_lsb").as_f64()? as f32,
            drift_gain_std: j.get("drift_gain_std").as_f64()? as f32,
            drift_offset_std_lsb: j.get("drift_offset_std_lsb").as_f64()? as f32,
            stuck_rate: j.get("stuck_rate").as_f64()? as f32,
            burst_rate: j.get("burst_rate").as_f64()? as f32,
            burst_window: j.get("burst_window").as_i64()? as u32,
            burst_sigma_mult: j.get("burst_sigma_mult").as_f64()? as f32,
        })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        let j = crate::util::json::parse_file(path)?;
        Self::from_json(&j).ok_or_else(|| crate::anyhow!("malformed fault profile"))
    }

    /// Variability-aware training view: a *fresh* device-to-device instance
    /// each step (the profile statistics stay fixed; the replica identity is
    /// remixed), so training sees the population rather than one chip.
    pub fn training_sample(&self, step: u64) -> FaultModel {
        let remixed = self
            .chip_id
            .wrapping_add(step.wrapping_add(1).wrapping_mul(0x9E3779B97F4A7C15));
        FaultModel::new(self.on_chip(remixed)).at_step(step)
    }
}

/// A fault profile pinned to a point on the step clock.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultModel {
    pub profile: FaultProfile,
    /// Current step: advances the drift walk and selects the burst window.
    pub step: u64,
}

/// Compiled per-column fault view: what the converter hot loop reads.
#[derive(Debug, Clone, PartialEq)]
pub struct ColumnFaults {
    /// Per-column multiplicative gain (device-to-device spread + drift).
    pub gain: Vec<f32>,
    /// Per-column additive offset in LSB.
    pub offset: Vec<f32>,
    /// 0 = healthy, 1 = stuck at zero, 2 = stuck at full-scale.
    pub stuck: Vec<u8>,
    /// Thermal-noise σ multiplier for the current step window.
    pub sigma_mult: f32,
}

const TAG_D2D: u64 = 0;
const TAG_DRIFT: u64 = 1;
const TAG_STUCK: u64 = 2;
const TAG_BURST: u64 = 3;

impl FaultModel {
    pub fn new(profile: FaultProfile) -> Self {
        FaultModel { profile, step: 0 }
    }

    /// The same model viewed at another step (drift + bursts advance;
    /// device-to-device spread and stuck columns are fixed per chip).
    pub fn at_step(mut self, step: u64) -> Self {
        self.step = step;
        self
    }

    fn field(&self) -> CounterRng {
        CounterRng::new(self.profile.seed).stream(self.profile.chip_id)
    }

    /// Chip-level drift at the current step: the random walk summed from
    /// step 0.  O(step) per call — evaluated once per converter build, and
    /// our step counts are small enough that recomputing beats carrying
    /// mutable walk state through the (bit-reproducibility-sensitive)
    /// engine plumbing.
    fn drift(&self) -> (f32, f32) {
        let p = &self.profile;
        if p.drift_gain_std == 0.0 && p.drift_offset_std_lsb == 0.0 {
            return (0.0, 0.0);
        }
        let walk = self.field().stream(TAG_DRIFT);
        let (mut dg, mut doff) = (0.0f64, 0.0f64);
        for s in 0..self.step {
            dg += p.drift_gain_std as f64 * walk.normal_at(2 * s);
            doff += p.drift_offset_std_lsb as f64 * walk.normal_at(2 * s + 1);
        }
        (dg as f32, doff as f32)
    }

    /// Magnitude of the chip-level drift walk at the current step:
    /// `(gain_displacement, offset_displacement_lsb)` summed from step 0.
    /// The serving health monitor and the drift tests use this to ask "how
    /// far has this replica walked from its day-one transfer curve" without
    /// compiling full per-column fault arrays.
    pub fn drift_at(&self) -> (f32, f32) {
        self.drift()
    }

    /// σ multiplier for the current step's burst window.
    pub fn sigma_mult(&self) -> f32 {
        let p = &self.profile;
        if p.burst_window == 0 || p.burst_rate <= 0.0 {
            return 1.0;
        }
        let w = self.step / p.burst_window as u64;
        let gate = self.field().stream(TAG_BURST);
        if gate.uniform_at(w) < p.burst_rate as f64 {
            p.burst_sigma_mult
        } else {
            1.0
        }
    }

    /// Compile the model into per-column arrays for `out` ADC columns.
    pub fn column_faults(&self, out: usize) -> ColumnFaults {
        let p = &self.profile;
        let field = self.field();
        let d2d = field.stream(TAG_D2D);
        let stuck_f = field.stream(TAG_STUCK);
        let (drift_g, drift_o) = self.drift();
        let mut gain = Vec::with_capacity(out);
        let mut offset = Vec::with_capacity(out);
        let mut stuck = Vec::with_capacity(out);
        for i in 0..out as u64 {
            gain.push(1.0 + p.gain_std * d2d.normal_at(2 * i) as f32 + drift_g);
            offset.push(p.offset_std_lsb * d2d.normal_at(2 * i + 1) as f32 + drift_o);
            let s = if p.stuck_rate > 0.0
                && stuck_f.uniform_at(2 * i) < p.stuck_rate as f64
            {
                1 + (stuck_f.u64_at(2 * i + 1) & 1) as u8
            } else {
                0
            };
            stuck.push(s);
        }
        ColumnFaults { gain, offset, stuck, sigma_mult: self.sigma_mult() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn column_faults_deterministic_per_chip() {
        let p = FaultProfile::severe().on_chip(7);
        let a = FaultModel::new(p).at_step(12).column_faults(64);
        let b = FaultModel::new(p).at_step(12).column_faults(64);
        assert_eq!(a, b);
        let other = FaultModel::new(p.on_chip(8)).at_step(12).column_faults(64);
        assert_ne!(a.gain, other.gain);
        assert_ne!(a.offset, other.offset);
    }

    #[test]
    fn none_profile_is_identity() {
        let cf = FaultModel::new(FaultProfile::none()).column_faults(32);
        assert!(cf.gain.iter().all(|&g| g == 1.0));
        assert!(cf.offset.iter().all(|&o| o == 0.0));
        assert!(cf.stuck.iter().all(|&s| s == 0));
        assert_eq!(cf.sigma_mult, 1.0);
    }

    #[test]
    fn drift_advances_with_step_and_d2d_stays_fixed() {
        let mut p = FaultProfile::none();
        p.gain_std = 0.05;
        p.drift_gain_std = 0.01;
        p.drift_offset_std_lsb = 0.05;
        let m = FaultModel::new(p);
        let a = m.at_step(0).column_faults(16);
        let b = m.at_step(40).column_faults(16);
        assert_ne!(a.gain, b.gain, "drift must move the gains across steps");
        // drift is chip-level: the per-column *differences* are step-invariant
        let rel_a: Vec<f32> = a.gain.iter().map(|g| g - a.gain[0]).collect();
        let rel_b: Vec<f32> = b.gain.iter().map(|g| g - b.gain[0]).collect();
        for (x, y) in rel_a.iter().zip(&rel_b) {
            assert!((x - y).abs() < 1e-5, "d2d spread must not change with step");
        }
    }

    #[test]
    fn stuck_rate_hits_expected_fraction() {
        let mut p = FaultProfile::none();
        p.stuck_rate = 0.1;
        let cf = FaultModel::new(p).column_faults(4000);
        let n = cf.stuck.iter().filter(|&&s| s != 0).count();
        assert!((300..=500).contains(&n), "stuck count {n} far from 10% of 4000");
        assert!(cf.stuck.iter().any(|&s| s == 1));
        assert!(cf.stuck.iter().any(|&s| s == 2));
    }

    #[test]
    fn burst_windows_gate_sigma() {
        let mut p = FaultProfile::none();
        p.burst_rate = 0.5;
        p.burst_window = 4;
        p.burst_sigma_mult = 6.0;
        let m = FaultModel::new(p);
        let mults: Vec<f32> = (0..200).map(|s| m.at_step(s).sigma_mult()).collect();
        assert!(mults.iter().any(|&x| x == 6.0));
        assert!(mults.iter().any(|&x| x == 1.0));
        // constant within a window
        for w in 0..50 {
            let base = mults[w * 4];
            assert!(mults[w * 4..(w + 1) * 4].iter().all(|&x| x == base));
        }
    }

    #[test]
    fn drift_query_grows_with_step_and_matches_column_view() {
        let mut p = FaultProfile::none();
        p.drift_gain_std = 0.01;
        p.drift_offset_std_lsb = 0.05;
        let m = FaultModel::new(p);
        assert_eq!(m.at_step(0).drift_at(), (0.0, 0.0), "no walk before step 1");
        let (g40, o40) = m.at_step(40).drift_at();
        assert!(g40 != 0.0 && o40 != 0.0, "walk must have moved by step 40");
        // the query is exactly what column_faults folds into every column
        let cf = m.at_step(40).column_faults(8);
        for i in 0..8 {
            assert_eq!(cf.gain[i], 1.0 + g40);
            assert_eq!(cf.offset[i], o40);
        }
        // deterministic: same step, same displacement
        assert_eq!(m.at_step(40).drift_at(), (g40, o40));
    }

    #[test]
    fn json_roundtrip_exact() {
        let p = FaultProfile::moderate().on_chip(42);
        let text = p.to_json().to_string();
        let back =
            FaultProfile::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(p, back);
    }

    #[test]
    fn parse_presets_and_chip_suffix() {
        assert_eq!(FaultProfile::parse("mild").unwrap(), FaultProfile::mild());
        let p = FaultProfile::parse("severe:9").unwrap();
        assert_eq!(p, FaultProfile::severe().on_chip(9));
        assert!(FaultProfile::parse("mild:notanumber").is_err());
        assert!(FaultProfile::parse("/no/such/file.json").is_err());
    }

    #[test]
    fn training_sample_varies_per_step() {
        let p = FaultProfile::moderate();
        let a = p.training_sample(3).column_faults(16);
        let b = p.training_sample(4).column_faults(16);
        assert_ne!(a.gain, b.gain, "each step must see a fresh replica");
        assert_eq!(a, p.training_sample(3).column_faults(16));
    }
}
