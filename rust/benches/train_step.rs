//! Bench: one native-backend train step (fwd + bwd + SGD) on the tiny
//! model — the end-to-end training hot loop the repo now owns.  Covers the
//! digital baseline and PIM-QAT (`mode=ours`, bit-serial b_PIM=7, where
//! every step runs the integer PIM engine forward plus the fused ξ digital
//! twin).  Because the trainer keeps per-layer engines and the step arena
//! alive across iterations (§Perf L3.5), the warmup phase doubles as the
//! grow-once pass and the measured iterations are the steady state the
//! trainer actually runs in.
//!
//! The `acquire+step/*` case pair (§Perf L3.7) times the FULL step
//! lifecycle — batch assembly + augmentation through the `BatchLoader`,
//! then the train step — serial (`prefetch0`) vs pipelined (`prefetch1`,
//! assembly overlapped with the step on the worker pool).  Emits
//! `BENCH_train_step.json` so the perf trajectory is tracked across PRs
//! (EXPERIMENTS.md §Perf); CI gates it against
//! `baselines/BENCH_train_step.json` via `bench_check`.
//!
//! Set `PIM_QAT_BENCH_QUICK=1` for a fast smoke run.

use pim_qat::config::{JobConfig, Mode, Scheme};
use pim_qat::data::loader::{with_loader, LoaderCfg};
use pim_qat::data::synth;
use pim_qat::runtime::Manifest;
use pim_qat::train::native::NativeTrainer;
use pim_qat::util::bench::{save_json, Bencher};
use pim_qat::util::rng::Rng;

fn main() {
    let b = if std::env::var_os("PIM_QAT_BENCH_QUICK").is_some() {
        Bencher::quick()
    } else {
        Bencher::default()
    };
    let manifest = Manifest::builtin();
    let bs = manifest.batch;
    let ds = synth::generate(16, 10, bs.max(64), 1);
    let mut drng = Rng::new(0);
    let batch = ds.batch(&(0..bs).collect::<Vec<_>>(), false, &mut drng);
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("native train step, tiny model, batch {bs}, {cores} cores");

    let mut all = Vec::new();
    for (label, mode, scheme) in [
        ("baseline/digital", Mode::Baseline, Scheme::BitSerial),
        ("ours/bit_serial_b7", Mode::Ours, Scheme::BitSerial),
        ("ours/native_b7", Mode::Ours, Scheme::Native),
    ] {
        let job = JobConfig {
            model: "tiny".into(),
            mode,
            scheme,
            unit_channels: if scheme == Scheme::Native { 1 } else { 8 },
            b_pim_train: 7,
            ..Default::default()
        };
        let mut trainer = NativeTrainer::new(&manifest, &job).unwrap();
        let mut rng = Rng::new(2);
        let stats = b.run(label, Some(bs as f64), || {
            std::hint::black_box(
                trainer.train_step(&batch.x, &batch.y, 0.05, &mut rng).unwrap(),
            );
        });
        println!("{}", stats.report());
        all.push(stats);
    }

    // the full lifecycle, serial vs pipelined acquire (bit-identical
    // results by the loader's determinism contract — this pair measures
    // pure overlap)
    for (label, prefetch) in [
        ("acquire+step/bit_serial_b7/prefetch0", 0usize),
        ("acquire+step/bit_serial_b7/prefetch1", 1usize),
    ] {
        let job = JobConfig {
            model: "tiny".into(),
            mode: Mode::Ours,
            scheme: Scheme::BitSerial,
            unit_channels: 8,
            b_pim_train: 7,
            ..Default::default()
        };
        let mut trainer = NativeTrainer::new(&manifest, &job).unwrap();
        let cfg = LoaderCfg {
            batch: bs,
            augment: true,
            flip: false,
            seed: 7,
            prefetch,
            shards: 0,
            stream_stride: 1,
            stream_offset: 0,
        };
        let mut rng = Rng::new(2);
        let stats = with_loader(&ds, cfg, |loader| {
            b.run(label, Some(bs as f64), || {
                let (x, y) = loader.next().unwrap();
                std::hint::black_box(trainer.train_step(x, y, 0.05, &mut rng).unwrap());
            })
        })
        .unwrap();
        println!("{}", stats.report());
        all.push(stats);
    }

    let path = std::path::Path::new("BENCH_train_step.json");
    match save_json(path, &all) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}
