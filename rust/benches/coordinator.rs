//! Bench: coordinator overhead — grid expansion, fingerprinting, dataset
//! batching/augmentation, and checkpoint (de)serialization.  The §Perf L3
//! target is coordinator overhead ≪ step time.

use pim_qat::config::JobConfig;
use pim_qat::coordinator::sweep::{fingerprint, parse_grid};
use pim_qat::data::synth;
use pim_qat::tensor::Tensor;
use pim_qat::train::Checkpoint;
use pim_qat::util::bench::Bencher;
use pim_qat::util::rng::Rng;

fn main() {
    let b = Bencher::default();
    let base = JobConfig::default();

    let stats = b.run("grid: parse 3x5x2 sweep", Some(30.0), || {
        std::hint::black_box(
            parse_grid(&base, "scheme=native,bit_serial,differential;b_pim=3..7;mode=ours,baseline")
                .unwrap(),
        );
    });
    println!("{}", stats.report());

    let jobs = parse_grid(&base, "b_pim=3..7").unwrap();
    let stats = b.run("fingerprint 5 jobs", Some(5.0), || {
        for j in &jobs {
            std::hint::black_box(fingerprint(j));
        }
    });
    println!("{}", stats.report());

    let ds = synth::generate(16, 10, 512, 1);
    let mut rng = Rng::new(2);
    let idx: Vec<usize> = (0..32).collect();
    let stats = b.run("batch assembly + augmentation (32 imgs)", Some(32.0), || {
        std::hint::black_box(ds.batch(&idx, true, &mut rng));
    });
    println!("{}", stats.report());

    let ck = Checkpoint {
        model: "tiny".into(),
        meta: Default::default(),
        params: (0..24)
            .map(|i| (format!("p{i}"), Tensor::full(&[3, 3, 8, 8], 0.5)))
            .collect(),
        state: vec![],
        velocity: vec![],
    };
    let dir = std::env::temp_dir().join("pimqat_bench_ckpt");
    let stats = b.run("checkpoint save+load (13k params)", None, || {
        ck.save(&dir).unwrap();
        std::hint::black_box(Checkpoint::load(&dir).unwrap());
    });
    println!("{}", stats.report());
}
