//! Bench: full-network chip-simulator inference (the Table-4 evaluation
//! path) — images/s for software vs ideal vs real chip execution.

use pim_qat::chip::ChipModel;
use pim_qat::config::Scheme;
use pim_qat::data::synth;
use pim_qat::nn::ExecSpec;
use pim_qat::train::{network_from_ckpt, Backend, Checkpoint, NativeBackend};
use pim_qat::util::bench::Bencher;
use pim_qat::util::rng::Rng;

fn main() {
    // trains a tiny 20-step checkpoint on the native backend if no cache
    // exists (no artifacts required).
    let backend = NativeBackend::open_default().unwrap();
    let dir = std::path::Path::new("results/bench_ckpt");
    let ckpt = if dir.join("ckpt.json").exists() {
        Checkpoint::load(dir).unwrap()
    } else {
        let job = pim_qat::config::JobConfig {
            steps: 20,
            train_size: 128,
            test_size: 64,
            ..Default::default()
        };
        let tr = synth::generate(16, 10, 128, 1);
        let te = synth::generate(16, 10, 64, 2);
        let res = backend.train_job(&job, &tr, &te, 10).unwrap();
        res.ckpt.save(dir).unwrap();
        res.ckpt
    };
    let net = network_from_ckpt(backend.manifest(), &ckpt).unwrap();
    let ds = synth::generate(16, 10, 32, 3);
    let batch = {
        let mut r = Rng::new(0);
        ds.batch(&(0..32).collect::<Vec<_>>(), false, &mut r)
    };

    let b = Bencher::default();
    let mut rng = Rng::new(4);
    let imgs = 32.0;
    let ideal = ChipModel::ideal(7);
    let real = ChipModel::real(1).with_noise(0.35);
    let cases: Vec<(&str, ExecSpec)> = vec![
        ("software (digital)", ExecSpec::Software),
        ("ideal 7-bit chip", ExecSpec::Pim { scheme: Scheme::BitSerial, unit_channels: 8, chip: &ideal }),
        ("real chip", ExecSpec::Pim { scheme: Scheme::BitSerial, unit_channels: 8, chip: &real }),
    ];
    println!("full-network inference, batch 32, tiny model (images/s)");
    for (label, exec) in &cases {
        let stats = b.run(label, Some(imgs), || {
            std::hint::black_box(net.forward(&batch.x, exec, &mut rng).unwrap());
        });
        println!("{}", stats.report());
    }
}
