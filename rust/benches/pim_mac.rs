//! Bench: the PIM MAC engine's grouped matmul (the chip simulator's hot
//! path) across schemes, ADC configurations, and thread counts.
//! Regenerates the throughput side of Table 1's story — how much work one
//! conversion chain amortizes and what the noise/curve models cost — and
//! emits `BENCH_pim_mac.json` so the perf trajectory is tracked across PRs
//! (EXPERIMENTS.md §Perf); CI gates it against
//! `baselines/BENCH_pim_mac.json` via `bench_check`.  Multi-threaded cases
//! run on the persistent worker pool (`util::pool`), so thread startup is
//! paid once per process, not per matmul.
//!
//! Set `PIM_QAT_BENCH_QUICK=1` for a fast smoke run.

use pim_qat::chip::ChipModel;
use pim_qat::config::Scheme;
use pim_qat::pim::{PimEngine, QuantBits};
use pim_qat::tensor::Tensor;
use pim_qat::util::bench::{save_json, Bencher};
use pim_qat::util::rng::Rng;

fn main() {
    let b = if std::env::var_os("PIM_QAT_BENCH_QUICK").is_some() {
        Bencher::quick()
    } else {
        Bencher::default()
    };
    let bits = QuantBits::default();
    let mut rng = Rng::new(1);
    // one mid-size conv layer's worth of work: M=1024 rows, C=16, O=32
    let (m, c, k, o, uc) = (1024usize, 16usize, 3usize, 32usize, 8usize);
    let cols = c * k * k;
    let a = Tensor::from_vec(&[m, cols], (0..m * cols).map(|_| rng.int_in(0, 15) as f32).collect());
    let w = Tensor::from_vec(&[cols, o], (0..cols * o).map(|_| rng.int_in(-7, 7) as f32).collect());
    let macs = (m * cols * o) as f64;
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);

    let mut all = Vec::new();
    println!(
        "PIM MAC engine, {m}x{cols}x{o} grouped matmul (N = {}), {cores} cores",
        uc * 9
    );
    for scheme in [Scheme::Native, Scheme::BitSerial, Scheme::Differential] {
        for (label, chip) in [
            ("ideal", ChipModel::ideal(7)),
            ("ideal+noise", ChipModel::ideal(7).with_noise(0.35)),
            ("real curves+noise", ChipModel::real(1).with_noise(0.35)),
        ] {
            for threads in [1usize, 0] {
                // 0 = auto (all cores); skip the duplicate on 1-core hosts
                if threads == 0 && cores <= 1 {
                    continue;
                }
                let engine = PimEngine::prepare(scheme, bits, &w, c, k, uc).with_threads(threads);
                let tlabel = if threads == 1 { "t1" } else { "tauto" };
                let mut nrng = Rng::new(2);
                let stats = b.run(&format!("{scheme}/{label}/{tlabel}"), Some(macs), || {
                    std::hint::black_box(engine.matmul(&a, &chip, &mut nrng));
                });
                println!("{}", stats.report());
                all.push(stats);
            }
        }
    }

    let path = std::path::Path::new("BENCH_pim_mac.json");
    match save_json(path, &all) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }

    // single-thread vs auto summary for the headline config
    let t1 = all.iter().find(|s| s.name == "bit_serial/ideal+noise/t1");
    let ta = all.iter().find(|s| s.name == "bit_serial/ideal+noise/tauto");
    if let (Some(t1), Some(ta)) = (t1, ta) {
        println!(
            "bit_serial/ideal+noise speedup (auto vs 1 thread): {:.2}x",
            t1.mean_ns / ta.mean_ns
        );
    }
}
