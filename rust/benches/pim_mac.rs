//! Bench: the PIM MAC engine's grouped matmul (the chip simulator's hot
//! path) across schemes and ADC configurations.  Regenerates the
//! throughput side of Table 1's story: how much work one conversion chain
//! amortizes, and what the noise/curve models cost on top.

use pim_qat::chip::ChipModel;
use pim_qat::config::Scheme;
use pim_qat::pim::{PimEngine, QuantBits};
use pim_qat::tensor::Tensor;
use pim_qat::util::bench::Bencher;
use pim_qat::util::rng::Rng;

fn main() {
    let b = Bencher::default();
    let bits = QuantBits::default();
    let mut rng = Rng::new(1);
    // one mid-size conv layer's worth of work: M=1024 rows, C=16, O=32
    let (m, c, k, o, uc) = (1024usize, 16usize, 3usize, 32usize, 8usize);
    let cols = c * k * k;
    let a = Tensor::from_vec(&[m, cols], (0..m * cols).map(|_| rng.int_in(0, 15) as f32).collect());
    let w = Tensor::from_vec(&[cols, o], (0..cols * o).map(|_| rng.int_in(-7, 7) as f32).collect());
    let macs = (m * cols * o) as f64;

    println!("PIM MAC engine, {m}x{cols}x{o} grouped matmul (N = {})", uc * 9);
    for scheme in [Scheme::Native, Scheme::BitSerial, Scheme::Differential] {
        let engine = PimEngine::prepare(scheme, bits, &w, c, k, uc);
        for (label, chip) in [
            ("ideal", ChipModel::ideal(7)),
            ("ideal+noise", ChipModel::ideal(7).with_noise(0.35)),
            ("real curves+noise", ChipModel::real(1).with_noise(0.35)),
        ] {
            let mut nrng = Rng::new(2);
            let stats = b.run(&format!("{scheme}/{label}"), Some(macs), || {
                std::hint::black_box(engine.matmul(&a, &chip, &mut nrng));
            });
            println!("{}", stats.report());
        }
    }
}
