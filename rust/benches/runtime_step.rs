//! Bench: the PJRT runtime path — train-step latency (the end-to-end
//! training hot loop) and the L1 kernel artifact in both lowerings
//! (Pallas interpret vs jnp twin).

use pim_qat::runtime::literal::{scalar_f32, scalar_i32, tensor_to_literal, vec_i32};
use pim_qat::runtime::Runtime;
use pim_qat::tensor::Tensor;
use pim_qat::util::bench::Bencher;
use pim_qat::util::rng::Rng;

fn main() {
    let rt = match Runtime::new(std::path::Path::new("artifacts")) {
        Ok(rt) => rt,
        Err(e) => {
            println!("skipping runtime bench (no artifacts): {e}");
            return;
        }
    };
    let b = Bencher::default();
    let mut rng = Rng::new(1);

    // --- train-step latency (tiny model, batch 32)
    let init = rt.load("tiny_init").unwrap();
    let outs = init.run(&[scalar_i32(0)]).unwrap();
    for name in ["tiny_train_baseline", "tiny_train_ours_bit_serial_uc8"] {
        let train = rt.load(name).unwrap();
        let x = Tensor::from_vec(
            &[32, 16, 16, 3],
            (0..32 * 16 * 16 * 3).map(|_| rng.uniform_in(0.0, 1.0)).collect(),
        );
        let y: Vec<i32> = (0..32).map(|_| rng.int_in(0, 9) as i32).collect();
        let stats = b.run(&format!("{name} (batch 32)"), Some(32.0), || {
            let mut inputs = Vec::with_capacity(outs.len() + 7);
            for l in &outs {
                inputs.push(
                    tensor_to_literal(
                        &pim_qat::runtime::literal::literal_to_tensor(l).unwrap(),
                    )
                    .unwrap(),
                );
            }
            inputs.push(tensor_to_literal(&x).unwrap());
            inputs.push(vec_i32(&y));
            inputs.push(scalar_f32(0.1));
            inputs.push(scalar_f32(127.0));
            inputs.push(scalar_f32(1.0));
            inputs.push(scalar_f32(0.0));
            inputs.push(scalar_i32(0));
            std::hint::black_box(train.run(&inputs).unwrap());
        });
        println!("{}", stats.report());
    }

    // --- L1 kernel artifact: pallas vs jnp lowering
    let (m, g, n, o) = (256usize, 2usize, 72usize, 16usize);
    let a = Tensor::from_vec(&[m, g, n], (0..m * g * n).map(|_| rng.int_in(0, 15) as f32 / 15.0).collect());
    let w = Tensor::from_vec(&[g, n, o], (0..g * n * o).map(|_| rng.int_in(-7, 7) as f32 / 7.0).collect());
    let lv = Tensor::from_vec(&[1], vec![127.0]);
    for name in ["kernel_pim_mac_jnp", "kernel_pim_mac_pallas"] {
        let exe = rt.load(name).unwrap();
        let macs = (m * g * n * o) as f64;
        let stats = b.run(name, Some(macs), || {
            let inputs = [
                tensor_to_literal(&a).unwrap(),
                tensor_to_literal(&w).unwrap(),
                tensor_to_literal(&lv).unwrap(),
            ];
            std::hint::black_box(exe.run(&inputs).unwrap());
        });
        println!("{}", stats.report());
    }
}
