//! Bench: chip-farm serving layer — sustained QPS and tail latency of the
//! dynamic batcher + replica farm under a synthetic open-loop load.
//!
//! Writes `BENCH_serve.json` in the bench-gate schema: `ns_per_iter` is
//! wall time per served request (the regression-gated figure); `qps`,
//! `p50_ns`, `p95_ns`, `p99_ns` and `mean_batch` ride along for the
//! EXPERIMENTS.md serve ledger.  The health case serves pristine replicas
//! with the monitor attached (probe cadence + ledger bookkeeping on the
//! hot path) — the monitoring-overhead figure.  `PIM_QAT_BENCH_QUICK=1`
//! shrinks the request count for the CI smoke leg.

use std::time::Duration;

use pim_qat::config::Scheme;
use pim_qat::data::synth;
use pim_qat::serve::{Farm, FarmServer, HealthCfg, HealthMonitor, LoadCfg, ReplicaCfg, ServeCfg};
use pim_qat::train::{Backend, Checkpoint, NativeBackend};
use pim_qat::util::json::Json;

fn main() {
    let quick = std::env::var("PIM_QAT_BENCH_QUICK").is_ok();
    // trains a tiny 20-step checkpoint on the native backend if no cache
    // exists (shared with the chip_infer bench).
    let backend = NativeBackend::open_default().unwrap();
    let dir = std::path::Path::new("results/bench_ckpt");
    let ckpt = if dir.join("ckpt.json").exists() {
        Checkpoint::load(dir).unwrap()
    } else {
        let job = pim_qat::config::JobConfig {
            steps: 20,
            train_size: 128,
            test_size: 64,
            ..Default::default()
        };
        let tr = synth::generate(16, 10, 128, 1);
        let te = synth::generate(16, 10, 64, 2);
        let res = backend.train_job(&job, &tr, &te, 10).unwrap();
        res.ckpt.save(dir).unwrap();
        res.ckpt
    };
    let ds = synth::generate(16, 10, 64, 3);
    let requests = if quick { 96 } else { 768 };

    let mut rows: Vec<Json> = Vec::new();
    println!("chip-farm serving, tiny model, {requests} requests per case");
    for &(label, replicas, batch, health) in &[
        ("serve 1 replica batch 8", 1usize, 8usize, false),
        ("serve 2 replicas batch 8", 2, 8, false),
        ("serve 4 replicas batch 16", 4, 16, false),
        ("serve 2 replicas batch 8 health", 2, 8, true),
    ] {
        let rcfg = ReplicaCfg {
            scheme: Scheme::BitSerial,
            unit_channels: 8,
            ..Default::default()
        };
        let mut farm = Farm::new(backend.manifest(), &ckpt, &rcfg, replicas).unwrap();
        if health {
            let probe_ds = synth::generate(16, 10, 32, 9);
            let calib = synth::generate(16, 10, 128, 11);
            let monitor = HealthMonitor::new(
                backend.manifest(),
                &ckpt,
                &rcfg,
                replicas,
                &probe_ds,
                calib,
                HealthCfg::default(),
            )
            .unwrap();
            farm.attach_health(monitor);
        }
        let mut server = FarmServer::start(
            farm,
            ServeCfg {
                batch,
                latency_budget: Duration::from_micros(2000),
                queue_cap: 4 * batch,
                hedge_after: None,
            },
        );
        let rep = pim_qat::serve::run_open_loop(
            &server,
            &ds,
            &LoadCfg {
                requests,
                interarrival: Duration::ZERO,
                producers: 2,
                ..Default::default()
            },
        );
        server.shutdown();
        let ns = |d: Option<Duration>| d.unwrap_or_default().as_nanos() as f64;
        let per_req_ns = rep.wall.as_nanos() as f64 / rep.requests.max(1) as f64;
        println!(
            "{label:<34} {:>8.1} qps  {:>10.1} ns/req  p50 {:>10.0}ns p95 {:>10.0}ns \
             p99 {:>10.0}ns  mean batch {:.2}",
            rep.qps(),
            per_req_ns,
            ns(rep.percentile(50.0)),
            ns(rep.percentile(95.0)),
            ns(rep.percentile(99.0)),
            rep.mean_batch,
        );
        rows.push(Json::obj(vec![
            ("name", Json::str(label)),
            ("iters", Json::num(rep.requests as f64)),
            ("ns_per_iter", Json::num(per_req_ns)),
            ("median_ns", Json::num(ns(rep.percentile(50.0)))),
            ("qps", Json::num(rep.qps())),
            ("p50_ns", Json::num(ns(rep.percentile(50.0)))),
            ("p95_ns", Json::num(ns(rep.percentile(95.0)))),
            ("p99_ns", Json::num(ns(rep.percentile(99.0)))),
            ("mean_batch", Json::num(rep.mean_batch)),
        ]));
    }
    let out = Json::obj(vec![("benches", Json::Arr(rows))]);
    std::fs::write("BENCH_serve.json", out.to_string()).unwrap();
    println!("wrote BENCH_serve.json");
}
