//! Bench: data-parallel training scaling (§Perf L3.10).  One global step
//! of the in-process data-parallel driver — N replica trainers, each
//! running one microbatch (fwd + bwd) against its own shard stream, a
//! fixed-order tree all-reduce over the gradient bus, a single optimizer
//! apply and in-place weight broadcast — at N ∈ {1, 2, 4}.
//!
//! Work per iteration is `N * batch` samples, so the reported throughput
//! column is directly comparable across N: ideal scaling holds
//! `ns_per_iter` flat while samples/s grows Nx.  The run prints the
//! scaling-efficiency curve (`t_1 / t_N`, the fraction of ideal) recorded
//! in EXPERIMENTS.md §Perf L3.10.
//!
//! Emits `BENCH_train_parallel.json`; CI gates it against
//! `baselines/BENCH_train_parallel.json` via `bench_check`.  Set
//! `PIM_QAT_BENCH_QUICK=1` for a fast smoke run.

use pim_qat::config::{JobConfig, Mode, Scheme};
use pim_qat::data::synth;
use pim_qat::runtime::Manifest;
use pim_qat::train::{with_parallel, ParallelCfg};
use pim_qat::util::bench::{save_json, Bencher};

fn main() {
    let b = if std::env::var_os("PIM_QAT_BENCH_QUICK").is_some() {
        Bencher::quick()
    } else {
        Bencher::default()
    };
    let manifest = Manifest::builtin();
    let bs = manifest.batch;
    let job = JobConfig {
        model: "tiny".into(),
        mode: Mode::Ours,
        scheme: Scheme::BitSerial,
        unit_channels: 8,
        b_pim_train: 7,
        ..Default::default()
    };
    // big enough that every shard stream sees several epochs without the
    // reshuffle dominating, small enough to stay cache-resident
    let ds = synth::generate(16, 10, (4 * bs).max(256), 1);
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("data-parallel train step, tiny model, batch {bs} per replica, {cores} cores");

    let mut all = Vec::new();
    let mut ns_at = Vec::new();
    for replicas in [1usize, 2, 4] {
        let label = format!("dp/bit_serial_b7/replicas{replicas}");
        let pcfg = ParallelCfg::new(replicas);
        let stats = with_parallel(&manifest, &job, &ds, &pcfg, |pt| {
            b.run(&label, Some((replicas * bs) as f64), || {
                std::hint::black_box(pt.step(0.05).unwrap());
            })
        })
        .unwrap();
        println!("{}", stats.report());
        ns_at.push((replicas, stats.mean_ns));
        all.push(stats);
    }

    // scaling efficiency: ideal data parallelism does N x the work in the
    // same wall time, so eff(N) = t_1 / t_N
    if let Some(&(_, t1)) = ns_at.first() {
        println!("scaling efficiency vs 1 replica (ideal 100%):");
        for &(n, tn) in &ns_at {
            let eff = if tn > 0.0 { t1 / tn } else { 0.0 };
            println!(
                "  replicas {n}: {:.2}x sample throughput vs serial (ideal {n}x), efficiency {:.0}%",
                n as f64 * eff,
                100.0 * eff
            );
        }
    }

    let path = std::path::Path::new("BENCH_train_parallel.json");
    match save_json(path, &all) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}
