//! Bench: the dispatched GEMM kernel subsystem (§Perf L3.6) — scalar
//! reference arm vs the runtime-selected arm, on DAC-plane-shaped
//! workloads (M batch-rows × N per conversion chain × O outputs, the
//! shapes `PimEngine::run_rows` feeds the kernels).
//!
//! Cases (`<kernel>/<shape>/<arm>`):
//!
//! * `u8i16` — the integer plane kernel (native/differential cells).
//! * `binpacked` — the bit-packed bit-serial plane kernel (64 cols/u64
//!   word, the engine's stored layout).
//! * `f32acc` — the dense f32 GEMM (digital convs, FC; packed-panel
//!   blocked on the SIMD arms, §Perf L3.9).
//! * `f32nt` / `f32tn` — the A·Bᵀ / Aᵀ·B backward-pass kernels (data- and
//!   weight-gradient GEMMs of the native trainer).
//!
//! The shape list includes a backward-shaped tall-k case (`bwd_k1152_o64`)
//! so the packed-panel path is measured where it matters most.
//!
//! Emits `BENCH_gemm_kernels.json`; CI gates it against
//! `baselines/BENCH_gemm_kernels.json` via `bench_check` (see ROADMAP.md,
//! bench-baseline convention).  Set `PIM_QAT_BENCH_QUICK=1` for a fast
//! smoke run.

use pim_qat::pim::layout::pack_bin_plane;
use pim_qat::tensor::kernels::{self, scalar, KernelTable};
use pim_qat::util::bench::{save_json, Bencher};
use pim_qat::util::rng::Rng;

fn main() {
    let b = if std::env::var_os("PIM_QAT_BENCH_QUICK").is_some() {
        Bencher::quick()
    } else {
        Bencher::default()
    };
    let active = kernels::active();
    println!(
        "GEMM kernel arms: scalar vs dispatched ({}{})",
        active.name,
        if active.name == "scalar" { " — no SIMD on this host" } else { "" }
    );
    match kernels::autotune::chosen() {
        Some(t) => println!("blocked-GEMM tile (autotuned or pinned): {}x{}x{}", t.mc, t.kc, t.nc),
        None => println!("blocked-GEMM tile: n/a (scalar arm never consults it)"),
    }

    // (label, m, k, n): m batch rows, k = N per conversion chain, n = O
    let shapes: &[(&str, usize, usize, usize)] = &[
        ("n144_o32", 1024, 144, 32),     // uc=16 3x3 mid conv (the paper's N=144)
        ("n72_o64", 1024, 72, 64),       // uc=8 3x3, wider output
        ("n9_o16", 1024, 9, 16),         // native uc=1 — many small planes
        ("bwd_k1152_o64", 256, 1152, 64), // backward-shaped tall-k (128ch 3x3 grad)
    ];
    let arms: Vec<(&str, &'static KernelTable)> =
        vec![("scalar", &scalar::TABLE), ("dispatch", active)];

    let mut rng = Rng::new(7);
    let mut all = Vec::new();
    for &(label, m, k, n) in shapes {
        let a: Vec<u8> = (0..m * k).map(|_| rng.int_in(0, 15) as u8).collect();
        let w16: Vec<i16> = (0..k * n).map(|_| rng.int_in(-7, 7) as i16).collect();
        let bin: Vec<u8> = (0..k * n).map(|_| rng.below(2) as u8).collect();
        let wp = pack_bin_plane(&bin, k, n);
        let af: Vec<f32> = a.iter().map(|&v| v as f32).collect();
        let wf: Vec<f32> = w16.iter().map(|&v| v as f32).collect();
        // backward operands: B[n,k]ᵀ for nt, dY[m,n] for tn (af doubles as
        // the patches operand in both)
        let wtf: Vec<f32> = (0..n * k).map(|_| rng.normal_in(0.0, 1.0)).collect();
        let btf: Vec<f32> = (0..m * n).map(|_| rng.normal_in(0.0, 1.0)).collect();
        let macs = (m * k * n) as f64;

        let mut ci = vec![0i32; m * n];
        let mut cf = vec![0.0f32; m * n];
        let mut ctn = vec![0.0f32; k * n];
        for (arm, table) in &arms {
            let stats = b.run(&format!("u8i16/{label}/{arm}"), Some(macs), || {
                ci.fill(0);
                (table.gemm_acc_u8_i16)(m, k, n, &a, &w16, &mut ci);
                std::hint::black_box(&ci);
            });
            println!("{}", stats.report());
            all.push(stats);

            let stats = b.run(&format!("binpacked/{label}/{arm}"), Some(macs), || {
                ci.fill(0);
                (table.gemm_acc_u8_bin_packed)(m, k, n, &a, &wp, &mut ci);
                std::hint::black_box(&ci);
            });
            println!("{}", stats.report());
            all.push(stats);

            let stats = b.run(&format!("f32acc/{label}/{arm}"), Some(macs), || {
                cf.fill(0.0);
                (table.gemm_acc)(m, k, n, &af, &wf, &mut cf);
                std::hint::black_box(&cf);
            });
            println!("{}", stats.report());
            all.push(stats);

            // backward kernels: nt treats wf as B[n,k]ᵀ (same buffer,
            // reinterpreted — only the shape contract matters to timing)
            let stats = b.run(&format!("f32nt/{label}/{arm}"), Some(macs), || {
                cf.fill(0.0);
                (table.gemm_nt_acc)(m, k, n, &af, &wtf, &mut cf);
                std::hint::black_box(&cf);
            });
            println!("{}", stats.report());
            all.push(stats);

            let stats = b.run(&format!("f32tn/{label}/{arm}"), Some(macs), || {
                ctn.fill(0.0);
                (table.gemm_tn_acc)(m, k, n, &af, &btf, &mut ctn);
                std::hint::black_box(&ctn);
            });
            println!("{}", stats.report());
            all.push(stats);
        }
    }

    let path = std::path::Path::new("BENCH_gemm_kernels.json");
    match save_json(path, &all) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }

    // headline: dispatched vs scalar on the big i16-plane shape
    let s = all.iter().find(|s| s.name == "u8i16/n144_o32/scalar");
    let d = all.iter().find(|s| s.name == "u8i16/n144_o32/dispatch");
    if let (Some(s), Some(d)) = (s, d) {
        println!(
            "u8i16/n144_o32 speedup ({} vs scalar): {:.2}x",
            active.name,
            s.mean_ns / d.mean_ns
        );
    }
}
