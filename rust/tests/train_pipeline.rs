//! Pipelined-training parity suite (§Perf L3.7, DESIGN.md §Data pipeline):
//!
//! 1. The pipelined training loop (loader prefetch ≥ 1, sharded batch
//!    assembly on the worker pool) must produce **bit-identical** losses
//!    and weights to the serial loop (prefetch 0, one shard) — the
//!    acquire-stage twin of the engine's thread-count invariance.
//! 2. The counter-RNG augmentation streams are independent per sample:
//!    a sample's crop is a pure function of (epoch, step, its dataset
//!    index), untouched by batch composition.

use pim_qat::config::{JobConfig, Mode, Scheme};
use pim_qat::data::loader::{fill_samples, with_loader, LoaderCfg};
use pim_qat::data::{synth, Dataset};
use pim_qat::runtime::Manifest;
use pim_qat::train::native::NativeTrainer;
use pim_qat::util::rng::{CounterRng, Rng};

/// The down-scaled resnet geometry the native-trainer unit tests use,
/// rebuilt here (integration tests cannot reach the private helper).
fn micro_manifest() -> Manifest {
    let mut m = Manifest::builtin();
    let mut e = m.models.get("tiny").unwrap().clone();
    e.width = 4;
    e.image = 8;
    e.classes = 4;
    m.models.insert("micro".to_string(), e);
    m.batch = 8;
    m
}

fn micro_job(steps: usize) -> JobConfig {
    JobConfig {
        model: "micro".to_string(),
        mode: Mode::Ours,
        scheme: Scheme::BitSerial,
        unit_channels: 8,
        b_pim_train: 7,
        steps,
        lr: 0.05,
        train_size: 64,
        test_size: 32,
        ..Default::default()
    }
}

/// Run `steps` acquire→step iterations at the given pipeline settings and
/// return (per-step losses, one PIM conv's final weights) for bitwise
/// comparison.
fn run_loop(ds: &Dataset, prefetch: usize, shards: usize, steps: usize) -> (Vec<f32>, Vec<f32>) {
    let m = micro_manifest();
    let job = micro_job(steps);
    let mut trainer = NativeTrainer::new(&m, &job).unwrap();
    let cfg = LoaderCfg {
        batch: 8,
        augment: true,
        flip: false,
        seed: 77,
        prefetch,
        shards,
        stream_stride: 1,
        stream_offset: 0,
    };
    let losses = with_loader(ds, cfg, |loader| {
        let mut losses = Vec::new();
        for step in 0..steps {
            let (x, y) = loader.next().unwrap();
            let mut srng = Rng::new(step as u64 ^ 0x5EED);
            let (loss, _) = trainer.train_step(x, y, 0.05, &mut srng).unwrap();
            losses.push(loss);
        }
        losses
    })
    .unwrap();
    let ckpt = trainer.into_checkpoint(&job);
    let w = ckpt.params_map().get("s0b0/conv1/w").unwrap().data.clone();
    (losses, w)
}

#[test]
fn pipelined_loop_bit_identical_to_serial_loop() {
    // 4 steps over 24 samples at batch 8: the loop crosses an epoch
    // boundary, so reshuffle timing under prefetch is on the path too
    let ds = synth::generate(8, 4, 24, 9);
    let steps = 4;
    let (ref_losses, ref_w) = run_loop(&ds, 0, 1, steps);
    assert!(ref_losses.iter().all(|l| l.is_finite()));
    for &(prefetch, shards) in &[(0usize, 4usize), (1, 1), (1, 4), (2, 1), (2, 4)] {
        let (losses, w) = run_loop(&ds, prefetch, shards, steps);
        assert_eq!(
            losses, ref_losses,
            "losses diverged from the serial loop at prefetch={prefetch} shards={shards}"
        );
        assert_eq!(
            w, ref_w,
            "weights diverged from the serial loop at prefetch={prefetch} shards={shards}"
        );
    }
}

#[test]
fn augmentation_stream_independent_of_batch_composition() {
    let ds = synth::generate(8, 4, 16, 4);
    let aug = CounterRng::new(123);
    let sample = ds.images[0].len();
    let fill = |ids: &[usize], epoch: u64, step: u64| {
        let mut x = vec![0.0f32; ids.len() * sample];
        fill_samples(&ds, ids, epoch, step, &aug, true, false, &mut x);
        x
    };
    let base = fill(&[4, 5, 6, 7], 2, 11);
    // replace every *other* sample in the batch: sample 5 keeps its slot
    // and must keep its exact pixels
    let swapped = fill(&[0, 5, 1, 2], 2, 11);
    assert_eq!(
        &base[sample..2 * sample],
        &swapped[sample..2 * sample],
        "sample 5's augmentation changed when the rest of the batch changed"
    );
    // reorder: sample 5's pixels move with it, bit-for-bit
    let reordered = fill(&[7, 6, 5, 4], 2, 11);
    assert_eq!(&base[sample..2 * sample], &reordered[2 * sample..3 * sample]);
    // shard split: assembling the halves separately equals the whole
    let mut halves = fill(&[4, 5], 2, 11);
    halves.extend(fill(&[6, 7], 2, 11));
    assert_eq!(base, halves, "sharded assembly diverged from one-shot assembly");
}

#[test]
fn prefetch_zero_and_deep_pipelines_share_the_shuffle_stream() {
    // the shuffle Rng must advance identically whether epochs reshuffle
    // lazily (serial) or ahead of the consumer (deep prefetch): compare
    // the *label* streams, which are pure functions of the index draws
    let ds = synth::generate(8, 4, 20, 2);
    let labels = |prefetch: usize| {
        let cfg = LoaderCfg {
            batch: 8,
            augment: false,
            flip: false,
            seed: 3,
            prefetch,
            shards: 2,
            stream_stride: 1,
            stream_offset: 0,
        };
        with_loader(&ds, cfg, |l| {
            let mut seen = Vec::new();
            for _ in 0..8 {
                let (_, y) = l.next().unwrap();
                seen.extend_from_slice(y);
            }
            seen
        })
        .unwrap()
    };
    let serial = labels(0);
    for p in [1usize, 2, 4] {
        assert_eq!(labels(p), serial, "index/label stream diverged at prefetch={p}");
    }
}
