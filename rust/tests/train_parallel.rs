//! Data-parallel driver parity suite (§Perf L3.10, DESIGN.md §Data
//! parallelism).  The determinism contract under test:
//!
//! 1. The training trajectory is a pure function of the **slot count**
//!    (global batch), never the replica count: with noise *and* fault
//!    injection live in the graph, N ∈ {1, 2, 4} replicas over 4 slots
//!    produce bit-identical per-step losses and final weights — "N=1 at
//!    global batch k·B" is bitwise "N=k at batch B".
//! 2. Loader prefetch depth does not perturb the trajectory (the sharded
//!    streams inherit the serial loader's pipeline invariance).
//! 3. At one replica and one slot, the data-parallel driver *is* the
//!    serial driver: `run_job_parallel` reproduces `run_job_native`'s
//!    history, checkpoint, and software accuracy bitwise (the ×1/M mean
//!    is an f32 identity at M = 1).
//!
//! Shard-stream disjointness/coverage and the fixed-order tree-reduce vs
//! serial-fold equivalence are pinned by unit tests next to their
//! implementations (`data::loader`, `tensor::arena`).

use pim_qat::config::{JobConfig, Mode, Scheme};
use pim_qat::data::{synth, Dataset};
use pim_qat::runtime::Manifest;
use pim_qat::train::native::run_job_native;
use pim_qat::train::{run_job_parallel, with_parallel, ParallelCfg};

/// The down-scaled resnet geometry the native-trainer unit tests use,
/// rebuilt here (integration tests cannot reach the private helper).
fn micro_manifest() -> Manifest {
    let mut m = Manifest::builtin();
    let mut e = m.models.get("tiny").unwrap().clone();
    e.width = 4;
    e.image = 8;
    e.classes = 4;
    m.models.insert("micro".to_string(), e);
    m.batch = 8;
    m
}

/// PIM-QAT training with the full stochastic surface on: injected PIM
/// noise (mode=ours) *and* variability-aware fault training, so the test
/// covers every per-slot random stream the driver keys positionally.
fn micro_job(steps: usize) -> JobConfig {
    JobConfig {
        model: "micro".to_string(),
        mode: Mode::Ours,
        scheme: Scheme::BitSerial,
        unit_channels: 8,
        b_pim_train: 7,
        steps,
        lr: 0.05,
        train_size: 64,
        test_size: 16,
        faults: "mild:7".to_string(),
        ..Default::default()
    }
}

/// Drive `steps` global steps at the given shape and return (per-step
/// (loss bits, correct), full final parameter state) for bitwise
/// comparison.
fn run_steps(
    ds: &Dataset,
    job: &JobConfig,
    replicas: usize,
    slots: usize,
    prefetch: Option<usize>,
) -> (Vec<(u32, usize)>, Vec<(String, Vec<u32>)>) {
    let m = micro_manifest();
    let mut pcfg = ParallelCfg::new(replicas);
    pcfg.slots = slots;
    pcfg.prefetch = prefetch;
    with_parallel(&m, job, ds, &pcfg, |pt| {
        let mut logs = Vec::new();
        for _ in 0..job.steps {
            let (loss, correct) = pt.step(job.lr).unwrap();
            assert!(loss.is_finite(), "micro job must train stably");
            logs.push((loss.to_bits(), correct));
        }
        let params = pt
            .checkpoint(job)
            .params_map()
            .into_iter()
            .map(|(k, t)| (k, t.data.iter().map(|v| v.to_bits()).collect()))
            .collect();
        (logs, params)
    })
    .unwrap()
}

#[test]
fn trajectory_is_a_pure_function_of_the_slot_count() {
    // 5 steps x 4 slots x batch 8 over 64 samples: the global stream
    // crosses epoch boundaries, so reshuffle timing under sharding is on
    // the path too
    let ds = synth::generate(8, 4, 64, 9);
    let job = micro_job(5);
    let (ref_logs, ref_params) = run_steps(&ds, &job, 1, 4, None);
    for replicas in [2usize, 4] {
        let (logs, params) = run_steps(&ds, &job, replicas, 4, None);
        assert_eq!(
            logs, ref_logs,
            "per-step (loss, correct) diverged from 1 replica at {replicas} replicas"
        );
        assert_eq!(
            params, ref_params,
            "final weights diverged from 1 replica at {replicas} replicas"
        );
    }
}

#[test]
fn prefetch_depth_does_not_change_the_trajectory() {
    let ds = synth::generate(8, 4, 64, 9);
    let job = micro_job(4);
    let serial = run_steps(&ds, &job, 2, 4, Some(0));
    for p in [1usize, 2] {
        assert_eq!(
            run_steps(&ds, &job, 2, 4, Some(p)),
            serial,
            "trajectory diverged at prefetch={p}"
        );
    }
}

#[test]
fn single_slot_parallel_is_bitwise_the_serial_driver() {
    let m = micro_manifest();
    let train = synth::generate(8, 4, 64, 9);
    let test = synth::generate(8, 4, 16, 10);
    let job = micro_job(5);
    let serial = run_job_native(&m, &job, &train, &test, 2).unwrap();
    let par = run_job_parallel(&m, &job, &train, &test, 2, &ParallelCfg::new(1)).unwrap();

    assert_eq!(serial.history.len(), par.history.len(), "history cadence");
    for (a, b) in serial.history.iter().zip(&par.history) {
        assert_eq!(a.step, b.step);
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "loss diverged at step {}", a.step);
        assert_eq!(a.acc.to_bits(), b.acc.to_bits(), "batch acc diverged at step {}", a.step);
        assert_eq!(a.lr.to_bits(), b.lr.to_bits());
    }
    let sp = serial.ckpt.params_map();
    let pp = par.ckpt.params_map();
    assert_eq!(
        sp.keys().collect::<Vec<_>>(),
        pp.keys().collect::<Vec<_>>(),
        "parameter sets differ"
    );
    for (name, t) in &sp {
        let bits = |t: &pim_qat::tensor::Tensor| {
            t.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        };
        assert_eq!(bits(t), bits(&pp[name]), "weights diverged for {name}");
    }
    assert_eq!(serial.software_acc.to_bits(), par.software_acc.to_bits());
}
