//! Serving-layer integration tests: replica-isolation parity, dynamic
//! batcher semantics (latency budget, backpressure), and shutdown drain.
//!
//! The parity contract (DESIGN.md §Serving layer): on a *noiseless* chip,
//! a request's answer is bitwise independent of how the batcher coalesced
//! it and which other requests shared its batch — replica `i`'s farm
//! output equals a standalone engine carrying the same fault replica,
//! at any replica count and any producer concurrency.

use std::sync::OnceLock;
use std::time::{Duration, Instant};

use pim_qat::chip::{ChipModel, FaultProfile};
use pim_qat::config::{JobConfig, Mode, Scheme};
use pim_qat::data::{synth, Dataset};
use pim_qat::runtime::Manifest;
use pim_qat::serve::{Farm, FarmServer, Pending, Replica, ReplicaCfg, ServeCfg};
use pim_qat::train::{native::run_job_native, Checkpoint};

fn micro_manifest() -> Manifest {
    let mut m = Manifest::builtin();
    let mut e = m.models.get("tiny").unwrap().clone();
    e.width = 4;
    e.image = 8;
    e.classes = 4;
    // the cloned spec lists describe tiny's geometry — regenerate for micro
    let (pspecs, sspecs) = pim_qat::nn::init::param_specs(&e);
    e.param_paths = pspecs.iter().map(|(n, _)| n.clone()).collect();
    e.param_shapes = pspecs.into_iter().map(|(_, s)| s).collect();
    e.state_paths = sspecs.iter().map(|(n, _)| n.clone()).collect();
    e.state_shapes = sspecs.into_iter().map(|(_, s)| s).collect();
    m.models.insert("micro".to_string(), e);
    m.batch = 8;
    m
}

/// One shared 2-step micro checkpoint for every test in this file.
fn fixture() -> &'static (Manifest, Checkpoint) {
    static FIX: OnceLock<(Manifest, Checkpoint)> = OnceLock::new();
    FIX.get_or_init(|| {
        let m = micro_manifest();
        let job = JobConfig {
            model: "micro".to_string(),
            mode: Mode::Ours,
            scheme: Scheme::BitSerial,
            unit_channels: 8,
            b_pim_train: 7,
            steps: 2,
            lr: 0.05,
            train_size: 32,
            test_size: 16,
            ..Default::default()
        };
        let tr = synth::generate(8, 4, 32, 1);
        let te = synth::generate(8, 4, 16, 2);
        let res = run_job_native(&m, &job, &tr, &te, 1).unwrap();
        (m, res.ckpt)
    })
}

fn request_images(n: usize) -> Dataset {
    synth::generate(8, 4, n, 77)
}

/// A farm serving on noiseless faulty chips: the parity configuration.
fn parity_cfg() -> ReplicaCfg {
    ReplicaCfg {
        scheme: Scheme::BitSerial,
        unit_channels: 8,
        chip: ChipModel::ideal(7), // noiseless: determinism contract holds
        faults: Some(FaultProfile::severe()),
        seed: 42,
    }
}

/// Submit every image from `producers` threads, wait out all responses.
/// Returns (image index, response) pairs.
fn drive(
    server: &FarmServer,
    ds: &Dataset,
    producers: usize,
) -> Vec<(usize, pim_qat::serve::Response)> {
    let n = ds.len();
    let pending: Vec<(usize, Pending)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..producers)
            .map(|p| {
                s.spawn(move || {
                    (p..n)
                        .step_by(producers)
                        .map(|q| (q, server.submit(ds.images[q].clone()).expect("server open")))
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    pending.into_iter().map(|(q, p)| (q, p.wait())).collect()
}

#[test]
fn farm_output_is_bitwise_identical_to_standalone_replicas() {
    let (m, ckpt) = fixture();
    let cfg = parity_cfg();
    let ds = request_images(24);
    for &replicas in &[1usize, 2, 8] {
        for &producers in &[1usize, 4] {
            let farm = Farm::new(m, ckpt, &cfg, replicas).unwrap();
            let mut server = FarmServer::start(
                farm,
                ServeCfg {
                    batch: 4,
                    latency_budget: Duration::from_micros(500),
                    queue_cap: 16,
                },
            );
            let responses = drive(&server, &ds, producers);
            server.shutdown();
            assert_eq!(responses.len(), ds.len());
            // rebuild each chip that served as a standalone engine and
            // replay its requests one at a time — bitwise equal
            for (q, resp) in &responses {
                assert!((resp.chip_id as usize) < replicas);
                let mut lone = Replica::new(m, ckpt, &cfg, resp.chip_id).unwrap();
                let solo = lone.infer_one(&ds.images[*q]);
                assert_eq!(
                    solo, resp.logits,
                    "replicas={replicas} producers={producers} req={q} \
                     chip={}: farm answer differs from standalone",
                    resp.chip_id
                );
            }
        }
    }
}

#[test]
fn distinct_replicas_disagree_under_severe_faults() {
    // sanity check that the parity test is not vacuous: different chip
    // replicas carry different injuries and thus give different logits
    let (m, ckpt) = fixture();
    let cfg = parity_cfg();
    let ds = request_images(1);
    let mut a = Replica::new(m, ckpt, &cfg, 0).unwrap();
    let mut b = Replica::new(m, ckpt, &cfg, 1).unwrap();
    assert_ne!(a.infer_one(&ds.images[0]), b.infer_one(&ds.images[0]));
}

#[test]
fn coalescing_is_batch_composition_invariant() {
    // the same image answered identically whether it rode in a full batch
    // or nearly alone: run once with batch=8 producers=4 (coalesced) and
    // once with batch=1 (every request its own batch), single replica
    let (m, ckpt) = fixture();
    let cfg = parity_cfg();
    let ds = request_images(16);
    let mut by_batch: Vec<Vec<(usize, Vec<f32>)>> = Vec::new();
    for &(batch, producers) in &[(8usize, 4usize), (1, 1)] {
        let farm = Farm::new(m, ckpt, &cfg, 1).unwrap();
        let mut server = FarmServer::start(
            farm,
            ServeCfg {
                batch,
                latency_budget: Duration::from_millis(2),
                queue_cap: 16,
            },
        );
        let mut out: Vec<(usize, Vec<f32>)> = drive(&server, &ds, producers)
            .into_iter()
            .map(|(q, r)| (q, r.logits))
            .collect();
        server.shutdown();
        out.sort_by_key(|(q, _)| *q);
        by_batch.push(out);
    }
    assert_eq!(by_batch[0], by_batch[1]);
}

#[test]
fn partial_batch_flushes_at_the_latency_budget() {
    // batch far larger than the offered load: without the deadline the
    // server would wait forever for a full batch
    let (m, ckpt) = fixture();
    let farm = Farm::new(m, ckpt, &parity_cfg(), 1).unwrap();
    let mut server = FarmServer::start(
        farm,
        ServeCfg {
            batch: 64,
            latency_budget: Duration::from_millis(20),
            queue_cap: 64,
        },
    );
    let ds = request_images(3);
    let t0 = Instant::now();
    let pend: Vec<Pending> =
        (0..3).map(|q| server.submit(ds.images[q].clone()).unwrap()).collect();
    for p in pend {
        let r = p.wait();
        assert!(r.batch_size <= 3, "must not wait for 64 requests");
    }
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "deadline flush must beat any full-batch wait"
    );
    server.shutdown();
}

#[test]
fn over_capacity_load_applies_backpressure_not_drops() {
    // 64 requests through a 4-deep queue: submit blocks when full, and
    // every single request still gets its answer
    let (m, ckpt) = fixture();
    let farm = Farm::new(m, ckpt, &parity_cfg(), 2).unwrap();
    let mut server = FarmServer::start(
        farm,
        ServeCfg {
            batch: 4,
            latency_budget: Duration::from_micros(200),
            queue_cap: 4,
        },
    );
    let ds = request_images(64);
    let responses = drive(&server, &ds, 4);
    assert_eq!(responses.len(), 64, "backpressure must never drop a request");
    server.shutdown();
}

#[test]
fn shutdown_drains_every_inflight_request() {
    // shutdown races a backlog: every accepted request must still resolve
    let (m, ckpt) = fixture();
    let farm = Farm::new(m, ckpt, &parity_cfg(), 2).unwrap();
    let mut server = FarmServer::start(
        farm,
        ServeCfg {
            batch: 4,
            latency_budget: Duration::from_millis(50),
            queue_cap: 32,
        },
    );
    let ds = request_images(10);
    let pend: Vec<Pending> =
        (0..10).map(|q| server.submit(ds.images[q].clone()).unwrap()).collect();
    server.shutdown(); // close + drain + join, while most are still queued
    for p in pend {
        let r = p.wait();
        assert_eq!(r.logits.len(), 4, "drained response must be a real answer");
    }
    // admission is closed after shutdown
    assert!(server.submit(ds.images[0].clone()).is_none());
}

#[test]
fn drop_performs_the_same_drain_as_shutdown() {
    let (m, ckpt) = fixture();
    let farm = Farm::new(m, ckpt, &parity_cfg(), 1).unwrap();
    let server = FarmServer::start(
        farm,
        ServeCfg {
            batch: 8,
            latency_budget: Duration::from_millis(50),
            queue_cap: 16,
        },
    );
    let ds = request_images(5);
    let pend: Vec<Pending> =
        (0..5).map(|q| server.submit(ds.images[q].clone()).unwrap()).collect();
    drop(server);
    for p in pend {
        let _ = p.wait(); // must not hang or lose a request
    }
}

#[test]
fn eight_producer_stress_hammers_the_queue_without_loss() {
    let (m, ckpt) = fixture();
    let farm = Farm::new(m, ckpt, &parity_cfg(), 4).unwrap();
    let mut server = FarmServer::start(
        farm,
        ServeCfg {
            batch: 8,
            latency_budget: Duration::from_micros(300),
            queue_cap: 8,
        },
    );
    let ds = request_images(8);
    let total = 8 * 24;
    let responses: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..8)
            .map(|p| {
                let server = &server;
                let ds = &ds;
                s.spawn(move || {
                    (0..24)
                        .map(|i| server.submit(ds.images[(p + i) % 8].clone()).unwrap())
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .map(Pending::wait)
            .collect()
    });
    assert_eq!(responses.len(), total);
    // all four chips should have seen work under this much concurrency
    let mut served = [0usize; 4];
    for r in &responses {
        served[r.chip_id as usize] += 1;
    }
    assert_eq!(served.iter().sum::<usize>(), total);
    server.shutdown();
}
