//! Serving-layer integration tests: replica-isolation parity, dynamic
//! batcher semantics (latency budget, backpressure), shutdown drain, and
//! the PR 8 robustness surface — request TTLs, hedging, client patience,
//! and the health monitor's quarantine → recalibrate → reinstate ladder.
//!
//! The parity contract (DESIGN.md §Serving layer): on a *noiseless* chip,
//! a request's answer is bitwise independent of how the batcher coalesced
//! it and which other requests shared its batch — replica `i`'s farm
//! output equals a standalone engine carrying the same fault replica,
//! at any replica count and any producer concurrency.
//!
//! The chaos test calibrates its own quarantine threshold from standalone
//! measurements (injured disagreement before/after a bitwise-identical
//! standalone recalibration), so it asserts the recovery ladder the
//! determinism contract actually implies for this checkpoint instead of
//! hoping a fixed threshold lands between the two.

use std::sync::OnceLock;
use std::time::{Duration, Instant};

use pim_qat::chip::{ChipModel, FaultProfile};
use pim_qat::config::{JobConfig, Mode, Scheme};
use pim_qat::data::{synth, Dataset};
use pim_qat::runtime::Manifest;
use pim_qat::serve::{
    Farm, FarmServer, HealthCfg, HealthMonitor, Pending, Replica, ReplicaCfg, ReplicaState,
    Reply, ServeCfg,
};
use pim_qat::tensor::{ops, Tensor};
use pim_qat::train::{native::run_job_native, Checkpoint};

fn micro_manifest() -> Manifest {
    let mut m = Manifest::builtin();
    let mut e = m.models.get("tiny").unwrap().clone();
    e.width = 4;
    e.image = 8;
    e.classes = 4;
    // the cloned spec lists describe tiny's geometry — regenerate for micro
    let (pspecs, sspecs) = pim_qat::nn::init::param_specs(&e);
    e.param_paths = pspecs.iter().map(|(n, _)| n.clone()).collect();
    e.param_shapes = pspecs.into_iter().map(|(_, s)| s).collect();
    e.state_paths = sspecs.iter().map(|(n, _)| n.clone()).collect();
    e.state_shapes = sspecs.into_iter().map(|(_, s)| s).collect();
    m.models.insert("micro".to_string(), e);
    m.batch = 8;
    m
}

/// One shared 2-step micro checkpoint for every test in this file.
fn fixture() -> &'static (Manifest, Checkpoint) {
    static FIX: OnceLock<(Manifest, Checkpoint)> = OnceLock::new();
    FIX.get_or_init(|| {
        let m = micro_manifest();
        let job = JobConfig {
            model: "micro".to_string(),
            mode: Mode::Ours,
            scheme: Scheme::BitSerial,
            unit_channels: 8,
            b_pim_train: 7,
            steps: 2,
            lr: 0.05,
            train_size: 32,
            test_size: 16,
            ..Default::default()
        };
        let tr = synth::generate(8, 4, 32, 1);
        let te = synth::generate(8, 4, 16, 2);
        let res = run_job_native(&m, &job, &tr, &te, 1).unwrap();
        (m, res.ckpt)
    })
}

fn request_images(n: usize) -> Dataset {
    synth::generate(8, 4, n, 77)
}

fn images_seed(n: usize, seed: u64) -> Dataset {
    synth::generate(8, 4, n, seed)
}

/// A farm serving on noiseless faulty chips: the parity configuration.
fn parity_cfg() -> ReplicaCfg {
    ReplicaCfg {
        scheme: Scheme::BitSerial,
        unit_channels: 8,
        chip: ChipModel::ideal(7), // noiseless: determinism contract holds
        faults: Some(FaultProfile::severe()),
        faults_only: None,
        seed: 42,
    }
}

fn serve_cfg(batch: usize, budget: Duration, queue_cap: usize) -> ServeCfg {
    ServeCfg { batch, latency_budget: budget, queue_cap, hedge_after: None }
}

/// Submit every image from `producers` threads, wait out all responses.
/// Returns (image index, response) pairs.  Panics on any non-Answer reply
/// — the no-drops/no-hangs contract for TTL-less requests.
fn drive(
    server: &FarmServer,
    ds: &Dataset,
    producers: usize,
) -> Vec<(usize, pim_qat::serve::Response)> {
    let n = ds.len();
    let pending: Vec<(usize, Pending)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..producers)
            .map(|p| {
                s.spawn(move || {
                    (p..n)
                        .step_by(producers)
                        .map(|q| (q, server.submit(ds.images[q].clone()).expect("server open")))
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    pending.into_iter().map(|(q, p)| (q, p.wait().answer())).collect()
}

/// Argmax class of one image on a standalone replica, matching the farm's
/// tie-breaking exactly (`ops::argmax_rows`).
fn classify(rep: &mut Replica, image: &Tensor) -> usize {
    let logits = rep.infer_one(image);
    let n = logits.len();
    ops::argmax_rows(&Tensor::from_vec(&[1, n], logits))[0]
}

#[test]
fn farm_output_is_bitwise_identical_to_standalone_replicas() {
    let (m, ckpt) = fixture();
    let cfg = parity_cfg();
    let ds = request_images(24);
    for &replicas in &[1usize, 2, 8] {
        for &producers in &[1usize, 4] {
            let farm = Farm::new(m, ckpt, &cfg, replicas).unwrap();
            let mut server = FarmServer::start(
                farm,
                serve_cfg(4, Duration::from_micros(500), 16),
            );
            let responses = drive(&server, &ds, producers);
            server.shutdown();
            assert_eq!(responses.len(), ds.len());
            // rebuild each chip that served as a standalone engine and
            // replay its requests one at a time — bitwise equal
            for (q, resp) in &responses {
                assert!((resp.chip_id as usize) < replicas);
                let mut lone = Replica::new(m, ckpt, &cfg, resp.chip_id).unwrap();
                let solo = lone.infer_one(&ds.images[*q]);
                assert_eq!(
                    solo, resp.logits,
                    "replicas={replicas} producers={producers} req={q} \
                     chip={}: farm answer differs from standalone",
                    resp.chip_id
                );
            }
        }
    }
}

#[test]
fn distinct_replicas_disagree_under_severe_faults() {
    // sanity check that the parity test is not vacuous: different chip
    // replicas carry different injuries and thus give different logits
    let (m, ckpt) = fixture();
    let cfg = parity_cfg();
    let ds = request_images(1);
    let mut a = Replica::new(m, ckpt, &cfg, 0).unwrap();
    let mut b = Replica::new(m, ckpt, &cfg, 1).unwrap();
    assert_ne!(a.infer_one(&ds.images[0]), b.infer_one(&ds.images[0]));
}

#[test]
fn coalescing_is_batch_composition_invariant() {
    // the same image answered identically whether it rode in a full batch
    // or nearly alone: run once with batch=8 producers=4 (coalesced) and
    // once with batch=1 (every request its own batch), single replica
    let (m, ckpt) = fixture();
    let cfg = parity_cfg();
    let ds = request_images(16);
    let mut by_batch: Vec<Vec<(usize, Vec<f32>)>> = Vec::new();
    for &(batch, producers) in &[(8usize, 4usize), (1, 1)] {
        let farm = Farm::new(m, ckpt, &cfg, 1).unwrap();
        let mut server = FarmServer::start(farm, serve_cfg(batch, Duration::from_millis(2), 16));
        let mut out: Vec<(usize, Vec<f32>)> = drive(&server, &ds, producers)
            .into_iter()
            .map(|(q, r)| (q, r.logits))
            .collect();
        server.shutdown();
        out.sort_by_key(|(q, _)| *q);
        by_batch.push(out);
    }
    assert_eq!(by_batch[0], by_batch[1]);
}

#[test]
fn partial_batch_flushes_at_the_latency_budget() {
    // batch far larger than the offered load: without the deadline the
    // server would wait forever for a full batch
    let (m, ckpt) = fixture();
    let farm = Farm::new(m, ckpt, &parity_cfg(), 1).unwrap();
    let mut server = FarmServer::start(farm, serve_cfg(64, Duration::from_millis(20), 64));
    let ds = request_images(3);
    let t0 = Instant::now();
    let pend: Vec<Pending> =
        (0..3).map(|q| server.submit(ds.images[q].clone()).unwrap()).collect();
    for p in pend {
        let r = p.wait().answer();
        assert!(r.batch_size <= 3, "must not wait for 64 requests");
    }
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "deadline flush must beat any full-batch wait"
    );
    server.shutdown();
}

#[test]
fn over_capacity_load_applies_backpressure_not_drops() {
    // 64 requests through a 4-deep queue: submit blocks when full, and
    // every single request still gets its answer
    let (m, ckpt) = fixture();
    let farm = Farm::new(m, ckpt, &parity_cfg(), 2).unwrap();
    let mut server = FarmServer::start(farm, serve_cfg(4, Duration::from_micros(200), 4));
    let ds = request_images(64);
    let responses = drive(&server, &ds, 4);
    assert_eq!(responses.len(), 64, "backpressure must never drop a request");
    server.shutdown();
}

#[test]
fn shutdown_drains_every_inflight_request() {
    // shutdown races a backlog: every accepted request must still resolve
    let (m, ckpt) = fixture();
    let farm = Farm::new(m, ckpt, &parity_cfg(), 2).unwrap();
    let mut server = FarmServer::start(farm, serve_cfg(4, Duration::from_millis(50), 32));
    let ds = request_images(10);
    let pend: Vec<Pending> =
        (0..10).map(|q| server.submit(ds.images[q].clone()).unwrap()).collect();
    server.shutdown(); // close + drain + join, while most are still queued
    for p in pend {
        let r = p.wait().answer();
        assert_eq!(r.logits.len(), 4, "drained response must be a real answer");
    }
    // admission is closed after shutdown
    assert!(server.submit(ds.images[0].clone()).is_none());
}

#[test]
fn drop_performs_the_same_drain_as_shutdown() {
    let (m, ckpt) = fixture();
    let farm = Farm::new(m, ckpt, &parity_cfg(), 1).unwrap();
    let server = FarmServer::start(farm, serve_cfg(8, Duration::from_millis(50), 16));
    let ds = request_images(5);
    let pend: Vec<Pending> =
        (0..5).map(|q| server.submit(ds.images[q].clone()).unwrap()).collect();
    drop(server);
    for p in pend {
        assert!(p.wait().is_answer(), "must not hang or lose a request");
    }
}

#[test]
fn eight_producer_stress_hammers_the_queue_without_loss() {
    let (m, ckpt) = fixture();
    let farm = Farm::new(m, ckpt, &parity_cfg(), 4).unwrap();
    let mut server = FarmServer::start(farm, serve_cfg(8, Duration::from_micros(300), 8));
    let ds = request_images(8);
    let total = 8 * 24;
    let responses: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..8)
            .map(|p| {
                let server = &server;
                let ds = &ds;
                s.spawn(move || {
                    (0..24)
                        .map(|i| server.submit(ds.images[(p + i) % 8].clone()).unwrap())
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .map(|p| p.wait().answer())
            .collect()
    });
    assert_eq!(responses.len(), total);
    // all four chips should have seen work under this much concurrency
    let mut served = [0usize; 4];
    for r in &responses {
        served[r.chip_id as usize] += 1;
    }
    assert_eq!(served.iter().sum::<usize>(), total);
    server.shutdown();
}

#[test]
fn expired_ttl_requests_get_explicit_timeout_not_stale_service() {
    let (m, ckpt) = fixture();
    let farm = Farm::new(m, ckpt, &parity_cfg(), 1).unwrap();
    let mut server = FarmServer::start(farm, serve_cfg(4, Duration::from_micros(500), 16));
    let ds = request_images(8);
    // TTL zero: already expired when the dispatcher looks — deterministic
    let doomed: Vec<Pending> = (0..4)
        .map(|q| server.submit_with_ttl(ds.images[q].clone(), Some(Duration::ZERO)).unwrap())
        .collect();
    let healthy: Vec<Pending> =
        (4..8).map(|q| server.submit(ds.images[q].clone()).unwrap()).collect();
    server.shutdown();
    for p in doomed {
        match p.wait() {
            Reply::Timeout { .. } => {}
            other => panic!("expired request must resolve to Timeout, got {other:?}"),
        }
    }
    for p in healthy {
        assert!(p.wait().is_answer(), "TTL-less requests are unaffected");
    }
}

#[test]
fn wait_timeout_gives_up_on_a_slow_response_and_returns_one_in_time() {
    let (m, ckpt) = fixture();
    let farm = Farm::new(m, ckpt, &parity_cfg(), 1).unwrap();
    // batch 64 with a 10s budget: a single request cannot be answered
    // until the budget flush, so a short client patience must expire
    let mut server = FarmServer::start(farm, serve_cfg(64, Duration::from_secs(10), 64));
    let ds = request_images(2);
    let p = server.submit(ds.images[0].clone()).unwrap();
    let t0 = Instant::now();
    assert!(
        p.wait_timeout(Duration::from_millis(50)).is_none(),
        "patience must expire before the 10s batch budget"
    );
    assert!(t0.elapsed() < Duration::from_secs(5));
    // a served request resolves well within a generous patience
    let p = server.submit(ds.images[1].clone()).unwrap();
    server.shutdown(); // close → flush partial batch immediately
    let reply = p.wait_timeout(Duration::from_secs(30)).expect("farm is alive");
    assert!(reply.is_answer());
}

#[test]
fn hedged_batches_keep_first_wins_per_chip_parity() {
    let (m, ckpt) = fixture();
    let cfg = parity_cfg();
    let farm = Farm::new(m, ckpt, &cfg, 2).unwrap();
    // hedge_after zero: every in-flight batch is eligible immediately, so
    // the idle partner replays nearly every batch — maximum hedging
    let mut server = FarmServer::start(
        farm,
        ServeCfg {
            batch: 4,
            latency_budget: Duration::from_micros(300),
            queue_cap: 16,
            hedge_after: Some(Duration::ZERO),
        },
    );
    let ds = request_images(32);
    let responses = drive(&server, &ds, 4);
    server.shutdown();
    assert_eq!(responses.len(), 32, "hedging must not drop or double-resolve");
    // whichever replica won each race, the answer is bitwise that
    // replica's standalone answer — the determinism contract under hedging
    for (q, resp) in &responses {
        let mut lone = Replica::new(m, ckpt, &cfg, resp.chip_id).unwrap();
        assert_eq!(
            lone.infer_one(&ds.images[*q]),
            resp.logits,
            "req {q}: hedged winner chip {} differs from standalone",
            resp.chip_id
        );
    }
}

#[test]
fn last_replica_in_rotation_is_never_quarantined() {
    let (m, ckpt) = fixture();
    let cfg = parity_cfg();
    let mut farm = Farm::new(m, ckpt, &cfg, 1).unwrap();
    let hcfg = HealthCfg {
        probe_every: 1,
        // impossible threshold: every probe breaches, every round
        quarantine_threshold: -1.0,
        quarantine_after: 2,
        drift_alert: f64::INFINITY,
        ..Default::default()
    };
    let monitor =
        HealthMonitor::new(m, ckpt, &cfg, 1, &images_seed(8, 99), images_seed(64, 123), hcfg)
            .unwrap();
    farm.attach_health(monitor);
    let mut server = FarmServer::start(farm, serve_cfg(4, Duration::from_micros(300), 16));
    let ds = request_images(32);
    let responses = drive(&server, &ds, 2);
    assert_eq!(responses.len(), 32, "a deferred quarantine must not drop requests");
    let snap = server.health_snapshot().unwrap();
    server.shutdown();
    let row = &snap.rows[0];
    assert_eq!(row.state, ReplicaState::Suspect, "held at Suspect, never quarantined");
    assert!(
        snap.ladder(0)
            .iter()
            .all(|(_, to)| !matches!(to, ReplicaState::Quarantined | ReplicaState::Retired)),
        "the rotation must never empty: {:?}",
        snap.transitions
    );
}

/// The chaos test: one severe replica among healthy ones is detected by
/// probe disagreement, quarantined out of rotation, recalibrated in
/// service via the §3.4 BN mechanism, and reinstated — while every
/// accepted request is answered and the healthy replicas keep bitwise
/// parity with their standalone engines.
#[test]
fn chaos_severe_replica_heals_while_farm_serves_every_request() {
    let (m, ckpt) = fixture();
    let replicas = 3usize;
    let mut cfg = parity_cfg();
    cfg.faults_only = Some(1); // chips 0 and 2 pristine, chip 1 severe
    let probe_ds = images_seed(8, 99);
    let calib_ds = images_seed(64, 123);
    let recal_seed = 0xC0FFEE;
    let (calib_batch, calib_batches) = (8usize, 4usize);

    // ---- standalone measurements the farm must reproduce bitwise ----
    // reference answers (pristine stack, same checkpoint)
    let ref_cfg = ReplicaCfg { faults: None, ..cfg.clone() };
    let mut reference = Replica::new(m, ckpt, &ref_cfg, replicas as u64).unwrap();
    let ref_classes: Vec<usize> =
        probe_ds.images.iter().map(|im| classify(&mut reference, im)).collect();
    let disagreement = |rep: &mut Replica| -> f64 {
        let n = probe_ds.len();
        let diff = probe_ds
            .images
            .iter()
            .zip(&ref_classes)
            .filter(|(im, r)| classify(rep, im) != **r)
            .count();
        diff as f64 / n as f64
    };
    // injured disagreement before and after the exact recalibration the
    // farm will run (same calib shard, batch schedule, and seed)
    let mut injured = Replica::new(m, ckpt, &cfg, 1).unwrap();
    let d_pre = disagreement(&mut injured);
    injured.recalibrate(&calib_ds, calib_batch, calib_batches, recal_seed).unwrap();
    let d_post = disagreement(&mut injured);

    // adaptive threshold: guaranteed between the injured and recovered
    // disagreement, so the ladder this checkpoint implies is decidable
    enum Expect {
        NoAction,
        Reinstated,
        Retired,
    }
    let (threshold, expect) = if d_pre == 0.0 {
        (0.25, Expect::NoAction) // injury invisible to the probe: no-op run
    } else if d_post < d_pre {
        ((d_pre + d_post) / 2.0, Expect::Reinstated)
    } else {
        (d_pre / 2.0, Expect::Retired) // recalibration cannot help here
    };

    // ---- the farm under test ----
    let hcfg = HealthCfg {
        probe_every: 2,
        quarantine_threshold: threshold,
        quarantine_after: 2,
        recal_retries: 2,
        probe_images: probe_ds.len(),
        calib_batch,
        calib_batches,
        recal_seed,
        drift_alert: f64::INFINITY, // decide on probes alone — deterministic
    };
    let mut farm = Farm::new(m, ckpt, &cfg, replicas).unwrap();
    let monitor =
        HealthMonitor::new(m, ckpt, &cfg, replicas, &probe_ds, calib_ds.clone(), hcfg).unwrap();
    farm.attach_health(monitor);
    let server = FarmServer::start(farm, serve_cfg(4, Duration::from_micros(500), 16));

    // standalone twins of the healthy replicas for the parity check
    let mut lone0 = Replica::new(m, ckpt, &cfg, 0).unwrap();
    let mut lone2 = Replica::new(m, ckpt, &cfg, 2).unwrap();

    let ds = request_images(24);
    let deadline = Instant::now() + Duration::from_secs(120);
    let mut total = 0usize;
    loop {
        // keep traffic flowing: probes are cadenced on dispatched batches
        let responses = drive(&server, &ds, 2);
        assert_eq!(responses.len(), ds.len(), "zero drops, zero hangs — always");
        total += responses.len();
        for (q, resp) in &responses {
            // healthy replicas keep bitwise standalone parity throughout
            // the chaos (chip 1's BN state legitimately changes on recal)
            match resp.chip_id {
                0 => assert_eq!(lone0.infer_one(&ds.images[*q]), resp.logits),
                2 => assert_eq!(lone2.infer_one(&ds.images[*q]), resp.logits),
                _ => {}
            }
        }
        let snap = server.health_snapshot().unwrap();
        let done = snap.rows[1].state == ReplicaState::Retired
            || snap
                .ladder(1)
                .iter()
                .any(|(_, to)| *to == ReplicaState::Reinstated);
        let no_action_settled =
            matches!(expect, Expect::NoAction) && snap.rows[1].probes >= 3;
        if done || no_action_settled {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "recovery ladder did not complete: {:?}",
            snap.transitions
        );
    }
    let snap = server.health_snapshot().unwrap();
    let mut server = server;
    server.shutdown();

    // healthy replicas were never even suspected: their probe disagreement
    // against the pristine reference is exactly zero on a noiseless chip
    for chip in [0u64, 2] {
        assert_eq!(
            snap.rows[chip as usize].state,
            ReplicaState::Healthy,
            "healthy chip {chip} must stay Healthy: {:?}",
            snap.transitions
        );
        assert!(snap.ladder(chip).is_empty());
        assert_eq!(snap.rows[chip as usize].last_disagreement, Some(0.0));
    }
    assert!(total >= ds.len());

    use ReplicaState::*;
    let ladder = snap.ladder(1);
    match expect {
        Expect::NoAction => {
            assert!(
                ladder.is_empty(),
                "probe-invisible injury must cause no transitions: {ladder:?}"
            );
            assert_eq!(snap.rows[1].state, Healthy);
        }
        Expect::Reinstated => {
            // the full recovery ladder, in order; a trailing clean probe
            // may add Reinstated -> Healthy
            assert!(
                ladder.len() >= 4,
                "expected the full recovery ladder, got {ladder:?}"
            );
            assert_eq!(
                ladder[..4],
                [
                    (Healthy, Suspect),
                    (Suspect, Quarantined),
                    (Quarantined, Recalibrating),
                    (Recalibrating, Reinstated),
                ],
                "recovery ladder out of order"
            );
            assert!(
                matches!(snap.rows[1].state, Reinstated | Healthy),
                "chip 1 must be back in rotation, is {:?}",
                snap.rows[1].state
            );
            assert_eq!(snap.rows[1].recal_attempts, 1, "first attempt must succeed (bitwise)");
        }
        Expect::Retired => {
            assert_eq!(
                ladder[..3],
                [(Healthy, Suspect), (Suspect, Quarantined), (Quarantined, Recalibrating)],
            );
            // attempt 1 fails bitwise; attempt 2 (different calib seed) is
            // deterministic but unmeasured here — accept either terminal
            let terminal = snap.rows[1].state;
            assert!(
                matches!(terminal, Retired | Reinstated | Healthy),
                "chip 1 must reach a terminal state, is {terminal:?}"
            );
            assert!(snap.rows[1].recal_attempts >= 1);
        }
    }
}
